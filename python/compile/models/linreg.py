"""L2 linear-regression model on the fused Pallas linreg kernel.

The paper's exact-fault-tolerance property (Def. 1) is checkable in
closed form on this workload: the synthetic data generator (Rust side,
rust/src/data/linreg.rs) plants a known w*, and E7 verifies
||w_t - w*|| -> 0 under attack.
"""

from __future__ import annotations

from ..kernels import linreg as klinreg


def grad_fn(theta, x, y):
    """(theta [d], x [B, d], y [B]) -> (grad [d], loss [1])."""
    g, l = klinreg.linreg_grad(theta, x, y)
    return g, l.reshape((1,))


def loss_fn(theta, x, y):
    """(theta [d], x [B, d], y [B]) -> (loss [1],)."""
    return (klinreg.linreg_loss(theta, x, y).reshape((1,)),)


def param_dim(d: int) -> int:
    return d
