"""L2 byte-level decoder-only transformer LM.

The end-to-end workload (EXPERIMENTS.md §E2E): the Rust master trains
this model through the full three-layer stack with Byzantine workers
active. Forward runs on the Pallas attention + matmul kernels
(custom_vjp wrappers keep jax.grad exact); the whole fwd+bwd lowers
into one HLO module per (config, batch).

Architecture: pre-LN GPT — embed + learned pos, L x [LN, causal MHA,
residual, LN, gelu MLP, residual], final LN, untied unembed. Next-token
cross-entropy over tokens[:, :-1] -> tokens[:, 1:].
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..kernels.attention import attention_ad
from ..kernels.matmul import matmul_ad
from .common import Packer


@dataclass(frozen=True)
class TransformerConfig:
    vocab: int = 256
    seq_len: int = 64          # T (includes the shifted-off target position)
    d_model: int = 64
    heads: int = 4
    layers: int = 2
    mlp_mult: int = 4

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.heads == 0
        return self.d_model // self.heads


def make_packer(cfg: TransformerConfig) -> Packer:
    p = Packer()
    p.add("embed", (cfg.vocab, cfg.d_model))
    p.add("pos", (cfg.seq_len, cfg.d_model))
    for i in range(cfg.layers):
        p.add(f"l{i}.ln1_s", (cfg.d_model,))
        p.add(f"l{i}.ln1_b", (cfg.d_model,))
        p.add(f"l{i}.wq", (cfg.d_model, cfg.d_model))
        p.add(f"l{i}.wk", (cfg.d_model, cfg.d_model))
        p.add(f"l{i}.wv", (cfg.d_model, cfg.d_model))
        p.add(f"l{i}.wo", (cfg.d_model, cfg.d_model))
        p.add(f"l{i}.ln2_s", (cfg.d_model,))
        p.add(f"l{i}.ln2_b", (cfg.d_model,))
        p.add(f"l{i}.w_up", (cfg.d_model, cfg.mlp_mult * cfg.d_model))
        p.add(f"l{i}.b_up", (cfg.mlp_mult * cfg.d_model,))
        p.add(f"l{i}.w_down", (cfg.mlp_mult * cfg.d_model, cfg.d_model))
        p.add(f"l{i}.b_down", (cfg.d_model,))
    p.add("lnf_s", (cfg.d_model,))
    p.add("lnf_b", (cfg.d_model,))
    p.add("unembed", (cfg.d_model, cfg.vocab))
    return p


def _layernorm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def _mm(x2d, w):
    """Pallas matmul over a [N, K] x [K, M] pair (differentiable)."""
    return matmul_ad(x2d, w)


def forward(cfg: TransformerConfig, params: list, tokens: jax.Array):
    """tokens int32 [B, T] -> logits [B, T-1, vocab] over positions 0..T-2."""
    b, t = tokens.shape
    it = iter(params)
    embed, pos = next(it), next(it)
    x = embed[tokens[:, :-1]] + pos[: t - 1]          # [B, T-1, D]
    tm1 = t - 1
    d = cfg.d_model
    for _ in range(cfg.layers):
        ln1_s, ln1_b = next(it), next(it)
        wq, wk, wv, wo = next(it), next(it), next(it), next(it)
        ln2_s, ln2_b = next(it), next(it)
        w_up, b_up, w_down, b_down = next(it), next(it), next(it), next(it)

        h = _layernorm(x, ln1_s, ln1_b)
        h2 = h.reshape(b * tm1, d)
        q = _mm(h2, wq).reshape(b, tm1, cfg.heads, cfg.head_dim)
        k = _mm(h2, wk).reshape(b, tm1, cfg.heads, cfg.head_dim)
        v = _mm(h2, wv).reshape(b, tm1, cfg.heads, cfg.head_dim)
        # [B, T-1, H, dh] -> [B*H, T-1, dh]
        q = q.transpose(0, 2, 1, 3).reshape(b * cfg.heads, tm1, cfg.head_dim)
        k = k.transpose(0, 2, 1, 3).reshape(b * cfg.heads, tm1, cfg.head_dim)
        v = v.transpose(0, 2, 1, 3).reshape(b * cfg.heads, tm1, cfg.head_dim)
        o = attention_ad(q, k, v)                      # causal
        o = (
            o.reshape(b, cfg.heads, tm1, cfg.head_dim)
            .transpose(0, 2, 1, 3)
            .reshape(b * tm1, d)
        )
        x = x + _mm(o, wo).reshape(b, tm1, d)

        h = _layernorm(x, ln2_s, ln2_b)
        u = _mm(h.reshape(b * tm1, d), w_up) + b_up
        u = jax.nn.gelu(u)
        x = x + (_mm(u, w_down) + b_down).reshape(b, tm1, d)

    lnf_s, lnf_b = next(it), next(it)
    unembed = next(it)
    x = _layernorm(x, lnf_s, lnf_b)
    logits = _mm(x.reshape(b * tm1, d), unembed).reshape(b, tm1, cfg.vocab)
    return logits


def loss_from_logits(logits, tokens):
    """Mean next-token cross-entropy."""
    targets = tokens[:, 1:]                            # [B, T-1]
    logz = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - picked)


def make_fns(cfg: TransformerConfig):
    """Return (grad_fn, loss_fn, packer) with the uniform artifact ABI."""
    packer = make_packer(cfg)

    def loss_of_theta(theta, tokens):
        params = packer.unpack(theta)
        return loss_from_logits(forward(cfg, params, tokens), tokens)

    def grad_fn(theta, tokens):
        """(theta [P], tokens [B, T] i32) -> (grad [P], loss [1])."""
        loss, g = jax.value_and_grad(loss_of_theta)(theta, tokens)
        return g, loss.reshape((1,))

    def loss_fn(theta, tokens):
        return (loss_of_theta(theta, tokens).reshape((1,)),)

    return grad_fn, loss_fn, packer
