"""Shared utilities for the L2 models: flat-parameter packing.

Every AOT artifact exposes the uniform interface the Rust runtime
expects (see rust/src/runtime/manifest.rs):

    grad   : (theta [P] f32, *data) -> (grad [P] f32, loss [1] f32)
    loss   : (theta [P] f32, *data) -> (loss [1] f32,)
    update : (theta [P] f32, grad [P] f32, lr [1] f32) -> (theta' [P],)

``Packer`` maps between the flat theta vector and the model's
structured parameter arrays with static offsets, so the unflatten is
free at HLO level (slices + reshapes fused by XLA).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax.numpy as jnp


@dataclass
class Packer:
    """Static flat-vector <-> pytree-of-arrays packing."""

    shapes: list = field(default_factory=list)
    names: list = field(default_factory=list)

    def add(self, name: str, shape) -> None:
        self.shapes.append(tuple(shape))
        self.names.append(name)

    @property
    def size(self) -> int:
        return sum(math.prod(s) for s in self.shapes)

    def unpack(self, theta):
        """Split flat [P] theta into the declared arrays."""
        out, off = [], 0
        for s in self.shapes:
            n = math.prod(s)
            out.append(jnp.reshape(theta[off : off + n], s))
            off += n
        return out

    def pack(self, arrays):
        return jnp.concatenate([jnp.reshape(a, (-1,)) for a in arrays])

    def init(self, rng, scale_fn=None):
        """He-style init as a flat numpy-free jnp vector (for tests)."""
        import numpy as np

        chunks = []
        for s in self.shapes:
            if len(s) >= 2:
                std = 1.0 / math.sqrt(s[0])
                chunks.append(rng.normal(0.0, std, size=s).reshape(-1))
            else:
                chunks.append(np.zeros(math.prod(s)))
        flat = np.concatenate(chunks).astype("float32")
        return jnp.asarray(flat)
