"""L2 softmax-classifier MLP on the Pallas matmul path.

Workload for the classification experiments (E7 grid): Gaussian-blob
classes generated Rust-side, 2-layer relu MLP, hand-derived backprop
(kernels/mlp.py) so the lowered HLO contains only forward-style Pallas
matmuls.
"""

from __future__ import annotations

from ..kernels import mlp as kmlp
from .common import Packer


def make_packer(in_dim: int, hidden: int, classes: int) -> Packer:
    p = Packer()
    p.add("w1", (in_dim, hidden))
    p.add("b1", (hidden,))
    p.add("w2", (hidden, classes))
    p.add("b2", (classes,))
    return p


def grad_fn(packer: Packer):
    def f(theta, x, labels):
        """(theta [P], x [B, I], labels [B] i32) -> (grad [P], loss [1])."""
        w1, b1, w2, b2 = packer.unpack(theta)
        (dw1, db1, dw2, db2), loss = kmlp.mlp_grad(w1, b1, w2, b2, x, labels)
        return packer.pack((dw1, db1, dw2, db2)), loss.reshape((1,))

    return f


def loss_fn(packer: Packer):
    def f(theta, x, labels):
        w1, b1, w2, b2 = packer.unpack(theta)
        return (kmlp.mlp_loss(w1, b1, w2, b2, x, labels).reshape((1,)),)

    return f
