"""AOT pipeline: lower every (model, shape-config) to HLO text + manifest.

Run once at build time (``make artifacts``); the Rust runtime
(rust/src/runtime/) loads the outputs and Python never appears on the
training path again.

Interchange format is **HLO text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` crate binds) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .models import linreg as m_linreg
from .models import mlp as m_mlp
from .models import transformer as m_tfm
from .kernels import sgd as ksgd

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _tensor(name, s):
    return {
        "name": name,
        "dtype": "i32" if s.dtype == I32 else "f32",
        "shape": list(s.shape),
    }


class Registry:
    def __init__(self):
        self.entries = []  # (meta, fn, arg_specs)

    def add(self, name, kind, model, param_dim, fn, inputs, outputs):
        """inputs/outputs: list of (name, ShapeDtypeStruct)."""
        meta = {
            "name": name,
            "file": f"{name}.hlo.txt",
            "kind": kind,
            "model": model,
            "param_dim": param_dim,
            "inputs": [_tensor(n, s) for n, s in inputs],
            "outputs": [_tensor(n, s) for n, s in outputs],
        }
        self.entries.append((meta, fn, [s for _, s in inputs]))


def build_registry() -> Registry:
    reg = Registry()

    # ---------------- linear regression ----------------
    for d, b in [(64, 256), (256, 1024)]:
        reg.add(
            f"linreg_grad_d{d}_b{b}", "grad", "linreg", d,
            m_linreg.grad_fn,
            [("theta", spec([d])), ("x", spec([b, d])), ("y", spec([b]))],
            [("grad", spec([d])), ("loss", spec([1]))],
        )
        reg.add(
            f"linreg_loss_d{d}_b{b}", "loss", "linreg", d,
            m_linreg.loss_fn,
            [("theta", spec([d])), ("x", spec([b, d])), ("y", spec([b]))],
            [("loss", spec([1]))],
        )

    # ---------------- MLP classifier ----------------
    in_dim, hidden, classes, b = 32, 64, 4, 128
    packer = m_mlp.make_packer(in_dim, hidden, classes)
    p = packer.size
    reg.add(
        f"mlp_grad_i{in_dim}_h{hidden}_c{classes}_b{b}", "grad", "mlp", p,
        m_mlp.grad_fn(packer),
        [("theta", spec([p])), ("x", spec([b, in_dim])), ("labels", spec([b], I32))],
        [("grad", spec([p])), ("loss", spec([1]))],
    )
    reg.add(
        f"mlp_loss_i{in_dim}_h{hidden}_c{classes}_b{b}", "loss", "mlp", p,
        m_mlp.loss_fn(packer),
        [("theta", spec([p])), ("x", spec([b, in_dim])), ("labels", spec([b], I32))],
        [("loss", spec([1]))],
    )

    # ---------------- transformer LM ----------------
    cfg = m_tfm.TransformerConfig(
        vocab=256, seq_len=65, d_model=64, heads=4, layers=2, mlp_mult=4
    )
    tb = 8
    grad_fn, loss_fn, tpacker = m_tfm.make_fns(cfg)
    tp = tpacker.size
    reg.add(
        "tfm_grad_tiny", "grad", "transformer", tp,
        grad_fn,
        [("theta", spec([tp])), ("tokens", spec([tb, cfg.seq_len], I32))],
        [("grad", spec([tp])), ("loss", spec([1]))],
    )
    reg.add(
        "tfm_loss_tiny", "loss", "transformer", tp,
        loss_fn,
        [("theta", spec([tp])), ("tokens", spec([tb, cfg.seq_len], I32))],
        [("loss", spec([1]))],
    )

    # ---------------- optimizer updates (one per param_dim) ----------------
    def upd(theta, g, lr):
        return (ksgd.sgd_update(theta, g, lr),)

    for name, pd in [("linreg_d64", 64), ("linreg_d256", 256), ("mlp", p), ("tfm_tiny", tp)]:
        reg.add(
            f"sgd_{name}", "update", name, pd,
            upd,
            [("theta", spec([pd])), ("grad", spec([pd])), ("lr", spec([1]))],
            [("theta_out", spec([pd]))],
        )

    return reg


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated artifact names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    reg = build_registry()
    only = set(args.only.split(",")) if args.only else None
    manifest = {"version": 1, "artifacts": []}
    for meta, fn, arg_specs in reg.entries:
        manifest["artifacts"].append(meta)
        if only and meta["name"] not in only:
            continue
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, meta["file"])
        with open(path, "w") as f:
            f.write(text)
        print(f"  wrote {meta['name']}: {len(text)} chars, P={meta['param_dim']}")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(manifest['artifacts'])} artifacts -> {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
