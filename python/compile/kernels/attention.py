"""FlashAttention-style causal attention forward kernel.

The paper's workloads are gradient computations for generic models; the
transformer workload's hot spot is attention. The CUDA flash-attention
insight (never materialize the [T, T] score matrix in HBM; stream K/V
tiles through on-chip memory with an online softmax) maps to TPU as:
Q blocks are grid-parallel, K/V tiles stream HBM->VMEM via the inner
fori_loop, and the running (max, sum, acc) state lives in VMEM for the
duration of a Q block (DESIGN.md §Hardware-Adaptation).

Grid: (batch*heads, T/bq). Inner loop: T/bk K-tiles with causal
skipping — tiles strictly above the diagonal are never loaded.

VMEM per step (f32): bq*dh (q) + 2*bk*dh (k,v tile) + bq*bk (scores)
+ bq*(dh+2) (state); defaults bq=bk=128, dh<=128 -> ~0.4 MiB.

Backward is provided via custom_vjp against the jnp oracle (exact same
math), so jax.grad through the transformer stays exact while the
forward exercises the Pallas path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .matmul import _pick_block

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int, t: int, scale):
    iq = pl.program_id(1)
    q = q_ref[0]                                  # [bq, dh]
    dh = q.shape[-1]

    nk_done = (iq * bq + bq + bk - 1) // bk        # causal: tiles <= diagonal
    m0 = jnp.full((bq,), NEG_INF, dtype=jnp.float32)
    s0 = jnp.zeros((bq,), dtype=jnp.float32)
    a0 = jnp.zeros((bq, dh), dtype=jnp.float32)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)

    def body(j, carry):
        m, s, acc = carry
        k = jax.lax.dynamic_slice(k_ref[0], (j * bk, 0), (bk, dh))
        v = jax.lax.dynamic_slice(v_ref[0], (j * bk, 0), (bk, dh))
        scores = (
            jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        )                                          # [bq, bk]
        k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        scores = jnp.where(k_pos <= q_pos, scores, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
        p = jnp.exp(scores - m_new[:, None])       # [bq, bk]
        corr = jnp.exp(m - m_new)
        s_new = s * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        return m_new, s_new, acc_new

    m, s, acc = jax.lax.fori_loop(0, nk_done, body, (m0, s0, a0))
    o_ref[0] = (acc / s[:, None]).astype(o_ref.dtype)


@jax.jit
def attention(q: jax.Array, k: jax.Array, v: jax.Array):
    """Causal attention; q,k,v: [BH, T, dh] -> [BH, T, dh]."""
    bh, t, dh = q.shape
    bq = _pick_block(t)
    bk = bq
    scale = 1.0 / (dh ** 0.5)
    grid = (bh, t // bq)
    return pl.pallas_call(
        functools.partial(_attn_kernel, bq=bq, bk=bk, t=t, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, t, dh), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, t, dh), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, dh), q.dtype),
        interpret=True,
    )(q, k, v)


# ---------------------------------------------------------------------------
# differentiable wrapper
# ---------------------------------------------------------------------------


@jax.custom_vjp
def attention_ad(q, k, v):
    return attention(q, k, v)


def _attn_fwd(q, k, v):
    return attention(q, k, v), (q, k, v)


def _attn_bwd(res, do):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: ref.attention(q_, k_, v_, causal=True), q, k, v)
    return vjp(do)


attention_ad.defvjp(_attn_fwd, _attn_bwd)
