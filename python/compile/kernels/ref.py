"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are tested against (pytest +
hypothesis in ``python/tests/``). They are also used as the backward
rules for some ``jax.custom_vjp`` wrappers, which keeps autodiff exact
while the forward pass exercises the Pallas path.

All functions are shape-polymorphic and jit-safe.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

def matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """Plain f32-accumulated matmul, the oracle for kernels.matmul."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


# ---------------------------------------------------------------------------
# linear regression: 0.5 * mean((Xw - y)^2)
# ---------------------------------------------------------------------------

def linreg_loss(w: jax.Array, x: jax.Array, y: jax.Array) -> jax.Array:
    r = x @ w - y
    return 0.5 * jnp.mean(r * r)


def linreg_grad(w: jax.Array, x: jax.Array, y: jax.Array):
    """Return (grad, loss) for the half-MSE linear-regression objective.

    grad = X^T (Xw - y) / B, loss = 0.5 * mean((Xw - y)^2).
    """
    b = x.shape[0]
    r = x @ w - y
    grad = x.T @ r / b
    loss = 0.5 * jnp.mean(r * r)
    return grad, loss


# ---------------------------------------------------------------------------
# 2-layer MLP with relu + softmax cross-entropy
# ---------------------------------------------------------------------------

def mlp_forward(w1, b1, w2, b2, x):
    h = jnp.maximum(x @ w1 + b1, 0.0)
    return h @ w2 + b2


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean cross-entropy; labels are int class ids."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - picked)


def mlp_loss(w1, b1, w2, b2, x, labels):
    return softmax_xent(mlp_forward(w1, b1, w2, b2, x), labels)


def mlp_grad(w1, b1, w2, b2, x, labels):
    """Return ((dw1, db1, dw2, db2), loss) via closed-form backprop."""
    b = x.shape[0]
    z1 = x @ w1 + b1
    h = jnp.maximum(z1, 0.0)
    logits = h @ w2 + b2
    # softmax cross-entropy backward
    p = jax.nn.softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    dlogits = (p - onehot) / b
    dw2 = h.T @ dlogits
    db2 = jnp.sum(dlogits, axis=0)
    dh = dlogits @ w2.T
    dz1 = dh * (z1 > 0.0).astype(x.dtype)
    dw1 = x.T @ dz1
    db1 = jnp.sum(dz1, axis=0)
    loss = softmax_xent(logits, labels)
    return (dw1, db1, dw2, db2), loss


# ---------------------------------------------------------------------------
# scaled-dot-product attention (causal)
# ---------------------------------------------------------------------------

def attention(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True):
    """Oracle attention. q, k, v: [..., T, dh]."""
    dh = q.shape[-1]
    s = jnp.einsum("...qd,...kd->...qk", q, k) / jnp.sqrt(dh).astype(q.dtype)
    if causal:
        t = q.shape[-2]
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        s = jnp.where(mask, s, jnp.finfo(s.dtype).min)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("...qk,...kd->...qd", p, v)


# ---------------------------------------------------------------------------
# optimizer updates
# ---------------------------------------------------------------------------

def sgd_update(w: jax.Array, g: jax.Array, lr) -> jax.Array:
    return w - lr * g


def momentum_update(w: jax.Array, m: jax.Array, g: jax.Array, lr, beta):
    """Heavy-ball momentum. Returns (new_w, new_m)."""
    m2 = beta * m + g
    return w - lr * m2, m2
