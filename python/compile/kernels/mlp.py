"""Fused 2-layer-MLP gradient kernels.

The forward/backward dense ops run on the Pallas matmul path; the
cheap elementwise glue (relu mask, softmax) is jnp and fuses into the
same HLO module at AOT time. Backprop is written out by hand (no
jax.grad), mirroring ref.mlp_grad exactly — this keeps the lowered HLO
free of transpose-of-pallas_call constructs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .matmul import matmul


def mlp_grad(w1, b1, w2, b2, x, labels):
    """((dw1, db1, dw2, db2), loss) for relu-MLP + softmax xent.

    Shapes: x [B, I], w1 [I, H], b1 [H], w2 [H, C], b2 [C],
    labels int32 [B].
    """
    b = x.shape[0]
    z1 = matmul(x, w1) + b1                        # [B, H]
    h = jnp.maximum(z1, 0.0)
    logits = matmul(h, w2) + b2                    # [B, C]

    logz = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(logz - picked)

    p = jax.nn.softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    dlogits = (p - onehot) / b                     # [B, C]

    dw2 = matmul(h.T, dlogits)                     # [H, C]
    db2 = jnp.sum(dlogits, axis=0)
    dh = matmul(dlogits, w2.T)                     # [B, H]
    dz1 = dh * (z1 > 0.0).astype(x.dtype)
    dw1 = matmul(x.T, dz1)                         # [I, H]
    db1 = jnp.sum(dz1, axis=0)
    return (dw1, db1, dw2, db2), loss


def mlp_loss(w1, b1, w2, b2, x, labels):
    """Loss-only entry point (adaptive policy's observed loss)."""
    z1 = matmul(x, w1) + b1
    h = jnp.maximum(z1, 0.0)
    logits = matmul(h, w2) + b2
    logz = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - picked)
