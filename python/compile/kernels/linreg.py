"""Fused linear-regression gradient kernel.

Computes, in one Pallas pass over the batch,
    r     = X w - y                   (residual, stays in VMEM)
    grad += X_tile^T r_tile / B       (MXU matmul per tile)
    loss += 0.5 * sum(r_tile^2) / B
i.e. the master's per-data-point gradient work for the paper's linreg
workload. Grid is over batch tiles; the [d] gradient block and the [1]
loss block are revisited by every grid step (accumulator pattern), so
the HBM traffic is one read of X/y and one write of grad — the same
schedule a CUDA implementation would express with a threadblock
reduction, here expressed with BlockSpecs (DESIGN.md
§Hardware-Adaptation).

VMEM per step (f32): bb*d (X tile) + 2*bb + d floats; default
bb=128, d<=1024 -> ~0.5 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .matmul import _pick_block


def _linreg_kernel(x_ref, y_ref, w_ref, g_ref, l_ref, *, batch: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        l_ref[...] = jnp.zeros_like(l_ref)

    x = x_ref[...]                      # [bb, d]
    r = (
        jnp.dot(x, w_ref[...][:, None], preferred_element_type=jnp.float32)[:, 0]
        - y_ref[...]
    )                                   # [bb]
    g_ref[...] += jnp.dot(r[None, :], x, preferred_element_type=jnp.float32)[
        0
    ] / batch
    l_ref[...] += 0.5 * jnp.sum(r * r) / batch


@jax.jit
def linreg_grad(w: jax.Array, x: jax.Array, y: jax.Array):
    """Return (grad [d], loss []) for 0.5*mean((Xw-y)^2).

    Matches ref.linreg_grad to f32 accumulation order.
    """
    b, d = x.shape
    bb = _pick_block(b)
    grid = (b // bb,)
    grad, loss = pl.pallas_call(
        functools.partial(_linreg_kernel, batch=b),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, d), lambda i: (i, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d,), x.dtype),
            jax.ShapeDtypeStruct((1,), x.dtype),
        ],
        interpret=True,
    )(x, y, w)
    return grad, loss[0]


def linreg_loss(w: jax.Array, x: jax.Array, y: jax.Array) -> jax.Array:
    """Loss-only entry point (used by the master's adaptive policy)."""
    return linreg_grad(w, x, y)[1]


__all__ = ["linreg_grad", "linreg_loss", "ref"]
