"""Fused elementwise optimizer-update kernels.

Trivial arithmetic, but keeping the update inside the AOT module means
the Rust master never touches parameter math on the hot path — it just
feeds (w, g, lr) buffers to PJRT. Grid is over 1-D tiles so arbitrarily
large (flattened) parameter vectors stream through VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _pick_block


def _sgd_kernel(w_ref, g_ref, lr_ref, o_ref):
    o_ref[...] = w_ref[...] - lr_ref[0] * g_ref[...]


@jax.jit
def sgd_update(w: jax.Array, g: jax.Array, lr: jax.Array):
    """w' = w - lr*g over flat f32 vectors; lr is a [1] array."""
    (n,) = w.shape
    bn = _pick_block(n, 1024)
    return pl.pallas_call(
        _sgd_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), w.dtype),
        interpret=True,
    )(w, g, lr)


def _momentum_kernel(w_ref, m_ref, g_ref, hp_ref, ow_ref, om_ref):
    lr, beta = hp_ref[0], hp_ref[1]
    m2 = beta * m_ref[...] + g_ref[...]
    om_ref[...] = m2
    ow_ref[...] = w_ref[...] - lr * m2


@jax.jit
def momentum_update(w: jax.Array, m: jax.Array, g: jax.Array, hp: jax.Array):
    """Heavy-ball update; hp = [lr, beta]. Returns (w', m')."""
    (n,) = w.shape
    bn = _pick_block(n, 1024)
    return pl.pallas_call(
        _momentum_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), w.dtype),
            jax.ShapeDtypeStruct((n,), w.dtype),
        ],
        interpret=True,
    )(w, m, g, hp)
