"""Tiled matmul Pallas kernel — the MXU building block for every model.

TPU mapping (see DESIGN.md §Hardware-Adaptation): blocks are sized in
multiples of 128 on both MXU dimensions when shapes allow; the K grid
dimension is innermost so the output block stays resident in VMEM while
partial products accumulate (double-buffered HBM->VMEM streaming of the
A/B tiles is expressed by the BlockSpec index maps). On this image the
kernel runs under ``interpret=True`` (CPU) — the structure, not the
wallclock, is what carries to real hardware.

VMEM footprint per grid step (f32): bm*bk + bk*bn + bm*bn floats.
Default 128^2 * 3 * 4B = 192 KiB  <<  16 MiB VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default MXU-aligned tile edge.
TILE = 128


def _pick_block(dim: int, tile: int = TILE) -> int:
    """Largest divisor of ``dim`` that is <= tile (prefers MXU multiples)."""
    if dim >= tile and dim % tile == 0:
        return tile
    # fall back to the largest divisor <= tile
    best = 1
    for cand in range(1, min(dim, tile) + 1):
        if dim % cand == 0:
            best = cand
    return best


def _mm_kernel(a_ref, b_ref, o_ref, *, nk: int):
    """Grid = (M/bm, N/bn, K/bk); K innermost so o block is revisited."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(a: jax.Array, b: jax.Array, bm: int = 0, bn: int = 0, bk: int = 0):
    """C = A @ B with f32 accumulation.  A: [M, K], B: [K, N].

    Shapes need not be multiples of the tile size: blocks are chosen as
    divisors (``_pick_block``), so odd shapes degrade to smaller tiles
    rather than failing. hypothesis sweeps this in python/tests.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch {a.shape} @ {b.shape}"
    bm = bm or _pick_block(m)
    bn = bn or _pick_block(n)
    bk = bk or _pick_block(k)
    nk = k // bk
    grid = (m // bm, n // bn, nk)
    return pl.pallas_call(
        functools.partial(_mm_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(a, b)


# ---------------------------------------------------------------------------
# differentiable wrapper: forward on the Pallas path, backward as two more
# Pallas matmuls (dA = dC @ B^T, dB = A^T @ dC) — autodiff never has to
# look inside pallas_call.
# ---------------------------------------------------------------------------


@jax.custom_vjp
def matmul_ad(a: jax.Array, b: jax.Array) -> jax.Array:
    return matmul(a, b)


def _matmul_fwd(a, b):
    return matmul(a, b), (a, b)


def _matmul_bwd(res, dc):
    a, b = res
    da = matmul(dc, b.T)
    db = matmul(a.T, dc)
    return da, db


matmul_ad.defvjp(_matmul_fwd, _matmul_bwd)
