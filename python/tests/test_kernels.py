"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle.

hypothesis sweeps shapes (including non-tile-multiple shapes, which
exercise the _pick_block divisor fallback) and value distributions.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as ka
from compile.kernels import linreg as kl
from compile.kernels import matmul as km
from compile.kernels import mlp as kmlp
from compile.kernels import ref
from compile.kernels import sgd as ks

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def randf(rng, *shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


class TestMatmul:
    @given(
        m=st.integers(1, 200),
        k=st.integers(1, 200),
        n=st.integers(1, 200),
        seed=st.integers(0, 2**31),
    )
    def test_matches_oracle(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        a = randf(rng, m, k)
        b = randf(rng, k, n)
        got = km.matmul(a, b)
        want = ref.matmul(a, b)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_mxu_aligned_tiles(self):
        rng = np.random.default_rng(0)
        a = randf(rng, 256, 384)
        b = randf(rng, 384, 128)
        np.testing.assert_allclose(
            km.matmul(a, b), ref.matmul(a, b), rtol=1e-4, atol=1e-4
        )

    def test_block_picker_prefers_mxu_tiles(self):
        assert km._pick_block(256) == 128
        assert km._pick_block(128) == 128
        assert km._pick_block(96) == 96
        assert km._pick_block(100) == 100
        assert km._pick_block(130) == 65  # largest divisor <= 128
        assert km._pick_block(1) == 1

    def test_custom_vjp_backward(self):
        rng = np.random.default_rng(1)
        a = randf(rng, 32, 16)
        b = randf(rng, 16, 8)

        def f(a, b):
            return jnp.sum(km.matmul_ad(a, b) ** 2)

        ga, gb = jax.grad(f, argnums=(0, 1))(a, b)
        ga_ref, gb_ref = jax.grad(
            lambda a, b: jnp.sum(ref.matmul(a, b) ** 2), argnums=(0, 1)
        )(a, b)
        np.testing.assert_allclose(ga, ga_ref, rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(gb, gb_ref, rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# linreg
# ---------------------------------------------------------------------------


class TestLinreg:
    @given(
        b=st.integers(1, 300),
        d=st.integers(1, 100),
        seed=st.integers(0, 2**31),
    )
    def test_grad_and_loss_match(self, b, d, seed):
        rng = np.random.default_rng(seed)
        w = randf(rng, d)
        x = randf(rng, b, d)
        y = randf(rng, b)
        g, l = kl.linreg_grad(w, x, y)
        g_ref, l_ref = ref.linreg_grad(w, x, y)
        np.testing.assert_allclose(g, g_ref, rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(l, l_ref, rtol=1e-4, atol=1e-5)

    def test_gradient_is_zero_at_optimum(self):
        rng = np.random.default_rng(7)
        w_star = randf(rng, 32)
        x = randf(rng, 128, 32)
        y = x @ w_star
        g, l = kl.linreg_grad(w_star, x, y)
        assert float(l) < 1e-8
        assert float(jnp.linalg.norm(g)) < 1e-3

    def test_loss_only_entry_point(self):
        rng = np.random.default_rng(8)
        w, x, y = randf(rng, 16), randf(rng, 64, 16), randf(rng, 64)
        np.testing.assert_allclose(
            kl.linreg_loss(w, x, y), ref.linreg_loss(w, x, y), rtol=1e-4
        )


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


class TestAttention:
    @given(
        bh=st.integers(1, 6),
        t=st.sampled_from([8, 16, 32, 64, 96, 128]),
        dh=st.sampled_from([4, 8, 16, 32, 64]),
        seed=st.integers(0, 2**31),
    )
    def test_matches_causal_oracle(self, bh, t, dh, seed):
        rng = np.random.default_rng(seed)
        q = randf(rng, bh, t, dh)
        k = randf(rng, bh, t, dh)
        v = randf(rng, bh, t, dh)
        got = ka.attention(q, k, v)
        want = ref.attention(q, k, v, causal=True)
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-4)

    def test_causality(self):
        # output at position i must not depend on inputs at j > i
        rng = np.random.default_rng(3)
        q = randf(rng, 1, 32, 8)
        k = randf(rng, 1, 32, 8)
        v = randf(rng, 1, 32, 8)
        base = ka.attention(q, k, v)
        k2 = k.at[0, -1].set(99.0)
        v2 = v.at[0, -1].set(-99.0)
        pert = ka.attention(q, k2, v2)
        np.testing.assert_allclose(base[0, :-1], pert[0, :-1], rtol=1e-5, atol=1e-6)
        assert not np.allclose(base[0, -1], pert[0, -1])

    def test_online_softmax_is_stable_for_large_scores(self):
        rng = np.random.default_rng(4)
        q = randf(rng, 1, 16, 8, scale=30.0)
        k = randf(rng, 1, 16, 8, scale=30.0)
        v = randf(rng, 1, 16, 8)
        out = ka.attention(q, k, v)
        assert np.isfinite(np.asarray(out)).all()

    def test_custom_vjp_matches_oracle_grad(self):
        rng = np.random.default_rng(5)
        q = randf(rng, 2, 16, 8)
        k = randf(rng, 2, 16, 8)
        v = randf(rng, 2, 16, 8)

        def f_pallas(q, k, v):
            return jnp.sum(ka.attention_ad(q, k, v) ** 2)

        def f_ref(q, k, v):
            return jnp.sum(ref.attention(q, k, v, causal=True) ** 2)

        for gp, gr in zip(
            jax.grad(f_pallas, argnums=(0, 1, 2))(q, k, v),
            jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v),
        ):
            np.testing.assert_allclose(gp, gr, rtol=2e-3, atol=2e-4)


# ---------------------------------------------------------------------------
# mlp
# ---------------------------------------------------------------------------


class TestMlp:
    @given(
        b=st.integers(2, 100),
        i=st.integers(1, 40),
        h=st.integers(1, 40),
        c=st.integers(2, 8),
        seed=st.integers(0, 2**31),
    )
    def test_grad_matches_oracle(self, b, i, h, c, seed):
        rng = np.random.default_rng(seed)
        w1, b1 = randf(rng, i, h, scale=0.2), randf(rng, h, scale=0.1)
        w2, b2 = randf(rng, h, c, scale=0.2), randf(rng, c, scale=0.1)
        x = randf(rng, b, i)
        labels = jnp.asarray(rng.integers(0, c, size=b), dtype=jnp.int32)
        grads, loss = kmlp.mlp_grad(w1, b1, w2, b2, x, labels)
        grads_ref, loss_ref = ref.mlp_grad(w1, b1, w2, b2, x, labels)
        np.testing.assert_allclose(loss, loss_ref, rtol=1e-4, atol=1e-5)
        for g, gr in zip(grads, grads_ref):
            np.testing.assert_allclose(g, gr, rtol=1e-3, atol=1e-4)

    def test_grad_matches_jax_autodiff(self):
        rng = np.random.default_rng(9)
        w1, b1 = randf(rng, 8, 16, scale=0.3), jnp.zeros(16)
        w2, b2 = randf(rng, 16, 4, scale=0.3), jnp.zeros(4)
        x = randf(rng, 32, 8)
        labels = jnp.asarray(rng.integers(0, 4, size=32), dtype=jnp.int32)
        (dw1, db1, dw2, db2), _ = kmlp.mlp_grad(w1, b1, w2, b2, x, labels)
        auto = jax.grad(ref.mlp_loss, argnums=(0, 1, 2, 3))(w1, b1, w2, b2, x, labels)
        for got, want in zip((dw1, db1, dw2, db2), auto):
            np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# optimizer updates
# ---------------------------------------------------------------------------


class TestSgd:
    @given(n=st.integers(1, 5000), seed=st.integers(0, 2**31))
    def test_sgd_update(self, n, seed):
        rng = np.random.default_rng(seed)
        w, g = randf(rng, n), randf(rng, n)
        got = ks.sgd_update(w, g, jnp.asarray([0.05], jnp.float32))
        np.testing.assert_allclose(got, ref.sgd_update(w, g, 0.05), rtol=1e-5, atol=1e-6)

    @given(n=st.integers(1, 2000), seed=st.integers(0, 2**31))
    def test_momentum_update(self, n, seed):
        rng = np.random.default_rng(seed)
        w, m, g = randf(rng, n), randf(rng, n), randf(rng, n)
        hp = jnp.asarray([0.1, 0.9], jnp.float32)
        w2, m2 = ks.momentum_update(w, m, g, hp)
        w2_ref, m2_ref = ref.momentum_update(w, m, g, 0.1, 0.9)
        np.testing.assert_allclose(w2, w2_ref, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(m2, m2_ref, rtol=1e-5, atol=1e-6)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])


# ---------------------------------------------------------------------------
# dtype sweep: bf16 inputs hit the MXU path (preferred_element_type=f32)
# ---------------------------------------------------------------------------


class TestDtypes:
    @given(
        m=st.sampled_from([16, 64, 128]),
        k=st.sampled_from([16, 64]),
        n=st.sampled_from([16, 64, 128]),
        seed=st.integers(0, 2**31),
    )
    def test_matmul_bfloat16(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        a = jnp.asarray(rng.normal(size=(m, k)), dtype=jnp.bfloat16)
        b = jnp.asarray(rng.normal(size=(k, n)), dtype=jnp.bfloat16)
        got = km.matmul(a, b).astype(jnp.float32)
        want = ref.matmul(a, b).astype(jnp.float32)
        # bf16 storage, f32 accumulation: tolerances sized for 8-bit mantissa
        np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)

    def test_sgd_update_bfloat16(self):
        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.normal(size=256), dtype=jnp.bfloat16)
        g = jnp.asarray(rng.normal(size=256), dtype=jnp.bfloat16)
        got = ks.sgd_update(w, g, jnp.asarray([0.1], jnp.bfloat16))
        want = ref.sgd_update(w, g, jnp.bfloat16(0.1))
        np.testing.assert_allclose(
            np.asarray(got, dtype=np.float32),
            np.asarray(want, dtype=np.float32),
            rtol=2e-2,
            atol=2e-2,
        )
