"""L2 model and AOT-pipeline tests: the flat-parameter ABI, the
transformer forward/backward, and the manifest written by aot.py."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.kernels import ref
from compile.models import linreg as m_linreg
from compile.models import mlp as m_mlp
from compile.models import transformer as m_tfm
from compile.models.common import Packer


class TestPacker:
    def test_pack_unpack_roundtrip(self):
        p = Packer()
        p.add("a", (3, 4))
        p.add("b", (5,))
        p.add("c", (2, 2, 2))
        assert p.size == 12 + 5 + 8
        rng = np.random.default_rng(0)
        arrays = [
            jnp.asarray(rng.normal(size=s), dtype=jnp.float32) for s in p.shapes
        ]
        flat = p.pack(arrays)
        assert flat.shape == (p.size,)
        back = p.unpack(flat)
        for a, b in zip(arrays, back):
            np.testing.assert_array_equal(a, b)

    def test_unpack_offsets_are_static(self):
        p = Packer()
        p.add("a", (2, 2))
        p.add("b", (3,))
        flat = jnp.arange(7, dtype=jnp.float32)
        a, b = p.unpack(flat)
        np.testing.assert_array_equal(a, [[0, 1], [2, 3]])
        np.testing.assert_array_equal(b, [4, 5, 6])


class TestLinRegModel:
    def test_grad_fn_abi(self):
        rng = np.random.default_rng(1)
        theta = jnp.asarray(rng.normal(size=16), dtype=jnp.float32)
        x = jnp.asarray(rng.normal(size=(32, 16)), dtype=jnp.float32)
        y = jnp.asarray(rng.normal(size=32), dtype=jnp.float32)
        g, l = m_linreg.grad_fn(theta, x, y)
        assert g.shape == (16,) and l.shape == (1,)
        g_ref, l_ref = ref.linreg_grad(theta, x, y)
        np.testing.assert_allclose(g, g_ref, rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(l[0], l_ref, rtol=1e-4)


class TestMlpModel:
    def test_flat_grad_matches_structured(self):
        packer = m_mlp.make_packer(8, 16, 4)
        rng = np.random.default_rng(2)
        theta = jnp.asarray(rng.normal(size=packer.size) * 0.1, dtype=jnp.float32)
        x = jnp.asarray(rng.normal(size=(32, 8)), dtype=jnp.float32)
        labels = jnp.asarray(rng.integers(0, 4, size=32), dtype=jnp.int32)
        g, l = m_mlp.grad_fn(packer)(theta, x, labels)
        assert g.shape == (packer.size,)
        w1, b1, w2, b2 = packer.unpack(theta)
        grads_ref, loss_ref = ref.mlp_grad(w1, b1, w2, b2, x, labels)
        np.testing.assert_allclose(g, packer.pack(grads_ref), rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(l[0], loss_ref, rtol=1e-4)


class TestTransformer:
    CFG = m_tfm.TransformerConfig(
        vocab=64, seq_len=17, d_model=16, heads=2, layers=2, mlp_mult=2
    )

    def _setup(self, seed=3):
        grad_fn, loss_fn, packer = m_tfm.make_fns(self.CFG)
        rng = np.random.default_rng(seed)
        theta = jnp.asarray(rng.normal(size=packer.size) * 0.05, dtype=jnp.float32)
        tokens = jnp.asarray(
            rng.integers(0, self.CFG.vocab, size=(4, self.CFG.seq_len)),
            dtype=jnp.int32,
        )
        return grad_fn, loss_fn, packer, theta, tokens

    def test_loss_near_uniform_at_random_init(self):
        _, loss_fn, _, theta, tokens = self._setup()
        (l,) = loss_fn(theta, tokens)
        assert 0.5 * np.log(64) < float(l[0]) < 2.0 * np.log(64)

    def test_grad_shape_and_descent(self):
        grad_fn, loss_fn, packer, theta, tokens = self._setup()
        g, l0 = grad_fn(theta, tokens)
        assert g.shape == (packer.size,)
        theta2 = theta - 0.5 * g
        (l1,) = loss_fn(theta2, tokens)
        assert float(l1[0]) < float(l0[0]), "one SGD step must reduce batch loss"

    def test_grad_matches_finite_difference_on_direction(self):
        grad_fn, loss_fn, _, theta, tokens = self._setup(4)
        g, l0 = grad_fn(theta, tokens)
        rng = np.random.default_rng(5)
        u = jnp.asarray(rng.normal(size=theta.shape), dtype=jnp.float32)
        u = u / jnp.linalg.norm(u)
        eps = 1e-2
        (lp,) = loss_fn(theta + eps * u, tokens)
        (lm,) = loss_fn(theta - eps * u, tokens)
        fd = (float(lp[0]) - float(lm[0])) / (2 * eps)
        analytic = float(jnp.dot(g, u))
        assert abs(fd - analytic) < 3e-2 * (1 + abs(fd)), f"{fd} vs {analytic}"

    def test_causal_lm_ignores_future_tokens(self):
        grad_fn, loss_fn, _, theta, tokens = self._setup(6)
        # loss over positions 0..T-2 predicts tokens 1..T-1; perturbing
        # ONLY the last target token must change loss but perturbing the
        # model's view of it cannot affect earlier logits (causality is
        # already covered at kernel level; here: ABI-level sanity)
        t2 = tokens.at[:, -1].set((tokens[:, -1] + 1) % self.CFG.vocab)
        (l1,) = loss_fn(theta, tokens)
        (l2,) = loss_fn(theta, t2)
        assert float(l1[0]) != float(l2[0])


class TestAotRegistry:
    def test_registry_is_complete_and_consistent(self):
        reg = aot.build_registry()
        names = [m["name"] for m, _, _ in reg.entries]
        assert len(names) == len(set(names)), "duplicate artifact names"
        for meta, _fn, arg_specs in reg.entries:
            assert meta["kind"] in ("grad", "loss", "update")
            assert len(arg_specs) == len(meta["inputs"])
            # theta is always input 0 with shape [param_dim]
            assert meta["inputs"][0]["shape"] == [meta["param_dim"]]
            if meta["kind"] == "grad":
                assert meta["outputs"][0]["shape"] == [meta["param_dim"]]
                assert meta["outputs"][1]["shape"] == [1]

    def test_lowering_one_artifact_produces_hlo_text(self):
        reg = aot.build_registry()
        meta, fn, specs = next(
            e for e in reg.entries if e[0]["name"] == "linreg_grad_d64_b256"
        )
        lowered = jax.jit(fn).lower(*specs)
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text
        assert "f32[64]" in text  # theta/grad shape visible in signature

    def test_manifest_matches_artifacts_dir(self):
        # validates the artifacts/ directory produced by `make artifacts`
        art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
        manifest_path = os.path.join(art, "manifest.json")
        if not os.path.exists(manifest_path):
            pytest.skip("artifacts/ not built")
        with open(manifest_path) as f:
            manifest = json.load(f)
        assert manifest["version"] == 1
        reg_names = {m["name"] for m, _, _ in aot.build_registry().entries}
        man_names = {a["name"] for a in manifest["artifacts"]}
        assert man_names == reg_names
        for a in manifest["artifacts"]:
            path = os.path.join(art, a["file"])
            assert os.path.exists(path), f"missing {a['file']}"
            with open(path) as f:
                head = f.read(200)
            assert "HloModule" in head


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
