//! Adaptive randomized coding (§4.3) in action, on the MLP classifier
//! through the XLA engine (the AOT Pallas/JAX artifacts) when
//! `artifacts/` is built, falling back to the native engine otherwise.
//!
//! Prints the per-iteration (loss, λ_t, q*_t) trajectory: early
//! iterations have high loss ⇒ λ≈1 ⇒ audit almost surely; as loss
//! falls the master trades reliability for efficiency; once all f
//! Byzantine workers are identified, q snaps to 0.
//!
//! ```sh
//! make artifacts && cargo run --release --example adaptive_training
//! ```

use std::sync::Arc;

use r3bft::config::{
    AttackConfig, AttackKind, ClusterConfig, ExperimentConfig, PolicyKind, TrainConfig,
};
use r3bft::coordinator::master::{Master, MasterOptions};
use r3bft::data::BlobsDataset;
use r3bft::grad::{GradientComputer, ModelSpec, NativeEngine, XlaEngine};
use r3bft::runtime::Runtime;

fn main() -> r3bft::Result<()> {
    let mut cluster = ClusterConfig::new(8, 2, 7);
    cluster.byzantine_ids = vec![1, 5];
    let cfg = ExperimentConfig {
        name: "adaptive".into(),
        cluster,
        policy: PolicyKind::Adaptive { p_assumed: 0.6 },
        attack: AttackConfig { kind: AttackKind::Noise, p: 0.6, magnitude: 2.0 },
        adversary: None,
        train: TrainConfig { steps: 120, lr: 0.4, ..Default::default() },
    };

    let spec = ModelSpec::Mlp { in_dim: 32, hidden: 64, classes: 4, batch: 128 };
    let dataset = Arc::new(BlobsDataset::generate(8192, 32, 4, 4.0, 7));

    let engine: Arc<dyn GradientComputer> =
        if std::path::Path::new("artifacts/manifest.json").exists() {
            println!("using XLA engine (AOT Pallas/JAX artifacts via PJRT)");
            let rt = Arc::new(Runtime::cpu("artifacts")?);
            Arc::new(XlaEngine::new(rt, spec.clone())?)
        } else {
            println!("artifacts/ missing — using native engine (run `make artifacts` for XLA)");
            Arc::new(NativeEngine::new(spec.clone()))
        };

    let theta0 = spec.init_theta(7);
    let master = Master::new(cfg, MasterOptions::default(), engine, dataset, theta0, 128)?;
    let out = master.run()?;

    println!("\n iter    loss   lambda_t     q_t  audited  identified");
    for r in &out.metrics.iterations {
        if r.iter < 10 || r.iter % 20 == 0 || r.identified > 0 {
            println!(
                "{:5}  {:6.3}   {:8.4}  {:6.3}  {:>7}  {:>10}",
                r.iter,
                r.loss,
                r.lambda,
                r.q,
                if r.audited { "yes" } else { "" },
                if r.identified > 0 { r.identified.to_string() } else { String::new() }
            );
        }
    }
    println!("\neliminated: {:?} (ground truth Byzantine: [1, 5])", out.eliminated);
    println!("avg efficiency: {:.3}", out.metrics.average_efficiency());
    println!(
        "final loss: {:.4} (from {:.4})",
        out.metrics.final_loss(),
        out.metrics.iterations[0].loss
    );
    Ok(())
}
