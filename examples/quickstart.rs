//! Quickstart: Byzantine-fault-tolerant training in ~30 lines.
//!
//! Trains linear regression with a planted optimum on 9 workers, 2 of
//! them Byzantine sign-flippers, using the paper's randomized scheme
//! (q = 0.3). Watch the master detect faults, impose reactive
//! redundancy, identify both attackers, and still converge exactly.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use r3bft::config::{
    AttackConfig, AttackKind, ClusterConfig, ExperimentConfig, PolicyKind, TrainConfig,
};
use r3bft::coordinator::master::{Master, MasterOptions};
use r3bft::data::LinRegDataset;
use r3bft::grad::{GradientComputer, ModelSpec, NativeEngine};

fn main() -> r3bft::Result<()> {
    // cluster: n = 9 workers, tolerate up to f = 2 Byzantine;
    // workers 7 and 8 actually are Byzantine (the master doesn't know)
    let mut cluster = ClusterConfig::new(9, 2, 42);
    cluster.byzantine_ids = vec![7, 8];

    let cfg = ExperimentConfig {
        name: "quickstart".into(),
        cluster,
        // the paper's randomized scheme: audit ~30% of iterations
        policy: PolicyKind::Bernoulli { q: 0.3 },
        // attackers flip + scale their gradients in 70% of iterations
        attack: AttackConfig { kind: AttackKind::SignFlip, p: 0.7, magnitude: 2.0 },
        adversary: None,
        train: TrainConfig { steps: 300, lr: 0.5, ..Default::default() },
    };

    // workload: y = X w* (noiseless), so exact fault-tolerance (Def. 1)
    // is checkable as ||theta - w*|| -> 0
    let dataset = Arc::new(LinRegDataset::generate(4096, 32, 0.0, 42));
    let w_star = dataset.w_star.clone();

    let spec = ModelSpec::LinReg { d: 32, batch: 16 };
    let engine: Arc<dyn GradientComputer> = Arc::new(NativeEngine::new(spec.clone()));
    let theta0 = spec.init_theta(42);
    let opts = MasterOptions { w_star: Some(w_star.clone()), ..Default::default() };

    let master = Master::new(cfg, opts, engine, dataset, theta0, 16)?;
    let out = master.run()?;

    println!("final loss        : {:.3e}", out.metrics.final_loss());
    println!("dist to optimum   : {:.3e}", r3bft::linalg::dist2(&out.theta, &w_star));
    println!("avg efficiency    : {:.3} (vanilla = 1, DRACO would be 0.2)", out.metrics.average_efficiency());
    println!("faults detected   : {}", out.events.detections());
    println!("identified        : {:?} (ground truth: [7, 8])", out.eliminated);
    assert!(r3bft::linalg::dist2(&out.theta, &w_star) < 1e-2, "exact fault-tolerance violated!");
    assert_eq!(out.eliminated.len(), 2);
    println!("\nexact fault-tolerance holds — both Byzantine workers identified.");
    Ok(())
}
