//! Figure 2, line by line: the n=3, f=1 linear detection code.
//!
//! Follows the paper's worked example exactly — three workers hold
//! data-point pairs (z1,z2), (z2,z3), (z3,z1) and send linear
//! combinations c1 = g1+2g2, c2 = -g2+g3, c3 = -g1-2g3. The master's
//! three reconstructions of Σgᵢ agree iff nobody lied; reactive
//! redundancy (symbol relaying + majority vote) then pins the liar.
//!
//! ```sh
//! cargo run --release --example fig2_demo
//! ```

use r3bft::coordinator::codes::{CheckOutcome, Fig2Code};
use r3bft::data::{Batch, Dataset, LinRegDataset};
use r3bft::grad::{GradientComputer, ModelSpec, NativeEngine};

fn show(label: &str, v: &[f32]) {
    let s: Vec<String> = v.iter().take(4).map(|x| format!("{x:+.3}")).collect();
    println!("  {label} = [{}]", s.join(", "));
}

fn main() -> r3bft::Result<()> {
    // three real data points from the linreg workload; g_i are genuine
    // per-point gradients computed by the engine at a common theta
    let ds = LinRegDataset::generate(3, 4, 0.0, 7);
    let engine = NativeEngine::new(ModelSpec::LinReg { d: 4, batch: 1 });
    let theta = vec![0.25f32, -0.5, 1.0, 0.0];
    let grad_of = |i: usize| -> r3bft::Result<Vec<f32>> {
        let b: Batch = ds.batch(&[i]);
        Ok(engine.grad(&theta, &b)?.grad)
    };
    let (g1, g2, g3) = (grad_of(0)?, grad_of(1)?, grad_of(2)?);
    println!("per-data-point gradients at theta:");
    show("g1", &g1);
    show("g2", &g2);
    show("g3", &g3);

    println!("\nworkers send symbols (worker i holds two data points):");
    let [c1, c2, c3] = Fig2Code::encode(&g1, &g2, &g3);
    show("c1 = g1 + 2 g2 ", &c1);
    show("c2 = -g2 + g3  ", &c2);
    show("c3 = -g1 - 2 g3", &c3);

    println!("\nmaster's three reconstructions of Σ g_i:");
    let [r1, r2, r3] = Fig2Code::reconstructions(&c1, &c2, &c3);
    show("c1 + c2      ", &r1);
    show("-(c2 + c3)   ", &r2);
    show("(c1 - c3) / 2", &r3);
    assert_eq!(Fig2Code::detect(&c1, &c2, &c3, 1e-5), CheckOutcome::Unanimous);
    println!("  -> unanimous: no fault detected");

    println!("\nnow worker 3 turns Byzantine and sends c != c3:");
    let mut bad = c3.clone();
    bad[0] += 0.5;
    show("c (forged)", &bad);
    assert_eq!(Fig2Code::detect(&c1, &c2, &bad, 1e-5), CheckOutcome::FaultDetected);
    println!("  -> reconstructions disagree: FAULT DETECTED (but liar unknown)");

    println!("\nreactive redundancy: workers relay u1=(c2,c3), u2=(c3,c1), u3=(c1,c2)");
    let honest = [c1.clone(), c2.clone(), c3.clone()];
    let mut claims: [[Vec<f32>; 3]; 3] = std::array::from_fn(|_| honest.clone());
    claims[2][2] = bad; // worker 3 keeps lying about its own symbol
    let liars = Fig2Code::identify(&claims, 1e-5);
    println!("  majority voting on relayed symbols -> Byzantine worker(s): {liars:?}");
    assert_eq!(liars, vec![2]);
    println!("  worker 3 identified; master recovers Σ g_i from c1 + c2 exactly.");
    Ok(())
}
