//! Attack gallery: every attack model vs every defense, side by side.
//!
//! For each Byzantine attack shape, run vanilla SGD, the two paper
//! schemes, and the strongest gradient-filter baseline, and report the
//! final distance to the planted optimum. Demonstrates the paper's
//! core comparison: filters are approximate and attack-dependent;
//! reactive redundancy is exact against everything.
//!
//! ```sh
//! cargo run --release --example attack_gallery
//! ```

use r3bft::config::{AttackKind, PolicyKind};
use r3bft::experiments::common::RunSpec;
use r3bft::linalg;

fn main() -> r3bft::Result<()> {
    println!(
        "{:<12} {:>14} {:>16} {:>16} {:>12}",
        "attack", "vanilla", "deterministic", "randomized q=.3", "eliminated"
    );
    for attack in AttackKind::ALL {
        let mut cells: Vec<String> = Vec::new();
        let mut elim = String::new();
        for policy in [
            PolicyKind::None,
            PolicyKind::Deterministic,
            PolicyKind::Bernoulli { q: 0.3 },
        ] {
            let (out, w_star) = RunSpec::new(9, 2, policy)
                .attack(attack, 0.8, 2.0)
                .steps(300)
                .seed(13)
                .run_linreg()?;
            cells.push(format!("{:.2e}", linalg::dist2(&out.theta, &w_star)));
            elim = format!("{:?}", out.eliminated);
        }
        println!(
            "{:<12} {:>14} {:>16} {:>16} {:>12}",
            attack.name(),
            cells[0],
            cells[1],
            cells[2],
            elim
        );
    }
    println!("\nvanilla SGD is corrupted by the loud attacks (noise/constant/collude) and");
    println!("biased by the stealthy one (small_bias); sign_flip/zero at f=2,n=9 merely");
    println!("attenuate the honest direction. Both paper schemes stay EXACT against all six");
    println!("and identify the attackers listed in the last column.");
    Ok(())
}
