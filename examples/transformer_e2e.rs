//! End-to-end driver: train a byte-level transformer LM through the
//! FULL three-layer stack with Byzantine workers active.
//!
//! Every gradient in this run flows:
//!   Rust master  ->  worker thread  ->  PJRT CPU executable
//!   (HLO lowered by JAX from the L2 model, whose attention and matmul
//!   hot loops are the L1 Pallas kernels)  ->  symbols back to the
//!   master  ->  randomized reactive redundancy  ->  fused SGD-update
//!   artifact.
//!
//! Python is not running: only `artifacts/*.hlo.txt` is consumed.
//!
//! Defaults: ~136k-parameter GPT (vocab 256, T=64, d=64, 4 heads,
//! 2 layers) on a synthetic English-like byte corpus, 300 steps,
//! n = 5 workers with f = 1 Byzantine sign-flipper, randomized scheme
//! q = 0.25. Takes a few minutes on CPU. `--steps N` to change.
//!
//! ```sh
//! make artifacts && cargo run --release --example transformer_e2e
//! ```

use std::sync::Arc;

use r3bft::config::{
    AttackConfig, AttackKind, ClusterConfig, ExperimentConfig, PolicyKind, TrainConfig,
};
use r3bft::coordinator::master::{Master, MasterOptions};
use r3bft::data::{Corpus, Dataset};
use r3bft::grad::{models, GradientComputer, ModelSpec, XlaEngine};
use r3bft::runtime::Runtime;
use r3bft::util::args::Args;

fn main() -> r3bft::Result<()> {
    r3bft::util::logger::init();
    let args = Args::from_env();
    let steps = args.usize("steps", 300);
    let seed = args.u64("seed", 42);

    let mut cluster = ClusterConfig::new(5, 1, seed);
    cluster.byzantine_ids = vec![3];
    let cfg = ExperimentConfig {
        name: "transformer_e2e".into(),
        cluster,
        policy: PolicyKind::Bernoulli { q: 0.25 },
        attack: AttackConfig { kind: AttackKind::SignFlip, p: 0.5, magnitude: 2.0 },
        adversary: None,
        train: TrainConfig { steps, lr: 0.25, ..Default::default() },
    };

    println!("loading PJRT runtime + AOT artifacts (run `make artifacts` first)...");
    let rt = Arc::new(Runtime::cpu(args.get_or("artifacts", "artifacts"))?);
    let spec = ModelSpec::Transformer { param_dim: 136_512, batch: 8, seq_len: 65 };
    let engine: Arc<dyn GradientComputer> = Arc::new(XlaEngine::new(rt.clone(), spec)?);

    let corpus = Arc::new(Corpus::synthetic(64 * 1024, 65, seed));
    println!(
        "corpus: {} bytes, {} windows; model: 136512 params (GPT: T=64 d=64 h=4 L=2)",
        corpus.num_bytes(),
        corpus.len()
    );
    println!(
        "cluster: n=5 f=1 (worker 3 Byzantine, sign-flip p=0.5), randomized q=0.25, {steps} steps\n"
    );

    let theta0 = models::init_transformer_tiny(seed);
    let t0 = std::time::Instant::now();
    let master = Master::new(cfg, MasterOptions::default(), engine, corpus, theta0, 8)?;
    let out = master.run()?;
    let wall = t0.elapsed().as_secs_f64();

    println!("\n iter    loss(bits/byte)   eff    audited  events");
    let mut csv = String::from("iter,loss,bits_per_byte,efficiency,audited\n");
    for r in &out.metrics.iterations {
        let bpb = r.loss as f64 / std::f64::consts::LN_2;
        csv.push_str(&format!(
            "{},{},{:.4},{:.4},{}\n",
            r.iter, r.loss, bpb, r.efficiency(), r.audited as u8
        ));
        if r.iter < 3 || r.iter % 25 == 0 || r.identified > 0 || r.iter as usize == steps - 1 {
            println!(
                "{:5}   {:6.3} ({:5.3})    {:.2}   {:>7}  {}",
                r.iter,
                r.loss,
                bpb,
                r.efficiency(),
                if r.audited { "yes" } else { "" },
                if r.identified > 0 { format!("identified {} worker(s)", r.identified) } else { String::new() }
            );
        }
    }
    std::fs::write("transformer_e2e_loss.csv", &csv)?;

    let first = out.metrics.iterations[0].loss;
    let last = out.metrics.final_loss();
    let stats = rt.stats();
    println!("\n== e2e summary ==");
    println!("wall time            : {wall:.1}s ({:.2} s/iter)", wall / steps as f64);
    println!("loss                 : {first:.3} -> {last:.3} (uniform = ln 256 = 5.545)");
    println!("bits/byte            : {:.3} -> {:.3}", first as f64 / std::f64::consts::LN_2, last as f64 / std::f64::consts::LN_2);
    println!("avg efficiency       : {:.3}", out.metrics.average_efficiency());
    println!("eliminated           : {:?} (ground truth: [3])", out.eliminated);
    println!("PJRT executions      : {} (mean {:.2} ms)", stats.executions, stats.mean_exec_us() / 1e3);
    println!("loss curve           : transformer_e2e_loss.csv");
    assert!(last < first, "loss must decrease through the full stack");
    Ok(())
}
