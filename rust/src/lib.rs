//! # r3bft — Randomized Reactive Redundancy for Byzantine Fault-Tolerance
//!
//! A production-oriented reproduction of Gupta & Vaidya (2019),
//! *"Randomized Reactive Redundancy for Byzantine Fault-Tolerance in
//! Parallelized Learning"*.
//!
//! The crate is the **Layer-3 coordinator** of a three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`) implement the
//!   gradient hot loops (tiled matmul, fused linreg gradient, flash
//!   attention, fused SGD), lowered in interpret mode.
//! * **L2** — JAX models (`python/compile/models/`) compose the kernels
//!   into loss/gradient functions with a uniform flat-parameter ABI,
//!   AOT-lowered to HLO text by `python/compile/aot.py` into
//!   `artifacts/`.
//! * **L3** — this crate: a parameter-server master over a
//!   completion-driven worker transport (threaded pool or virtual-time
//!   simulator). The master assigns data points, collects gradient
//!   *symbols* as they arrive (waiting for all of them, a K-of-N
//!   quorum, or a deadline — `--gather`), runs the paper's
//!   deterministic / randomized / adaptive fault-check policies,
//!   imposes **reactive redundancy** on detection, identifies and
//!   eliminates Byzantine workers, and applies SGD updates. Gradients
//!   are computed either natively (pure Rust) or by executing the AOT
//!   artifacts on the PJRT CPU client ([`runtime`]).
//!
//! Python never runs on the training path; after `make artifacts` the
//! Rust binary is self-contained.
//!
//! Entry points:
//! * [`coordinator::Master`] — the training loop.
//! * [`coordinator::policy::FaultCheckPolicy`] — deterministic /
//!   Bernoulli(q) / adaptive / selective audit policies.
//! * [`coordinator::analysis`] — the paper's closed forms (Eqs. 2–5).
//! * [`grad::GradientComputer`] — pluggable gradient engines.
//! * [`baselines`] — DRACO and gradient-filter comparators.
//! * [`adversary`] — coordinated, protocol-aware Byzantine strategies
//!   (the red-team layer; `--adversary <strategy>`).
//! * [`trace`] — flight-recorder tracing, the forensic evidence
//!   ledger, and the Prometheus metrics surface (`--trace`,
//!   `--events`, `--metrics-out`, `--flight`).

pub mod adversary;
pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod grad;
pub mod linalg;
pub mod runtime;
pub mod trace;
pub mod util;

pub type Result<T> = anyhow::Result<T>;
