//! Small dense linear-algebra substrate.
//!
//! Powers the native gradient engine (`grad::native`), the gradient
//! filters (`baselines`), and the coordinator's aggregation hot path.
//! Row-major `Vec<f32>` storage; the handful of kernels that sit on the
//! L3 hot path (axpy / dot / matvec-T) are written to autovectorize.

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(rows * cols, data.len(), "shape/data mismatch");
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    /// C = A @ B (naive ikj loop — cache-friendly; fine off the hot path).
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "matmul {}x{} @ {}x{}", self.rows, self.cols, b.rows, b.cols);
        let mut c = Mat::zeros(self.rows, b.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a_ik = self.at(i, k);
                if a_ik == 0.0 {
                    continue;
                }
                let brow = b.row(k);
                let crow = &mut c.data[i * b.cols..(i + 1) * b.cols];
                for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                    *cv += a_ik * bv;
                }
            }
        }
        c
    }

    /// y = A @ x.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, x.len());
        (0..self.rows).map(|i| dot(self.row(i), x)).collect()
    }

    /// y = A^T @ x (column accumulation over rows; autovectorizes).
    pub fn matvec_t(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.rows, x.len());
        let mut y = vec![0.0f32; self.cols];
        for (r, &xr) in x.iter().enumerate() {
            if xr == 0.0 {
                continue;
            }
            for (yv, av) in y.iter_mut().zip(self.row(r).iter()) {
                *yv += xr * av;
            }
        }
        y
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                *t.at_mut(c, r) = self.at(r, c);
            }
        }
        t
    }
}

// ---------------------------------------------------------------------------
// vector kernels
// ---------------------------------------------------------------------------

#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for (x, y) in a.iter().zip(b.iter()) {
        acc += x * y;
    }
    acc
}

/// y += alpha * x.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yv, xv) in y.iter_mut().zip(x.iter()) {
        *yv += alpha * xv;
    }
}

#[inline]
pub fn scale(alpha: f32, x: &mut [f32]) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

pub fn norm2(x: &[f32]) -> f32 {
    dot(x, x).sqrt()
}

pub fn sub(a: &[f32], b: &[f32]) -> Vec<f32> {
    a.iter().zip(b.iter()).map(|(x, y)| x - y).collect()
}

pub fn add(a: &[f32], b: &[f32]) -> Vec<f32> {
    a.iter().zip(b.iter()).map(|(x, y)| x + y).collect()
}

pub fn dist2(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for (x, y) in a.iter().zip(b.iter()) {
        let d = x - y;
        acc += d * d;
    }
    acc.sqrt()
}

/// Mean of a set of equal-length vectors.
pub fn mean_of(vs: &[&[f32]]) -> Vec<f32> {
    assert!(!vs.is_empty());
    let n = vs[0].len();
    let mut out = vec![0.0f32; n];
    for v in vs {
        axpy(1.0, v, &mut out);
    }
    scale(1.0 / vs.len() as f32, &mut out);
    out
}

/// Max |a_i - b_i|.
pub fn linf(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

/// Reproducible fixed-shape pairwise-tree sum over an array of leaf
/// slots, skipping absent (`None`) leaves.
///
/// The reduction tree is defined by leaf *position* alone: a range of
/// `len > 1` slots splits at `len.next_power_of_two() / 2`, and an
/// absent subtree is elided rather than added as zero. Two properties
/// follow:
///
/// * **Determinism** — for a fixed slot layout the float-addition
///   order is fixed, independent of which leaves are present.
/// * **Partition invariance** — if the slot array is cut into
///   contiguous shards whose width is a power of two, summing each
///   shard with `tree_sum` and then combining the per-shard partials
///   with `tree_sum` yields the *bit-identical* result of a single
///   global `tree_sum`. This is what makes a sharded parameter-server
///   round reproduce the single-master trajectory exactly (see
///   `coordinator::shard`).
///
/// Returns `None` when every leaf is absent.
pub fn tree_sum(leaves: &[Option<&[f32]>]) -> Option<Vec<f32>> {
    match leaves.len() {
        0 => None,
        1 => leaves[0].map(|x| x.to_vec()),
        n => {
            let split = n.next_power_of_two() / 2;
            let left = tree_sum(&leaves[..split]);
            let right = tree_sum(&leaves[split..]);
            match (left, right) {
                (Some(mut a), Some(b)) => {
                    axpy(1.0, &b, &mut a);
                    Some(a)
                }
                (Some(a), None) => Some(a),
                (None, b) => b,
            }
        }
    }
}

/// Combine an already-computed partial sum into an accumulator the
/// same way `tree_sum` combines two subtrees (`acc += partial`,
/// creating `acc` from the partial if empty).
pub fn tree_combine(acc: &mut Option<Vec<f32>>, partial: &[f32]) {
    match acc {
        Some(a) => axpy(1.0, partial, a),
        None => *acc = Some(partial.to_vec()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tree_sum_skips_absent_and_matches_manual_tree() {
        let a = [1.0f32, 2.0];
        let b = [10.0f32, 20.0];
        let c = [100.0f32, 200.0];
        // 4 slots, slot 2 absent: ((a+b) + c) with c at slot 3
        let leaves = [Some(&a[..]), Some(&b[..]), None, Some(&c[..])];
        let s = tree_sum(&leaves).unwrap();
        assert_eq!(s, vec![111.0, 222.0]);
        assert!(tree_sum(&[None, None]).is_none());
        assert!(tree_sum(&[]).is_none());
    }

    #[test]
    fn tree_sum_is_partition_invariant_for_pow2_shards() {
        // 16 leaves with adversarial magnitudes (so addition order
        // matters), some absent; shard widths 4 and 8 must reproduce
        // the global sum bit-for-bit
        let vals: Vec<Vec<f32>> = (0..16)
            .map(|i| vec![(i as f32 + 1.0) * 1e5, 1.0 / (i as f32 + 3.0), -7e-4 * i as f32])
            .collect();
        let leaves: Vec<Option<&[f32]>> = (0..16)
            .map(|i| if i % 5 == 2 { None } else { Some(vals[i].as_slice()) })
            .collect();
        let global = tree_sum(&leaves).unwrap();
        for width in [4usize, 8] {
            let partials: Vec<Option<Vec<f32>>> =
                leaves.chunks(width).map(tree_sum).collect();
            let slots: Vec<Option<&[f32]>> =
                partials.iter().map(|p| p.as_deref()).collect();
            let combined = tree_sum(&slots).unwrap();
            assert_eq!(
                combined.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                global.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "width {width} not bit-identical"
            );
        }
    }

    #[test]
    fn matmul_identity() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let mut i3 = Mat::zeros(3, 3);
        for k in 0..3 {
            *i3.at_mut(k, k) = 1.0;
        }
        assert_eq!(a.matmul(&i3), a);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Mat::from_vec(2, 2, vec![5., 6., 7., 8.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn matvec_and_transpose_agree() {
        let a = Mat::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let x = vec![1.0, -1.0, 2.0];
        let y1 = a.matvec_t(&x);
        let y2 = a.transpose().matvec(&x);
        assert_eq!(y1, y2);
    }

    #[test]
    fn vector_ops() {
        let mut y = vec![1.0, 2.0];
        axpy(2.0, &[3.0, 4.0], &mut y);
        assert_eq!(y, vec![7.0, 10.0]);
        assert!((dot(&[1., 2., 3.], &[4., 5., 6.]) - 32.0).abs() < 1e-6);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-6);
        assert!((dist2(&[0.0, 0.0], &[3.0, 4.0]) - 5.0).abs() < 1e-6);
        assert_eq!(linf(&[1.0, 5.0], &[2.0, 3.0]), 2.0);
    }

    #[test]
    fn mean_of_vectors() {
        let a = vec![1.0f32, 3.0];
        let b = vec![3.0f32, 5.0];
        assert_eq!(mean_of(&[&a, &b]), vec![2.0, 4.0]);
    }
}
