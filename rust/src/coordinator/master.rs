//! The master: policy + SGD-update glue over the protocol core.
//!
//! After the transport/protocol refactor this layer is small by
//! design: it builds the cluster (choosing a [`Transport`] from the
//! config), drives each iteration through the protocol core's
//! begin → collect → finish phases (which own the proactive →
//! detection → reactive machine), then aggregates the per-chunk
//! gradients into a **reused** buffer, applies the SGD step through
//! the gradient engine, and records metrics/events.
//!
//! With `--pipeline DEPTH ≥ 2` the single-core driver software-
//! pipelines rounds: iteration t+1's proactive wave is launched on a
//! *provisional* θ computed from iteration t's pre-audit symbols, and
//! is reissued on the exact θ only when round t's audit changed the
//! update (caught a liar, or a filter/vote correction). θ application
//! stays strictly ordered; fault-free rounds overlap fully.
//!
//! See [`super::protocol`] for the protocol semantics and the
//! exactness argument, and [`super::transport`] for the execution
//! models (`--transport threaded|sim`).

use std::sync::Arc;
use std::time::Instant;

use super::byzantine::ByzantineBehavior;
use super::compress::Compressor;
use super::events::{Event, EventLog};
use super::metrics::{IterationRecord, TrainMetrics};
use super::policy::FaultCheckPolicy;
use super::protocol::{ProtocolConfig, ProtocolCore, RoundState};
use super::shard::{ParameterServer, ShardPlan, ShardedTransport};
use super::transport::{
    AdversaryWiring, AuthKey, ChaosSpec, LatencyModel, NetConfig, NetTransport, SimTransport,
    ThreadedTransport, Transport,
};
use super::{WorkerId, MASTER_SENTINEL};
use crate::adversary::{AdversaryController, CoreTap, ShardInfo, Topology};
use crate::config::{ExperimentConfig, TransportKind};
use crate::data::Dataset;
use crate::grad::GradientComputer;
use crate::util::stats;
use crate::Result;

/// Extra master behaviour knobs not present in the file config.
#[derive(Clone)]
pub struct MasterOptions {
    /// §5 self-check generalization: audit by recomputing on the master
    /// instead of replicating to additional workers.
    pub self_check: bool,
    /// Comparison tolerance (0.0 = exact bitwise, the default — honest
    /// engines are deterministic).
    pub tol: f32,
    /// Oracle: the planted optimum for dist-to-opt metrics.
    pub w_star: Option<Vec<f32>>,
    /// Measurement mode for the E2/E3 benches: identify (and correct)
    /// but never eliminate, holding f_t = f as the paper's Eqs. (2)-(3)
    /// assume. Never used in production runs.
    pub no_eliminate: bool,
    /// §2.1/§5: workers send compressed symbols; detection and voting
    /// operate on the packed wire bytes, the master aggregates the
    /// exact decode. None = dense protocol.
    pub compressor: Option<Arc<dyn Compressor>>,
    /// Election decode (cf. Election Coding): aggregate each chunk by
    /// per-symbol majority over its replica wires instead of the exact
    /// decode of the chosen copy. A statistical-robustness measurement
    /// mode (E13) — detection/identification still run on exact wires.
    pub election: bool,
    /// §5 hybrid generalization: in *unaudited* iterations aggregate the
    /// per-chunk gradients through a lightweight gradient filter instead
    /// of the plain mean, bounding the damage of un-audited tampering.
    pub unaudited_filter: Option<Arc<dyn crate::baselines::GradientFilter>>,
    /// Scenario knobs for `--transport sim` (latency distribution,
    /// stragglers, crash plan). Ignored by the threaded transport.
    pub sim: super::transport::SimConfig,
    /// Flight-recorder tracing (`--trace` / `--events` /
    /// `--metrics-out` / `--flight`): when set, every protocol core
    /// gets a [`crate::trace::TraceHandle`] and the master reports its
    /// own events through [`crate::trace::Recorder::on_master_event`].
    /// `None` (the default) costs nothing on the hot path.
    pub recorder: Option<Arc<crate::trace::Recorder>>,
    /// Model spec forwarded to remote workers by the net transport
    /// (their hello carries it so they build identical engines).
    /// Required when `cfg.cluster.transport` is net; ignored otherwise.
    pub net_model: Option<crate::grad::ModelSpec>,
    /// Live `/status` scoreboard (`--metrics-listen`): the master
    /// posts one update per finished round. `None` costs nothing.
    pub status: Option<Arc<crate::trace::http::StatusBoard>>,
}

impl Default for MasterOptions {
    fn default() -> Self {
        MasterOptions {
            self_check: false,
            tol: 0.0,
            w_star: None,
            no_eliminate: false,
            compressor: None,
            election: false,
            unaudited_filter: None,
            sim: super::transport::SimConfig::default(),
            recorder: None,
            net_model: None,
            status: None,
        }
    }
}

/// Result of a training run.
pub struct TrainOutcome {
    pub theta: Vec<f32>,
    pub metrics: TrainMetrics,
    pub events: EventLog,
    /// Workers identified as Byzantine (in identification order).
    pub eliminated: Vec<WorkerId>,
    /// Workers that crash-stopped (sim transport scenarios only; a
    /// crash is not an identification).
    pub crashed: Vec<WorkerId>,
}

/// Execution backend: one protocol core over all n workers, or the
/// sharded parameter server (K > 1 shards, each with its own core).
enum Backend {
    Single(ProtocolCore),
    Sharded(ParameterServer),
}

pub struct Master {
    cfg: ExperimentConfig,
    opts: MasterOptions,
    engine: Arc<dyn GradientComputer>,
    dataset: Arc<dyn Dataset>,
    backend: Backend,
    theta: Vec<f32>,
    chunk_size: usize,
    /// Reused aggregation buffer (single-core compressed/filtered
    /// paths; the dense path tree-sums into a fresh buffer).
    agg: Vec<f32>,
    /// Reused per-chunk loss buffer.
    used_losses: Vec<f64>,
    /// Wall-clock origin for the exclusive `wall_ns` accounting.
    wall_origin: Instant,
    /// End of the previous round's wall period (ns since
    /// `wall_origin`): round t's `wall_ns` starts where round t-1's
    /// ended, so pipelined rounds never double-count overlapped work.
    last_wall_end_ns: u64,
}

impl Master {
    /// Build a master over an engine + dataset, choosing the transport
    /// named by `cfg.cluster.transport` ("threaded" | "sim").
    /// `init_theta` seeds the parameter vector (use
    /// `ModelSpec::init_theta` or `init_transformer_tiny`).
    /// `chunk_size` is the number of data points per chunk — for the
    /// XLA engine it must equal the artifact's compiled batch size.
    /// With `cfg.cluster.shards > 1` the master delegates every round
    /// to a [`ParameterServer`] over per-shard protocol cores.
    pub fn new(
        cfg: ExperimentConfig,
        opts: MasterOptions,
        engine: Arc<dyn GradientComputer>,
        dataset: Arc<dyn Dataset>,
        init_theta: Vec<f32>,
        chunk_size: usize,
    ) -> Result<Master> {
        cfg.cluster.validate()?;
        if cfg.cluster.shards > 1 {
            return Self::new_sharded(cfg, opts, engine, dataset, init_theta, chunk_size);
        }
        let n = cfg.cluster.n;
        let seed = cfg.cluster.seed;
        let attack = cfg.attack.clone();
        let byz_ids = cfg.cluster.byzantine_ids.clone();
        // a coordinated adversary replaces the stateless per-worker
        // behaviour path for the configured Byzantine ids (the legacy
        // kinds keep their exact construction when no --adversary is
        // set, preserving bit-identity)
        let controller = cfg.adversary.map(|kind| {
            Arc::new(AdversaryController::new(
                kind,
                Topology::single(n, cfg.cluster.f),
                &cfg.cluster.byzantine_ids,
                cfg.attack.magnitude,
            ))
        });
        let coordinated = controller.is_some();
        let byzantine = |i: WorkerId| {
            (!coordinated && byz_ids.contains(&i))
                .then(|| ByzantineBehavior::new(attack.clone(), seed, i))
        };
        let wiring = controller
            .as_ref()
            .map(|c| AdversaryWiring { controller: c.clone(), lo: 0 });
        let transport: Box<dyn Transport> = match cfg.cluster.transport {
            TransportKind::Threaded => Box::new(ThreadedTransport::spawn_full(
                n,
                engine.clone(),
                byzantine,
                opts.compressor.clone(),
                cfg.cluster.latency_us,
                wiring,
            )),
            TransportKind::Sim => {
                let mut sim_cfg = opts.sim.clone();
                // convenience: a cluster-level fixed latency applies to
                // the simulator too unless a distribution is configured
                if matches!(sim_cfg.latency, LatencyModel::Zero) && cfg.cluster.latency_us > 0 {
                    sim_cfg.latency = LatencyModel::Fixed { us: cfg.cluster.latency_us };
                }
                Box::new(SimTransport::new_full(
                    n,
                    engine.clone(),
                    byzantine,
                    opts.compressor.clone(),
                    sim_cfg,
                    wiring,
                ))
            }
            TransportKind::Net => {
                // the coordinated adversary is wired through in-process
                // Arcs — it cannot reach across a process boundary
                anyhow::ensure!(
                    cfg.adversary.is_none(),
                    "--adversary strategies are in-process only (use --transport threaded|sim)"
                );
                let model = opts.net_model.clone().ok_or_else(|| {
                    anyhow::anyhow!("net transport needs the model spec (MasterOptions.net_model)")
                })?;
                let mut net_cfg = NetConfig::new(cfg.cluster.peers.clone(), model);
                net_cfg.seed = seed;
                net_cfg.latency_us = cfg.cluster.latency_us;
                net_cfg.attack = Some(attack.clone());
                net_cfg.byzantine_ids = byz_ids.clone();
                net_cfg.compressor = opts.compressor.clone();
                net_cfg.chaos = match &cfg.cluster.chaos {
                    Some(s) => Some(ChaosSpec::parse(s)?),
                    None => None,
                };
                net_cfg.auth = cfg.cluster.auth_key.as_deref().map(AuthKey::from_passphrase);
                // worker-side spans + clock sync only pay for themselves
                // when a recorder will consume them
                net_cfg.telemetry = opts.recorder.is_some();
                Box::new(NetTransport::connect(net_cfg)?)
            }
        };
        let mut master =
            Self::with_transport(cfg, opts, engine, dataset, init_theta, chunk_size, transport)?;
        if let Some(c) = controller {
            match &mut master.backend {
                Backend::Single(core) => core.set_tap(Arc::new(CoreTap::new(c, 0, 0))),
                Backend::Sharded(_) => unreachable!("single-master path"),
            }
        }
        Ok(master)
    }

    /// Build the sharded backend: a [`ShardPlan`] partitions the
    /// workers, each shard gets its own inner transport + protocol
    /// core, and a [`ParameterServer`] owns theta and the SGD step.
    fn new_sharded(
        cfg: ExperimentConfig,
        opts: MasterOptions,
        engine: Arc<dyn GradientComputer>,
        dataset: Arc<dyn Dataset>,
        init_theta: Vec<f32>,
        chunk_size: usize,
    ) -> Result<Master> {
        anyhow::ensure!(
            opts.unaudited_filter.is_none() && !opts.election,
            "sharded runs do not support unaudited filters or election decode yet"
        );
        anyhow::ensure!(chunk_size > 0, "chunk_size must be positive");
        let plan = ShardPlan::build(
            cfg.cluster.n,
            cfg.cluster.shards,
            cfg.cluster.f,
            &cfg.cluster.byzantine_ids,
        )?;
        // one omniscient controller spans every shard: its topology is
        // the plan itself, so the shard-equivocator can read each
        // shard's 2f_s+1 floor
        let controller = cfg.adversary.map(|kind| {
            let topology = Topology {
                shards: plan
                    .specs
                    .iter()
                    .map(|s| ShardInfo { shard: s.shard, lo: s.lo, n: s.width(), f: s.f_s })
                    .collect(),
                n: cfg.cluster.n,
            };
            Arc::new(AdversaryController::new(
                kind,
                topology,
                &cfg.cluster.byzantine_ids,
                cfg.attack.magnitude,
            ))
        });
        let build = super::shard::transport::ShardBuildConfig {
            transport: cfg.cluster.transport,
            gather: cfg.cluster.gather,
            cluster_n: cfg.cluster.n,
            seed: cfg.cluster.seed,
            attack: cfg.attack.clone(),
            policy: cfg.policy.clone(),
            chunk_size,
            self_check: opts.self_check,
            tol: opts.tol,
            no_eliminate: opts.no_eliminate,
            compressor: opts.compressor.clone(),
            pipeline: cfg.cluster.pipeline,
            latency_us: cfg.cluster.latency_us,
            sim: opts.sim.clone(),
            adversary: controller,
            recorder: opts.recorder.clone(),
            peers: cfg.cluster.peers.clone(),
            net_model: opts.net_model.clone(),
            chaos: match &cfg.cluster.chaos {
                Some(s) => Some(ChaosSpec::parse(s)?),
                None => None,
            },
            auth: cfg.cluster.auth_key.as_deref().map(AuthKey::from_passphrase),
        };
        let transport = ShardedTransport::build(&plan, &build, &engine)?;
        let ps = ParameterServer::new(
            transport,
            engine.clone(),
            dataset.clone(),
            init_theta,
            chunk_size,
            cfg.train.lr,
            cfg.cluster.seed,
            opts.w_star.clone(),
            cfg.train.steps as u64,
            cfg.cluster.pipeline,
            opts.recorder.clone(),
        )?;
        let d = engine.param_dim();
        Ok(Master {
            cfg,
            opts,
            engine,
            dataset,
            backend: Backend::Sharded(ps),
            theta: Vec::new(), // owned by the parameter server until `finish`
            chunk_size,
            agg: vec![0.0f32; d],
            used_losses: Vec::new(),
            wall_origin: Instant::now(),
            last_wall_end_ns: 0,
        })
    }

    /// Build a master over an explicit transport (tests and benches
    /// inject custom scenarios here; single-core only). A coordinated
    /// `cfg.adversary` is wired by [`Master::new`] — which builds the
    /// transport, the controller, and the protocol tap together — not
    /// here: an injected transport carries its own worker behaviours.
    pub fn with_transport(
        cfg: ExperimentConfig,
        opts: MasterOptions,
        engine: Arc<dyn GradientComputer>,
        dataset: Arc<dyn Dataset>,
        init_theta: Vec<f32>,
        chunk_size: usize,
        transport: Box<dyn Transport>,
    ) -> Result<Master> {
        cfg.cluster.validate()?;
        anyhow::ensure!(
            cfg.cluster.shards <= 1,
            "with_transport drives a single protocol core; use Master::new for sharded runs"
        );
        anyhow::ensure!(chunk_size > 0, "chunk_size must be positive");
        anyhow::ensure!(
            init_theta.len() == engine.param_dim(),
            "init theta dim {} != engine param dim {}",
            init_theta.len(),
            engine.param_dim()
        );
        anyhow::ensure!(
            transport.n() == cfg.cluster.n,
            "transport has {} workers, cluster config says {}",
            transport.n(),
            cfg.cluster.n
        );
        let policy = FaultCheckPolicy::new(cfg.policy.clone(), cfg.cluster.n, cfg.cluster.seed);
        let mut core = ProtocolCore::new(
            transport,
            policy,
            ProtocolConfig {
                f: cfg.cluster.f,
                seed: cfg.cluster.seed,
                chunk_size,
                self_check: opts.self_check,
                tol: opts.tol,
                no_eliminate: opts.no_eliminate,
                compressor: opts.compressor.clone(),
                gather: cfg.cluster.gather,
                pipeline: cfg.cluster.pipeline,
            },
        );
        if let Some(rec) = &opts.recorder {
            core.set_recorder(rec.clone().handle());
        }
        let d = engine.param_dim();
        Ok(Master {
            cfg,
            opts,
            engine,
            dataset,
            backend: Backend::Single(core),
            theta: init_theta,
            chunk_size,
            agg: vec![0.0f32; d],
            used_losses: Vec::new(),
            wall_origin: Instant::now(),
            last_wall_end_ns: 0,
        })
    }

    /// Run the configured number of iterations.
    pub fn run(mut self) -> Result<TrainOutcome> {
        let mut metrics = TrainMetrics::default();
        let mut events = EventLog::default();
        let steps = self.cfg.train.steps;
        let sharded = matches!(self.backend, Backend::Sharded(_));
        if !sharded && self.cfg.cluster.pipeline.max(1) > 1 {
            self.run_pipelined(steps as u64, &mut metrics, &mut events)?;
        } else {
            for t in 0..steps as u64 {
                let rec = if sharded {
                    match &mut self.backend {
                        Backend::Sharded(ps) => ps.run_round(t, &mut events)?,
                        Backend::Single(_) => unreachable!(),
                    }
                } else {
                    self.iteration(t, &mut events)?
                };
                if let Some(board) = &self.opts.status {
                    board.on_round(&rec, &events);
                }
                metrics.push(rec);
            }
        }
        let (theta, eliminated, crashed) = match self.backend {
            Backend::Single(core) => {
                let (eliminated, crashed) = core.into_outcome();
                (self.theta, eliminated, crashed)
            }
            Backend::Sharded(ps) => ps.finish(),
        };
        Ok(TrainOutcome { theta, metrics, events, eliminated, crashed })
    }

    fn core_mut(&mut self) -> &mut ProtocolCore {
        match &mut self.backend {
            Backend::Single(core) => core,
            Backend::Sharded(_) => unreachable!("sharded rounds go through the parameter server"),
        }
    }

    /// One full single-core protocol iteration (unpipelined):
    /// begin → collect → finish back-to-back, then aggregate + update.
    fn iteration(&mut self, t: u64, events: &mut EventLog) -> Result<IterationRecord> {
        let start_wall_ns = self.wall_origin.elapsed().as_nanos() as u64;
        let dataset = self.dataset.clone();
        let theta = Arc::new(self.theta.clone());
        self.core_mut().begin_round_sampled(t, &theta, dataset.as_ref())?;
        self.core_mut().collect_proactive(t, &theta, dataset.as_ref(), events)?;
        self.apply_finished_round(t, &theta, start_wall_ns, events)
    }

    /// Software-pipelined single-core driver (`--pipeline DEPTH ≥ 2`).
    ///
    /// Per iteration t: collect t's proactive wave, compute a
    /// *provisional* θ' from the pre-audit symbols and launch t+1's
    /// wave on it, then finish t (detection/reactive audit) and apply
    /// the exact update. If the audit changed anything — a liar was
    /// identified, or the exact θ differs bit-wise from θ' — the
    /// speculative wave is invalidated and reissued on the exact θ;
    /// otherwise θ' *was* exact and the overlapped wave stands. θ thus
    /// applies in strict iteration order at any depth.
    fn run_pipelined(
        &mut self,
        steps: u64,
        metrics: &mut TrainMetrics,
        events: &mut EventLog,
    ) -> Result<()> {
        if steps == 0 {
            return Ok(());
        }
        let dataset = self.dataset.clone();
        let engine = self.engine.clone();
        let d = engine.param_dim();
        let n = self.cfg.cluster.n;
        let lr = self.cfg.train.lr;
        let mut agg_prov = vec![0.0f32; d];
        // prime the pipeline: round 0 runs on the real θ
        let mut theta_t = Arc::new(self.theta.clone());
        self.core_mut().begin_round_sampled(0, &theta_t, dataset.as_ref())?;
        for t in 0..steps {
            let start_wall_ns = self.wall_origin.elapsed().as_nanos() as u64;
            self.core_mut().collect_proactive(t, &theta_t, dataset.as_ref(), events)?;

            // speculate: provisional θ' from t's pre-audit symbols
            // (never trusting an audit that has not happened — the
            // provisional aggregate uses the unaudited ruleset)
            let mut speculative = None;
            if t + 1 < steps {
                {
                    let core = match &self.backend {
                        Backend::Single(core) => core,
                        Backend::Sharded(_) => unreachable!(),
                    };
                    let round = core.pending_round(t).expect("collected above");
                    Self::aggregate_round(&mut agg_prov, round, false, core.f_t(), n, d, &self.opts);
                }
                let mut prov = self.theta.clone();
                engine.sgd_step(&mut prov, &agg_prov, lr)?;
                let prov = Arc::new(prov);
                self.core_mut().begin_round_sampled(t + 1, &prov, dataset.as_ref())?;
                speculative = Some(prov);
            }

            // retire round t: audit, vote, eliminate, exact update
            let rec = self.apply_finished_round(t, &theta_t, start_wall_ns, events)?;
            let caught_liar = rec.identified > 0;
            if let Some(board) = &self.opts.status {
                board.on_round(&rec, events);
            }
            metrics.push(rec);

            // ordered θ application: reissue t+1 on the exact θ iff
            // the speculation was wrong (fault-free rounds keep their
            // overlapped wave untouched)
            if let Some(prov) = speculative {
                if caught_liar || prov.as_slice() != self.theta.as_slice() {
                    let exact = Arc::new(self.theta.clone());
                    self.core_mut().reissue_round(t + 1, &exact, dataset.as_ref())?;
                    theta_t = exact;
                } else {
                    theta_t = prov;
                }
            }
        }
        Ok(())
    }

    /// Finish iteration `t` in the core (detection/reactive audit),
    /// aggregate the chosen symbols, apply the SGD step, and build the
    /// metrics record. Shared by the sequential and pipelined drivers;
    /// `theta` must be the θ the round's surviving proactive wave was
    /// issued on, so audit recomputations compare like with like.
    /// `start_wall_ns` is the round's wall start (ns since
    /// `wall_origin`); the reported `wall_ns` is **exclusive** — it
    /// runs from `max(start, previous round's end)`, so the per-round
    /// wall periods tile the run without double-counting the overlap a
    /// pipelined driver creates (mirrors `round_ns` on the transport
    /// clock).
    fn apply_finished_round(
        &mut self,
        t: u64,
        theta: &Arc<Vec<f32>>,
        start_wall_ns: u64,
        events: &mut EventLog,
    ) -> Result<IterationRecord> {
        let dataset = self.dataset.clone();
        let engine = self.engine.clone();
        let d = engine.param_dim();
        let n = self.cfg.cluster.n;
        let core = match &mut self.backend {
            Backend::Single(core) => core,
            Backend::Sharded(_) => unreachable!("sharded rounds go through the parameter server"),
        };
        let f_t = core.f_t();
        let out = core.finish_round(t, theta, dataset.as_ref(), engine.as_ref(), events)?;

        // ---- aggregate + update ----------------------------------------
        let round = core.round();
        let nchunks = round.nchunks();
        let mut oracle_faulty = false;
        self.used_losses.clear();
        for c in 0..nchunks {
            let chosen = round.chosen(c);
            self.used_losses.push(chosen.loss as f64);
            if chosen.worker != MASTER_SENTINEL
                && round.tampered_by_chunk[c].contains(&chosen.worker)
            {
                oracle_faulty = true;
            }
        }
        Self::aggregate_round(&mut self.agg, round, out.audited, f_t, n, d, &self.opts);
        if oracle_faulty {
            let e = Event::OracleFaultyUpdate { iter: t };
            if let Some(rec) = &self.opts.recorder {
                rec.on_master_event(None, &e);
            }
            events.push(e);
        }
        engine.sgd_step(&mut self.theta, &self.agg, self.cfg.train.lr)?;

        // ---- metrics -----------------------------------------------------
        let round = core.round();
        let computed_points: u64 = round
            .chunks
            .iter()
            .map(|c| (c.computed_copies * self.chunk_size) as u64)
            .sum::<u64>()
            + out.master_computed_points;
        let (lambda, _) = core.policy().adaptive_state();
        // exclusive wall period: from wherever the previous round's
        // wall period ended (or this round's start, whichever is
        // later) to now — pipelined overlap is counted exactly once
        let now_wall_ns = self.wall_origin.elapsed().as_nanos() as u64;
        let wall_ns = now_wall_ns.saturating_sub(start_wall_ns.max(self.last_wall_end_ns));
        self.last_wall_end_ns = now_wall_ns;
        Ok(IterationRecord {
            iter: t,
            gradients_used: out.gradients_used,
            gradients_computed: computed_points,
            audited: out.audited,
            faults_detected: out.faults_detected,
            identified: out.identified_now.len(),
            crashed: out.crashed_now.len(),
            loss: stats::median(&self.used_losses) as f32,
            q: core.policy().last_q,
            lambda,
            oracle_faulty_update: oracle_faulty,
            dist_to_opt: self
                .opts
                .w_star
                .as_ref()
                .map(|w| crate::linalg::dist2(&self.theta, w)),
            wall_ns,
            round_ns: out.round_ns,
            bytes_round: out.bytes_round,
            pipeline_depth: self.cfg.cluster.pipeline.max(1),
            net_reconnects: out.net_reconnects,
            stragglers: out.stragglers_now.len(),
            audited_chunks: out.audited_chunks,
            suspicion: core.policy().suspicion_nonzero(),
            shard_stats: Vec::new(),
        })
    }

    /// Aggregate the round's chosen per-chunk gradients into `agg`
    /// under the configured ruleset. `audited` gates the §5 hybrid
    /// filter; the pipelined driver also calls this with
    /// `audited = false` to form the provisional update that seeds the
    /// next round's speculative wave.
    fn aggregate_round(
        agg: &mut Vec<f32>,
        round: &RoundState,
        audited: bool,
        f_t: usize,
        n: usize,
        d: usize,
        opts: &MasterOptions,
    ) {
        let nchunks = round.nchunks();
        let needs_dense_copies =
            opts.compressor.is_some() || (opts.unaudited_filter.is_some() && !audited);
        if needs_dense_copies {
            // per-chunk clone + axpy keeps the legacy summation order
            // of the compressed path
            let chunk_values: Vec<Vec<f32>> = (0..nchunks)
                .map(|c| match &opts.compressor {
                    // election decode (E13): per-symbol majority across
                    // every replica wire of the chunk
                    Some(comp) if opts.election => {
                        let wires: Vec<&[u8]> = round.chunks[c]
                            .copies
                            .iter()
                            .filter_map(|s| s.wire.as_deref())
                            .collect();
                        if wires.is_empty() {
                            round.chosen(c).grad.clone()
                        } else {
                            comp.unpack_election(&wires, d)
                        }
                    }
                    // exact decode: symbols already carry the dense
                    // unpack of their wire bytes
                    _ => round.chosen(c).grad.clone(),
                })
                .collect();
            match (&opts.unaudited_filter, audited) {
                // hybrid mode (§5): filter the un-audited aggregation
                (Some(filter), false) => *agg = filter.aggregate(&chunk_values, f_t),
                _ => {
                    agg.fill(0.0);
                    for v in &chunk_values {
                        crate::linalg::axpy(1.0 / nchunks as f32, v, agg);
                    }
                }
            }
        } else {
            // dense path: the same fixed-shape worker-id-slotted tree
            // sum the sharded parameter server uses, so a K = 1 run is
            // bit-identical to a sharded one (see `coordinator::shard`)
            let mut leaves: Vec<Option<&[f32]>> = vec![None; n];
            for c in 0..nchunks {
                leaves[round.assignment.owners[c][0]] = Some(&round.chosen(c).grad);
            }
            *agg = crate::linalg::tree_sum(&leaves).expect("at least one chunk");
            crate::linalg::scale(1.0 / nchunks as f32, agg);
        }
    }
}
