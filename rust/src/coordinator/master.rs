//! The master: synchronous parallelized-SGD training loop with
//! randomized reactive redundancy (the paper's full protocol).
//!
//! Per-iteration phases (numbered as wire `phase` values):
//!
//! * **0 proactive** — sample m points, assign chunks with replication
//!   r (f_t+1 deterministic / 1 otherwise), collect symbols.
//! * **1 detection** — if this iteration is audited and a chunk has
//!   only one copy, assign it to f_t additional workers (self-check
//!   mode instead recomputes on the master) and compare copies.
//! * **2 reactive** — for chunks whose copies disagree, top up to
//!   2f_t+1 distinct owners, majority-vote the true value, identify
//!   the liars, eliminate them (κ_t += …, f_t shrinks).
//! * **update** — aggregate the per-chunk gradients, SGD-step through
//!   the gradient engine, record metrics/events.
//!
//! Exactness (Def. 1): every audited iteration ends with provably
//! correct chunk values; unaudited iterations may use tampered
//! gradients, but each persistent Byzantine worker is identified
//! almost surely ((1-qp)^t -> 0) and eliminated, after which the run
//! is attack-free and converges exactly.

use std::sync::Arc;
use std::time::Instant;

use super::assignment::{sample_points, Assignment};
use super::byzantine::ByzantineBehavior;
use super::compress::Compressor;
use super::codes::{check_copies, CheckOutcome, SymbolCopy};
use super::events::{Event, EventLog};
use super::identify::majority_vote;
use super::metrics::{IterationRecord, TrainMetrics};
use super::policy::{AuditDecision, FaultCheckPolicy};
use super::worker::{Symbol, WorkerPool};
use super::{ChunkId, WorkerId};
use crate::config::ExperimentConfig;
use crate::data::Dataset;
use crate::grad::GradientComputer;
use crate::util::rng::Pcg64;
use crate::util::stats;
use crate::Result;

/// Extra master behaviour knobs not present in the file config.
#[derive(Clone)]
pub struct MasterOptions {
    /// §5 self-check generalization: audit by recomputing on the master
    /// instead of replicating to additional workers.
    pub self_check: bool,
    /// Comparison tolerance (0.0 = exact bitwise, the default — honest
    /// engines are deterministic).
    pub tol: f32,
    /// Oracle: the planted optimum for dist-to-opt metrics.
    pub w_star: Option<Vec<f32>>,
    /// Measurement mode for the E2/E3 benches: identify (and correct)
    /// but never eliminate, holding f_t = f as the paper's Eqs. (2)-(3)
    /// assume. Never used in production runs.
    pub no_eliminate: bool,
    /// §2.1/§5: workers send compressed symbols; detection and voting
    /// operate on the compressed wire form, the master decompresses for
    /// aggregation. None = dense protocol.
    pub compressor: Option<Arc<dyn Compressor>>,
    /// §5 hybrid generalization: in *unaudited* iterations aggregate the
    /// per-chunk gradients through a lightweight gradient filter instead
    /// of the plain mean, bounding the damage of un-audited tampering.
    pub unaudited_filter: Option<Arc<dyn crate::baselines::GradientFilter>>,
}

impl Default for MasterOptions {
    fn default() -> Self {
        MasterOptions {
            self_check: false,
            tol: 0.0,
            w_star: None,
            no_eliminate: false,
            compressor: None,
            unaudited_filter: None,
        }
    }
}

/// Result of a training run.
pub struct TrainOutcome {
    pub theta: Vec<f32>,
    pub metrics: TrainMetrics,
    pub events: EventLog,
    /// Workers identified as Byzantine (in identification order).
    pub eliminated: Vec<WorkerId>,
}

pub struct Master {
    cfg: ExperimentConfig,
    opts: MasterOptions,
    engine: Arc<dyn GradientComputer>,
    dataset: Arc<dyn Dataset>,
    pool: WorkerPool,
    policy: FaultCheckPolicy,
    rng: Pcg64,
    active: Vec<WorkerId>,
    eliminated: Vec<WorkerId>,
    theta: Vec<f32>,
    chunk_size: usize,
}

/// Per-chunk working state during one iteration.
struct ChunkState {
    copies: Vec<SymbolCopy>,
    /// data-point count already charged to `gradients_computed`.
    computed_copies: usize,
}

impl Master {
    /// Build a master over an engine + dataset. `init_theta` seeds the
    /// parameter vector (use `ModelSpec::init_theta` or
    /// `init_transformer_tiny`). `chunk_size` is the number of data
    /// points per chunk — for the XLA engine it must equal the
    /// artifact's compiled batch size.
    pub fn new(
        cfg: ExperimentConfig,
        opts: MasterOptions,
        engine: Arc<dyn GradientComputer>,
        dataset: Arc<dyn Dataset>,
        init_theta: Vec<f32>,
        chunk_size: usize,
    ) -> Result<Master> {
        cfg.cluster.validate()?;
        anyhow::ensure!(chunk_size > 0, "chunk_size must be positive");
        anyhow::ensure!(
            init_theta.len() == engine.param_dim(),
            "init theta dim {} != engine param dim {}",
            init_theta.len(),
            engine.param_dim()
        );
        let n = cfg.cluster.n;
        let seed = cfg.cluster.seed;
        let attack = cfg.attack.clone();
        let byz_ids = cfg.cluster.byzantine_ids.clone();
        let pool = WorkerPool::spawn_with_compressor(
            n,
            engine.clone(),
            |i| {
                byz_ids
                    .contains(&i)
                    .then(|| ByzantineBehavior::new(attack.clone(), seed, i))
            },
            opts.compressor.clone(),
            cfg.cluster.latency_us,
        );
        let policy = FaultCheckPolicy::new(cfg.policy.clone(), n, seed);
        Ok(Master {
            opts,
            engine,
            dataset,
            pool,
            policy,
            rng: Pcg64::new(seed, 0xaa57e2),
            active: (0..n).collect(),
            eliminated: Vec::new(),
            theta: init_theta,
            chunk_size,
            cfg,
        })
    }

    /// Current Byzantine budget f_t = f - κ_t.
    fn f_t(&self) -> usize {
        self.cfg.cluster.f.saturating_sub(self.eliminated.len())
    }

    /// Run the configured number of iterations.
    pub fn run(mut self) -> Result<TrainOutcome> {
        let mut metrics = TrainMetrics::default();
        let mut events = EventLog::default();
        let steps = self.cfg.train.steps;
        for t in 0..steps as u64 {
            let rec = self.iteration(t, &mut events)?;
            metrics.push(rec);
        }
        self.pool.shutdown();
        Ok(TrainOutcome {
            theta: self.theta,
            metrics,
            events,
            eliminated: self.eliminated,
        })
    }

    /// One full protocol iteration.
    fn iteration(&mut self, t: u64, events: &mut EventLog) -> Result<IterationRecord> {
        let t0 = Instant::now();
        let f_t = self.f_t();
        let nact = self.active.len();
        let r = self.policy.proactive_r(f_t).min(nact);

        // ---- phase 0: proactive assignment + symbols -------------------
        let m = nact * self.chunk_size;
        let data_ids = sample_points(&mut self.rng, self.dataset.len(), m);
        let mut assignment = Assignment::new(&data_ids, &self.active, r);
        let theta = Arc::new(self.theta.clone());

        let mut per_worker: Vec<(WorkerId, Vec<(ChunkId, crate::data::Batch)>)> = Vec::new();
        for &w in &self.active {
            let tasks: Vec<(ChunkId, crate::data::Batch)> = assignment
                .chunks_of(w)
                .into_iter()
                .map(|c| (c, self.dataset.batch(&assignment.chunks[c])))
                .collect();
            per_worker.push((w, tasks));
        }
        for (w, tasks) in per_worker {
            self.pool.send(w, t, 0, &theta, tasks)?;
        }
        let responses = self.pool.collect(t, 0, nact)?;

        let nchunks = assignment.nchunks();
        let mut chunks: Vec<ChunkState> = (0..nchunks)
            .map(|_| ChunkState { copies: Vec::new(), computed_copies: 0 })
            .collect();
        let mut tampered_by_chunk: Vec<Vec<WorkerId>> = vec![Vec::new(); nchunks];
        for resp in responses {
            for Symbol { chunk, grad, loss, tampered } in resp.symbols {
                if tampered {
                    tampered_by_chunk[chunk].push(resp.worker);
                }
                chunks[chunk].copies.push(SymbolCopy { worker: resp.worker, grad, loss });
                chunks[chunk].computed_copies += 1;
            }
        }

        // observed loss ℓ_t: median of received symbol losses (robust to
        // up to f liars as the paper's trimmed-estimate note suggests)
        let losses: Vec<f64> = chunks
            .iter()
            .flat_map(|c| c.copies.iter().map(|s| s.loss as f64))
            .collect();
        let observed_loss = stats::median(&losses);

        // ---- audit decision --------------------------------------------
        let decision = self.policy.decide(t, observed_loss, f_t, &self.active);
        let audited = decision != AuditDecision::Skip;
        events.push(Event::AuditDecision { iter: t, q: self.policy.last_q, audited });

        let audit_chunks: Vec<ChunkId> = match &decision {
            AuditDecision::Skip => vec![],
            AuditDecision::Full => (0..nchunks).collect(),
            AuditDecision::Workers(ws) => (0..nchunks)
                .filter(|&c| assignment.owners[c].iter().any(|w| ws.contains(w)))
                .collect(),
        };

        let mut master_computed_points = 0u64;
        let mut faults_detected = 0usize;
        let mut identified_now: Vec<WorkerId> = Vec::new();

        if !audit_chunks.is_empty() {
            // ---- phase 1: detection ------------------------------------
            // top every audited chunk up to f_t+1 distinct copies
            let mut extra: Vec<(WorkerId, Vec<ChunkId>)> = Vec::new();
            let mut master_tasks: Vec<ChunkId> = Vec::new();
            for &c in &audit_chunks {
                let have = chunks[c].copies.len();
                let want = f_t + 1;
                if have >= want {
                    continue;
                }
                if self.opts.self_check {
                    master_tasks.push(c);
                } else {
                    let added = assignment.extend(c, want - have, &mut self.rng);
                    for w in added {
                        match extra.iter_mut().find(|(ww, _)| *ww == w) {
                            Some((_, cs)) => cs.push(c),
                            None => extra.push((w, vec![c])),
                        }
                    }
                }
            }
            let expected = extra.len();
            for (w, cs) in extra {
                let tasks: Vec<_> = cs
                    .into_iter()
                    .map(|c| (c, self.dataset.batch(&assignment.chunks[c])))
                    .collect();
                self.pool.send(w, t, 1, &theta, tasks)?;
            }
            if expected > 0 {
                for resp in self.pool.collect(t, 1, expected)? {
                    for Symbol { chunk, grad, loss, tampered } in resp.symbols {
                        if tampered {
                            tampered_by_chunk[chunk].push(resp.worker);
                        }
                        chunks[chunk]
                            .copies
                            .push(SymbolCopy { worker: resp.worker, grad, loss });
                        chunks[chunk].computed_copies += 1;
                    }
                }
            }
            // master self-checks: recompute locally (trusted copy)
            for c in master_tasks {
                let batch = self.dataset.batch(&assignment.chunks[c]);
                let g = self.engine.grad(&theta, &batch)?;
                master_computed_points += self.chunk_size as u64;
                let grad = match &self.opts.compressor {
                    Some(comp) => comp.encode(&g.grad),
                    None => g.grad,
                };
                chunks[c].copies.push(SymbolCopy {
                    // the master is not a worker: use a sentinel id that
                    // can never be eliminated
                    worker: usize::MAX,
                    grad,
                    loss: g.loss,
                });
            }

            // ---- detection comparisons + phase 2: reactive redundancy --
            let mut flagged: Vec<ChunkId> = Vec::new();
            for &c in &audit_chunks {
                match check_copies(&chunks[c].copies, self.opts.tol) {
                    CheckOutcome::Unanimous => {
                        for s in &chunks[c].copies {
                            if s.worker != usize::MAX {
                                self.policy.report_verified(s.worker);
                            }
                        }
                    }
                    CheckOutcome::FaultDetected => {
                        faults_detected += 1;
                        let owners: Vec<WorkerId> = chunks[c]
                            .copies
                            .iter()
                            .map(|s| s.worker)
                            .filter(|&w| w != usize::MAX)
                            .collect();
                        events.push(Event::FaultDetected { iter: t, chunk: c, owners: owners.clone() });
                        self.policy.report_suspects(&owners);
                        flagged.push(c);
                    }
                }
            }

            if !flagged.is_empty() {
                if self.opts.self_check {
                    // the master's own copy is ground truth: every worker
                    // copy differing from it is provably Byzantine
                    for &c in &flagged {
                        let master_copy = chunks[c]
                            .copies
                            .iter()
                            .find(|s| s.worker == usize::MAX)
                            .expect("self-check copy present")
                            .clone();
                        let liars: Vec<WorkerId> = chunks[c]
                            .copies
                            .iter()
                            .filter(|s| {
                                s.worker != usize::MAX
                                    && !super::codes::symbols_equal(s, &master_copy, self.opts.tol)
                            })
                            .map(|s| s.worker)
                            .collect();
                        self.finish_vote(t, c, &mut chunks[c], master_copy, liars, &mut identified_now, events);
                    }
                } else {
                    // top flagged chunks up to 2 f_t + 1 copies
                    let mut extra: Vec<(WorkerId, Vec<ChunkId>)> = Vec::new();
                    for &c in &flagged {
                        let want = 2 * f_t + 1;
                        let have = chunks[c].copies.len();
                        if have < want {
                            let added = assignment.extend(c, want - have, &mut self.rng);
                            events.push(Event::ReactiveRedundancy {
                                iter: t,
                                chunk: c,
                                added: added.clone(),
                            });
                            for w in added {
                                match extra.iter_mut().find(|(ww, _)| *ww == w) {
                                    Some((_, cs)) => cs.push(c),
                                    None => extra.push((w, vec![c])),
                                }
                            }
                        }
                    }
                    let expected = extra.len();
                    for (w, cs) in extra {
                        let tasks: Vec<_> = cs
                            .into_iter()
                            .map(|c| (c, self.dataset.batch(&assignment.chunks[c])))
                            .collect();
                        self.pool.send(w, t, 2, &theta, tasks)?;
                    }
                    if expected > 0 {
                        for resp in self.pool.collect(t, 2, expected)? {
                            for Symbol { chunk, grad, loss, tampered } in resp.symbols {
                                if tampered {
                                    tampered_by_chunk[chunk].push(resp.worker);
                                }
                                chunks[chunk]
                                    .copies
                                    .push(SymbolCopy { worker: resp.worker, grad, loss });
                                chunks[chunk].computed_copies += 1;
                            }
                        }
                    }
                    for &c in &flagged {
                        let vote = majority_vote(&chunks[c].copies, f_t)
                            .expect("quorum guaranteed with 2f_t+1 distinct owners");
                        let winner =
                            SymbolCopy { worker: usize::MAX, grad: vote.grad, loss: vote.loss };
                        let liars = vote.liars;
                        self.finish_vote(t, c, &mut chunks[c], winner, liars, &mut identified_now, events);
                    }
                }
            }
        }

        // ---- aggregate + update ----------------------------------------
        // chunk value: majority-corrected value if present (stored at
        // front by finish_vote), else the first received copy
        let d = self.engine.param_dim();
        let mut oracle_faulty = false;
        let mut used_losses: Vec<f64> = Vec::with_capacity(nchunks);
        for (c, chunk) in chunks.iter().enumerate() {
            let chosen = &chunk.copies[0];
            used_losses.push(chosen.loss as f64);
            if chosen.worker != usize::MAX && tampered_by_chunk[c].contains(&chosen.worker) {
                oracle_faulty = true;
            }
        }
        let needs_dense_copies =
            self.opts.compressor.is_some() || (self.opts.unaudited_filter.is_some() && !audited);
        let aggregate = if needs_dense_copies {
            let chunk_values: Vec<Vec<f32>> = chunks
                .iter()
                .map(|chunk| match &self.opts.compressor {
                    Some(comp) => comp.decode(&chunk.copies[0].grad, d),
                    None => chunk.copies[0].grad.clone(),
                })
                .collect();
            match (&self.opts.unaudited_filter, audited) {
                // hybrid mode (§5): filter the un-audited aggregation
                (Some(filter), false) => filter.aggregate(&chunk_values, f_t),
                _ => {
                    let mut acc = vec![0.0f32; d];
                    for v in &chunk_values {
                        crate::linalg::axpy(1.0 / nchunks as f32, v, &mut acc);
                    }
                    acc
                }
            }
        } else {
            // hot path: accumulate straight from the chosen copies, no
            // per-chunk clone (perf: saves nchunks × d copies/iteration)
            let mut acc = vec![0.0f32; d];
            for chunk in &chunks {
                crate::linalg::axpy(1.0 / nchunks as f32, &chunk.copies[0].grad, &mut acc);
            }
            acc
        };
        if oracle_faulty {
            events.push(Event::OracleFaultyUpdate { iter: t });
        }
        self.engine
            .sgd_step(&mut self.theta, &aggregate, self.cfg.train.lr)?;

        // ---- metrics -----------------------------------------------------
        let computed_points: u64 = chunks
            .iter()
            .map(|c| (c.computed_copies * self.chunk_size) as u64)
            .sum::<u64>()
            + master_computed_points;
        let (lambda, _) = self.policy.adaptive_state();
        Ok(IterationRecord {
            iter: t,
            gradients_used: m as u64,
            gradients_computed: computed_points,
            audited,
            faults_detected,
            identified: identified_now.len(),
            loss: stats::median(&used_losses) as f32,
            q: self.policy.last_q,
            lambda,
            oracle_faulty_update: oracle_faulty,
            dist_to_opt: self
                .opts
                .w_star
                .as_ref()
                .map(|w| crate::linalg::dist2(&self.theta, w)),
            wall_ns: t0.elapsed().as_nanos() as u64,
        })
    }

    /// Common tail of both identification paths: store the corrected
    /// value at the front of the chunk's copies, eliminate liars.
    #[allow(clippy::too_many_arguments)]
    fn finish_vote(
        &mut self,
        t: u64,
        _c: ChunkId,
        chunk: &mut ChunkState,
        winner: SymbolCopy,
        liars: Vec<WorkerId>,
        identified_now: &mut Vec<WorkerId>,
        events: &mut EventLog,
    ) {
        chunk.copies.insert(0, winner);
        if liars.is_empty() {
            return;
        }
        events.push(Event::Identified { iter: t, workers: liars.clone() });
        if self.opts.no_eliminate {
            return;
        }
        for w in liars {
            if let Some(pos) = self.active.iter().position(|&a| a == w) {
                self.active.remove(pos);
                self.eliminated.push(w);
                self.policy.report_identified(w);
                events.push(Event::Eliminated { iter: t, worker: w });
                identified_now.push(w);
            }
        }
    }
}
