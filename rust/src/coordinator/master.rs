//! The master: policy + SGD-update glue over the protocol core.
//!
//! After the transport/protocol refactor this layer is small by
//! design: it builds the cluster (choosing a [`Transport`] from the
//! config), hands each iteration to
//! [`super::protocol::ProtocolCore::run_round`] (which owns the
//! proactive → detection → reactive phase machine), then aggregates
//! the per-chunk gradients into a **reused** buffer, applies the SGD
//! step through the gradient engine, and records metrics/events.
//!
//! See [`super::protocol`] for the protocol semantics and the
//! exactness argument, and [`super::transport`] for the execution
//! models (`--transport threaded|sim`).

use std::sync::Arc;
use std::time::Instant;

use super::byzantine::ByzantineBehavior;
use super::compress::Compressor;
use super::events::{Event, EventLog};
use super::metrics::{IterationRecord, TrainMetrics};
use super::policy::FaultCheckPolicy;
use super::protocol::{ProtocolConfig, ProtocolCore};
use super::shard::{ParameterServer, ShardPlan, ShardedTransport};
use super::transport::{
    AdversaryWiring, LatencyModel, SimTransport, ThreadedTransport, Transport,
};
use super::{WorkerId, MASTER_SENTINEL};
use crate::adversary::{AdversaryController, CoreTap, ShardInfo, Topology};
use crate::config::{ExperimentConfig, TransportKind};
use crate::data::Dataset;
use crate::grad::GradientComputer;
use crate::util::stats;
use crate::Result;

/// Extra master behaviour knobs not present in the file config.
#[derive(Clone)]
pub struct MasterOptions {
    /// §5 self-check generalization: audit by recomputing on the master
    /// instead of replicating to additional workers.
    pub self_check: bool,
    /// Comparison tolerance (0.0 = exact bitwise, the default — honest
    /// engines are deterministic).
    pub tol: f32,
    /// Oracle: the planted optimum for dist-to-opt metrics.
    pub w_star: Option<Vec<f32>>,
    /// Measurement mode for the E2/E3 benches: identify (and correct)
    /// but never eliminate, holding f_t = f as the paper's Eqs. (2)-(3)
    /// assume. Never used in production runs.
    pub no_eliminate: bool,
    /// §2.1/§5: workers send compressed symbols; detection and voting
    /// operate on the compressed wire form, the master decompresses for
    /// aggregation. None = dense protocol.
    pub compressor: Option<Arc<dyn Compressor>>,
    /// §5 hybrid generalization: in *unaudited* iterations aggregate the
    /// per-chunk gradients through a lightweight gradient filter instead
    /// of the plain mean, bounding the damage of un-audited tampering.
    pub unaudited_filter: Option<Arc<dyn crate::baselines::GradientFilter>>,
    /// Scenario knobs for `--transport sim` (latency distribution,
    /// stragglers, crash plan). Ignored by the threaded transport.
    pub sim: super::transport::SimConfig,
}

impl Default for MasterOptions {
    fn default() -> Self {
        MasterOptions {
            self_check: false,
            tol: 0.0,
            w_star: None,
            no_eliminate: false,
            compressor: None,
            unaudited_filter: None,
            sim: super::transport::SimConfig::default(),
        }
    }
}

/// Result of a training run.
pub struct TrainOutcome {
    pub theta: Vec<f32>,
    pub metrics: TrainMetrics,
    pub events: EventLog,
    /// Workers identified as Byzantine (in identification order).
    pub eliminated: Vec<WorkerId>,
    /// Workers that crash-stopped (sim transport scenarios only; a
    /// crash is not an identification).
    pub crashed: Vec<WorkerId>,
}

/// Execution backend: one protocol core over all n workers, or the
/// sharded parameter server (K > 1 shards, each with its own core).
enum Backend {
    Single(ProtocolCore),
    Sharded(ParameterServer),
}

pub struct Master {
    cfg: ExperimentConfig,
    opts: MasterOptions,
    engine: Arc<dyn GradientComputer>,
    dataset: Arc<dyn Dataset>,
    backend: Backend,
    theta: Vec<f32>,
    chunk_size: usize,
    /// Reused aggregation buffer (single-core compressed/filtered
    /// paths; the dense path tree-sums into a fresh buffer).
    agg: Vec<f32>,
    /// Reused per-chunk loss buffer.
    used_losses: Vec<f64>,
}

impl Master {
    /// Build a master over an engine + dataset, choosing the transport
    /// named by `cfg.cluster.transport` ("threaded" | "sim").
    /// `init_theta` seeds the parameter vector (use
    /// `ModelSpec::init_theta` or `init_transformer_tiny`).
    /// `chunk_size` is the number of data points per chunk — for the
    /// XLA engine it must equal the artifact's compiled batch size.
    /// With `cfg.cluster.shards > 1` the master delegates every round
    /// to a [`ParameterServer`] over per-shard protocol cores.
    pub fn new(
        cfg: ExperimentConfig,
        opts: MasterOptions,
        engine: Arc<dyn GradientComputer>,
        dataset: Arc<dyn Dataset>,
        init_theta: Vec<f32>,
        chunk_size: usize,
    ) -> Result<Master> {
        cfg.cluster.validate()?;
        if cfg.cluster.shards > 1 {
            return Self::new_sharded(cfg, opts, engine, dataset, init_theta, chunk_size);
        }
        let n = cfg.cluster.n;
        let seed = cfg.cluster.seed;
        let attack = cfg.attack.clone();
        let byz_ids = cfg.cluster.byzantine_ids.clone();
        // a coordinated adversary replaces the stateless per-worker
        // behaviour path for the configured Byzantine ids (the legacy
        // kinds keep their exact construction when no --adversary is
        // set, preserving bit-identity)
        let controller = cfg.adversary.map(|kind| {
            Arc::new(AdversaryController::new(
                kind,
                Topology::single(n, cfg.cluster.f),
                &cfg.cluster.byzantine_ids,
                cfg.attack.magnitude,
            ))
        });
        let coordinated = controller.is_some();
        let byzantine = |i: WorkerId| {
            (!coordinated && byz_ids.contains(&i))
                .then(|| ByzantineBehavior::new(attack.clone(), seed, i))
        };
        let wiring = controller
            .as_ref()
            .map(|c| AdversaryWiring { controller: c.clone(), lo: 0 });
        let transport: Box<dyn Transport> = match cfg.cluster.transport {
            TransportKind::Threaded => Box::new(ThreadedTransport::spawn_full(
                n,
                engine.clone(),
                byzantine,
                opts.compressor.clone(),
                cfg.cluster.latency_us,
                wiring,
            )),
            TransportKind::Sim => {
                let mut sim_cfg = opts.sim.clone();
                // convenience: a cluster-level fixed latency applies to
                // the simulator too unless a distribution is configured
                if matches!(sim_cfg.latency, LatencyModel::Zero) && cfg.cluster.latency_us > 0 {
                    sim_cfg.latency = LatencyModel::Fixed { us: cfg.cluster.latency_us };
                }
                Box::new(SimTransport::new_full(
                    n,
                    engine.clone(),
                    byzantine,
                    opts.compressor.clone(),
                    sim_cfg,
                    wiring,
                ))
            }
        };
        let mut master =
            Self::with_transport(cfg, opts, engine, dataset, init_theta, chunk_size, transport)?;
        if let Some(c) = controller {
            match &mut master.backend {
                Backend::Single(core) => core.set_tap(Arc::new(CoreTap::new(c, 0, 0))),
                Backend::Sharded(_) => unreachable!("single-master path"),
            }
        }
        Ok(master)
    }

    /// Build the sharded backend: a [`ShardPlan`] partitions the
    /// workers, each shard gets its own inner transport + protocol
    /// core, and a [`ParameterServer`] owns theta and the SGD step.
    fn new_sharded(
        cfg: ExperimentConfig,
        opts: MasterOptions,
        engine: Arc<dyn GradientComputer>,
        dataset: Arc<dyn Dataset>,
        init_theta: Vec<f32>,
        chunk_size: usize,
    ) -> Result<Master> {
        anyhow::ensure!(
            opts.compressor.is_none() && opts.unaudited_filter.is_none(),
            "sharded runs do not support compressed symbols or unaudited filters yet"
        );
        anyhow::ensure!(chunk_size > 0, "chunk_size must be positive");
        let plan = ShardPlan::build(
            cfg.cluster.n,
            cfg.cluster.shards,
            cfg.cluster.f,
            &cfg.cluster.byzantine_ids,
        )?;
        // one omniscient controller spans every shard: its topology is
        // the plan itself, so the shard-equivocator can read each
        // shard's 2f_s+1 floor
        let controller = cfg.adversary.map(|kind| {
            let topology = Topology {
                shards: plan
                    .specs
                    .iter()
                    .map(|s| ShardInfo { shard: s.shard, lo: s.lo, n: s.width(), f: s.f_s })
                    .collect(),
                n: cfg.cluster.n,
            };
            Arc::new(AdversaryController::new(
                kind,
                topology,
                &cfg.cluster.byzantine_ids,
                cfg.attack.magnitude,
            ))
        });
        let build = super::shard::transport::ShardBuildConfig {
            transport: cfg.cluster.transport,
            gather: cfg.cluster.gather,
            cluster_n: cfg.cluster.n,
            seed: cfg.cluster.seed,
            attack: cfg.attack.clone(),
            policy: cfg.policy.clone(),
            chunk_size,
            self_check: opts.self_check,
            tol: opts.tol,
            no_eliminate: opts.no_eliminate,
            latency_us: cfg.cluster.latency_us,
            sim: opts.sim.clone(),
            adversary: controller,
        };
        let transport = ShardedTransport::build(&plan, &build, &engine)?;
        let ps = ParameterServer::new(
            transport,
            engine.clone(),
            dataset.clone(),
            init_theta,
            chunk_size,
            cfg.train.lr,
            cfg.cluster.seed,
            opts.w_star.clone(),
        )?;
        let d = engine.param_dim();
        Ok(Master {
            cfg,
            opts,
            engine,
            dataset,
            backend: Backend::Sharded(ps),
            theta: Vec::new(), // owned by the parameter server until `finish`
            chunk_size,
            agg: vec![0.0f32; d],
            used_losses: Vec::new(),
        })
    }

    /// Build a master over an explicit transport (tests and benches
    /// inject custom scenarios here; single-core only). A coordinated
    /// `cfg.adversary` is wired by [`Master::new`] — which builds the
    /// transport, the controller, and the protocol tap together — not
    /// here: an injected transport carries its own worker behaviours.
    pub fn with_transport(
        cfg: ExperimentConfig,
        opts: MasterOptions,
        engine: Arc<dyn GradientComputer>,
        dataset: Arc<dyn Dataset>,
        init_theta: Vec<f32>,
        chunk_size: usize,
        transport: Box<dyn Transport>,
    ) -> Result<Master> {
        cfg.cluster.validate()?;
        anyhow::ensure!(
            cfg.cluster.shards <= 1,
            "with_transport drives a single protocol core; use Master::new for sharded runs"
        );
        anyhow::ensure!(chunk_size > 0, "chunk_size must be positive");
        anyhow::ensure!(
            init_theta.len() == engine.param_dim(),
            "init theta dim {} != engine param dim {}",
            init_theta.len(),
            engine.param_dim()
        );
        anyhow::ensure!(
            transport.n() == cfg.cluster.n,
            "transport has {} workers, cluster config says {}",
            transport.n(),
            cfg.cluster.n
        );
        let policy = FaultCheckPolicy::new(cfg.policy.clone(), cfg.cluster.n, cfg.cluster.seed);
        let core = ProtocolCore::new(
            transport,
            policy,
            ProtocolConfig {
                f: cfg.cluster.f,
                seed: cfg.cluster.seed,
                chunk_size,
                self_check: opts.self_check,
                tol: opts.tol,
                no_eliminate: opts.no_eliminate,
                compressor: opts.compressor.clone(),
                gather: cfg.cluster.gather,
            },
        );
        let d = engine.param_dim();
        Ok(Master {
            cfg,
            opts,
            engine,
            dataset,
            backend: Backend::Single(core),
            theta: init_theta,
            chunk_size,
            agg: vec![0.0f32; d],
            used_losses: Vec::new(),
        })
    }

    /// Run the configured number of iterations.
    pub fn run(mut self) -> Result<TrainOutcome> {
        let mut metrics = TrainMetrics::default();
        let mut events = EventLog::default();
        let steps = self.cfg.train.steps;
        let sharded = matches!(self.backend, Backend::Sharded(_));
        for t in 0..steps as u64 {
            let rec = if sharded {
                match &mut self.backend {
                    Backend::Sharded(ps) => ps.run_round(t, &mut events)?,
                    Backend::Single(_) => unreachable!(),
                }
            } else {
                self.iteration(t, &mut events)?
            };
            metrics.push(rec);
        }
        let (theta, eliminated, crashed) = match self.backend {
            Backend::Single(core) => {
                let (eliminated, crashed) = core.into_outcome();
                (self.theta, eliminated, crashed)
            }
            Backend::Sharded(ps) => ps.finish(),
        };
        Ok(TrainOutcome { theta, metrics, events, eliminated, crashed })
    }

    /// One full single-core protocol iteration: delegate the phases to
    /// the core, then aggregate + update.
    fn iteration(&mut self, t: u64, events: &mut EventLog) -> Result<IterationRecord> {
        let t0 = Instant::now();
        let core = match &mut self.backend {
            Backend::Single(core) => core,
            Backend::Sharded(_) => unreachable!("sharded rounds go through the parameter server"),
        };
        let f_t = core.f_t();
        let theta = Arc::new(self.theta.clone());
        let out = core.run_round(
            t,
            &theta,
            self.dataset.as_ref(),
            self.engine.as_ref(),
            events,
        )?;

        // ---- aggregate + update ----------------------------------------
        let round = core.round();
        let nchunks = round.nchunks();
        let d = self.engine.param_dim();
        let mut oracle_faulty = false;
        self.used_losses.clear();
        for c in 0..nchunks {
            let chosen = round.chosen(c);
            self.used_losses.push(chosen.loss as f64);
            if chosen.worker != MASTER_SENTINEL
                && round.tampered_by_chunk[c].contains(&chosen.worker)
            {
                oracle_faulty = true;
            }
        }
        let needs_dense_copies = self.opts.compressor.is_some()
            || (self.opts.unaudited_filter.is_some() && !out.audited);
        if needs_dense_copies {
            let chunk_values: Vec<Vec<f32>> = (0..nchunks)
                .map(|c| match &self.opts.compressor {
                    Some(comp) => comp.decode(&round.chosen(c).grad, d),
                    None => round.chosen(c).grad.clone(),
                })
                .collect();
            match (&self.opts.unaudited_filter, out.audited) {
                // hybrid mode (§5): filter the un-audited aggregation
                (Some(filter), false) => self.agg = filter.aggregate(&chunk_values, f_t),
                _ => {
                    self.agg.fill(0.0);
                    for v in &chunk_values {
                        crate::linalg::axpy(1.0 / nchunks as f32, v, &mut self.agg);
                    }
                }
            }
        } else {
            // dense path: the same fixed-shape worker-id-slotted tree
            // sum the sharded parameter server uses, so a K = 1 run is
            // bit-identical to a sharded one (see `coordinator::shard`)
            let mut leaves: Vec<Option<&[f32]>> = vec![None; self.cfg.cluster.n];
            for c in 0..nchunks {
                leaves[round.assignment.owners[c][0]] = Some(&round.chosen(c).grad);
            }
            self.agg = crate::linalg::tree_sum(&leaves).expect("at least one chunk");
            crate::linalg::scale(1.0 / nchunks as f32, &mut self.agg);
        }
        if oracle_faulty {
            events.push(Event::OracleFaultyUpdate { iter: t });
        }
        self.engine
            .sgd_step(&mut self.theta, &self.agg, self.cfg.train.lr)?;

        // ---- metrics -----------------------------------------------------
        let round = core.round();
        let computed_points: u64 = round
            .chunks
            .iter()
            .map(|c| (c.computed_copies * self.chunk_size) as u64)
            .sum::<u64>()
            + out.master_computed_points;
        let (lambda, _) = core.policy().adaptive_state();
        Ok(IterationRecord {
            iter: t,
            gradients_used: out.gradients_used,
            gradients_computed: computed_points,
            audited: out.audited,
            faults_detected: out.faults_detected,
            identified: out.identified_now.len(),
            crashed: out.crashed_now.len(),
            loss: stats::median(&self.used_losses) as f32,
            q: core.policy().last_q,
            lambda,
            oracle_faulty_update: oracle_faulty,
            dist_to_opt: self
                .opts
                .w_star
                .as_ref()
                .map(|w| crate::linalg::dist2(&self.theta, w)),
            wall_ns: t0.elapsed().as_nanos() as u64,
            round_ns: out.round_ns,
            stragglers: out.stragglers_now.len(),
            audited_chunks: out.audited_chunks,
            suspicion: core.policy().suspicion_nonzero(),
            shard_stats: Vec::new(),
        })
    }
}
