//! Latency-aware worker profiling: timing as a Byzantine signal.
//!
//! The paper's reactive-redundancy schemes (§4-§5) decide *when* to
//! audit from loss signals alone. The completion-driven transport
//! timestamps every [`super::transport::Delivery`], which exposes a
//! second, free signal: **how long each worker takes to answer**. A
//! worker that is consistently much slower than its peers is worth
//! extra scrutiny — it may be overloaded, co-tenanted with an
//! attacker, or spending its cycles computing something other than the
//! assigned gradient (Election Coding (Sohn et al., 2019) tunes
//! per-node redundancy to node trustworthiness; Jin et al. (2019)
//! weight workers by an online suspicion statistic instead of auditing
//! uniformly).
//!
//! This module keeps one [`LatencyProfile`] per worker — an EWMA mean
//! and variance of the worker's delivery latency — and turns the
//! profiles into a per-worker *latency anomaly* in [0, 1]:
//!
//! * observations are the worker's delivery delay **relative to the
//!   wave's first arrival**, on the transport clock (virtual ns under
//!   sim, wall-clock ns under threaded), quantized to [`QUANTUM_NS`]
//!   buckets. Quantization is what keeps suspicion **bit-identical
//!   across transports at zero latency**: a zero-latency simulated
//!   wave arrives at one instant (observation exactly 0), and a
//!   threaded wave's sub-millisecond scheduling jitter quantizes to
//!   the same 0 (asserted by `tests/test_latency.rs`);
//! * a worker abandoned by a quorum/deadline wave yields a *censored*
//!   observation — it was at least as slow as the wave cutoff — with a
//!   penalty factor, so repeated abandonment alone raises anomaly;
//! * [`LatencyTracker::refresh`] compares each profile's mean against
//!   the **median of the active cluster's means** and reports an
//!   anomaly only past three gates (minimum sample count, minimum
//!   absolute excess, minimum ratio), so one scheduling hiccup or a
//!   noisy-but-healthy cluster never manufactures a suspect.
//!
//! The anomaly is fused with the audit policy's reliability score
//! ([`super::policy::FaultCheckPolicy`]) by [`fuse_suspicion`] into
//! the per-worker *suspicion score* that drives the
//! `latency-selective` audit policy and the suspicion-ranked chunk
//! re-replication ([`super::assignment::Assignment::extend_ranked`]).

use super::WorkerId;

/// Observation quantum: latencies are bucketed to whole milliseconds
/// before entering a profile. Coarse on purpose — see the module docs
/// for why this buys cross-transport determinism at zero latency.
pub const QUANTUM_NS: u64 = 1_000_000;

/// EWMA step for the profile mean/variance (≈ the last ~10 rounds
/// dominate, so a straggler that recovers sheds its anomaly quickly).
pub const EWMA_ALPHA: f64 = 0.2;

/// A worker's mean must exceed `SLOW_RATIO` × the cluster median
/// before it counts as anomalous.
pub const SLOW_RATIO: f64 = 2.0;

/// ... and exceed the median by at least this many quanta in absolute
/// terms (2 ms), so µs-scale jitter around a µs-scale median is never
/// anomalous.
pub const MIN_EXCESS_QUANTA: f64 = 2.0;

/// ... and have at least this many observations, so a single early
/// scheduling hiccup decays out of the EWMA before anomalies are
/// allowed at all.
pub const MIN_SAMPLES: u64 = 5;

/// Censoring penalty for abandoned stragglers: an abandoned worker is
/// at least as slow as the wave cutoff, so it is charged the cutoff
/// times this factor (floored at the anomaly gates, so abandonment
/// always registers).
pub const ABANDON_PENALTY: f64 = 2.0;

/// Weight of the latency anomaly in the fused suspicion score.
pub const LATENCY_WEIGHT: f64 = 0.5;

/// Weight of the reliability deficit (1 - ρ) in the fused score.
pub const RELIABILITY_WEIGHT: f64 = 0.5;

/// Minimum change in a worker's suspicion before a new
/// [`super::events::Event::SuspicionUpdated`] is emitted for it, so
/// the event log stays bounded by *changes*, not rounds × workers.
pub const SUSPICION_EVENT_DELTA: f64 = 0.05;

/// Fuse a latency anomaly and a reliability score into the per-worker
/// suspicion in [0, 1] (0 = fully trusted, 1 = maximally suspect).
pub fn fuse_suspicion(anomaly: f64, reliability: f64) -> f64 {
    (LATENCY_WEIGHT * anomaly + RELIABILITY_WEIGHT * (1.0 - reliability)).clamp(0.0, 1.0)
}

/// One worker's online latency profile (units: [`QUANTUM_NS`] quanta).
#[derive(Clone, Debug, Default)]
pub struct LatencyProfile {
    /// EWMA of the worker's quantized delivery latency.
    pub mean: f64,
    /// EWMA variance around that mean. Kept for introspection and
    /// diagnostics (how noisy is this worker's timing?) — the anomaly
    /// gates in [`LatencyTracker::refresh`] deliberately use only the
    /// mean, because a variance-scaled gate would let a *consistently*
    /// slow worker (tiny variance) look exactly as legitimate as a
    /// fast one.
    pub var: f64,
    /// Observations folded in so far.
    pub samples: u64,
}

impl LatencyProfile {
    /// Fold one quantized observation into the profile. The mean
    /// starts at 0 (a fresh worker is presumed fast), so a profile
    /// ramps toward a straggler's true latency over ~1/α rounds
    /// instead of trusting the first sample outright.
    pub fn observe(&mut self, quanta: f64) {
        let delta = quanta - self.mean;
        self.mean += EWMA_ALPHA * delta;
        self.var = (1.0 - EWMA_ALPHA) * (self.var + EWMA_ALPHA * delta * delta);
        self.samples += 1;
    }

    /// EWMA standard deviation (quanta).
    pub fn std(&self) -> f64 {
        self.var.sqrt()
    }
}

/// Per-worker latency profiles plus the cluster-relative anomaly
/// scores derived from them. Owned by the audit policy; fed by the
/// protocol core's `wait_wave` as deliveries arrive.
#[derive(Clone, Debug)]
pub struct LatencyTracker {
    profiles: Vec<LatencyProfile>,
    anomaly: Vec<f64>,
    /// Reused buffer for the cluster-median computation.
    scratch: Vec<f64>,
}

impl LatencyTracker {
    pub fn new(n_workers: usize) -> LatencyTracker {
        LatencyTracker {
            profiles: vec![LatencyProfile::default(); n_workers],
            anomaly: vec![0.0; n_workers],
            scratch: Vec::new(),
        }
    }

    pub fn profile(&self, w: WorkerId) -> &LatencyProfile {
        &self.profiles[w]
    }

    /// Record one delivery: `excess_ns` is the delay behind the wave's
    /// first arrival, on the transport clock.
    pub fn observe_ns(&mut self, w: WorkerId, excess_ns: u64) {
        self.profiles[w].observe((excess_ns / QUANTUM_NS) as f64);
    }

    /// Record an abandonment: the quorum/deadline wave stopped waiting
    /// for `w` once `cutoff_excess_ns` had passed since the wave's
    /// first arrival (the same baseline as [`LatencyTracker::observe_ns`],
    /// so the profile never mixes submit-relative and arrival-relative
    /// quantities), so the worker's excess is right-censored at the
    /// cutoff. Charge the cutoff with a penalty, floored so the signal
    /// registers even when the cutoff itself is sub-quantum.
    pub fn observe_abandoned(&mut self, w: WorkerId, cutoff_excess_ns: u64) {
        let censored = ((cutoff_excess_ns / QUANTUM_NS) as f64 * ABANDON_PENALTY)
            .max(MIN_EXCESS_QUANTA * SLOW_RATIO);
        self.profiles[w].observe(censored);
    }

    /// Recompute every active worker's anomaly against the cluster:
    /// the median of the active means (floored at one quantum) is the
    /// baseline, and a worker is anomalous only past all three gates
    /// (see the module docs). The anomaly grows linearly from 0 at
    /// `SLOW_RATIO`× the median to 1 at `2·SLOW_RATIO`× and saturates.
    pub fn refresh(&mut self, active: &[WorkerId]) {
        self.scratch.clear();
        self.scratch.extend(active.iter().map(|&w| self.profiles[w].mean));
        if self.scratch.is_empty() {
            return;
        }
        // in-place nearest-rank median (same rank `stats::median`
        // picks), so the reused buffer really is allocation-free
        self.scratch
            .sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let med = self.scratch[self.scratch.len() / 2].max(1.0);
        for &w in active {
            let p = &self.profiles[w];
            let ratio = p.mean / med;
            let excess = p.mean - med;
            self.anomaly[w] = if p.samples < MIN_SAMPLES
                || excess < MIN_EXCESS_QUANTA
                || ratio <= SLOW_RATIO
            {
                0.0
            } else {
                ((ratio - SLOW_RATIO) / SLOW_RATIO).min(1.0)
            };
        }
    }

    /// Latency anomaly in [0, 1] from the most recent refresh.
    pub fn anomaly(&self, w: WorkerId) -> f64 {
        self.anomaly[w]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn active(n: usize) -> Vec<WorkerId> {
        (0..n).collect()
    }

    #[test]
    fn profile_converges_to_a_steady_latency() {
        let mut p = LatencyProfile::default();
        for _ in 0..60 {
            p.observe(5.0);
        }
        assert!((p.mean - 5.0).abs() < 1e-3, "mean {}", p.mean);
        assert!(p.std() < 1e-1, "steady input should have tiny spread");
        assert_eq!(p.samples, 60);
    }

    #[test]
    fn zero_latency_cluster_has_zero_anomaly() {
        let mut t = LatencyTracker::new(4);
        for _ in 0..10 {
            for w in 0..4 {
                t.observe_ns(w, 0);
            }
            t.refresh(&active(4));
        }
        for w in 0..4 {
            assert_eq!(t.anomaly(w), 0.0, "worker {w}");
            assert_eq!(t.profile(w).mean, 0.0);
        }
    }

    #[test]
    fn sub_quantum_jitter_is_invisible() {
        // threaded-transport scheduling noise: hundreds of µs, always
        // below the 1 ms quantum — must quantize to exactly 0
        let mut t = LatencyTracker::new(3);
        for round in 0..10u64 {
            t.observe_ns(0, 0);
            t.observe_ns(1, 300_000 + round * 10_000);
            t.observe_ns(2, 900_000);
            t.refresh(&active(3));
        }
        for w in 0..3 {
            assert_eq!(t.profile(w).mean, 0.0, "worker {w} saw sub-quantum noise");
            assert_eq!(t.anomaly(w), 0.0);
        }
    }

    #[test]
    fn persistent_straggler_saturates_anomaly() {
        // one worker 5 ms behind a cluster that answers together
        let mut t = LatencyTracker::new(4);
        for round in 0..12u64 {
            for w in 0..3 {
                t.observe_ns(w, 0);
            }
            t.observe_ns(3, 4_900_000);
            t.refresh(&active(4));
            if round + 1 < MIN_SAMPLES {
                assert_eq!(t.anomaly(3), 0.0, "anomaly before {MIN_SAMPLES} samples");
            }
        }
        assert!(t.anomaly(3) > 0.5, "anomaly {}", t.anomaly(3));
        assert_eq!(t.anomaly(0), 0.0);
        // EWMA mean approaches the true 4-quantum excess
        assert!(t.profile(3).mean > 3.0);
    }

    #[test]
    fn one_early_hiccup_decays_before_anomalies_are_allowed() {
        // a single 6 ms scheduling stall in round 0 must never flag
        // the worker: by the MIN_SAMPLES-th observation the EWMA has
        // decayed below the excess gate
        let mut t = LatencyTracker::new(4);
        t.observe_ns(0, 6_000_000);
        for w in 1..4 {
            t.observe_ns(w, 0);
        }
        t.refresh(&active(4));
        assert_eq!(t.anomaly(0), 0.0, "gated by MIN_SAMPLES");
        for _ in 0..MIN_SAMPLES {
            for w in 0..4 {
                t.observe_ns(w, 0);
            }
            t.refresh(&active(4));
        }
        assert_eq!(t.anomaly(0), 0.0, "hiccup decayed: mean {}", t.profile(0).mean);
    }

    #[test]
    fn recovered_straggler_sheds_its_anomaly() {
        // time-varying straggler: slow for 10 rounds, then healthy —
        // the anomaly must decay back to 0
        let mut t = LatencyTracker::new(4);
        for _ in 0..10 {
            for w in 0..3 {
                t.observe_ns(w, 0);
            }
            t.observe_ns(3, 8_000_000);
            t.refresh(&active(4));
        }
        assert!(t.anomaly(3) > 0.5);
        for _ in 0..20 {
            for w in 0..4 {
                t.observe_ns(w, 0);
            }
            t.refresh(&active(4));
        }
        assert_eq!(t.anomaly(3), 0.0, "mean {}", t.profile(3).mean);
    }

    #[test]
    fn abandonment_alone_raises_anomaly() {
        // a quorum wave that keeps abandoning one worker never sees
        // its latency — the censored observations must still flag it
        let mut t = LatencyTracker::new(4);
        for _ in 0..8 {
            for w in 0..3 {
                t.observe_ns(w, 0);
            }
            t.observe_abandoned(3, 200_000); // sub-quantum cutoff
            t.refresh(&active(4));
        }
        assert!(t.anomaly(3) > 0.0, "anomaly {}", t.anomaly(3));
    }

    #[test]
    fn fuse_clamps_and_weighs_both_signals() {
        assert_eq!(fuse_suspicion(0.0, 1.0), 0.0);
        assert_eq!(fuse_suspicion(1.0, 0.0), 1.0);
        let lat_only = fuse_suspicion(1.0, 1.0);
        assert!((lat_only - LATENCY_WEIGHT).abs() < 1e-12);
        let rel_only = fuse_suspicion(0.0, 0.5);
        assert!((rel_only - RELIABILITY_WEIGHT * 0.5).abs() < 1e-12);
    }
}
