//! Compressed-gradient symbols (§2.1 / §5 generalization) with a real
//! byte-packed wire format.
//!
//! The paper notes both schemes extend unchanged to workers that send
//! *compressed* gradients [1, 2, 19, 20]: detection compares compressed
//! symbols (honest compressors are deterministic, so replicas are still
//! bit-identical), and the master aggregates after decompression.
//!
//! Two classic compressors are provided:
//! * [`TopK`] — magnitude top-k sparsification (Aji & Heafield, 2017),
//!   packed as (u32 index, f32 value) little-endian pairs;
//! * [`SignSgd`] — 1-bit sign compression with a per-symbol scale
//!   (Bernstein et al., 2018), packed 32 signs per u32 word after a
//!   4-byte scale.
//!
//! A symbol travels as `Vec<u8>` wire bytes. The *exact decode path*
//! ([`Compressor::unpack`]) is deterministic, so every honest replica
//! of a chunk produces bit-identical wire bytes and detection/voting
//! compare the packed representation directly. The optional *election
//! decode path* ([`Compressor::unpack_election`], cf. Election Coding,
//! arXiv 1910.06093) instead combines all replica wires of a chunk by
//! per-symbol majority — a statistical-robustness decode measured in
//! E13; it is never used for fault detection.

use std::sync::Arc;

use crate::Result;

/// Parse a `--compress` CLI spec: `dense`, `sign`, or `topk:K`.
pub fn parse(spec: &str) -> Result<Arc<dyn Compressor>> {
    match spec {
        "dense" => Ok(Arc::new(Dense)),
        "sign" | "signsgd" => Ok(Arc::new(SignSgd)),
        _ => {
            let k = spec
                .strip_prefix("topk:")
                .and_then(|k| k.parse::<usize>().ok())
                .filter(|&k| k > 0)
                .ok_or_else(|| {
                    anyhow::anyhow!("bad --compress '{spec}': expected dense | sign | topk:K")
                })?;
            Ok(Arc::new(TopK { k }))
        }
    }
}

/// A gradient compressor: deterministic byte packing + exact decode.
///
/// Decoding is split into two surfaces. [`Compressor::try_unpack`] is
/// the **validating** path: wire bytes that arrive from a socket are
/// attacker-controlled, so every format checks length, index, and
/// value invariants and returns a decode error instead of panicking.
/// [`Compressor::unpack`] is the trusted in-process shorthand (the
/// bytes were packed moments ago by the same binary) and simply
/// unwraps the validating decode.
pub trait Compressor: Send + Sync {
    fn name(&self) -> &'static str;

    /// Parseable spec string (`compress::parse(spec)` reconstructs an
    /// equivalent compressor — how the net transport tells a remote
    /// worker which compressor to build).
    fn spec(&self) -> String;

    /// Pack a dense gradient into wire bytes.
    fn pack(&self, grad: &[f32]) -> Vec<u8>;

    /// Validating decode of possibly-malformed wire bytes into a dense
    /// gradient of dimension `d`. Truncated, oversized, or
    /// garbage-valued buffers yield `Err`, never a panic.
    fn try_unpack(&self, wire: &[u8], d: usize) -> Result<Vec<f32>>;

    /// Exact deterministic decode back to a dense gradient of
    /// dimension `d` (the representative the master aggregates with).
    /// Trusted-path shorthand: panics on malformed bytes — socket
    /// receivers must use [`Compressor::try_unpack`].
    fn unpack(&self, wire: &[u8], d: usize) -> Vec<f32> {
        self.try_unpack(wire, d)
            .unwrap_or_else(|e| panic!("{} decode failed on trusted wire: {e:#}", self.name()))
    }

    /// Wire size in bytes for a d-dimensional gradient.
    fn wire_bytes(&self, d: usize) -> usize;

    /// Compression ratio: dense bytes (4 per f32) / packed wire bytes.
    fn ratio(&self, d: usize) -> f64 {
        (4 * d) as f64 / self.wire_bytes(d).max(1) as f64
    }

    /// Validating election decode over the replica wires of one chunk
    /// (majority per symbol where the format supports it). The default
    /// is the exact decode of the first replica, which every format
    /// supports; an empty replica set is an error.
    fn try_unpack_election(&self, wires: &[&[u8]], d: usize) -> Result<Vec<f32>> {
        let first = wires
            .first()
            .ok_or_else(|| anyhow::anyhow!("election decode over zero replica wires"))?;
        self.try_unpack(first, d)
    }

    /// Election decode (trusted-path shorthand of
    /// [`Compressor::try_unpack_election`]).
    fn unpack_election(&self, wires: &[&[u8]], d: usize) -> Vec<f32> {
        self.try_unpack_election(wires, d)
            .unwrap_or_else(|e| panic!("{} election decode failed: {e:#}", self.name()))
    }
}

fn read_f32_le(b: &[u8]) -> f32 {
    f32::from_le_bytes([b[0], b[1], b[2], b[3]])
}

fn read_u32_le(b: &[u8]) -> u32 {
    u32::from_le_bytes([b[0], b[1], b[2], b[3]])
}

/// Identity compressor: 4·d little-endian bytes (useful for measuring
/// the wire accounting itself; runs without any compressor skip the
/// packing entirely).
pub struct Dense;

impl Compressor for Dense {
    fn name(&self) -> &'static str {
        "dense"
    }

    fn spec(&self) -> String {
        "dense".into()
    }

    fn pack(&self, grad: &[f32]) -> Vec<u8> {
        let mut wire = Vec::with_capacity(4 * grad.len());
        for v in grad {
            wire.extend_from_slice(&v.to_le_bytes());
        }
        wire
    }

    fn try_unpack(&self, wire: &[u8], d: usize) -> Result<Vec<f32>> {
        if wire.len() != 4 * d {
            anyhow::bail!("dense wire: got {} bytes, expected {}", wire.len(), 4 * d);
        }
        Ok(wire.chunks_exact(4).map(read_f32_le).collect())
    }

    fn wire_bytes(&self, d: usize) -> usize {
        4 * d
    }
}

/// Magnitude top-k: wire = k × (u32 index, f32 value) little-endian
/// pairs in ascending index order. Deterministic tie-breaking by index
/// so honest replicas agree bit-for-bit.
pub struct TopK {
    pub k: usize,
}

impl Compressor for TopK {
    fn name(&self) -> &'static str {
        "topk"
    }

    fn spec(&self) -> String {
        format!("topk:{}", self.k)
    }

    fn pack(&self, grad: &[f32]) -> Vec<u8> {
        let k = self.k.min(grad.len());
        let mut idx: Vec<usize> = (0..grad.len()).collect();
        // sort by |value| desc, index asc for determinism
        idx.sort_by(|&a, &b| {
            grad[b]
                .abs()
                .partial_cmp(&grad[a].abs())
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let mut chosen: Vec<usize> = idx[..k].to_vec();
        chosen.sort_unstable(); // canonical order
        let mut wire = Vec::with_capacity(8 * k);
        for i in chosen {
            wire.extend_from_slice(&(i as u32).to_le_bytes());
            wire.extend_from_slice(&grad[i].to_le_bytes());
        }
        wire
    }

    fn try_unpack(&self, wire: &[u8], d: usize) -> Result<Vec<f32>> {
        // pack() always emits exactly k.min(d) pairs, so any other
        // length (including a truncation at a pair boundary) is forged
        if wire.len() != self.wire_bytes(d) {
            anyhow::bail!(
                "topk wire: got {} bytes, expected {} (k={}, d={d})",
                wire.len(),
                self.wire_bytes(d),
                self.k
            );
        }
        let mut out = vec![0.0f32; d];
        let mut prev: Option<usize> = None;
        for pair in wire.chunks_exact(8) {
            let i = read_u32_le(&pair[0..4]) as usize;
            if i >= d {
                anyhow::bail!("topk wire: index {i} out of range for d={d}");
            }
            // pack() emits canonical ascending order; anything else is forged
            if prev.is_some_and(|p| p >= i) {
                anyhow::bail!("topk wire: indices not strictly ascending at {i}");
            }
            prev = Some(i);
            out[i] = read_f32_le(&pair[4..8]);
        }
        Ok(out)
    }

    fn wire_bytes(&self, d: usize) -> usize {
        8 * self.k.min(d)
    }
}

/// signSGD with norm scale: wire = 4-byte scale (mean |g|, little
/// endian) followed by ceil(d/32) little-endian u32 words packing one
/// sign bit per coordinate (bit set ⟺ value ≥ 0). 4 + 4·ceil(d/32)
/// bytes against 4·d dense — ~31× at d = 1024.
pub struct SignSgd;

impl SignSgd {
    fn scale_of(grad: &[f32]) -> f32 {
        grad.iter().map(|v| v.abs()).sum::<f32>() / grad.len().max(1) as f32
    }

    fn sign_bit(wire: &[u8], i: usize) -> bool {
        let word = read_u32_le(&wire[4 + 4 * (i / 32)..8 + 4 * (i / 32)]);
        word & (1 << (i % 32)) != 0
    }
}

impl Compressor for SignSgd {
    fn name(&self) -> &'static str {
        "signsgd"
    }

    fn spec(&self) -> String {
        "sign".into()
    }

    fn pack(&self, grad: &[f32]) -> Vec<u8> {
        let words = grad.len().div_ceil(32);
        let mut wire = Vec::with_capacity(4 + 4 * words);
        wire.extend_from_slice(&Self::scale_of(grad).to_le_bytes());
        for block in grad.chunks(32) {
            let mut w = 0u32;
            for (b, v) in block.iter().enumerate() {
                if *v >= 0.0 {
                    w |= 1 << b;
                }
            }
            wire.extend_from_slice(&w.to_le_bytes());
        }
        wire
    }

    fn try_unpack(&self, wire: &[u8], d: usize) -> Result<Vec<f32>> {
        if wire.len() != self.wire_bytes(d) {
            anyhow::bail!(
                "signsgd wire: got {} bytes, expected {}",
                wire.len(),
                self.wire_bytes(d)
            );
        }
        let scale = read_f32_le(&wire[0..4]);
        if !scale.is_finite() {
            anyhow::bail!("signsgd wire: non-finite scale {scale}");
        }
        Ok((0..d)
            .map(|i| if Self::sign_bit(wire, i) { scale } else { -scale })
            .collect())
    }

    fn wire_bytes(&self, d: usize) -> usize {
        4 + 4 * d.div_ceil(32)
    }

    /// Election decode: per-coordinate majority over the replica sign
    /// bits (ties, only possible with an even replica count, fall to
    /// negative) scaled by the median replica scale. With an honest
    /// majority of replicas this recovers the honest signs even when a
    /// minority lies — without any exact comparison.
    fn try_unpack_election(&self, wires: &[&[u8]], d: usize) -> Result<Vec<f32>> {
        if wires.is_empty() {
            anyhow::bail!("election decode over zero replica wires");
        }
        let expect = self.wire_bytes(d);
        for w in wires {
            if w.len() != expect {
                anyhow::bail!("signsgd election wire: got {} bytes, expected {expect}", w.len());
            }
        }
        let mut scales: Vec<f32> = wires.iter().map(|w| read_f32_le(&w[0..4])).collect();
        scales.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let scale = scales[scales.len() / 2];
        if !scale.is_finite() {
            anyhow::bail!("signsgd election wire: non-finite median scale {scale}");
        }
        Ok((0..d)
            .map(|i| {
                let pos = wires.iter().filter(|w| Self::sign_bit(w, i)).count();
                if 2 * pos > wires.len() {
                    scale
                } else {
                    -scale
                }
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn dense_roundtrip_exact() {
        let mut rng = Pcg64::seeded(1);
        let g = rng.gauss_vec(64);
        let c = Dense;
        assert_eq!(c.unpack(&c.pack(&g), 64), g);
        assert_eq!(c.wire_bytes(64), 256);
        assert_eq!(c.ratio(64), 1.0);
    }

    #[test]
    fn topk_keeps_largest_coordinates() {
        let g = vec![0.1f32, -5.0, 0.2, 3.0, -0.05, 1.0];
        let c = TopK { k: 3 };
        let back = c.unpack(&c.pack(&g), 6);
        assert_eq!(back, vec![0.0, -5.0, 0.0, 3.0, 0.0, 1.0]);
        assert_eq!(c.wire_bytes(6), 24);
        assert!((c.ratio(1000) - 4000.0 / 24.0).abs() < 1e-9);
    }

    #[test]
    fn topk_is_deterministic_under_ties() {
        let g = vec![1.0f32, -1.0, 1.0, -1.0];
        let c = TopK { k: 2 };
        assert_eq!(c.pack(&g), c.pack(&g));
        // ties broken by lowest index
        let back = c.unpack(&c.pack(&g), 4);
        assert_eq!(back, vec![1.0, -1.0, 0.0, 0.0]);
    }

    #[test]
    fn signsgd_preserves_signs_and_mean_magnitude() {
        let g = vec![2.0f32, -4.0, 6.0, -8.0];
        let c = SignSgd;
        let wire = c.pack(&g);
        assert_eq!(wire.len(), 8); // scale word + one sign word
        assert_eq!(c.unpack(&wire, 4), vec![5.0, -5.0, 5.0, -5.0]); // scale = mean |g| = 5
        // honest accounting: 4 + 4*ceil(d/32) bytes, ~31x at d = 1024
        assert_eq!(c.wire_bytes(1024), 4 + 128);
        assert!(c.ratio(1024) > 16.0, "ratio {}", c.ratio(1024));
    }

    #[test]
    fn signsgd_packs_across_word_boundaries() {
        // d = 37 spans two sign words; every sign must survive
        let mut rng = Pcg64::seeded(7);
        let g = rng.gauss_vec(37);
        let c = SignSgd;
        assert_eq!(c.wire_bytes(37), 4 + 8);
        let back = c.unpack(&c.pack(&g), 37);
        for (v, b) in g.iter().zip(&back) {
            assert_eq!(*v >= 0.0, *b >= 0.0, "sign lost at {v} -> {b}");
        }
    }

    #[test]
    fn signsgd_election_majority_overrides_minority_liar() {
        let g = vec![2.0f32, -4.0, 6.0, -8.0];
        let c = SignSgd;
        let honest = c.pack(&g);
        let mut flipped = g.clone();
        for v in flipped.iter_mut() {
            *v = -*v;
        }
        let lie = c.pack(&flipped);
        let wires: Vec<&[u8]> = vec![&honest, &lie, &honest];
        let elected = c.unpack_election(&wires, 4);
        assert_eq!(elected, c.unpack(&honest, 4), "2-of-3 honest majority must win");
        // single wire: election decode degenerates to the exact decode
        assert_eq!(c.unpack_election(&[&honest], 4), c.unpack(&honest, 4));
    }

    #[test]
    fn honest_replicas_agree_bitwise_for_all_compressors() {
        // the property detection relies on: same gradient -> same wire
        let mut rng = Pcg64::seeded(2);
        let g = rng.gauss_vec(128);
        let comps: Vec<Box<dyn Compressor>> =
            vec![Box::new(Dense), Box::new(TopK { k: 16 }), Box::new(SignSgd)];
        for c in comps {
            assert_eq!(c.pack(&g), c.pack(&g), "{} nondeterministic", c.name());
        }
    }

    #[test]
    fn parse_cli_specs() {
        assert_eq!(parse("dense").unwrap().name(), "dense");
        assert_eq!(parse("sign").unwrap().name(), "signsgd");
        let c = parse("topk:16").unwrap();
        assert_eq!(c.name(), "topk");
        assert_eq!(c.wire_bytes(1024), 8 * 16);
        assert!(parse("topk:0").is_err());
        assert!(parse("gzip").is_err());
    }

    #[test]
    fn spec_roundtrips_through_parse() {
        for c in [&Dense as &dyn Compressor, &TopK { k: 16 }, &SignSgd] {
            let back = parse(&c.spec()).unwrap();
            assert_eq!(back.name(), c.name());
            assert_eq!(back.wire_bytes(1024), c.wire_bytes(1024));
        }
    }

    #[test]
    fn truncated_wires_error_instead_of_panicking() {
        let mut rng = Pcg64::seeded(11);
        let g = rng.gauss_vec(64);
        for c in [&Dense as &dyn Compressor, &TopK { k: 8 }, &SignSgd] {
            let wire = c.pack(&g);
            for cut in [0, 1, wire.len() / 2, wire.len() - 1] {
                assert!(
                    c.try_unpack(&wire[..cut], 64).is_err(),
                    "{} accepted a {cut}-byte truncation of {} bytes",
                    c.name(),
                    wire.len()
                );
            }
            assert_eq!(c.try_unpack(&wire, 64).unwrap(), c.unpack(&wire, 64));
        }
    }

    #[test]
    fn oversized_wires_are_rejected() {
        let mut rng = Pcg64::seeded(12);
        let g = rng.gauss_vec(64);
        for c in [&Dense as &dyn Compressor, &TopK { k: 8 }, &SignSgd] {
            let mut wire = c.pack(&g);
            wire.extend_from_slice(&[0u8; 8]);
            assert!(c.try_unpack(&wire, 64).is_err(), "{} accepted padding", c.name());
        }
    }

    #[test]
    fn topk_rejects_forged_indices() {
        fn pairs(ps: &[(u32, f32)]) -> Vec<u8> {
            let mut wire = Vec::new();
            for (i, v) in ps {
                wire.extend_from_slice(&i.to_le_bytes());
                wire.extend_from_slice(&v.to_le_bytes());
            }
            wire
        }
        // out-of-range index (correct length for k=1, d=8)
        assert!(TopK { k: 1 }.try_unpack(&pairs(&[(99, 1.0)]), 8).is_err());
        // duplicate index (not strictly ascending)
        let c = TopK { k: 2 };
        assert!(c.try_unpack(&pairs(&[(3, 1.0), (3, 1.0)]), 8).is_err());
        // descending order
        assert!(c.try_unpack(&pairs(&[(5, 1.0), (2, 1.0)]), 8).is_err());
        // canonical ascending pairs of the exact length decode fine
        assert_eq!(
            c.try_unpack(&pairs(&[(2, 1.0), (5, -1.0)]), 8).unwrap(),
            vec![0.0, 0.0, 1.0, 0.0, 0.0, -1.0, 0.0, 0.0]
        );
    }

    #[test]
    fn signsgd_rejects_garbage_scale() {
        let c = SignSgd;
        let g = vec![1.0f32; 40];
        let mut wire = c.pack(&g);
        wire[0..4].copy_from_slice(&f32::NAN.to_le_bytes());
        assert!(c.try_unpack(&wire, 40).is_err(), "NaN scale accepted");
        wire[0..4].copy_from_slice(&f32::INFINITY.to_le_bytes());
        assert!(c.try_unpack(&wire, 40).is_err(), "inf scale accepted");
        // bit-garbage in the sign words decodes (any bit pattern is a
        // legal sign vector) — the point is it must not panic
        let mut garbage = c.pack(&g);
        for b in garbage[4..].iter_mut() {
            *b = 0xA5;
        }
        assert_eq!(c.try_unpack(&garbage, 40).unwrap().len(), 40);
    }

    #[test]
    fn election_decode_rejects_malformed_replica_sets() {
        let c = SignSgd;
        let g = vec![1.0f32, -2.0, 3.0];
        let ok = c.pack(&g);
        let short = &ok[..ok.len() - 1];
        assert!(c.try_unpack_election(&[], 3).is_err(), "empty replica set accepted");
        assert!(c.try_unpack_election(&[&ok, short], 3).is_err(), "short replica accepted");
        let d = Dense;
        assert!(d.try_unpack_election(&[], 3).is_err());
    }

    #[test]
    fn tampered_wire_differs() {
        let mut rng = Pcg64::seeded(3);
        let g = rng.gauss_vec(128);
        for c in [&TopK { k: 16 } as &dyn Compressor, &SignSgd] {
            // not guaranteed for every perturbation (compression is lossy),
            // but a sign-visible, magnitude-visible change must show
            let w1 = c.pack(&g);
            let mut g3 = g.clone();
            for v in g3.iter_mut() {
                *v = -*v; // sign flip attack
            }
            let w3 = c.pack(&g3);
            assert_ne!(w1, w3, "{} hides a sign-flip", c.name());
        }
    }
}
