//! Compressed-gradient symbols (§2.1 / §5 generalization).
//!
//! The paper notes both schemes extend unchanged to workers that send
//! *compressed* gradients [1, 2, 19, 20]: detection compares compressed
//! symbols (honest compressors are deterministic, so replicas are still
//! bit-identical), and the master aggregates after decompression.
//!
//! Two classic compressors are provided:
//! * [`TopK`] — magnitude top-k sparsification (Aji & Heafield, 2017);
//! * [`SignSgd`] — 1-bit sign compression with a per-symbol scale
//!   (Bernstein et al., 2018).
//!
//! A compressed symbol is (indices?, values) packed into a flat f32
//! vector so the whole symbol pipeline (hashing, comparison, majority
//! vote) works on it unchanged.

/// A gradient compressor: deterministic encode + linear-enough decode.
pub trait Compressor: Send + Sync {
    fn name(&self) -> &'static str;

    /// Encode a dense gradient into the compressed wire form.
    fn encode(&self, grad: &[f32]) -> Vec<f32>;

    /// Decode back to a dense gradient of dimension `d`.
    fn decode(&self, wire: &[f32], d: usize) -> Vec<f32>;

    /// Wire size in f32 words for a d-dimensional gradient.
    fn wire_len(&self, d: usize) -> usize;

    /// Compression ratio (dense words / wire words).
    fn ratio(&self, d: usize) -> f64 {
        d as f64 / self.wire_len(d) as f64
    }
}

/// Identity compressor (the default dense protocol).
pub struct Dense;

impl Compressor for Dense {
    fn name(&self) -> &'static str {
        "dense"
    }

    fn encode(&self, grad: &[f32]) -> Vec<f32> {
        grad.to_vec()
    }

    fn decode(&self, wire: &[f32], d: usize) -> Vec<f32> {
        debug_assert_eq!(wire.len(), d);
        wire.to_vec()
    }

    fn wire_len(&self, d: usize) -> usize {
        d
    }
}

/// Magnitude top-k: wire = [idx_0, val_0, ..., idx_{k-1}, val_{k-1}],
/// indices stored as f32 (exact for d < 2^24). Deterministic
/// tie-breaking by index so honest replicas agree bit-for-bit.
pub struct TopK {
    pub k: usize,
}

impl Compressor for TopK {
    fn name(&self) -> &'static str {
        "topk"
    }

    fn encode(&self, grad: &[f32]) -> Vec<f32> {
        let k = self.k.min(grad.len());
        let mut idx: Vec<usize> = (0..grad.len()).collect();
        // sort by |value| desc, index asc for determinism
        idx.sort_by(|&a, &b| {
            grad[b]
                .abs()
                .partial_cmp(&grad[a].abs())
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let mut chosen: Vec<usize> = idx[..k].to_vec();
        chosen.sort_unstable(); // canonical order
        let mut wire = Vec::with_capacity(2 * k);
        for i in chosen {
            wire.push(i as f32);
            wire.push(grad[i]);
        }
        wire
    }

    fn decode(&self, wire: &[f32], d: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; d];
        for pair in wire.chunks_exact(2) {
            let i = pair[0] as usize;
            if i < d {
                out[i] = pair[1];
            }
        }
        out
    }

    fn wire_len(&self, d: usize) -> usize {
        2 * self.k.min(d)
    }
}

/// signSGD with norm scale: wire = [scale, sign bits packed 1/f32].
/// (Packing stays f32-per-sign for pipeline uniformity; the *counted*
/// communication uses 1 bit/coord + 1 word, reported by `wire_bits`.)
pub struct SignSgd;

impl SignSgd {
    /// True wire cost in bits (what E11 reports).
    pub fn wire_bits(d: usize) -> usize {
        32 + d
    }
}

impl Compressor for SignSgd {
    fn name(&self) -> &'static str {
        "signsgd"
    }

    fn encode(&self, grad: &[f32]) -> Vec<f32> {
        let scale = grad.iter().map(|v| v.abs()).sum::<f32>() / grad.len().max(1) as f32;
        let mut wire = Vec::with_capacity(grad.len() + 1);
        wire.push(scale);
        wire.extend(grad.iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }));
        wire
    }

    fn decode(&self, wire: &[f32], d: usize) -> Vec<f32> {
        debug_assert_eq!(wire.len(), d + 1);
        let scale = wire[0];
        wire[1..].iter().map(|&s| s * scale).collect()
    }

    fn wire_len(&self, d: usize) -> usize {
        d + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn dense_roundtrip_exact() {
        let mut rng = Pcg64::seeded(1);
        let g = rng.gauss_vec(64);
        let c = Dense;
        assert_eq!(c.decode(&c.encode(&g), 64), g);
        assert_eq!(c.ratio(64), 1.0);
    }

    #[test]
    fn topk_keeps_largest_coordinates() {
        let g = vec![0.1f32, -5.0, 0.2, 3.0, -0.05, 1.0];
        let c = TopK { k: 3 };
        let back = c.decode(&c.encode(&g), 6);
        assert_eq!(back, vec![0.0, -5.0, 0.0, 3.0, 0.0, 1.0]);
        assert_eq!(c.wire_len(6), 6);
        assert!((c.ratio(1000) - 1000.0 / 6.0).abs() < 1e-9);
    }

    #[test]
    fn topk_is_deterministic_under_ties() {
        let g = vec![1.0f32, -1.0, 1.0, -1.0];
        let c = TopK { k: 2 };
        assert_eq!(c.encode(&g), c.encode(&g));
        // ties broken by lowest index
        let back = c.decode(&c.encode(&g), 4);
        assert_eq!(back, vec![1.0, -1.0, 0.0, 0.0]);
    }

    #[test]
    fn signsgd_preserves_signs_and_mean_magnitude() {
        let g = vec![2.0f32, -4.0, 6.0, -8.0];
        let c = SignSgd;
        let back = c.decode(&c.encode(&g), 4);
        assert_eq!(back, vec![5.0, -5.0, 5.0, -5.0]); // scale = mean |g| = 5
        assert_eq!(SignSgd::wire_bits(1024), 32 + 1024);
    }

    #[test]
    fn honest_replicas_agree_bitwise_for_all_compressors() {
        // the property detection relies on: same gradient -> same wire
        let mut rng = Pcg64::seeded(2);
        let g = rng.gauss_vec(128);
        let comps: Vec<Box<dyn Compressor>> =
            vec![Box::new(Dense), Box::new(TopK { k: 16 }), Box::new(SignSgd)];
        for c in comps {
            assert_eq!(c.encode(&g), c.encode(&g), "{} nondeterministic", c.name());
        }
    }

    #[test]
    fn tampered_wire_differs() {
        let mut rng = Pcg64::seeded(3);
        let g = rng.gauss_vec(128);
        let mut g2 = g.clone();
        g2[7] += 0.5;
        for c in [&TopK { k: 16 } as &dyn Compressor, &SignSgd] {
            // not guaranteed for every perturbation (compression is lossy),
            // but a sign-visible, magnitude-visible change must show
            let w1 = c.encode(&g);
            let mut g3 = g.clone();
            for v in g3.iter_mut() {
                *v = -*v; // sign flip attack
            }
            let w3 = c.encode(&g3);
            assert_ne!(w1, w3, "{} hides a sign-flip", c.name());
        }
    }
}
