//! Protocol core: one training iteration as explicit phase transitions
//! over a [`RoundState`], independent of both the transport (threaded
//! or simulated, see [`super::transport`]) and the policy/SGD glue
//! (see [`super::master`]).
//!
//! ## Phases
//!
//! [`Phase`] names the paper's three wire phases:
//!
//! * [`Phase::Proactive`] — sample m points, assign chunks with
//!   replication r (f_t+1 deterministic / 1 otherwise), submit the
//!   wave, collect deliveries, ingest. Chunks orphaned by crashed
//!   workers are reassigned until every chunk has at least one copy.
//! * [`Phase::Detection`] — if this iteration is audited, top every
//!   audited chunk up to f_t+1 distinct copies (self-check mode
//!   instead recomputes on the master) and compare copies.
//! * [`Phase::Reactive`] — for chunks whose copies disagree, top up to
//!   2f_t+1 distinct owners, majority-vote the true value, identify
//!   the liars, eliminate them (κ_t += …, f_t shrinks).
//!
//! ## Completion-driven waves
//!
//! The core is no longer phase-blocked on the slowest worker: each
//! phase submits a *wave* of task bundles and then reacts to
//! [`super::transport::Delivery`]s as they arrive ([`ProtocolCore`]'s
//! `wait_wave`). How long the **initial proactive wave** keeps waiting
//! is the cluster's [`GatherPolicy`]:
//!
//! * [`GatherPolicy::All`] — wait for every worker (the paper's
//!   synchronous model; bit-identical to the pre-quorum protocol);
//! * [`GatherPolicy::Quorum`] — stop once k workers responded;
//! * [`GatherPolicy::Deadline`] — stop once the deadline passed (but
//!   never empty-handed).
//!
//! Workers the wave stops waiting for are *abandoned for the round*:
//! retired from the round's assignment pool so chunks they alone own
//! are reassigned exactly like a crashed worker's (exactness under
//! 2f < n is untouched), while the workers themselves rejoin at the
//! next round. Their late deliveries — and any delivery from a
//! previous phase — are drained and discarded, never ingested, so no
//! symbol leaks across phases. Detection and reactive waves always
//! wait for every requested copy, and crash-stops arrive in-band as
//! [`super::transport::Delivery::Failed`].
//!
//! ## Pipelined rounds
//!
//! A round is split across [`ProtocolCore::begin_round`] /
//! [`ProtocolCore::collect_proactive`] / [`ProtocolCore::finish_round`]
//! (with [`ProtocolCore::complete_round`] = collect + finish), and the
//! core holds a bounded ring of [`PendingRound`]s (capacity
//! [`ProtocolConfig::pipeline`]): iteration t+1's proactive wave can be
//! submitted — on a *provisional* θ — while iteration t's detection and
//! reactive waves are still in flight. Every `Transport::submit` gets a
//! fresh monotone *wave id*, echoed in each response, and `wait_wave`
//! routes deliveries by it: deliveries of another still-live wave are
//! buffered in a mailbox; deliveries of a dead wave (an abandoned
//! straggler's, or a provisional wave invalidated by
//! [`ProtocolCore::reissue_round`]) are dropped, never ingested. θ
//! application stays strictly ordered: the driver finishes round t,
//! and only if t changed θ (a liar was caught, or the audit corrected
//! the provisional aggregate) re-issues round t+1's wave on the exact
//! θ; fault-free rounds overlap fully. At `pipeline = 1` the ring
//! holds one round and behaviour is bit-identical to the unpipelined
//! core. A caller driving many cores (the sharded parameter server)
//! uses the same split to put every shard's wave in flight before
//! waiting on any of them.
//!
//! Every symbol, regardless of phase, enters the round through the
//! single ingest path [`RoundState::ingest`] — the three copy-pasted
//! ingest loops of the pre-refactor master collapse here.
//!
//! ## Timing as a Byzantine signal
//!
//! The **initial proactive wave** also feeds every fresh delivery's
//! arrival timestamp (and every abandonment, as a censored sample)
//! into the policy's per-worker latency profiles ([`super::latency`];
//! top-up waves are excluded — they are small and often
//! single-target, so their zero-excess samples would dilute the
//! signal); once per round the fused suspicion scores are refreshed
//! and material changes surface as
//! [`super::events::Event::SuspicionUpdated`]. The `latency-selective`
//! policy audits from those scores, and its audit re-replication
//! places copies on the least-suspect workers first
//! ([`super::assignment::Assignment::extend_ranked`]); all other
//! policies only record the signal.
//!
//! Exactness (Def. 1): every audited iteration ends with provably
//! correct chunk values; unaudited iterations may use tampered
//! gradients, but each persistent Byzantine worker is identified
//! almost surely ((1-qp)^t -> 0) and eliminated, after which the run
//! is attack-free and converges exactly.

use std::sync::Arc;

use super::assignment::{sample_points, Assignment};
use super::codes::{check_copies, symbols_equal, CheckOutcome, SymbolCopy};
use super::compress::Compressor;
use super::events::{Event, EventLog};
use super::identify::majority_vote;
use super::policy::{AuditDecision, FaultCheckPolicy};
use super::transport::{Delivery, NetStats, TaskBundle, Transport};
use super::worker::{Response, Symbol};
use super::{ChunkId, WorkerId, MASTER_SENTINEL};
use crate::config::GatherPolicy;
use crate::data::Dataset;
use crate::grad::GradientComputer;
use crate::trace::TraceHandle;
use crate::util::rng::Pcg64;
use crate::util::stats;
use crate::Result;

/// Stream id of the data-point sampling RNG. The sharded
/// [`super::shard::ParameterServer`] samples from the *same* stream to
/// reproduce the single-master data assignment exactly — both
/// constructors must reference this constant, or the K = 1 vs K > 1
/// bit-identity contract silently breaks.
pub const SAMPLE_STREAM: u64 = 0xaa57e2;

/// Consecutive-abandonment streak after which a worker counts as a
/// *chronic* straggler and the quorum gather stops budgeting a
/// response slot for it ([`GatherPolicy::Quorum`] only): the effective
/// quorum shrinks by the number of chronic workers in the wave, never
/// below the 2f_t+1 identification floor. One fresh delivery resets
/// the streak, so a recovered worker is waited for again.
pub const ABANDON_STREAK: u32 = 3;

/// Read-only tap on the protocol core's public state, mirroring what
/// an observer at the master could see: each round's proactive
/// assignment the moment it is fixed (before the wave is submitted)
/// and every event as it is logged. The adversary subsystem
/// ([`crate::adversary`]) uses this to drive coordinated,
/// protocol-aware Byzantine strategies; the tap can neither mutate
/// protocol state nor see oracle data (`tampered` flags never pass
/// through events).
///
/// Shard cores install a remapping wrapper so tap consumers always see
/// **global** worker ids; chunk ids stay round-local.
pub trait ProtocolTap: Send + Sync {
    /// A round's proactive assignment is fixed: `owners[c]` lists chunk
    /// `c`'s owners. Called before the wave is submitted to workers.
    fn on_round_start(&self, iter: u64, f_t: usize, owners: &[Vec<WorkerId>]);
    /// Mirror of every [`Event`] pushed by the core, in push order.
    fn on_event(&self, event: &Event);
}

/// The protocol's wire phases (the `phase` field of every request).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Initial assignment + symbol collection.
    Proactive,
    /// Audit replication up to f_t+1 copies.
    Detection,
    /// Reactive redundancy up to 2f_t+1 copies + majority vote.
    Reactive,
}

impl Phase {
    pub fn wire(self) -> u32 {
        match self {
            Phase::Proactive => 0,
            Phase::Detection => 1,
            Phase::Reactive => 2,
        }
    }
}

/// Working state of one chunk during a round.
#[derive(Default)]
pub struct ChunkCopies {
    /// Received symbol copies. After a vote, the corrected value sits
    /// at the front (worker = [`MASTER_SENTINEL`]).
    pub copies: Vec<SymbolCopy>,
    /// Copies charged to `gradients_computed` (Definition 2).
    pub computed_copies: usize,
}

/// Per-iteration protocol state: the assignment plus everything
/// ingested so far. Buffers are reused across iterations.
#[derive(Default)]
pub struct RoundState {
    pub assignment: Assignment,
    pub chunks: Vec<ChunkCopies>,
    /// Oracle bookkeeping (metrics only): which workers sent a
    /// tampered copy of each chunk.
    pub tampered_by_chunk: Vec<Vec<WorkerId>>,
    /// Wire bytes ingested this round: packed bytes when symbols carry
    /// a wire, 4 bytes per f32 for dense symbols. Master self-check
    /// copies are local and not counted.
    pub bytes: u64,
}

impl RoundState {
    /// Re-arm for a new round, reusing allocations.
    fn reset(&mut self, assignment: Assignment) {
        let nchunks = assignment.nchunks();
        self.assignment = assignment;
        for c in &mut self.chunks {
            c.copies.clear();
            c.computed_copies = 0;
        }
        self.chunks.resize_with(nchunks, ChunkCopies::default);
        for v in &mut self.tampered_by_chunk {
            v.clear();
        }
        self.tampered_by_chunk.resize_with(nchunks, Vec::new);
        self.bytes = 0;
    }

    pub fn nchunks(&self) -> usize {
        self.chunks.len()
    }

    /// The single symbol-ingest path: every response from every phase
    /// funnels through here exactly once.
    pub fn ingest(&mut self, responses: Vec<Response>) {
        for resp in responses {
            let worker = resp.worker;
            for Symbol { chunk, grad, loss, tampered, wire } in resp.symbols {
                if tampered {
                    self.tampered_by_chunk[chunk].push(worker);
                }
                self.bytes += wire
                    .as_ref()
                    .map(|w| w.len() as u64)
                    .unwrap_or(4 * grad.len() as u64);
                let state = &mut self.chunks[chunk];
                state.copies.push(SymbolCopy { worker, grad, loss, wire });
                state.computed_copies += 1;
            }
        }
    }

    /// Chunk value used for the update: the majority-corrected value
    /// if a vote ran (stored at the front by the reactive phase), else
    /// the first received copy.
    pub fn chosen(&self, c: ChunkId) -> &SymbolCopy {
        &self.chunks[c].copies[0]
    }

    /// Observed loss ℓ_t: the median over **one loss per chunk** (the
    /// chunk's first copy). The pre-refactor master pooled every
    /// received copy, silently weighting r-replicated chunks r× in the
    /// median; replicas of one chunk are copies of the same
    /// measurement, not independent samples.
    pub fn observed_loss(&self, scratch: &mut Vec<f64>) -> f64 {
        scratch.clear();
        scratch.extend(
            self.chunks
                .iter()
                .filter_map(|c| c.copies.first().map(|s| s.loss as f64)),
        );
        stats::median(scratch)
    }
}

/// Static protocol parameters (split off `MasterOptions` so the core
/// has no dependency on the master layer).
pub struct ProtocolConfig {
    /// Byzantine tolerance bound f.
    pub f: usize,
    /// Seed for the protocol RNG (sampling, reassignment shuffles).
    pub seed: u64,
    /// Data points per chunk.
    pub chunk_size: usize,
    /// §5 self-check generalization: audit by recomputing on the
    /// master instead of replicating to additional workers.
    pub self_check: bool,
    /// Symbol comparison tolerance (0.0 = exact bitwise).
    pub tol: f32,
    /// Measurement mode: identify but never eliminate (holds f_t = f).
    pub no_eliminate: bool,
    /// §2.1/§5 compressed symbols: the master's self-check copies are
    /// encoded with the same compressor the workers use.
    pub compressor: Option<Arc<dyn Compressor>>,
    /// When the initial proactive wave may stop waiting (detection and
    /// reactive waves always wait for every requested copy).
    pub gather: GatherPolicy,
    /// Pipeline depth: how many rounds may be in flight at once (>= 1;
    /// 1 = the classic one-round-at-a-time protocol).
    pub pipeline: usize,
}

/// What one round did (the master turns this into an
/// [`super::metrics::IterationRecord`]).
pub struct RoundOutcome {
    /// Data points whose gradients enter the update (m).
    pub gradients_used: u64,
    pub audited: bool,
    pub faults_detected: usize,
    pub identified_now: Vec<WorkerId>,
    pub crashed_now: Vec<WorkerId>,
    /// Data points the master recomputed itself (self-check audits).
    pub master_computed_points: u64,
    /// Chunks the audit decision covered (0 when unaudited; equal to
    /// the round's chunk count when the audit was full).
    pub audited_chunks: usize,
    /// Workers the proactive gather stopped waiting for this round
    /// (they rejoin next round; a straggle is not a crash).
    pub stragglers_now: Vec<WorkerId>,
    /// Duration of the round on the transport clock: virtual time
    /// under sim, wall-clock under threaded. Under pipelining this is
    /// the round's *exclusive* span — measured from the later of its
    /// own submit and the previous round's finish — so per-round times
    /// still sum to the run's span instead of double-counting overlap.
    pub round_ns: u64,
    /// Wire bytes moved worker → master this round (packed bytes under
    /// a compressor, 4 per f32 dense). Under the net transport this is
    /// the honest on-the-wire figure instead: every TCP byte moved in
    /// either direction during the round, frame and header overhead
    /// included.
    pub bytes_round: u64,
    /// TCP reconnects ridden out this round (0 on in-process
    /// transports).
    pub net_reconnects: u64,
}

/// One slot of the pipeline ring: a round between
/// [`ProtocolCore::begin_round`] and [`ProtocolCore::finish_round`].
struct PendingRound {
    iter: u64,
    /// Wave id of the round's proactive submit (deliveries are routed
    /// by it; a reissue retires the old wave and allocates a new one).
    wave: u64,
    round: RoundState,
    /// Workers the wave submitted to and is still owed a delivery by.
    outstanding: Vec<WorkerId>,
    /// Transport clock at submit (wave deadlines and `round_ns` are
    /// measured from here).
    start_ns: u64,
    f_t: usize,
    /// Data points sampled for the round (m).
    m: u64,
    /// Has the proactive wave been gathered yet? A round may only be
    /// reissued before, and finished after.
    collected: bool,
    /// Crashes and abandonments observed while gathering this round's
    /// proactive wave (stashed between collect and finish).
    crashed_now: Vec<WorkerId>,
    stragglers_now: Vec<WorkerId>,
}

/// The phase-driven protocol state machine. Owns the transport, the
/// audit policy, the active/eliminated worker sets, and the round
/// buffers; borrows the dataset and gradient engine per round.
pub struct ProtocolCore {
    transport: Box<dyn Transport>,
    policy: FaultCheckPolicy,
    /// Data-point sampling stream. Kept separate from `rng_assign` so
    /// reactive-extension shuffles (whose count depends on audit luck)
    /// can never perturb *which data* later rounds sample — the
    /// sharded parameter server relies on this to reproduce the
    /// single-master sampling stream exactly.
    rng_sample: Pcg64,
    /// Ownership-extension shuffle stream (reactive/detection top-ups).
    rng_assign: Pcg64,
    active: Vec<WorkerId>,
    eliminated: Vec<WorkerId>,
    crashed: Vec<WorkerId>,
    cfg: ProtocolConfig,
    round: RoundState,
    /// Pipeline ring of in-flight rounds, oldest first (capacity
    /// `cfg.pipeline`).
    pending: Vec<PendingRound>,
    /// Next wave id (monotone; one per transport submit).
    next_wave: u64,
    /// Waves whose deliveries are still wanted: the uncollected
    /// proactive waves of the ring plus the wave currently being
    /// waited on. Anything else is dropped on arrival.
    live_waves: Vec<u64>,
    /// Deliveries of a live wave that arrived while a *different* wave
    /// was being waited on, held until their wave is waited.
    mailbox: Vec<(u64, Response)>,
    /// Transport clock when the last round finished (exclusive
    /// `round_ns` accounting under pipelining).
    last_round_end_ns: u64,
    /// Net-transport byte total ([`Transport::net_stats`] tx + rx) at
    /// the end of the previous round; each round's honest wire figure
    /// is the delta from here. Unused on in-process transports.
    net_bytes_baseline: u64,
    loss_scratch: Vec<f64>,
    /// Consecutive proactive-wave abandonments per worker (reset by any
    /// fresh delivery); >= [`ABANDON_STREAK`] marks a chronic straggler
    /// the quorum gather stops waiting for.
    abandon_streak: Vec<u32>,
    /// Read-only observer of assignments + events (None = silent).
    tap: Option<Arc<dyn ProtocolTap>>,
    /// Flight-recorder handle ([`crate::trace::Recorder`]); None =
    /// tracing off. Checked once per event / wave / round — never in
    /// the per-symbol hot loop.
    recorder: Option<TraceHandle>,
}

impl ProtocolCore {
    pub fn new(
        transport: Box<dyn Transport>,
        policy: FaultCheckPolicy,
        cfg: ProtocolConfig,
    ) -> ProtocolCore {
        let n = transport.n();
        ProtocolCore {
            transport,
            policy,
            rng_sample: Pcg64::new(cfg.seed, SAMPLE_STREAM),
            rng_assign: Pcg64::new(cfg.seed, 0xa5516e),
            active: (0..n).collect(),
            eliminated: Vec::new(),
            crashed: Vec::new(),
            cfg,
            round: RoundState::default(),
            pending: Vec::new(),
            next_wave: 0,
            live_waves: Vec::new(),
            mailbox: Vec::new(),
            last_round_end_ns: 0,
            net_bytes_baseline: 0,
            loss_scratch: Vec::new(),
            abandon_streak: vec![0; n],
            tap: None,
            recorder: None,
        }
    }

    /// Install a read-only [`ProtocolTap`]. The tap sees each round's
    /// assignment before the wave is submitted and every event as it
    /// is logged; it cannot mutate protocol state.
    pub fn set_tap(&mut self, tap: Arc<dyn ProtocolTap>) {
        self.tap = Some(tap);
    }

    /// Install a flight-recorder handle ([`crate::trace::Recorder`]).
    /// Like the tap, the recorder is read-only; unlike the tap it also
    /// timestamps everything it sees on the transport clock.
    pub fn set_recorder(&mut self, recorder: TraceHandle) {
        self.recorder = Some(recorder);
    }

    /// Mirror an event to the tap and the recorder (if any), then log
    /// it. The recorder stamp is the transport clock at emit time,
    /// computed only when a recorder is installed.
    fn emit(
        tap: &Option<Arc<dyn ProtocolTap>>,
        recorder: &Option<TraceHandle>,
        transport: &dyn Transport,
        events: &mut EventLog,
        e: Event,
    ) {
        if let Some(r) = recorder {
            r.on_event(transport.now_ns(), &e);
        }
        if let Some(t) = tap {
            t.on_event(&e);
        }
        events.push(e);
    }

    /// Current Byzantine budget f_t = f - κ_t.
    pub fn f_t(&self) -> usize {
        self.cfg.f.saturating_sub(self.eliminated.len())
    }

    pub fn active(&self) -> &[WorkerId] {
        &self.active
    }

    pub fn eliminated(&self) -> &[WorkerId] {
        &self.eliminated
    }

    pub fn crashed(&self) -> &[WorkerId] {
        &self.crashed
    }

    pub fn policy(&self) -> &FaultCheckPolicy {
        &self.policy
    }

    /// The most recent round (valid after `run_round`).
    pub fn round(&self) -> &RoundState {
        &self.round
    }

    /// Shut the transport down and surrender the final worker sets.
    pub fn into_outcome(mut self) -> (Vec<WorkerId>, Vec<WorkerId>) {
        self.transport.shutdown();
        (self.eliminated, self.crashed)
    }

    /// Drive one full iteration: sample m points from the protocol's
    /// own stream, then proactive → (detection → reactive).
    pub fn run_round(
        &mut self,
        t: u64,
        theta: &Arc<Vec<f32>>,
        dataset: &dyn Dataset,
        engine: &dyn GradientComputer,
        events: &mut EventLog,
    ) -> Result<RoundOutcome> {
        self.begin_round_sampled(t, theta, dataset)?;
        self.complete_round(t, theta, dataset, engine, events)
    }

    /// Sample this round's m data points from the protocol's own
    /// stream and submit the proactive wave without waiting. Sampling
    /// happens at begin time, so the sample stream stays in iteration
    /// order at any pipeline depth — a reissue reuses the same chunks.
    pub fn begin_round_sampled(
        &mut self,
        t: u64,
        theta: &Arc<Vec<f32>>,
        dataset: &dyn Dataset,
    ) -> Result<()> {
        anyhow::ensure!(!self.active.is_empty(), "no active workers left at iteration {t}");
        let cs = self.cfg.chunk_size;
        let m = self.active.len() * cs;
        let data_ids = sample_points(&mut self.rng_sample, dataset.len(), m);
        let chunks: Vec<Vec<usize>> = data_ids.chunks(cs).map(|s| s.to_vec()).collect();
        self.begin_round(t, theta, chunks, dataset)
    }

    /// Drive one full iteration over externally-sampled chunks (the
    /// sharded parameter server samples globally and hands each shard
    /// its chunk slice). `chunks.len()` normally equals the active
    /// count; a rescue round absorbing a dead shard's chunks may pass
    /// more or fewer.
    pub fn run_round_with_chunks(
        &mut self,
        t: u64,
        theta: &Arc<Vec<f32>>,
        chunks: Vec<Vec<usize>>,
        dataset: &dyn Dataset,
        engine: &dyn GradientComputer,
        events: &mut EventLog,
    ) -> Result<RoundOutcome> {
        self.begin_round(t, theta, chunks, dataset)?;
        self.complete_round(t, theta, dataset, engine, events)
    }

    /// Submit iteration `t`'s proactive wave and return without
    /// waiting. Up to [`ProtocolConfig::pipeline`] rounds may be in
    /// flight at once: a pipelined driver begins t+1 on a provisional
    /// θ while t's later phases run, a multi-core driver (the sharded
    /// parameter server) puts every core's wave in flight before
    /// waiting on any. Pair with [`ProtocolCore::complete_round`] (or
    /// `collect_proactive` + `finish_round`) for the same `t`.
    pub fn begin_round(
        &mut self,
        t: u64,
        theta: &Arc<Vec<f32>>,
        chunks: Vec<Vec<usize>>,
        dataset: &dyn Dataset,
    ) -> Result<()> {
        let depth = self.cfg.pipeline.max(1);
        anyhow::ensure!(
            self.pending.len() < depth,
            "begin_round at iteration {t}: pipeline ring full (depth {depth})"
        );
        anyhow::ensure!(
            self.pending.iter().all(|p| p.iter != t),
            "begin_round twice for iteration {t}"
        );
        anyhow::ensure!(!self.active.is_empty(), "no active workers left at iteration {t}");
        let f_t = self.f_t();
        let nact = self.active.len();
        let r = self.policy.proactive_r(f_t).min(nact);

        let m = (chunks.len() * self.cfg.chunk_size) as u64;
        let mut round = std::mem::take(&mut self.round);
        round.reset(Assignment::from_chunks(chunks, &self.active, r));
        let (wave, outstanding, start_ns) = self.submit_proactive(t, f_t, theta, dataset, &round)?;
        self.pending.push(PendingRound {
            iter: t,
            wave,
            round,
            outstanding,
            start_ns,
            f_t,
            m,
            collected: false,
            crashed_now: Vec::new(),
            stragglers_now: Vec::new(),
        });
        Ok(())
    }

    /// Build per-worker bundles for `round`'s assignment, show the tap
    /// the fixed assignment, allocate a wave id, and submit. Shared by
    /// `begin_round` and `reissue_round`.
    fn submit_proactive(
        &mut self,
        t: u64,
        f_t: usize,
        theta: &Arc<Vec<f32>>,
        dataset: &dyn Dataset,
        round: &RoundState,
    ) -> Result<(u64, Vec<WorkerId>, u64)> {
        let bundles: Vec<TaskBundle> = self
            .active
            .iter()
            .map(|&w| TaskBundle {
                worker: w,
                tasks: round
                    .assignment
                    .chunks_of(w)
                    .into_iter()
                    .map(|c| (c, dataset.batch(&round.assignment.chunks[c])))
                    .collect(),
            })
            .collect();
        let outstanding: Vec<WorkerId> = bundles.iter().map(|b| b.worker).collect();
        // the tap sees the fixed assignment before any worker does
        if let Some(tap) = &self.tap {
            tap.on_round_start(t, f_t, &round.assignment.owners);
        }
        let wave = self.next_wave;
        self.next_wave += 1;
        let start_ns = self.transport.now_ns();
        self.transport.submit(t, Phase::Proactive.wire(), wave, theta, bundles)?;
        self.live_waves.push(wave);
        if let Some(rec) = &self.recorder {
            rec.wave_begin(t, wave, Phase::Proactive.wire() as u8, start_ns, outstanding.len());
        }
        Ok((wave, outstanding, start_ns))
    }

    /// Invalidate iteration `t`'s still-uncollected proactive wave and
    /// resubmit it on a new θ. The pipelined driver calls this when
    /// finishing an earlier round changed θ after `t`'s wave had
    /// already been submitted speculatively on a provisional value:
    /// the old wave's id is retired, so anything it still delivers is
    /// dropped, and the same sampled chunks are reassigned over the
    /// *current* active set and Byzantine budget.
    pub fn reissue_round(
        &mut self,
        t: u64,
        theta: &Arc<Vec<f32>>,
        dataset: &dyn Dataset,
    ) -> Result<()> {
        let idx = self
            .pending
            .iter()
            .position(|p| p.iter == t)
            .ok_or_else(|| anyhow::anyhow!("reissue_round without begin_round at iteration {t}"))?;
        anyhow::ensure!(
            !self.pending[idx].collected,
            "reissue_round after collect_proactive at iteration {t}"
        );
        anyhow::ensure!(!self.active.is_empty(), "no active workers left at iteration {t}");
        let mut pr = self.pending.remove(idx);
        // retire the provisional wave: late deliveries computed on the
        // provisional θ must never reach the authoritative round
        self.live_waves.retain(|&w| w != pr.wave);
        self.mailbox.retain(|(_, r)| r.wave != pr.wave);
        if let Some(rec) = &self.recorder {
            rec.wave_reissued(t, pr.wave, self.transport.now_ns());
        }
        let f_t = self.f_t();
        let r = self.policy.proactive_r(f_t).min(self.active.len());
        let chunks = std::mem::take(&mut pr.round.assignment.chunks);
        pr.round.reset(Assignment::from_chunks(chunks, &self.active, r));
        let (wave, outstanding, start_ns) =
            self.submit_proactive(t, f_t, theta, dataset, &pr.round)?;
        pr.wave = wave;
        pr.outstanding = outstanding;
        pr.start_ns = start_ns;
        pr.f_t = f_t;
        self.pending.insert(idx, pr);
        Ok(())
    }

    /// Gather iteration `t`'s proactive wave under the configured
    /// [`GatherPolicy`] and reassign any orphaned chunks, leaving the
    /// collected round in the ring. After this, the round's pre-audit
    /// symbols are visible through [`ProtocolCore::pending_round`] (the
    /// pipelined driver computes its provisional θ from them) and the
    /// round is ready for [`ProtocolCore::finish_round`]. Idempotent:
    /// collecting an already-collected round is a no-op.
    pub fn collect_proactive(
        &mut self,
        t: u64,
        theta: &Arc<Vec<f32>>,
        dataset: &dyn Dataset,
        events: &mut EventLog,
    ) -> Result<()> {
        let idx = self.pending.iter().position(|p| p.iter == t).ok_or_else(|| {
            anyhow::anyhow!("collect_proactive without begin_round at iteration {t}")
        })?;
        if self.pending[idx].collected {
            return Ok(());
        }
        // take the round out of the ring so wait_wave can borrow core
        // state (note_failure retires crashed workers from the *other*
        // in-flight rounds through self.pending)
        let mut pr = self.pending.remove(idx);
        let mut crashed_now: Vec<WorkerId> = Vec::new();
        let mut stragglers_now: Vec<WorkerId> = Vec::new();

        // ---- Phase::Proactive ------------------------------------------
        // the reactive phase needs 2f_t+1 distinct owners for its
        // majority vote, so no quorum/deadline wave may retain fewer
        // responders than that — the wave waits past its trigger until
        // the floor is met (validate() already rejects k < 2f+1, this
        // also covers deadline waves and per-shard scaled quorums)
        let floor = (2 * pr.f_t + 1).min(pr.outstanding.len());
        let gather = self.cfg.gather;
        let outstanding = std::mem::take(&mut pr.outstanding);
        let responses = self.wait_wave(
            t,
            pr.wave,
            gather,
            floor,
            outstanding,
            pr.start_ns,
            true,
            &mut pr.round,
            &mut crashed_now,
            &mut stragglers_now,
            events,
        )?;
        pr.round.ingest(responses);

        // crash-drops and abandoned stragglers: reassign orphaned
        // chunks so every chunk has at least one copy before the
        // update (abandoned workers were retired from the round's
        // candidate pool by wait_wave, exactly like crashed ones)
        if pr.round.chunks.iter().any(|c| c.copies.is_empty()) {
            let targets: Vec<(ChunkId, usize)> =
                (0..pr.round.nchunks()).map(|c| (c, 1)).collect();
            self.ensure_copies(
                t,
                Phase::Proactive,
                theta,
                dataset,
                &mut pr.round,
                &mut crashed_now,
                &targets,
                events,
            )?;
        }
        pr.collected = true;
        pr.crashed_now = crashed_now;
        pr.stragglers_now = stragglers_now;
        self.pending.insert(idx, pr);
        Ok(())
    }

    /// The collected-but-unfinished round for iteration `t`, if any:
    /// its pre-audit symbols are what the pipelined driver aggregates
    /// into the provisional θ.
    pub fn pending_round(&self, t: u64) -> Option<&RoundState> {
        self.pending
            .iter()
            .find(|p| p.iter == t && p.collected)
            .map(|p| &p.round)
    }

    /// Collect iteration `t`'s proactive wave (if not already
    /// collected) and drive the rest of the round to completion.
    pub fn complete_round(
        &mut self,
        t: u64,
        theta: &Arc<Vec<f32>>,
        dataset: &dyn Dataset,
        engine: &dyn GradientComputer,
        events: &mut EventLog,
    ) -> Result<RoundOutcome> {
        self.collect_proactive(t, theta, dataset, events)?;
        self.finish_round(t, theta, dataset, engine, events)
    }

    /// Drive a collected round through suspicion refresh, the audit
    /// decision, detection, and reactive identification, and pop it
    /// from the ring. θ application order is the caller's contract:
    /// rounds must be finished in iteration order.
    pub fn finish_round(
        &mut self,
        t: u64,
        theta: &Arc<Vec<f32>>,
        dataset: &dyn Dataset,
        engine: &dyn GradientComputer,
        events: &mut EventLog,
    ) -> Result<RoundOutcome> {
        let idx = self
            .pending
            .iter()
            .position(|p| p.iter == t)
            .ok_or_else(|| anyhow::anyhow!("finish_round without begin_round at iteration {t}"))?;
        anyhow::ensure!(
            self.pending[idx].collected,
            "finish_round before collect_proactive at iteration {t}"
        );
        let PendingRound {
            mut round,
            start_ns,
            f_t,
            m,
            mut crashed_now,
            stragglers_now,
            ..
        } = self.pending.remove(idx);

        // ---- latency profiles → suspicion ------------------------------
        // the proactive wave's delivery timestamps (and any straggler
        // abandonments) are folded in by now: refresh the fused
        // per-worker suspicion so this round's audit decision — and the
        // suspicion-ranked re-replication below — see current timing
        for (w, s) in self.policy.refresh_suspicion(&self.active) {
            let e = Event::SuspicionUpdated { iter: t, worker: w, suspicion: s };
            Self::emit(&self.tap, &self.recorder, &*self.transport, events, e);
        }

        // ---- audit decision --------------------------------------------
        let observed_loss = round.observed_loss(&mut self.loss_scratch);
        let decision = self.policy.decide(t, observed_loss, f_t, &self.active);
        let audited = decision != AuditDecision::Skip;
        Self::emit(
            &self.tap,
            &self.recorder,
            &*self.transport,
            events,
            Event::AuditDecision { iter: t, q: self.policy.last_q, audited },
        );

        let audit_chunks: Vec<ChunkId> = match &decision {
            AuditDecision::Skip => vec![],
            AuditDecision::Full => (0..round.nchunks()).collect(),
            AuditDecision::Workers(ws) => (0..round.nchunks())
                .filter(|&c| round.assignment.owners[c].iter().any(|w| ws.contains(w)))
                .collect(),
        };

        let audited_chunks = audit_chunks.len();
        let mut master_computed_points = 0u64;
        let mut faults_detected = 0usize;
        let mut identified_now: Vec<WorkerId> = Vec::new();

        if !audit_chunks.is_empty() {
            // ---- Phase::Detection --------------------------------------
            if self.cfg.self_check {
                // master recomputes under-replicated chunks locally
                // (trusted copy with the sentinel id)
                for &c in &audit_chunks {
                    if round.chunks[c].copies.len() >= f_t + 1 {
                        continue;
                    }
                    let batch = dataset.batch(&round.assignment.chunks[c]);
                    let g = engine.grad(theta, &batch)?;
                    master_computed_points += self.cfg.chunk_size as u64;
                    let (grad, wire) = match &self.cfg.compressor {
                        Some(comp) => {
                            let w = comp.pack(&g.grad);
                            let dense = comp.unpack(&w, g.grad.len());
                            (dense, Some(w))
                        }
                        None => (g.grad, None),
                    };
                    round.chunks[c].copies.push(SymbolCopy {
                        worker: MASTER_SENTINEL,
                        grad,
                        loss: g.loss,
                        wire,
                    });
                }
            } else {
                let targets: Vec<(ChunkId, usize)> =
                    audit_chunks.iter().map(|&c| (c, f_t + 1)).collect();
                self.ensure_copies(
                    t,
                    Phase::Detection,
                    theta,
                    dataset,
                    &mut round,
                    &mut crashed_now,
                    &targets,
                    events,
                )?;
            }

            // detection comparisons
            let mut flagged: Vec<ChunkId> = Vec::new();
            for &c in &audit_chunks {
                match check_copies(&round.chunks[c].copies, self.cfg.tol) {
                    CheckOutcome::Unanimous => {
                        for s in &round.chunks[c].copies {
                            if s.worker != MASTER_SENTINEL {
                                self.policy.report_verified(s.worker);
                            }
                        }
                    }
                    CheckOutcome::FaultDetected => {
                        faults_detected += 1;
                        let owners: Vec<WorkerId> = round.chunks[c]
                            .copies
                            .iter()
                            .map(|s| s.worker)
                            .filter(|&w| w != MASTER_SENTINEL)
                            .collect();
                        Self::emit(
                            &self.tap,
                            &self.recorder,
                            &*self.transport,
                            events,
                            Event::FaultDetected { iter: t, chunk: c, owners: owners.clone() },
                        );
                        // the ledger keeps each disagreeing copy's
                        // packed-symbol hash as detection evidence
                        if let Some(rec) = &self.recorder {
                            rec.detection_evidence(
                                self.transport.now_ns(),
                                t,
                                c,
                                &round.chunks[c].copies,
                            );
                        }
                        self.policy.report_suspects(&owners);
                        flagged.push(c);
                    }
                }
            }

            // ---- Phase::Reactive ---------------------------------------
            if !flagged.is_empty() {
                if self.cfg.self_check {
                    // the master's own copy is ground truth: every worker
                    // copy differing from it is provably Byzantine
                    for &c in &flagged {
                        // a chunk that was already replicated to >= f_t+1
                        // workers (e.g. deterministic policy) skipped the
                        // detection-phase self-check; compute the trusted
                        // copy on demand before judging
                        if !round.chunks[c].copies.iter().any(|s| s.worker == MASTER_SENTINEL) {
                            let batch = dataset.batch(&round.assignment.chunks[c]);
                            let g = engine.grad(theta, &batch)?;
                            master_computed_points += self.cfg.chunk_size as u64;
                            let (grad, wire) = match &self.cfg.compressor {
                                Some(comp) => {
                                    let w = comp.pack(&g.grad);
                                    let dense = comp.unpack(&w, g.grad.len());
                                    (dense, Some(w))
                                }
                                None => (g.grad, None),
                            };
                            round.chunks[c].copies.push(SymbolCopy {
                                worker: MASTER_SENTINEL,
                                grad,
                                loss: g.loss,
                                wire,
                            });
                        }
                        let master_copy = round.chunks[c]
                            .copies
                            .iter()
                            .find(|s| s.worker == MASTER_SENTINEL)
                            .expect("self-check copy present")
                            .clone();
                        let liars: Vec<WorkerId> = round.chunks[c]
                            .copies
                            .iter()
                            .filter(|s| {
                                s.worker != MASTER_SENTINEL
                                    && !symbols_equal(s, &master_copy, self.cfg.tol)
                            })
                            .map(|s| s.worker)
                            .collect();
                        if let Some(rec) = &self.recorder {
                            rec.vote_evidence(
                                self.transport.now_ns(),
                                t,
                                c,
                                &round.chunks[c].copies,
                                &master_copy,
                                &liars,
                            );
                        }
                        self.finish_vote(t, c, &mut round, master_copy, liars, &mut identified_now, events);
                    }
                } else {
                    let targets: Vec<(ChunkId, usize)> =
                        flagged.iter().map(|&c| (c, 2 * f_t + 1)).collect();
                    self.ensure_copies(
                        t,
                        Phase::Reactive,
                        theta,
                        dataset,
                        &mut round,
                        &mut crashed_now,
                        &targets,
                        events,
                    )?;
                    for &c in &flagged {
                        let vote = majority_vote(&round.chunks[c].copies, f_t)
                            .expect("quorum guaranteed with 2f_t+1 distinct owners");
                        let winner = SymbolCopy {
                            worker: MASTER_SENTINEL,
                            grad: vote.grad,
                            loss: vote.loss,
                            wire: vote.wire,
                        };
                        if let Some(rec) = &self.recorder {
                            rec.vote_evidence(
                                self.transport.now_ns(),
                                t,
                                c,
                                &round.chunks[c].copies,
                                &winner,
                                &vote.liars,
                            );
                        }
                        self.finish_vote(t, c, &mut round, winner, vote.liars, &mut identified_now, events);
                    }
                }
            }
        }

        self.round = round;
        // exclusive span: under pipelining this round's wave may have
        // been submitted while the previous round was still finishing —
        // measure from the later of its own submit and the previous
        // round's end, so per-round times sum to the run's span
        let now = self.transport.now_ns();
        let round_ns = now.saturating_sub(start_ns.max(self.last_round_end_ns));
        self.last_round_end_ns = now;

        // ---- net transport accounting ----------------------------------
        // reconnects ridden out since the last finish surface here as
        // events; the wire figure becomes the honest TCP byte delta
        // (frames, headers, theta broadcast — both directions) instead
        // of the payload-only estimate in-process transports report
        let reconnects = self.transport.drain_reconnects();
        let net_reconnects = reconnects.len() as u64;
        for (_ns, w) in reconnects {
            Self::emit(
                &self.tap,
                &self.recorder,
                &*self.transport,
                events,
                Event::NetReconnect { iter: t, worker: w },
            );
        }
        let bytes_round = net_bytes_round(
            self.transport.net_stats(),
            &mut self.net_bytes_baseline,
            self.round.bytes,
        );
        if let Some(rec) = &self.recorder {
            // worker-side telemetry (telemetry-enabled net transport
            // only): clock-remapped remote spans become worker-process
            // rows in the trace, and the per-link health snapshot
            // refreshes the worker-labeled metric families
            let spans = self.transport.drain_remote_spans();
            if !spans.is_empty() {
                rec.remote_spans(spans);
            }
            let links = self.transport.link_stats();
            if !links.is_empty() {
                rec.link_stats(links);
            }
            rec.round_finished(t, start_ns, now, round_ns, bytes_round);
        }
        Ok(RoundOutcome {
            gradients_used: m,
            audited,
            faults_detected,
            identified_now,
            crashed_now,
            master_computed_points,
            audited_chunks,
            stragglers_now,
            round_ns,
            bytes_round,
            net_reconnects,
        })
    }

    /// Collect one wave's deliveries under `policy`. Deliveries are
    /// routed by wave id: responses for `wave` are buffered and
    /// returned sorted by worker id (deliveries of this wave consumed
    /// during an earlier wait are picked up from the mailbox first);
    /// responses of a *different still-live* wave are mailboxed for
    /// their own wait; responses of a dead wave (an abandoned
    /// straggler's, a reissued provisional wave's) are dropped, never
    /// ingested. In-band failures are recorded as crashes the moment
    /// they arrive — during whichever wave's wait happens to be
    /// running — and retire the worker from every in-flight round's
    /// candidate pool. On a quorum/deadline early exit the
    /// still-outstanding workers are abandoned for the round: retired
    /// from the round's candidate pool — their chunks get reassigned
    /// exactly like a crashed worker's — but they stay active for
    /// future rounds. `min_responses` is the floor no early exit may
    /// cut below (the proactive wave passes 2f_t+1 so the reactive
    /// vote stays assemblable; crash-stops can still shrink the wave,
    /// exactly as they always could). `profile_latency` is set only
    /// for the round's **initial proactive wave**: top-up waves are
    /// small and often single-target, so their zero-excess
    /// observations would dilute a straggler's profile with
    /// meaningless samples.
    #[allow(clippy::too_many_arguments)]
    fn wait_wave(
        &mut self,
        t: u64,
        wave: u64,
        policy: GatherPolicy,
        min_responses: usize,
        outstanding: Vec<WorkerId>,
        start_ns: u64,
        profile_latency: bool,
        round: &mut RoundState,
        crashed_now: &mut Vec<WorkerId>,
        stragglers_now: &mut Vec<WorkerId>,
        events: &mut EventLog,
    ) -> Result<Vec<Response>> {
        let floor = min_responses.max(1);
        let quorum = match policy {
            GatherPolicy::Quorum { k } => {
                // k counts responders at full cluster strength; what
                // stays fixed as crashes/eliminations shrink the wave
                // is the *allowed missing* margin n - k, so the quorum
                // tracks the current wave size instead of becoming
                // unreachable (which would silently degrade to All and
                // re-expose straggler gating)
                let allowed_missing = self.transport.n().saturating_sub(k);
                let base = outstanding.len().saturating_sub(allowed_missing);
                // chronic stragglers (>= ABANDON_STREAK consecutive
                // abandonments) are not worth budgeting a response slot
                // for: auto-shrink the effective quorum by their count.
                // The 2f_t+1 floor below is untouched, so the reactive
                // majority vote stays assemblable no matter how many
                // workers turn chronic
                let chronic = outstanding
                    .iter()
                    .filter(|&&w| self.abandon_streak[w] >= ABANDON_STREAK)
                    .count();
                base.saturating_sub(chronic).max(floor)
            }
            GatherPolicy::All | GatherPolicy::Deadline { .. } => usize::MAX,
        };
        // saturating: an astronomically large deadline means "never",
        // i.e. All — it must not wrap into the past
        let deadline_ns = match policy {
            GatherPolicy::Deadline { us } => {
                Some(start_ns.saturating_add(us.saturating_mul(1000)))
            }
            _ => None,
        };
        // O(1) per-delivery membership: worker ids index the mask. A
        // worker whose crash already surfaced (possibly during another
        // wave's wait) will never answer this wave either — its slot is
        // resolved up front so the wait cannot stall on it.
        let mut waiting = vec![false; self.transport.n()];
        let mut remaining = 0usize;
        for &w in &outstanding {
            if !waiting[w] && !self.crashed.contains(&w) {
                waiting[w] = true;
                remaining += 1;
            }
        }
        let mut responses: Vec<Response> = Vec::new();
        // first fresh arrival of this wave: the latency-profile origin
        // (per-worker observations are *relative* delays behind it, so
        // per-wave fixed costs cancel — see `super::latency`)
        let mut wave_first: Option<u64> = None;
        // deliveries of this wave consumed while another wave was being
        // waited on sit in the mailbox, in arrival order
        let mut boxed: Vec<(u64, Response)> = Vec::new();
        let mut i = 0;
        while i < self.mailbox.len() {
            if self.mailbox[i].1.wave == wave {
                boxed.push(self.mailbox.remove(i));
            } else {
                i += 1;
            }
        }
        for (at_ns, response) in boxed {
            if !waiting[response.worker] {
                continue;
            }
            if profile_latency {
                let first = *wave_first.get_or_insert(at_ns);
                self.policy
                    .observe_latency(response.worker, at_ns.saturating_sub(first));
            }
            if let Some(rec) = &self.recorder {
                rec.delivery(t, wave, response.worker, start_ns, at_ns);
            }
            self.abandon_streak[response.worker] = 0;
            waiting[response.worker] = false;
            remaining -= 1;
            responses.push(response);
        }
        loop {
            if remaining == 0 || responses.len() >= quorum {
                break;
            }
            // a deadline may expire the wave, but never below the
            // floor: until then we wait for arrivals unbounded
            let bound = if responses.len() < floor { None } else { deadline_ns };
            let deliveries = self.transport.poll(bound)?;
            if deliveries.is_empty() {
                if bound.is_some() {
                    break; // deadline hit
                }
                anyhow::bail!(
                    "transport stalled at iteration {t}: {remaining} workers outstanding, \
                     nothing in flight"
                );
            }
            for d in deliveries {
                match d {
                    Delivery::Failed { worker, .. } => {
                        self.note_failure(t, worker, round, crashed_now, events);
                        if waiting[worker] {
                            waiting[worker] = false;
                            remaining -= 1;
                        }
                    }
                    Delivery::Response { at_ns, response } => {
                        if response.wave == wave && waiting[response.worker] {
                            if profile_latency {
                                let first = *wave_first.get_or_insert(at_ns);
                                self.policy.observe_latency(
                                    response.worker,
                                    at_ns.saturating_sub(first),
                                );
                            }
                            if let Some(rec) = &self.recorder {
                                rec.delivery(t, wave, response.worker, start_ns, at_ns);
                            }
                            // a delivered wave breaks the worker's
                            // consecutive-abandonment streak
                            self.abandon_streak[response.worker] = 0;
                            waiting[response.worker] = false;
                            remaining -= 1;
                            responses.push(response);
                        } else if response.wave != wave
                            && self.live_waves.contains(&response.wave)
                        {
                            // another in-flight wave's delivery: hold it
                            // for that wave's own wait
                            self.mailbox.push((at_ns, response));
                        }
                        // else: dead wave (abandoned straggler, or a
                        // reissued provisional round) — dropped, never
                        // ingested
                    }
                }
            }
        }
        // this wave is over: whatever it still delivers is dead
        self.live_waves.retain(|&w| w != wave);
        if let Some(rec) = &self.recorder {
            rec.wave_end(wave, self.transport.now_ns(), responses.len());
        }
        // quorum/deadline early exit: abandon the stragglers this round
        // (censored samples use the same baseline as regular
        // observations — excess behind the wave's first arrival — so
        // the profile never mixes submit-relative and arrival-relative
        // quantities)
        let cutoff_excess_ns = self
            .transport
            .now_ns()
            .saturating_sub(wave_first.unwrap_or(start_ns));
        for w in outstanding {
            if waiting[w] {
                // the abandoned worker was at least as slow as the wave
                // cutoff: charge its latency profile a censored sample
                if profile_latency {
                    self.policy.observe_abandoned(w, cutoff_excess_ns);
                }
                self.abandon_streak[w] = self.abandon_streak[w].saturating_add(1);
                round.assignment.retire(w);
                stragglers_now.push(w);
                Self::emit(
                    &self.tap,
                    &self.recorder,
                    &*self.transport,
                    events,
                    Event::StragglerAbandoned { iter: t, worker: w },
                );
            }
        }
        responses.sort_by_key(|r| r.worker);
        Ok(responses)
    }

    /// Top chunks up to their target copy counts: extend ownership,
    /// submit, collect every requested copy, ingest — looping while
    /// crashes keep knocking out newly-assigned owners. Terminates
    /// because every pass either satisfies all targets or permanently
    /// shrinks the active set.
    #[allow(clippy::too_many_arguments)]
    fn ensure_copies(
        &mut self,
        t: u64,
        phase: Phase,
        theta: &Arc<Vec<f32>>,
        dataset: &dyn Dataset,
        round: &mut RoundState,
        crashed_now: &mut Vec<WorkerId>,
        targets: &[(ChunkId, usize)],
        events: &mut EventLog,
    ) -> Result<()> {
        loop {
            let mut extra: Vec<(WorkerId, Vec<ChunkId>)> = Vec::new();
            for &(c, want) in targets {
                let have = round.chunks[c].copies.len();
                if have >= want {
                    continue;
                }
                let shortfall = want - have;
                let candidates = round
                    .assignment
                    .active
                    .iter()
                    .copied()
                    .filter(|w| !round.assignment.owners[c].contains(w))
                    .count();
                anyhow::ensure!(
                    candidates >= shortfall,
                    "cannot reach {want} copies of chunk {c} at iteration {t}: \
                     only {candidates} candidate workers remain"
                );
                // the latency-aware policy places audit replicas on the
                // least-suspect candidates first (deterministic, no RNG
                // draw); every other policy keeps the uniform shuffle —
                // and its `rng_assign` stream — exactly as before
                let added = if self.policy.rank_extensions() {
                    round.assignment.extend_ranked(c, shortfall, self.policy.suspicion())
                } else {
                    round.assignment.extend(c, shortfall, &mut self.rng_assign)
                };
                if phase == Phase::Reactive {
                    Self::emit(
                        &self.tap,
                        &self.recorder,
                        &*self.transport,
                        events,
                        Event::ReactiveRedundancy { iter: t, chunk: c, added: added.clone() },
                    );
                }
                for w in added {
                    match extra.iter_mut().find(|(ww, _)| *ww == w) {
                        Some((_, cs)) => cs.push(c),
                        None => extra.push((w, vec![c])),
                    }
                }
            }
            if extra.is_empty() {
                return Ok(());
            }
            let bundles: Vec<TaskBundle> = extra
                .into_iter()
                .map(|(w, cs)| TaskBundle {
                    worker: w,
                    tasks: cs
                        .into_iter()
                        .map(|c| (c, dataset.batch(&round.assignment.chunks[c])))
                        .collect(),
                })
                .collect();
            let outstanding: Vec<WorkerId> = bundles.iter().map(|b| b.worker).collect();
            let wave = self.next_wave;
            self.next_wave += 1;
            let start_ns = self.transport.now_ns();
            self.transport.submit(t, phase.wire(), wave, theta, bundles)?;
            self.live_waves.push(wave);
            if let Some(rec) = &self.recorder {
                rec.wave_begin(t, wave, phase.wire() as u8, start_ns, outstanding.len());
            }
            // top-up waves always wait for every requested copy: only
            // the initial proactive wave is quorum-relaxed
            let mut no_stragglers = Vec::new();
            let responses = self.wait_wave(
                t,
                wave,
                GatherPolicy::All,
                0,
                outstanding,
                start_ns,
                false,
                round,
                crashed_now,
                &mut no_stragglers,
                events,
            )?;
            debug_assert!(no_stragglers.is_empty(), "an All wave cannot abandon workers");
            round.ingest(responses);
        }
    }

    /// Record one in-band crash-stop: retire the worker from the
    /// active set (it is *not* eliminated — crashing is not lying),
    /// from the current assignment's candidate pool, and from every
    /// other in-flight round's pool (a crash is global, whichever
    /// wave's wait happened to observe it). Idempotent: the transport
    /// may report a crash once per submit.
    fn note_failure(
        &mut self,
        t: u64,
        w: WorkerId,
        round: &mut RoundState,
        crashed_now: &mut Vec<WorkerId>,
        events: &mut EventLog,
    ) {
        if self.crashed.contains(&w) {
            return;
        }
        self.crashed.push(w);
        crashed_now.push(w);
        if let Some(pos) = self.active.iter().position(|&a| a == w) {
            self.active.remove(pos);
        }
        round.assignment.retire(w);
        for pr in &mut self.pending {
            pr.round.assignment.retire(w);
        }
        self.policy.report_crashed(w);
        Self::emit(
            &self.tap,
            &self.recorder,
            &*self.transport,
            events,
            Event::WorkerCrashed { iter: t, worker: w },
        );
    }

    /// Common tail of both identification paths: store the corrected
    /// value at the front of the chunk's copies, eliminate liars.
    #[allow(clippy::too_many_arguments)]
    fn finish_vote(
        &mut self,
        t: u64,
        c: ChunkId,
        round: &mut RoundState,
        winner: SymbolCopy,
        liars: Vec<WorkerId>,
        identified_now: &mut Vec<WorkerId>,
        events: &mut EventLog,
    ) {
        round.chunks[c].copies.insert(0, winner);
        if liars.is_empty() {
            return;
        }
        Self::emit(
            &self.tap,
            &self.recorder,
            &*self.transport,
            events,
            Event::Identified { iter: t, workers: liars.clone() },
        );
        if self.cfg.no_eliminate {
            return;
        }
        for w in liars {
            if let Some(pos) = self.active.iter().position(|&a| a == w) {
                self.active.remove(pos);
                self.eliminated.push(w);
                self.policy.report_identified(w);
                Self::emit(
                    &self.tap,
                    &self.recorder,
                    &*self.transport,
                    events,
                    Event::Eliminated { iter: t, worker: w },
                );
                identified_now.push(w);
            }
        }
    }
}

/// One round's honest wire figure: the socket-counter delta since the
/// previous round's baseline (which then advances to the new total),
/// or the in-process payload estimate when the transport moves no real
/// bytes. Retransmitted frames and reconnect handshakes *are* counted
/// — they hit the wire — while the saturating delta guarantees a
/// reconnect storm (or any counter hiccup) can never underflow into a
/// wrapped, absurd `bytes_round`.
fn net_bytes_round(stats: Option<NetStats>, baseline: &mut u64, payload_estimate: u64) -> u64 {
    match stats {
        Some(s) => {
            let total = s.bytes_tx.saturating_add(s.bytes_rx);
            let delta = total.saturating_sub(*baseline);
            *baseline = total;
            delta
        }
        None => payload_estimate,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PolicyKind;
    use std::collections::VecDeque;

    #[test]
    fn phase_wire_numbers_are_stable() {
        // the wire encoding is part of the request format: 0/1/2
        assert_eq!(Phase::Proactive.wire(), 0);
        assert_eq!(Phase::Detection.wire(), 1);
        assert_eq!(Phase::Reactive.wire(), 2);
    }

    #[test]
    fn observed_loss_counts_each_chunk_once() {
        // chunk 0 has r = 3 copies of loss 10.0, chunks 1..=2 have one
        // copy each of loss 1.0: the median must be 1.0 (per-chunk),
        // not 10.0 (per-copy, the pre-refactor bug)
        let mut round = RoundState::default();
        round.chunks = (0..3).map(|_| ChunkCopies::default()).collect();
        round.tampered_by_chunk = vec![Vec::new(); 3];
        let resp = |worker, chunk, loss| Response {
            worker,
            iter: 0,
            phase: 0,
            wave: 0,
            symbols: vec![Symbol { chunk, grad: vec![1.0], loss, tampered: false, wire: None }],
            error: None,
        };
        round.ingest(vec![
            resp(0, 0, 10.0),
            resp(1, 0, 10.0),
            resp(2, 0, 10.0),
            resp(1, 1, 1.0),
            resp(2, 2, 1.0),
        ]);
        let mut scratch = Vec::new();
        assert_eq!(round.observed_loss(&mut scratch), 1.0);
        assert_eq!(round.chunks[0].computed_copies, 3);
        assert_eq!(round.chunks[0].copies.len(), 3);
    }

    #[test]
    fn ingest_records_tamper_oracle() {
        let mut round = RoundState::default();
        round.chunks = vec![ChunkCopies::default()];
        round.tampered_by_chunk = vec![Vec::new()];
        round.ingest(vec![Response {
            worker: 4,
            iter: 0,
            phase: 0,
            wave: 0,
            symbols: vec![Symbol {
                chunk: 0,
                grad: vec![0.0],
                loss: 0.0,
                tampered: true,
                wire: None,
            }],
            error: None,
        }]);
        assert_eq!(round.tampered_by_chunk[0], vec![4]);
        assert_eq!(round.chosen(0).worker, 4);
    }

    // ------------------------- duplicated deliveries at wait_wave level

    /// Transport whose polls return a pre-scripted delivery sequence —
    /// exactly what a chaos-duplicated wire hands the protocol core.
    struct ScriptedTransport {
        n: usize,
        now: u64,
        script: VecDeque<Vec<Delivery>>,
    }

    impl Transport for ScriptedTransport {
        fn n(&self) -> usize {
            self.n
        }
        fn now_ns(&self) -> u64 {
            self.now
        }
        fn submit(
            &mut self,
            _iter: u64,
            _phase: u32,
            _wave: u64,
            _theta: &Arc<Vec<f32>>,
            _bundles: Vec<TaskBundle>,
        ) -> Result<()> {
            Ok(())
        }
        fn poll(&mut self, _deadline_ns: Option<u64>) -> Result<Vec<Delivery>> {
            self.now += 1_000_000;
            Ok(self.script.pop_front().unwrap_or_default())
        }
    }

    fn scripted_core(script: Vec<Vec<Delivery>>, gather: GatherPolicy) -> ProtocolCore {
        let transport = ScriptedTransport { n: 3, now: 0, script: script.into() };
        let policy = FaultCheckPolicy::new(PolicyKind::None, 3, 1);
        ProtocolCore::new(
            Box::new(transport),
            policy,
            ProtocolConfig {
                f: 1,
                seed: 1,
                chunk_size: 1,
                self_check: false,
                tol: 0.0,
                no_eliminate: false,
                compressor: None,
                gather,
                pipeline: 1,
            },
        )
    }

    fn resp(worker: WorkerId, wave: u64) -> Response {
        Response { worker, iter: 0, phase: 0, wave, symbols: Vec::new(), error: None }
    }

    fn delivered(at_ns: u64, worker: WorkerId, wave: u64) -> Delivery {
        Delivery::Response { at_ns, response: resp(worker, wave) }
    }

    /// A duplicated `Response` (chaos `dup`, or a resend answered
    /// twice) arriving after first-response-wins must not double-feed
    /// the latency EWMA: exactly one sample per worker per wave.
    #[test]
    fn duplicated_response_is_never_ingested_twice() {
        let wave = 7;
        let script = vec![
            vec![delivered(1_000, 0, wave), delivered(2_000, 1, wave)],
            // worker 1's response delivered again, then worker 2
            vec![delivered(3_000, 1, wave), delivered(4_000, 2, wave)],
        ];
        let mut core = scripted_core(script, GatherPolicy::All);
        let mut round = RoundState::default();
        let (mut crashed, mut stragglers) = (Vec::new(), Vec::new());
        let mut events = EventLog::default();
        let out = core
            .wait_wave(
                0,
                wave,
                GatherPolicy::All,
                1,
                vec![0, 1, 2],
                0,
                true,
                &mut round,
                &mut crashed,
                &mut stragglers,
                &mut events,
            )
            .unwrap();
        let workers: Vec<WorkerId> = out.iter().map(|r| r.worker).collect();
        assert_eq!(workers, vec![0, 1, 2], "one response per worker, duplicate discarded");
        for w in 0..3 {
            assert_eq!(
                core.policy.latency.profile(w).samples,
                1,
                "worker {w}: the duplicate must not double-feed the EWMA"
            );
        }
    }

    /// A duplicate must not count toward a quorum either: two copies of
    /// one worker's response are one responder, so the wave keeps
    /// waiting for a second distinct worker.
    #[test]
    fn duplicated_response_does_not_count_toward_the_quorum() {
        let wave = 9;
        let gather = GatherPolicy::Quorum { k: 2 };
        let script = vec![
            vec![delivered(1_000, 0, wave), delivered(1_500, 0, wave)],
            vec![delivered(2_000, 1, wave)],
        ];
        let mut core = scripted_core(script, gather);
        let mut round = RoundState::default();
        let (mut crashed, mut stragglers) = (Vec::new(), Vec::new());
        let mut events = EventLog::default();
        let out = core
            .wait_wave(
                0,
                wave,
                gather,
                1,
                vec![0, 1, 2],
                0,
                false,
                &mut round,
                &mut crashed,
                &mut stragglers,
                &mut events,
            )
            .unwrap();
        // had the duplicate counted, the wave would have closed after
        // the first poll with worker 0's response alone
        let workers: Vec<WorkerId> = out.iter().map(|r| r.worker).collect();
        assert_eq!(workers, vec![0, 1], "quorum of 2 means 2 distinct responders");
        assert_eq!(stragglers, vec![2], "the quorum exit abandons only the true laggard");
    }

    // --------------------------------- net byte accounting per round

    #[test]
    fn net_bytes_round_counts_retransmitted_bytes() {
        let mut baseline = 0u64;
        let r1 = net_bytes_round(
            Some(NetStats { bytes_tx: 100, bytes_rx: 50, reconnects: 0 }),
            &mut baseline,
            7,
        );
        assert_eq!(r1, 150);
        // a reconnect round: handshakes + resent frames inflate the
        // socket counters, and every one of those bytes is honest
        let r2 = net_bytes_round(
            Some(NetStats { bytes_tx: 300, bytes_rx: 80, reconnects: 1 }),
            &mut baseline,
            7,
        );
        assert_eq!(r2, 230, "retransmissions are honest wire bytes");
        assert_eq!(r1 + r2, 380, "per-round deltas sum to the counter total");
    }

    #[test]
    fn net_bytes_round_never_underflows_the_baseline() {
        // a baseline ahead of the counters (reconnect storm racing the
        // round boundary) must clamp to 0, not wrap to ~u64::MAX
        let mut baseline = 10_000u64;
        let r = net_bytes_round(
            Some(NetStats { bytes_tx: 100, bytes_rx: 0, reconnects: 3 }),
            &mut baseline,
            7,
        );
        assert_eq!(r, 0, "a counter behind the baseline yields 0, never a wrap");
        assert_eq!(baseline, 100, "the baseline resynchronizes to the counter");
        // in-process transports keep the payload-based estimate
        assert_eq!(net_bytes_round(None, &mut baseline, 7), 7);
        assert_eq!(baseline, 100, "the estimate path leaves the baseline alone");
    }
}
