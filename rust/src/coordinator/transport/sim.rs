//! Deterministic virtual-time transport: thousands of simulated
//! workers, zero OS threads.
//!
//! Workers are plain structs executed sequentially on the caller's
//! thread; *time* is a discrete-event virtual clock. Each response is
//! stamped with a completion time drawn from a configurable
//! [`LatencyModel`], scaled by per-worker straggler multipliers;
//! `gather` advances the clock to the slowest responder (the
//! synchronous-round semantics of the paper). Workers can crash-stop
//! at a configured iteration, after which they never respond and are
//! reported through [`Transport::take_failed`] so the protocol core
//! reassigns their chunks.
//!
//! Determinism: compute goes through the same
//! [`super::super::worker::WorkerState`] as the threaded transport and
//! responses are gathered sorted by worker id, so for zero latency and
//! no faults a sim run is bit-identical to a threaded run with the
//! same seed (asserted by `tests/test_transport.rs`).

use std::sync::Arc;
use std::time::Duration;

use super::super::byzantine::ByzantineBehavior;
use super::super::compress::Compressor;
use super::super::worker::{Response, WorkerState};
use super::super::WorkerId;
use super::{TaskBundle, Transport};
use crate::grad::GradientComputer;
use crate::util::rng::Pcg64;
use crate::Result;

/// Per-message latency distribution (virtual time).
#[derive(Clone, Copy, Debug)]
pub enum LatencyModel {
    /// No latency: pure protocol semantics (and bit-parity with the
    /// threaded transport at latency 0).
    Zero,
    /// Constant latency per message.
    Fixed { us: u64 },
    /// Uniform in [lo, hi].
    Uniform { lo_us: u64, hi_us: u64 },
    /// Exponential with the given mean (heavy-ish tail).
    Exp { mean_us: f64 },
}

impl LatencyModel {
    fn draw_ns(&self, rng: &mut Pcg64) -> u64 {
        match *self {
            LatencyModel::Zero => 0,
            LatencyModel::Fixed { us } => us * 1000,
            LatencyModel::Uniform { lo_us, hi_us } => {
                let span = hi_us.saturating_sub(lo_us);
                (lo_us + if span == 0 { 0 } else { rng.below(span + 1) }) * 1000
            }
            LatencyModel::Exp { mean_us } => {
                let u = rng.f64();
                (-(1.0 - u).ln() * mean_us * 1000.0) as u64
            }
        }
    }
}

/// Scenario description for a simulated cluster.
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Base per-message latency distribution.
    pub latency: LatencyModel,
    /// Per-worker latency multipliers (worker, factor): stragglers
    /// (factor > 1) or fast workers (factor < 1).
    pub stragglers: Vec<(WorkerId, f64)>,
    /// Crash-stop plan (worker, iteration): from that iteration on the
    /// worker never responds again.
    pub crash_at: Vec<(WorkerId, u64)>,
    /// Seed for the latency draws (independent of the protocol RNG).
    pub seed: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            latency: LatencyModel::Zero,
            stragglers: Vec::new(),
            crash_at: Vec::new(),
            seed: 0x51a7,
        }
    }
}

struct SimWorker {
    state: WorkerState,
    latency_mult: f64,
    crash_at: Option<u64>,
    crashed: bool,
}

/// The simulated cluster.
pub struct SimTransport {
    workers: Vec<SimWorker>,
    latency: LatencyModel,
    rng: Pcg64,
    /// Virtual clock (ns since construction).
    now_ns: u64,
    /// Responses awaiting the in-flight gather: (completion time, resp).
    ready: Vec<(u64, Response)>,
    newly_failed: Vec<WorkerId>,
    last_round_ns: u64,
}

impl SimTransport {
    /// Build `n` simulated workers (signature mirrors
    /// [`super::ThreadedTransport::spawn_with_compressor`]).
    pub fn new(
        n: usize,
        engine: Arc<dyn GradientComputer>,
        mut byzantine: impl FnMut(WorkerId) -> Option<ByzantineBehavior>,
        compressor: Option<Arc<dyn Compressor>>,
        cfg: SimConfig,
    ) -> SimTransport {
        let workers = (0..n)
            .map(|id| SimWorker {
                state: WorkerState::new(id, engine.clone(), byzantine(id), compressor.clone()),
                latency_mult: cfg
                    .stragglers
                    .iter()
                    .find(|(w, _)| *w == id)
                    .map(|(_, m)| *m)
                    .unwrap_or(1.0),
                crash_at: cfg.crash_at.iter().find(|(w, _)| *w == id).map(|(_, t)| *t),
                crashed: false,
            })
            .collect();
        SimTransport {
            workers,
            latency: cfg.latency,
            rng: Pcg64::new(cfg.seed, 0x51b_7a2),
            now_ns: 0,
            ready: Vec::new(),
            newly_failed: Vec::new(),
            last_round_ns: 0,
        }
    }

    /// Virtual time elapsed since construction.
    pub fn virtual_elapsed(&self) -> Duration {
        Duration::from_nanos(self.now_ns)
    }

    /// Virtual duration of the most recent gather's round (max over its
    /// responders' completion latencies).
    pub fn last_round(&self) -> Duration {
        Duration::from_nanos(self.last_round_ns)
    }
}

impl Transport for SimTransport {
    fn n(&self) -> usize {
        self.workers.len()
    }

    fn scatter(
        &mut self,
        iter: u64,
        phase: u32,
        theta: &Arc<Vec<f32>>,
        bundles: Vec<TaskBundle>,
    ) -> Result<()> {
        for TaskBundle { worker, tasks } in bundles {
            anyhow::ensure!(worker < self.workers.len(), "scatter to unknown worker {worker}");
            let w = &mut self.workers[worker];
            if w.crashed || w.crash_at.map(|t| iter >= t).unwrap_or(false) {
                if !w.crashed {
                    w.crashed = true;
                    self.newly_failed.push(worker);
                }
                continue; // crash-stop: the message disappears
            }
            let symbols = w.state.handle(iter, theta, tasks)?;
            let latency =
                (self.latency.draw_ns(&mut self.rng) as f64 * w.latency_mult) as u64;
            self.ready.push((
                self.now_ns + latency,
                Response { worker, iter, phase, symbols, error: None },
            ));
        }
        Ok(())
    }

    fn gather(&mut self, iter: u64, phase: u32) -> Result<Vec<Response>> {
        let mut out: Vec<(u64, Response)> = Vec::with_capacity(self.ready.len());
        // the synchronous protocol has exactly one phase in flight;
        // filter defensively anyway
        let mut i = 0;
        while i < self.ready.len() {
            if self.ready[i].1.iter == iter && self.ready[i].1.phase == phase {
                out.push(self.ready.swap_remove(i));
            } else {
                i += 1;
            }
        }
        // the round ends when the slowest responder finishes
        let end = out.iter().map(|(t, _)| *t).max().unwrap_or(self.now_ns);
        self.last_round_ns = end - self.now_ns;
        self.now_ns = end;
        let mut responses: Vec<Response> = out.into_iter().map(|(_, r)| r).collect();
        responses.sort_by_key(|r| r.worker);
        Ok(responses)
    }

    fn take_failed(&mut self) -> Vec<WorkerId> {
        std::mem::take(&mut self.newly_failed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Dataset, LinRegDataset};
    use crate::grad::{GradientComputer, ModelSpec, NativeEngine};

    fn cluster(n: usize, cfg: SimConfig) -> (SimTransport, LinRegDataset) {
        let ds = LinRegDataset::generate(64, 8, 0.0, 1);
        let engine: Arc<dyn GradientComputer> =
            Arc::new(NativeEngine::new(ModelSpec::LinReg { d: 8, batch: 64 }));
        (SimTransport::new(n, engine, |_| None, None, cfg), ds)
    }

    fn bundles(ds: &LinRegDataset, workers: &[WorkerId]) -> Vec<TaskBundle> {
        let batch = ds.batch(&(0..16).collect::<Vec<_>>());
        workers
            .iter()
            .map(|&w| TaskBundle { worker: w, tasks: vec![(w, batch.clone())] })
            .collect()
    }

    #[test]
    fn zero_latency_round_takes_no_virtual_time() {
        let (mut t, ds) = cluster(4, SimConfig::default());
        let theta = Arc::new(vec![0.1f32; 8]);
        t.scatter(0, 0, &theta, bundles(&ds, &[0, 1, 2, 3])).unwrap();
        let resps = t.gather(0, 0).unwrap();
        assert_eq!(resps.len(), 4);
        assert_eq!(t.virtual_elapsed(), Duration::ZERO);
        let ids: Vec<WorkerId> = resps.iter().map(|r| r.worker).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn straggler_dominates_round_time() {
        let cfg = SimConfig {
            latency: LatencyModel::Fixed { us: 100 },
            stragglers: vec![(2, 50.0)],
            ..Default::default()
        };
        let (mut t, ds) = cluster(4, cfg);
        let theta = Arc::new(vec![0.1f32; 8]);
        t.scatter(0, 0, &theta, bundles(&ds, &[0, 1, 2, 3])).unwrap();
        let resps = t.gather(0, 0).unwrap();
        assert_eq!(resps.len(), 4);
        // round time = straggler's 100us * 50 = 5ms, not the 100us base
        assert_eq!(t.last_round(), Duration::from_micros(5000));
        assert_eq!(t.virtual_elapsed(), Duration::from_micros(5000));
    }

    #[test]
    fn crashed_worker_stops_responding_and_is_reported() {
        let cfg = SimConfig { crash_at: vec![(1, 2)], ..Default::default() };
        let (mut t, ds) = cluster(3, cfg);
        let theta = Arc::new(vec![0.1f32; 8]);
        for iter in 0..4u64 {
            t.scatter(iter, 0, &theta, bundles(&ds, &[0, 1, 2])).unwrap();
            let resps = t.gather(iter, 0).unwrap();
            if iter < 2 {
                assert_eq!(resps.len(), 3, "iter {iter}");
                assert!(t.take_failed().is_empty());
            } else {
                assert_eq!(resps.len(), 2, "iter {iter}");
                let failed = t.take_failed();
                if iter == 2 {
                    assert_eq!(failed, vec![1]);
                } else {
                    assert!(failed.is_empty(), "crash reported once");
                }
            }
        }
    }

    #[test]
    fn uniform_and_exp_latency_advance_the_clock() {
        for latency in [
            LatencyModel::Uniform { lo_us: 10, hi_us: 20 },
            LatencyModel::Exp { mean_us: 15.0 },
        ] {
            let cfg = SimConfig { latency, ..Default::default() };
            let (mut t, ds) = cluster(2, cfg);
            let theta = Arc::new(vec![0.1f32; 8]);
            t.scatter(0, 0, &theta, bundles(&ds, &[0, 1])).unwrap();
            t.gather(0, 0).unwrap();
            assert!(t.virtual_elapsed() > Duration::ZERO, "{latency:?}");
        }
    }

    #[test]
    fn thousand_workers_no_threads() {
        // n = 2048 simulated workers on the caller's thread: the whole
        // point of the simulator. (Each worker gets a tiny task.)
        let (mut t, ds) = cluster(2048, SimConfig::default());
        let theta = Arc::new(vec![0.1f32; 8]);
        let all: Vec<WorkerId> = (0..2048).collect();
        t.scatter(0, 0, &theta, bundles(&ds, &all)).unwrap();
        let resps = t.gather(0, 0).unwrap();
        assert_eq!(resps.len(), 2048);
    }
}
