//! Deterministic virtual-time transport: thousands of simulated
//! workers, zero OS threads.
//!
//! Workers are plain structs executed sequentially on the caller's
//! thread; *time* is a discrete-event virtual clock. [`Transport::submit`]
//! computes each targeted worker's symbols immediately and stamps the
//! resulting [`Delivery`] with a completion time drawn from a
//! configurable [`LatencyModel`], scaled by per-worker straggler
//! multipliers whose schedule a [`StragglerModel`] controls (always
//! on, per-worker time-varying bursts, or correlated group bursts —
//! the adversarial timing scenarios the latency-aware audit policy is
//! measured against). [`Transport::poll`] advances the clock to the earliest
//! pending completion and returns every delivery due at that instant —
//! so a quorum gather stops the clock at the k-th arrival instead of
//! the slowest worker, and an abandoned straggler's delivery stays
//! queued until a later poll drains it. Workers can crash-stop at a
//! configured iteration, after which every submit to them yields a
//! [`Delivery::Failed`] instead of a response.
//!
//! Determinism: compute goes through the same
//! [`super::super::worker::WorkerState`] as the threaded transport,
//! deliveries sharing an arrival instant are returned sorted by worker
//! id, and at zero latency *every* delivery of a wave shares the
//! submit instant — one poll returns the whole wave, bit-identical to
//! a threaded run with the same seed (asserted by
//! `tests/test_transport.rs`).

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::Arc;
use std::time::Duration;

use super::super::byzantine::ByzantineBehavior;
use super::super::compress::Compressor;
use super::super::worker::{Response, WorkerState};
use super::super::WorkerId;
use super::{AdversaryWiring, Delivery, TaskBundle, Transport};
use crate::grad::GradientComputer;
use crate::util::rng::Pcg64;
use crate::Result;

/// Per-message latency distribution (virtual time).
#[derive(Clone, Copy, Debug)]
pub enum LatencyModel {
    /// No latency: pure protocol semantics (and bit-parity with the
    /// threaded transport at latency 0).
    Zero,
    /// Constant latency per message.
    Fixed { us: u64 },
    /// Uniform in [lo, hi].
    Uniform { lo_us: u64, hi_us: u64 },
    /// Exponential with the given mean (heavy-ish tail).
    Exp { mean_us: f64 },
}

impl LatencyModel {
    fn draw_ns(&self, rng: &mut Pcg64) -> u64 {
        match *self {
            LatencyModel::Zero => 0,
            LatencyModel::Fixed { us } => us * 1000,
            LatencyModel::Uniform { lo_us, hi_us } => {
                let span = hi_us.saturating_sub(lo_us);
                (lo_us + if span == 0 { 0 } else { rng.below(span + 1) }) * 1000
            }
            LatencyModel::Exp { mean_us } => {
                let u = rng.f64();
                (-(1.0 - u).ln() * mean_us * 1000.0) as u64
            }
        }
    }
}

/// When a configured straggler's latency multiplier applies —
/// adversarial timing scenarios for the latency-aware audit policies.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum StragglerModel {
    /// The multiplier applies in every iteration (the original static
    /// straggler; the default).
    #[default]
    Fixed,
    /// Time-varying stragglers: worker w is slow only during its own
    /// bursts — iterations where `(iter + w) % period < duty`. Each
    /// straggler's burst window is phase-shifted by its id, so the
    /// bursts are *independent*: the adversarial case for an EWMA
    /// profile, which must both catch the bursts and shed the
    /// suspicion between them.
    TimeVarying { period: u64, duty: u64 },
    /// Correlated stragglers: every configured straggler is slow in
    /// the same iterations — `iter % period < duty` — as when the
    /// slow workers share a machine or network link. Stress-tests the
    /// cluster-median anomaly baseline: a whole slow *group* shifts
    /// per-round timing together without any single worker drifting
    /// from the group.
    Correlated { period: u64, duty: u64 },
}

impl StragglerModel {
    /// Is `worker`'s multiplier in force at iteration `iter`?
    /// A non-positive `period` never activates (duty 0 likewise).
    pub fn active(self, worker: WorkerId, iter: u64) -> bool {
        match self {
            StragglerModel::Fixed => true,
            StragglerModel::TimeVarying { period, duty } => {
                period > 0 && (iter + worker as u64) % period < duty
            }
            StragglerModel::Correlated { period, duty } => period > 0 && iter % period < duty,
        }
    }
}

/// Scenario description for a simulated cluster.
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Base per-message latency distribution.
    pub latency: LatencyModel,
    /// Per-worker latency multipliers (worker, factor): stragglers
    /// (factor > 1) or fast workers (factor < 1).
    pub stragglers: Vec<(WorkerId, f64)>,
    /// When the straggler multipliers apply (always, in per-worker
    /// bursts, or in correlated bursts).
    pub straggler_model: StragglerModel,
    /// Crash-stop plan (worker, iteration): from that iteration on the
    /// worker never responds again.
    pub crash_at: Vec<(WorkerId, u64)>,
    /// Seed for the latency draws (independent of the protocol RNG).
    pub seed: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            latency: LatencyModel::Zero,
            stragglers: Vec::new(),
            straggler_model: StragglerModel::Fixed,
            crash_at: Vec::new(),
            seed: 0x51a7,
        }
    }
}

struct SimWorker {
    state: WorkerState,
    latency_mult: f64,
    crash_at: Option<u64>,
    crashed: bool,
}

/// A completed-but-undelivered exchange, ordered by (arrival instant,
/// worker id) so the event heap pops deliveries in exactly the order
/// `poll` hands them out.
struct PendingEvent {
    at_ns: u64,
    worker: WorkerId,
    delivery: Delivery,
}

impl PartialEq for PendingEvent {
    fn eq(&self, other: &PendingEvent) -> bool {
        (self.at_ns, self.worker) == (other.at_ns, other.worker)
    }
}

impl Eq for PendingEvent {}

impl PartialOrd for PendingEvent {
    fn partial_cmp(&self, other: &PendingEvent) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for PendingEvent {
    fn cmp(&self, other: &PendingEvent) -> std::cmp::Ordering {
        (self.at_ns, self.worker).cmp(&(other.at_ns, other.worker))
    }
}

/// The simulated cluster.
pub struct SimTransport {
    workers: Vec<SimWorker>,
    latency: LatencyModel,
    straggler_model: StragglerModel,
    rng: Pcg64,
    /// Virtual clock (ns since construction).
    now_ns: u64,
    /// Discrete-event queue: completed exchanges awaiting delivery,
    /// min-ordered by (arrival instant, worker id) so each `poll` is
    /// O(log n) per delivery instead of a linear scan.
    pending: BinaryHeap<Reverse<PendingEvent>>,
    /// Coordinated-adversary wiring: colluders may fake extra
    /// per-response stalls (latency mimicry) on top of the drawn
    /// latency.
    adversary: Option<AdversaryWiring>,
}

impl SimTransport {
    /// Build `n` simulated workers (signature mirrors
    /// [`super::ThreadedTransport::spawn_with_compressor`]).
    pub fn new(
        n: usize,
        engine: Arc<dyn GradientComputer>,
        mut byzantine: impl FnMut(WorkerId) -> Option<ByzantineBehavior>,
        compressor: Option<Arc<dyn Compressor>>,
        cfg: SimConfig,
    ) -> SimTransport {
        Self::new_full(n, engine, &mut byzantine, compressor, cfg, None)
    }

    /// Build with every knob, including the coordinated-adversary
    /// wiring (mirrors [`super::ThreadedTransport::spawn_full`]).
    pub fn new_full(
        n: usize,
        engine: Arc<dyn GradientComputer>,
        mut byzantine: impl FnMut(WorkerId) -> Option<ByzantineBehavior>,
        compressor: Option<Arc<dyn Compressor>>,
        cfg: SimConfig,
        adversary: Option<AdversaryWiring>,
    ) -> SimTransport {
        let workers = (0..n)
            .map(|id| {
                let state =
                    WorkerState::new(id, engine.clone(), byzantine(id), compressor.clone())
                        .with_adversary(adversary.as_ref().and_then(|aw| aw.handle(id)));
                SimWorker {
                    state,
                    latency_mult: cfg
                        .stragglers
                        .iter()
                        .find(|(w, _)| *w == id)
                        .map(|(_, m)| *m)
                        .unwrap_or(1.0),
                    crash_at: cfg.crash_at.iter().find(|(w, _)| *w == id).map(|(_, t)| *t),
                    crashed: false,
                }
            })
            .collect();
        SimTransport {
            workers,
            latency: cfg.latency,
            straggler_model: cfg.straggler_model,
            rng: Pcg64::new(cfg.seed, 0x51b_7a2),
            now_ns: 0,
            pending: BinaryHeap::new(),
            adversary,
        }
    }

    /// Virtual time elapsed since construction.
    pub fn virtual_elapsed(&self) -> Duration {
        Duration::from_nanos(self.now_ns)
    }
}

impl Transport for SimTransport {
    fn n(&self) -> usize {
        self.workers.len()
    }

    fn now_ns(&self) -> u64 {
        self.now_ns
    }

    fn submit(
        &mut self,
        iter: u64,
        phase: u32,
        wave: u64,
        theta: &Arc<Vec<f32>>,
        bundles: Vec<TaskBundle>,
    ) -> Result<()> {
        for TaskBundle { worker, tasks } in bundles {
            anyhow::ensure!(worker < self.workers.len(), "submit to unknown worker {worker}");
            let w = &mut self.workers[worker];
            if w.crashed || w.crash_at.map(|t| iter >= t).unwrap_or(false) {
                // crash-stop: the request disappears and the failure is
                // reported in-band at the current instant
                w.crashed = true;
                self.pending.push(Reverse(PendingEvent {
                    at_ns: self.now_ns,
                    worker,
                    delivery: Delivery::Failed { at_ns: self.now_ns, worker },
                }));
                continue;
            }
            let symbols = w.state.handle(iter, theta, tasks)?;
            let mult = if self.straggler_model.active(worker, iter) {
                w.latency_mult
            } else {
                1.0
            };
            let latency = (self.latency.draw_ns(&mut self.rng) as f64 * mult) as u64;
            // coordinated adversaries may fake an extra stall on top of
            // the drawn latency (latency mimicry — see crate::adversary);
            // the lock-free colluder check keeps the honest-worker path
            // off the controller mutex entirely
            let stall = match &self.adversary {
                Some(aw) if aw.controller.is_colluder(aw.lo + worker) => {
                    aw.controller.response_delay_ns(aw.lo + worker, iter)
                }
                _ => 0,
            };
            let at_ns = self.now_ns + latency + stall;
            self.pending.push(Reverse(PendingEvent {
                at_ns,
                worker,
                delivery: Delivery::Response {
                    at_ns,
                    response: Response { worker, iter, phase, wave, symbols, error: None },
                },
            }));
        }
        Ok(())
    }

    fn poll(&mut self, deadline_ns: Option<u64>) -> Result<Vec<Delivery>> {
        let next = match self.pending.peek() {
            Some(Reverse(e)) => e.at_ns,
            None => {
                // nothing in flight; a deadline wait still spends the time
                if let Some(d) = deadline_ns {
                    self.now_ns = self.now_ns.max(d);
                }
                return Ok(Vec::new());
            }
        };
        if let Some(d) = deadline_ns {
            if next > d {
                self.now_ns = self.now_ns.max(d);
                return Ok(Vec::new());
            }
        }
        self.now_ns = self.now_ns.max(next);
        // pop everything due at this instant: the heap yields them in
        // worker-id order, which is the delivery order contract
        let mut out: Vec<Delivery> = Vec::new();
        while let Some(Reverse(e)) = self.pending.peek() {
            if e.at_ns != next {
                break;
            }
            let Reverse(e) = self.pending.pop().expect("peeked entry present");
            out.push(e.delivery);
        }
        Ok(out)
    }

    fn shutdown(&mut self) {
        self.pending.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Dataset, LinRegDataset};
    use crate::grad::{GradientComputer, ModelSpec, NativeEngine};

    fn cluster(n: usize, cfg: SimConfig) -> (SimTransport, LinRegDataset) {
        let ds = LinRegDataset::generate(64, 8, 0.0, 1);
        let engine: Arc<dyn GradientComputer> =
            Arc::new(NativeEngine::new(ModelSpec::LinReg { d: 8, batch: 64 }));
        (SimTransport::new(n, engine, |_| None, None, cfg), ds)
    }

    fn bundles(ds: &LinRegDataset, workers: &[WorkerId]) -> Vec<TaskBundle> {
        let batch = ds.batch(&(0..16).collect::<Vec<_>>());
        workers
            .iter()
            .map(|&w| TaskBundle { worker: w, tasks: vec![(w, batch.clone())] })
            .collect()
    }

    /// Drain everything in flight, appending to `out`; returns the
    /// number of deliveries consumed.
    fn drain(t: &mut SimTransport, out: &mut Vec<Delivery>) -> usize {
        let mut n = 0;
        loop {
            let batch = t.poll(None).unwrap();
            if batch.is_empty() {
                return n;
            }
            n += batch.len();
            out.extend(batch);
        }
    }

    #[test]
    fn zero_latency_wave_arrives_in_one_poll_sorted() {
        let (mut t, ds) = cluster(4, SimConfig::default());
        let theta = Arc::new(vec![0.1f32; 8]);
        t.submit(0, 0, 0, &theta, bundles(&ds, &[0, 1, 2, 3])).unwrap();
        let batch = t.poll(None).unwrap();
        assert_eq!(batch.len(), 4, "zero latency: the whole wave shares one instant");
        let ids: Vec<WorkerId> = batch.iter().map(|d| d.worker()).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
        assert_eq!(t.virtual_elapsed(), Duration::ZERO);
        assert!(t.poll(None).unwrap().is_empty(), "nothing left in flight");
    }

    #[test]
    fn time_varying_straggler_is_slow_only_in_its_bursts() {
        // worker 1 with a 50x multiplier under TimeVarying{period:4,
        // duty:2}: slow when (iter + 1) % 4 < 2, i.e. iters 0, 3, 4,
        // 7, ... — and at full speed in between
        let cfg = SimConfig {
            latency: LatencyModel::Fixed { us: 100 },
            stragglers: vec![(1, 50.0)],
            straggler_model: StragglerModel::TimeVarying { period: 4, duty: 2 },
            ..Default::default()
        };
        let (mut t, ds) = cluster(2, cfg);
        let theta = Arc::new(vec![0.1f32; 8]);
        for iter in 0..8u64 {
            let before = t.now_ns();
            t.submit(iter, 0, iter, &theta, bundles(&ds, &[0, 1])).unwrap();
            let mut all = Vec::new();
            drain(&mut t, &mut all);
            assert_eq!(all.len(), 2);
            let round_us = (t.now_ns() - before) / 1000;
            let slow = (iter + 1) % 4 < 2;
            assert_eq!(round_us, if slow { 5000 } else { 100 }, "iter {iter}");
        }
    }

    #[test]
    fn correlated_stragglers_burst_together() {
        // both stragglers slow in the same iterations (iter % 2 == 0)
        let cfg = SimConfig {
            latency: LatencyModel::Fixed { us: 100 },
            stragglers: vec![(0, 10.0), (2, 10.0)],
            straggler_model: StragglerModel::Correlated { period: 2, duty: 1 },
            ..Default::default()
        };
        let (mut t, ds) = cluster(3, cfg);
        let theta = Arc::new(vec![0.1f32; 8]);
        for iter in 0..4u64 {
            let before = t.now_ns();
            t.submit(iter, 0, iter, &theta, bundles(&ds, &[0, 1, 2])).unwrap();
            // first instant is always the healthy worker 1 at 100us
            let first = t.poll(None).unwrap();
            let mut all = first;
            drain(&mut t, &mut all);
            assert_eq!(all.len(), 3);
            let round_us = (t.now_ns() - before) / 1000;
            let slow = iter % 2 == 0;
            assert_eq!(round_us, if slow { 1000 } else { 100 }, "iter {iter}");
        }
    }

    #[test]
    fn straggler_model_schedules() {
        assert!(StragglerModel::Fixed.active(3, 17));
        let tv = StragglerModel::TimeVarying { period: 4, duty: 1 };
        // worker 0 slow at iters 0,4,8...; worker 2 at iters 2,6,10...
        assert!(tv.active(0, 0) && tv.active(0, 4) && !tv.active(0, 1));
        assert!(tv.active(2, 2) && !tv.active(2, 0));
        let co = StragglerModel::Correlated { period: 4, duty: 1 };
        for w in 0..8 {
            assert!(co.active(w, 0) && co.active(w, 4) && !co.active(w, 1));
        }
        // degenerate periods never activate
        assert!(!StragglerModel::TimeVarying { period: 0, duty: 0 }.active(0, 0));
        assert!(!StragglerModel::Correlated { period: 4, duty: 0 }.active(0, 0));
    }

    #[test]
    fn straggler_arrives_last_and_dominates_the_clock() {
        let cfg = SimConfig {
            latency: LatencyModel::Fixed { us: 100 },
            stragglers: vec![(2, 50.0)],
            ..Default::default()
        };
        let (mut t, ds) = cluster(4, cfg);
        let theta = Arc::new(vec![0.1f32; 8]);
        t.submit(0, 0, 0, &theta, bundles(&ds, &[0, 1, 2, 3])).unwrap();
        // first instant: the three normal workers at 100us
        let first = t.poll(None).unwrap();
        assert_eq!(first.iter().map(|d| d.worker()).collect::<Vec<_>>(), vec![0, 1, 3]);
        assert_eq!(t.virtual_elapsed(), Duration::from_micros(100));
        // a quorum caller could stop here; draining instead advances to
        // the straggler's 100us * 50 = 5ms completion
        let late = t.poll(None).unwrap();
        assert_eq!(late.iter().map(|d| d.worker()).collect::<Vec<_>>(), vec![2]);
        assert_eq!(t.virtual_elapsed(), Duration::from_micros(5000));
    }

    #[test]
    fn deadline_poll_stops_the_clock_short() {
        let cfg = SimConfig { latency: LatencyModel::Fixed { us: 100 }, ..Default::default() };
        let (mut t, ds) = cluster(2, cfg);
        let theta = Arc::new(vec![0.1f32; 8]);
        t.submit(0, 0, 0, &theta, bundles(&ds, &[0, 1])).unwrap();
        // deadline before the 100us completions: empty batch, clock at
        // the deadline, deliveries still pending
        let early = t.poll(Some(40_000)).unwrap();
        assert!(early.is_empty());
        assert_eq!(t.virtual_elapsed(), Duration::from_micros(40));
        let rest = t.poll(None).unwrap();
        assert_eq!(rest.len(), 2);
        assert_eq!(t.virtual_elapsed(), Duration::from_micros(100));
    }

    #[test]
    fn crashed_worker_fails_in_band_every_submit() {
        let cfg = SimConfig { crash_at: vec![(1, 2)], ..Default::default() };
        let (mut t, ds) = cluster(3, cfg);
        let theta = Arc::new(vec![0.1f32; 8]);
        for iter in 0..4u64 {
            t.submit(iter, 0, iter, &theta, bundles(&ds, &[0, 1, 2])).unwrap();
            let mut all = Vec::new();
            drain(&mut t, &mut all);
            let failed: Vec<WorkerId> = all
                .iter()
                .filter(|d| matches!(d, Delivery::Failed { .. }))
                .map(|d| d.worker())
                .collect();
            let ok = all.len() - failed.len();
            if iter < 2 {
                assert_eq!(ok, 3, "iter {iter}");
                assert!(failed.is_empty());
            } else {
                assert_eq!(ok, 2, "iter {iter}");
                assert_eq!(failed, vec![1], "in-band failure, every submit");
            }
        }
    }

    #[test]
    fn uniform_and_exp_latency_advance_the_clock() {
        for latency in [
            LatencyModel::Uniform { lo_us: 10, hi_us: 20 },
            LatencyModel::Exp { mean_us: 15.0 },
        ] {
            let cfg = SimConfig { latency, ..Default::default() };
            let (mut t, ds) = cluster(2, cfg);
            let theta = Arc::new(vec![0.1f32; 8]);
            t.submit(0, 0, 0, &theta, bundles(&ds, &[0, 1])).unwrap();
            let mut all = Vec::new();
            drain(&mut t, &mut all);
            assert_eq!(all.len(), 2);
            assert!(t.virtual_elapsed() > Duration::ZERO, "{latency:?}");
        }
    }

    #[test]
    fn thousand_workers_no_threads() {
        // n = 2048 simulated workers on the caller's thread: the whole
        // point of the simulator. (Each worker gets a tiny task.)
        let (mut t, ds) = cluster(2048, SimConfig::default());
        let theta = Arc::new(vec![0.1f32; 8]);
        let all: Vec<WorkerId> = (0..2048).collect();
        t.submit(0, 0, 0, &theta, bundles(&ds, &all)).unwrap();
        let mut got = Vec::new();
        assert_eq!(drain(&mut t, &mut got), 2048);
    }
}
