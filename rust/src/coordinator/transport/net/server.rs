//! Worker-side TCP server: a standalone process hosting the same
//! [`WorkerState`] compute core the in-process transports drive.
//!
//! `r3bft worker --listen ADDR` binds a listener and calls
//! [`serve`]. One master session is served at a time: the master's
//! [`Hello`](super::frame::Hello) carries everything needed to build
//! the worker bit-identically to its in-process twin — ids, seed,
//! scripted attack, compressor spec, model — so a loopback net run
//! reproduces a threaded/sim run exactly.
//!
//! Reconnect semantics: a dropped connection sends [`serve`] back to
//! `accept`. If the next session's hello matches the previous one,
//! the existing [`WorkerState`] is **reused**, preserving the
//! Byzantine RNG stream and the per-iteration tamper cache across
//! the reconnect (the master resends unanswered requests; honest
//! recomputation is deterministic). A hello for a different
//! configuration rebuilds the state from scratch. A
//! [`Frame::Shutdown`] ends the process's serve loop.

use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use super::super::super::byzantine::ByzantineBehavior;
use super::super::super::compress;
use super::super::super::worker::WorkerState;
use super::frame::{read_frame, write_frame, Frame, Hello, NetGrad, NetResponse, NetSymbol};
use crate::grad::{GradientComputer, NativeEngine};
use crate::Result;

enum SessionEnd {
    /// Master went away (EOF or torn frame): await a reconnect.
    Eof,
    /// Master said shutdown: stop serving.
    Shutdown,
}

/// Worker state kept across master reconnects, keyed by the hello
/// that built it.
struct Persistent {
    hello: Hello,
    state: WorkerState,
}

/// Accept loop: serve master sessions until a shutdown frame arrives.
/// Blocks the calling thread; run-from-test harnesses call this on a
/// listener bound to `127.0.0.1:0` in a spawned thread.
pub fn serve(listener: TcpListener) -> Result<()> {
    let mut persistent: Option<Persistent> = None;
    for stream in listener.incoming() {
        let stream = match stream {
            Ok(s) => s,
            Err(e) => {
                log::warn!("worker accept failed: {e}");
                continue;
            }
        };
        match serve_session(stream, &mut persistent) {
            Ok(SessionEnd::Shutdown) => return Ok(()),
            Ok(SessionEnd::Eof) => continue, // master may reconnect
            Err(e) => {
                log::warn!("worker session error: {e:#}");
                continue;
            }
        }
    }
    Ok(())
}

/// Build the compute state a hello describes — the exact construction
/// path `ThreadedTransport::spawn_full` uses in-process.
fn build_state(hello: &Hello) -> Result<WorkerState> {
    let engine: Arc<dyn GradientComputer> = Arc::new(NativeEngine::new(hello.model.clone()));
    let byzantine = hello
        .byzantine
        .as_ref()
        .map(|a| ByzantineBehavior::new(a.clone(), hello.seed, hello.global_id as usize));
    let compressor = match &hello.compressor {
        Some(spec) => Some(compress::parse(spec)?),
        None => None,
    };
    Ok(WorkerState::new(hello.local_id as usize, engine, byzantine, compressor))
}

fn serve_session(stream: TcpStream, persistent: &mut Option<Persistent>) -> Result<SessionEnd> {
    let _ = stream.set_nodelay(true);
    let mut w = stream.try_clone()?;
    let mut r = BufReader::new(stream);
    // session preamble: Hello (or an immediate Shutdown)
    let hello = match read_frame(&mut r)? {
        None => return Ok(SessionEnd::Eof),
        Some((Frame::Hello(h), _)) => h,
        Some((Frame::Shutdown, _)) => return Ok(SessionEnd::Shutdown),
        Some(_) => anyhow::bail!("session did not start with a hello"),
    };
    let same = persistent.as_ref().map(|p| p.hello == hello).unwrap_or(false);
    if !same {
        *persistent = Some(Persistent { state: build_state(&hello)?, hello: hello.clone() });
    }
    write_frame(&mut w, &Frame::HelloAck { global_id: hello.global_id })?;
    let p = persistent.as_mut().expect("state built above");
    loop {
        match read_frame(&mut r)? {
            None => return Ok(SessionEnd::Eof),
            Some((Frame::Shutdown, _)) => return Ok(SessionEnd::Shutdown),
            Some((Frame::Request(req), _)) => {
                if hello.latency_us > 0 {
                    std::thread::sleep(std::time::Duration::from_micros(hello.latency_us));
                }
                let tasks: Vec<(usize, crate::data::Batch)> =
                    req.tasks.into_iter().map(|(c, b)| (c as usize, b)).collect();
                // a panic must become an error response, not a dead
                // process: the master counts one delivery per request
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    p.state.handle(req.iter, &req.theta, tasks)
                }));
                let error = match &result {
                    Ok(Ok(_)) => None,
                    Ok(Err(e)) => Some(format!("{e:#}")),
                    Err(panic) => Some(
                        panic
                            .downcast_ref::<String>()
                            .cloned()
                            .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                            .unwrap_or_else(|| "worker panicked".into()),
                    ),
                };
                let symbols = match result {
                    Ok(Ok(symbols)) => symbols
                        .into_iter()
                        .map(|s| NetSymbol {
                            chunk: s.chunk as u64,
                            loss: s.loss,
                            tampered: s.tampered,
                            grad: match s.wire {
                                Some(wire) => NetGrad::Wire(wire),
                                None => NetGrad::Dense(s.grad),
                            },
                        })
                        .collect(),
                    _ => vec![],
                };
                let resp = NetResponse {
                    seq: req.seq,
                    worker: hello.local_id,
                    iter: req.iter,
                    phase: req.phase,
                    wave: req.wave,
                    error,
                    symbols,
                };
                write_frame(&mut w, &Frame::Response(resp))?;
            }
            Some(_) => anyhow::bail!("unexpected frame mid-session"),
        }
    }
}
