//! Worker-side TCP server: a standalone process hosting the same
//! [`WorkerState`] compute core the in-process transports drive.
//!
//! `r3bft worker --listen ADDR` binds a listener and calls
//! [`serve`]. One master session is served at a time: the master's
//! [`Hello`](super::frame::Hello) carries everything needed to build
//! the worker bit-identically to its in-process twin — ids, seed,
//! scripted attack, compressor spec, model — so a loopback net run
//! reproduces a threaded/sim run exactly.
//!
//! Reconnect semantics: a dropped connection sends [`serve`] back to
//! `accept`. If the next session's hello matches the previous one,
//! the existing [`WorkerState`] is **reused**, preserving the
//! Byzantine RNG stream and the per-iteration tamper cache across
//! the reconnect (the master resends unanswered requests; honest
//! recomputation is deterministic). A hello for a different
//! configuration rebuilds the state from scratch. A
//! [`Frame::Shutdown`] ends the process's serve loop.
//!
//! With [`ServeOptions::auth`] set (`--auth-key`), every frame must
//! carry a valid MAC: a hello from a master that does not share the
//! key fails verification **before** any worker state is built, and
//! the session is refused. With [`ServeOptions::chaos`] set the
//! worker's response writes pass through a seeded
//! [`ChaosLink`](super::chaos::ChaosLink) (stream keyed by the
//! hello's run seed + global id, [`CHANNEL_WORKER_SEND`]), so both
//! directions of a link can be made hostile. The handshake ack is
//! exempt, mirroring the master side.
//!
//! ## Worker-side telemetry
//!
//! When the hello carries `telemetry = true` the process runs its own
//! lightweight recorder ([`WorkerTelemetry`]): per-chunk gradient
//! compute spans, frame decode/encode time, duplicate-request (chaos
//! resend) observations, MAC-reject and undecodable-frame counts, and
//! a span-queue high-water mark, all on a monotonic per-process clock.
//! After every response it ships one bounded
//! [`TelemetryBatch`](super::frame::TelemetryBatch) frame carrying the
//! request's `(recv, send)` clock pair — the NTP t1/t2 sample the
//! master's per-link offset EWMA feeds on. Telemetry frames bypass the
//! chaos link (control plane, like the handshake) so an opted-in run
//! draws exactly the chaos coins a telemetry-off run draws — the
//! bit-identity contract is untouched. With telemetry off the request
//! path is byte-for-byte the PR 8/9 one.

use std::collections::BTreeSet;
use std::io::{BufReader, Read};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Instant;

use super::super::super::byzantine::ByzantineBehavior;
use super::super::super::compress;
use super::super::super::worker::WorkerState;
use super::chaos::{ChaosLink, ChaosSpec, CHANNEL_WORKER_SEND};
use super::frame::{
    decode_body_auth, encode_frame, read_raw_body, write_frame_auth, AuthKey, Frame, Hello,
    NetGrad, NetResponse, NetSymbol, TelemetryBatch, TelemetrySpan, SPAN_COMPUTE, SPAN_DECODE,
    SPAN_ENCODE,
};
use super::{send_wire, SleepFn};
use crate::grad::{GradientComputer, NativeEngine};
use crate::Result;

/// Worker-side hardening knobs, from `r3bft worker` flags.
#[derive(Default)]
pub struct ServeOptions {
    /// Shared frame-authentication key (None = legacy wire).
    pub auth: Option<AuthKey>,
    /// Fault injection on this worker's response writes (None =
    /// clean wire). Seeded from the master's hello, so the storm is
    /// replayable from the run seed like every other link.
    pub chaos: Option<ChaosSpec>,
}

/// Spans per [`TelemetryBatch`] are bounded: a request that somehow
/// accumulates more drops the excess and counts it in `dropped_spans`
/// instead of growing the frame without limit.
const MAX_BATCH_SPANS: usize = 128;

/// Handled-seq window for duplicate detection (resends only reach a
/// bounded distance back; the set is pruned so a long run stays flat).
const SEEN_SEQ_WINDOW: usize = 8192;

/// The worker process's own recorder: one monotonic clock plus the
/// counters and span buffer the [`TelemetryBatch`] frames ship.
/// Counters are process-lifetime cumulative — they survive master
/// reconnects and are maintained even while no session has asked for
/// telemetry, so the first opted-in session reports full history.
struct WorkerTelemetry {
    /// Clock origin; every span/stamp is ns since this instant.
    origin: Instant,
    requests: u64,
    dup_requests: u64,
    auth_rejects: u64,
    chaos_hits: u64,
    dropped_spans: u64,
    /// Span-buffer high-water mark since the last flush.
    queue_high: u64,
    spans: Vec<TelemetrySpan>,
    req_clock: Vec<(u64, u64, u64)>,
    seen: BTreeSet<u64>,
}

impl WorkerTelemetry {
    fn new() -> WorkerTelemetry {
        WorkerTelemetry {
            origin: Instant::now(),
            requests: 0,
            dup_requests: 0,
            auth_rejects: 0,
            chaos_hits: 0,
            dropped_spans: 0,
            queue_high: 0,
            spans: Vec::new(),
            req_clock: Vec::new(),
            seen: BTreeSet::new(),
        }
    }

    fn now_ns(&self) -> u64 {
        self.origin.elapsed().as_nanos() as u64
    }

    fn push_span(&mut self, s: TelemetrySpan) {
        if self.spans.len() >= MAX_BATCH_SPANS {
            self.dropped_spans += 1;
        } else {
            self.spans.push(s);
        }
        self.queue_high = self.queue_high.max(self.spans.len() as u64);
    }

    /// Count one request; true iff its seq was already handled (a
    /// master resend — the worker still recomputes and responds, the
    /// master dedups by seq; this only observes it).
    fn note_request(&mut self, seq: u64) -> bool {
        self.requests += 1;
        let dup = !self.seen.insert(seq);
        if dup {
            self.dup_requests += 1;
        }
        if self.seen.len() > SEEN_SEQ_WINDOW {
            if let Some(&cut) = self.seen.iter().nth(SEEN_SEQ_WINDOW / 2) {
                self.seen = self.seen.split_off(&cut);
            }
        }
        dup
    }

    /// Classify and count a failed frame decode: a MAC refusal vs any
    /// other corruption (the chaos layer's bit flips, torn bodies).
    fn note_decode_error(&mut self, e: &anyhow::Error) {
        if format!("{e:#}").contains("authentication") {
            self.auth_rejects += 1;
            log::warn!("worker: rejected frame with bad MAC (auth_rejects={})", self.auth_rejects);
        } else {
            self.chaos_hits += 1;
            log::warn!("worker: undecodable frame (chaos_hits={}): {e:#}", self.chaos_hits);
        }
    }

    /// Drain the pending spans/stamps into one bounded batch.
    fn flush(&mut self, worker: u64) -> TelemetryBatch {
        let batch = TelemetryBatch {
            worker,
            req_clock: std::mem::take(&mut self.req_clock),
            spans: std::mem::take(&mut self.spans),
            requests: self.requests,
            dup_requests: self.dup_requests,
            auth_rejects: self.auth_rejects,
            chaos_hits: self.chaos_hits,
            queue_depth: self.queue_high,
            dropped_spans: self.dropped_spans,
        };
        self.queue_high = 0;
        batch
    }
}

/// Read one frame, timing the decode separately from the socket wait:
/// returns `(frame, recv_ns, decoded_ns)` where `recv_ns` stamps the
/// moment the raw body finished arriving (the NTP t1). Decode failures
/// are classified into the telemetry counters before propagating.
fn read_frame_timed(
    r: &mut impl Read,
    auth: Option<&AuthKey>,
    tel: &mut WorkerTelemetry,
) -> Result<Option<(Frame, u64, u64)>> {
    let body = match read_raw_body(r)? {
        None => return Ok(None),
        Some((body, _)) => body,
    };
    let recv_ns = tel.now_ns();
    match decode_body_auth(&body, auth) {
        Ok(frame) => Ok(Some((frame, recv_ns, tel.now_ns()))),
        Err(e) => {
            tel.note_decode_error(&e);
            Err(e)
        }
    }
}

enum SessionEnd {
    /// Master went away (EOF or torn frame): await a reconnect.
    Eof,
    /// Master said shutdown: stop serving.
    Shutdown,
}

/// Worker state kept across master reconnects, keyed by the hello
/// that built it.
struct Persistent {
    hello: Hello,
    state: WorkerState,
    /// Response-write fault injector; persists across reconnects so
    /// the storm doesn't restart with every session.
    chaos: Option<ChaosLink>,
}

/// Accept loop with a clean wire and no authentication — what
/// `r3bft worker` without flags runs, byte-identical to PR 8.
pub fn serve(listener: TcpListener) -> Result<()> {
    serve_with(listener, ServeOptions::default())
}

/// Accept loop: serve master sessions until a shutdown frame arrives.
/// Blocks the calling thread; run-from-test harnesses call this on a
/// listener bound to `127.0.0.1:0` in a spawned thread.
pub fn serve_with(listener: TcpListener, opts: ServeOptions) -> Result<()> {
    let mut persistent: Option<Persistent> = None;
    let mut tel = WorkerTelemetry::new();
    for stream in listener.incoming() {
        let stream = match stream {
            Ok(s) => s,
            Err(e) => {
                log::warn!("worker accept failed: {e}");
                continue;
            }
        };
        match serve_session(stream, &mut persistent, &opts, &mut tel) {
            Ok(SessionEnd::Shutdown) => return Ok(()),
            Ok(SessionEnd::Eof) => continue, // master may reconnect
            Err(e) => {
                log::warn!("worker session error: {e:#}");
                continue;
            }
        }
    }
    Ok(())
}

/// Build the compute state a hello describes — the exact construction
/// path `ThreadedTransport::spawn_full` uses in-process.
fn build_state(hello: &Hello) -> Result<WorkerState> {
    let engine: Arc<dyn GradientComputer> = Arc::new(NativeEngine::new(hello.model.clone()));
    let byzantine = hello
        .byzantine
        .as_ref()
        .map(|a| ByzantineBehavior::new(a.clone(), hello.seed, hello.global_id as usize));
    let compressor = match &hello.compressor {
        Some(spec) => Some(compress::parse(spec)?),
        None => None,
    };
    Ok(WorkerState::new(hello.local_id as usize, engine, byzantine, compressor))
}

fn serve_session(
    stream: TcpStream,
    persistent: &mut Option<Persistent>,
    opts: &ServeOptions,
    tel: &mut WorkerTelemetry,
) -> Result<SessionEnd> {
    let _ = stream.set_nodelay(true);
    let mut w = stream.try_clone()?;
    let mut r = BufReader::new(stream);
    let auth = opts.auth.as_ref();
    // session preamble: Hello (or an immediate Shutdown). With auth
    // on, a forged or unauthenticated hello dies right here — no
    // worker state is built for a master that doesn't share the key.
    let hello = match read_frame_timed(&mut r, auth, tel)? {
        None => return Ok(SessionEnd::Eof),
        Some((Frame::Hello(h), _, _)) => h,
        Some((Frame::Shutdown, _, _)) => return Ok(SessionEnd::Shutdown),
        Some(_) => anyhow::bail!("session did not start with a hello"),
    };
    let same = persistent.as_ref().map(|p| p.hello == hello).unwrap_or(false);
    if persistent.is_some() {
        log::info!(
            "worker {}: master reconnected ({})",
            hello.global_id,
            if same { "state reused" } else { "state rebuilt" }
        );
    }
    if !same {
        let chaos = opts
            .chaos
            .filter(|s| !s.is_noop())
            .map(|s| ChaosLink::new(s, hello.seed, hello.global_id, CHANNEL_WORKER_SEND));
        *persistent =
            Some(Persistent { state: build_state(&hello)?, hello: hello.clone(), chaos });
    }
    // the ack is exempt from chaos (handshakes must succeed for the
    // steady state to be exercised at all), but carries a MAC; with
    // telemetry on it also samples the worker clock, seeding the
    // master's per-link offset estimate at the handshake RTT midpoint
    let ack = Frame::HelloAck {
        global_id: hello.global_id,
        clock_ns: hello.telemetry.then(|| tel.now_ns()),
    };
    write_frame_auth(&mut w, &ack, auth)?;
    let p = persistent.as_mut().expect("state built above");
    let sleep: SleepFn = Arc::new(std::thread::sleep);
    let emit = hello.telemetry;
    loop {
        match read_frame_timed(&mut r, auth, tel)? {
            None => return Ok(SessionEnd::Eof),
            Some((Frame::Shutdown, _, _)) => return Ok(SessionEnd::Shutdown),
            Some((Frame::Request(req), recv_ns, decoded_ns)) => {
                if tel.note_request(req.seq) {
                    log::info!(
                        "worker {}: duplicate request seq={} iter={} (master resend)",
                        hello.global_id,
                        req.seq,
                        req.iter
                    );
                }
                if emit {
                    tel.push_span(TelemetrySpan {
                        kind: SPAN_DECODE,
                        seq: req.seq,
                        iter: req.iter,
                        wave: req.wave,
                        chunk: 0,
                        start_ns: recv_ns,
                        end_ns: decoded_ns,
                    });
                }
                if hello.latency_us > 0 {
                    std::thread::sleep(std::time::Duration::from_micros(hello.latency_us));
                }
                let tasks: Vec<(usize, crate::data::Batch)> =
                    req.tasks.into_iter().map(|(c, b)| (c as usize, b)).collect();
                // a panic must become an error response, not a dead
                // process: the master counts one delivery per request
                let origin = tel.origin;
                let now = move || origin.elapsed().as_nanos() as u64;
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    let mut chunk_spans: Vec<(usize, u64, u64)> = Vec::new();
                    let symbols = if emit {
                        p.state.handle_observed(
                            req.iter,
                            &req.theta,
                            tasks,
                            &now,
                            &mut |chunk, start, end| chunk_spans.push((chunk, start, end)),
                        )
                    } else {
                        p.state.handle(req.iter, &req.theta, tasks)
                    };
                    (symbols, chunk_spans)
                }));
                let error = match &result {
                    Ok((Ok(_), _)) => None,
                    Ok((Err(e), _)) => Some(format!("{e:#}")),
                    Err(panic) => Some(
                        panic
                            .downcast_ref::<String>()
                            .cloned()
                            .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                            .unwrap_or_else(|| "worker panicked".into()),
                    ),
                };
                let (symbols, chunk_spans) = match result {
                    Ok((Ok(symbols), spans)) => (
                        symbols
                            .into_iter()
                            .map(|s| NetSymbol {
                                chunk: s.chunk as u64,
                                loss: s.loss,
                                tampered: s.tampered,
                                grad: match s.wire {
                                    Some(wire) => NetGrad::Wire(wire),
                                    None => NetGrad::Dense(s.grad),
                                },
                            })
                            .collect(),
                        spans,
                    ),
                    _ => (vec![], vec![]),
                };
                if emit {
                    for (chunk, start_ns, end_ns) in chunk_spans {
                        tel.push_span(TelemetrySpan {
                            kind: SPAN_COMPUTE,
                            seq: req.seq,
                            iter: req.iter,
                            wave: req.wave,
                            chunk: chunk as u64,
                            start_ns,
                            end_ns,
                        });
                    }
                }
                let resp = NetResponse {
                    seq: req.seq,
                    worker: hello.local_id,
                    iter: req.iter,
                    phase: req.phase,
                    wave: req.wave,
                    error,
                    symbols,
                };
                let enc_start = tel.now_ns();
                let wire = encode_frame(&Frame::Response(resp), auth)?;
                if emit {
                    tel.push_span(TelemetrySpan {
                        kind: SPAN_ENCODE,
                        seq: req.seq,
                        iter: req.iter,
                        wave: req.wave,
                        chunk: 0,
                        start_ns: enc_start,
                        end_ns: tel.now_ns(),
                    });
                }
                send_wire(&mut w, p.chaos.as_mut(), &sleep, &wire)?;
                if emit {
                    // the response-handed-to-socket stamp is the NTP t2
                    let send_ns = tel.now_ns();
                    tel.req_clock.push((req.seq, recv_ns, send_ns));
                    let batch = tel.flush(hello.local_id);
                    // telemetry is control plane: MAC'd, chaos-exempt —
                    // an opted-in run draws the same chaos coins as a
                    // telemetry-off one
                    write_frame_auth(&mut w, &Frame::Telemetry(batch), auth)?;
                }
            }
            Some(_) => anyhow::bail!("unexpected frame mid-session"),
        }
    }
}
