//! Worker-side TCP server: a standalone process hosting the same
//! [`WorkerState`] compute core the in-process transports drive.
//!
//! `r3bft worker --listen ADDR` binds a listener and calls
//! [`serve`]. One master session is served at a time: the master's
//! [`Hello`](super::frame::Hello) carries everything needed to build
//! the worker bit-identically to its in-process twin — ids, seed,
//! scripted attack, compressor spec, model — so a loopback net run
//! reproduces a threaded/sim run exactly.
//!
//! Reconnect semantics: a dropped connection sends [`serve`] back to
//! `accept`. If the next session's hello matches the previous one,
//! the existing [`WorkerState`] is **reused**, preserving the
//! Byzantine RNG stream and the per-iteration tamper cache across
//! the reconnect (the master resends unanswered requests; honest
//! recomputation is deterministic). A hello for a different
//! configuration rebuilds the state from scratch. A
//! [`Frame::Shutdown`] ends the process's serve loop.
//!
//! With [`ServeOptions::auth`] set (`--auth-key`), every frame must
//! carry a valid MAC: a hello from a master that does not share the
//! key fails verification **before** any worker state is built, and
//! the session is refused. With [`ServeOptions::chaos`] set the
//! worker's response writes pass through a seeded
//! [`ChaosLink`](super::chaos::ChaosLink) (stream keyed by the
//! hello's run seed + global id, [`CHANNEL_WORKER_SEND`]), so both
//! directions of a link can be made hostile. The handshake ack is
//! exempt, mirroring the master side.

use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use super::super::super::byzantine::ByzantineBehavior;
use super::super::super::compress;
use super::super::super::worker::WorkerState;
use super::chaos::{ChaosLink, ChaosSpec, CHANNEL_WORKER_SEND};
use super::frame::{
    encode_frame, read_frame_auth, write_frame_auth, AuthKey, Frame, Hello, NetGrad, NetResponse,
    NetSymbol,
};
use super::{send_wire, SleepFn};
use crate::grad::{GradientComputer, NativeEngine};
use crate::Result;

/// Worker-side hardening knobs, from `r3bft worker` flags.
#[derive(Default)]
pub struct ServeOptions {
    /// Shared frame-authentication key (None = legacy wire).
    pub auth: Option<AuthKey>,
    /// Fault injection on this worker's response writes (None =
    /// clean wire). Seeded from the master's hello, so the storm is
    /// replayable from the run seed like every other link.
    pub chaos: Option<ChaosSpec>,
}

enum SessionEnd {
    /// Master went away (EOF or torn frame): await a reconnect.
    Eof,
    /// Master said shutdown: stop serving.
    Shutdown,
}

/// Worker state kept across master reconnects, keyed by the hello
/// that built it.
struct Persistent {
    hello: Hello,
    state: WorkerState,
    /// Response-write fault injector; persists across reconnects so
    /// the storm doesn't restart with every session.
    chaos: Option<ChaosLink>,
}

/// Accept loop with a clean wire and no authentication — what
/// `r3bft worker` without flags runs, byte-identical to PR 8.
pub fn serve(listener: TcpListener) -> Result<()> {
    serve_with(listener, ServeOptions::default())
}

/// Accept loop: serve master sessions until a shutdown frame arrives.
/// Blocks the calling thread; run-from-test harnesses call this on a
/// listener bound to `127.0.0.1:0` in a spawned thread.
pub fn serve_with(listener: TcpListener, opts: ServeOptions) -> Result<()> {
    let mut persistent: Option<Persistent> = None;
    for stream in listener.incoming() {
        let stream = match stream {
            Ok(s) => s,
            Err(e) => {
                log::warn!("worker accept failed: {e}");
                continue;
            }
        };
        match serve_session(stream, &mut persistent, &opts) {
            Ok(SessionEnd::Shutdown) => return Ok(()),
            Ok(SessionEnd::Eof) => continue, // master may reconnect
            Err(e) => {
                log::warn!("worker session error: {e:#}");
                continue;
            }
        }
    }
    Ok(())
}

/// Build the compute state a hello describes — the exact construction
/// path `ThreadedTransport::spawn_full` uses in-process.
fn build_state(hello: &Hello) -> Result<WorkerState> {
    let engine: Arc<dyn GradientComputer> = Arc::new(NativeEngine::new(hello.model.clone()));
    let byzantine = hello
        .byzantine
        .as_ref()
        .map(|a| ByzantineBehavior::new(a.clone(), hello.seed, hello.global_id as usize));
    let compressor = match &hello.compressor {
        Some(spec) => Some(compress::parse(spec)?),
        None => None,
    };
    Ok(WorkerState::new(hello.local_id as usize, engine, byzantine, compressor))
}

fn serve_session(
    stream: TcpStream,
    persistent: &mut Option<Persistent>,
    opts: &ServeOptions,
) -> Result<SessionEnd> {
    let _ = stream.set_nodelay(true);
    let mut w = stream.try_clone()?;
    let mut r = BufReader::new(stream);
    // session preamble: Hello (or an immediate Shutdown). With auth
    // on, a forged or unauthenticated hello dies right here — no
    // worker state is built for a master that doesn't share the key.
    let hello = match read_frame_auth(&mut r, opts.auth.as_ref())? {
        None => return Ok(SessionEnd::Eof),
        Some((Frame::Hello(h), _)) => h,
        Some((Frame::Shutdown, _)) => return Ok(SessionEnd::Shutdown),
        Some(_) => anyhow::bail!("session did not start with a hello"),
    };
    let same = persistent.as_ref().map(|p| p.hello == hello).unwrap_or(false);
    if !same {
        let chaos = opts
            .chaos
            .filter(|s| !s.is_noop())
            .map(|s| ChaosLink::new(s, hello.seed, hello.global_id, CHANNEL_WORKER_SEND));
        *persistent =
            Some(Persistent { state: build_state(&hello)?, hello: hello.clone(), chaos });
    }
    // the ack is exempt from chaos (handshakes must succeed for the
    // steady state to be exercised at all), but carries a MAC
    write_frame_auth(&mut w, &Frame::HelloAck { global_id: hello.global_id }, opts.auth.as_ref())?;
    let p = persistent.as_mut().expect("state built above");
    let sleep: SleepFn = Arc::new(std::thread::sleep);
    loop {
        match read_frame_auth(&mut r, opts.auth.as_ref())? {
            None => return Ok(SessionEnd::Eof),
            Some((Frame::Shutdown, _)) => return Ok(SessionEnd::Shutdown),
            Some((Frame::Request(req), _)) => {
                if hello.latency_us > 0 {
                    std::thread::sleep(std::time::Duration::from_micros(hello.latency_us));
                }
                let tasks: Vec<(usize, crate::data::Batch)> =
                    req.tasks.into_iter().map(|(c, b)| (c as usize, b)).collect();
                // a panic must become an error response, not a dead
                // process: the master counts one delivery per request
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    p.state.handle(req.iter, &req.theta, tasks)
                }));
                let error = match &result {
                    Ok(Ok(_)) => None,
                    Ok(Err(e)) => Some(format!("{e:#}")),
                    Err(panic) => Some(
                        panic
                            .downcast_ref::<String>()
                            .cloned()
                            .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                            .unwrap_or_else(|| "worker panicked".into()),
                    ),
                };
                let symbols = match result {
                    Ok(Ok(symbols)) => symbols
                        .into_iter()
                        .map(|s| NetSymbol {
                            chunk: s.chunk as u64,
                            loss: s.loss,
                            tampered: s.tampered,
                            grad: match s.wire {
                                Some(wire) => NetGrad::Wire(wire),
                                None => NetGrad::Dense(s.grad),
                            },
                        })
                        .collect(),
                    _ => vec![],
                };
                let resp = NetResponse {
                    seq: req.seq,
                    worker: hello.local_id,
                    iter: req.iter,
                    phase: req.phase,
                    wave: req.wave,
                    error,
                    symbols,
                };
                let wire = encode_frame(&Frame::Response(resp), opts.auth.as_ref())?;
                send_wire(&mut w, p.chaos.as_mut(), &sleep, &wire)?;
            }
            Some(_) => anyhow::bail!("unexpected frame mid-session"),
        }
    }
}
