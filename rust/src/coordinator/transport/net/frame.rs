//! Length-prefixed binary frame codec for the TCP transport.
//!
//! Wire layout of one frame:
//!
//! ```text
//! +----------------+---------+------------------+
//! | u32 LE length  | u8 tag  | payload bytes    |
//! +----------------+---------+------------------+
//! ```
//!
//! `length` counts the tag byte plus the payload (so a frame occupies
//! `4 + length` bytes on the wire); `length == 0` and
//! `length > MAX_FRAME` are rejected before any allocation. All
//! integers are little-endian; `f32`/`f64` travel as their LE byte
//! representation, so dense gradients and θ round-trip bit-exactly —
//! the property the cross-transport bit-identity suite rests on.
//!
//! Every decode path is fallible: truncated payloads, corrupt length
//! prefixes, unknown tags, trailing garbage, and mid-frame EOF all
//! surface as errors, never panics — these bytes arrive from a socket.
//! Compressed symbol payloads are *not* decoded here; the receiver
//! validates them with [`Compressor::try_unpack`]
//! (`crate::coordinator::compress`).
//!
//! With a shared [`AuthKey`] in force (`--auth-key` on both sides)
//! every frame additionally carries a [`MAC_LEN`]-byte SipHash-2-4
//! tag at the end of the length-counted region, verified *before* any
//! body field is decoded: a tampered, truncated, or forged frame is an
//! in-band authentication error, never silently ingested protocol
//! state. Without a key the wire format is bit-for-bit the legacy
//! (PR 8) layout. See docs/NETWORK.md for the threat model.

use std::io::{Read, Write};

use crate::config::{AttackConfig, AttackKind};
use crate::data::Batch;
use crate::grad::ModelSpec;
use crate::Result;

/// Hard ceiling on a frame body (tag + payload): 256 MiB. Large enough
/// for any θ broadcast we ship, small enough that a corrupt length
/// prefix cannot trigger a multi-GiB allocation.
pub const MAX_FRAME: u32 = 1 << 28;

const TAG_HELLO: u8 = 1;
const TAG_HELLO_ACK: u8 = 2;
const TAG_REQUEST: u8 = 3;
const TAG_RESPONSE: u8 = 4;
const TAG_SHUTDOWN: u8 = 5;
const TAG_TELEMETRY: u8 = 6;

// ------------------------------------------------------------- auth

/// Bytes appended to an authenticated frame body: the SipHash-2-4 tag
/// over `tag + payload` under the shared session key. Inside the
/// length-counted region, so framing is identical either way.
pub const MAC_LEN: usize = 8;

/// Shared-secret frame-authentication key.
///
/// Both sides derive the same key from the `--auth-key` /
/// `R3BFT_AUTH_KEY` passphrase; the worker then refuses any session
/// whose Hello does not carry a valid tag (today any peer that says
/// Hello would be trusted as the master), and both directions reject
/// tampered frames before decoding a single field.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AuthKey {
    k0: u64,
    k1: u64,
}

impl AuthKey {
    /// Derive a key from a shared passphrase. The two halves come from
    /// SipHash-2-4 of the passphrase under distinct fixed
    /// domain-separation keys, so `k0` and `k1` are independent even
    /// for short passphrases.
    pub fn from_passphrase(pass: &str) -> AuthKey {
        let b = pass.as_bytes();
        AuthKey {
            k0: siphash24(0x7233_6266_745f_6b64_u64, 0x6672_616d_655f_6b30_u64, b),
            k1: siphash24(0x7233_6266_745f_6b64_u64, 0x6672_616d_655f_6b31_u64, b),
        }
    }

    /// The authentication tag for one frame body (`tag + payload`).
    pub fn mac(&self, body: &[u8]) -> [u8; MAC_LEN] {
        siphash24(self.k0, self.k1, body).to_le_bytes()
    }
}

/// SipHash-2-4 (Aumasson–Bernstein), the keyed PRF behind
/// [`AuthKey::mac`]. Hand-rolled: the vendored dependency set carries
/// no crypto crate, and an 8-byte PRF tag is exactly what frame
/// authentication against accidental/chaos corruption and
/// unauthenticated peers needs (threat model in docs/NETWORK.md).
fn siphash24(k0: u64, k1: u64, data: &[u8]) -> u64 {
    #[inline]
    fn round(v: &mut [u64; 4]) {
        v[0] = v[0].wrapping_add(v[1]);
        v[1] = v[1].rotate_left(13) ^ v[0];
        v[0] = v[0].rotate_left(32);
        v[2] = v[2].wrapping_add(v[3]);
        v[3] = v[3].rotate_left(16) ^ v[2];
        v[0] = v[0].wrapping_add(v[3]);
        v[3] = v[3].rotate_left(21) ^ v[0];
        v[2] = v[2].wrapping_add(v[1]);
        v[1] = v[1].rotate_left(17) ^ v[2];
        v[2] = v[2].rotate_left(32);
    }
    let mut v = [
        k0 ^ 0x736f_6d65_7073_6575,
        k1 ^ 0x646f_7261_6e64_6f6d,
        k0 ^ 0x6c79_6765_6e65_7261,
        k1 ^ 0x7465_6462_7974_6573,
    ];
    let mut words = data.chunks_exact(8);
    for w in &mut words {
        let m = u64::from_le_bytes(w.try_into().unwrap());
        v[3] ^= m;
        round(&mut v);
        round(&mut v);
        v[0] ^= m;
    }
    let mut last = (data.len() as u64 & 0xff) << 56;
    for (i, &b) in words.remainder().iter().enumerate() {
        last |= (b as u64) << (8 * i);
    }
    v[3] ^= last;
    round(&mut v);
    round(&mut v);
    v[0] ^= last;
    v[2] ^= 0xff;
    for _ in 0..4 {
        round(&mut v);
    }
    v[0] ^ v[1] ^ v[2] ^ v[3]
}

/// Master → worker session preamble: everything the worker process
/// needs to build the exact [`WorkerState`](crate::coordinator::worker::WorkerState)
/// an in-process transport would have built, so net runs stay
/// bit-identical to threaded/sim runs.
#[derive(Clone, Debug, PartialEq)]
pub struct Hello {
    /// Transport-local worker id (echoed in every response).
    pub local_id: u64,
    /// Global id (shard offset + local): seeds the Byzantine RNG.
    pub global_id: u64,
    /// Run seed (Byzantine RNG input).
    pub seed: u64,
    /// Artificial per-request compute delay (µs), as `--latency` does
    /// for the threaded pool.
    pub latency_us: u64,
    /// `Some` iff this worker is scripted Byzantine.
    pub byzantine: Option<AttackConfig>,
    /// Compressor spec (`Compressor::spec`), if the run compresses.
    pub compressor: Option<String>,
    /// Model the worker instantiates its gradient engine from.
    pub model: ModelSpec,
    /// Master asks the worker to run its local recorder and ship
    /// [`TelemetryBatch`] frames. Encoded as a trailing byte only when
    /// set, so a telemetry-off Hello is bit-identical to the PR 8/9
    /// wire and a PR 10 worker still accepts an old master's Hello.
    pub telemetry: bool,
}

/// One timed interval on the *worker's* monotonic clock, shipped in a
/// [`TelemetryBatch`]. `kind` selects the taxonomy row (see
/// docs/TRACING.md): 0 = per-chunk gradient compute, 1 = request frame
/// decode, 2 = response frame encode.
#[derive(Clone, Debug, PartialEq)]
pub struct TelemetrySpan {
    pub kind: u8,
    /// Request sequence number the span belongs to.
    pub seq: u64,
    pub iter: u64,
    pub wave: u64,
    /// Chunk id for compute spans (0 for decode/encode spans).
    pub chunk: u64,
    /// Span bounds in ns on the worker's session clock.
    pub start_ns: u64,
    pub end_ns: u64,
}

/// Span kind tags for [`TelemetrySpan::kind`].
pub const SPAN_COMPUTE: u8 = 0;
pub const SPAN_DECODE: u8 = 1;
pub const SPAN_ENCODE: u8 = 2;

/// Worker → master telemetry (one bounded batch per handled request,
/// only when the session's [`Hello`] asked for it). Everything is on
/// the worker's clock; the master's supervisor remaps spans onto its
/// own transport clock with the per-link offset estimate.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TelemetryBatch {
    /// Transport-local worker id (same namespace as `NetResponse`).
    pub worker: u64,
    /// `(seq, recv_ns, send_ns)` per request covered by this batch:
    /// when the request frame finished arriving and when its response
    /// was handed to the socket — the NTP t1/t2 pair the master's
    /// offset EWMA feeds on.
    pub req_clock: Vec<(u64, u64, u64)>,
    /// Timed intervals (bounded; see `dropped_spans`).
    pub spans: Vec<TelemetrySpan>,
    /// Requests handled, cumulative over the worker *process* (the
    /// count rides across reconnects — exactly what makes a flapping
    /// link's history legible to the master).
    pub requests: u64,
    /// Cumulative duplicate requests observed (a seq already handled —
    /// the receive side of the master's resend-after-reconnect path).
    pub dup_requests: u64,
    /// Cumulative frames refused by the MAC check (pre-handshake
    /// forgeries included).
    pub auth_rejects: u64,
    /// Cumulative undecodable/torn frames survived (chaos hits that
    /// were not clean MAC rejects).
    pub chaos_hits: u64,
    /// Telemetry span-buffer high-water mark since the last batch.
    pub queue_depth: u64,
    /// Spans dropped because the per-batch bound was hit.
    pub dropped_spans: u64,
}

/// One wave's work for one worker (master → worker).
#[derive(Clone, Debug)]
pub struct NetRequest {
    /// Per-connection sequence number: the ack that lets the master
    /// resend exactly the unacknowledged requests after a reconnect.
    pub seq: u64,
    pub iter: u64,
    pub phase: u32,
    pub wave: u64,
    pub theta: Vec<f32>,
    pub tasks: Vec<(u64, Batch)>,
}

/// A symbol's gradient payload: packed wire bytes when the run
/// compresses (the receiver decodes with `try_unpack`), dense f32s
/// otherwise.
#[derive(Clone, Debug, PartialEq)]
pub enum NetGrad {
    Dense(Vec<f32>),
    Wire(Vec<u8>),
}

/// One computed symbol on the wire.
#[derive(Clone, Debug, PartialEq)]
pub struct NetSymbol {
    pub chunk: u64,
    pub loss: f32,
    pub tampered: bool,
    pub grad: NetGrad,
}

/// One wave's results from one worker (worker → master).
#[derive(Clone, Debug)]
pub struct NetResponse {
    /// Echo of the request's sequence number (resend bookkeeping).
    pub seq: u64,
    pub worker: u64,
    pub iter: u64,
    pub phase: u32,
    pub wave: u64,
    pub error: Option<String>,
    pub symbols: Vec<NetSymbol>,
}

/// Every frame the protocol exchanges.
#[derive(Clone, Debug)]
pub enum Frame {
    Hello(Hello),
    /// Worker's session accept. `clock_ns` is the worker's telemetry
    /// clock at ack time — present only when the Hello asked for
    /// telemetry (trailing field, so the legacy ack is byte-identical)
    /// — and seeds the master's per-link clock-offset estimate.
    HelloAck { global_id: u64, clock_ns: Option<u64> },
    Request(NetRequest),
    Response(NetResponse),
    Shutdown,
    /// Worker-side observability batch (never sent unless the session
    /// Hello opted in, so a telemetry-off wire carries tags 1–5 only).
    Telemetry(TelemetryBatch),
}

// ---------------------------------------------------------------- enc

/// Append-only little-endian encoder.
struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn new(tag: u8) -> Enc {
        Enc { buf: vec![tag] }
    }

    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn bytes(&mut self, v: &[u8]) {
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }

    fn str(&mut self, v: &str) {
        self.bytes(v.as_bytes());
    }

    fn f32s(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        for x in v {
            self.f32(*x);
        }
    }

    fn i32s(&mut self, v: &[i32]) {
        self.u32(v.len() as u32);
        for x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

// ---------------------------------------------------------------- dec

/// Fallible little-endian cursor: every take checks the remaining
/// length, and length-prefixed vectors are bounds-checked against the
/// frame body *before* allocation.
struct Dec<'a> {
    b: &'a [u8],
}

impl<'a> Dec<'a> {
    fn new(b: &'a [u8]) -> Dec<'a> {
        Dec { b }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.b.len() < n {
            anyhow::bail!("frame truncated: need {n} bytes, have {}", self.b.len());
        }
        let (head, rest) = self.b.split_at(n);
        self.b = rest;
        Ok(head)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn f64(&mut self) -> Result<f64> {
        let b = self.take(8)?;
        Ok(f64::from_le_bytes(b.try_into().unwrap()))
    }

    /// Element count for `width`-byte elements, rejected before any
    /// allocation if the remaining body cannot hold it.
    fn count(&mut self, width: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        match n.checked_mul(width) {
            Some(total) if total <= self.b.len() => Ok(n),
            _ => anyhow::bail!("frame vector length {n} exceeds remaining {} bytes", self.b.len()),
        }
    }

    fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.count(1)?;
        Ok(self.take(n)?.to_vec())
    }

    fn string(&mut self) -> Result<String> {
        String::from_utf8(self.bytes()?).map_err(|e| anyhow::anyhow!("frame string not utf-8: {e}"))
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.count(4)?;
        let raw = self.take(4 * n)?;
        Ok(raw
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }

    fn i32s(&mut self) -> Result<Vec<i32>> {
        let n = self.count(4)?;
        let raw = self.take(4 * n)?;
        Ok(raw
            .chunks_exact(4)
            .map(|b| i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }

    fn finish(&self) -> Result<()> {
        if !self.b.is_empty() {
            anyhow::bail!("frame has {} trailing bytes", self.b.len());
        }
        Ok(())
    }
}

// ------------------------------------------------------- field codecs

fn enc_attack(e: &mut Enc, a: &AttackConfig) {
    let kind = match a.kind {
        AttackKind::SignFlip => 0u8,
        AttackKind::Noise => 1,
        AttackKind::Constant => 2,
        AttackKind::Zero => 3,
        AttackKind::SmallBias => 4,
        AttackKind::Collude => 5,
    };
    e.u8(kind);
    e.f64(a.p);
    e.f32(a.magnitude);
}

fn dec_attack(d: &mut Dec) -> Result<AttackConfig> {
    let kind = match d.u8()? {
        0 => AttackKind::SignFlip,
        1 => AttackKind::Noise,
        2 => AttackKind::Constant,
        3 => AttackKind::Zero,
        4 => AttackKind::SmallBias,
        5 => AttackKind::Collude,
        other => anyhow::bail!("unknown attack kind tag {other}"),
    };
    Ok(AttackConfig { kind, p: d.f64()?, magnitude: d.f32()? })
}

fn enc_model(e: &mut Enc, m: &ModelSpec) {
    match m {
        ModelSpec::LinReg { d, batch } => {
            e.u8(0);
            e.u64(*d as u64);
            e.u64(*batch as u64);
        }
        ModelSpec::Mlp { in_dim, hidden, classes, batch } => {
            e.u8(1);
            e.u64(*in_dim as u64);
            e.u64(*hidden as u64);
            e.u64(*classes as u64);
            e.u64(*batch as u64);
        }
        ModelSpec::Transformer { param_dim, batch, seq_len } => {
            e.u8(2);
            e.u64(*param_dim as u64);
            e.u64(*batch as u64);
            e.u64(*seq_len as u64);
        }
    }
}

fn dec_model(d: &mut Dec) -> Result<ModelSpec> {
    Ok(match d.u8()? {
        0 => ModelSpec::LinReg { d: d.u64()? as usize, batch: d.u64()? as usize },
        1 => ModelSpec::Mlp {
            in_dim: d.u64()? as usize,
            hidden: d.u64()? as usize,
            classes: d.u64()? as usize,
            batch: d.u64()? as usize,
        },
        2 => ModelSpec::Transformer {
            param_dim: d.u64()? as usize,
            batch: d.u64()? as usize,
            seq_len: d.u64()? as usize,
        },
        other => anyhow::bail!("unknown model tag {other}"),
    })
}

fn enc_batch(e: &mut Enc, b: &Batch) {
    match b {
        Batch::LinReg { x, y, b, d } => {
            e.u8(0);
            e.u64(*b as u64);
            e.u64(*d as u64);
            e.f32s(x);
            e.f32s(y);
        }
        Batch::Classif { x, labels, b, d } => {
            e.u8(1);
            e.u64(*b as u64);
            e.u64(*d as u64);
            e.f32s(x);
            e.i32s(labels);
        }
        Batch::Tokens { tokens, b, t } => {
            e.u8(2);
            e.u64(*b as u64);
            e.u64(*t as u64);
            e.i32s(tokens);
        }
    }
}

fn dec_batch(dec: &mut Dec) -> Result<Batch> {
    Ok(match dec.u8()? {
        0 => {
            let (b, d) = (dec.u64()? as usize, dec.u64()? as usize);
            let x = dec.f32s()?;
            let y = dec.f32s()?;
            if x.len() != b * d || y.len() != b {
                anyhow::bail!("linreg batch shape mismatch: b={b} d={d} |x|={} |y|={}", x.len(), y.len());
            }
            Batch::LinReg { x, y, b, d }
        }
        1 => {
            let (b, d) = (dec.u64()? as usize, dec.u64()? as usize);
            let x = dec.f32s()?;
            let labels = dec.i32s()?;
            if x.len() != b * d || labels.len() != b {
                anyhow::bail!(
                    "classif batch shape mismatch: b={b} d={d} |x|={} |labels|={}",
                    x.len(),
                    labels.len()
                );
            }
            Batch::Classif { x, labels, b, d }
        }
        2 => {
            let (b, t) = (dec.u64()? as usize, dec.u64()? as usize);
            let tokens = dec.i32s()?;
            if tokens.len() != b * t {
                anyhow::bail!("tokens batch shape mismatch: b={b} t={t} |tokens|={}", tokens.len());
            }
            Batch::Tokens { tokens, b, t }
        }
        other => anyhow::bail!("unknown batch tag {other}"),
    })
}

fn enc_opt<T>(e: &mut Enc, v: &Option<T>, f: impl FnOnce(&mut Enc, &T)) {
    match v {
        None => e.u8(0),
        Some(x) => {
            e.u8(1);
            f(e, x);
        }
    }
}

fn dec_opt<T>(d: &mut Dec, f: impl FnOnce(&mut Dec) -> Result<T>) -> Result<Option<T>> {
    match d.u8()? {
        0 => Ok(None),
        1 => Ok(Some(f(d)?)),
        other => anyhow::bail!("bad option tag {other}"),
    }
}

// ------------------------------------------------------- frame codec

impl Frame {
    /// Encode as `tag + payload` (the body behind the length prefix).
    fn encode_body(&self) -> Vec<u8> {
        match self {
            Frame::Hello(h) => {
                let mut e = Enc::new(TAG_HELLO);
                e.u64(h.local_id);
                e.u64(h.global_id);
                e.u64(h.seed);
                e.u64(h.latency_us);
                enc_opt(&mut e, &h.byzantine, enc_attack);
                enc_opt(&mut e, &h.compressor, |e, s| e.str(s));
                enc_model(&mut e, &h.model);
                // trailing extension byte: absent = telemetry off, so
                // the telemetry-off Hello stays bit-identical to PR 8/9
                if h.telemetry {
                    e.u8(1);
                }
                e.buf
            }
            Frame::HelloAck { global_id, clock_ns } => {
                let mut e = Enc::new(TAG_HELLO_ACK);
                e.u64(*global_id);
                // trailing extension, mirror of Hello::telemetry
                if let Some(ns) = clock_ns {
                    e.u64(*ns);
                }
                e.buf
            }
            Frame::Request(r) => {
                let mut e = Enc::new(TAG_REQUEST);
                e.u64(r.seq);
                e.u64(r.iter);
                e.u32(r.phase);
                e.u64(r.wave);
                e.f32s(&r.theta);
                e.u32(r.tasks.len() as u32);
                for (chunk, batch) in &r.tasks {
                    e.u64(*chunk);
                    enc_batch(&mut e, batch);
                }
                e.buf
            }
            Frame::Response(r) => {
                let mut e = Enc::new(TAG_RESPONSE);
                e.u64(r.seq);
                e.u64(r.worker);
                e.u64(r.iter);
                e.u32(r.phase);
                e.u64(r.wave);
                enc_opt(&mut e, &r.error, |e, s| e.str(s));
                e.u32(r.symbols.len() as u32);
                for s in &r.symbols {
                    e.u64(s.chunk);
                    e.f32(s.loss);
                    e.u8(s.tampered as u8);
                    match &s.grad {
                        NetGrad::Dense(g) => {
                            e.u8(0);
                            e.f32s(g);
                        }
                        NetGrad::Wire(w) => {
                            e.u8(1);
                            e.bytes(w);
                        }
                    }
                }
                e.buf
            }
            Frame::Shutdown => Enc::new(TAG_SHUTDOWN).buf,
            Frame::Telemetry(t) => {
                let mut e = Enc::new(TAG_TELEMETRY);
                e.u64(t.worker);
                e.u64(t.requests);
                e.u64(t.dup_requests);
                e.u64(t.auth_rejects);
                e.u64(t.chaos_hits);
                e.u64(t.queue_depth);
                e.u64(t.dropped_spans);
                e.u32(t.req_clock.len() as u32);
                for (seq, recv_ns, send_ns) in &t.req_clock {
                    e.u64(*seq);
                    e.u64(*recv_ns);
                    e.u64(*send_ns);
                }
                e.u32(t.spans.len() as u32);
                for s in &t.spans {
                    e.u8(s.kind);
                    e.u64(s.seq);
                    e.u64(s.iter);
                    e.u64(s.wave);
                    e.u64(s.chunk);
                    e.u64(s.start_ns);
                    e.u64(s.end_ns);
                }
                e.buf
            }
        }
    }

    /// Decode a full `tag + payload` body (trailing bytes rejected).
    fn decode_body(body: &[u8]) -> Result<Frame> {
        let mut d = Dec::new(body);
        let frame = match d.u8()? {
            TAG_HELLO => Frame::Hello(Hello {
                local_id: d.u64()?,
                global_id: d.u64()?,
                seed: d.u64()?,
                latency_us: d.u64()?,
                byzantine: dec_opt(&mut d, dec_attack)?,
                compressor: dec_opt(&mut d, |d| d.string())?,
                model: dec_model(&mut d)?,
                telemetry: if d.b.is_empty() { false } else { d.u8()? != 0 },
            }),
            TAG_HELLO_ACK => Frame::HelloAck {
                global_id: d.u64()?,
                clock_ns: if d.b.is_empty() { None } else { Some(d.u64()?) },
            },
            TAG_REQUEST => {
                let seq = d.u64()?;
                let iter = d.u64()?;
                let phase = d.u32()?;
                let wave = d.u64()?;
                let theta = d.f32s()?;
                let ntasks = d.count(9)?; // each task: u64 chunk + >= 1 byte batch
                let mut tasks = Vec::with_capacity(ntasks);
                for _ in 0..ntasks {
                    let chunk = d.u64()?;
                    tasks.push((chunk, dec_batch(&mut d)?));
                }
                Frame::Request(NetRequest { seq, iter, phase, wave, theta, tasks })
            }
            TAG_RESPONSE => {
                let seq = d.u64()?;
                let worker = d.u64()?;
                let iter = d.u64()?;
                let phase = d.u32()?;
                let wave = d.u64()?;
                let error = dec_opt(&mut d, |d| d.string())?;
                let nsym = d.count(14)?; // chunk + loss + flag + grad tag
                let mut symbols = Vec::with_capacity(nsym);
                for _ in 0..nsym {
                    let chunk = d.u64()?;
                    let loss = d.f32()?;
                    let tampered = match d.u8()? {
                        0 => false,
                        1 => true,
                        other => anyhow::bail!("bad tampered flag {other}"),
                    };
                    let grad = match d.u8()? {
                        0 => NetGrad::Dense(d.f32s()?),
                        1 => NetGrad::Wire(d.bytes()?),
                        other => anyhow::bail!("unknown grad tag {other}"),
                    };
                    symbols.push(NetSymbol { chunk, loss, tampered, grad });
                }
                Frame::Response(NetResponse { seq, worker, iter, phase, wave, error, symbols })
            }
            TAG_SHUTDOWN => Frame::Shutdown,
            TAG_TELEMETRY => {
                let worker = d.u64()?;
                let requests = d.u64()?;
                let dup_requests = d.u64()?;
                let auth_rejects = d.u64()?;
                let chaos_hits = d.u64()?;
                let queue_depth = d.u64()?;
                let dropped_spans = d.u64()?;
                let nclk = d.count(24)?; // seq + recv_ns + send_ns
                let mut req_clock = Vec::with_capacity(nclk);
                for _ in 0..nclk {
                    req_clock.push((d.u64()?, d.u64()?, d.u64()?));
                }
                let nsp = d.count(49)?; // kind + 6 × u64
                let mut spans = Vec::with_capacity(nsp);
                for _ in 0..nsp {
                    spans.push(TelemetrySpan {
                        kind: d.u8()?,
                        seq: d.u64()?,
                        iter: d.u64()?,
                        wave: d.u64()?,
                        chunk: d.u64()?,
                        start_ns: d.u64()?,
                        end_ns: d.u64()?,
                    });
                }
                Frame::Telemetry(TelemetryBatch {
                    worker,
                    req_clock,
                    spans,
                    requests,
                    dup_requests,
                    auth_rejects,
                    chaos_hits,
                    queue_depth,
                    dropped_spans,
                })
            }
            other => anyhow::bail!("unknown frame tag {other}"),
        };
        d.finish()?;
        Ok(frame)
    }
}

/// Encode one frame to its full wire bytes: length prefix + body, plus
/// the MAC tag when a key is in force (the prefix counts tag byte,
/// payload, and MAC). The chaos layer plans its injections over these
/// bytes, so a "corrupted frame" in a test is exactly a corrupted wire.
pub fn encode_frame(frame: &Frame, auth: Option<&AuthKey>) -> Result<Vec<u8>> {
    let mut body = frame.encode_body();
    if let Some(key) = auth {
        let tag = key.mac(&body);
        body.extend_from_slice(&tag);
    }
    if body.len() as u64 > MAX_FRAME as u64 {
        anyhow::bail!("frame body {} bytes exceeds MAX_FRAME {MAX_FRAME}", body.len());
    }
    let mut wire = Vec::with_capacity(4 + body.len());
    wire.extend_from_slice(&(body.len() as u32).to_le_bytes());
    wire.extend_from_slice(&body);
    Ok(wire)
}

/// Write one frame under an optional auth key; returns the total bytes
/// put on the wire (length prefix included) for the honest
/// `bytes_round` accounting.
pub fn write_frame_auth(w: &mut impl Write, frame: &Frame, auth: Option<&AuthKey>) -> Result<u64> {
    let wire = encode_frame(frame, auth)?;
    w.write_all(&wire)?;
    w.flush()?;
    Ok(wire.len() as u64)
}

/// Write one unauthenticated frame (the legacy PR 8 wire, bit-for-bit).
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> Result<u64> {
    write_frame_auth(w, frame, None)
}

/// Read one frame's raw body (tag + payload [+ MAC]). `Ok(None)` means
/// the peer closed the stream cleanly *at a frame boundary*; EOF
/// inside a length prefix or body is an error (a torn frame). Returns
/// the body plus its wire size. Split out from [`read_frame_auth`] so
/// the supervisor's reader can run inbound chaos over the raw bytes
/// before verification/decode — exactly where a hostile network sits.
pub fn read_raw_body(r: &mut impl Read) -> Result<Option<(Vec<u8>, u64)>> {
    let mut prefix = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        match r.read(&mut prefix[got..])? {
            0 if got == 0 => return Ok(None),
            0 => anyhow::bail!("EOF inside frame length prefix ({got}/4 bytes)"),
            n => got += n,
        }
    }
    let len = u32::from_le_bytes(prefix);
    if len == 0 || len > MAX_FRAME {
        anyhow::bail!("corrupt frame length {len} (max {MAX_FRAME})");
    }
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body)
        .map_err(|e| anyhow::anyhow!("EOF inside {len}-byte frame body: {e}"))?;
    Ok(Some((body, 4 + len as u64)))
}

/// Verify (when a key is in force) and decode one frame body. The MAC
/// check runs before any field decode, so a forged or bit-flipped
/// frame never reaches protocol state.
pub fn decode_body_auth(body: &[u8], auth: Option<&AuthKey>) -> Result<Frame> {
    match auth {
        None => Frame::decode_body(body),
        Some(key) => {
            if body.len() < 1 + MAC_LEN {
                anyhow::bail!("authenticated frame too short ({} bytes)", body.len());
            }
            let (head, tag) = body.split_at(body.len() - MAC_LEN);
            let want = key.mac(head);
            // fold the whole difference instead of short-circuiting on
            // the first mismatched byte
            let diff = tag.iter().zip(want.iter()).fold(0u8, |acc, (a, b)| acc | (a ^ b));
            if diff != 0 {
                anyhow::bail!("frame authentication failed (bad MAC)");
            }
            Frame::decode_body(head)
        }
    }
}

/// True iff a raw (possibly MAC-trailed) body is a telemetry frame.
/// The tag is always the body's first byte (the MAC is a trailer), so
/// this needs no decode; the net reader uses it to route telemetry —
/// control plane, like the handshake — around inbound chaos so an
/// opted-in run draws exactly the chaos coins a telemetry-off run
/// draws.
pub fn body_is_telemetry(body: &[u8]) -> bool {
    body.first() == Some(&TAG_TELEMETRY)
}

/// Read one frame under an optional auth key (see [`read_raw_body`]
/// for the EOF contract). Returns the frame plus its wire size.
pub fn read_frame_auth(r: &mut impl Read, auth: Option<&AuthKey>) -> Result<Option<(Frame, u64)>> {
    match read_raw_body(r)? {
        None => Ok(None),
        Some((body, nb)) => Ok(Some((decode_body_auth(&body, auth)?, nb))),
    }
}

/// Read one unauthenticated frame (the legacy PR 8 wire, bit-for-bit).
pub fn read_frame(r: &mut impl Read) -> Result<Option<(Frame, u64)>> {
    read_frame_auth(r, None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;
    use std::io::Cursor;

    /// A `Read` that hands out at most `k` bytes per call — the
    /// split-read simulation: every frame crosses several short reads,
    /// as TCP segments do.
    struct Chunker<'a> {
        data: &'a [u8],
        pos: usize,
        k: usize,
    }

    impl Read for Chunker<'_> {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            let n = self.k.min(buf.len()).min(self.data.len() - self.pos);
            buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    fn sample_frames() -> Vec<Frame> {
        let mut rng = Pcg64::seeded(42);
        vec![
            Frame::Hello(Hello {
                local_id: 3,
                global_id: 11,
                seed: 7,
                latency_us: 250,
                byzantine: Some(AttackConfig {
                    kind: AttackKind::Noise,
                    p: 0.25,
                    magnitude: 2.5,
                }),
                compressor: Some("topk:16".into()),
                model: ModelSpec::Mlp { in_dim: 16, hidden: 8, classes: 4, batch: 32 },
                telemetry: false,
            }),
            Frame::Hello(Hello {
                local_id: 0,
                global_id: 0,
                seed: 1,
                latency_us: 0,
                byzantine: None,
                compressor: None,
                model: ModelSpec::LinReg { d: 8, batch: 64 },
                telemetry: true,
            }),
            Frame::HelloAck { global_id: 11, clock_ns: None },
            Frame::HelloAck { global_id: 11, clock_ns: Some(123_456_789) },
            Frame::Telemetry(TelemetryBatch {
                worker: 3,
                req_clock: vec![(9, 1_000, 5_000), (10, 9_000, 12_345)],
                spans: vec![
                    TelemetrySpan {
                        kind: SPAN_DECODE,
                        seq: 9,
                        iter: 4,
                        wave: 77,
                        chunk: 0,
                        start_ns: 1_000,
                        end_ns: 1_200,
                    },
                    TelemetrySpan {
                        kind: SPAN_COMPUTE,
                        seq: 9,
                        iter: 4,
                        wave: 77,
                        chunk: 2,
                        start_ns: 1_300,
                        end_ns: 4_000,
                    },
                    TelemetrySpan {
                        kind: SPAN_ENCODE,
                        seq: 9,
                        iter: 4,
                        wave: 77,
                        chunk: 0,
                        start_ns: 4_100,
                        end_ns: 4_900,
                    },
                ],
                requests: 12,
                dup_requests: 1,
                auth_rejects: 2,
                chaos_hits: 3,
                queue_depth: 4,
                dropped_spans: 0,
            }),
            Frame::Telemetry(TelemetryBatch { worker: 0, ..Default::default() }),
            Frame::Request(NetRequest {
                seq: 9,
                iter: 4,
                phase: 1,
                wave: 77,
                theta: rng.gauss_vec(33),
                tasks: vec![
                    (2, Batch::LinReg { x: rng.gauss_vec(12), y: rng.gauss_vec(3), b: 3, d: 4 }),
                    (
                        5,
                        Batch::Classif {
                            x: rng.gauss_vec(8),
                            labels: vec![0, 3],
                            b: 2,
                            d: 4,
                        },
                    ),
                    (7, Batch::Tokens { tokens: vec![1, 2, 3, 4, 5, 6], b: 2, t: 3 }),
                ],
            }),
            Frame::Response(NetResponse {
                seq: 9,
                worker: 3,
                iter: 4,
                phase: 1,
                wave: 77,
                error: None,
                symbols: vec![
                    NetSymbol {
                        chunk: 2,
                        loss: 0.5,
                        tampered: false,
                        grad: NetGrad::Dense(rng.gauss_vec(16)),
                    },
                    NetSymbol {
                        chunk: 5,
                        loss: -1.5,
                        tampered: true,
                        grad: NetGrad::Wire(vec![1, 2, 3, 255, 0, 128]),
                    },
                ],
            }),
            Frame::Response(NetResponse {
                seq: 10,
                worker: 0,
                iter: 5,
                phase: 0,
                wave: 78,
                error: Some("engine error: NaN loss".into()),
                symbols: vec![],
            }),
            Frame::Shutdown,
        ]
    }

    fn assert_frames_eq(a: &Frame, b: &Frame) {
        // Frame has no PartialEq (Batch holds floats); byte equality of
        // the canonical encoding is the identity we actually need
        assert_eq!(a.encode_body(), b.encode_body());
    }

    #[test]
    fn every_frame_round_trips() {
        for f in sample_frames() {
            let mut buf = Vec::new();
            let wrote = write_frame(&mut buf, &f).unwrap();
            assert_eq!(wrote, buf.len() as u64, "write_frame must report true wire bytes");
            let (back, read) = read_frame(&mut Cursor::new(&buf)).unwrap().unwrap();
            assert_eq!(read, wrote);
            assert_frames_eq(&f, &back);
        }
    }

    #[test]
    fn frames_round_trip_across_split_reads() {
        // all frames back-to-back in one stream, delivered in 1-, 3-,
        // and 7-byte slivers
        let frames = sample_frames();
        let mut stream = Vec::new();
        for f in &frames {
            write_frame(&mut stream, f).unwrap();
        }
        for k in [1usize, 3, 7] {
            let mut r = Chunker { data: &stream, pos: 0, k };
            let mut back = Vec::new();
            while let Some((f, _)) = read_frame(&mut r).unwrap() {
                back.push(f);
            }
            assert_eq!(back.len(), frames.len(), "k={k}");
            for (a, b) in frames.iter().zip(&back) {
                assert_frames_eq(a, b);
            }
        }
    }

    #[test]
    fn clean_eof_at_frame_boundary_is_none() {
        let mut empty = Cursor::new(Vec::<u8>::new());
        assert!(read_frame(&mut empty).unwrap().is_none());
    }

    #[test]
    fn mid_frame_eof_is_an_error() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::HelloAck { global_id: 5, clock_ns: None }).unwrap();
        // every strict prefix (incl. a torn length prefix) must error
        for cut in 1..buf.len() {
            let r = read_frame(&mut Cursor::new(&buf[..cut]));
            assert!(r.is_err(), "prefix of {cut}/{} bytes accepted", buf.len());
        }
    }

    #[test]
    fn corrupt_length_prefixes_are_rejected() {
        // zero length
        let z = 0u32.to_le_bytes();
        assert!(read_frame(&mut Cursor::new(&z[..])).is_err());
        // oversized length — must reject BEFORE trying to allocate/read
        let huge = (MAX_FRAME + 1).to_le_bytes();
        assert!(read_frame(&mut Cursor::new(&huge[..])).is_err());
        // plausible length, but the body lies about its vector sizes:
        // a Request claiming u32::MAX thetas inside a tiny body
        let mut body = vec![TAG_REQUEST];
        body.extend_from_slice(&9u64.to_le_bytes()); // seq
        body.extend_from_slice(&0u64.to_le_bytes()); // iter
        body.extend_from_slice(&0u32.to_le_bytes()); // phase
        body.extend_from_slice(&1u64.to_le_bytes()); // wave
        body.extend_from_slice(&u32::MAX.to_le_bytes()); // |theta| lie
        let mut buf = (body.len() as u32).to_le_bytes().to_vec();
        buf.extend_from_slice(&body);
        assert!(read_frame(&mut Cursor::new(&buf)).is_err());
    }

    #[test]
    fn unknown_tags_and_trailing_garbage_are_rejected() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::Shutdown).unwrap();
        // unknown tag
        let mut bad = buf.clone();
        bad[4] = 99;
        assert!(read_frame(&mut Cursor::new(&bad)).is_err());
        // trailing garbage inside the declared body
        let mut padded = Vec::new();
        padded.extend_from_slice(&3u32.to_le_bytes());
        padded.push(TAG_SHUTDOWN);
        padded.extend_from_slice(&[0xde, 0xad]);
        assert!(read_frame(&mut Cursor::new(&padded)).is_err());
    }

    #[test]
    fn theta_round_trips_bit_exactly() {
        // the bit-identity suite depends on f32 LE round-tripping
        let theta: Vec<f32> = vec![0.1, -0.0, f32::MIN_POSITIVE, 3.5e37, -1.0e-37];
        let f = Frame::Request(NetRequest {
            seq: 0,
            iter: 0,
            phase: 0,
            wave: 0,
            theta: theta.clone(),
            tasks: vec![],
        });
        let mut buf = Vec::new();
        write_frame(&mut buf, &f).unwrap();
        let (back, _) = read_frame(&mut Cursor::new(&buf)).unwrap().unwrap();
        match back {
            Frame::Request(r) => {
                assert_eq!(r.theta.len(), theta.len());
                for (a, b) in theta.iter().zip(&r.theta) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            _ => panic!("wrong frame"),
        }
    }

    #[test]
    fn random_byte_garbage_never_panics() {
        let mut rng = Pcg64::seeded(1234);
        for _ in 0..500 {
            let len = (rng.next_u64() % 64) as usize;
            let bytes: Vec<u8> = (0..len).map(|_| (rng.next_u64() & 0xff) as u8).collect();
            // any outcome but a panic is acceptable
            let _ = read_frame(&mut Cursor::new(&bytes));
        }
    }

    // ------------------------------------------------------- auth

    fn key() -> AuthKey {
        AuthKey::from_passphrase("correct horse battery staple")
    }

    #[test]
    fn authed_frames_round_trip() {
        for f in sample_frames() {
            let wire = encode_frame(&f, Some(&key())).unwrap();
            let plain = encode_frame(&f, None).unwrap();
            assert_eq!(wire.len(), plain.len() + MAC_LEN, "MAC adds exactly MAC_LEN bytes");
            let (back, nb) = read_frame_auth(&mut Cursor::new(&wire), Some(&key()))
                .unwrap()
                .unwrap();
            assert_eq!(nb, wire.len() as u64);
            assert_frames_eq(&f, &back);
        }
    }

    #[test]
    fn every_single_bit_flip_in_a_maced_frame_is_rejected() {
        // the tentpole's corruption contract: chaos-injected bit flips
        // must surface as in-band authentication failures, never as
        // silently ingested wrong protocol state — for EVERY bit of
        // the length-counted region (tag + payload + MAC)
        let k = key();
        for f in sample_frames() {
            let wire = encode_frame(&f, Some(&k)).unwrap();
            for byte in 4..wire.len() {
                for bit in 0..8 {
                    let mut bad = wire.clone();
                    bad[byte] ^= 1 << bit;
                    assert!(
                        read_frame_auth(&mut Cursor::new(&bad), Some(&k)).is_err(),
                        "bit {bit} of byte {byte} flipped undetected"
                    );
                }
            }
        }
    }

    #[test]
    fn wrong_key_hello_is_refused_before_decode() {
        let hello = sample_frames().remove(0);
        let right = AuthKey::from_passphrase("fleet secret");
        let wrong = AuthKey::from_passphrase("fleet secret?");
        let wire = encode_frame(&hello, Some(&right)).unwrap();
        let err = read_frame_auth(&mut Cursor::new(&wire), Some(&wrong)).unwrap_err();
        assert!(err.to_string().contains("authentication"), "{err}");
        // an authenticated receiver also refuses unauthenticated peers
        let plain = encode_frame(&hello, None).unwrap();
        assert!(read_frame_auth(&mut Cursor::new(&plain), Some(&right)).is_err());
        // and a legacy receiver rejects an authed frame (trailing MAC
        // reads as garbage) instead of half-parsing it
        assert!(read_frame(&mut Cursor::new(&wire)).is_err());
    }

    #[test]
    fn no_auth_wire_stays_byte_identical_to_legacy() {
        // chaos off + auth off must stay bit-identical to the PR 8
        // wire: write_frame, write_frame_auth(None), and the length
        // prefix + encode_body concatenation all agree
        for f in sample_frames() {
            let mut legacy = Vec::new();
            write_frame(&mut legacy, &f).unwrap();
            let mut via_auth = Vec::new();
            write_frame_auth(&mut via_auth, &f, None).unwrap();
            assert_eq!(legacy, via_auth);
            assert_eq!(encode_frame(&f, None).unwrap(), legacy);
            let body = f.encode_body();
            assert_eq!(legacy[..4], (body.len() as u32).to_le_bytes()[..]);
            assert_eq!(legacy[4..], body[..]);
        }
    }

    #[test]
    fn telemetry_extensions_are_trailing_and_legacy_compatible() {
        // telemetry-off Hello/HelloAck must be byte-identical to the
        // PR 8/9 encoding: the extension is exactly one trailing field
        let mut on = Hello {
            local_id: 3,
            global_id: 11,
            seed: 7,
            latency_us: 250,
            byzantine: None,
            compressor: None,
            model: ModelSpec::LinReg { d: 8, batch: 64 },
            telemetry: true,
        };
        let on_bytes = Frame::Hello(on.clone()).encode_body();
        on.telemetry = false;
        let off_bytes = Frame::Hello(on.clone()).encode_body();
        assert_eq!(on_bytes[..on_bytes.len() - 1], off_bytes[..]);
        assert_eq!(on_bytes.len(), off_bytes.len() + 1);
        // a legacy (extension-less) Hello body decodes as telemetry off
        match Frame::decode_body(&off_bytes).unwrap() {
            Frame::Hello(h) => assert!(!h.telemetry),
            other => panic!("wrong frame {other:?}"),
        }
        let acked = Frame::HelloAck { global_id: 4, clock_ns: Some(99) }.encode_body();
        let legacy = Frame::HelloAck { global_id: 4, clock_ns: None }.encode_body();
        assert_eq!(acked[..legacy.len()], legacy[..]);
        match Frame::decode_body(&legacy).unwrap() {
            Frame::HelloAck { global_id: 4, clock_ns: None } => {}
            other => panic!("wrong frame {other:?}"),
        }
        // partial trailing fields are torn frames, not silently padded
        assert!(Frame::decode_body(&acked[..acked.len() - 3]).is_err());
    }

    #[test]
    fn passphrase_derivation_is_deterministic_and_separating() {
        let a = AuthKey::from_passphrase("alpha");
        assert_eq!(a, AuthKey::from_passphrase("alpha"));
        assert_ne!(a, AuthKey::from_passphrase("alphb"));
        assert_ne!(AuthKey::from_passphrase(""), AuthKey::from_passphrase(" "));
        assert_ne!(a.mac(b"body"), AuthKey::from_passphrase("beta").mac(b"body"));
        assert_ne!(a.mac(&[1, 2, 3]), a.mac(&[1, 2, 4]));
        assert_ne!(a.mac(&[]), a.mac(&[0]), "length is part of the MAC input");
    }
}
