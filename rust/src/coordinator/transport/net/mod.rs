//! TCP transport: master and workers as separate processes.
//!
//! This is the first transport where the protocol meets the OS. The
//! master side ([`NetTransport`]) implements the completion-driven
//! [`Transport`] contract over one TCP connection per worker; the
//! worker side ([`server::serve`]) is a standalone process (`r3bft
//! worker --listen ADDR`) hosting the exact same
//! [`WorkerState`](crate::coordinator::worker::WorkerState) compute
//! core the in-process transports drive — which is why a loopback net
//! run is bit-identical to a threaded or sim run for the same seed.
//!
//! Architecture, per worker:
//!
//! * a **supervisor thread** owns the connection lifecycle: connect →
//!   [`frame::Hello`] handshake → resend unacknowledged requests →
//!   write loop. Outbound requests arrive over a *bounded* channel
//!   ([`NetConfig::outbound_depth`]), so a stalled connection
//!   backpressures `submit` instead of buffering unboundedly;
//! * a **reader thread** per live session turns incoming
//!   [`frame::NetResponse`] frames into events for `poll`, acking the
//!   per-connection sequence number that reconnect resends key on;
//! * when the session drops, the supervisor reconnects with capped
//!   exponential backoff. Each re-established session is surfaced as a
//!   reconnect notice ([`Transport::drain_reconnects`] → the
//!   `net_reconnects` metric and a trace event). A worker that
//!   exhausts [`NetConfig::max_attempts`] becomes a **crash-stop**:
//!   every owed delivery comes back as [`Delivery::Failed`] in-band —
//!   never a hang — and later submits to it fail immediately.
//!
//! Deadline-based gathers run on the wall clock ([`Transport::poll`]
//! mirrors [`super::ThreadedTransport`]'s blocking recv/timeout shape
//! exactly), and the socket byte counters ([`Transport::net_stats`])
//! include frame and header overhead — the honest `bytes_round`
//! figure an in-process transport cannot measure.
//!
//! Incoming bytes are untrusted: frames decode fallibly
//! ([`frame::read_frame`]) and compressed symbol payloads pass
//! through [`Compressor::try_unpack`]; a malformed response is logged
//! and surfaced as that worker's crash-stop, not a master panic. With
//! a shared [`frame::AuthKey`] (`--auth-key`) every frame additionally
//! carries a MAC verified before decode, and the worker refuses
//! sessions from unauthenticated masters.
//!
//! The whole lifecycle can be run under seeded fault injection
//! ([`chaos`]): the supervisor's writes, the reader's receives, and
//! the worker's response writes each pass through a [`chaos::ChaosLink`]
//! when `--chaos` is set, and the timed partition schedule gates the
//! connect loop. Silent drops are recovered by resend-on-timeout
//! ([`NetConfig::resend_ms`], armed only under chaos so clean runs
//! stay bit-identical); a request resent more than
//! [`NetConfig::max_resends`] times breaks the session and burns
//! reconnect budget, so a black-holed link still ends as an in-band
//! crash-stop — never a hang.

pub mod chaos;
pub mod frame;
pub mod server;

use std::collections::BTreeMap;
use std::io::{BufReader, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::super::compress::Compressor;
use super::super::worker::{Response, Symbol};
use super::super::WorkerId;
use super::{Delivery, LinkStats, NetStats, RemoteSpan, TaskBundle, Transport};
use crate::config::AttackConfig;
use crate::grad::ModelSpec;
use crate::Result;

use chaos::{ChaosLink, ChaosSpec, SendOp, CHANNEL_MASTER_RECV, CHANNEL_MASTER_SEND};
use frame::{
    body_is_telemetry, decode_body_auth, encode_frame, read_frame_auth, read_raw_body,
    write_frame_auth, AuthKey, Frame, Hello, NetGrad, NetRequest, NetResponse, TelemetryBatch,
};

/// Injectable sleep, so backoff/chaos timing is observable in tests
/// (record the durations) instead of slept through for real.
pub type SleepFn = Arc<dyn Fn(Duration) + Send + Sync>;

/// Master-side configuration for one [`NetTransport`].
pub struct NetConfig {
    /// One `host:port` per worker; local id = index, global id =
    /// `lo + index`.
    pub peers: Vec<String>,
    /// Global id of local worker 0 (shard inner transports pass their
    /// range offset; flat runs pass 0).
    pub lo: WorkerId,
    /// Run seed, forwarded so remote Byzantine RNGs match in-process
    /// ones.
    pub seed: u64,
    /// Artificial per-request compute delay (µs) applied worker-side.
    pub latency_us: u64,
    /// Scripted attack given to the workers in `byzantine_ids`.
    pub attack: Option<AttackConfig>,
    /// *Global* ids of scripted-Byzantine workers.
    pub byzantine_ids: Vec<WorkerId>,
    /// Gradient compressor; its [`Compressor::spec`] is forwarded in
    /// the hello so the worker builds an identical one.
    pub compressor: Option<Arc<dyn Compressor>>,
    /// Model the workers instantiate their engines from.
    pub model: ModelSpec,
    /// Connection attempts per outage before the worker is declared
    /// crash-stopped (the budget tolerates exactly this many
    /// consecutive failures; see [`ReconnectBudget`]).
    pub max_attempts: u32,
    /// Base reconnect backoff (doubles per attempt, capped at 16×).
    pub backoff_ms: u64,
    /// Outbound queue depth per worker (bounded backpressure).
    pub outbound_depth: usize,
    /// Master-side fault injection (None = clean wire). Seeded from
    /// [`NetConfig::seed`], per-link streams — replayable storms.
    pub chaos: Option<ChaosSpec>,
    /// Shared frame-authentication key (None = legacy unauthenticated
    /// wire, bit-identical to PR 8).
    pub auth: Option<AuthKey>,
    /// With chaos active: resend an unacknowledged request after this
    /// many ms on a live session (silent-drop recovery). Ignored on a
    /// clean wire, where TCP itself guarantees delivery or breakage.
    pub resend_ms: u64,
    /// With chaos active: a request resent this many times without an
    /// ack breaks the session (burning reconnect budget), so a
    /// black-holed link becomes a crash-stop instead of a hang.
    pub max_resends: u32,
    /// Injectable sleep for backoff/chaos delays (None = real sleep).
    pub sleep: Option<SleepFn>,
    /// Ask workers for telemetry (worker-side spans + clock samples,
    /// shipped back in `Telemetry` frames). Off = the PR 8/9 wire,
    /// byte-identical.
    pub telemetry: bool,
}

impl NetConfig {
    pub fn new(peers: Vec<String>, model: ModelSpec) -> NetConfig {
        NetConfig {
            peers,
            lo: 0,
            seed: 0,
            latency_us: 0,
            attack: None,
            byzantine_ids: Vec::new(),
            compressor: None,
            model,
            max_attempts: 5,
            backoff_ms: 25,
            outbound_depth: 4,
            chaos: None,
            auth: None,
            resend_ms: 400,
            max_resends: 10,
            sleep: None,
            telemetry: false,
        }
    }
}

/// Per-outage reconnect budget with capped exponential backoff,
/// extracted so the edge semantics are unit-testable without sockets
/// or sleeps: the budget tolerates exactly `max_attempts` consecutive
/// failures (each returning the backoff to wait), the
/// `max_attempts + 1`-th failure is terminal (`None` — the caller
/// crash-stops the worker), and any completed handshake refills it.
pub struct ReconnectBudget {
    max_attempts: u32,
    backoff_ms: u64,
    failures: u32,
}

impl ReconnectBudget {
    pub fn new(max_attempts: u32, backoff_ms: u64) -> ReconnectBudget {
        ReconnectBudget {
            max_attempts: max_attempts.max(1),
            backoff_ms: backoff_ms.max(1),
            failures: 0,
        }
    }

    /// Record one failed attempt. `Some(ms)` = sleep that long and try
    /// again; `None` = budget exhausted. Backoff doubles per
    /// consecutive failure, capped at 16× the base.
    pub fn on_failure(&mut self) -> Option<u64> {
        self.failures += 1;
        if self.failures > self.max_attempts {
            return None;
        }
        let exp = (self.failures - 1).min(4);
        Some(self.backoff_ms << exp)
    }

    /// The outage is over (handshake completed): refill the budget.
    pub fn on_success(&mut self) {
        self.failures = 0;
    }

    /// True once [`ReconnectBudget::on_failure`] has returned `None`.
    pub fn exhausted(&self) -> bool {
        self.failures > self.max_attempts
    }
}

/// Cumulative socket counters shared by every supervisor/reader.
#[derive(Default)]
struct Counters {
    bytes_tx: AtomicU64,
    bytes_rx: AtomicU64,
    reconnects: AtomicU64,
}

/// Remote spans buffered per link between `drain_remote_spans` calls
/// are bounded; excess is dropped and counted, never accumulated.
const MAX_LINK_SPANS: usize = 4096;

/// NTP-style send-stamp map entries are pruned beyond this many
/// outstanding seqs (a leak is only possible via chaos drops).
const MAX_CLOCK_STAMPS: usize = 8192;

/// Per-link telemetry state shared between the supervisor (send
/// stamps, resend counts), the session reader (batch ingestion, clock
/// refinement) and the transport (drain/snapshot). All master-side.
///
/// Clock model: the worker runs its own monotonic clock; `offset_ns`
/// estimates `worker_clock - master_clock`. The handshake seeds it at
/// the RTT midpoint (the ack's clock sample against the master's
/// hello-send/ack-recv stamps), and every telemetry batch refines it
/// with a classic two-sample NTP step over the request's
/// `(t0 = master send, t1 = worker recv, t2 = worker send,
/// t3 = master recv)` quadruple, EWMA-smoothed (α = 1/8). Worker span
/// stamps are remapped as `master_ns = worker_ns - offset` at
/// ingestion time.
struct LinkShared {
    /// Sessions re-established on this link.
    reconnects: AtomicU64,
    /// Master-side request resends (reconnect replays + chaos
    /// resend-on-timeout).
    resends: AtomicU64,
    /// True once any clock sample exists (offset/rtt are meaningful).
    have_clock: AtomicBool,
    /// EWMA of `worker_clock - master_clock`, ns.
    offset_ns: AtomicI64,
    /// EWMA link round-trip, ns.
    rtt_ns: AtomicU64,
    // worker-reported cumulative counters (latest batch wins: the
    // worker ships totals, not deltas)
    w_requests: AtomicU64,
    w_dup_requests: AtomicU64,
    w_auth_rejects: AtomicU64,
    w_chaos_hits: AtomicU64,
    w_queue_depth: AtomicU64,
    w_dropped_spans: AtomicU64,
    /// Spans dropped master-side to keep the buffer bounded.
    m_dropped_spans: AtomicU64,
    /// Master-clock send stamp per outstanding seq (NTP t0).
    send_ns: Mutex<BTreeMap<u64, u64>>,
    /// Clock-remapped worker spans awaiting a drain.
    spans: Mutex<Vec<RemoteSpan>>,
}

impl LinkShared {
    fn new() -> LinkShared {
        LinkShared {
            reconnects: AtomicU64::new(0),
            resends: AtomicU64::new(0),
            have_clock: AtomicBool::new(false),
            offset_ns: AtomicI64::new(0),
            rtt_ns: AtomicU64::new(0),
            w_requests: AtomicU64::new(0),
            w_dup_requests: AtomicU64::new(0),
            w_auth_rejects: AtomicU64::new(0),
            w_chaos_hits: AtomicU64::new(0),
            w_queue_depth: AtomicU64::new(0),
            w_dropped_spans: AtomicU64::new(0),
            m_dropped_spans: AtomicU64::new(0),
            send_ns: Mutex::new(BTreeMap::new()),
            spans: Mutex::new(Vec::new()),
        }
    }

    /// Record a request's master-clock send stamp (overwritten on
    /// resend: the latest transmit is the one the response answers).
    fn note_send(&self, seq: u64, master_ns: u64) {
        let mut m = self.send_ns.lock().expect("send_ns lock");
        m.insert(seq, master_ns);
        if m.len() > MAX_CLOCK_STAMPS {
            if let Some(&cut) = m.keys().nth(MAX_CLOCK_STAMPS / 2) {
                *m = m.split_off(&cut);
            }
        }
    }

    /// Seed the clock estimate from the handshake: the worker stamped
    /// its ack at `worker_clock`, between the master's hello-send `t0`
    /// and ack-recv `t3` — assume the RTT midpoint.
    fn init_clock(&self, worker_clock: u64, t0: u64, t3: u64) {
        let mid = (t0 / 2) + (t3 / 2);
        self.offset_ns
            .store((worker_clock as i128 - mid as i128) as i64, Ordering::Relaxed);
        self.rtt_ns.store(t3.saturating_sub(t0), Ordering::Relaxed);
        self.have_clock.store(true, Ordering::Release);
    }

    /// One NTP refinement step from a request's clock quadruple.
    fn refine_clock(&self, t0: u64, t1_w: u64, t2_w: u64, t3: u64) {
        let off = ((t1_w as i128 - t0 as i128) + (t2_w as i128 - t3 as i128)) / 2;
        let rtt = t3.saturating_sub(t0).saturating_sub(t2_w.saturating_sub(t1_w));
        if !self.have_clock.load(Ordering::Acquire) {
            self.offset_ns.store(off as i64, Ordering::Relaxed);
            self.rtt_ns.store(rtt, Ordering::Relaxed);
            self.have_clock.store(true, Ordering::Release);
            return;
        }
        let old = self.offset_ns.load(Ordering::Relaxed) as i128;
        self.offset_ns.store((old + (off - old) / 8) as i64, Ordering::Relaxed);
        let old_rtt = self.rtt_ns.load(Ordering::Relaxed) as i128;
        self.rtt_ns
            .store((old_rtt + (rtt as i128 - old_rtt) / 8) as u64, Ordering::Relaxed);
    }

    /// Worker-clock ns → master-clock ns via the current offset
    /// estimate (clamped at the transport's birth).
    fn to_master_ns(&self, worker_ns: u64) -> u64 {
        let off = self.offset_ns.load(Ordering::Relaxed) as i128;
        (worker_ns as i128 - off).max(0) as u64
    }

    /// Fold one telemetry batch in: refine the clock from its request
    /// stamps (against our recorded sends and its arrival time), store
    /// the worker's cumulative counters, and buffer its spans remapped
    /// onto the master clock.
    fn ingest_batch(&self, batch: TelemetryBatch, local: WorkerId, arrival_ns: u64) {
        {
            let mut m = self.send_ns.lock().expect("send_ns lock");
            for (seq, t1_w, t2_w) in &batch.req_clock {
                if let Some(t0) = m.remove(seq) {
                    self.refine_clock(t0, *t1_w, *t2_w, arrival_ns);
                }
            }
        }
        self.w_requests.store(batch.requests, Ordering::Relaxed);
        self.w_dup_requests.store(batch.dup_requests, Ordering::Relaxed);
        self.w_auth_rejects.store(batch.auth_rejects, Ordering::Relaxed);
        self.w_chaos_hits.store(batch.chaos_hits, Ordering::Relaxed);
        self.w_queue_depth.store(batch.queue_depth, Ordering::Relaxed);
        self.w_dropped_spans.store(batch.dropped_spans, Ordering::Relaxed);
        let mut buf = self.spans.lock().expect("spans lock");
        for s in batch.spans {
            if buf.len() >= MAX_LINK_SPANS {
                self.m_dropped_spans.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            buf.push(RemoteSpan {
                worker: local,
                kind: s.kind,
                iter: s.iter,
                wave: s.wave,
                chunk: s.chunk,
                start_ns: self.to_master_ns(s.start_ns),
                end_ns: self.to_master_ns(s.end_ns),
            });
        }
    }

    fn snapshot(&self, local: WorkerId) -> LinkStats {
        LinkStats {
            worker: local,
            rtt_ns: self.rtt_ns.load(Ordering::Relaxed),
            offset_ns: self.offset_ns.load(Ordering::Relaxed),
            reconnects: self.reconnects.load(Ordering::Relaxed),
            resends: self.resends.load(Ordering::Relaxed),
            auth_rejects: self.w_auth_rejects.load(Ordering::Relaxed),
            requests: self.w_requests.load(Ordering::Relaxed),
            dup_requests: self.w_dup_requests.load(Ordering::Relaxed),
            chaos_hits: self.w_chaos_hits.load(Ordering::Relaxed),
            queue_depth: self.w_queue_depth.load(Ordering::Relaxed),
            dropped_spans: self.w_dropped_spans.load(Ordering::Relaxed)
                + self.m_dropped_spans.load(Ordering::Relaxed),
        }
    }
}

/// Supervisor/reader → master events.
enum NetEvent {
    Resp(NetResponse),
    /// `count` owed deliveries will never arrive: the worker is
    /// crash-stopped (reconnect budget exhausted).
    Failed { worker: WorkerId, count: usize },
    /// A session was re-established (metrics/trace only).
    Reconnect { worker: WorkerId },
}

struct SupervisorCtx {
    worker: WorkerId,
    addr: String,
    hello: Hello,
    cmd_rx: Receiver<NetRequest>,
    events: Sender<NetEvent>,
    counters: Arc<Counters>,
    /// Requests written but not yet answered, by sequence number —
    /// exactly what a fresh session must resend.
    unacked: Arc<Mutex<BTreeMap<u64, NetRequest>>>,
    max_attempts: u32,
    backoff_ms: u64,
    /// Fault injection for this link (None = clean wire).
    chaos: Option<ChaosSpec>,
    /// Frame authentication key (None = legacy wire).
    auth: Option<AuthKey>,
    /// Run seed: chaos streams key on (seed, global id, channel).
    seed: u64,
    /// Transport birth instant — the partition schedule's clock zero,
    /// shared by every link so partitions are fleet-synchronized.
    origin: Instant,
    resend_ms: u64,
    max_resends: u32,
    sleep: SleepFn,
    /// This link's telemetry/clock state (shared with the transport).
    shared: Arc<LinkShared>,
}

/// TCP-backed [`Transport`]: one connection actor per worker.
pub struct NetTransport {
    n: usize,
    /// Dense gradient dimension (`model.param_dim()`): what compressed
    /// symbol payloads must decode to.
    d: usize,
    compressor: Option<Arc<dyn Compressor>>,
    cmd_txs: Vec<Option<SyncSender<NetRequest>>>,
    events_rx: Receiver<NetEvent>,
    handles: Vec<JoinHandle<()>>,
    /// Deliveries owed via the events channel.
    in_flight: usize,
    /// Deliveries already due (submits to known-dead workers).
    pending: Vec<Delivery>,
    dead: Vec<bool>,
    next_seq: u64,
    reconnect_log: Vec<(u64, WorkerId)>,
    counters: Arc<Counters>,
    /// Per-link telemetry/clock state, indexed by local worker id.
    links: Vec<Arc<LinkShared>>,
    origin: Instant,
}

impl NetTransport {
    /// Spawn one supervisor per peer. Returns immediately: connections
    /// are established concurrently by the supervisors, and a peer
    /// that never comes up surfaces as an in-band crash-stop once its
    /// reconnect budget runs out.
    pub fn connect(cfg: NetConfig) -> Result<NetTransport> {
        let n = cfg.peers.len();
        if n == 0 {
            anyhow::bail!("net transport needs at least one peer");
        }
        let d = cfg.model.param_dim();
        let (events_tx, events_rx) = channel::<NetEvent>();
        let counters = Arc::new(Counters::default());
        let origin = Instant::now();
        let chaos = cfg.chaos.filter(|s| !s.is_noop());
        let sleep: SleepFn = cfg.sleep.clone().unwrap_or_else(|| Arc::new(std::thread::sleep));
        let mut cmd_txs = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        let links: Vec<Arc<LinkShared>> = (0..n).map(|_| Arc::new(LinkShared::new())).collect();
        for (i, addr) in cfg.peers.iter().enumerate() {
            let global = cfg.lo + i;
            let byzantine = if cfg.byzantine_ids.contains(&global) {
                cfg.attack.clone()
            } else {
                None
            };
            let hello = Hello {
                local_id: i as u64,
                global_id: global as u64,
                seed: cfg.seed,
                latency_us: cfg.latency_us,
                byzantine,
                compressor: cfg.compressor.as_ref().map(|c| c.spec()),
                model: cfg.model.clone(),
                telemetry: cfg.telemetry,
            };
            let (cmd_tx, cmd_rx) = sync_channel::<NetRequest>(cfg.outbound_depth.max(1));
            cmd_txs.push(Some(cmd_tx));
            let ctx = SupervisorCtx {
                worker: i,
                addr: addr.clone(),
                hello,
                cmd_rx,
                events: events_tx.clone(),
                counters: counters.clone(),
                unacked: Arc::new(Mutex::new(BTreeMap::new())),
                max_attempts: cfg.max_attempts.max(1),
                backoff_ms: cfg.backoff_ms.max(1),
                chaos,
                auth: cfg.auth,
                seed: cfg.seed,
                origin,
                resend_ms: cfg.resend_ms.max(1),
                max_resends: cfg.max_resends.max(1),
                sleep: sleep.clone(),
                shared: links[i].clone(),
            };
            handles.push(
                std::thread::Builder::new()
                    .name(format!("r3bft-net-{i}"))
                    .spawn(move || run_supervisor(ctx))
                    .expect("spawn net supervisor"),
            );
        }
        Ok(NetTransport {
            n,
            d,
            compressor: cfg.compressor,
            cmd_txs,
            events_rx,
            handles,
            in_flight: 0,
            pending: Vec::new(),
            dead: vec![false; n],
            next_seq: 0,
            reconnect_log: Vec::new(),
            counters,
            links,
            origin,
        })
    }

    fn note_reconnect(&mut self, worker: WorkerId) {
        let at = self.now_ns();
        log::info!("worker {worker}: session re-established");
        self.reconnect_log.push((at, worker));
    }

    /// Decode one response into a delivery. A worker-reported engine
    /// error or a malformed symbol payload is that worker's
    /// crash-stop, mirroring [`super::ThreadedTransport`].
    fn to_delivery(&self, r: NetResponse, at_ns: u64) -> Delivery {
        let worker = r.worker as WorkerId;
        if let Some(err) = &r.error {
            log::warn!("worker {worker} failed: {err}");
            return Delivery::Failed { at_ns, worker };
        }
        let mut symbols = Vec::with_capacity(r.symbols.len());
        for s in r.symbols {
            let (grad, wire) = match (s.grad, &self.compressor) {
                (NetGrad::Wire(w), Some(c)) => match c.try_unpack(&w, self.d) {
                    Ok(g) => (g, Some(w)),
                    Err(e) => {
                        log::warn!("worker {worker}: undecodable symbol wire: {e:#}");
                        return Delivery::Failed { at_ns, worker };
                    }
                },
                (NetGrad::Dense(g), None) => {
                    if g.len() != self.d {
                        log::warn!("worker {worker}: symbol dim {} != {}", g.len(), self.d);
                        return Delivery::Failed { at_ns, worker };
                    }
                    (g, None)
                }
                (_, _) => {
                    log::warn!("worker {worker}: symbol encoding disagrees with compressor config");
                    return Delivery::Failed { at_ns, worker };
                }
            };
            symbols.push(Symbol {
                chunk: s.chunk as usize,
                grad,
                loss: s.loss,
                tampered: s.tampered,
                wire,
            });
        }
        Delivery::Response {
            at_ns,
            response: Response {
                worker,
                iter: r.iter,
                phase: r.phase,
                wave: r.wave,
                symbols,
                error: None,
            },
        }
    }

    /// Fold one delivery-producing event into `out`. Returns how many
    /// deliveries it yielded (a budget-exhausted notice for a worker
    /// with nothing owed yields zero).
    fn ingest(&mut self, ev: NetEvent, out: &mut Vec<Delivery>) -> usize {
        match ev {
            NetEvent::Reconnect { worker } => {
                self.note_reconnect(worker);
                0
            }
            NetEvent::Resp(r) => {
                self.in_flight = self.in_flight.saturating_sub(1);
                let at = self.now_ns();
                out.push(self.to_delivery(r, at));
                1
            }
            NetEvent::Failed { worker, count } => {
                if !self.dead[worker] {
                    log::warn!("worker {worker}: connection lost for good (crash-stop)");
                }
                self.dead[worker] = true;
                let count = count.min(self.in_flight);
                self.in_flight -= count;
                let at = self.now_ns();
                for _ in 0..count {
                    out.push(Delivery::Failed { at_ns: at, worker });
                }
                count
            }
        }
    }
}

impl Transport for NetTransport {
    fn n(&self) -> usize {
        self.n
    }

    fn now_ns(&self) -> u64 {
        self.origin.elapsed().as_nanos() as u64
    }

    fn submit(
        &mut self,
        iter: u64,
        phase: u32,
        wave: u64,
        theta: &Arc<Vec<f32>>,
        bundles: Vec<TaskBundle>,
    ) -> Result<()> {
        for TaskBundle { worker, tasks } in bundles {
            if worker >= self.n {
                anyhow::bail!("submit to unknown worker {worker} (n = {})", self.n);
            }
            if self.dead[worker] {
                // crash-stopped: owe the failure directly, nothing to send
                let at = self.now_ns();
                self.pending.push(Delivery::Failed { at_ns: at, worker });
                continue;
            }
            let req = NetRequest {
                seq: self.next_seq,
                iter,
                phase,
                wave,
                theta: theta.as_ref().clone(),
                tasks: tasks.into_iter().map(|(c, b)| (c as u64, b)).collect(),
            };
            self.next_seq += 1;
            // bounded channel: blocks when the worker's outbound queue
            // is full (backpressure), errs only if the supervisor died
            let sent = match &self.cmd_txs[worker] {
                Some(tx) => tx.send(req).is_ok(),
                None => false,
            };
            if sent {
                self.in_flight += 1;
            } else {
                self.dead[worker] = true;
                let at = self.now_ns();
                self.pending.push(Delivery::Failed { at_ns: at, worker });
            }
        }
        Ok(())
    }

    fn poll(&mut self, deadline_ns: Option<u64>) -> Result<Vec<Delivery>> {
        let mut out: Vec<Delivery> = Vec::new();
        // failures recorded at submit time are already due
        if !self.pending.is_empty() {
            out.append(&mut self.pending);
            out.sort_by_key(|d| d.worker());
            return Ok(out);
        }
        if self.in_flight == 0 {
            return Ok(out);
        }
        // block for the first delivery-producing event, bounded by the
        // deadline; reconnect notices and zero-yield failure notices
        // are folded in without ending the wait
        loop {
            let ev = match deadline_ns {
                None => match self.events_rx.recv() {
                    Ok(ev) => Some(ev),
                    Err(_) => anyhow::bail!("all worker connections gone"),
                },
                Some(d) => {
                    let now = self.now_ns();
                    if d <= now {
                        // past the deadline: hand over whatever already
                        // arrived, never block
                        self.events_rx.try_recv().ok()
                    } else {
                        match self.events_rx.recv_timeout(Duration::from_nanos(d - now)) {
                            Ok(ev) => Some(ev),
                            Err(RecvTimeoutError::Timeout) => None,
                            Err(RecvTimeoutError::Disconnected) => {
                                anyhow::bail!("all worker connections gone")
                            }
                        }
                    }
                }
            };
            match ev {
                None => return Ok(out), // deadline passed
                Some(ev) => {
                    if self.ingest(ev, &mut out) > 0 {
                        break;
                    }
                    // zero-yield event: keep waiting (deadline re-checked)
                }
            }
        }
        // drain whatever else is already ready, without blocking
        while self.in_flight > 0 {
            match self.events_rx.try_recv() {
                Ok(ev) => {
                    self.ingest(ev, &mut out);
                }
                Err(_) => break,
            }
        }
        out.sort_by_key(|d| d.worker());
        Ok(out)
    }

    fn shutdown(&mut self) {
        // dropping the senders makes each supervisor send a Shutdown
        // frame to its worker and exit
        for tx in self.cmd_txs.iter_mut() {
            *tx = None;
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        self.in_flight = 0;
        self.pending.clear();
    }

    fn net_stats(&self) -> Option<NetStats> {
        Some(NetStats {
            bytes_tx: self.counters.bytes_tx.load(Ordering::Relaxed),
            bytes_rx: self.counters.bytes_rx.load(Ordering::Relaxed),
            reconnects: self.counters.reconnects.load(Ordering::Relaxed),
        })
    }

    fn drain_reconnects(&mut self) -> Vec<(u64, WorkerId)> {
        std::mem::take(&mut self.reconnect_log)
    }

    fn drain_remote_spans(&mut self) -> Vec<RemoteSpan> {
        let mut out = Vec::new();
        for link in &self.links {
            out.append(&mut link.spans.lock().expect("spans lock"));
        }
        out
    }

    fn link_stats(&self) -> Vec<LinkStats> {
        self.links.iter().enumerate().map(|(i, l)| l.snapshot(i)).collect()
    }
}

impl Drop for NetTransport {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ------------------------------------------------------- supervisor

/// One session attempt: connect, handshake, spawn the reader, resend
/// unacked requests, then serve the write loop until the session or
/// the master goes away.
enum SessionEnd {
    /// Connection broke — reconnect (resending unacked).
    Broken,
    /// Master dropped the command channel — send Shutdown and exit.
    MasterGone,
}

/// Put one pre-encoded frame on the wire, through the link's chaos
/// plan when one is active. Returns the bytes actually written
/// (duplicates and torn prefixes included — they hit the wire, so the
/// honest accounting counts them); `Err` means the session is over
/// (write failure or a chaos kill).
fn send_wire(
    stream: &mut TcpStream,
    link: Option<&mut ChaosLink>,
    sleep: &SleepFn,
    wire: &[u8],
) -> Result<u64> {
    let Some(link) = link else {
        stream.write_all(wire)?;
        stream.flush()?;
        return Ok(wire.len() as u64);
    };
    let mut nb = 0u64;
    for op in link.plan_send(wire) {
        match op {
            SendOp::Sleep(d) => sleep(d),
            SendOp::Write(b) => {
                stream.write_all(&b)?;
                nb += b.len() as u64;
            }
            SendOp::WritePrefix(b, cut) => {
                let _ = stream.write_all(&b[..cut]);
                nb += cut as u64;
            }
            SendOp::Kill => {
                let _ = stream.flush();
                let _ = stream.shutdown(Shutdown::Both);
                anyhow::bail!("chaos killed the connection");
            }
        }
    }
    stream.flush()?;
    Ok(nb)
}

fn run_supervisor(ctx: SupervisorCtx) {
    let mut budget = ReconnectBudget::new(ctx.max_attempts, ctx.backoff_ms);
    let mut first_session = true;
    // chaos links persist across sessions so a storm doesn't restart
    // from its first coin at every reconnect
    let global = ctx.hello.global_id;
    let mut send_link = ctx
        .chaos
        .map(|s| ChaosLink::new(s, ctx.seed, global, CHANNEL_MASTER_SEND));
    let recv_link = ctx
        .chaos
        .map(|s| Arc::new(Mutex::new(ChaosLink::new(s, ctx.seed, global, CHANNEL_MASTER_RECV))));
    // per-seq live-session resend bookkeeping (chaos only)
    let mut sent_at: BTreeMap<u64, Instant> = BTreeMap::new();
    let mut resend_counts: BTreeMap<u64, u32> = BTreeMap::new();
    loop {
        // connect, gated by the partition schedule, with capped
        // exponential backoff; each failed attempt (or partitioned
        // tick) burns budget, so an outage longer than the budget's
        // total wait becomes a crash-stop
        let stream = loop {
            let partitioned = ctx
                .chaos
                .map(|s| s.partitioned(ctx.origin.elapsed()))
                .unwrap_or(false);
            let attempt = if partitioned {
                Err(std::io::Error::new(
                    std::io::ErrorKind::NotConnected,
                    "link partitioned",
                ))
            } else {
                TcpStream::connect(&ctx.addr)
            };
            match attempt {
                Ok(s) => break Some(s),
                Err(e) => match budget.on_failure() {
                    Some(backoff_ms) => (ctx.sleep)(Duration::from_millis(backoff_ms)),
                    None => {
                        log::warn!("worker {} @ {}: connect failed: {e}", ctx.worker, ctx.addr);
                        break None;
                    }
                },
            }
        };
        let stream = match stream {
            Some(s) => s,
            None => return fail_forever(&ctx),
        };
        let end = run_session(
            &ctx,
            stream,
            first_session,
            &mut budget,
            send_link.as_mut(),
            recv_link.clone(),
            &mut sent_at,
            &mut resend_counts,
        );
        match end {
            SessionEnd::MasterGone => return,
            SessionEnd::Broken => match budget.on_failure() {
                Some(backoff_ms) => {
                    first_session = false;
                    (ctx.sleep)(Duration::from_millis(backoff_ms));
                }
                None => return fail_forever(&ctx),
            },
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn run_session(
    ctx: &SupervisorCtx,
    mut stream: TcpStream,
    first: bool,
    budget: &mut ReconnectBudget,
    mut send_link: Option<&mut ChaosLink>,
    recv_link: Option<Arc<Mutex<ChaosLink>>>,
    sent_at: &mut BTreeMap<u64, Instant>,
    resend_counts: &mut BTreeMap<u64, u32>,
) -> SessionEnd {
    let _ = stream.set_nodelay(true);
    // a session that dies mid-write must also unblock its reader
    // thread, which may be parked in a blocking read on the same socket
    let broken = |stream: &TcpStream| {
        let _ = stream.shutdown(Shutdown::Both);
        SessionEnd::Broken
    };
    // handshake: Hello out, HelloAck back. Exempt from per-frame chaos
    // (the partition schedule already gates connects), so a chaotic
    // run exercises the steady state instead of never booting; the
    // MAC is still on — an unauthenticated worker refuses us here.
    // With telemetry on, the hello-send/ack-recv stamps bracket the
    // worker's ack clock sample: the seed of this link's offset
    // estimate (NTP midpoint assumption).
    let t0 = ctx.origin.elapsed().as_nanos() as u64;
    match write_frame_auth(&mut stream, &Frame::Hello(ctx.hello.clone()), ctx.auth.as_ref()) {
        Ok(nb) => ctx.counters.bytes_tx.fetch_add(nb, Ordering::Relaxed),
        Err(e) => {
            log::warn!("worker {}: hello write failed: {e:#}", ctx.worker);
            return broken(&stream);
        }
    };
    match read_frame_auth(&mut stream, ctx.auth.as_ref()) {
        Ok(Some((Frame::HelloAck { global_id, clock_ns }, nb)))
            if global_id == ctx.hello.global_id =>
        {
            ctx.counters.bytes_rx.fetch_add(nb, Ordering::Relaxed);
            if let Some(worker_clock) = clock_ns {
                let t3 = ctx.origin.elapsed().as_nanos() as u64;
                ctx.shared.init_clock(worker_clock, t0, t3);
            }
        }
        Ok(_) | Err(_) => {
            log::warn!("worker {}: bad hello ack", ctx.worker);
            return broken(&stream);
        }
    }
    // handshake done: the outage (if any) is over, refill the budget
    budget.on_success();
    if !first {
        ctx.counters.reconnects.fetch_add(1, Ordering::Relaxed);
        ctx.shared.reconnects.fetch_add(1, Ordering::Relaxed);
        let _ = ctx.events.send(NetEvent::Reconnect { worker: ctx.worker });
    }
    // reader for this session (clears `alive` when the session dies)
    let alive = Arc::new(AtomicBool::new(true));
    let reader_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(e) => {
            log::warn!("worker {}: stream clone failed: {e}", ctx.worker);
            return broken(&stream);
        }
    };
    {
        let alive = alive.clone();
        let events = ctx.events.clone();
        let unacked = ctx.unacked.clone();
        let counters = ctx.counters.clone();
        let auth = ctx.auth;
        let recv_link = recv_link.clone();
        let worker = ctx.worker;
        let shared = ctx.shared.clone();
        let origin = ctx.origin;
        let telemetry = ctx.hello.telemetry;
        std::thread::Builder::new()
            .name(format!("r3bft-net-read-{worker}"))
            .spawn(move || {
                run_reader(ReaderCtx {
                    stream: reader_stream,
                    alive,
                    events,
                    unacked,
                    counters,
                    auth,
                    recv_link,
                    shared,
                    worker,
                    origin,
                    telemetry,
                })
            })
            .expect("spawn net reader");
    }
    // a fresh session starts by resending everything unanswered, in
    // sequence order (the worker recomputes deterministically, and the
    // reader's seq dedup keeps every request to exactly one delivery)
    let resend: Vec<NetRequest> = {
        let m = ctx.unacked.lock().expect("unacked lock");
        m.values().cloned().collect()
    };
    for req in resend {
        let seq = req.seq;
        let wire = match encode_frame(&Frame::Request(req), ctx.auth.as_ref()) {
            Ok(w) => w,
            Err(_) => return broken(&stream),
        };
        sent_at.insert(seq, Instant::now());
        ctx.shared.resends.fetch_add(1, Ordering::Relaxed);
        if ctx.hello.telemetry {
            ctx.shared.note_send(seq, ctx.origin.elapsed().as_nanos() as u64);
        }
        match send_wire(&mut stream, send_link.as_deref_mut(), &ctx.sleep, &wire) {
            Ok(nb) => ctx.counters.bytes_tx.fetch_add(nb, Ordering::Relaxed),
            Err(_) => return broken(&stream),
        }
    }
    // write loop; the timeout tick doubles as the resend-on-timeout
    // and partition watchdog under chaos — requests themselves are
    // written immediately
    loop {
        if !alive.load(Ordering::Acquire) {
            return broken(&stream);
        }
        if let Some(spec) = &ctx.chaos {
            if spec.partitioned(ctx.origin.elapsed()) {
                log::info!("worker {}: chaos partition opened, dropping session", ctx.worker);
                return broken(&stream);
            }
        }
        match ctx.cmd_rx.recv_timeout(Duration::from_millis(20)) {
            Ok(req) => {
                let seq = req.seq;
                ctx.unacked.lock().expect("unacked lock").insert(seq, req.clone());
                let wire = match encode_frame(&Frame::Request(req), ctx.auth.as_ref()) {
                    Ok(w) => w,
                    Err(_) => return broken(&stream),
                };
                sent_at.insert(seq, Instant::now());
                if ctx.hello.telemetry {
                    ctx.shared.note_send(seq, ctx.origin.elapsed().as_nanos() as u64);
                }
                match send_wire(&mut stream, send_link.as_deref_mut(), &ctx.sleep, &wire) {
                    Ok(nb) => ctx.counters.bytes_tx.fetch_add(nb, Ordering::Relaxed),
                    Err(_) => return broken(&stream),
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                // silent-drop recovery, chaos only: resend anything
                // unacknowledged for longer than resend_ms; a request
                // that keeps vanishing breaks the session (and with
                // it, eventually, the reconnect budget) — black holes
                // become crash-stops, never hangs
                if ctx.chaos.is_some() {
                    let now = Instant::now();
                    let due: Vec<NetRequest> = {
                        let m = ctx.unacked.lock().expect("unacked lock");
                        sent_at.retain(|seq, _| m.contains_key(seq));
                        resend_counts.retain(|seq, _| m.contains_key(seq));
                        m.values()
                            .filter(|r| match sent_at.get(&r.seq) {
                                Some(t) => {
                                    let waited = now.duration_since(*t).as_millis() as u64;
                                    waited >= ctx.resend_ms
                                }
                                None => true,
                            })
                            .cloned()
                            .collect()
                    };
                    for req in due {
                        let seq = req.seq;
                        let count = resend_counts.entry(seq).or_insert(0);
                        *count += 1;
                        if *count > ctx.max_resends {
                            log::warn!(
                                "worker {}: request seq {seq} resent {} times without an ack",
                                ctx.worker,
                                ctx.max_resends
                            );
                            return broken(&stream);
                        }
                        let wire = match encode_frame(&Frame::Request(req), ctx.auth.as_ref()) {
                            Ok(w) => w,
                            Err(_) => return broken(&stream),
                        };
                        sent_at.insert(seq, now);
                        ctx.shared.resends.fetch_add(1, Ordering::Relaxed);
                        if ctx.hello.telemetry {
                            ctx.shared.note_send(seq, ctx.origin.elapsed().as_nanos() as u64);
                        }
                        match send_wire(&mut stream, send_link.as_deref_mut(), &ctx.sleep, &wire) {
                            Ok(nb) => ctx.counters.bytes_tx.fetch_add(nb, Ordering::Relaxed),
                            Err(_) => return broken(&stream),
                        }
                    }
                }
                continue;
            }
            Err(RecvTimeoutError::Disconnected) => {
                // Teardown is harness traffic, chaos-exempt like the
                // handshake: a dropped Shutdown would strand the worker
                // process in its accept loop forever.
                if let Ok(wire) = encode_frame(&Frame::Shutdown, ctx.auth.as_ref()) {
                    if let Ok(nb) = send_wire(&mut stream, None, &ctx.sleep, &wire) {
                        ctx.counters.bytes_tx.fetch_add(nb, Ordering::Relaxed);
                    }
                }
                return SessionEnd::MasterGone;
            }
        }
    }
}

/// Everything one session reader needs (bundled: the list outgrew a
/// readable argument spread).
struct ReaderCtx {
    stream: TcpStream,
    alive: Arc<AtomicBool>,
    events: Sender<NetEvent>,
    unacked: Arc<Mutex<BTreeMap<u64, NetRequest>>>,
    counters: Arc<Counters>,
    auth: Option<AuthKey>,
    recv_link: Option<Arc<Mutex<ChaosLink>>>,
    shared: Arc<LinkShared>,
    worker: WorkerId,
    /// Transport birth instant (batch arrival stamps — the NTP t3).
    origin: Instant,
    telemetry: bool,
}

fn run_reader(ctx: ReaderCtx) {
    let ReaderCtx {
        stream,
        alive,
        events,
        unacked,
        counters,
        auth,
        recv_link,
        shared,
        worker,
        origin,
        telemetry,
    } = ctx;
    let mut r = BufReader::new(stream);
    'session: loop {
        // raw body first: inbound chaos operates on the received bytes
        // before MAC verification/decode, exactly where a hostile
        // network sits
        let (raw, nb) = match read_raw_body(&mut r) {
            Ok(Some(x)) => x,
            Ok(None) | Err(_) => break, // EOF or torn frame: session over
        };
        counters.bytes_rx.fetch_add(nb, Ordering::Relaxed);
        // telemetry frames are control plane, chaos-exempt like the
        // handshake: routing them around the chaos link keeps the
        // chaos coin stream identical to a telemetry-off run (a
        // telemetry-off run never carries the tag, so its stream is
        // untouched by this branch existing)
        let bodies = match &recv_link {
            Some(_) if telemetry && body_is_telemetry(&raw) => vec![raw],
            Some(link) => link.lock().expect("chaos link lock").plan_recv(&raw),
            None => vec![raw],
        };
        for body in bodies {
            match decode_body_auth(&body, auth.as_ref()) {
                Ok(Frame::Response(resp)) => {
                    // ack: the seq is no longer owed by future sessions.
                    // An unknown seq is a stale duplicate (already
                    // answered, possibly a chaos dup) — dropped, so
                    // every request yields exactly one event.
                    let known =
                        unacked.lock().expect("unacked lock").remove(&resp.seq).is_some();
                    if known && events.send(NetEvent::Resp(resp)).is_err() {
                        break 'session; // master gone
                    }
                }
                Ok(Frame::Telemetry(batch)) => {
                    // folded straight into the link's shared state: no
                    // event, nothing protocol-visible — telemetry must
                    // never perturb delivery order
                    let arrival = origin.elapsed().as_nanos() as u64;
                    shared.ingest_batch(batch, worker, arrival);
                }
                Ok(_) => {
                    log::warn!("net reader: protocol violation (unexpected frame)");
                    break 'session;
                }
                Err(e) => {
                    // a corrupted (or forged) frame: with auth on this
                    // is a MAC failure; either way the session is torn
                    // down and reconnect/resend takes over — the bytes
                    // never reach protocol state
                    log::warn!("net reader: undecodable frame: {e:#}");
                    break 'session;
                }
            }
        }
    }
    alive.store(false, Ordering::Release);
}

/// The worker is crash-stopped: report every owed delivery as failed,
/// then keep converting any further submits (raced in before the
/// master marked it dead) into single failures until the master drops
/// the channel.
fn fail_forever(ctx: &SupervisorCtx) {
    let lost = {
        let mut m = ctx.unacked.lock().expect("unacked lock");
        let k = m.len();
        m.clear();
        k
    };
    // count requests already queued but never written, too
    let mut lost = lost;
    while let Ok(_req) = ctx.cmd_rx.try_recv() {
        lost += 1;
    }
    let _ = ctx.events.send(NetEvent::Failed { worker: ctx.worker, count: lost });
    loop {
        match ctx.cmd_rx.recv() {
            Ok(_req) => {
                if ctx
                    .events
                    .send(NetEvent::Failed { worker: ctx.worker, count: 1 })
                    .is_err()
                {
                    return;
                }
            }
            Err(_) => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Batch;

    // ----------------------------------- reconnect budget edge table

    #[test]
    fn budget_tolerates_exactly_max_attempts_then_exhausts() {
        // (max_attempts, base backoff, expected backoff sequence)
        let table: &[(u32, u64, &[u64])] = &[
            (1, 25, &[25]),
            (3, 5, &[5, 10, 20]),
            (5, 25, &[25, 50, 100, 200, 400]),
            // doubling caps at 16x the base
            (8, 1, &[1, 2, 4, 8, 16, 16, 16, 16]),
        ];
        for &(max, base, expect) in table {
            let mut b = ReconnectBudget::new(max, base);
            assert!(!b.exhausted());
            for (i, &ms) in expect.iter().enumerate() {
                assert_eq!(b.on_failure(), Some(ms), "max={max} failure #{}", i + 1);
                assert!(!b.exhausted());
            }
            // the (max_attempts + 1)-th consecutive failure is terminal
            assert_eq!(b.on_failure(), None, "max={max} must exhaust");
            assert!(b.exhausted());
        }
    }

    #[test]
    fn budget_refills_on_success() {
        let mut b = ReconnectBudget::new(2, 10);
        assert_eq!(b.on_failure(), Some(10));
        assert_eq!(b.on_failure(), Some(20));
        // outage ends one failure short of exhaustion: full refill
        b.on_success();
        assert_eq!(b.on_failure(), Some(10), "backoff restarts at base");
        assert_eq!(b.on_failure(), Some(20));
        assert_eq!(b.on_failure(), None);
    }

    #[test]
    fn budget_clamps_degenerate_configs() {
        // zero attempts/backoff would mean instant permanent death and
        // hot-spin reconnects; both clamp to 1
        let mut b = ReconnectBudget::new(0, 0);
        assert_eq!(b.on_failure(), Some(1));
        assert_eq!(b.on_failure(), None);
    }

    // ------------------------------- crash-stop via exhausted budget

    /// A peer that never accepts: the supervisor must burn exactly
    /// `max_attempts` backoffs (observed through the injected sleep —
    /// no real ones), then surface the pending submit as an in-band
    /// `Delivery::Failed`, and keep failing later submits immediately.
    #[test]
    fn unreachable_peer_crash_stops_and_drains_submits() {
        let model = ModelSpec::LinReg { d: 4, batch: 2 };
        // port 1 is reserved: connects are refused, never accepted
        let mut cfg = NetConfig::new(vec!["127.0.0.1:1".into()], model);
        cfg.max_attempts = 3;
        cfg.backoff_ms = 1;
        let slept: Arc<Mutex<Vec<Duration>>> = Arc::new(Mutex::new(Vec::new()));
        let rec = slept.clone();
        cfg.sleep = Some(Arc::new(move |d| rec.lock().unwrap().push(d)));
        let mut t = NetTransport::connect(cfg).unwrap();

        let theta = Arc::new(vec![0.0f32; 4]);
        let batch = Batch::LinReg { x: vec![0.0; 4], y: vec![0.0], b: 1, d: 4 };
        let bundle = TaskBundle { worker: 0, tasks: vec![(0, batch.clone())] };
        t.submit(0, 0, 0, &theta, vec![bundle]).unwrap();
        let out = t.poll(None).unwrap();
        assert_eq!(out.len(), 1, "the owed delivery must come back");
        assert!(
            matches!(out[0], Delivery::Failed { worker: 0, .. }),
            "an unreachable peer is a crash-stop, got {:?}",
            out[0].worker()
        );
        assert_eq!(
            *slept.lock().unwrap(),
            vec![
                Duration::from_millis(1),
                Duration::from_millis(2),
                Duration::from_millis(4)
            ],
            "exactly max_attempts capped-exponential backoffs, via the mock clock"
        );

        // the worker is now known-dead: submits fail without blocking
        let bundle = TaskBundle { worker: 0, tasks: vec![(0, batch)] };
        t.submit(1, 0, 0, &theta, vec![bundle]).unwrap();
        let out = t.poll(None).unwrap();
        assert_eq!(out.len(), 1);
        assert!(matches!(out[0], Delivery::Failed { worker: 0, .. }));
        t.shutdown();
    }
}
