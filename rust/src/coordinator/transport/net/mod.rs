//! TCP transport: master and workers as separate processes.
//!
//! This is the first transport where the protocol meets the OS. The
//! master side ([`NetTransport`]) implements the completion-driven
//! [`Transport`] contract over one TCP connection per worker; the
//! worker side ([`server::serve`]) is a standalone process (`r3bft
//! worker --listen ADDR`) hosting the exact same
//! [`WorkerState`](crate::coordinator::worker::WorkerState) compute
//! core the in-process transports drive — which is why a loopback net
//! run is bit-identical to a threaded or sim run for the same seed.
//!
//! Architecture, per worker:
//!
//! * a **supervisor thread** owns the connection lifecycle: connect →
//!   [`frame::Hello`] handshake → resend unacknowledged requests →
//!   write loop. Outbound requests arrive over a *bounded* channel
//!   ([`NetConfig::outbound_depth`]), so a stalled connection
//!   backpressures `submit` instead of buffering unboundedly;
//! * a **reader thread** per live session turns incoming
//!   [`frame::NetResponse`] frames into events for `poll`, acking the
//!   per-connection sequence number that reconnect resends key on;
//! * when the session drops, the supervisor reconnects with capped
//!   exponential backoff. Each re-established session is surfaced as a
//!   reconnect notice ([`Transport::drain_reconnects`] → the
//!   `net_reconnects` metric and a trace event). A worker that
//!   exhausts [`NetConfig::max_attempts`] becomes a **crash-stop**:
//!   every owed delivery comes back as [`Delivery::Failed`] in-band —
//!   never a hang — and later submits to it fail immediately.
//!
//! Deadline-based gathers run on the wall clock ([`Transport::poll`]
//! mirrors [`super::ThreadedTransport`]'s blocking recv/timeout shape
//! exactly), and the socket byte counters ([`Transport::net_stats`])
//! include frame and header overhead — the honest `bytes_round`
//! figure an in-process transport cannot measure.
//!
//! Incoming bytes are untrusted: frames decode fallibly
//! ([`frame::read_frame`]) and compressed symbol payloads pass
//! through [`Compressor::try_unpack`]; a malformed response is logged
//! and surfaced as that worker's crash-stop, not a master panic.

pub mod frame;
pub mod server;

use std::collections::BTreeMap;
use std::io::BufReader;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::super::compress::Compressor;
use super::super::worker::{Response, Symbol};
use super::super::WorkerId;
use super::{Delivery, NetStats, TaskBundle, Transport};
use crate::config::AttackConfig;
use crate::grad::ModelSpec;
use crate::Result;

use frame::{read_frame, write_frame, Frame, Hello, NetGrad, NetRequest, NetResponse};

/// Master-side configuration for one [`NetTransport`].
pub struct NetConfig {
    /// One `host:port` per worker; local id = index, global id =
    /// `lo + index`.
    pub peers: Vec<String>,
    /// Global id of local worker 0 (shard inner transports pass their
    /// range offset; flat runs pass 0).
    pub lo: WorkerId,
    /// Run seed, forwarded so remote Byzantine RNGs match in-process
    /// ones.
    pub seed: u64,
    /// Artificial per-request compute delay (µs) applied worker-side.
    pub latency_us: u64,
    /// Scripted attack given to the workers in `byzantine_ids`.
    pub attack: Option<AttackConfig>,
    /// *Global* ids of scripted-Byzantine workers.
    pub byzantine_ids: Vec<WorkerId>,
    /// Gradient compressor; its [`Compressor::spec`] is forwarded in
    /// the hello so the worker builds an identical one.
    pub compressor: Option<Arc<dyn Compressor>>,
    /// Model the workers instantiate their engines from.
    pub model: ModelSpec,
    /// Connection attempts per outage before the worker is declared
    /// crash-stopped.
    pub max_attempts: u32,
    /// Base reconnect backoff (doubles per attempt, capped at 16×).
    pub backoff_ms: u64,
    /// Outbound queue depth per worker (bounded backpressure).
    pub outbound_depth: usize,
}

impl NetConfig {
    pub fn new(peers: Vec<String>, model: ModelSpec) -> NetConfig {
        NetConfig {
            peers,
            lo: 0,
            seed: 0,
            latency_us: 0,
            attack: None,
            byzantine_ids: Vec::new(),
            compressor: None,
            model,
            max_attempts: 5,
            backoff_ms: 25,
            outbound_depth: 4,
        }
    }
}

/// Cumulative socket counters shared by every supervisor/reader.
#[derive(Default)]
struct Counters {
    bytes_tx: AtomicU64,
    bytes_rx: AtomicU64,
    reconnects: AtomicU64,
}

/// Supervisor/reader → master events.
enum NetEvent {
    Resp(NetResponse),
    /// `count` owed deliveries will never arrive: the worker is
    /// crash-stopped (reconnect budget exhausted).
    Failed { worker: WorkerId, count: usize },
    /// A session was re-established (metrics/trace only).
    Reconnect { worker: WorkerId },
}

struct SupervisorCtx {
    worker: WorkerId,
    addr: String,
    hello: Hello,
    cmd_rx: Receiver<NetRequest>,
    events: Sender<NetEvent>,
    counters: Arc<Counters>,
    /// Requests written but not yet answered, by sequence number —
    /// exactly what a fresh session must resend.
    unacked: Arc<Mutex<BTreeMap<u64, NetRequest>>>,
    max_attempts: u32,
    backoff_ms: u64,
}

/// TCP-backed [`Transport`]: one connection actor per worker.
pub struct NetTransport {
    n: usize,
    /// Dense gradient dimension (`model.param_dim()`): what compressed
    /// symbol payloads must decode to.
    d: usize,
    compressor: Option<Arc<dyn Compressor>>,
    cmd_txs: Vec<Option<SyncSender<NetRequest>>>,
    events_rx: Receiver<NetEvent>,
    handles: Vec<JoinHandle<()>>,
    /// Deliveries owed via the events channel.
    in_flight: usize,
    /// Deliveries already due (submits to known-dead workers).
    pending: Vec<Delivery>,
    dead: Vec<bool>,
    next_seq: u64,
    reconnect_log: Vec<(u64, WorkerId)>,
    counters: Arc<Counters>,
    origin: Instant,
}

impl NetTransport {
    /// Spawn one supervisor per peer. Returns immediately: connections
    /// are established concurrently by the supervisors, and a peer
    /// that never comes up surfaces as an in-band crash-stop once its
    /// reconnect budget runs out.
    pub fn connect(cfg: NetConfig) -> Result<NetTransport> {
        let n = cfg.peers.len();
        if n == 0 {
            anyhow::bail!("net transport needs at least one peer");
        }
        let d = cfg.model.param_dim();
        let (events_tx, events_rx) = channel::<NetEvent>();
        let counters = Arc::new(Counters::default());
        let mut cmd_txs = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for (i, addr) in cfg.peers.iter().enumerate() {
            let global = cfg.lo + i;
            let byzantine = if cfg.byzantine_ids.contains(&global) {
                cfg.attack.clone()
            } else {
                None
            };
            let hello = Hello {
                local_id: i as u64,
                global_id: global as u64,
                seed: cfg.seed,
                latency_us: cfg.latency_us,
                byzantine,
                compressor: cfg.compressor.as_ref().map(|c| c.spec()),
                model: cfg.model.clone(),
            };
            let (cmd_tx, cmd_rx) = sync_channel::<NetRequest>(cfg.outbound_depth.max(1));
            cmd_txs.push(Some(cmd_tx));
            let ctx = SupervisorCtx {
                worker: i,
                addr: addr.clone(),
                hello,
                cmd_rx,
                events: events_tx.clone(),
                counters: counters.clone(),
                unacked: Arc::new(Mutex::new(BTreeMap::new())),
                max_attempts: cfg.max_attempts.max(1),
                backoff_ms: cfg.backoff_ms.max(1),
            };
            handles.push(
                std::thread::Builder::new()
                    .name(format!("r3bft-net-{i}"))
                    .spawn(move || run_supervisor(ctx))
                    .expect("spawn net supervisor"),
            );
        }
        Ok(NetTransport {
            n,
            d,
            compressor: cfg.compressor,
            cmd_txs,
            events_rx,
            handles,
            in_flight: 0,
            pending: Vec::new(),
            dead: vec![false; n],
            next_seq: 0,
            reconnect_log: Vec::new(),
            counters,
            origin: Instant::now(),
        })
    }

    fn note_reconnect(&mut self, worker: WorkerId) {
        let at = self.now_ns();
        log::info!("worker {worker}: session re-established");
        self.reconnect_log.push((at, worker));
    }

    /// Decode one response into a delivery. A worker-reported engine
    /// error or a malformed symbol payload is that worker's
    /// crash-stop, mirroring [`super::ThreadedTransport`].
    fn to_delivery(&self, r: NetResponse, at_ns: u64) -> Delivery {
        let worker = r.worker as WorkerId;
        if let Some(err) = &r.error {
            log::warn!("worker {worker} failed: {err}");
            return Delivery::Failed { at_ns, worker };
        }
        let mut symbols = Vec::with_capacity(r.symbols.len());
        for s in r.symbols {
            let (grad, wire) = match (s.grad, &self.compressor) {
                (NetGrad::Wire(w), Some(c)) => match c.try_unpack(&w, self.d) {
                    Ok(g) => (g, Some(w)),
                    Err(e) => {
                        log::warn!("worker {worker}: undecodable symbol wire: {e:#}");
                        return Delivery::Failed { at_ns, worker };
                    }
                },
                (NetGrad::Dense(g), None) => {
                    if g.len() != self.d {
                        log::warn!("worker {worker}: symbol dim {} != {}", g.len(), self.d);
                        return Delivery::Failed { at_ns, worker };
                    }
                    (g, None)
                }
                (_, _) => {
                    log::warn!("worker {worker}: symbol encoding disagrees with compressor config");
                    return Delivery::Failed { at_ns, worker };
                }
            };
            symbols.push(Symbol {
                chunk: s.chunk as usize,
                grad,
                loss: s.loss,
                tampered: s.tampered,
                wire,
            });
        }
        Delivery::Response {
            at_ns,
            response: Response {
                worker,
                iter: r.iter,
                phase: r.phase,
                wave: r.wave,
                symbols,
                error: None,
            },
        }
    }

    /// Fold one delivery-producing event into `out`. Returns how many
    /// deliveries it yielded (a budget-exhausted notice for a worker
    /// with nothing owed yields zero).
    fn ingest(&mut self, ev: NetEvent, out: &mut Vec<Delivery>) -> usize {
        match ev {
            NetEvent::Reconnect { worker } => {
                self.note_reconnect(worker);
                0
            }
            NetEvent::Resp(r) => {
                self.in_flight = self.in_flight.saturating_sub(1);
                let at = self.now_ns();
                out.push(self.to_delivery(r, at));
                1
            }
            NetEvent::Failed { worker, count } => {
                if !self.dead[worker] {
                    log::warn!("worker {worker}: connection lost for good (crash-stop)");
                }
                self.dead[worker] = true;
                let count = count.min(self.in_flight);
                self.in_flight -= count;
                let at = self.now_ns();
                for _ in 0..count {
                    out.push(Delivery::Failed { at_ns: at, worker });
                }
                count
            }
        }
    }
}

impl Transport for NetTransport {
    fn n(&self) -> usize {
        self.n
    }

    fn now_ns(&self) -> u64 {
        self.origin.elapsed().as_nanos() as u64
    }

    fn submit(
        &mut self,
        iter: u64,
        phase: u32,
        wave: u64,
        theta: &Arc<Vec<f32>>,
        bundles: Vec<TaskBundle>,
    ) -> Result<()> {
        for TaskBundle { worker, tasks } in bundles {
            if worker >= self.n {
                anyhow::bail!("submit to unknown worker {worker} (n = {})", self.n);
            }
            if self.dead[worker] {
                // crash-stopped: owe the failure directly, nothing to send
                let at = self.now_ns();
                self.pending.push(Delivery::Failed { at_ns: at, worker });
                continue;
            }
            let req = NetRequest {
                seq: self.next_seq,
                iter,
                phase,
                wave,
                theta: theta.as_ref().clone(),
                tasks: tasks.into_iter().map(|(c, b)| (c as u64, b)).collect(),
            };
            self.next_seq += 1;
            // bounded channel: blocks when the worker's outbound queue
            // is full (backpressure), errs only if the supervisor died
            let sent = match &self.cmd_txs[worker] {
                Some(tx) => tx.send(req).is_ok(),
                None => false,
            };
            if sent {
                self.in_flight += 1;
            } else {
                self.dead[worker] = true;
                let at = self.now_ns();
                self.pending.push(Delivery::Failed { at_ns: at, worker });
            }
        }
        Ok(())
    }

    fn poll(&mut self, deadline_ns: Option<u64>) -> Result<Vec<Delivery>> {
        let mut out: Vec<Delivery> = Vec::new();
        // failures recorded at submit time are already due
        if !self.pending.is_empty() {
            out.append(&mut self.pending);
            out.sort_by_key(|d| d.worker());
            return Ok(out);
        }
        if self.in_flight == 0 {
            return Ok(out);
        }
        // block for the first delivery-producing event, bounded by the
        // deadline; reconnect notices and zero-yield failure notices
        // are folded in without ending the wait
        loop {
            let ev = match deadline_ns {
                None => match self.events_rx.recv() {
                    Ok(ev) => Some(ev),
                    Err(_) => anyhow::bail!("all worker connections gone"),
                },
                Some(d) => {
                    let now = self.now_ns();
                    if d <= now {
                        // past the deadline: hand over whatever already
                        // arrived, never block
                        self.events_rx.try_recv().ok()
                    } else {
                        match self.events_rx.recv_timeout(Duration::from_nanos(d - now)) {
                            Ok(ev) => Some(ev),
                            Err(RecvTimeoutError::Timeout) => None,
                            Err(RecvTimeoutError::Disconnected) => {
                                anyhow::bail!("all worker connections gone")
                            }
                        }
                    }
                }
            };
            match ev {
                None => return Ok(out), // deadline passed
                Some(ev) => {
                    if self.ingest(ev, &mut out) > 0 {
                        break;
                    }
                    // zero-yield event: keep waiting (deadline re-checked)
                }
            }
        }
        // drain whatever else is already ready, without blocking
        while self.in_flight > 0 {
            match self.events_rx.try_recv() {
                Ok(ev) => {
                    self.ingest(ev, &mut out);
                }
                Err(_) => break,
            }
        }
        out.sort_by_key(|d| d.worker());
        Ok(out)
    }

    fn shutdown(&mut self) {
        // dropping the senders makes each supervisor send a Shutdown
        // frame to its worker and exit
        for tx in self.cmd_txs.iter_mut() {
            *tx = None;
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        self.in_flight = 0;
        self.pending.clear();
    }

    fn net_stats(&self) -> Option<NetStats> {
        Some(NetStats {
            bytes_tx: self.counters.bytes_tx.load(Ordering::Relaxed),
            bytes_rx: self.counters.bytes_rx.load(Ordering::Relaxed),
            reconnects: self.counters.reconnects.load(Ordering::Relaxed),
        })
    }

    fn drain_reconnects(&mut self) -> Vec<(u64, WorkerId)> {
        std::mem::take(&mut self.reconnect_log)
    }
}

impl Drop for NetTransport {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ------------------------------------------------------- supervisor

/// One session attempt: connect, handshake, spawn the reader, resend
/// unacked requests, then serve the write loop until the session or
/// the master goes away.
enum SessionEnd {
    /// Connection broke — reconnect (resending unacked).
    Broken,
    /// Master dropped the command channel — send Shutdown and exit.
    MasterGone,
}

fn run_supervisor(ctx: SupervisorCtx) {
    let mut attempts_left = ctx.max_attempts;
    let mut first_session = true;
    loop {
        // connect with capped exponential backoff
        let stream = loop {
            match TcpStream::connect(&ctx.addr) {
                Ok(s) => break Some(s),
                Err(e) => {
                    attempts_left = attempts_left.saturating_sub(1);
                    if attempts_left == 0 {
                        log::warn!("worker {} @ {}: connect failed: {e}", ctx.worker, ctx.addr);
                        break None;
                    }
                    let exp = (ctx.max_attempts - attempts_left).min(4);
                    std::thread::sleep(Duration::from_millis(ctx.backoff_ms << exp));
                }
            }
        };
        let stream = match stream {
            Some(s) => s,
            None => return fail_forever(&ctx),
        };
        match run_session(&ctx, stream, first_session, &mut attempts_left) {
            SessionEnd::MasterGone => return,
            SessionEnd::Broken => {
                attempts_left = attempts_left.saturating_sub(1);
                if attempts_left == 0 {
                    return fail_forever(&ctx);
                }
                first_session = false;
                let exp = (ctx.max_attempts - attempts_left).min(4);
                std::thread::sleep(Duration::from_millis(ctx.backoff_ms << exp));
            }
        }
    }
}

fn run_session(
    ctx: &SupervisorCtx,
    mut stream: TcpStream,
    first: bool,
    attempts_left: &mut u32,
) -> SessionEnd {
    let _ = stream.set_nodelay(true);
    // handshake: Hello out, HelloAck back (reads are unbuffered here;
    // the worker sends nothing after the ack until we send requests)
    match write_frame(&mut stream, &Frame::Hello(ctx.hello.clone())) {
        Ok(nb) => ctx.counters.bytes_tx.fetch_add(nb, Ordering::Relaxed),
        Err(e) => {
            log::warn!("worker {}: hello write failed: {e:#}", ctx.worker);
            return SessionEnd::Broken;
        }
    };
    match read_frame(&mut stream) {
        Ok(Some((Frame::HelloAck { global_id }, nb)))
            if global_id == ctx.hello.global_id =>
        {
            ctx.counters.bytes_rx.fetch_add(nb, Ordering::Relaxed);
        }
        Ok(_) | Err(_) => {
            log::warn!("worker {}: bad hello ack", ctx.worker);
            return SessionEnd::Broken;
        }
    }
    // handshake done: the outage (if any) is over, refill the budget
    *attempts_left = ctx.max_attempts;
    if !first {
        ctx.counters.reconnects.fetch_add(1, Ordering::Relaxed);
        let _ = ctx.events.send(NetEvent::Reconnect { worker: ctx.worker });
    }
    // reader for this session (clears `alive` when the session dies)
    let alive = Arc::new(AtomicBool::new(true));
    let reader_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(e) => {
            log::warn!("worker {}: stream clone failed: {e}", ctx.worker);
            return SessionEnd::Broken;
        }
    };
    {
        let alive = alive.clone();
        let events = ctx.events.clone();
        let unacked = ctx.unacked.clone();
        let counters = ctx.counters.clone();
        let worker = ctx.worker;
        std::thread::Builder::new()
            .name(format!("r3bft-net-read-{worker}"))
            .spawn(move || run_reader(reader_stream, alive, events, unacked, counters))
            .expect("spawn net reader");
    }
    // a fresh session starts by resending everything unanswered, in
    // sequence order (the worker recomputes deterministically)
    let resend: Vec<NetRequest> = {
        let m = ctx.unacked.lock().expect("unacked lock");
        m.values().cloned().collect()
    };
    for req in resend {
        match write_frame(&mut stream, &Frame::Request(req)) {
            Ok(nb) => ctx.counters.bytes_tx.fetch_add(nb, Ordering::Relaxed),
            Err(_) => return SessionEnd::Broken,
        }
    }
    // write loop; the timeout tick is only how fast we notice a dead
    // reader while idle — requests themselves are written immediately
    loop {
        if !alive.load(Ordering::Acquire) {
            return SessionEnd::Broken;
        }
        match ctx.cmd_rx.recv_timeout(Duration::from_millis(20)) {
            Ok(req) => {
                ctx.unacked.lock().expect("unacked lock").insert(req.seq, req.clone());
                match write_frame(&mut stream, &Frame::Request(req)) {
                    Ok(nb) => ctx.counters.bytes_tx.fetch_add(nb, Ordering::Relaxed),
                    Err(_) => return SessionEnd::Broken,
                }
            }
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => {
                if let Ok(nb) = write_frame(&mut stream, &Frame::Shutdown) {
                    ctx.counters.bytes_tx.fetch_add(nb, Ordering::Relaxed);
                }
                return SessionEnd::MasterGone;
            }
        }
    }
}

fn run_reader(
    stream: TcpStream,
    alive: Arc<AtomicBool>,
    events: Sender<NetEvent>,
    unacked: Arc<Mutex<BTreeMap<u64, NetRequest>>>,
    counters: Arc<Counters>,
) {
    let mut r = BufReader::new(stream);
    loop {
        match read_frame(&mut r) {
            Ok(Some((Frame::Response(resp), nb))) => {
                counters.bytes_rx.fetch_add(nb, Ordering::Relaxed);
                // ack: the seq is no longer owed by future sessions.
                // An unknown seq is a stale duplicate (already answered
                // on an earlier session) — dropped, so every request
                // yields exactly one event.
                let known =
                    unacked.lock().expect("unacked lock").remove(&resp.seq).is_some();
                if known && events.send(NetEvent::Resp(resp)).is_err() {
                    break; // master gone
                }
            }
            Ok(Some((_, _))) => {
                log::warn!("net reader: protocol violation (unexpected frame)");
                break;
            }
            Ok(None) | Err(_) => break, // EOF or torn frame: session over
        }
    }
    alive.store(false, Ordering::Release);
}

/// The worker is crash-stopped: report every owed delivery as failed,
/// then keep converting any further submits (raced in before the
/// master marked it dead) into single failures until the master drops
/// the channel.
fn fail_forever(ctx: &SupervisorCtx) {
    let lost = {
        let mut m = ctx.unacked.lock().expect("unacked lock");
        let k = m.len();
        m.clear();
        k
    };
    // count requests already queued but never written, too
    let mut lost = lost;
    while let Ok(_req) = ctx.cmd_rx.try_recv() {
        lost += 1;
    }
    let _ = ctx.events.send(NetEvent::Failed { worker: ctx.worker, count: lost });
    loop {
        match ctx.cmd_rx.recv() {
            Ok(_req) => {
                if ctx
                    .events
                    .send(NetEvent::Failed { worker: ctx.worker, count: 1 })
                    .is_err()
                {
                    return;
                }
            }
            Err(_) => return,
        }
    }
}
