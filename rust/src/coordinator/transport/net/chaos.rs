//! Deterministic, seeded fault injection for the TCP transport.
//!
//! A [`ChaosSpec`] (parsed from `--chaos` / `cluster.chaos`) describes
//! a hostile network as per-frame Bernoulli faults plus a timed
//! partition schedule; a [`ChaosLink`] turns it into concrete
//! injections for one direction of one master↔worker link. All coins
//! come from a [`Pcg64`] stream keyed by `(run seed, link, channel)`,
//! and every planning call draws the *same number* of coins whatever
//! the traffic contents — so a failure sequence is replayable from the
//! run seed alone, which is what lets `tests/test_chaos.rs` assert the
//! exactness contract under a specific storm instead of a flaky one.
//!
//! The layer is socket-agnostic on purpose: [`ChaosLink::plan_send`]
//! maps one encoded frame to a list of [`SendOp`]s (write, write a
//! torn prefix then kill, sleep, duplicate, hold-for-reorder) and
//! [`ChaosLink::plan_recv`] maps one received body to the list of
//! bodies to actually process. The supervisor/server threads execute
//! the plans against real streams; unit tests execute them against
//! byte buffers. Handshake frames (Hello/HelloAck) are exempt so a
//! chaotic run still *starts* — chaos exercises the steady-state
//! resend/reconnect/crash-stop machinery, not the test harness's
//! ability to boot.
//!
//! What each fault exercises:
//!
//! * `drop` — silent loss; the resend-on-timeout path must recover or
//!   the run would hang under `GatherPolicy::All`.
//! * `delay` — bounded per-frame latency; feeds the latency profiles
//!   and the quorum/deadline gathers.
//! * `dup` — duplicate delivery; first-response-wins dedup must hold
//!   at both the transport seq level and `wait_wave`'s quorum count.
//! * `reorder` — a one-frame hold-back window; seq-keyed acks must
//!   not care about arrival order.
//! * `corrupt` — one bit flipped inside the length-counted body (the
//!   prefix is left alone so the stream stays framed); with auth on
//!   this must surface as a MAC failure, never as ingested state.
//! * `kill` — a torn frame followed by connection death; the
//!   reconnect + resend machinery takes over.
//! * `partition` — the link is down for a window at the start of every
//!   period; outages longer than the reconnect-backoff budget must
//!   surface as in-band crash-stops, never hangs.

use std::time::Duration;

use crate::util::rng::Pcg64;
use crate::Result;

/// RNG stream tag base for chaos links (xor-ed with link and channel).
const CHAOS_STREAM: u64 = 0xc4a0_51de;

/// Outbound direction of the master's supervisor (requests).
pub const CHANNEL_MASTER_SEND: u64 = 0;
/// Inbound direction of the master's reader (responses).
pub const CHANNEL_MASTER_RECV: u64 = 1;
/// The worker process's response writes.
pub const CHANNEL_WORKER_SEND: u64 = 2;

/// A hostile-network profile: per-frame fault probabilities, a delay
/// bound, and a timed partition schedule. `Default` is a no-op.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ChaosSpec {
    /// P(frame silently dropped).
    pub drop: f64,
    /// Per-frame delay drawn uniformly from `[0, delay_max_us]` µs.
    pub delay_max_us: u64,
    /// P(frame delivered twice).
    pub dup: f64,
    /// P(frame held back one frame, i.e. swapped with its successor).
    pub reorder: f64,
    /// P(one bit of the frame body flipped).
    pub corrupt: f64,
    /// P(torn mid-frame write followed by connection death).
    pub kill: f64,
    /// Partition period in ms (0 = no partitions).
    pub partition_every_ms: u64,
    /// Partition window at the start of each period, in ms.
    pub partition_for_ms: u64,
}

fn parse_prob(key: &str, val: &str) -> Result<f64> {
    let p: f64 = val
        .parse()
        .map_err(|_| anyhow::anyhow!("chaos {key} wants a probability, got '{val}'"))?;
    anyhow::ensure!((0.0..=1.0).contains(&p), "chaos {key} probability {p} outside [0, 1]");
    Ok(p)
}

/// Parse a duration with a `us`/`ms`/`s` suffix to microseconds.
fn parse_duration_us(val: &str) -> Result<u64> {
    let (num, scale) = if let Some(n) = val.strip_suffix("us") {
        (n, 1u64)
    } else if let Some(n) = val.strip_suffix("ms") {
        (n, 1_000)
    } else if let Some(n) = val.strip_suffix('s') {
        (n, 1_000_000)
    } else {
        anyhow::bail!("duration '{val}' needs a us/ms/s suffix");
    };
    let v: u64 = num
        .trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("bad duration '{val}'"))?;
    Ok(v * scale)
}

impl ChaosSpec {
    /// Parse the `--chaos` grammar: comma-separated `key:value`
    /// clauses — `drop:P`, `dup:P`, `reorder:P`, `corrupt:P`, `kill:P`
    /// with P ∈ [0,1]; `delay:DUR` (uniform per-frame delay in
    /// [0, DUR]); `partition:DUR@PERIOD` (link down for DUR at the
    /// start of every PERIOD). Durations take `us`/`ms`/`s` suffixes;
    /// empty or `off` is a no-op spec.
    ///
    /// Example: `drop:0.05,delay:20ms,partition:200ms@2s`.
    pub fn parse(s: &str) -> Result<ChaosSpec> {
        let mut spec = ChaosSpec::default();
        let s = s.trim();
        if s.is_empty() || s == "off" {
            return Ok(spec);
        }
        for clause in s.split(',') {
            let clause = clause.trim();
            let (key, val) = clause
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("chaos clause '{clause}' is not key:value"))?;
            match key {
                "drop" => spec.drop = parse_prob(key, val)?,
                "dup" => spec.dup = parse_prob(key, val)?,
                "reorder" => spec.reorder = parse_prob(key, val)?,
                "corrupt" => spec.corrupt = parse_prob(key, val)?,
                "kill" => spec.kill = parse_prob(key, val)?,
                "delay" => spec.delay_max_us = parse_duration_us(val)?,
                "partition" => {
                    let (dur, period) = val.split_once('@').ok_or_else(|| {
                        anyhow::anyhow!("chaos partition wants DUR@PERIOD, got '{val}'")
                    })?;
                    spec.partition_for_ms = parse_duration_us(dur)? / 1_000;
                    spec.partition_every_ms = parse_duration_us(period)? / 1_000;
                    anyhow::ensure!(
                        spec.partition_for_ms > 0
                            && spec.partition_every_ms >= spec.partition_for_ms,
                        "chaos partition window must be >= 1ms and fit inside its period"
                    );
                }
                other => anyhow::bail!("unknown chaos key '{other}'"),
            }
        }
        Ok(spec)
    }

    /// Canonical spec string (round-trips through [`ChaosSpec::parse`]).
    pub fn describe(&self) -> String {
        let mut parts = Vec::new();
        if self.drop > 0.0 {
            parts.push(format!("drop:{}", self.drop));
        }
        if self.delay_max_us > 0 {
            parts.push(format!("delay:{}us", self.delay_max_us));
        }
        if self.dup > 0.0 {
            parts.push(format!("dup:{}", self.dup));
        }
        if self.reorder > 0.0 {
            parts.push(format!("reorder:{}", self.reorder));
        }
        if self.corrupt > 0.0 {
            parts.push(format!("corrupt:{}", self.corrupt));
        }
        if self.kill > 0.0 {
            parts.push(format!("kill:{}", self.kill));
        }
        if self.partition_every_ms > 0 {
            parts.push(format!(
                "partition:{}ms@{}ms",
                self.partition_for_ms, self.partition_every_ms
            ));
        }
        if parts.is_empty() {
            "off".into()
        } else {
            parts.join(",")
        }
    }

    /// True when no clause can ever fire (the transport then skips the
    /// chaos paths entirely, keeping the clean run bit-identical).
    pub fn is_noop(&self) -> bool {
        *self == ChaosSpec::default()
    }

    /// Whether the timed partition schedule has the link down at
    /// `elapsed` since transport birth. Pure in (spec, clock) so the
    /// connect loop, the write loop, and the unit tests all agree.
    pub fn partitioned(&self, elapsed: Duration) -> bool {
        if self.partition_every_ms == 0 || self.partition_for_ms == 0 {
            return false;
        }
        (elapsed.as_millis() as u64 % self.partition_every_ms) < self.partition_for_ms
    }
}

/// One step of an outbound injection plan, executed in order against
/// the real stream (or a test buffer).
#[derive(Clone, Debug, PartialEq)]
pub enum SendOp {
    /// Injected latency before the following writes.
    Sleep(Duration),
    /// Put these bytes on the wire.
    Write(Vec<u8>),
    /// Put only the first `.1` bytes of `.0` on the wire (a torn
    /// frame), then the connection dies.
    WritePrefix(Vec<u8>, usize),
    /// Kill the connection (shutdown both directions).
    Kill,
}

/// Seeded fault injector for one direction of one link.
pub struct ChaosLink {
    spec: ChaosSpec,
    rng: Pcg64,
    /// Reorder window of one frame: the held (older) frame is emitted
    /// after its successor.
    held: Option<Vec<u8>>,
}

impl ChaosLink {
    /// `link` is the global worker id; `channel` one of the
    /// `CHANNEL_*` constants, so the two directions of a link and the
    /// worker's own writes draw from independent streams.
    pub fn new(spec: ChaosSpec, seed: u64, link: u64, channel: u64) -> ChaosLink {
        let stream = CHAOS_STREAM ^ (link << 8) ^ channel;
        ChaosLink { spec, rng: Pcg64::new(seed, stream), held: None }
    }

    /// Plan the fate of one outbound frame (`wire` = full frame bytes,
    /// length prefix included). Draws a fixed number of coins per call
    /// regardless of which fire, so the decision stream depends only
    /// on (seed, link, channel, frame index) — replayable by seed.
    pub fn plan_send(&mut self, wire: &[u8]) -> Vec<SendOp> {
        let s = self.spec;
        let kill = self.rng.bernoulli(s.kill);
        let cut = (self.rng.next_u64() as usize) % wire.len().max(1);
        let dropped = self.rng.bernoulli(s.drop);
        let corrupt = self.rng.bernoulli(s.corrupt);
        let bit = self.rng.next_u64();
        let delay_us =
            if s.delay_max_us == 0 { 0 } else { self.rng.next_u64() % (s.delay_max_us + 1) };
        let dup = self.rng.bernoulli(s.dup);
        let reorder = self.rng.bernoulli(s.reorder);

        if kill {
            // a torn frame then connection death; anything held back
            // for reorder dies with the link (resend recovers it)
            self.held = None;
            return vec![SendOp::WritePrefix(wire.to_vec(), cut), SendOp::Kill];
        }
        let mut ops = Vec::new();
        let mut stored = false;
        if !dropped {
            if delay_us > 0 {
                ops.push(SendOp::Sleep(Duration::from_micros(delay_us)));
            }
            let mut frame = wire.to_vec();
            if corrupt {
                flip_one_body_bit(&mut frame, bit);
            }
            if reorder && self.held.is_none() {
                self.held = Some(frame);
                stored = true;
            } else {
                if dup {
                    ops.push(SendOp::Write(frame.clone()));
                }
                ops.push(SendOp::Write(frame));
            }
        }
        if !stored {
            if let Some(older) = self.held.take() {
                ops.push(SendOp::Write(older));
            }
        }
        ops
    }

    /// Plan the fate of one inbound frame body (no length prefix):
    /// the bodies to actually process — empty means dropped, two means
    /// duplicated, and a corrupted body must die in decode/MAC
    /// verification, never reach protocol state.
    pub fn plan_recv(&mut self, body: &[u8]) -> Vec<Vec<u8>> {
        let s = self.spec;
        let dropped = self.rng.bernoulli(s.drop);
        let corrupt = self.rng.bernoulli(s.corrupt);
        let bit = self.rng.next_u64();
        let dup = self.rng.bernoulli(s.dup);
        if dropped {
            return Vec::new();
        }
        let mut b = body.to_vec();
        if corrupt && !b.is_empty() {
            let k = (bit as usize) % (b.len() * 8);
            b[k / 8] ^= 1 << (k % 8);
        }
        if dup {
            vec![b.clone(), b]
        } else {
            vec![b]
        }
    }
}

/// Flip one RNG-chosen bit *inside the length-counted body* (bytes 4..)
/// so the stream stays framed and the receiver sees a decode/MAC
/// failure instead of a desynchronized byte stream.
fn flip_one_body_bit(wire: &mut [u8], r: u64) {
    if wire.len() <= 4 {
        return;
    }
    let nbits = (wire.len() - 4) * 8;
    let k = (r as usize) % nbits;
    wire[4 + k / 8] ^= 1 << (k % 8);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(n: usize, fill: u8) -> Vec<u8> {
        // shaped like a real frame: 4-byte length prefix + body
        let mut w = ((n - 4) as u32).to_le_bytes().to_vec();
        w.extend(vec![fill; n - 4]);
        w
    }

    #[test]
    fn grammar_parses_and_round_trips() {
        let spec = ChaosSpec::parse("drop:0.05,delay:20ms,dup:0.1,partition:200ms@2s").unwrap();
        assert_eq!(spec.drop, 0.05);
        assert_eq!(spec.delay_max_us, 20_000);
        assert_eq!(spec.dup, 0.1);
        assert_eq!(spec.partition_for_ms, 200);
        assert_eq!(spec.partition_every_ms, 2_000);
        assert_eq!(ChaosSpec::parse(&spec.describe()).unwrap(), spec);
        assert!(ChaosSpec::parse("off").unwrap().is_noop());
        assert!(ChaosSpec::parse("").unwrap().is_noop());
        assert_eq!(ChaosSpec::parse("delay:500us").unwrap().delay_max_us, 500);
        assert_eq!(ChaosSpec::parse("delay:1s").unwrap().delay_max_us, 1_000_000);
    }

    #[test]
    fn grammar_rejects_nonsense() {
        for bad in [
            "drop",
            "drop:1.5",
            "drop:-0.1",
            "drop:x",
            "delay:20",
            "delay:ms",
            "partition:200ms",
            "partition:2s@200ms", // window larger than period
            "partition:0ms@1s",
            "warp:0.5",
        ] {
            assert!(ChaosSpec::parse(bad).is_err(), "'{bad}' accepted");
        }
    }

    #[test]
    fn same_seed_same_link_replays_the_same_storm() {
        let spec = ChaosSpec::parse("drop:0.3,dup:0.2,corrupt:0.2,delay:1ms,kill:0.05").unwrap();
        let mut a = ChaosLink::new(spec, 42, 3, CHANNEL_MASTER_SEND);
        let mut b = ChaosLink::new(spec, 42, 3, CHANNEL_MASTER_SEND);
        let mut diverged = Vec::new();
        for i in 0..256usize {
            let w = frame(16 + (i % 7), i as u8);
            if a.plan_send(&w) != b.plan_send(&w) {
                diverged.push(i);
            }
        }
        assert!(diverged.is_empty(), "same (seed, link, channel) diverged at {diverged:?}");
        // a different link draws an independent stream
        let mut c = ChaosLink::new(spec, 42, 4, CHANNEL_MASTER_SEND);
        let mut a2 = ChaosLink::new(spec, 42, 3, CHANNEL_MASTER_SEND);
        let plans: Vec<_> = (0..256).map(|i| a2.plan_send(&frame(16, i as u8))).collect();
        let other: Vec<_> = (0..256).map(|i| c.plan_send(&frame(16, i as u8))).collect();
        assert_ne!(plans, other, "links 3 and 4 drew identical 256-frame storms");
    }

    #[test]
    fn decision_stream_ignores_frame_contents() {
        // constant coin consumption per call: the fate of frame k must
        // not depend on what frames 0..k contained
        let spec = ChaosSpec::parse("drop:0.5,corrupt:0.3,dup:0.2").unwrap();
        let mut a = ChaosLink::new(spec, 7, 0, CHANNEL_WORKER_SEND);
        let mut b = ChaosLink::new(spec, 7, 0, CHANNEL_WORKER_SEND);
        for i in 0..128usize {
            let wa = frame(8 + 4 * (i % 5), 0xaa);
            let wb = frame(8 + 4 * (i % 5), 0x55);
            let (pa, pb) = (a.plan_send(&wa), b.plan_send(&wb));
            // same *shape* of plan: op kinds and counts match
            let shape = |p: &[SendOp]| {
                p.iter()
                    .map(|op| match op {
                        SendOp::Sleep(_) => 0u8,
                        SendOp::Write(_) => 1,
                        SendOp::WritePrefix(..) => 2,
                        SendOp::Kill => 3,
                    })
                    .collect::<Vec<_>>()
            };
            assert_eq!(shape(&pa), shape(&pb), "frame {i}");
        }
    }

    #[test]
    fn corrupt_flips_exactly_one_bit_outside_the_prefix() {
        let spec = ChaosSpec::parse("corrupt:1").unwrap();
        let mut link = ChaosLink::new(spec, 1, 0, CHANNEL_MASTER_SEND);
        for i in 0..64usize {
            let w = frame(12 + i, 0xc3);
            let plan = link.plan_send(&w);
            assert_eq!(plan.len(), 1);
            let SendOp::Write(bad) = &plan[0] else { panic!("expected a write") };
            assert_eq!(bad.len(), w.len());
            assert_eq!(bad[..4], w[..4], "length prefix must stay intact");
            let flipped: u32 = bad
                .iter()
                .zip(&w)
                .map(|(a, b)| (a ^ b).count_ones())
                .sum();
            assert_eq!(flipped, 1, "exactly one bit flips");
        }
    }

    #[test]
    fn drop_dup_and_kill_fates() {
        let mut dropper = ChaosLink::new(ChaosSpec::parse("drop:1").unwrap(), 1, 0, 0);
        assert!(dropper.plan_send(&frame(10, 1)).is_empty());

        let mut duper = ChaosLink::new(ChaosSpec::parse("dup:1").unwrap(), 1, 0, 0);
        let w = frame(10, 2);
        let plan = duper.plan_send(&w);
        assert_eq!(plan, vec![SendOp::Write(w.clone()), SendOp::Write(w)]);

        let mut killer = ChaosLink::new(ChaosSpec::parse("kill:1").unwrap(), 1, 0, 0);
        let w = frame(10, 3);
        let plan = killer.plan_send(&w);
        assert_eq!(plan.len(), 2);
        let SendOp::WritePrefix(full, cut) = &plan[0] else { panic!("expected a torn write") };
        assert_eq!(*full, w);
        assert!(*cut < w.len(), "must be a strict prefix");
        assert_eq!(plan[1], SendOp::Kill);
    }

    #[test]
    fn reorder_swaps_adjacent_frames() {
        let mut link = ChaosLink::new(ChaosSpec::parse("reorder:1").unwrap(), 1, 0, 0);
        let (a, b) = (frame(10, 0xa), frame(10, 0xb));
        assert!(link.plan_send(&a).is_empty(), "first frame is held back");
        // second frame goes out first, then the held one — and because
        // the second is itself re-held-eligible but the window is one
        // frame deep, it ships immediately
        assert_eq!(link.plan_send(&b), vec![SendOp::Write(b.clone()), SendOp::Write(a)]);
    }

    #[test]
    fn delay_bounds_the_injected_sleep() {
        let mut link = ChaosLink::new(ChaosSpec::parse("delay:2ms").unwrap(), 9, 0, 0);
        let mut slept = 0usize;
        for i in 0..64usize {
            let w = frame(10, i as u8);
            let plan = link.plan_send(&w);
            match plan.as_slice() {
                [SendOp::Sleep(d), SendOp::Write(out)] => {
                    assert!(*d <= Duration::from_millis(2));
                    assert_eq!(*out, w);
                    slept += 1;
                }
                [SendOp::Write(out)] => assert_eq!(*out, w), // drew delay 0
                other => panic!("unexpected plan {other:?}"),
            }
        }
        assert!(slept > 32, "a 2ms bound should almost always inject a sleep ({slept}/64)");
    }

    #[test]
    fn partition_schedule_is_a_pure_clock_function() {
        let spec = ChaosSpec::parse("partition:100ms@1s").unwrap();
        for (ms, down) in [
            (0, true),
            (50, true),
            (99, true),
            (100, false),
            (500, false),
            (999, false),
            (1000, true),
            (1099, true),
            (1100, false),
        ] {
            assert_eq!(
                spec.partitioned(Duration::from_millis(ms)),
                down,
                "at {ms}ms"
            );
        }
        assert!(!ChaosSpec::default().partitioned(Duration::ZERO));
    }

    #[test]
    fn recv_plans_drop_duplicate_and_corrupt() {
        let mut dropper = ChaosLink::new(ChaosSpec::parse("drop:1").unwrap(), 1, 0, 1);
        assert!(dropper.plan_recv(&[1, 2, 3]).is_empty());

        let mut duper = ChaosLink::new(ChaosSpec::parse("dup:1").unwrap(), 1, 0, 1);
        assert_eq!(duper.plan_recv(&[1, 2, 3]), vec![vec![1, 2, 3], vec![1, 2, 3]]);

        let mut corrupter = ChaosLink::new(ChaosSpec::parse("corrupt:1").unwrap(), 1, 0, 1);
        let body = vec![0u8; 16];
        let out = corrupter.plan_recv(&body);
        assert_eq!(out.len(), 1);
        let flipped: u32 = out[0].iter().zip(&body).map(|(a, b)| (a ^ b).count_ones()).sum();
        assert_eq!(flipped, 1);

        let mut clean = ChaosLink::new(ChaosSpec::default(), 1, 0, 1);
        assert_eq!(clean.plan_recv(&[9, 9]), vec![vec![9, 9]]);
    }
}
