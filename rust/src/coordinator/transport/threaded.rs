//! One-OS-thread-per-worker transport over mpsc channels (the
//! original execution model of the seed implementation, now behind the
//! [`Transport`] trait).
//!
//! Each worker thread owns a [`WorkerState`] and serves `Compute`
//! requests until `Shutdown`. Honest workers are deterministic, so a
//! run's outcome is independent of thread scheduling: `gather` sorts
//! responses by worker id before the protocol core ingests them.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use super::super::byzantine::ByzantineBehavior;
use super::super::compress::Compressor;
use super::super::worker::{Request, Response, WorkerState};
use super::super::{ChunkId, WorkerId};
use super::{TaskBundle, Transport};
use crate::data::Batch;
use crate::grad::GradientComputer;
use crate::Result;

fn byzantine_fn(
    f: &mut impl FnMut(WorkerId) -> Option<ByzantineBehavior>,
) -> impl FnMut(WorkerId) -> Option<ByzantineBehavior> + '_ {
    move |w| f(w)
}

/// Handle to the running worker-thread pool.
pub struct ThreadedTransport {
    senders: Vec<Sender<Request>>,
    receiver: Receiver<Response>,
    handles: Vec<JoinHandle<()>>,
    /// Responses owed to the in-flight `(iter, phase)` gather.
    outstanding: usize,
    pub n: usize,
}

impl ThreadedTransport {
    /// Spawn `n` workers. `byzantine(i)` returns the behaviour for
    /// worker i (None = honest). All workers share the engine handle
    /// (engines are Send + Sync; the XLA engine serializes internally).
    pub fn spawn(
        n: usize,
        engine: Arc<dyn GradientComputer>,
        mut byzantine: impl FnMut(WorkerId) -> Option<ByzantineBehavior>,
        latency_us: u64,
    ) -> ThreadedTransport {
        Self::spawn_with_compressor(n, engine, byzantine_fn(&mut byzantine), None, latency_us)
    }

    /// Spawn with an optional gradient compressor applied to every
    /// outgoing symbol (the §2.1/§5 compressed-gradients generalization).
    pub fn spawn_with_compressor(
        n: usize,
        engine: Arc<dyn GradientComputer>,
        mut byzantine: impl FnMut(WorkerId) -> Option<ByzantineBehavior>,
        compressor: Option<Arc<dyn Compressor>>,
        latency_us: u64,
    ) -> ThreadedTransport {
        let (resp_tx, resp_rx) = channel::<Response>();
        let mut senders = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for id in 0..n {
            let (req_tx, req_rx) = channel::<Request>();
            senders.push(req_tx);
            let resp_tx = resp_tx.clone();
            let mut state = WorkerState::new(id, engine.clone(), byzantine(id), compressor.clone());
            handles.push(
                std::thread::Builder::new()
                    .name(format!("r3bft-worker-{id}"))
                    .spawn(move || {
                        while let Ok(req) = req_rx.recv() {
                            match req {
                                Request::Shutdown => break,
                                Request::Compute { iter, phase, theta, tasks } => {
                                    if latency_us > 0 {
                                        std::thread::sleep(std::time::Duration::from_micros(
                                            latency_us,
                                        ));
                                    }
                                    // a panic must become a Response, not a
                                    // dead thread: gather counts responses,
                                    // so a silently-lost worker would hang
                                    // the master forever
                                    let result = std::panic::catch_unwind(
                                        std::panic::AssertUnwindSafe(|| {
                                            state.handle(iter, &theta, tasks)
                                        }),
                                    );
                                    let error = match &result {
                                        Ok(Ok(_)) => None,
                                        Ok(Err(e)) => Some(format!("{e:#}")),
                                        Err(p) => Some(
                                            p.downcast_ref::<String>()
                                                .cloned()
                                                .or_else(|| {
                                                    p.downcast_ref::<&str>()
                                                        .map(|s| s.to_string())
                                                })
                                                .unwrap_or_else(|| "worker panicked".into()),
                                        ),
                                    };
                                    let symbols = match result {
                                        Ok(Ok(symbols)) => symbols,
                                        _ => vec![],
                                    };
                                    let resp = Response { worker: id, iter, phase, symbols, error };
                                    if resp_tx.send(resp).is_err() {
                                        break; // master gone
                                    }
                                }
                            }
                        }
                    })
                    .expect("spawn worker thread"),
            );
        }
        ThreadedTransport { senders, receiver: resp_rx, handles, outstanding: 0, n }
    }

    /// Send a compute request to one worker.
    pub fn send(
        &self,
        w: WorkerId,
        iter: u64,
        phase: u32,
        theta: &Arc<Vec<f32>>,
        tasks: Vec<(ChunkId, Batch)>,
    ) -> Result<()> {
        self.senders[w]
            .send(Request::Compute { iter, phase, theta: theta.clone(), tasks })
            .map_err(|_| anyhow::anyhow!("worker {w} channel closed"))
    }

    /// Collect exactly `expected` responses for (iter, phase).
    pub fn collect(&self, iter: u64, phase: u32, expected: usize) -> Result<Vec<Response>> {
        let mut out = Vec::with_capacity(expected);
        while out.len() < expected {
            let resp = self
                .receiver
                .recv()
                .map_err(|_| anyhow::anyhow!("all workers disconnected"))?;
            if let Some(err) = &resp.error {
                anyhow::bail!("worker {} failed: {err}", resp.worker);
            }
            if resp.iter == iter && resp.phase == phase {
                out.push(resp);
            }
            // responses from other (iter, phase) pairs cannot occur in
            // the synchronous protocol; drop them defensively if they do
        }
        Ok(out)
    }
}

impl Transport for ThreadedTransport {
    fn n(&self) -> usize {
        self.n
    }

    fn scatter(
        &mut self,
        iter: u64,
        phase: u32,
        theta: &Arc<Vec<f32>>,
        bundles: Vec<TaskBundle>,
    ) -> Result<()> {
        for TaskBundle { worker, tasks } in bundles {
            self.send(worker, iter, phase, theta, tasks)?;
            self.outstanding += 1;
        }
        Ok(())
    }

    fn gather(&mut self, iter: u64, phase: u32) -> Result<Vec<Response>> {
        let expected = std::mem::take(&mut self.outstanding);
        let mut out = self.collect(iter, phase, expected)?;
        out.sort_by_key(|r| r.worker);
        Ok(out)
    }

    fn take_failed(&mut self) -> Vec<WorkerId> {
        Vec::new() // OS threads do not crash-stop; engine errors bail
    }

    fn shutdown(&mut self) {
        for s in &self.senders {
            let _ = s.send(Request::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ThreadedTransport {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AttackConfig, AttackKind};
    use crate::data::{Dataset, LinRegDataset};
    use crate::grad::{ModelSpec, NativeEngine};

    fn pool(n: usize, byz: Vec<WorkerId>) -> (ThreadedTransport, LinRegDataset) {
        let ds = LinRegDataset::generate(64, 8, 0.0, 1);
        let engine: Arc<dyn GradientComputer> =
            Arc::new(NativeEngine::new(ModelSpec::LinReg { d: 8, batch: 64 }));
        let pool = ThreadedTransport::spawn(
            n,
            engine,
            |i| {
                byz.contains(&i).then(|| {
                    ByzantineBehavior::new(
                        AttackConfig { kind: AttackKind::SignFlip, p: 1.0, magnitude: 1.0 },
                        7,
                        i,
                    )
                })
            },
            0,
        );
        (pool, ds)
    }

    #[test]
    fn honest_workers_return_identical_symbols() {
        let (pool, ds) = pool(3, vec![]);
        let theta = Arc::new(vec![0.1f32; 8]);
        let batch = ds.batch(&(0..16).collect::<Vec<_>>());
        for w in 0..3 {
            pool.send(w, 0, 0, &theta, vec![(5, batch.clone())]).unwrap();
        }
        let resps = pool.collect(0, 0, 3).unwrap();
        assert_eq!(resps.len(), 3);
        let g0 = &resps[0].symbols[0].grad;
        for r in &resps {
            assert_eq!(r.symbols.len(), 1);
            assert_eq!(r.symbols[0].chunk, 5);
            assert_eq!(&r.symbols[0].grad, g0, "honest symbols must be bit-identical");
            assert!(!r.symbols[0].tampered);
        }
    }

    #[test]
    fn byzantine_worker_tampers() {
        let (pool, ds) = pool(2, vec![1]);
        let theta = Arc::new(vec![0.1f32; 8]);
        let batch = ds.batch(&(0..16).collect::<Vec<_>>());
        pool.send(0, 0, 0, &theta, vec![(0, batch.clone())]).unwrap();
        pool.send(1, 0, 0, &theta, vec![(0, batch.clone())]).unwrap();
        let resps = pool.collect(0, 0, 2).unwrap();
        let honest = resps.iter().find(|r| r.worker == 0).unwrap();
        let byz = resps.iter().find(|r| r.worker == 1).unwrap();
        assert!(byz.symbols[0].tampered);
        assert_ne!(honest.symbols[0].grad, byz.symbols[0].grad);
    }

    #[test]
    fn tamper_decision_is_per_iteration() {
        // p = 1.0 means tampering in EVERY iteration, across phases
        let (pool, ds) = pool(1, vec![0]);
        let theta = Arc::new(vec![0.1f32; 8]);
        let batch = ds.batch(&(0..16).collect::<Vec<_>>());
        for phase in 0..3u32 {
            pool.send(0, 7, phase, &theta, vec![(0, batch.clone())]).unwrap();
            let r = pool.collect(7, phase, 1).unwrap();
            assert!(r[0].symbols[0].tampered, "phase {phase}");
        }
    }

    #[test]
    fn multiple_chunks_per_request() {
        let (pool, ds) = pool(1, vec![]);
        let theta = Arc::new(vec![0.0f32; 8]);
        let b1 = ds.batch(&(0..8).collect::<Vec<_>>());
        let b2 = ds.batch(&(8..16).collect::<Vec<_>>());
        pool.send(0, 0, 0, &theta, vec![(0, b1), (1, b2)]).unwrap();
        let r = pool.collect(0, 0, 1).unwrap();
        assert_eq!(r[0].symbols.len(), 2);
        assert_ne!(r[0].symbols[0].grad, r[0].symbols[1].grad);
    }

    #[test]
    fn scatter_gather_sorts_by_worker_id() {
        let (mut pool, ds) = pool(4, vec![]);
        let theta = Arc::new(vec![0.1f32; 8]);
        let batch = ds.batch(&(0..16).collect::<Vec<_>>());
        let bundles: Vec<TaskBundle> = (0..4)
            .rev() // scatter in reverse order on purpose
            .map(|w| TaskBundle { worker: w, tasks: vec![(w, batch.clone())] })
            .collect();
        pool.scatter(3, 0, &theta, bundles).unwrap();
        let resps = pool.gather(3, 0).unwrap();
        let ids: Vec<WorkerId> = resps.iter().map(|r| r.worker).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
        assert!(pool.take_failed().is_empty());
        pool.shutdown();
    }
}
