//! One-OS-thread-per-worker transport over mpsc channels (the
//! original execution model of the seed implementation, now behind the
//! completion-driven [`Transport`] trait).
//!
//! Each worker thread owns a [`WorkerState`] and serves `Compute`
//! requests until `Shutdown`. [`Transport::submit`] only enqueues
//! requests; [`Transport::poll`] blocks for the next response on the
//! shared reply channel, then drains whatever else is already ready,
//! stamping each delivery with wall-clock ns since construction. A
//! worker whose engine errors or panics produces a
//! [`Delivery::Failed`] (crash-stop) instead of aborting the run — the
//! protocol core reassigns its chunks like any other crash.
//!
//! Honest workers are deterministic, so a run's outcome is independent
//! of thread scheduling as long as the caller waits for the full wave:
//! poll batches are sorted by worker id, and the protocol core sorts
//! the assembled wave again before ingesting.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::super::byzantine::ByzantineBehavior;
use super::super::compress::Compressor;
use super::super::worker::{Request, Response, WorkerState};
use super::super::{ChunkId, WorkerId};
use super::{AdversaryWiring, Delivery, TaskBundle, Transport};
use crate::data::Batch;
use crate::grad::GradientComputer;
use crate::Result;

fn byzantine_fn(
    f: &mut impl FnMut(WorkerId) -> Option<ByzantineBehavior>,
) -> impl FnMut(WorkerId) -> Option<ByzantineBehavior> + '_ {
    move |w| f(w)
}

/// Handle to the running worker-thread pool.
pub struct ThreadedTransport {
    senders: Vec<Sender<Request>>,
    receiver: Receiver<Response>,
    handles: Vec<JoinHandle<()>>,
    /// Responses still owed by worker threads (one per submitted
    /// bundle, across all waves in flight).
    in_flight: usize,
    /// Wall-clock origin of the transport clock.
    origin: Instant,
    pub n: usize,
}

impl ThreadedTransport {
    /// Spawn `n` workers. `byzantine(i)` returns the behaviour for
    /// worker i (None = honest). All workers share the engine handle
    /// (engines are Send + Sync; the XLA engine serializes internally).
    pub fn spawn(
        n: usize,
        engine: Arc<dyn GradientComputer>,
        mut byzantine: impl FnMut(WorkerId) -> Option<ByzantineBehavior>,
        latency_us: u64,
    ) -> ThreadedTransport {
        Self::spawn_with_compressor(n, engine, byzantine_fn(&mut byzantine), None, latency_us)
    }

    /// Spawn with an optional gradient compressor applied to every
    /// outgoing symbol (the §2.1/§5 compressed-gradients generalization).
    pub fn spawn_with_compressor(
        n: usize,
        engine: Arc<dyn GradientComputer>,
        mut byzantine: impl FnMut(WorkerId) -> Option<ByzantineBehavior>,
        compressor: Option<Arc<dyn Compressor>>,
        latency_us: u64,
    ) -> ThreadedTransport {
        Self::spawn_full(n, engine, byzantine_fn(&mut byzantine), compressor, latency_us, None)
    }

    /// Spawn with every knob, including the coordinated-adversary
    /// wiring (colluding workers get a line to the shared
    /// [`crate::adversary::AdversaryController`]; the stateless
    /// `byzantine` path and the coordinated path are mutually
    /// exclusive per worker — the master passes one or the other).
    pub fn spawn_full(
        n: usize,
        engine: Arc<dyn GradientComputer>,
        mut byzantine: impl FnMut(WorkerId) -> Option<ByzantineBehavior>,
        compressor: Option<Arc<dyn Compressor>>,
        latency_us: u64,
        adversary: Option<AdversaryWiring>,
    ) -> ThreadedTransport {
        let (resp_tx, resp_rx) = channel::<Response>();
        let mut senders = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for id in 0..n {
            let (req_tx, req_rx) = channel::<Request>();
            senders.push(req_tx);
            let resp_tx = resp_tx.clone();
            let mut state = WorkerState::new(id, engine.clone(), byzantine(id), compressor.clone())
                .with_adversary(adversary.as_ref().and_then(|aw| aw.handle(id)));
            handles.push(
                std::thread::Builder::new()
                    .name(format!("r3bft-worker-{id}"))
                    .spawn(move || {
                        while let Ok(req) = req_rx.recv() {
                            match req {
                                Request::Shutdown => break,
                                Request::Compute { iter, phase, wave, theta, tasks } => {
                                    if latency_us > 0 {
                                        std::thread::sleep(std::time::Duration::from_micros(
                                            latency_us,
                                        ));
                                    }
                                    // a panic must become a Response, not a
                                    // dead thread: the master counts one
                                    // delivery per submitted bundle, so a
                                    // silently-lost worker would stall it
                                    let result = std::panic::catch_unwind(
                                        std::panic::AssertUnwindSafe(|| {
                                            state.handle(iter, &theta, tasks)
                                        }),
                                    );
                                    let error = match &result {
                                        Ok(Ok(_)) => None,
                                        Ok(Err(e)) => Some(format!("{e:#}")),
                                        Err(p) => Some(
                                            p.downcast_ref::<String>()
                                                .cloned()
                                                .or_else(|| {
                                                    p.downcast_ref::<&str>()
                                                        .map(|s| s.to_string())
                                                })
                                                .unwrap_or_else(|| "worker panicked".into()),
                                        ),
                                    };
                                    let symbols = match result {
                                        Ok(Ok(symbols)) => symbols,
                                        _ => vec![],
                                    };
                                    let resp =
                                        Response { worker: id, iter, phase, wave, symbols, error };
                                    if resp_tx.send(resp).is_err() {
                                        break; // master gone
                                    }
                                }
                            }
                        }
                    })
                    .expect("spawn worker thread"),
            );
        }
        ThreadedTransport {
            senders,
            receiver: resp_rx,
            handles,
            in_flight: 0,
            origin: Instant::now(),
            n,
        }
    }

    /// Send a compute request to one worker (does not count toward the
    /// poll bookkeeping — use [`Transport::submit`] in protocol code).
    pub fn send(
        &self,
        w: WorkerId,
        iter: u64,
        phase: u32,
        wave: u64,
        theta: &Arc<Vec<f32>>,
        tasks: Vec<(ChunkId, Batch)>,
    ) -> Result<()> {
        self.senders[w]
            .send(Request::Compute { iter, phase, wave, theta: theta.clone(), tasks })
            .map_err(|_| anyhow::anyhow!("worker {w} channel closed"))
    }

    /// An engine error or panic is a crash-stop, not a run abort.
    fn to_delivery(&self, resp: Response, at_ns: u64) -> Delivery {
        match &resp.error {
            Some(err) => {
                log::warn!("worker {} failed: {err}", resp.worker);
                Delivery::Failed { at_ns, worker: resp.worker }
            }
            None => Delivery::Response { at_ns, response: resp },
        }
    }
}

impl Transport for ThreadedTransport {
    fn n(&self) -> usize {
        self.n
    }

    fn now_ns(&self) -> u64 {
        self.origin.elapsed().as_nanos() as u64
    }

    fn submit(
        &mut self,
        iter: u64,
        phase: u32,
        wave: u64,
        theta: &Arc<Vec<f32>>,
        bundles: Vec<TaskBundle>,
    ) -> Result<()> {
        for TaskBundle { worker, tasks } in bundles {
            self.send(worker, iter, phase, wave, theta, tasks)?;
            self.in_flight += 1;
        }
        Ok(())
    }

    fn poll(&mut self, deadline_ns: Option<u64>) -> Result<Vec<Delivery>> {
        let mut out: Vec<Delivery> = Vec::new();
        if self.in_flight == 0 {
            return Ok(out);
        }
        // block for the first response (bounded by the deadline)
        let first = match deadline_ns {
            None => {
                let r = self.receiver.recv();
                Some(r.map_err(|_| anyhow::anyhow!("all workers disconnected"))?)
            }
            Some(d) => {
                let now = self.now_ns();
                if d <= now {
                    // past the deadline: hand over whatever already
                    // arrived, never block
                    self.receiver.try_recv().ok()
                } else {
                    match self.receiver.recv_timeout(Duration::from_nanos(d - now)) {
                        Ok(r) => Some(r),
                        Err(RecvTimeoutError::Timeout) => None,
                        Err(RecvTimeoutError::Disconnected) => {
                            anyhow::bail!("all workers disconnected")
                        }
                    }
                }
            }
        };
        if let Some(resp) = first {
            self.in_flight -= 1;
            let at = self.now_ns();
            out.push(self.to_delivery(resp, at));
            // drain whatever else is already ready, without blocking
            while self.in_flight > 0 {
                match self.receiver.try_recv() {
                    Ok(resp) => {
                        self.in_flight -= 1;
                        let at = self.now_ns();
                        out.push(self.to_delivery(resp, at));
                    }
                    Err(_) => break,
                }
            }
            out.sort_by_key(|d| d.worker());
        }
        Ok(out)
    }

    fn shutdown(&mut self) {
        for s in &self.senders {
            let _ = s.send(Request::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        self.in_flight = 0;
    }
}

impl Drop for ThreadedTransport {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AttackConfig, AttackKind};
    use crate::data::{Dataset, LinRegDataset};
    use crate::grad::{ModelSpec, NativeEngine};

    fn pool(n: usize, byz: Vec<WorkerId>) -> (ThreadedTransport, LinRegDataset) {
        let ds = LinRegDataset::generate(64, 8, 0.0, 1);
        let engine: Arc<dyn GradientComputer> =
            Arc::new(NativeEngine::new(ModelSpec::LinReg { d: 8, batch: 64 }));
        let pool = ThreadedTransport::spawn(
            n,
            engine,
            |i| {
                byz.contains(&i).then(|| {
                    ByzantineBehavior::new(
                        AttackConfig { kind: AttackKind::SignFlip, p: 1.0, magnitude: 1.0 },
                        7,
                        i,
                    )
                })
            },
            0,
        );
        (pool, ds)
    }

    /// Poll until `expected` responses for (iter, phase) arrived,
    /// panicking on failures; returns them sorted by worker id.
    fn collect(
        pool: &mut ThreadedTransport,
        iter: u64,
        phase: u32,
        expected: usize,
    ) -> Vec<Response> {
        let mut out: Vec<Response> = Vec::new();
        while out.len() < expected {
            for d in pool.poll(None).unwrap() {
                match d {
                    Delivery::Response { response, .. }
                        if response.iter == iter && response.phase == phase =>
                    {
                        out.push(response)
                    }
                    Delivery::Response { .. } => {} // stale: dropped
                    Delivery::Failed { worker, .. } => panic!("worker {worker} failed"),
                }
            }
        }
        out.sort_by_key(|r| r.worker);
        out
    }

    #[test]
    fn honest_workers_return_identical_symbols() {
        let (mut pool, ds) = pool(3, vec![]);
        let theta = Arc::new(vec![0.1f32; 8]);
        let batch = ds.batch(&(0..16).collect::<Vec<_>>());
        let bundles = (0..3)
            .map(|w| TaskBundle { worker: w, tasks: vec![(5, batch.clone())] })
            .collect();
        pool.submit(0, 0, 0, &theta, bundles).unwrap();
        let resps = collect(&mut pool, 0, 0, 3);
        assert_eq!(resps.len(), 3);
        let g0 = &resps[0].symbols[0].grad;
        for r in &resps {
            assert_eq!(r.symbols.len(), 1);
            assert_eq!(r.symbols[0].chunk, 5);
            assert_eq!(&r.symbols[0].grad, g0, "honest symbols must be bit-identical");
            assert!(!r.symbols[0].tampered);
        }
    }

    #[test]
    fn byzantine_worker_tampers() {
        let (mut pool, ds) = pool(2, vec![1]);
        let theta = Arc::new(vec![0.1f32; 8]);
        let batch = ds.batch(&(0..16).collect::<Vec<_>>());
        let bundles = (0..2)
            .map(|w| TaskBundle { worker: w, tasks: vec![(0, batch.clone())] })
            .collect();
        pool.submit(0, 0, 0, &theta, bundles).unwrap();
        let resps = collect(&mut pool, 0, 0, 2);
        let honest = resps.iter().find(|r| r.worker == 0).unwrap();
        let byz = resps.iter().find(|r| r.worker == 1).unwrap();
        assert!(byz.symbols[0].tampered);
        assert_ne!(honest.symbols[0].grad, byz.symbols[0].grad);
    }

    #[test]
    fn tamper_decision_is_per_iteration() {
        // p = 1.0 means tampering in EVERY iteration, across phases
        let (mut pool, ds) = pool(1, vec![0]);
        let theta = Arc::new(vec![0.1f32; 8]);
        let batch = ds.batch(&(0..16).collect::<Vec<_>>());
        for phase in 0..3u32 {
            let bundles = vec![TaskBundle { worker: 0, tasks: vec![(0, batch.clone())] }];
            pool.submit(7, phase, phase as u64, &theta, bundles).unwrap();
            let r = collect(&mut pool, 7, phase, 1);
            assert!(r[0].symbols[0].tampered, "phase {phase}");
        }
    }

    #[test]
    fn multiple_chunks_per_request() {
        let (mut pool, ds) = pool(1, vec![]);
        let theta = Arc::new(vec![0.0f32; 8]);
        let b1 = ds.batch(&(0..8).collect::<Vec<_>>());
        let b2 = ds.batch(&(8..16).collect::<Vec<_>>());
        pool.submit(0, 0, 0, &theta, vec![TaskBundle { worker: 0, tasks: vec![(0, b1), (1, b2)] }])
            .unwrap();
        let r = collect(&mut pool, 0, 0, 1);
        assert_eq!(r[0].symbols.len(), 2);
        assert_ne!(r[0].symbols[0].grad, r[0].symbols[1].grad);
    }

    #[test]
    fn deliveries_are_timestamped_and_batches_sorted() {
        let (mut pool, ds) = pool(4, vec![]);
        let theta = Arc::new(vec![0.1f32; 8]);
        let batch = ds.batch(&(0..16).collect::<Vec<_>>());
        let bundles: Vec<TaskBundle> = (0..4)
            .rev() // submit in reverse order on purpose
            .map(|w| TaskBundle { worker: w, tasks: vec![(w, batch.clone())] })
            .collect();
        pool.submit(3, 0, 0, &theta, bundles).unwrap();
        let mut got: Vec<(u64, WorkerId)> = Vec::new();
        while got.len() < 4 {
            let b = pool.poll(None).unwrap();
            // within one poll batch: sorted by worker id
            let ids: Vec<WorkerId> = b.iter().map(|d| d.worker()).collect();
            let mut sorted = ids.clone();
            sorted.sort_unstable();
            assert_eq!(ids, sorted);
            got.extend(b.into_iter().map(|d| (d.at_ns(), d.worker())));
        }
        // nothing left: an idle poll returns immediately
        assert!(pool.poll(None).unwrap().is_empty());
        pool.shutdown();
    }

    #[test]
    fn erroring_worker_becomes_failed_delivery() {
        // a dim-mismatched batch makes the engine error; the master
        // must see Delivery::Failed (crash-stop), not hang or abort
        let (mut pool, ds) = pool(2, vec![]);
        let theta = Arc::new(vec![0.1f32; 8]);
        let good = ds.batch(&(0..16).collect::<Vec<_>>());
        let bad = crate::data::Batch::LinReg { x: vec![0.0; 7], y: vec![0.0], b: 1, d: 7 };
        pool.submit(
            0,
            0,
            0,
            &theta,
            vec![
                TaskBundle { worker: 0, tasks: vec![(0, good)] },
                TaskBundle { worker: 1, tasks: vec![(1, bad)] },
            ],
        )
        .unwrap();
        let mut ok = 0usize;
        let mut failed: Vec<WorkerId> = Vec::new();
        while ok + failed.len() < 2 {
            for d in pool.poll(None).unwrap() {
                match d {
                    Delivery::Response { .. } => ok += 1,
                    Delivery::Failed { worker, .. } => failed.push(worker),
                }
            }
        }
        assert_eq!(ok, 1);
        assert_eq!(failed, vec![1]);
    }
}
