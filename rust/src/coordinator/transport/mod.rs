//! Transport layer: how the master's protocol core talks to workers.
//!
//! The protocol core ([`super::protocol`]) is written against the
//! [`Transport`] trait — a synchronous *scatter/gather* API matched to
//! the paper's synchronous parallelized-SGD model:
//!
//! * [`Transport::scatter`] queues one phase's task bundles (θ
//!   broadcast + per-worker chunk batches);
//! * [`Transport::gather`] blocks until every scattered-to worker has
//!   responded or is known to have failed, and returns the responses
//!   **sorted by worker id** so protocol behaviour is deterministic
//!   and transport-independent;
//! * [`Transport::take_failed`] drains the set of workers newly known
//!   to have failed (crash-stop model), so the protocol can reassign
//!   their chunks.
//!
//! Two implementations:
//!
//! * [`ThreadedTransport`] — one OS thread per worker over mpsc
//!   channels (the original execution model; real parallelism, real
//!   wall-clock latency).
//! * [`SimTransport`] — deterministic discrete-event simulation in
//!   virtual time: per-worker latency distributions, stragglers, and
//!   crash-drops, scaling to thousands of simulated workers with zero
//!   OS threads. With zero latency and no faults it is bit-identical
//!   to [`ThreadedTransport`] for the same seed (both drive the same
//!   [`super::worker::WorkerState`] compute core).

pub mod sim;
pub mod threaded;

use std::sync::Arc;

use super::worker::Response;
use super::{ChunkId, WorkerId};
use crate::data::Batch;
use crate::Result;

pub use sim::{LatencyModel, SimConfig, SimTransport};
pub use threaded::ThreadedTransport;

/// One worker's task list for a phase.
pub struct TaskBundle {
    pub worker: WorkerId,
    pub tasks: Vec<(ChunkId, Batch)>,
}

/// A synchronous scatter/gather channel between master and workers.
///
/// Contract: every `scatter` for a `(iter, phase)` pair must be
/// followed by exactly one `gather` for the same pair before the next
/// scatter (the protocol is phase-synchronous). `gather` returns one
/// [`Response`] per scattered-to worker that has not failed, sorted by
/// worker id; failed workers are reported through [`Transport::take_failed`].
pub trait Transport {
    /// Number of worker endpoints (fixed at construction).
    fn n(&self) -> usize;

    /// Queue task bundles for `(iter, phase)`.
    fn scatter(
        &mut self,
        iter: u64,
        phase: u32,
        theta: &Arc<Vec<f32>>,
        bundles: Vec<TaskBundle>,
    ) -> Result<()>;

    /// Collect the responses for `(iter, phase)`, sorted by worker id.
    fn gather(&mut self, iter: u64, phase: u32) -> Result<Vec<Response>>;

    /// Drain the workers that failed since the last call (crash-stop).
    fn take_failed(&mut self) -> Vec<WorkerId>;

    /// Tear down (idempotent).
    fn shutdown(&mut self) {}
}
