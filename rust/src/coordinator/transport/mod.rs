//! Transport layer: how the master's protocol core talks to workers.
//!
//! The protocol core ([`super::protocol`]) is written against the
//! [`Transport`] trait — a **completion-driven** submit/poll API. The
//! paper's synchronous scatter/gather model made every round wait for
//! its slowest worker; this contract instead hands the protocol each
//! response *as it arrives*, so the caller decides how long to keep
//! waiting (see `GatherPolicy` in [`crate::config`]):
//!
//! * [`Transport::submit`] queues one wave's task bundles (θ broadcast
//!   + per-worker chunk batches) without waiting for anything;
//! * [`Transport::poll`] waits for the **next arrival instant** and
//!   returns every [`Delivery`] due at it, sorted by worker id. Each
//!   delivery is stamped with its arrival time on the transport's
//!   clock — *virtual* time for [`SimTransport`], *wall-clock* for
//!   [`ThreadedTransport`] — and worker failures come back in-band as
//!   [`Delivery::Failed`] (there is no failure side-channel);
//! * [`Transport::now_ns`] exposes that clock, which is also how the
//!   per-round `round_time` metric is measured.
//!
//! The per-delivery timestamps are consumed twice upstream: the
//! gather decides when to stop waiting, and the protocol core feeds
//! each fresh delivery's relative delay into the per-worker latency
//! profiles of [`super::latency`] — timing doubles as a Byzantine
//! signal for the `latency-selective` audit policy.
//!
//! The protocol core is responsible for matching deliveries to the
//! wave it is waiting on. Every [`Transport::submit`] carries a
//! caller-chosen *wave id*, echoed verbatim in each resulting
//! [`super::worker::Response`]: with pipelined rounds several waves
//! (even of different iterations) are in flight at once, and the core
//! routes each delivery by wave id — buffering deliveries that belong
//! to another still-live wave, and discarding deliveries from dead
//! waves (a straggler the quorum stopped waiting for, or a provisional
//! wave invalidated by a reissue), so no symbol leaks across phases or
//! rounds.
//!
//! Two implementations:
//!
//! * [`ThreadedTransport`] — one OS thread per worker over mpsc
//!   channels (the original execution model; real parallelism, real
//!   wall-clock latency). A worker whose engine errors or panics is
//!   reported as [`Delivery::Failed`] (crash-stop), not a run abort.
//! * [`SimTransport`] — deterministic discrete-event simulation in
//!   virtual time: per-worker latency distributions, stragglers, and
//!   crash-drops, scaling to thousands of simulated workers with zero
//!   OS threads. With zero latency and no faults it is bit-identical
//!   to [`ThreadedTransport`] for the same seed (both drive the same
//!   [`super::worker::WorkerState`] compute core), because every
//!   delivery then shares one arrival instant and a single `poll`
//!   returns the full wave sorted by worker id — exactly the old
//!   blocking gather.

pub mod net;
pub mod sim;
pub mod threaded;

use std::sync::Arc;

use super::worker::{AdversaryHandle, Response};
use super::WorkerId;
use crate::adversary::AdversaryController;
use crate::data::Batch;
use crate::Result;

pub use net::chaos::ChaosSpec;
pub use net::frame::AuthKey;
pub use net::{NetConfig, NetTransport, ReconnectBudget, SleepFn};
pub use sim::{LatencyModel, SimConfig, SimTransport, StragglerModel};
pub use threaded::ThreadedTransport;

use super::ChunkId;

/// One worker's task list for a wave.
pub struct TaskBundle {
    pub worker: WorkerId,
    pub tasks: Vec<(ChunkId, Batch)>,
}

/// How a transport plugs its workers into a coordinated
/// [`AdversaryController`]: `lo` is the global id of local worker 0
/// (shard inner transports pass their range offset; single-master runs
/// pass 0). Construction hands each colluding worker an
/// [`AdversaryHandle`] carrying its global id, and the simulator asks
/// the controller for per-response fake stalls.
#[derive(Clone)]
pub struct AdversaryWiring {
    pub controller: Arc<AdversaryController>,
    pub lo: WorkerId,
}

impl AdversaryWiring {
    /// The handle for local worker `id` (None for honest workers).
    pub fn handle(&self, id: WorkerId) -> Option<AdversaryHandle> {
        let global = self.lo + id;
        self.controller
            .is_colluder(global)
            .then(|| AdversaryHandle { controller: self.controller.clone(), worker: global })
    }
}

/// One completed exchange surfaced by [`Transport::poll`].
#[derive(Debug)]
pub enum Delivery {
    /// A worker's response, stamped with its arrival time (ns on the
    /// transport's clock).
    Response { at_ns: u64, response: Response },
    /// The worker is now known to have crash-stopped: it will never
    /// answer this or any future submit. Reported in-band so the
    /// protocol can reassign its chunks the moment it learns.
    Failed { at_ns: u64, worker: WorkerId },
}

impl Delivery {
    pub fn at_ns(&self) -> u64 {
        match self {
            Delivery::Response { at_ns, .. } | Delivery::Failed { at_ns, .. } => *at_ns,
        }
    }

    pub fn worker(&self) -> WorkerId {
        match self {
            Delivery::Response { response, .. } => response.worker,
            Delivery::Failed { worker, .. } => *worker,
        }
    }
}

/// A completion-driven channel between master and workers.
///
/// Contract: `submit` never blocks on worker compute; every submitted
/// bundle eventually produces exactly one [`Delivery`] (a `Response`,
/// or `Failed` if the worker crash-stopped). `poll` advances to the
/// next arrival instant — blocking in wall-clock for the threaded
/// transport, advancing the virtual clock for the simulator — and
/// returns all deliveries due at it, sorted by worker id. Deliveries
/// are returned in global arrival order across waves: the caller
/// routes by the echoed `wave` id and by the worker set it is actually
/// waiting on, discarding stale deliveries from dead waves.
pub trait Transport {
    /// Number of worker endpoints (fixed at construction).
    fn n(&self) -> usize;

    /// The transport clock: ns since construction. Virtual time for
    /// the simulator, wall-clock for the threaded pool.
    fn now_ns(&self) -> u64;

    /// Queue task bundles for `(iter, phase)` without waiting. `wave`
    /// is a caller-chosen id echoed in every resulting response —
    /// unique per submit so pipelined rounds can route deliveries.
    fn submit(
        &mut self,
        iter: u64,
        phase: u32,
        wave: u64,
        theta: &Arc<Vec<f32>>,
        bundles: Vec<TaskBundle>,
    ) -> Result<()>;

    /// Wait for the next deliveries. Returns the batch of deliveries
    /// sharing the next arrival instant, sorted by worker id; an empty
    /// vec means `deadline_ns` passed first (or nothing is in flight).
    /// With `deadline_ns` set, the clock never advances past the
    /// deadline on a timeout.
    fn poll(&mut self, deadline_ns: Option<u64>) -> Result<Vec<Delivery>>;

    /// Tear down (idempotent). Undelivered responses are discarded.
    fn shutdown(&mut self) {}

    /// Socket-level byte/reconnect counters, if this transport moves
    /// real bytes. `None` (the default) means the caller should keep
    /// its own payload-based `bytes_round` estimate; `Some` means the
    /// counters are authoritative — they include frame and header
    /// overhead, which the in-process estimate cannot see.
    fn net_stats(&self) -> Option<NetStats> {
        None
    }

    /// Drain reconnect notices accumulated since the last drain:
    /// `(at_ns on the transport clock, worker)` per re-established
    /// session. Non-network transports never reconnect.
    fn drain_reconnects(&mut self) -> Vec<(u64, WorkerId)> {
        Vec::new()
    }

    /// Drain worker-side telemetry spans accumulated since the last
    /// drain, already remapped onto *this transport's* clock via the
    /// per-link offset estimate. Worker ids are **local**; the caller
    /// applies its global offset. Only a telemetry-enabled net
    /// transport ever yields any.
    fn drain_remote_spans(&mut self) -> Vec<RemoteSpan> {
        Vec::new()
    }

    /// Per-link health snapshot (RTT/offset estimates, reconnect and
    /// resend counters, worker-reported conduct counters). Worker ids
    /// are local. Empty for in-process transports.
    fn link_stats(&self) -> Vec<LinkStats> {
        Vec::new()
    }
}

/// One worker-side span shipped over a telemetry-enabled net link (see
/// [`Transport::drain_remote_spans`]), with `start_ns`/`end_ns`
/// already remapped onto the master transport clock. `kind` is one of
/// the `net::frame::SPAN_*` constants (compute / decode / encode).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RemoteSpan {
    /// Local worker id (caller remaps to global).
    pub worker: WorkerId,
    /// `net::frame::SPAN_COMPUTE` / `SPAN_DECODE` / `SPAN_ENCODE`.
    pub kind: u8,
    pub iter: u64,
    pub wave: u64,
    pub chunk: u64,
    /// Master-transport-clock ns (clock-offset remapped).
    pub start_ns: u64,
    pub end_ns: u64,
}

/// One link's live health snapshot (see [`Transport::link_stats`]).
/// Counter fields are cumulative since transport construction;
/// `rtt_ns`/`offset_ns` are the current EWMA estimates (0 until the
/// first handshake sample).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LinkStats {
    /// Local worker id (caller remaps to global).
    pub worker: WorkerId,
    /// EWMA link round-trip estimate, ns.
    pub rtt_ns: u64,
    /// Estimated worker-clock minus master-clock, ns (NTP midpoint,
    /// EWMA-refined on every telemetry batch).
    pub offset_ns: i64,
    /// Sessions re-established on this link.
    pub reconnects: u64,
    /// Master-side request resends (reconnect replays + chaos
    /// resend-on-timeout).
    pub resends: u64,
    /// Worker-reported: frames refused for a bad MAC.
    pub auth_rejects: u64,
    /// Worker-reported: requests handled (process lifetime).
    pub requests: u64,
    /// Worker-reported: duplicate requests observed (master resends).
    pub dup_requests: u64,
    /// Worker-reported: undecodable frames (chaos corruption).
    pub chaos_hits: u64,
    /// Worker-reported: span-queue high-water mark in the last batch.
    pub queue_depth: u64,
    /// Spans dropped to keep buffers bounded (worker + master side).
    pub dropped_spans: u64,
}

/// Cumulative socket counters for a byte-moving transport (see
/// [`Transport::net_stats`]). All values are totals since construction;
/// callers diff against their own baseline for per-round figures.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct NetStats {
    /// Bytes written to sockets, including frame length prefixes and
    /// headers.
    pub bytes_tx: u64,
    /// Bytes read from sockets, same accounting.
    pub bytes_rx: u64,
    /// Sessions re-established after a drop (a worker that exhausts
    /// its reconnect budget becomes a crash-stop instead).
    pub reconnects: u64,
}
