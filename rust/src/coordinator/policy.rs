//! Fault-check policies: when does the master audit an iteration, and
//! with what proactive replication does it start?
//!
//! | policy        | proactive r | audit decision               | paper |
//! |---------------|-------------|------------------------------|-------|
//! | `None`        | 1           | never                        | §1.1 (vulnerable baseline) |
//! | `Deterministic`| f_t + 1    | every iteration (built-in)   | §4.1  |
//! | `Bernoulli(q)`| 1           | coin flip with fixed q       | §4.2  |
//! | `Adaptive`    | 1           | coin flip with q*_t (Eq. 4)  | §4.3  |
//! | `Selective`   | 1           | per-worker coin flips driven | §5    |
//! |               |             | by reliability scores        |       |

use super::adaptive::AdaptiveState;
use super::WorkerId;
use crate::config::PolicyKind;
use crate::util::rng::Pcg64;

/// What the master decided for one iteration.
#[derive(Clone, Debug, PartialEq)]
pub enum AuditDecision {
    /// No audit: accept the symbols as-is.
    Skip,
    /// Audit every chunk (replication comparison over all of them).
    Full,
    /// Audit only chunks owned by the given workers (selective checks).
    Workers(Vec<WorkerId>),
}

/// Runtime policy state.
pub struct FaultCheckPolicy {
    kind: PolicyKind,
    rng: Pcg64,
    adaptive: AdaptiveState,
    /// Reliability scores in [0,1], one per worker (selective policy).
    /// Start optimistic at 1.0; a detected-but-unidentified incident
    /// halves every suspect's score; identification zeroes it.
    pub reliability: Vec<f64>,
    /// The q actually used for the most recent decision (logged by E5).
    pub last_q: f64,
}

impl FaultCheckPolicy {
    pub fn new(kind: PolicyKind, n_workers: usize, seed: u64) -> Self {
        let p_assumed = match &kind {
            PolicyKind::Adaptive { p_assumed } => *p_assumed,
            _ => 0.5,
        };
        FaultCheckPolicy {
            kind,
            rng: Pcg64::new(seed, 0x90_11c4),
            adaptive: AdaptiveState::new(p_assumed),
            reliability: vec![1.0; n_workers],
            last_q: 0.0,
        }
    }

    pub fn kind(&self) -> &PolicyKind {
        &self.kind
    }

    /// Proactive replication factor for this iteration.
    pub fn proactive_r(&self, f_t: usize) -> usize {
        match self.kind {
            PolicyKind::Deterministic => f_t + 1,
            _ => 1,
        }
    }

    /// Audit decision for iteration `t`.
    ///
    /// * `observed_loss` — robust estimate of ℓ_t (median of chunk
    ///   losses), used by the adaptive policy.
    /// * `f_t` — unidentified Byzantine budget f - κ_t.
    /// * `active` — currently active workers.
    pub fn decide(
        &mut self,
        _t: u64,
        observed_loss: f64,
        f_t: usize,
        active: &[WorkerId],
    ) -> AuditDecision {
        if f_t == 0 {
            // every Byzantine worker is identified: auditing is pure waste
            self.last_q = 0.0;
            if matches!(self.kind, PolicyKind::Adaptive { .. }) {
                // keep λ_t tracking the observed loss for logging even
                // though q* is pinned to 0 by κ_t = f
                self.adaptive.decide_q(observed_loss, 0);
            }
            return AuditDecision::Skip;
        }
        match &self.kind {
            PolicyKind::None => {
                self.last_q = 0.0;
                AuditDecision::Skip
            }
            PolicyKind::Deterministic => {
                self.last_q = 1.0;
                AuditDecision::Full
            }
            PolicyKind::Bernoulli { q } => {
                self.last_q = *q;
                if self.rng.bernoulli(*q) {
                    AuditDecision::Full
                } else {
                    AuditDecision::Skip
                }
            }
            PolicyKind::Adaptive { .. } => {
                let q = self.adaptive.decide_q(observed_loss, f_t);
                self.last_q = q;
                if self.rng.bernoulli(q) {
                    AuditDecision::Full
                } else {
                    AuditDecision::Skip
                }
            }
            PolicyKind::Selective { q_base } => {
                // per-worker probability: q_i = q_base * (2 - ρ_i),
                // clamped — workers with degraded reliability get
                // audited up to twice as often.
                let mut suspects = Vec::new();
                for &w in active {
                    let q_i = (q_base * (2.0 - self.reliability[w])).clamp(0.0, 1.0);
                    if self.rng.bernoulli(q_i) {
                        suspects.push(w);
                    }
                }
                self.last_q = *q_base;
                if suspects.is_empty() {
                    AuditDecision::Skip
                } else {
                    AuditDecision::Workers(suspects)
                }
            }
        }
    }

    /// Adaptive-policy introspection (λ_t, q*_t) for logging.
    pub fn adaptive_state(&self) -> (f64, f64) {
        (self.adaptive.last_lambda, self.adaptive.last_qstar)
    }

    /// Feedback: a fault was detected on a chunk owned by these workers
    /// (identity still ambiguous) — degrade their reliability.
    pub fn report_suspects(&mut self, owners: &[WorkerId]) {
        for &w in owners {
            self.reliability[w] *= 0.5;
        }
    }

    /// Feedback: worker identified as Byzantine.
    pub fn report_identified(&mut self, w: WorkerId) {
        self.reliability[w] = 0.0;
    }

    /// Feedback: worker's chunk verified correct — slowly restore trust.
    pub fn report_verified(&mut self, w: WorkerId) {
        self.reliability[w] = (self.reliability[w] + 0.1).min(1.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn active(n: usize) -> Vec<WorkerId> {
        (0..n).collect()
    }

    #[test]
    fn none_never_audits() {
        let mut p = FaultCheckPolicy::new(PolicyKind::None, 8, 1);
        for t in 0..100 {
            assert_eq!(p.decide(t, 5.0, 2, &active(8)), AuditDecision::Skip);
        }
        assert_eq!(p.proactive_r(2), 1);
    }

    #[test]
    fn deterministic_always_audits_with_replication() {
        let mut p = FaultCheckPolicy::new(PolicyKind::Deterministic, 8, 1);
        assert_eq!(p.proactive_r(2), 3);
        assert_eq!(p.decide(0, 5.0, 2, &active(8)), AuditDecision::Full);
    }

    #[test]
    fn bernoulli_audit_rate_matches_q() {
        let mut p = FaultCheckPolicy::new(PolicyKind::Bernoulli { q: 0.25 }, 8, 7);
        let hits = (0..40_000)
            .filter(|&t| p.decide(t, 1.0, 2, &active(8)) == AuditDecision::Full)
            .count();
        assert!((hits as f64 / 40_000.0 - 0.25).abs() < 0.01);
    }

    #[test]
    fn audits_stop_when_all_byzantine_found() {
        for kind in [
            PolicyKind::Deterministic,
            PolicyKind::Bernoulli { q: 1.0 },
            PolicyKind::Adaptive { p_assumed: 0.9 },
        ] {
            let mut p = FaultCheckPolicy::new(kind, 8, 3);
            assert_eq!(p.decide(0, 100.0, 0, &active(8)), AuditDecision::Skip);
        }
    }

    #[test]
    fn selective_targets_unreliable_workers() {
        let mut p = FaultCheckPolicy::new(PolicyKind::Selective { q_base: 0.3 }, 4, 9);
        p.report_identified(3);
        p.report_suspects(&[1]);
        assert_eq!(p.reliability, vec![1.0, 0.5, 1.0, 0.0]);
        // over many iterations, worker 1 must be audited more than worker 0
        let (mut a0, mut a1) = (0usize, 0usize);
        for t in 0..20_000 {
            if let AuditDecision::Workers(ws) = p.decide(t, 1.0, 2, &active(4)) {
                a0 += ws.contains(&0) as usize;
                a1 += ws.contains(&1) as usize;
            }
        }
        assert!(
            a1 as f64 > 1.3 * a0 as f64,
            "worker1 (ρ=0.5) audited {a1}, worker0 (ρ=1.0) audited {a0}"
        );
        // verified reports restore trust
        for _ in 0..10 {
            p.report_verified(1);
        }
        assert!((p.reliability[1] - 1.0).abs() < 1e-12);
    }
}
