//! Fault-check policies: when does the master audit an iteration, and
//! with what proactive replication does it start?
//!
//! | policy        | proactive r | audit decision               | paper |
//! |---------------|-------------|------------------------------|-------|
//! | `None`        | 1           | never                        | §1.1 (vulnerable baseline) |
//! | `Deterministic`| f_t + 1    | every iteration (built-in)   | §4.1  |
//! | `Bernoulli(q)`| 1           | coin flip with fixed q       | §4.2  |
//! | `Adaptive`    | 1           | coin flip with q*_t (Eq. 4)  | §4.3  |
//! | `Selective`   | 1           | per-worker coin flips driven | §5    |
//! |               |             | by reliability scores        |       |
//! | `LatencySelective` | 1      | per-worker coin flips driven | — (extension; |
//! |               |             | by fused suspicion (latency  | see [`super::latency`]) |
//! |               |             | anomaly + reliability)       |       |
//!
//! All policies passively maintain the per-worker latency profiles
//! and the fused suspicion scores (the protocol core feeds delivery
//! timestamps in regardless of policy, and
//! [`super::events::Event::SuspicionUpdated`] is emitted on material
//! changes), but only `LatencySelective` *acts* on them — both in its
//! audit decision and by ranking audit re-replication onto the
//! least-suspect workers ([`FaultCheckPolicy::rank_extensions`]).

use super::adaptive::AdaptiveState;
use super::latency::{self, LatencyTracker};
use super::WorkerId;
use crate::config::{PolicyKind, DEFAULT_P_ASSUMED};
use crate::util::rng::Pcg64;

/// What the master decided for one iteration.
#[derive(Clone, Debug, PartialEq)]
pub enum AuditDecision {
    /// No audit: accept the symbols as-is.
    Skip,
    /// Audit every chunk (replication comparison over all of them).
    Full,
    /// Audit only chunks owned by the given workers (selective checks).
    Workers(Vec<WorkerId>),
}

/// Runtime policy state.
pub struct FaultCheckPolicy {
    kind: PolicyKind,
    rng: Pcg64,
    adaptive: AdaptiveState,
    /// Reliability scores in [0,1], one per worker (selective policy).
    /// Start optimistic at 1.0; a detected-but-unidentified incident
    /// halves every suspect's score; identification zeroes it.
    pub reliability: Vec<f64>,
    /// Per-worker online latency profiles (EWMA mean + variance of
    /// proactive-wave delivery latencies), fed by the protocol core's
    /// delivery stream. See [`super::latency`].
    pub latency: LatencyTracker,
    /// Fused per-worker suspicion in [0,1]: latency anomaly blended
    /// with the reliability deficit ([`latency::fuse_suspicion`]).
    /// Refreshed once per round by [`FaultCheckPolicy::refresh_suspicion`].
    suspicion: Vec<f64>,
    /// Last suspicion surfaced per worker as an event (change-driven
    /// emission; see [`latency::SUSPICION_EVENT_DELTA`]).
    reported: Vec<f64>,
    /// Workers eliminated after identification. An explicit flag, not
    /// the reliability-==-0.0 sentinel: repeated halving of an
    /// *unidentified* worker underflows to exactly 0.0 after ~1075
    /// incidents, which must not lock an honest worker out of
    /// recovery.
    eliminated: Vec<bool>,
    /// The q actually used for the most recent decision (logged by E5).
    pub last_q: f64,
}

impl FaultCheckPolicy {
    pub fn new(kind: PolicyKind, n_workers: usize, seed: u64) -> Self {
        // non-adaptive kinds never consult p, but the adaptive state
        // still tracks λ_t for logging — seed it with the documented
        // default rather than a buried literal
        let p_assumed = match &kind {
            PolicyKind::Adaptive { p_assumed } => *p_assumed,
            _ => DEFAULT_P_ASSUMED,
        };
        FaultCheckPolicy {
            kind,
            rng: Pcg64::new(seed, 0x90_11c4),
            adaptive: AdaptiveState::new(p_assumed),
            reliability: vec![1.0; n_workers],
            latency: LatencyTracker::new(n_workers),
            suspicion: vec![0.0; n_workers],
            reported: vec![0.0; n_workers],
            eliminated: vec![false; n_workers],
            last_q: 0.0,
        }
    }

    pub fn kind(&self) -> &PolicyKind {
        &self.kind
    }

    /// Proactive replication factor for this iteration.
    pub fn proactive_r(&self, f_t: usize) -> usize {
        match self.kind {
            PolicyKind::Deterministic => f_t + 1,
            _ => 1,
        }
    }

    /// Audit decision for iteration `t`.
    ///
    /// * `observed_loss` — robust estimate of ℓ_t (median of chunk
    ///   losses), used by the adaptive policy.
    /// * `f_t` — unidentified Byzantine budget f - κ_t.
    /// * `active` — currently active workers.
    pub fn decide(
        &mut self,
        _t: u64,
        observed_loss: f64,
        f_t: usize,
        active: &[WorkerId],
    ) -> AuditDecision {
        if f_t == 0 {
            // every Byzantine worker is identified: auditing is pure waste
            self.last_q = 0.0;
            if matches!(self.kind, PolicyKind::Adaptive { .. }) {
                // keep λ_t tracking the observed loss for logging even
                // though q* is pinned to 0 by κ_t = f
                self.adaptive.decide_q(observed_loss, 0);
            }
            return AuditDecision::Skip;
        }
        match &self.kind {
            PolicyKind::None => {
                self.last_q = 0.0;
                AuditDecision::Skip
            }
            PolicyKind::Deterministic => {
                self.last_q = 1.0;
                AuditDecision::Full
            }
            PolicyKind::Bernoulli { q } => {
                self.last_q = *q;
                if self.rng.bernoulli(*q) {
                    AuditDecision::Full
                } else {
                    AuditDecision::Skip
                }
            }
            PolicyKind::Adaptive { .. } => {
                let q = self.adaptive.decide_q(observed_loss, f_t);
                self.last_q = q;
                if self.rng.bernoulli(q) {
                    AuditDecision::Full
                } else {
                    AuditDecision::Skip
                }
            }
            PolicyKind::Selective { q_base } => {
                // per-worker probability: q_i = q_base * (2 - ρ_i),
                // clamped — workers with degraded reliability get
                // audited up to twice as often.
                let mut suspects = Vec::new();
                for &w in active {
                    let q_i = (q_base * (2.0 - self.reliability[w])).clamp(0.0, 1.0);
                    if self.rng.bernoulli(q_i) {
                        suspects.push(w);
                    }
                }
                self.last_q = *q_base;
                if suspects.is_empty() {
                    AuditDecision::Skip
                } else {
                    AuditDecision::Workers(suspects)
                }
            }
            PolicyKind::LatencySelective { q_base } => {
                // per-worker probability q_i = q_base * (1/2 + 2 s_i):
                // a fully-suspect worker (s = 1, e.g. a persistent
                // straggler with degraded reliability) is audited at up
                // to 2.5x the base rate, a fully-trusted one at half of
                // it — the audit budget is *concentrated* on the
                // workers the timing and history point at.
                let mut suspects = Vec::new();
                for &w in active {
                    let q_i = (q_base * (0.5 + 2.0 * self.suspicion[w])).clamp(0.0, 1.0);
                    if self.rng.bernoulli(q_i) {
                        suspects.push(w);
                    }
                }
                self.last_q = *q_base;
                if suspects.is_empty() {
                    AuditDecision::Skip
                } else {
                    AuditDecision::Workers(suspects)
                }
            }
        }
    }

    /// Adaptive-policy introspection (λ_t, q*_t) for logging.
    pub fn adaptive_state(&self) -> (f64, f64) {
        (self.adaptive.last_lambda, self.adaptive.last_qstar)
    }

    /// Feed one delivery's latency into the worker's profile.
    /// `excess_ns` is the delay behind the wave's first arrival on the
    /// transport clock (see [`super::latency`] for the quantization).
    pub fn observe_latency(&mut self, w: WorkerId, excess_ns: u64) {
        self.latency.observe_ns(w, excess_ns);
    }

    /// Feed one abandonment (the quorum/deadline gather stopped
    /// waiting for `w` once `cutoff_excess_ns` had passed since the
    /// wave's first arrival) as a censored latency sample.
    pub fn observe_abandoned(&mut self, w: WorkerId, cutoff_excess_ns: u64) {
        self.latency.observe_abandoned(w, cutoff_excess_ns);
    }

    /// Recompute every active worker's fused suspicion from the latest
    /// latency profiles and reliability scores. Returns the workers
    /// whose suspicion moved by at least
    /// [`latency::SUSPICION_EVENT_DELTA`] since it was last reported
    /// (ascending worker id), for the protocol core to surface as
    /// [`super::events::Event::SuspicionUpdated`].
    pub fn refresh_suspicion(&mut self, active: &[WorkerId]) -> Vec<(WorkerId, f64)> {
        self.latency.refresh(active);
        let mut updates = Vec::new();
        for &w in active {
            let s = latency::fuse_suspicion(self.latency.anomaly(w), self.reliability[w]);
            self.suspicion[w] = s;
            if (s - self.reported[w]).abs() >= latency::SUSPICION_EVENT_DELTA {
                self.reported[w] = s;
                updates.push((w, s));
            }
        }
        updates
    }

    /// Fused per-worker suspicion scores (index = worker id).
    pub fn suspicion(&self) -> &[f64] {
        &self.suspicion
    }

    /// The nonzero suspicion scores as (worker, score) pairs,
    /// ascending by worker id — the metrics layer's suspicion column.
    pub fn suspicion_nonzero(&self) -> Vec<(WorkerId, f64)> {
        self.suspicion
            .iter()
            .enumerate()
            .filter(|(_, &s)| s > 0.0)
            .map(|(w, &s)| (w, s))
            .collect()
    }

    /// Whether audit re-replication (detection/reactive top-ups and
    /// crash reassignment) should rank candidate owners by ascending
    /// suspicion instead of shuffling uniformly — replicas of a
    /// suspect's chunks then land on trusted/fast workers first. Only
    /// the latency-aware policy opts in, so every other policy keeps
    /// its RNG stream (and its bit-identity contracts) untouched.
    pub fn rank_extensions(&self) -> bool {
        matches!(self.kind, PolicyKind::LatencySelective { .. })
    }

    /// Feedback: a fault was detected on a chunk owned by these workers
    /// (identity still ambiguous) — degrade their reliability.
    pub fn report_suspects(&mut self, owners: &[WorkerId]) {
        for &w in owners {
            self.reliability[w] *= 0.5;
        }
    }

    /// Feedback: worker identified as Byzantine. Its reliability is
    /// pinned to 0 and its suspicion score is cleared — the worker
    /// left the roster, so it must stop appearing in the suspicion
    /// column / top-suspect summary (which describe *live* workers);
    /// the event log keeps its pre-elimination history.
    pub fn report_identified(&mut self, w: WorkerId) {
        self.reliability[w] = 0.0;
        self.suspicion[w] = 0.0;
        self.reported[w] = 0.0;
        self.eliminated[w] = true;
    }

    /// Feedback: worker crash-stopped. Clears its suspicion score for
    /// the same roster-view reason as identification (a crash is not
    /// an identification — reliability is left alone).
    pub fn report_crashed(&mut self, w: WorkerId) {
        self.suspicion[w] = 0.0;
        self.reported[w] = 0.0;
    }

    /// Feedback: worker's chunk verified correct — slowly restore
    /// trust. An *identified* liar can never recover: it was
    /// eliminated from the roster, and a stale verification of one of
    /// its earlier copies must not resurrect it. An unidentified
    /// worker always can, however low halving has driven its score.
    pub fn report_verified(&mut self, w: WorkerId) {
        if self.eliminated[w] {
            return;
        }
        self.reliability[w] = (self.reliability[w] + 0.1).min(1.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn active(n: usize) -> Vec<WorkerId> {
        (0..n).collect()
    }

    #[test]
    fn none_never_audits() {
        let mut p = FaultCheckPolicy::new(PolicyKind::None, 8, 1);
        for t in 0..100 {
            assert_eq!(p.decide(t, 5.0, 2, &active(8)), AuditDecision::Skip);
        }
        assert_eq!(p.proactive_r(2), 1);
    }

    #[test]
    fn deterministic_always_audits_with_replication() {
        let mut p = FaultCheckPolicy::new(PolicyKind::Deterministic, 8, 1);
        assert_eq!(p.proactive_r(2), 3);
        assert_eq!(p.decide(0, 5.0, 2, &active(8)), AuditDecision::Full);
    }

    #[test]
    fn bernoulli_audit_rate_matches_q() {
        let mut p = FaultCheckPolicy::new(PolicyKind::Bernoulli { q: 0.25 }, 8, 7);
        let hits = (0..40_000)
            .filter(|&t| p.decide(t, 1.0, 2, &active(8)) == AuditDecision::Full)
            .count();
        assert!((hits as f64 / 40_000.0 - 0.25).abs() < 0.01);
    }

    #[test]
    fn audits_stop_when_all_byzantine_found() {
        for kind in [
            PolicyKind::Deterministic,
            PolicyKind::Bernoulli { q: 1.0 },
            PolicyKind::Adaptive { p_assumed: 0.9 },
        ] {
            let mut p = FaultCheckPolicy::new(kind, 8, 3);
            assert_eq!(p.decide(0, 100.0, 0, &active(8)), AuditDecision::Skip);
        }
    }

    #[test]
    fn selective_targets_unreliable_workers() {
        let mut p = FaultCheckPolicy::new(PolicyKind::Selective { q_base: 0.3 }, 4, 9);
        p.report_identified(3);
        p.report_suspects(&[1]);
        assert_eq!(p.reliability, vec![1.0, 0.5, 1.0, 0.0]);
        // over many iterations, worker 1 must be audited more than worker 0
        let (mut a0, mut a1) = (0usize, 0usize);
        for t in 0..20_000 {
            if let AuditDecision::Workers(ws) = p.decide(t, 1.0, 2, &active(4)) {
                a0 += ws.contains(&0) as usize;
                a1 += ws.contains(&1) as usize;
            }
        }
        assert!(
            a1 as f64 > 1.3 * a0 as f64,
            "worker1 (ρ=0.5) audited {a1}, worker0 (ρ=1.0) audited {a0}"
        );
        // verified reports restore trust
        for _ in 0..10 {
            p.report_verified(1);
        }
        assert!((p.reliability[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn reliability_halves_per_unidentified_incident() {
        // every detected-but-unidentified incident halves each
        // suspect's score, compounding across incidents
        let mut p = FaultCheckPolicy::new(PolicyKind::Selective { q_base: 0.2 }, 4, 1);
        p.report_suspects(&[0, 2]);
        assert_eq!(p.reliability, vec![0.5, 1.0, 0.5, 1.0]);
        p.report_suspects(&[0]);
        p.report_suspects(&[0]);
        assert_eq!(p.reliability[0], 0.125);
        assert_eq!(p.reliability[2], 0.5, "other suspects unaffected");
    }

    #[test]
    fn identification_zeroes_and_is_permanent() {
        // zeroing on identification beats any halving history, and no
        // amount of later "verified" feedback can resurrect the score:
        // the worker left the roster — recovery is impossible
        let mut p = FaultCheckPolicy::new(PolicyKind::Selective { q_base: 0.2 }, 3, 2);
        p.report_suspects(&[1]);
        p.report_identified(1);
        assert_eq!(p.reliability[1], 0.0);
        for _ in 0..100 {
            p.report_verified(1);
        }
        assert_eq!(p.reliability[1], 0.0, "eliminated worker recovered trust");
        // an honest worker's recovery path still works
        p.report_suspects(&[0]);
        p.report_verified(0);
        assert!((p.reliability[0] - 0.6).abs() < 1e-12);
    }

    #[test]
    fn unidentified_halving_stays_recoverable_even_past_underflow() {
        // repeated halving asymptotes toward 0 — and after ~1075
        // incidents underflows to exactly 0.0 — but an unidentified
        // worker must always keep its recovery path: elimination is an
        // explicit flag, not a float sentinel
        let mut p = FaultCheckPolicy::new(PolicyKind::Selective { q_base: 0.2 }, 2, 3);
        for _ in 0..1100 {
            p.report_suspects(&[0]);
        }
        assert_eq!(p.reliability[0], 0.0, "f64 halving underflows to zero");
        p.report_verified(0);
        assert!(
            (p.reliability[0] - 0.1).abs() < 1e-12,
            "unidentified suspects stay recoverable"
        );
    }

    #[test]
    fn latency_selective_concentrates_audits_on_the_suspect() {
        let mut p = FaultCheckPolicy::new(PolicyKind::LatencySelective { q_base: 0.2 }, 4, 17);
        // feed a persistent 5 ms straggler signal for worker 3
        for _ in 0..10 {
            for w in 0..3 {
                p.observe_latency(w, 0);
            }
            p.observe_latency(3, 5_000_000);
            p.refresh_suspicion(&active(4));
        }
        assert!(p.suspicion()[3] > 0.4, "suspicion {}", p.suspicion()[3]);
        assert_eq!(p.suspicion()[0], 0.0);
        assert_eq!(p.suspicion_nonzero(), vec![(3, p.suspicion()[3])]);
        assert!(p.rank_extensions());
        let (mut a0, mut a3) = (0usize, 0usize);
        for t in 0..20_000 {
            if let AuditDecision::Workers(ws) = p.decide(t, 1.0, 2, &active(4)) {
                a0 += ws.contains(&0) as usize;
                a3 += ws.contains(&3) as usize;
            }
        }
        assert!(
            a3 as f64 > 2.0 * a0 as f64,
            "straggler audited {a3}, trusted worker audited {a0}"
        );
    }

    #[test]
    fn eliminated_and_crashed_workers_leave_the_suspicion_view() {
        let mut p = FaultCheckPolicy::new(PolicyKind::LatencySelective { q_base: 0.2 }, 4, 11);
        // two suspects: worker 1 (reliability) and worker 3 (latency)
        p.report_suspects(&[1]);
        for _ in 0..10 {
            for w in 0..3 {
                p.observe_latency(w, 0);
            }
            p.observe_latency(3, 5_000_000);
            p.refresh_suspicion(&active(4));
        }
        assert!(p.suspicion()[1] > 0.0 && p.suspicion()[3] > 0.0);
        // identification / crash clear the live-roster view
        p.report_identified(1);
        p.report_crashed(3);
        assert!(p.suspicion_nonzero().is_empty(), "{:?}", p.suspicion_nonzero());
        // the survivors keep refreshing without resurrecting the dead
        let active_now = vec![0usize, 2];
        p.refresh_suspicion(&active_now);
        assert_eq!(p.suspicion()[1], 0.0);
        assert_eq!(p.suspicion()[3], 0.0);
    }

    #[test]
    fn suspicion_events_are_change_driven() {
        let mut p = FaultCheckPolicy::new(PolicyKind::LatencySelective { q_base: 0.2 }, 3, 5);
        // no signal: nothing to report
        assert!(p.refresh_suspicion(&active(3)).is_empty());
        // a detection incident moves worker 1's suspicion materially
        p.report_suspects(&[1]);
        let updates = p.refresh_suspicion(&active(3));
        assert_eq!(updates.len(), 1);
        assert_eq!(updates[0].0, 1);
        assert!(updates[0].1 > 0.0);
        // unchanged state: no re-report
        assert!(p.refresh_suspicion(&active(3)).is_empty());
    }
}
