//! Byzantine attack models.
//!
//! The paper's analysis is parameterized only by each Byzantine
//! worker's per-iteration tamper probability p_i; the attack *shape*
//! matters for the baselines (gradient filters are fooled by some
//! shapes and not others) and for stress-testing detection. All
//! attacks tamper the *symbol* (chunk gradient) a worker sends.

use crate::config::{AttackConfig, AttackKind};
use crate::util::rng::Pcg64;

/// Per-worker Byzantine behaviour; `None` for honest workers.
pub struct ByzantineBehavior {
    pub cfg: AttackConfig,
    rng: Pcg64,
}

impl ByzantineBehavior {
    pub fn new(cfg: AttackConfig, seed: u64, worker: usize) -> Self {
        ByzantineBehavior {
            cfg,
            rng: Pcg64::new(seed ^ 0xbad0_0000, worker as u64 + 1000),
        }
    }

    /// Decide once per iteration whether to tamper (prob. p, §4.2).
    pub fn tampers_this_iteration(&mut self) -> bool {
        self.rng.bernoulli(self.cfg.p)
    }

    /// Corrupt a gradient in place (and the reported loss). `iter` keys
    /// the colluding attack's shared pseudo-randomness, so colluders
    /// push a *fresh* common direction every iteration while staying
    /// mutually consistent within one.
    pub fn corrupt(&mut self, iter: u64, grad: &mut [f32], loss: &mut f32) {
        let m = self.cfg.magnitude;
        match self.cfg.kind {
            AttackKind::SignFlip => {
                for v in grad.iter_mut() {
                    *v = -m * *v;
                }
            }
            AttackKind::Noise => {
                for v in grad.iter_mut() {
                    *v += 10.0 * m * self.rng.gauss_f32();
                }
            }
            AttackKind::Constant => {
                for (i, v) in grad.iter_mut().enumerate() {
                    *v = m * if i % 2 == 0 { 1.0 } else { -1.0 };
                }
            }
            AttackKind::Zero => {
                for v in grad.iter_mut() {
                    *v = 0.0;
                }
            }
            AttackKind::SmallBias => {
                // stealthy: shift every coordinate by a small epsilon —
                // defeats norm-based filters, still caught by exact
                // replication comparison
                let eps = 0.01 * m;
                for v in grad.iter_mut() {
                    *v += eps;
                }
            }
            AttackKind::Collude => {
                // colluding workers derive the same vector from shared
                // pseudo-randomness keyed by the iteration count: every
                // colluder at the same iteration draws the identical
                // malicious direction, and the direction moves from one
                // iteration to the next (the pre-fix constant stream
                // re-seeded `Pcg64::new(0xc011ade0, 7)` on every call,
                // so colluders pushed the *same* vector forever)
                let mut colluder = Pcg64::new(0xc011ade0u64, iter);
                for v in grad.iter_mut() {
                    *v = m * colluder.gauss_f32();
                }
            }
        }
        // lie about the loss too (it feeds the adaptive policy)
        *loss *= 1.0 + 0.5 * m;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::codes::{check_copies, grad_key, CheckOutcome, SymbolCopy};

    fn mk(kind: AttackKind, p: f64) -> ByzantineBehavior {
        ByzantineBehavior::new(
            AttackConfig { kind, p, magnitude: 1.0 },
            42,
            0,
        )
    }

    #[test]
    fn tamper_probability_respected_for_every_kind() {
        for kind in AttackKind::ALL {
            let mut b = mk(kind, 0.3);
            let hits = (0..20_000).filter(|_| b.tampers_this_iteration()).count();
            assert!(
                (hits as f64 / 20_000.0 - 0.3).abs() < 0.02,
                "{kind:?}: {hits}/20000 tampers at p=0.3"
            );
            let mut always = mk(kind, 1.0);
            assert!((0..100).all(|_| always.tampers_this_iteration()), "{kind:?} at p=1");
            let mut never = mk(kind, 0.0);
            assert!(!(0..100).any(|_| never.tampers_this_iteration()), "{kind:?} at p=0");
        }
    }

    #[test]
    fn every_attack_changes_the_gradient_and_its_key() {
        for kind in AttackKind::ALL {
            let mut b = mk(kind, 1.0);
            let orig = vec![0.5f32, -1.5, 2.0, 0.25];
            let mut g = orig.clone();
            let mut loss = 1.0f32;
            b.corrupt(0, &mut g, &mut loss);
            assert_ne!(g, orig, "attack {kind:?} left gradient unchanged");
            // the voting key (the exact-comparison fingerprint) must
            // move too — an attack invisible to grad_key would be
            // invisible to majority voting
            assert_ne!(
                grad_key(&g, loss),
                grad_key(&orig, 1.0),
                "attack {kind:?} left the symbol key unchanged"
            );
        }
    }

    #[test]
    fn every_attack_is_caught_by_replication_comparison() {
        // r >= 2 honest copies of a chunk agree bit-exactly; any
        // tampered copy among them must flip the check to FaultDetected
        let honest = vec![0.5f32, -1.5, 2.0, 0.25];
        for kind in AttackKind::ALL {
            let mut b = mk(kind, 1.0);
            let mut g = honest.clone();
            let mut loss = 1.0f32;
            b.corrupt(0, &mut g, &mut loss);
            let copies = vec![
                SymbolCopy { worker: 0, grad: honest.clone(), loss: 1.0, wire: None },
                SymbolCopy { worker: 1, grad: honest.clone(), loss: 1.0, wire: None },
                SymbolCopy { worker: 2, grad: g, loss, wire: None },
            ];
            assert_eq!(
                check_copies(&copies, 0.0),
                CheckOutcome::FaultDetected,
                "attack {kind:?} survived exact replication comparison"
            );
            // ... and the two honest copies alone are unanimous
            assert_eq!(check_copies(&copies[..2], 0.0), CheckOutcome::Unanimous);
        }
    }

    #[test]
    fn sign_flip_negates() {
        let mut b = mk(AttackKind::SignFlip, 1.0);
        let mut g = vec![1.0f32, -2.0];
        let mut loss = 1.0;
        b.corrupt(0, &mut g, &mut loss);
        assert_eq!(g, vec![-1.0, 2.0]);
    }

    #[test]
    fn colluders_agree_within_an_iteration() {
        let mut b1 = ByzantineBehavior::new(
            AttackConfig { kind: AttackKind::Collude, p: 1.0, magnitude: 1.0 },
            1,
            0,
        );
        let mut b2 = ByzantineBehavior::new(
            AttackConfig { kind: AttackKind::Collude, p: 1.0, magnitude: 1.0 },
            999, // different seed, different worker
            5,
        );
        let mut g1 = vec![1.0f32; 8];
        let mut g2 = vec![-3.0f32; 8];
        let (mut l1, mut l2) = (0.0f32, 0.0f32);
        b1.corrupt(3, &mut g1, &mut l1);
        b2.corrupt(3, &mut g2, &mut l2);
        assert_eq!(g1, g2, "colluding attack must be identical across workers");
    }

    #[test]
    fn collude_direction_moves_across_iterations() {
        // the pre-fix code re-seeded the shared RNG with constants on
        // every call, so colluders pushed one frozen vector forever;
        // keyed by iteration, consecutive iterations must differ while
        // repeated calls at the same iteration stay identical
        let mut b = mk(AttackKind::Collude, 1.0);
        let base = vec![1.0f32; 8];
        let mut at_iter = |iter: u64| {
            let mut g = base.clone();
            let mut loss = 1.0;
            b.corrupt(iter, &mut g, &mut loss);
            g
        };
        let g0 = at_iter(0);
        let g1 = at_iter(1);
        let g0_again = at_iter(0);
        assert_ne!(g0, g1, "colluders must push a fresh direction each iteration");
        assert_eq!(g0, g0_again, "the shared direction is a pure function of the iteration");
    }

    #[test]
    fn small_bias_is_small() {
        let mut b = mk(AttackKind::SmallBias, 1.0);
        let orig = vec![1.0f32; 16];
        let mut g = orig.clone();
        let mut loss = 1.0;
        b.corrupt(0, &mut g, &mut loss);
        let max_shift = g
            .iter()
            .zip(orig.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_shift <= 0.011, "stealth attack too loud: {max_shift}");
        assert!(max_shift > 0.0);
    }
}
