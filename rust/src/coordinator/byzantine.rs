//! Byzantine attack models.
//!
//! The paper's analysis is parameterized only by each Byzantine
//! worker's per-iteration tamper probability p_i; the attack *shape*
//! matters for the baselines (gradient filters are fooled by some
//! shapes and not others) and for stress-testing detection. All
//! attacks tamper the *symbol* (chunk gradient) a worker sends.

use crate::config::{AttackConfig, AttackKind};
use crate::util::rng::Pcg64;

/// Per-worker Byzantine behaviour; `None` for honest workers.
pub struct ByzantineBehavior {
    pub cfg: AttackConfig,
    rng: Pcg64,
}

impl ByzantineBehavior {
    pub fn new(cfg: AttackConfig, seed: u64, worker: usize) -> Self {
        ByzantineBehavior {
            cfg,
            rng: Pcg64::new(seed ^ 0xbad0_0000, worker as u64 + 1000),
        }
    }

    /// Decide once per iteration whether to tamper (prob. p, §4.2).
    pub fn tampers_this_iteration(&mut self) -> bool {
        self.rng.bernoulli(self.cfg.p)
    }

    /// Corrupt a gradient in place (and the reported loss).
    pub fn corrupt(&mut self, grad: &mut [f32], loss: &mut f32) {
        let m = self.cfg.magnitude;
        match self.cfg.kind {
            AttackKind::SignFlip => {
                for v in grad.iter_mut() {
                    *v = -m * *v;
                }
            }
            AttackKind::Noise => {
                for v in grad.iter_mut() {
                    *v += 10.0 * m * self.rng.gauss_f32();
                }
            }
            AttackKind::Constant => {
                for (i, v) in grad.iter_mut().enumerate() {
                    *v = m * if i % 2 == 0 { 1.0 } else { -1.0 };
                }
            }
            AttackKind::Zero => {
                for v in grad.iter_mut() {
                    *v = 0.0;
                }
            }
            AttackKind::SmallBias => {
                // stealthy: shift every coordinate by a small epsilon —
                // defeats norm-based filters, still caught by exact
                // replication comparison
                let eps = 0.01 * m;
                for v in grad.iter_mut() {
                    *v += eps;
                }
            }
            AttackKind::Collude => {
                // colluding workers derive the same vector from shared
                // pseudo-randomness (keyed only by iteration count via
                // their common magnitude seed), pushing a consistent
                // malicious direction
                let mut colluder = Pcg64::new(0xc011ade0u64, 7);
                for v in grad.iter_mut() {
                    *v = m * colluder.gauss_f32();
                }
            }
        }
        // lie about the loss too (it feeds the adaptive policy)
        *loss *= 1.0 + 0.5 * m;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(kind: AttackKind, p: f64) -> ByzantineBehavior {
        ByzantineBehavior::new(
            AttackConfig { kind, p, magnitude: 1.0 },
            42,
            0,
        )
    }

    #[test]
    fn tamper_probability_respected() {
        let mut b = mk(AttackKind::SignFlip, 0.3);
        let hits = (0..20_000).filter(|_| b.tampers_this_iteration()).count();
        assert!((hits as f64 / 20_000.0 - 0.3).abs() < 0.02);
        let mut always = mk(AttackKind::SignFlip, 1.0);
        assert!((0..100).all(|_| always.tampers_this_iteration()));
        let mut never = mk(AttackKind::SignFlip, 0.0);
        assert!(!(0..100).any(|_| never.tampers_this_iteration()));
    }

    #[test]
    fn every_attack_changes_the_gradient() {
        for kind in AttackKind::ALL {
            let mut b = mk(kind, 1.0);
            let orig = vec![0.5f32, -1.5, 2.0, 0.25];
            let mut g = orig.clone();
            let mut loss = 1.0f32;
            b.corrupt(&mut g, &mut loss);
            assert_ne!(g, orig, "attack {kind:?} left gradient unchanged");
        }
    }

    #[test]
    fn sign_flip_negates() {
        let mut b = mk(AttackKind::SignFlip, 1.0);
        let mut g = vec![1.0f32, -2.0];
        let mut loss = 1.0;
        b.corrupt(&mut g, &mut loss);
        assert_eq!(g, vec![-1.0, 2.0]);
    }

    #[test]
    fn colluders_agree() {
        let mut b1 = ByzantineBehavior::new(
            AttackConfig { kind: AttackKind::Collude, p: 1.0, magnitude: 1.0 },
            1,
            0,
        );
        let mut b2 = ByzantineBehavior::new(
            AttackConfig { kind: AttackKind::Collude, p: 1.0, magnitude: 1.0 },
            999, // different seed, different worker
            5,
        );
        let mut g1 = vec![1.0f32; 8];
        let mut g2 = vec![-3.0f32; 8];
        let (mut l1, mut l2) = (0.0f32, 0.0f32);
        b1.corrupt(&mut g1, &mut l1);
        b2.corrupt(&mut g2, &mut l2);
        assert_eq!(g1, g2, "colluding attack must be identical across workers");
    }

    #[test]
    fn small_bias_is_small() {
        let mut b = mk(AttackKind::SmallBias, 1.0);
        let orig = vec![1.0f32; 16];
        let mut g = orig.clone();
        let mut loss = 1.0;
        b.corrupt(&mut g, &mut loss);
        let max_shift = g
            .iter()
            .zip(orig.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_shift <= 0.011, "stealth attack too loud: {max_shift}");
        assert!(max_shift > 0.0);
    }
}
