//! The parameter server: owns the model state, samples each round's
//! data globally, fans the round out to the shard cores, combines
//! their partial aggregates with the fixed-shape tree sum, and applies
//! one fused SGD step.
//!
//! Global sampling uses the *same* RNG stream as the single-master
//! [`super::super::protocol::ProtocolCore`], so the data each global
//! chunk sees is independent of K — one half of the determinism
//! contract (see [`super`] module docs). The other half is the
//! aggregation: per-shard partials are combined with
//! [`crate::linalg::tree_sum`] over fixed shard slots, matching the
//! single-master reduction bit-for-bit when shard widths are a power
//! of two.

use std::sync::Arc;
use std::time::Instant;

use super::super::assignment::sample_points;
use super::super::events::{Event, EventLog};
use super::super::metrics::{IterationRecord, ShardStat};
use super::super::protocol::SAMPLE_STREAM;
use super::super::WorkerId;
use super::{Roster, ShardRound, ShardedTransport};
use crate::data::Dataset;
use crate::grad::GradientComputer;
use crate::linalg;
use crate::util::rng::Pcg64;
use crate::util::stats;
use crate::Result;

pub struct ParameterServer {
    theta: Vec<f32>,
    engine: Arc<dyn GradientComputer>,
    dataset: Arc<dyn Dataset>,
    transport: ShardedTransport,
    roster: Roster,
    /// Global data-sampling stream — bit-compatible with the
    /// single-master core's `rng_sample` for the same seed.
    rng_sample: Pcg64,
    chunk_size: usize,
    lr: f32,
    w_star: Option<Vec<f32>>,
    /// Reused per-chunk loss buffer.
    losses: Vec<f64>,
}

impl ParameterServer {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        transport: ShardedTransport,
        engine: Arc<dyn GradientComputer>,
        dataset: Arc<dyn Dataset>,
        init_theta: Vec<f32>,
        chunk_size: usize,
        lr: f32,
        seed: u64,
        w_star: Option<Vec<f32>>,
    ) -> Result<ParameterServer> {
        anyhow::ensure!(chunk_size > 0, "chunk_size must be positive");
        anyhow::ensure!(
            init_theta.len() == engine.param_dim(),
            "init theta dim {} != engine param dim {}",
            init_theta.len(),
            engine.param_dim()
        );
        let n = transport.n();
        Ok(ParameterServer {
            theta: init_theta,
            engine,
            dataset,
            transport,
            roster: Roster::new(n),
            rng_sample: Pcg64::new(seed, SAMPLE_STREAM),
            chunk_size,
            lr,
            w_star,
            losses: Vec::new(),
        })
    }

    pub fn theta(&self) -> &[f32] {
        &self.theta
    }

    pub fn roster(&self) -> &Roster {
        &self.roster
    }

    /// One global round: sample → fan out → (rescue) → fuse → step.
    pub fn run_round(&mut self, t: u64, events: &mut EventLog) -> Result<IterationRecord> {
        let t0 = Instant::now();
        let cs = self.chunk_size;
        let k = self.transport.k();

        // roster enforcement: a published liar can never rejoin
        for core in self.transport.cores() {
            for w in core.active_globals() {
                anyhow::ensure!(
                    !self.roster.is_eliminated(w),
                    "eliminated worker {w} resurfaced in shard {} at iteration {t}",
                    core.spec().shard
                );
            }
        }

        // ---- global sampling + per-shard chunk slices ------------------
        let counts = self.transport.active_counts();
        let total: usize = counts.iter().sum();
        anyhow::ensure!(total > 0, "no active workers left in any shard at iteration {t}");
        let m = total * cs;
        let data_ids = sample_points(&mut self.rng_sample, self.dataset.len(), m);
        let mut slices: Vec<Vec<Vec<usize>>> = Vec::with_capacity(k);
        let mut offsets: Vec<usize> = Vec::with_capacity(k);
        // each shard's [start, start+len) window into data_ids, kept so
        // a dead shard's chunks can be rebuilt and handed to survivors
        let mut ranges: Vec<(usize, usize)> = Vec::with_capacity(k);
        let mut cursor = 0usize;
        for &c_s in &counts {
            offsets.push(cursor / cs);
            let take = c_s * cs;
            ranges.push((cursor, take));
            let slice: Vec<Vec<usize>> = data_ids[cursor..cursor + take]
                .chunks(cs)
                .map(|s| s.to_vec())
                .collect();
            cursor += take;
            slices.push(slice);
        }

        // ---- fan out ---------------------------------------------------
        let theta = Arc::new(self.theta.clone());
        let results = self.transport.fan_round(
            t,
            &theta,
            slices,
            &offsets,
            cs,
            self.dataset.as_ref(),
            self.engine.as_ref(),
            events,
        );

        let mut partials: Vec<Option<Vec<f32>>> = Vec::with_capacity(k);
        partials.resize_with(k, || None);
        let mut rescue_partials: Vec<Vec<f32>> = Vec::new();
        self.losses.clear();
        let mut shard_stats: Vec<ShardStat> = Vec::new();
        let mut orphans: Vec<Vec<usize>> = Vec::new();
        let mut suspicion: Vec<(WorkerId, f64)> = Vec::new();
        let mut oracle_faulty = false;
        let mut audited = false;
        let mut q_sum = 0.0f64;
        let mut q_n = 0usize;
        let mut lambda_sum = 0.0f64;
        let mut extra_crashed = 0usize;
        // shards run concurrently, so the fan-out costs the slowest
        // shard's round; rescue rounds happen after it, serially
        let mut fan_round_ns = 0u64;
        let mut rescue_round_ns = 0u64;

        let absorb = |round: ShardRound,
                      losses: &mut Vec<f64>,
                      roster: &mut Roster,
                      events: &mut EventLog|
         -> ShardStat {
            let shard = round.stat.shard;
            for &w in &round.identified {
                if roster.publish_elimination(w, shard, t) {
                    events.push(Event::RosterEliminated { iter: t, shard, worker: w });
                }
            }
            for &w in &round.crashed {
                roster.record_crash(w, t);
            }
            losses.extend_from_slice(&round.losses);
            round.stat
        };

        for (s, res) in results.into_iter().enumerate() {
            match res {
                None => {}
                Some(Ok(mut round)) => {
                    oracle_faulty |= round.oracle_faulty;
                    audited |= round.stat.audited;
                    fan_round_ns = fan_round_ns.max(round.stat.round_ns);
                    q_sum += self.transport.cores()[s].last_q();
                    lambda_sum += self.transport.cores()[s].lambda();
                    q_n += 1;
                    partials[s] = round.partial.take();
                    suspicion.append(&mut round.suspicion);
                    let stat = absorb(round, &mut self.losses, &mut self.roster, events);
                    shard_stats.push(stat);
                }
                Some(Err(e)) => {
                    log::warn!("shard {s} died at iteration {t}: {e:#}");
                    events.push(Event::ShardDead { iter: t, shard: s });
                    // eliminations from the failed round would otherwise
                    // be lost with the error — publish them first
                    for w in self.transport.cores()[s].eliminated_globals() {
                        if self.roster.publish_elimination(w, s, t) {
                            events.push(Event::RosterEliminated { iter: t, shard: s, worker: w });
                        }
                    }
                    let stranded = self.transport.fail_shard(s);
                    for w in stranded {
                        if self.roster.record_crash(w, t) {
                            extra_crashed += 1;
                        }
                    }
                    let (start, len) = ranges[s];
                    orphans.extend(data_ids[start..start + len].chunks(cs).map(|c| c.to_vec()));
                }
            }
        }

        // ---- rescue: reassign a dead shard's chunks to survivors -------
        let mut rescue_offset = total; // rescue chunks index past the main range
        while !orphans.is_empty() {
            // deterministic choice: the alive shard with the most
            // active workers (lowest index wins ties)
            let target = self
                .transport
                .active_counts()
                .into_iter()
                .enumerate()
                .max_by_key(|&(s, c)| (c, usize::MAX - s))
                .filter(|&(_, c)| c > 0)
                .map(|(s, _)| s);
            let Some(target) = target else {
                let n = orphans.len();
                anyhow::bail!("all shards dead at iteration {t}: {n} chunks stranded");
            };
            let batch = std::mem::take(&mut orphans);
            let nbatch = batch.len();
            match self.transport.rescue(
                target,
                t,
                &theta,
                batch.clone(),
                rescue_offset,
                cs,
                self.dataset.as_ref(),
                self.engine.as_ref(),
                events,
            ) {
                Ok(mut round) => {
                    rescue_offset += nbatch;
                    oracle_faulty |= round.oracle_faulty;
                    audited |= round.stat.audited;
                    rescue_round_ns += round.stat.round_ns;
                    if let Some(p) = round.partial.take() {
                        rescue_partials.push(p);
                    }
                    suspicion.append(&mut round.suspicion);
                    let stat = absorb(round, &mut self.losses, &mut self.roster, events);
                    shard_stats.push(stat);
                }
                Err(e) => {
                    log::warn!("rescue shard {target} died at iteration {t}: {e:#}");
                    events.push(Event::ShardDead { iter: t, shard: target });
                    for w in self.transport.cores()[target].eliminated_globals() {
                        if self.roster.publish_elimination(w, target, t) {
                            events.push(Event::RosterEliminated {
                                iter: t,
                                shard: target,
                                worker: w,
                            });
                        }
                    }
                    let stranded = self.transport.fail_shard(target);
                    for w in stranded {
                        if self.roster.record_crash(w, t) {
                            extra_crashed += 1;
                        }
                    }
                    orphans = batch; // try the next survivor
                }
            }
        }

        // ---- fused aggregation + SGD step ------------------------------
        let nchunks = self.losses.len();
        anyhow::ensure!(nchunks > 0, "no chunk survived iteration {t}");
        let slots: Vec<Option<&[f32]>> = partials.iter().map(|p| p.as_deref()).collect();
        let mut agg = linalg::tree_sum(&slots);
        for p in &rescue_partials {
            linalg::tree_combine(&mut agg, p);
        }
        let mut agg = agg.expect("at least one partial aggregate");
        linalg::scale(1.0 / nchunks as f32, &mut agg);
        if oracle_faulty {
            events.push(Event::OracleFaultyUpdate { iter: t });
        }
        self.engine.sgd_step(&mut self.theta, &agg, self.lr)?;

        // ---- metrics ---------------------------------------------------
        let gradients_used: u64 = shard_stats.iter().map(|s| s.gradients_used).sum();
        let gradients_computed: u64 = shard_stats.iter().map(|s| s.gradients_computed).sum();
        let faults_detected: usize = shard_stats.iter().map(|s| s.faults_detected).sum();
        let identified: usize = shard_stats.iter().map(|s| s.identified).sum();
        let crashed: usize =
            shard_stats.iter().map(|s| s.crashed).sum::<usize>() + extra_crashed;
        let stragglers: usize = shard_stats.iter().map(|s| s.stragglers).sum();
        let audited_chunks: usize = shard_stats.iter().map(|s| s.audited_chunks).sum();
        // global-id suspicion column: a shard that also served a rescue
        // round reports twice — keep the later (rescue-round) snapshot
        suspicion.sort_by(|a, b| a.0.cmp(&b.0));
        suspicion.dedup_by(|later, first| {
            if later.0 == first.0 {
                first.1 = later.1;
                true
            } else {
                false
            }
        });
        Ok(IterationRecord {
            iter: t,
            gradients_used,
            gradients_computed,
            audited,
            faults_detected,
            identified,
            crashed,
            loss: stats::median(&self.losses) as f32,
            q: if q_n > 0 { q_sum / q_n as f64 } else { 0.0 },
            lambda: if q_n > 0 { lambda_sum / q_n as f64 } else { 0.0 },
            oracle_faulty_update: oracle_faulty,
            dist_to_opt: self.w_star.as_ref().map(|w| linalg::dist2(&self.theta, w)),
            wall_ns: t0.elapsed().as_nanos() as u64,
            round_ns: fan_round_ns + rescue_round_ns,
            stragglers,
            audited_chunks,
            suspicion,
            shard_stats,
        })
    }

    /// Shut the fleet down; returns (theta, eliminated, crashed) with
    /// the roster's global publication order.
    pub fn finish(self) -> (Vec<f32>, Vec<WorkerId>, Vec<WorkerId>) {
        let ParameterServer { theta, transport, roster, .. } = self;
        let _ = transport.into_outcome(); // shutdown inner transports
        (theta, roster.eliminated.clone(), roster.crashed.clone())
    }
}
