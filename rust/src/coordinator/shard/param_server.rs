//! The parameter server: owns the model state, samples each round's
//! data globally, fans the round out to the shard cores, combines
//! their partial aggregates with the fixed-shape tree sum, and applies
//! one fused SGD step.
//!
//! Global sampling uses the *same* RNG stream as the single-master
//! [`super::super::protocol::ProtocolCore`], so the data each global
//! chunk sees is independent of K — one half of the determinism
//! contract (see [`super`] module docs). The other half is the
//! aggregation: per-shard partials are combined with
//! [`crate::linalg::tree_sum`] over fixed shard slots, matching the
//! single-master reduction bit-for-bit when shard widths are a power
//! of two.

use std::sync::Arc;
use std::time::Instant;

use super::super::assignment::sample_points;
use super::super::events::{Event, EventLog};
use super::super::metrics::{IterationRecord, ShardStat};
use super::super::protocol::SAMPLE_STREAM;
use super::super::WorkerId;
use super::{Roster, ShardRound, ShardedTransport};
use crate::data::Dataset;
use crate::grad::GradientComputer;
use crate::linalg;
use crate::trace::Recorder;
use crate::util::rng::Pcg64;
use crate::util::stats;
use crate::Result;

/// Which state one shard's slice of a pipelined global round is in.
enum SlotState {
    /// No chunks dispatched to this shard this round.
    Idle,
    /// Proactive wave in flight.
    InFlight,
    /// Died this round before finishing — death bookkeeping owed.
    Died(anyhow::Error),
    /// Shard was already dead (bookkept in an earlier round); its
    /// chunks just need rescuing.
    Orphaned,
}

/// One pipelined global round between `begin_global` and
/// `finish_global`.
struct GlobalPending {
    t: u64,
    /// θ the surviving waves were issued on (updated by reissue).
    theta: Arc<Vec<f32>>,
    /// The round's global sample, kept so a dead shard's chunks can be
    /// rebuilt for rescue.
    data_ids: Vec<usize>,
    /// Per-shard (start, len) windows into `data_ids`.
    ranges: Vec<(usize, usize)>,
    slots: Vec<SlotState>,
    collected: bool,
    /// A shard died during begin/collect: don't speculate the next
    /// round from a partial picture (pipeline flush).
    flushed: bool,
    /// Global chunk count dispatched (rescue chunks index past it).
    total: usize,
}

/// Per-round accumulation shared by the sequential and pipelined
/// drivers between the shard-results stage and the fused update.
struct RoundAccum {
    partials: Vec<Option<Vec<f32>>>,
    orphans: Vec<Vec<usize>>,
    shard_stats: Vec<ShardStat>,
    suspicion: Vec<(WorkerId, f64)>,
    oracle_faulty: bool,
    audited: bool,
    q_sum: f64,
    q_n: usize,
    lambda_sum: f64,
    extra_crashed: usize,
    /// Shards run concurrently, so the fan-out costs the slowest
    /// shard's round (max); rescue rounds happen after it, serially.
    fan_round_ns: u64,
}

impl RoundAccum {
    fn new(k: usize) -> RoundAccum {
        let mut partials = Vec::with_capacity(k);
        partials.resize_with(k, || None);
        RoundAccum {
            partials,
            orphans: Vec::new(),
            shard_stats: Vec::new(),
            suspicion: Vec::new(),
            oracle_faulty: false,
            audited: false,
            q_sum: 0.0,
            q_n: 0,
            lambda_sum: 0.0,
            extra_crashed: 0,
            fan_round_ns: 0,
        }
    }
}

/// Publish a completed shard round's eliminations/crashes to the
/// roster and absorb its losses; returns the stat row.
fn absorb(
    round: ShardRound,
    t: u64,
    losses: &mut Vec<f64>,
    roster: &mut Roster,
    recorder: &Option<Arc<Recorder>>,
    events: &mut EventLog,
) -> ShardStat {
    let shard = round.stat.shard;
    for &w in &round.identified {
        if roster.publish_elimination(w, shard, t) {
            let ev = Event::RosterEliminated { iter: t, shard, worker: w };
            if let Some(rec) = recorder {
                rec.on_master_event(Some(shard), &ev);
            }
            events.push(ev);
        }
    }
    for &w in &round.crashed {
        roster.record_crash(w, t);
    }
    losses.extend_from_slice(&round.losses);
    round.stat
}

pub struct ParameterServer {
    theta: Vec<f32>,
    engine: Arc<dyn GradientComputer>,
    dataset: Arc<dyn Dataset>,
    transport: ShardedTransport,
    roster: Roster,
    /// Global data-sampling stream — bit-compatible with the
    /// single-master core's `rng_sample` for the same seed.
    rng_sample: Pcg64,
    chunk_size: usize,
    lr: f32,
    w_star: Option<Vec<f32>>,
    /// Total iterations the run will ask for (bounds speculation).
    steps: u64,
    /// Round pipeline depth (1 = strictly sequential).
    pipeline: usize,
    /// Pipelined rounds in flight, oldest first.
    pending: Vec<GlobalPending>,
    /// Reused per-chunk loss buffer.
    losses: Vec<f64>,
    /// Flight recorder for master-level events (shard deaths, roster
    /// eliminations, oracle faulty updates). `None` costs nothing.
    recorder: Option<Arc<Recorder>>,
    /// Wall-clock origin for the exclusive `wall_ns` accounting.
    wall_origin: Instant,
    /// End of the previous round's wall period (ns since
    /// `wall_origin`) — see `coordinator::master::apply_finished_round`
    /// for the exclusive-tiling contract.
    last_wall_end_ns: u64,
}

impl ParameterServer {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        transport: ShardedTransport,
        engine: Arc<dyn GradientComputer>,
        dataset: Arc<dyn Dataset>,
        init_theta: Vec<f32>,
        chunk_size: usize,
        lr: f32,
        seed: u64,
        w_star: Option<Vec<f32>>,
        steps: u64,
        pipeline: usize,
        recorder: Option<Arc<Recorder>>,
    ) -> Result<ParameterServer> {
        anyhow::ensure!(chunk_size > 0, "chunk_size must be positive");
        anyhow::ensure!(
            init_theta.len() == engine.param_dim(),
            "init theta dim {} != engine param dim {}",
            init_theta.len(),
            engine.param_dim()
        );
        let n = transport.n();
        Ok(ParameterServer {
            theta: init_theta,
            engine,
            dataset,
            transport,
            roster: Roster::new(n),
            rng_sample: Pcg64::new(seed, SAMPLE_STREAM),
            chunk_size,
            lr,
            w_star,
            steps,
            pipeline,
            pending: Vec::new(),
            losses: Vec::new(),
            recorder,
            wall_origin: Instant::now(),
            last_wall_end_ns: 0,
        })
    }

    pub fn theta(&self) -> &[f32] {
        &self.theta
    }

    pub fn roster(&self) -> &Roster {
        &self.roster
    }

    /// One global round: sample → fan out → (rescue) → fuse → step.
    /// With `pipeline ≥ 2` the next round's proactive waves are
    /// launched on a provisional θ while this round's audits are
    /// still in flight (see `coordinator::master` module docs).
    pub fn run_round(&mut self, t: u64, events: &mut EventLog) -> Result<IterationRecord> {
        if self.pipeline.max(1) > 1 {
            self.run_round_pipelined(t, events)
        } else {
            self.run_round_sequential(t, events)
        }
    }

    fn run_round_sequential(&mut self, t: u64, events: &mut EventLog) -> Result<IterationRecord> {
        let start_wall_ns = self.wall_origin.elapsed().as_nanos() as u64;
        let cs = self.chunk_size;
        let k = self.transport.k();

        // roster enforcement: a published liar can never rejoin
        for core in self.transport.cores() {
            for w in core.active_globals() {
                anyhow::ensure!(
                    !self.roster.is_eliminated(w),
                    "eliminated worker {w} resurfaced in shard {} at iteration {t}",
                    core.spec().shard
                );
            }
        }

        // ---- global sampling + per-shard chunk slices ------------------
        let counts = self.transport.active_counts();
        let total: usize = counts.iter().sum();
        anyhow::ensure!(total > 0, "no active workers left in any shard at iteration {t}");
        let m = total * cs;
        let data_ids = sample_points(&mut self.rng_sample, self.dataset.len(), m);
        let mut slices: Vec<Vec<Vec<usize>>> = Vec::with_capacity(k);
        let mut offsets: Vec<usize> = Vec::with_capacity(k);
        // each shard's [start, start+len) window into data_ids, kept so
        // a dead shard's chunks can be rebuilt and handed to survivors
        let mut ranges: Vec<(usize, usize)> = Vec::with_capacity(k);
        let mut cursor = 0usize;
        for &c_s in &counts {
            offsets.push(cursor / cs);
            let take = c_s * cs;
            ranges.push((cursor, take));
            let slice: Vec<Vec<usize>> = data_ids[cursor..cursor + take]
                .chunks(cs)
                .map(|s| s.to_vec())
                .collect();
            cursor += take;
            slices.push(slice);
        }

        // ---- fan out ---------------------------------------------------
        let theta = Arc::new(self.theta.clone());
        let results = self.transport.fan_round(
            t,
            &theta,
            slices,
            &offsets,
            cs,
            self.dataset.as_ref(),
            self.engine.as_ref(),
            events,
        );

        let mut acc = RoundAccum::new(k);
        self.losses.clear();
        for (s, res) in results.into_iter().enumerate() {
            match res {
                None => {}
                Some(Ok(mut round)) => {
                    acc.oracle_faulty |= round.oracle_faulty;
                    acc.audited |= round.stat.audited;
                    acc.fan_round_ns = acc.fan_round_ns.max(round.stat.round_ns);
                    acc.q_sum += self.transport.cores()[s].last_q();
                    acc.lambda_sum += self.transport.cores()[s].lambda();
                    acc.q_n += 1;
                    acc.partials[s] = round.partial.take();
                    acc.suspicion.append(&mut round.suspicion);
                    let stat = absorb(
                        round,
                        t,
                        &mut self.losses,
                        &mut self.roster,
                        &self.recorder,
                        events,
                    );
                    acc.shard_stats.push(stat);
                }
                Some(Err(e)) => {
                    acc.extra_crashed += self.note_shard_death(s, t, &e, events);
                    let (start, len) = ranges[s];
                    acc.orphans
                        .extend(data_ids[start..start + len].chunks(cs).map(|c| c.to_vec()));
                }
            }
        }
        self.rescue_and_fuse(t, &theta, acc, total, start_wall_ns, events)
    }

    /// Pipelined global round: (begin if not speculated earlier) →
    /// collect every shard's proactive wave → launch t+1 on a
    /// provisional θ → finish t exactly → reissue t+1 if the audit
    /// changed θ. Per-shard pipelines are fused at this single ordered
    /// apply point; a shard death during begin/collect flushes the
    /// speculation for one round.
    fn run_round_pipelined(&mut self, t: u64, events: &mut EventLog) -> Result<IterationRecord> {
        let start_wall_ns = self.wall_origin.elapsed().as_nanos() as u64;
        if !self.pending.iter().any(|p| p.t == t) {
            let theta = Arc::new(self.theta.clone());
            self.begin_global(t, &theta)?;
        }
        self.collect_global(t, events)?;

        // speculate: provisional θ' from t's pre-audit partials
        let mut speculative = None;
        if t + 1 < self.steps && !self.flushed(t) {
            if let Some(agg) = self.provisional_aggregate(t) {
                let mut prov = self.theta.clone();
                self.engine.sgd_step(&mut prov, &agg, self.lr)?;
                let prov = Arc::new(prov);
                // a failed speculative begin is a flush, not a round
                // failure — t+1 will begin sequentially and re-surface
                // any real error
                if self.begin_global(t + 1, &prov).is_ok() {
                    speculative = Some(prov);
                }
            }
        }

        let rec = self.finish_global(t, start_wall_ns, events)?;

        // ordered θ application: reissue t+1 on the exact θ iff the
        // speculation was wrong
        if let Some(prov) = speculative {
            if rec.identified > 0 || prov.as_slice() != self.theta.as_slice() {
                let exact = Arc::new(self.theta.clone());
                self.reissue_global(t + 1, &exact);
            }
        }
        Ok(rec)
    }

    /// Sample a global round and submit every shard's proactive wave
    /// without waiting. Begin failures are recorded as `Died` slots
    /// and bookkept at finish, like a sequential fan-out failure.
    fn begin_global(&mut self, t: u64, theta: &Arc<Vec<f32>>) -> Result<()> {
        let cs = self.chunk_size;
        // roster enforcement: a published liar can never rejoin
        for core in self.transport.cores() {
            for w in core.active_globals() {
                anyhow::ensure!(
                    !self.roster.is_eliminated(w),
                    "eliminated worker {w} resurfaced in shard {} at iteration {t}",
                    core.spec().shard
                );
            }
        }
        let counts = self.transport.active_counts();
        let total: usize = counts.iter().sum();
        anyhow::ensure!(total > 0, "no active workers left in any shard at iteration {t}");
        let m = total * cs;
        let data_ids = sample_points(&mut self.rng_sample, self.dataset.len(), m);
        let k = counts.len();
        let mut ranges: Vec<(usize, usize)> = Vec::with_capacity(k);
        let mut slots: Vec<SlotState> = Vec::with_capacity(k);
        let mut flushed = false;
        let mut cursor = 0usize;
        for (s, &c_s) in counts.iter().enumerate() {
            let offset = cursor / cs;
            let take = c_s * cs;
            ranges.push((cursor, take));
            let slice: Vec<Vec<usize>> = data_ids[cursor..cursor + take]
                .chunks(cs)
                .map(|x| x.to_vec())
                .collect();
            cursor += take;
            if slice.is_empty() {
                slots.push(SlotState::Idle);
                continue;
            }
            let dataset = self.dataset.clone();
            match self.transport.cores_mut()[s]
                .begin(t, theta, slice, offset, cs, true, dataset.as_ref())
            {
                Ok(()) => slots.push(SlotState::InFlight),
                Err(e) => {
                    slots.push(SlotState::Died(e));
                    flushed = true;
                }
            }
        }
        self.pending.push(GlobalPending {
            t,
            theta: theta.clone(),
            data_ids,
            ranges,
            slots,
            collected: false,
            flushed,
            total,
        });
        Ok(())
    }

    /// Gather every in-flight shard's proactive wave for iteration `t`
    /// (idempotent). A shard failure here becomes a `Died` slot and a
    /// pipeline flush.
    fn collect_global(&mut self, t: u64, events: &mut EventLog) -> Result<()> {
        let idx = self
            .pending
            .iter()
            .position(|p| p.t == t)
            .ok_or_else(|| anyhow::anyhow!("collect before begin at iteration {t}"))?;
        if self.pending[idx].collected {
            return Ok(());
        }
        let theta = self.pending[idx].theta.clone();
        let k = self.transport.k();
        for s in 0..k {
            if !matches!(self.pending[idx].slots[s], SlotState::InFlight) {
                continue;
            }
            if !self.transport.cores()[s].alive() {
                // died finishing an earlier round; already bookkept
                self.pending[idx].slots[s] = SlotState::Orphaned;
                self.pending[idx].flushed = true;
                continue;
            }
            let dataset = self.dataset.clone();
            if let Err(e) =
                self.transport.cores_mut()[s].collect(t, &theta, dataset.as_ref(), events)
            {
                self.pending[idx].slots[s] = SlotState::Died(e);
                self.pending[idx].flushed = true;
            }
        }
        self.pending[idx].collected = true;
        Ok(())
    }

    fn flushed(&self, t: u64) -> bool {
        self.pending.iter().find(|p| p.t == t).map(|p| p.flushed).unwrap_or(true)
    }

    /// Pre-audit global aggregate (mean over collected chunks) — the
    /// input to the pipelined driver's provisional θ.
    fn provisional_aggregate(&self, t: u64) -> Option<Vec<f32>> {
        let pending = self.pending.iter().find(|p| p.t == t)?;
        let k = self.transport.k();
        let mut partials: Vec<Option<Vec<f32>>> = Vec::with_capacity(k);
        partials.resize_with(k, || None);
        let mut nchunks = 0usize;
        for (s, core) in self.transport.cores().iter().enumerate() {
            if !matches!(pending.slots[s], SlotState::InFlight) {
                continue;
            }
            if let Some((partial, chunks)) = core.provisional_partial(t) {
                nchunks += chunks;
                partials[s] = partial;
            }
        }
        if nchunks == 0 {
            return None;
        }
        let slots: Vec<Option<&[f32]>> = partials.iter().map(|p| p.as_deref()).collect();
        let mut agg = linalg::tree_sum(&slots)?;
        linalg::scale(1.0 / nchunks as f32, &mut agg);
        Some(agg)
    }

    /// Finish a collected global round: per-shard audits, death
    /// bookkeeping, rescue, fused aggregate, SGD step, metrics.
    fn finish_global(
        &mut self,
        t: u64,
        start_wall_ns: u64,
        events: &mut EventLog,
    ) -> Result<IterationRecord> {
        let idx = self
            .pending
            .iter()
            .position(|p| p.t == t)
            .ok_or_else(|| anyhow::anyhow!("finish before begin at iteration {t}"))?;
        let pending = self.pending.remove(idx);
        anyhow::ensure!(pending.collected, "finish before collect at iteration {t}");
        let GlobalPending { theta, data_ids, ranges, slots, total, .. } = pending;
        let cs = self.chunk_size;
        let k = self.transport.k();
        let mut acc = RoundAccum::new(k);
        self.losses.clear();
        for (s, slot) in slots.into_iter().enumerate() {
            let orphan_range = |acc: &mut RoundAccum| {
                let (start, len) = ranges[s];
                acc.orphans.extend(data_ids[start..start + len].chunks(cs).map(|c| c.to_vec()));
            };
            match slot {
                SlotState::Idle => {}
                SlotState::InFlight => {
                    let dataset = self.dataset.clone();
                    let engine = self.engine.clone();
                    match self.transport.cores_mut()[s].finish(
                        t,
                        &theta,
                        dataset.as_ref(),
                        engine.as_ref(),
                        events,
                    ) {
                        Ok(mut round) => {
                            acc.oracle_faulty |= round.oracle_faulty;
                            acc.audited |= round.stat.audited;
                            acc.fan_round_ns = acc.fan_round_ns.max(round.stat.round_ns);
                            acc.q_sum += self.transport.cores()[s].last_q();
                            acc.lambda_sum += self.transport.cores()[s].lambda();
                            acc.q_n += 1;
                            acc.partials[s] = round.partial.take();
                            acc.suspicion.append(&mut round.suspicion);
                            let stat = absorb(
                                round,
                                t,
                                &mut self.losses,
                                &mut self.roster,
                                &self.recorder,
                                events,
                            );
                            acc.shard_stats.push(stat);
                        }
                        Err(e) => {
                            acc.extra_crashed += self.note_shard_death(s, t, &e, events);
                            orphan_range(&mut acc);
                        }
                    }
                }
                SlotState::Died(e) => {
                    acc.extra_crashed += self.note_shard_death(s, t, &e, events);
                    orphan_range(&mut acc);
                }
                SlotState::Orphaned => orphan_range(&mut acc),
            }
        }
        self.rescue_and_fuse(t, &theta, acc, total, start_wall_ns, events)
    }

    /// Retire every in-flight speculative wave for iteration `t` and
    /// resubmit it on the corrected θ.
    fn reissue_global(&mut self, t: u64, theta: &Arc<Vec<f32>>) {
        let Some(idx) = self.pending.iter().position(|p| p.t == t) else {
            return;
        };
        let k = self.transport.k();
        for s in 0..k {
            if !matches!(self.pending[idx].slots[s], SlotState::InFlight) {
                continue;
            }
            if !self.transport.cores()[s].alive() {
                self.pending[idx].slots[s] = SlotState::Orphaned;
                self.pending[idx].flushed = true;
                continue;
            }
            let dataset = self.dataset.clone();
            if let Err(e) = self.transport.cores_mut()[s].reissue(t, theta, dataset.as_ref()) {
                self.pending[idx].slots[s] = SlotState::Died(e);
                self.pending[idx].flushed = true;
            }
        }
        self.pending[idx].theta = theta.clone();
    }

    /// Log a shard death, publish its surviving eliminations, retire
    /// it, and record its stranded workers as crashed; returns how
    /// many crashes were newly recorded.
    fn note_shard_death(
        &mut self,
        s: usize,
        t: u64,
        e: &anyhow::Error,
        events: &mut EventLog,
    ) -> usize {
        log::warn!("shard {s} died at iteration {t}: {e:#}");
        let dead = Event::ShardDead { iter: t, shard: s };
        if let Some(rec) = &self.recorder {
            rec.on_master_event(Some(s), &dead);
        }
        events.push(dead);
        // eliminations from the failed round would otherwise be lost
        // with the error — publish them first
        for w in self.transport.cores()[s].eliminated_globals() {
            if self.roster.publish_elimination(w, s, t) {
                let ev = Event::RosterEliminated { iter: t, shard: s, worker: w };
                if let Some(rec) = &self.recorder {
                    rec.on_master_event(Some(s), &ev);
                }
                events.push(ev);
            }
        }
        let stranded = self.transport.fail_shard(s);
        let mut extra = 0usize;
        for w in stranded {
            if self.roster.record_crash(w, t) {
                extra += 1;
            }
        }
        extra
    }

    /// Rescue orphaned chunks through survivors, then fuse the partial
    /// aggregates, apply the SGD step, and build the metrics record.
    /// The reported `wall_ns` is **exclusive**: it runs from
    /// `max(start_wall_ns, previous round's wall end)`, so pipelined
    /// rounds tile the run's wall time without double-counting overlap.
    fn rescue_and_fuse(
        &mut self,
        t: u64,
        theta: &Arc<Vec<f32>>,
        mut acc: RoundAccum,
        total: usize,
        start_wall_ns: u64,
        events: &mut EventLog,
    ) -> Result<IterationRecord> {
        let cs = self.chunk_size;
        let mut rescue_partials: Vec<Vec<f32>> = Vec::new();
        let mut rescue_round_ns = 0u64;
        // ---- rescue: reassign a dead shard's chunks to survivors -------
        let mut rescue_offset = total; // rescue chunks index past the main range
        while !acc.orphans.is_empty() {
            // deterministic choice: the alive shard with the most
            // active workers (lowest index wins ties)
            let target = self
                .transport
                .active_counts()
                .into_iter()
                .enumerate()
                .max_by_key(|&(s, c)| (c, usize::MAX - s))
                .filter(|&(_, c)| c > 0)
                .map(|(s, _)| s);
            let Some(target) = target else {
                let n = acc.orphans.len();
                anyhow::bail!("all shards dead at iteration {t}: {n} chunks stranded");
            };
            let batch = std::mem::take(&mut acc.orphans);
            let nbatch = batch.len();
            match self.transport.rescue(
                target,
                t,
                theta,
                batch.clone(),
                rescue_offset,
                cs,
                self.dataset.as_ref(),
                self.engine.as_ref(),
                events,
            ) {
                Ok(mut round) => {
                    rescue_offset += nbatch;
                    acc.oracle_faulty |= round.oracle_faulty;
                    acc.audited |= round.stat.audited;
                    rescue_round_ns += round.stat.round_ns;
                    if let Some(p) = round.partial.take() {
                        rescue_partials.push(p);
                    }
                    acc.suspicion.append(&mut round.suspicion);
                    let stat = absorb(
                        round,
                        t,
                        &mut self.losses,
                        &mut self.roster,
                        &self.recorder,
                        events,
                    );
                    acc.shard_stats.push(stat);
                }
                Err(e) => {
                    acc.extra_crashed += self.note_shard_death(target, t, &e, events);
                    acc.orphans = batch; // try the next survivor
                }
            }
        }

        // ---- fused aggregation + SGD step ------------------------------
        let nchunks = self.losses.len();
        anyhow::ensure!(nchunks > 0, "no chunk survived iteration {t}");
        let slots: Vec<Option<&[f32]>> = acc.partials.iter().map(|p| p.as_deref()).collect();
        let mut agg = linalg::tree_sum(&slots);
        for p in &rescue_partials {
            linalg::tree_combine(&mut agg, p);
        }
        let mut agg = agg.expect("at least one partial aggregate");
        linalg::scale(1.0 / nchunks as f32, &mut agg);
        if acc.oracle_faulty {
            let ev = Event::OracleFaultyUpdate { iter: t };
            if let Some(rec) = &self.recorder {
                rec.on_master_event(None, &ev);
            }
            events.push(ev);
        }
        self.engine.sgd_step(&mut self.theta, &agg, self.lr)?;

        // ---- metrics ---------------------------------------------------
        let RoundAccum {
            shard_stats,
            mut suspicion,
            oracle_faulty,
            audited,
            q_sum,
            q_n,
            lambda_sum,
            extra_crashed,
            fan_round_ns,
            ..
        } = acc;
        let gradients_used: u64 = shard_stats.iter().map(|s| s.gradients_used).sum();
        let gradients_computed: u64 = shard_stats.iter().map(|s| s.gradients_computed).sum();
        let faults_detected: usize = shard_stats.iter().map(|s| s.faults_detected).sum();
        let identified: usize = shard_stats.iter().map(|s| s.identified).sum();
        let crashed: usize =
            shard_stats.iter().map(|s| s.crashed).sum::<usize>() + extra_crashed;
        let stragglers: usize = shard_stats.iter().map(|s| s.stragglers).sum();
        let audited_chunks: usize = shard_stats.iter().map(|s| s.audited_chunks).sum();
        let bytes_round: u64 = shard_stats.iter().map(|s| s.bytes).sum();
        let net_reconnects: u64 = shard_stats.iter().map(|s| s.net_reconnects).sum();
        // global-id suspicion column: a shard that also served a rescue
        // round reports twice — keep the later (rescue-round) snapshot
        suspicion.sort_by(|a, b| a.0.cmp(&b.0));
        suspicion.dedup_by(|later, first| {
            if later.0 == first.0 {
                first.1 = later.1;
                true
            } else {
                false
            }
        });
        Ok(IterationRecord {
            iter: t,
            gradients_used,
            gradients_computed,
            audited,
            faults_detected,
            identified,
            crashed,
            loss: stats::median(&self.losses) as f32,
            q: if q_n > 0 { q_sum / q_n as f64 } else { 0.0 },
            lambda: if q_n > 0 { lambda_sum / q_n as f64 } else { 0.0 },
            oracle_faulty_update: oracle_faulty,
            dist_to_opt: self.w_star.as_ref().map(|w| linalg::dist2(&self.theta, w)),
            wall_ns: {
                let now_wall_ns = self.wall_origin.elapsed().as_nanos() as u64;
                let wall_ns =
                    now_wall_ns.saturating_sub(start_wall_ns.max(self.last_wall_end_ns));
                self.last_wall_end_ns = now_wall_ns;
                wall_ns
            },
            round_ns: fan_round_ns + rescue_round_ns,
            bytes_round,
            pipeline_depth: self.pipeline.max(1),
            net_reconnects,
            stragglers,
            audited_chunks,
            suspicion,
            shard_stats,
        })
    }

    /// Shut the fleet down; returns (theta, eliminated, crashed) with
    /// the roster's global publication order.
    pub fn finish(self) -> (Vec<f32>, Vec<WorkerId>, Vec<WorkerId>) {
        let ParameterServer { theta, transport, roster, .. } = self;
        let _ = transport.into_outcome(); // shutdown inner transports
        (theta, roster.eliminated.clone(), roster.crashed.clone())
    }
}
