//! Sharded multi-master subsystem: several independent protocol cores
//! share one parameter server.
//!
//! The single-master design concentrates both detection work and
//! gather latency in one process. This module partitions the n
//! workers into K contiguous *shards*, each running the full
//! proactive/detection/reactive protocol — majority votes, liar
//! identification, crash reassignment — over **only its own worker
//! subset**, while one [`ParameterServer`] owns the model state and
//! applies a single fused SGD step per round from the shards' partial
//! aggregates. Fault localization stays shard-local (the DRACO-style
//! grouping of Blanchard et al. 2017 / Jain et al. 2024); aggregation
//! stays global.
//!
//! ## Pieces
//!
//! * [`ShardPlan`] — build-time partition of workers into K contiguous
//!   ranges with per-shard Byzantine budgets f_s; `2 f_s < n_s` is
//!   validated when the plan is built, and a shard's budget is raised
//!   to cover any configured liars that land in it.
//! * [`ShardCore`] — wraps a [`super::protocol::ProtocolCore`] (and
//!   its [`super::protocol::RoundState`]) over an *inner* transport
//!   with local worker ids `0..n_s`; runs one shard round over the
//!   chunk slice the parameter server hands it, and returns the
//!   shard's partial aggregate plus remapped (global-id) events.
//!   Latency profiles ([`super::latency`]) live per shard core over
//!   local ids; the suspicion scores and
//!   [`super::events::Event::SuspicionUpdated`] events each shard
//!   reports are remapped to global ids here, so the parameter
//!   server's metrics see one global suspicion roster.
//! * [`ShardedTransport`] — fans a round out to the per-shard inner
//!   transports (threaded or sim, mixed allowed) and gathers the
//!   partial aggregates; the fan-out is poll-interleaved (every
//!   shard's proactive wave is submitted before any shard's
//!   completion wait starts, so shard compute overlaps) and each
//!   shard's gather applies the cluster `GatherPolicy` scaled to its
//!   own width (per-shard K-of-N quorum). A shard whose round fails
//!   is marked dead and its chunks are reassigned to survivors
//!   ("rescue" rounds).
//! * [`ParameterServer`] — samples the round's data points globally
//!   (the same RNG stream the single master uses), partitions them
//!   into per-shard chunk slices, drives the fan-out, combines the
//!   partials with the fixed-shape [`crate::linalg::tree_sum`], and
//!   applies one SGD step. Shard-local eliminations are published to
//!   its global [`Roster`], so an identified liar can never rejoin
//!   through any shard.
//!
//! ## Determinism contract
//!
//! At zero latency, a sharded run is **bit-identical** to the K = 1
//! run with the same seed whenever the chunk values entering the
//! update are partition-invariant — i.e. under the deterministic
//! (always-audit) policy, where every tampered chunk is corrected to
//! the true gradient before aggregation, or in fault-free runs under
//! any policy. Two mechanisms make this hold:
//!
//! 1. the parameter server samples with the *same* RNG stream as the
//!    single-master protocol core, and per-round audit/extension
//!    randomness lives on separate shard-local streams; and
//! 2. every aggregation (sharded or not) is the fixed-shape pairwise
//!    tree of [`crate::linalg::tree_sum`] over worker-id-slotted
//!    leaves, which decomposes exactly along shard boundaries when
//!    the shard width is a power of two.
//!
//! Under randomized audit policies with active attackers, the audit
//! coin flips are shard-local, so *which* iteration a tampered chunk
//! slips through differs across K — that is the paper's randomness
//! semantics, not a bug.

pub mod core;
pub mod param_server;
pub mod transport;

pub use self::core::{ShardCore, ShardRound};
pub use param_server::ParameterServer;
pub use transport::ShardedTransport;

use super::WorkerId;
use crate::Result;

/// One shard's static description: the contiguous global worker range
/// `[lo, hi)`, its Byzantine budget, and the configured liars that
/// fall inside it.
#[derive(Clone, Debug)]
pub struct ShardSpec {
    pub shard: usize,
    pub lo: WorkerId,
    pub hi: WorkerId,
    /// Per-shard Byzantine tolerance bound f_s (2 f_s < n_s).
    pub f_s: usize,
    /// Configured Byzantine worker ids inside this shard (global ids).
    pub byzantine: Vec<WorkerId>,
}

impl ShardSpec {
    pub fn width(&self) -> usize {
        self.hi - self.lo
    }

    pub fn contains(&self, w: WorkerId) -> bool {
        (self.lo..self.hi).contains(&w)
    }

    /// Local id of a global worker in this shard.
    pub fn local(&self, w: WorkerId) -> WorkerId {
        debug_assert!(self.contains(w));
        w - self.lo
    }
}

/// Build-time partition of `n` workers into `k` contiguous shards.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    pub specs: Vec<ShardSpec>,
    pub n: usize,
}

impl ShardPlan {
    /// Partition `n` workers into `k` contiguous shards (sizes differ
    /// by at most one; remainders go to the first shards). The global
    /// budget `f` is split evenly; a shard's budget is raised to cover
    /// any configured liars concentrated in it. Fails unless every
    /// shard satisfies `2 f_s < n_s`.
    pub fn build(n: usize, k: usize, f: usize, byzantine_ids: &[WorkerId]) -> Result<ShardPlan> {
        anyhow::ensure!(k >= 1, "shard count must be positive");
        anyhow::ensure!(k <= n, "cannot split {n} workers into {k} shards");
        let base = n / k;
        let extra = n % k;
        let f_base = f / k;
        let f_extra = f % k;
        let mut specs = Vec::with_capacity(k);
        let mut lo = 0usize;
        for s in 0..k {
            let width = base + usize::from(s < extra);
            let hi = lo + width;
            let byzantine: Vec<WorkerId> = byzantine_ids
                .iter()
                .copied()
                .filter(|&w| (lo..hi).contains(&w))
                .collect();
            let f_s = (f_base + usize::from(s < f_extra)).max(byzantine.len());
            anyhow::ensure!(
                2 * f_s < width,
                "shard {s} (workers {lo}..{hi}) has budget f_s={f_s} violating \
                 2*f_s < n_s={width}; use fewer shards or spread the Byzantine ids"
            );
            specs.push(ShardSpec { shard: s, lo, hi, f_s, byzantine });
            lo = hi;
        }
        Ok(ShardPlan { specs, n })
    }

    pub fn k(&self) -> usize {
        self.specs.len()
    }

    /// The shard owning a global worker id.
    pub fn shard_of(&self, w: WorkerId) -> usize {
        self.specs
            .iter()
            .position(|s| s.contains(w))
            .expect("worker id out of plan range")
    }
}

/// Why a worker left the global roster.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkerStatus {
    Active,
    /// Identified as Byzantine by its shard and published here; the
    /// worker can never rejoin through any shard.
    Eliminated { shard: usize, iter: u64 },
    /// Crash-stopped (not an identification).
    Crashed { iter: u64 },
}

/// The parameter server's global worker roster: the authoritative
/// record of which workers are still trusted, across all shards.
#[derive(Clone, Debug)]
pub struct Roster {
    status: Vec<WorkerStatus>,
    eliminated: Vec<WorkerId>,
    crashed: Vec<WorkerId>,
}

impl Roster {
    pub fn new(n: usize) -> Roster {
        Roster {
            status: vec![WorkerStatus::Active; n],
            eliminated: Vec::new(),
            crashed: Vec::new(),
        }
    }

    pub fn status(&self, w: WorkerId) -> WorkerStatus {
        self.status[w]
    }

    pub fn is_eliminated(&self, w: WorkerId) -> bool {
        matches!(self.status[w], WorkerStatus::Eliminated { .. })
    }

    /// Publish a shard-local elimination globally (idempotent).
    /// Returns true when the worker was newly published.
    pub fn publish_elimination(&mut self, w: WorkerId, shard: usize, iter: u64) -> bool {
        if self.status[w] == WorkerStatus::Active {
            self.status[w] = WorkerStatus::Eliminated { shard, iter };
            self.eliminated.push(w);
            true
        } else {
            false
        }
    }

    /// Record a crash-stop (idempotent; never downgrades an
    /// elimination). Returns true when the worker was newly recorded.
    pub fn record_crash(&mut self, w: WorkerId, iter: u64) -> bool {
        if self.status[w] == WorkerStatus::Active {
            self.status[w] = WorkerStatus::Crashed { iter };
            self.crashed.push(w);
            true
        } else {
            false
        }
    }

    /// Eliminated workers in publication order.
    pub fn eliminated(&self) -> &[WorkerId] {
        &self.eliminated
    }

    /// Crashed workers in record order.
    pub fn crashed(&self) -> &[WorkerId] {
        &self.crashed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_partitions_contiguously_with_even_budgets() {
        let plan = ShardPlan::build(64, 4, 8, &[3, 19, 35, 51]).unwrap();
        assert_eq!(plan.k(), 4);
        for (s, spec) in plan.specs.iter().enumerate() {
            assert_eq!(spec.width(), 16);
            assert_eq!(spec.lo, s * 16);
            assert_eq!(spec.f_s, 2); // 8 / 4
            assert_eq!(spec.byzantine, vec![s * 16 + 3]);
        }
        assert_eq!(plan.shard_of(0), 0);
        assert_eq!(plan.shard_of(63), 3);
    }

    #[test]
    fn plan_uneven_sizes_and_budget_raise() {
        // 16 workers in 3 shards: widths 6, 5, 5; both liars in shard 0
        let plan = ShardPlan::build(16, 3, 2, &[0, 1]).unwrap();
        let widths: Vec<usize> = plan.specs.iter().map(|s| s.width()).collect();
        assert_eq!(widths, vec![6, 5, 5]);
        // even split gives shard 0 f_s = 1, raised to 2 to cover its
        // liars; shard 1 keeps the remainder budget, shard 2 gets none
        let budgets: Vec<usize> = plan.specs.iter().map(|s| s.f_s).collect();
        assert_eq!(budgets, vec![2, 1, 0]);
    }

    #[test]
    fn plan_rejects_overloaded_shard() {
        // shard width 2 cannot tolerate f_s = 1 (2*1 >= 2)
        assert!(ShardPlan::build(8, 4, 4, &[]).is_err());
        // liar concentration raises f_s past the bound
        assert!(ShardPlan::build(16, 4, 2, &[0, 1]).is_err());
        // degenerate: more shards than workers
        assert!(ShardPlan::build(4, 8, 0, &[]).is_err());
        // fine: budget 0, any width >= 1
        assert!(ShardPlan::build(4, 4, 0, &[]).is_ok());
    }

    #[test]
    fn roster_publishes_once_and_keeps_order() {
        let mut r = Roster::new(8);
        r.publish_elimination(5, 1, 3);
        r.publish_elimination(2, 0, 4);
        r.publish_elimination(5, 1, 9); // duplicate: ignored
        r.record_crash(7, 2);
        r.record_crash(5, 6); // already eliminated: ignored
        assert_eq!(r.eliminated(), &[5, 2]);
        assert_eq!(r.crashed(), &[7]);
        assert!(r.is_eliminated(5));
        assert_eq!(r.status(5), WorkerStatus::Eliminated { shard: 1, iter: 3 });
        assert_eq!(r.status(7), WorkerStatus::Crashed { iter: 2 });
        assert_eq!(r.status(0), WorkerStatus::Active);
    }
}
