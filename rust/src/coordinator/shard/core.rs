//! One shard's protocol core: today's [`ProtocolCore`] + `RoundState`
//! over an inner transport with *local* worker ids `0..n_s`, plus the
//! local→global id remapping for everything that leaves the shard
//! (events, identifications, crash reports, partial aggregates).

use std::sync::Arc;

use super::super::events::{Event, EventLog};
use super::super::metrics::ShardStat;
use super::super::protocol::ProtocolCore;
use super::super::{ChunkId, WorkerId, MASTER_SENTINEL};
use super::ShardSpec;
use crate::data::Dataset;
use crate::grad::GradientComputer;
use crate::linalg;
use crate::Result;

/// What one shard hands back to the parameter server after a round.
pub struct ShardRound {
    /// Partial aggregate: the shard's fixed-shape tree sum over its
    /// worker-id-slotted chunk gradients (undivided; the parameter
    /// server scales by the global chunk count once). `None` when the
    /// round produced no chunks.
    pub partial: Option<Vec<f32>>,
    /// Chosen loss per chunk, in local chunk order (the server
    /// concatenates them in shard order for the global median).
    pub losses: Vec<f64>,
    /// Shard dimension of the metrics.
    pub stat: ShardStat,
    /// Workers identified and eliminated this round (global ids), for
    /// publication to the global roster.
    pub identified: Vec<WorkerId>,
    /// Workers that crash-stopped this round (global ids).
    pub crashed: Vec<WorkerId>,
    /// Per-worker suspicion scores after this round (global ids,
    /// nonzero only) — the shard's slice of the latency-aware roster
    /// view (see `coordinator::latency`).
    pub suspicion: Vec<(WorkerId, f64)>,
    /// Oracle: did a tampered copy end up as a chosen chunk value?
    pub oracle_faulty: bool,
}

/// Per-round bookkeeping between [`ShardCore::begin`] and
/// [`ShardCore::finish`]. With pipelined rounds several iterations can
/// be pending on one shard at once, keyed by iteration.
struct ShardPending {
    chunk_offset: ChunkId,
    chunk_size: usize,
    slot_by_owner: bool,
    workers_active: usize,
}

/// A shard: spec + wrapped protocol core + liveness.
pub struct ShardCore {
    spec: ShardSpec,
    core: ProtocolCore,
    alive: bool,
    pending: Vec<(u64, ShardPending)>,
}

impl ShardCore {
    /// Wrap a protocol core whose transport has `spec.width()` workers
    /// with local ids `0..n_s`.
    pub fn new(spec: ShardSpec, core: ProtocolCore) -> ShardCore {
        ShardCore { spec, core, alive: true, pending: Vec::new() }
    }

    pub fn spec(&self) -> &ShardSpec {
        &self.spec
    }

    pub fn alive(&self) -> bool {
        self.alive
    }

    /// Active workers right now (count; ids are local to the shard).
    pub fn active_count(&self) -> usize {
        if self.alive {
            self.core.active().len()
        } else {
            0
        }
    }

    /// Global ids of the shard's active workers (roster cross-checks).
    pub fn active_globals(&self) -> Vec<WorkerId> {
        if !self.alive {
            return Vec::new();
        }
        self.core.active().iter().map(|&w| self.global(w)).collect()
    }

    /// Global ids of every worker this shard has eliminated so far.
    /// Normally eliminations reach the roster through [`ShardRound`];
    /// when a round fails mid-way its `identified_now` is lost with
    /// the error, so the parameter server re-publishes from here
    /// before retiring the shard.
    pub fn eliminated_globals(&self) -> Vec<WorkerId> {
        self.core.eliminated().iter().map(|&w| self.global(w)).collect()
    }

    fn global(&self, local: WorkerId) -> WorkerId {
        if local == MASTER_SENTINEL {
            local
        } else {
            self.spec.lo + local
        }
    }

    /// Remap a shard-local event to global worker/chunk ids.
    fn remap(&self, e: Event, chunk_offset: ChunkId) -> Event {
        match e {
            Event::AuditDecision { iter, q, audited } => Event::AuditDecision { iter, q, audited },
            Event::FaultDetected { iter, chunk, owners } => Event::FaultDetected {
                iter,
                chunk: chunk + chunk_offset,
                owners: owners.into_iter().map(|w| self.global(w)).collect(),
            },
            Event::ReactiveRedundancy { iter, chunk, added } => Event::ReactiveRedundancy {
                iter,
                chunk: chunk + chunk_offset,
                added: added.into_iter().map(|w| self.global(w)).collect(),
            },
            Event::Identified { iter, workers } => Event::Identified {
                iter,
                workers: workers.into_iter().map(|w| self.global(w)).collect(),
            },
            Event::Eliminated { iter, worker } => {
                Event::Eliminated { iter, worker: self.global(worker) }
            }
            Event::WorkerCrashed { iter, worker } => {
                Event::WorkerCrashed { iter, worker: self.global(worker) }
            }
            Event::StragglerAbandoned { iter, worker } => {
                Event::StragglerAbandoned { iter, worker: self.global(worker) }
            }
            Event::SuspicionUpdated { iter, worker, suspicion } => {
                Event::SuspicionUpdated { iter, worker: self.global(worker), suspicion }
            }
            // the inner core never emits shard-level events
            other => other,
        }
    }

    /// Run one shard round over the chunk slice the parameter server
    /// sampled for this shard (submit + complete back to back; the
    /// parameter server instead calls [`ShardCore::begin`] on every
    /// shard first so all proactive waves are in flight before any
    /// shard's completion wait starts). `chunk_offset` is the shard's
    /// first global chunk index (for event remapping). `slot_by_owner`
    /// selects the partial-aggregate leaf layout: normal rounds slot
    /// each chunk by its primary owner's local id (the layout that
    /// makes the tree sum partition-invariant); rescue rounds, where
    /// the chunk count is unrelated to the worker count, slot by chunk
    /// index instead.
    #[allow(clippy::too_many_arguments)]
    pub fn run(
        &mut self,
        t: u64,
        theta: &Arc<Vec<f32>>,
        chunks: Vec<Vec<usize>>,
        chunk_offset: ChunkId,
        chunk_size: usize,
        slot_by_owner: bool,
        dataset: &dyn Dataset,
        engine: &dyn GradientComputer,
        events: &mut EventLog,
    ) -> Result<ShardRound> {
        self.begin(t, theta, chunks, chunk_offset, chunk_size, slot_by_owner, dataset)?;
        self.complete(t, theta, dataset, engine, events)
    }

    /// Submit the shard's proactive wave without waiting on it. On
    /// error the shard is marked dead (its chunks must be rescued).
    #[allow(clippy::too_many_arguments)]
    pub fn begin(
        &mut self,
        t: u64,
        theta: &Arc<Vec<f32>>,
        chunks: Vec<Vec<usize>>,
        chunk_offset: ChunkId,
        chunk_size: usize,
        slot_by_owner: bool,
        dataset: &dyn Dataset,
    ) -> Result<()> {
        debug_assert!(self.alive, "round dispatched to a dead shard");
        debug_assert!(
            !self.pending.iter().any(|(pt, _)| *pt == t),
            "shard round {t} already in flight"
        );
        let workers_active = self.core.active().len();
        if let Err(e) = self.core.begin_round(t, theta, chunks, dataset) {
            self.alive = false;
            return Err(e);
        }
        self.pending
            .push((t, ShardPending { chunk_offset, chunk_size, slot_by_owner, workers_active }));
        Ok(())
    }

    /// Gather the proactive wave begun by [`ShardCore::begin`]
    /// (idempotent). On error the shard is marked dead; the events it
    /// emitted before failing are still surrendered.
    pub fn collect(
        &mut self,
        t: u64,
        theta: &Arc<Vec<f32>>,
        dataset: &dyn Dataset,
        events: &mut EventLog,
    ) -> Result<()> {
        let chunk_offset = self
            .pending
            .iter()
            .find(|(pt, _)| *pt == t)
            .expect("collect without begin")
            .1
            .chunk_offset;
        let mut local_events = EventLog::default();
        let res = self.core.collect_proactive(t, theta, dataset, &mut local_events);
        for e in local_events.events {
            let remapped = self.remap(e, chunk_offset);
            events.push(Event::Shard { shard: self.spec.shard, inner: Box::new(remapped) });
        }
        if let Err(e) = res {
            self.alive = false;
            self.pending.retain(|(pt, _)| *pt != t);
            return Err(e);
        }
        Ok(())
    }

    /// Undivided pre-audit partial aggregate of a collected pending
    /// round (owner-slotted tree sum, like [`ShardCore::finish`]'s
    /// exact partial) and its chunk count — the parameter server's
    /// input to the pipelined driver's provisional θ. `None` if the
    /// shard is dead or the round is not collected.
    pub fn provisional_partial(&self, t: u64) -> Option<(Option<Vec<f32>>, usize)> {
        if !self.alive {
            return None;
        }
        let round = self.core.pending_round(t)?;
        let nchunks = round.nchunks();
        let mut leaves: Vec<Option<&[f32]>> = vec![None; self.spec.width()];
        for c in 0..nchunks {
            leaves[round.assignment.owners[c][0]] = Some(&round.chosen(c).grad);
        }
        Some((linalg::tree_sum(&leaves), nchunks))
    }

    /// Retire a pending (uncollected) wave and resubmit it on a new θ
    /// — the pipelined driver's ordered-apply correction. On error the
    /// shard is marked dead.
    pub fn reissue(&mut self, t: u64, theta: &Arc<Vec<f32>>, dataset: &dyn Dataset) -> Result<()> {
        debug_assert!(self.alive, "reissue on a dead shard");
        if let Err(e) = self.core.reissue_round(t, theta, dataset) {
            self.alive = false;
            self.pending.retain(|(pt, _)| *pt != t);
            return Err(e);
        }
        Ok(())
    }

    /// Collect the wave begun by [`ShardCore::begin`] and finish the
    /// shard round: detection/reactive phases, partial aggregate,
    /// remapped events.
    pub fn complete(
        &mut self,
        t: u64,
        theta: &Arc<Vec<f32>>,
        dataset: &dyn Dataset,
        engine: &dyn GradientComputer,
        events: &mut EventLog,
    ) -> Result<ShardRound> {
        self.collect(t, theta, dataset, events)?;
        self.finish(t, theta, dataset, engine, events)
    }

    /// Finish a collected shard round: detection/reactive phases,
    /// partial aggregate, remapped events.
    pub fn finish(
        &mut self,
        t: u64,
        theta: &Arc<Vec<f32>>,
        dataset: &dyn Dataset,
        engine: &dyn GradientComputer,
        events: &mut EventLog,
    ) -> Result<ShardRound> {
        let pos = self
            .pending
            .iter()
            .position(|(pt, _)| *pt == t)
            .expect("finish without begin");
        let (_, ShardPending { chunk_offset, chunk_size, slot_by_owner, workers_active }) =
            self.pending.remove(pos);
        let mut local_events = EventLog::default();
        let completed = self.core.finish_round(t, theta, dataset, engine, &mut local_events);
        let outcome = match completed {
            Ok(out) => out,
            Err(e) => {
                // the shard is unusable from here on: surrender what
                // happened before the failure, then report the error
                self.alive = false;
                for e in local_events.events {
                    let remapped = self.remap(e, chunk_offset);
                    events.push(Event::Shard { shard: self.spec.shard, inner: Box::new(remapped) });
                }
                return Err(e);
            }
        };
        for e in local_events.events {
            let remapped = self.remap(e, chunk_offset);
            events.push(Event::Shard { shard: self.spec.shard, inner: Box::new(remapped) });
        }

        let round = self.core.round();
        let nchunks = round.nchunks();

        // partial aggregate over fixed leaf slots
        let width = self.spec.width();
        let slots = if slot_by_owner { width } else { nchunks };
        let mut leaves: Vec<Option<&[f32]>> = vec![None; slots];
        let mut losses = Vec::with_capacity(nchunks);
        let mut oracle_faulty = false;
        let mut computed_points = 0u64;
        for c in 0..nchunks {
            let chosen = round.chosen(c);
            let slot = if slot_by_owner { round.assignment.owners[c][0] } else { c };
            debug_assert!(leaves[slot].is_none(), "two chunks slotted to one owner");
            leaves[slot] = Some(&chosen.grad);
            losses.push(chosen.loss as f64);
            if chosen.worker != MASTER_SENTINEL
                && round.tampered_by_chunk[c].contains(&chosen.worker)
            {
                oracle_faulty = true;
            }
            computed_points += (round.chunks[c].computed_copies * chunk_size) as u64;
        }
        let partial = linalg::tree_sum(&leaves);
        computed_points += outcome.master_computed_points;

        let identified: Vec<WorkerId> =
            outcome.identified_now.iter().map(|&w| self.global(w)).collect();
        let crashed: Vec<WorkerId> =
            outcome.crashed_now.iter().map(|&w| self.global(w)).collect();
        let suspicion: Vec<(WorkerId, f64)> = self
            .core
            .policy()
            .suspicion_nonzero()
            .into_iter()
            .map(|(w, s)| (self.global(w), s))
            .collect();
        Ok(ShardRound {
            partial,
            losses,
            stat: ShardStat {
                shard: self.spec.shard,
                workers_active,
                gradients_used: outcome.gradients_used,
                gradients_computed: computed_points,
                audited: outcome.audited,
                audited_chunks: outcome.audited_chunks,
                faults_detected: outcome.faults_detected,
                identified: identified.len(),
                crashed: crashed.len(),
                stragglers: outcome.stragglers_now.len(),
                round_ns: outcome.round_ns,
                bytes: outcome.bytes_round,
                net_reconnects: outcome.net_reconnects,
            },
            identified,
            crashed,
            suspicion,
            oracle_faulty,
        })
    }

    /// Mark the shard dead and surrender the global ids of every
    /// worker it can no longer vouch for: the ones it still considered
    /// active plus the ones it saw crash (a failed round returns no
    /// [`ShardRound`], so the parameter server re-learns the crashes
    /// here; the roster records each worker at most once).
    pub fn fail(&mut self) -> Vec<WorkerId> {
        self.alive = false;
        self.pending.clear();
        let mut ws: Vec<WorkerId> =
            self.core.active().iter().map(|&w| self.global(w)).collect();
        ws.extend(self.core.crashed().iter().map(|&w| self.global(w)));
        ws
    }

    /// Mean of the shard policy's most recent audit probability (for
    /// the iteration record's q column).
    pub fn last_q(&self) -> f64 {
        self.core.policy().last_q
    }

    /// Adaptive-policy λ_t (0 for other policies).
    pub fn lambda(&self) -> f64 {
        self.core.policy().adaptive_state().0
    }

    /// Shut the inner transport down and surrender the shard's final
    /// eliminated/crashed worker sets (global ids).
    pub fn into_outcome(self) -> (Vec<WorkerId>, Vec<WorkerId>) {
        let lo = self.spec.lo;
        let (elim, crashed) = self.core.into_outcome();
        (
            elim.into_iter().map(|w| w + lo).collect(),
            crashed.into_iter().map(|w| w + lo).collect(),
        )
    }
}
