//! Shard-level transport: fans one round out to the per-shard inner
//! transports and gathers the partial aggregates.
//!
//! This sits one level *above* the symbol-level
//! [`super::super::transport::Transport`] trait: each shard owns an
//! inner `Transport` (threaded or sim — a mixed fleet is allowed, e.g.
//! local threaded shards next to simulated remote ones), and the
//! [`ShardedTransport`] exchanges chunk slices for partial aggregates
//! instead of task bundles for symbols. A shard whose round fails is
//! marked dead; the caller (the parameter server) reassigns its chunks
//! to survivors via [`ShardedTransport::rescue`].
//!
//! The fan-out is poll-interleaved, not sequential: `fan_round` first
//! calls [`ShardCore::begin`] on every alive shard — putting every
//! shard's proactive wave in flight — and only then completes the
//! shards one by one. Threaded shards therefore compute concurrently
//! while the master waits on the first one (the wall-clock cost of a
//! round is max over shards, not the sum), and each shard's completion
//! wait applies its own [`crate::config::GatherPolicy`] — a cluster
//! quorum `k` is scaled to each shard's width (ceil(k·n_s/n)), so the
//! K-of-N wait is per shard, as the sharded protocol requires.

use std::sync::Arc;

use super::super::byzantine::ByzantineBehavior;
use super::super::events::EventLog;
use super::super::policy::FaultCheckPolicy;
use super::super::protocol::{ProtocolConfig, ProtocolCore};
use super::super::transport::{
    AdversaryWiring, AuthKey, ChaosSpec, LatencyModel, NetConfig, NetTransport, SimConfig,
    SimTransport, ThreadedTransport, Transport,
};
use super::super::{ChunkId, WorkerId};
use super::{ShardCore, ShardPlan, ShardRound, ShardSpec};
use crate::adversary::{AdversaryController, CoreTap};
use crate::config::{AttackConfig, GatherPolicy, PolicyKind, TransportKind};
use crate::data::Dataset;
use crate::grad::GradientComputer;
use crate::Result;

/// Everything needed to build one shard's inner transport + core.
pub struct ShardBuildConfig {
    /// Inner transport kind (uniform; use
    /// [`ShardedTransport::from_cores`] to mix kinds).
    pub transport: TransportKind,
    /// Cluster-level gather policy; a quorum count is scaled to each
    /// shard's width so the K-of-N wait is per shard.
    pub gather: GatherPolicy,
    /// Total worker count n (the quorum scaling denominator).
    pub cluster_n: usize,
    pub seed: u64,
    pub attack: AttackConfig,
    pub policy: PolicyKind,
    pub chunk_size: usize,
    pub self_check: bool,
    pub tol: f32,
    pub no_eliminate: bool,
    /// Wire compressor shared by every shard's workers (None = dense).
    pub compressor: Option<Arc<dyn crate::coordinator::compress::Compressor>>,
    /// Round pipeline depth for each shard's protocol core ring.
    pub pipeline: usize,
    pub latency_us: u64,
    /// Sim scenario knobs; straggler/crash worker ids are *global* and
    /// remapped into each shard here.
    pub sim: SimConfig,
    /// Coordinated adversary spanning the whole fleet: each shard's
    /// inner transport wires its colluders to this one controller, and
    /// each shard core gets a [`CoreTap`] remapping its local ids to
    /// global ones. Replaces the stateless `attack` path for the
    /// configured Byzantine ids when set.
    pub adversary: Option<Arc<AdversaryController>>,
    /// Flight recorder: each shard core gets a
    /// [`crate::trace::TraceHandle`] that shard-wraps its events and
    /// remaps local worker ids to global ones, exactly like the
    /// `EventLog` the parameter server keeps.
    pub recorder: Option<Arc<crate::trace::Recorder>>,
    /// Worker addresses in global id order (net transport only; each
    /// shard takes the `lo..lo+width` slice). Empty otherwise.
    pub peers: Vec<String>,
    /// Model spec forwarded to remote workers in the net hello
    /// (required when `transport` is [`TransportKind::Net`]).
    pub net_model: Option<crate::grad::ModelSpec>,
    /// Net-transport fault injection, shared by every shard's links
    /// (chaos streams key on global worker ids, so the storm is
    /// identical whichever shard layout contains a link).
    pub chaos: Option<ChaosSpec>,
    /// Net-transport frame authentication key (None = legacy wire).
    pub auth: Option<AuthKey>,
}

/// Scale a cluster-level gather policy to one shard: `Quorum { k }`
/// becomes k-of-n_s with k_s = ceil(k * n_s / n) (so `quorum:0.8`
/// means 80% of *each shard*); `All` and `Deadline` pass through.
fn shard_gather(gather: GatherPolicy, n_s: usize, n: usize) -> GatherPolicy {
    match gather {
        GatherPolicy::Quorum { k } => {
            let k_s = (k * n_s).div_ceil(n);
            GatherPolicy::Quorum { k: k_s.clamp(1, n_s) }
        }
        other => other,
    }
}

/// Derive a shard-local seed so shards draw independent audit coins
/// and extension shuffles.
fn shard_seed(seed: u64, shard: usize) -> u64 {
    seed ^ (0x9e37_79b9_7f4a_7c15u64.wrapping_mul(shard as u64 + 1))
}

/// Build one shard's inner transport: local ids `0..n_s`, Byzantine
/// behaviour and sim scenarios remapped from global ids.
fn build_inner(
    spec: &ShardSpec,
    cfg: &ShardBuildConfig,
    engine: &Arc<dyn GradientComputer>,
) -> Result<Box<dyn Transport>> {
    let n_s = spec.width();
    let lo = spec.lo;
    let byz = spec.byzantine.clone();
    let attack = cfg.attack.clone();
    let seed = cfg.seed;
    let coordinated = cfg.adversary.is_some();
    // behaviour is seeded with the *global* id, so a liar's tamper
    // stream is identical whichever shard layout contains it (the
    // coordinated adversary supersedes the stateless path entirely)
    let byzantine = move |local: WorkerId| {
        let global = lo + local;
        (!coordinated && byz.contains(&global))
            .then(|| ByzantineBehavior::new(attack.clone(), seed, global))
    };
    // the wiring carries the shard's global offset so colluders get
    // handles keyed by their global ids
    let wiring = cfg
        .adversary
        .as_ref()
        .map(|c| AdversaryWiring { controller: c.clone(), lo });
    Ok(match cfg.transport {
        TransportKind::Threaded => Box::new(ThreadedTransport::spawn_full(
            n_s,
            engine.clone(),
            byzantine,
            cfg.compressor.clone(),
            cfg.latency_us,
            wiring,
        )),
        TransportKind::Sim => {
            let mut sim = cfg.sim.clone();
            if matches!(sim.latency, LatencyModel::Zero) && cfg.latency_us > 0 {
                sim.latency = LatencyModel::Fixed { us: cfg.latency_us };
            }
            sim.seed = shard_seed(sim.seed, spec.shard);
            let stragglers: Vec<(WorkerId, f64)> = sim
                .stragglers
                .iter()
                .filter(|(w, _)| spec.contains(*w))
                .map(|(w, m)| (spec.local(*w), *m))
                .collect();
            sim.stragglers = stragglers;
            let crash_at: Vec<(WorkerId, u64)> = sim
                .crash_at
                .iter()
                .filter(|(w, _)| spec.contains(*w))
                .map(|(w, t)| (spec.local(*w), *t))
                .collect();
            sim.crash_at = crash_at;
            Box::new(SimTransport::new_full(
                n_s,
                engine.clone(),
                byzantine,
                cfg.compressor.clone(),
                sim,
                wiring,
            ))
        }
        TransportKind::Net => {
            let _ = byzantine; // remote workers rebuild it from the hello
            anyhow::ensure!(
                wiring.is_none(),
                "coordinated adversaries are in-process only (use --transport threaded|sim)"
            );
            anyhow::ensure!(
                cfg.peers.len() >= lo + n_s,
                "net transport needs {} peer addresses, got {}",
                lo + n_s,
                cfg.peers.len()
            );
            let model = cfg.net_model.clone().ok_or_else(|| {
                anyhow::anyhow!("net transport needs the model spec (ShardBuildConfig.net_model)")
            })?;
            let mut net_cfg = NetConfig::new(cfg.peers[lo..lo + n_s].to_vec(), model);
            net_cfg.lo = lo;
            // the global seed, not shard_seed: remote Byzantine RNGs key
            // on (seed, global id), matching the in-process closure above
            net_cfg.seed = seed;
            net_cfg.latency_us = cfg.latency_us;
            net_cfg.attack = Some(cfg.attack.clone());
            net_cfg.byzantine_ids = spec.byzantine.clone();
            net_cfg.compressor = cfg.compressor.clone();
            net_cfg.chaos = cfg.chaos;
            net_cfg.auth = cfg.auth;
            net_cfg.telemetry = cfg.recorder.is_some();
            Box::new(NetTransport::connect(net_cfg)?)
        }
    })
}

/// The fleet of shard cores behind the parameter server.
pub struct ShardedTransport {
    cores: Vec<ShardCore>,
}

impl ShardedTransport {
    /// Build a uniform fleet from a plan (the CLI/config path).
    pub fn build(
        plan: &ShardPlan,
        cfg: &ShardBuildConfig,
        engine: &Arc<dyn GradientComputer>,
    ) -> Result<ShardedTransport> {
        let mut cores = Vec::with_capacity(plan.k());
        for spec in &plan.specs {
            let inner = build_inner(spec, cfg, engine)?;
            let policy = FaultCheckPolicy::new(
                cfg.policy.clone(),
                spec.width(),
                shard_seed(cfg.seed, spec.shard),
            );
            let mut core = ProtocolCore::new(
                inner,
                policy,
                ProtocolConfig {
                    f: spec.f_s,
                    seed: shard_seed(cfg.seed, spec.shard),
                    chunk_size: cfg.chunk_size,
                    self_check: cfg.self_check,
                    tol: cfg.tol,
                    no_eliminate: cfg.no_eliminate,
                    compressor: cfg.compressor.clone(),
                    gather: shard_gather(cfg.gather, spec.width(), cfg.cluster_n),
                    pipeline: cfg.pipeline,
                },
            );
            if let Some(c) = &cfg.adversary {
                // the tap remaps this shard's local ids to global ones
                core.set_tap(Arc::new(CoreTap::new(c.clone(), spec.shard, spec.lo)));
            }
            if let Some(rec) = &cfg.recorder {
                core.set_recorder(rec.clone().shard_handle(spec.shard, spec.lo));
            }
            cores.push(ShardCore::new(spec.clone(), core));
        }
        Ok(ShardedTransport { cores })
    }

    /// Assemble from pre-built cores (tests mix threaded and sim
    /// shards here).
    pub fn from_cores(cores: Vec<ShardCore>) -> ShardedTransport {
        ShardedTransport { cores }
    }

    pub fn k(&self) -> usize {
        self.cores.len()
    }

    /// Total worker endpoints across shards.
    pub fn n(&self) -> usize {
        self.cores.iter().map(|c| c.spec().width()).sum()
    }

    pub fn cores(&self) -> &[ShardCore] {
        &self.cores
    }

    /// Mutable shard access for the parameter server's pipelined
    /// driver, which begins/collects/finishes shard rounds itself
    /// instead of going through [`ShardedTransport::fan_round`].
    pub fn cores_mut(&mut self) -> &mut [ShardCore] {
        &mut self.cores
    }

    /// Per-shard active worker counts (0 for dead shards) — the
    /// parameter server sizes each shard's chunk slice with these.
    pub fn active_counts(&self) -> Vec<usize> {
        self.cores.iter().map(|c| c.active_count()).collect()
    }

    /// Fan one round out: `slices[s]` is shard s's chunk slice (empty
    /// for dead shards) and `offsets[s]` its first global chunk index.
    /// Returns one entry per shard; a failed shard yields `Err` and is
    /// marked dead (its chunks must be re-dispatched via `rescue`).
    ///
    /// Poll-interleaved dispatch: every alive shard's proactive wave is
    /// submitted (`ShardCore::begin`) before any shard's completion
    /// wait starts, so shard compute overlaps — waiting on shard 0
    /// costs nothing for shards 1..K, whose workers are already
    /// running (threaded) or whose virtual clocks are independent
    /// (sim).
    #[allow(clippy::too_many_arguments)]
    pub fn fan_round(
        &mut self,
        t: u64,
        theta: &Arc<Vec<f32>>,
        slices: Vec<Vec<Vec<usize>>>,
        offsets: &[ChunkId],
        chunk_size: usize,
        dataset: &dyn Dataset,
        engine: &dyn GradientComputer,
        events: &mut EventLog,
    ) -> Vec<Option<Result<ShardRound>>> {
        debug_assert_eq!(slices.len(), self.cores.len());
        let k = self.cores.len();
        let mut results: Vec<Option<Result<ShardRound>>> = Vec::with_capacity(k);
        results.resize_with(k, || None);
        let mut begun = vec![false; k];
        for (s, (core, chunks)) in self.cores.iter_mut().zip(slices).enumerate() {
            if !core.alive() || chunks.is_empty() {
                continue;
            }
            match core.begin(t, theta, chunks, offsets[s], chunk_size, true, dataset) {
                Ok(()) => begun[s] = true,
                Err(e) => results[s] = Some(Err(e)),
            }
        }
        for (s, core) in self.cores.iter_mut().enumerate() {
            if begun[s] {
                results[s] = Some(core.complete(t, theta, dataset, engine, events));
            }
        }
        results
    }

    /// Run orphaned chunks (from a dead shard) through one survivor.
    #[allow(clippy::too_many_arguments)]
    pub fn rescue(
        &mut self,
        shard: usize,
        t: u64,
        theta: &Arc<Vec<f32>>,
        chunks: Vec<Vec<usize>>,
        chunk_offset: ChunkId,
        chunk_size: usize,
        dataset: &dyn Dataset,
        engine: &dyn GradientComputer,
        events: &mut EventLog,
    ) -> Result<ShardRound> {
        self.cores[shard].run(
            t,
            theta,
            chunks,
            chunk_offset,
            chunk_size,
            false,
            dataset,
            engine,
            events,
        )
    }

    /// Mark a shard dead, returning the global ids of the workers it
    /// still considered active.
    pub fn fail_shard(&mut self, shard: usize) -> Vec<WorkerId> {
        self.cores[shard].fail()
    }

    /// Shut every shard down; returns (eliminated, crashed) global ids
    /// across shards in shard order.
    pub fn into_outcome(self) -> (Vec<WorkerId>, Vec<WorkerId>) {
        let mut eliminated = Vec::new();
        let mut crashed = Vec::new();
        for core in self.cores {
            let (e, c) = core.into_outcome();
            eliminated.extend(e);
            crashed.extend(c);
        }
        (eliminated, crashed)
    }
}
