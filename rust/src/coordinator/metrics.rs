//! Computation-efficiency accounting (Definition 2 of the paper) and
//! per-iteration training telemetry.
//!
//! Definition 2: efficiency of an iteration = (# gradients used for the
//! update) / (# gradients computed in total). We count *gradients* in
//! data-point units: a worker computing the symbol of a chunk of B
//! points computed B gradients; the master's self-checks count too.
//!
//! Every column of the metrics CSV ([`TrainMetrics::to_csv`]) is
//! documented in `docs/METRICS.md`, including which transport clock
//! each timestamp lives on.

use super::WorkerId;

/// One shard's slice of an iteration (sharded runs only): the shard
/// dimension of the efficiency accounting.
#[derive(Clone, Debug, Default)]
pub struct ShardStat {
    pub shard: usize,
    /// Active workers in the shard when the round started.
    pub workers_active: usize,
    pub gradients_used: u64,
    pub gradients_computed: u64,
    pub audited: bool,
    /// Chunks the shard's audit decision covered this round.
    pub audited_chunks: usize,
    pub faults_detected: usize,
    pub identified: usize,
    pub crashed: usize,
    /// Workers the shard's proactive gather abandoned this round.
    pub stragglers: usize,
    /// Shard round duration on the shard transport's clock (virtual
    /// under sim, wall-clock under threaded).
    pub round_ns: u64,
    /// Honest wire bytes the shard's round moved (see
    /// `IterationRecord::bytes_round`).
    pub bytes: u64,
    /// TCP reconnects the shard's net transport rode out this round
    /// (0 on in-process transports).
    pub net_reconnects: u64,
}

#[derive(Clone, Debug, Default)]
pub struct IterationRecord {
    pub iter: u64,
    /// Gradients (data points) whose values entered the update.
    pub gradients_used: u64,
    /// Gradients computed across all workers + master this iteration.
    pub gradients_computed: u64,
    pub audited: bool,
    pub faults_detected: usize,
    pub identified: usize,
    /// Workers that crash-stopped this iteration (sim scenarios).
    pub crashed: usize,
    /// Loss at w_t observed from the (honest-majority) symbols.
    pub loss: f32,
    /// q used by the policy this iteration.
    pub q: f64,
    /// λ_t (adaptive policy only, else 0).
    pub lambda: f64,
    /// Oracle: did a tampered gradient enter the update?
    pub oracle_faulty_update: bool,
    /// Distance to the planted optimum (linreg workloads only).
    pub dist_to_opt: Option<f32>,
    pub wall_ns: u64,
    /// Round duration on the transport clock: virtual time under sim,
    /// wall-clock under threaded. This is the number the quorum-gather
    /// speedup shows up in (`wall_ns` measures the master process,
    /// which under sim excludes simulated latency entirely). Sharded
    /// runs report max over the shard rounds plus any serial rescue
    /// rounds — exact for sim shards (independent virtual clocks); an
    /// upper bound for threaded shards, whose wall-clocks also tick
    /// while earlier shards' completions run on the caller's thread.
    pub round_ns: u64,
    /// Honest wire bytes moved this iteration: the sum over delivered
    /// (untampered) symbol copies of their packed wire size — packed
    /// bytes under `--compress sign|topk:K`, dense `4·d` otherwise.
    /// Adversarial corruption does not change what honest workers
    /// would send, so tampered copies count at the same size. Under
    /// the net transport this is the honest TCP figure instead: every
    /// byte moved in either direction, frame/header overhead and the
    /// theta broadcast included.
    pub bytes_round: u64,
    /// Round pipeline depth the run was configured with
    /// (`cluster.pipeline`); 1 = strictly sequential rounds.
    pub pipeline_depth: usize,
    /// TCP reconnects ridden out this iteration (net transport only;
    /// always 0 in-process). Sharded runs sum over shards.
    pub net_reconnects: u64,
    /// Workers the proactive gather abandoned this iteration (they
    /// rejoin next round; see `Event::StragglerAbandoned`).
    pub stragglers: usize,
    /// Chunks the audit decision covered (0 when unaudited; the full
    /// chunk count when the audit was `Full` — the per-worker
    /// selective policies usually cover far fewer).
    pub audited_chunks: usize,
    /// Per-worker suspicion scores, as (worker id, score in [0,1])
    /// pairs ascending by id; workers at exactly 0 are omitted. The
    /// snapshot is the one this iteration's audit decision used
    /// (refreshed after the proactive wave, *before* the audit), with
    /// one exception: workers eliminated or crashed during the
    /// iteration are already cleared. Reliability changes from this
    /// iteration's own audit show up in the next row. See
    /// `coordinator::latency` for how the score is fused from latency
    /// anomaly and reliability.
    pub suspicion: Vec<(WorkerId, f64)>,
    /// Per-shard breakdown (empty for single-master runs).
    pub shard_stats: Vec<ShardStat>,
}

impl IterationRecord {
    pub fn efficiency(&self) -> f64 {
        if self.gradients_computed == 0 {
            1.0
        } else {
            self.gradients_used as f64 / self.gradients_computed as f64
        }
    }
}

/// Whole-run metrics.
#[derive(Clone, Debug, Default)]
pub struct TrainMetrics {
    pub iterations: Vec<IterationRecord>,
}

impl TrainMetrics {
    pub fn push(&mut self, rec: IterationRecord) {
        self.iterations.push(rec);
    }

    /// Mean of the per-iteration efficiencies — the quantity whose
    /// expectation Eq. (2) lower-bounds ("expected computation
    /// efficiency" is per-iteration in the paper's analysis).
    pub fn mean_iteration_efficiency(&self) -> f64 {
        if self.iterations.is_empty() {
            return 1.0;
        }
        self.iterations.iter().map(|r| r.efficiency()).sum::<f64>()
            / self.iterations.len() as f64
    }

    /// Average efficiency = Σ used / Σ computed (ratio of sums, which is
    /// what Definition 2 yields over a whole run).
    pub fn average_efficiency(&self) -> f64 {
        let used: u64 = self.iterations.iter().map(|r| r.gradients_used).sum();
        let computed: u64 = self.iterations.iter().map(|r| r.gradients_computed).sum();
        if computed == 0 {
            1.0
        } else {
            used as f64 / computed as f64
        }
    }

    pub fn faulty_update_rate(&self) -> f64 {
        if self.iterations.is_empty() {
            return 0.0;
        }
        self.iterations.iter().filter(|r| r.oracle_faulty_update).count() as f64
            / self.iterations.len() as f64
    }

    pub fn audit_rate(&self) -> f64 {
        if self.iterations.is_empty() {
            return 0.0;
        }
        self.iterations.iter().filter(|r| r.audited).count() as f64
            / self.iterations.len() as f64
    }

    pub fn final_loss(&self) -> f32 {
        self.iterations.last().map(|r| r.loss).unwrap_or(f32::NAN)
    }

    pub fn losses(&self) -> Vec<f32> {
        self.iterations.iter().map(|r| r.loss).collect()
    }

    /// Mean per-iteration round duration on the transport clock (ns).
    pub fn mean_round_ns(&self) -> f64 {
        if self.iterations.is_empty() {
            return 0.0;
        }
        self.iterations.iter().map(|r| r.round_ns as f64).sum::<f64>()
            / self.iterations.len() as f64
    }

    /// The most-suspect worker of the final iteration, if any worker's
    /// suspicion is above zero (the run-summary line).
    pub fn top_suspect(&self) -> Option<(WorkerId, f64)> {
        self.iterations.last().and_then(|r| {
            r.suspicion
                .iter()
                .copied()
                .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal))
        })
    }

    /// CSV dump for EXPERIMENTS.md plots. `round_time` is the round
    /// duration in ns on the transport clock (virtual under sim); the
    /// `suspicion` column serializes the per-worker scores as
    /// `worker:score` pairs joined by `;`. Every column is documented
    /// in `docs/METRICS.md`.
    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "iter,loss,efficiency,used,computed,audited,q,lambda,faults,identified,crashed,stragglers,faulty_update,dist_to_opt,round_time,shards,audited_chunks,suspicion,bytes_round,pipeline_depth,net_reconnects\n",
        );
        for r in &self.iterations {
            let suspicion = r
                .suspicion
                .iter()
                .map(|(w, v)| format!("{w}:{v:.3}"))
                .collect::<Vec<_>>()
                .join(";");
            s.push_str(&format!(
                "{},{},{:.6},{},{},{},{:.4},{:.4},{},{},{},{},{},{},{},{},{},{},{},{},{}\n",
                r.iter,
                r.loss,
                r.efficiency(),
                r.gradients_used,
                r.gradients_computed,
                r.audited as u8,
                r.q,
                r.lambda,
                r.faults_detected,
                r.identified,
                r.crashed,
                r.stragglers,
                r.oracle_faulty_update as u8,
                r.dist_to_opt.map(|d| d.to_string()).unwrap_or_default(),
                r.round_ns,
                r.shard_stats.len(), // 0 = single-master run
                r.audited_chunks,
                suspicion,
                r.bytes_round,
                r.pipeline_depth,
                r.net_reconnects,
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(used: u64, computed: u64, faulty: bool) -> IterationRecord {
        IterationRecord {
            gradients_used: used,
            gradients_computed: computed,
            oracle_faulty_update: faulty,
            ..Default::default()
        }
    }

    #[test]
    fn efficiency_per_iteration_and_average() {
        let mut m = TrainMetrics::default();
        m.push(rec(64, 64, false)); // unaudited: efficiency 1
        m.push(rec(64, 192, false)); // audited, f=1: 1/3
        assert!((m.iterations[0].efficiency() - 1.0).abs() < 1e-12);
        assert!((m.iterations[1].efficiency() - 1.0 / 3.0).abs() < 1e-12);
        assert!((m.average_efficiency() - 128.0 / 256.0).abs() < 1e-12);
    }

    #[test]
    fn rates() {
        let mut m = TrainMetrics::default();
        m.push(rec(1, 1, true));
        m.push(rec(1, 1, false));
        m.push(rec(1, 1, false));
        m.push(rec(1, 1, true));
        assert!((m.faulty_update_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut m = TrainMetrics::default();
        m.push(rec(1, 2, false));
        let csv = m.to_csv();
        assert!(csv.starts_with("iter,loss"));
        assert!(csv.lines().next().unwrap().contains("round_time"));
        assert!(csv
            .lines()
            .next()
            .unwrap()
            .ends_with("audited_chunks,suspicion,bytes_round,pipeline_depth,net_reconnects"));
        assert_eq!(csv.lines().count(), 2);
        // every row has as many cells as the header
        let cols = csv.lines().next().unwrap().split(',').count();
        assert_eq!(csv.lines().nth(1).unwrap().split(',').count(), cols);
    }

    #[test]
    fn suspicion_column_serializes_per_worker_scores() {
        let mut m = TrainMetrics::default();
        let mut r = rec(1, 1, false);
        r.suspicion = vec![(3, 0.5), (7, 1.0)];
        r.audited_chunks = 2;
        r.bytes_round = 512;
        r.pipeline_depth = 2;
        r.net_reconnects = 1;
        m.push(r);
        let csv = m.to_csv();
        let row = csv.lines().nth(1).unwrap();
        assert!(row.ends_with(",2,3:0.500;7:1.000,512,2,1"), "row: {row}");
        assert_eq!(m.top_suspect(), Some((7, 1.0)));
        // empty suspicion: empty cell, no phantom suspect
        let mut m = TrainMetrics::default();
        m.push(rec(1, 1, false));
        assert!(m.to_csv().lines().nth(1).unwrap().ends_with(",0,,0,0,0"));
        assert_eq!(m.top_suspect(), None);
    }

    #[test]
    fn mean_round_time_over_iterations() {
        let mut m = TrainMetrics::default();
        assert_eq!(m.mean_round_ns(), 0.0);
        let mut a = rec(1, 1, false);
        a.round_ns = 1_000;
        let mut b = rec(1, 1, false);
        b.round_ns = 3_000;
        m.push(a);
        m.push(b);
        assert!((m.mean_round_ns() - 2_000.0).abs() < 1e-9);
    }
}
