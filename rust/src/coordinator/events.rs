//! Structured event log: everything notable the master does, kept as
//! data so tests and benches can assert on protocol behaviour instead
//! of scraping log lines.

use super::{ChunkId, WorkerId};
use crate::util::json::{Json, JsonError};

#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// Audit decision for an iteration (q used, and whether it fired).
    AuditDecision { iter: u64, q: f64, audited: bool },
    /// Replication comparison found disagreeing copies on a chunk.
    FaultDetected { iter: u64, chunk: ChunkId, owners: Vec<WorkerId> },
    /// Reactive redundancy imposed: chunk extended to 2f_t+1 owners.
    ReactiveRedundancy { iter: u64, chunk: ChunkId, added: Vec<WorkerId> },
    /// Majority vote identified Byzantine workers.
    Identified { iter: u64, workers: Vec<WorkerId> },
    /// Worker eliminated from subsequent iterations.
    Eliminated { iter: u64, worker: WorkerId },
    /// Worker crash-stopped (sim transport scenarios): retired from
    /// the active set without being *identified* — crashing is not
    /// lying, so it does not consume the Byzantine budget.
    WorkerCrashed { iter: u64, worker: WorkerId },
    /// The proactive quorum/deadline gather stopped waiting for this
    /// worker: its chunks were reassigned like a crashed worker's and
    /// its late delivery is drained, but it rejoins next round. The
    /// raw material for latency-aware audit policies.
    StragglerAbandoned { iter: u64, worker: WorkerId },
    /// A worker's fused suspicion score (latency anomaly blended with
    /// its reliability deficit — see `coordinator::latency`) moved
    /// materially. Emitted once per material change, not per round, so
    /// the log stays bounded; the latest event per worker is its
    /// current score.
    SuspicionUpdated { iter: u64, worker: WorkerId, suspicion: f64 },
    /// A faulty gradient slipped into the update (oracle knowledge —
    /// only the simulator can emit this, never the real master).
    OracleFaultyUpdate { iter: u64 },
    /// Shard-scoped protocol event (sharded runs): the inner event's
    /// worker and chunk ids are already remapped to the global roster,
    /// so the flat queries below see through the wrapper.
    Shard { shard: usize, inner: Box<Event> },
    /// An entire shard lost its last worker: the parameter server
    /// marked it dead and reassigned its chunks to surviving shards.
    ShardDead { iter: u64, shard: usize },
    /// A shard-local elimination was published to the parameter
    /// server's global roster (the liar can never rejoin anywhere).
    RosterEliminated { iter: u64, shard: usize, worker: WorkerId },
    /// A worker's TCP connection dropped and was re-established (net
    /// transport only; a reconnect that *fails* its retry budget
    /// surfaces as [`Event::WorkerCrashed`] instead).
    NetReconnect { iter: u64, worker: WorkerId },
}

fn ev_obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn ev_num(j: &Json, key: &str) -> Result<f64, JsonError> {
    j.req(key)?
        .as_f64()
        .ok_or_else(|| JsonError(format!("field '{key}' is not a number")))
}

fn ev_workers(j: &Json, key: &str) -> Result<Vec<WorkerId>, JsonError> {
    j.req_arr(key)?
        .iter()
        .map(|v| {
            v.as_usize()
                .ok_or_else(|| JsonError(format!("'{key}' element is not a worker id")))
        })
        .collect()
}

fn workers_json(ws: &[WorkerId]) -> Json {
    Json::Arr(ws.iter().map(|&w| Json::Num(w as f64)).collect())
}

impl Event {
    /// Copy of the event with every worker id passed through `f`
    /// (chunk ids untouched). The shard layer and the trace recorder
    /// use this to remap core-local ids onto the global roster;
    /// [`Event::Shard`] recurses.
    pub fn map_workers(&self, f: &mut dyn FnMut(WorkerId) -> WorkerId) -> Event {
        let map = |ws: &[WorkerId], f: &mut dyn FnMut(WorkerId) -> WorkerId| {
            ws.iter().map(|&w| f(w)).collect::<Vec<_>>()
        };
        match self {
            Event::AuditDecision { .. } | Event::OracleFaultyUpdate { .. } => self.clone(),
            Event::FaultDetected { iter, chunk, owners } => {
                Event::FaultDetected { iter: *iter, chunk: *chunk, owners: map(owners, f) }
            }
            Event::ReactiveRedundancy { iter, chunk, added } => {
                Event::ReactiveRedundancy { iter: *iter, chunk: *chunk, added: map(added, f) }
            }
            Event::Identified { iter, workers } => {
                Event::Identified { iter: *iter, workers: map(workers, f) }
            }
            Event::Eliminated { iter, worker } => {
                Event::Eliminated { iter: *iter, worker: f(*worker) }
            }
            Event::WorkerCrashed { iter, worker } => {
                Event::WorkerCrashed { iter: *iter, worker: f(*worker) }
            }
            Event::StragglerAbandoned { iter, worker } => {
                Event::StragglerAbandoned { iter: *iter, worker: f(*worker) }
            }
            Event::SuspicionUpdated { iter, worker, suspicion } => Event::SuspicionUpdated {
                iter: *iter,
                worker: f(*worker),
                suspicion: *suspicion,
            },
            Event::Shard { shard, inner } => {
                Event::Shard { shard: *shard, inner: Box::new(inner.map_workers(f)) }
            }
            Event::ShardDead { .. } => self.clone(),
            Event::RosterEliminated { iter, shard, worker } => {
                Event::RosterEliminated { iter: *iter, shard: *shard, worker: f(*worker) }
            }
            Event::NetReconnect { iter, worker } => {
                Event::NetReconnect { iter: *iter, worker: f(*worker) }
            }
        }
    }

    /// JSON representation with a `"type"` discriminant — the JSONL
    /// export schema (`--events`; documented in `docs/TRACING.md`).
    /// Inverse of [`Event::from_json`].
    pub fn to_json(&self) -> Json {
        let n = |v: u64| Json::Num(v as f64);
        let nu = |v: usize| Json::Num(v as f64);
        match self {
            Event::AuditDecision { iter, q, audited } => ev_obj(vec![
                ("type", Json::Str("audit_decision".into())),
                ("iter", n(*iter)),
                ("q", Json::Num(*q)),
                ("audited", Json::Bool(*audited)),
            ]),
            Event::FaultDetected { iter, chunk, owners } => ev_obj(vec![
                ("type", Json::Str("fault_detected".into())),
                ("iter", n(*iter)),
                ("chunk", nu(*chunk)),
                ("owners", workers_json(owners)),
            ]),
            Event::ReactiveRedundancy { iter, chunk, added } => ev_obj(vec![
                ("type", Json::Str("reactive_redundancy".into())),
                ("iter", n(*iter)),
                ("chunk", nu(*chunk)),
                ("added", workers_json(added)),
            ]),
            Event::Identified { iter, workers } => ev_obj(vec![
                ("type", Json::Str("identified".into())),
                ("iter", n(*iter)),
                ("workers", workers_json(workers)),
            ]),
            Event::Eliminated { iter, worker } => ev_obj(vec![
                ("type", Json::Str("eliminated".into())),
                ("iter", n(*iter)),
                ("worker", nu(*worker)),
            ]),
            Event::WorkerCrashed { iter, worker } => ev_obj(vec![
                ("type", Json::Str("worker_crashed".into())),
                ("iter", n(*iter)),
                ("worker", nu(*worker)),
            ]),
            Event::StragglerAbandoned { iter, worker } => ev_obj(vec![
                ("type", Json::Str("straggler_abandoned".into())),
                ("iter", n(*iter)),
                ("worker", nu(*worker)),
            ]),
            Event::SuspicionUpdated { iter, worker, suspicion } => ev_obj(vec![
                ("type", Json::Str("suspicion_updated".into())),
                ("iter", n(*iter)),
                ("worker", nu(*worker)),
                ("suspicion", Json::Num(*suspicion)),
            ]),
            Event::OracleFaultyUpdate { iter } => ev_obj(vec![
                ("type", Json::Str("oracle_faulty_update".into())),
                ("iter", n(*iter)),
            ]),
            Event::Shard { shard, inner } => ev_obj(vec![
                ("type", Json::Str("shard".into())),
                ("shard", nu(*shard)),
                ("inner", inner.to_json()),
            ]),
            Event::ShardDead { iter, shard } => ev_obj(vec![
                ("type", Json::Str("shard_dead".into())),
                ("iter", n(*iter)),
                ("shard", nu(*shard)),
            ]),
            Event::RosterEliminated { iter, shard, worker } => ev_obj(vec![
                ("type", Json::Str("roster_eliminated".into())),
                ("iter", n(*iter)),
                ("shard", nu(*shard)),
                ("worker", nu(*worker)),
            ]),
            Event::NetReconnect { iter, worker } => ev_obj(vec![
                ("type", Json::Str("net_reconnect".into())),
                ("iter", n(*iter)),
                ("worker", nu(*worker)),
            ]),
        }
    }

    /// Parse an event from its [`Event::to_json`] representation.
    pub fn from_json(j: &Json) -> Result<Event, JsonError> {
        let iter = |j: &Json| ev_num(j, "iter").map(|v| v as u64);
        let worker = |j: &Json| ev_num(j, "worker").map(|v| v as WorkerId);
        let chunk = |j: &Json| ev_num(j, "chunk").map(|v| v as ChunkId);
        let shard = |j: &Json| ev_num(j, "shard").map(|v| v as usize);
        match j.req_str("type")? {
            "audit_decision" => Ok(Event::AuditDecision {
                iter: iter(j)?,
                q: ev_num(j, "q")?,
                audited: j
                    .req("audited")?
                    .as_bool()
                    .ok_or_else(|| JsonError("field 'audited' is not a bool".into()))?,
            }),
            "fault_detected" => Ok(Event::FaultDetected {
                iter: iter(j)?,
                chunk: chunk(j)?,
                owners: ev_workers(j, "owners")?,
            }),
            "reactive_redundancy" => Ok(Event::ReactiveRedundancy {
                iter: iter(j)?,
                chunk: chunk(j)?,
                added: ev_workers(j, "added")?,
            }),
            "identified" => {
                Ok(Event::Identified { iter: iter(j)?, workers: ev_workers(j, "workers")? })
            }
            "eliminated" => Ok(Event::Eliminated { iter: iter(j)?, worker: worker(j)? }),
            "worker_crashed" => Ok(Event::WorkerCrashed { iter: iter(j)?, worker: worker(j)? }),
            "straggler_abandoned" => {
                Ok(Event::StragglerAbandoned { iter: iter(j)?, worker: worker(j)? })
            }
            "suspicion_updated" => Ok(Event::SuspicionUpdated {
                iter: iter(j)?,
                worker: worker(j)?,
                suspicion: ev_num(j, "suspicion")?,
            }),
            "oracle_faulty_update" => Ok(Event::OracleFaultyUpdate { iter: iter(j)? }),
            "shard" => Ok(Event::Shard {
                shard: shard(j)?,
                inner: Box::new(Event::from_json(j.req("inner")?)?),
            }),
            "shard_dead" => Ok(Event::ShardDead { iter: iter(j)?, shard: shard(j)? }),
            "roster_eliminated" => Ok(Event::RosterEliminated {
                iter: iter(j)?,
                shard: shard(j)?,
                worker: worker(j)?,
            }),
            "net_reconnect" => Ok(Event::NetReconnect { iter: iter(j)?, worker: worker(j)? }),
            other => Err(JsonError(format!("unknown event type '{other}'"))),
        }
    }
}

/// Append-only event log.
#[derive(Clone, Debug, Default)]
pub struct EventLog {
    pub events: Vec<Event>,
}

impl EventLog {
    pub fn push(&mut self, e: Event) {
        self.events.push(e);
    }

    /// Events with one level of [`Event::Shard`] wrapping peeled off,
    /// so per-shard protocol events answer the same queries as
    /// single-master ones.
    pub fn flat(&self) -> impl Iterator<Item = &Event> {
        self.events.iter().map(|e| match e {
            Event::Shard { inner, .. } => inner.as_ref(),
            e => e,
        })
    }

    /// Events of one shard (unwrapped). Single-master events have no
    /// shard dimension and are never returned here.
    pub fn shard_events(&self, shard: usize) -> Vec<&Event> {
        self.events
            .iter()
            .filter_map(|e| match e {
                Event::Shard { shard: s, inner } if *s == shard => Some(inner.as_ref()),
                _ => None,
            })
            .collect()
    }

    pub fn count<F: Fn(&Event) -> bool>(&self, pred: F) -> usize {
        self.flat().filter(|e| pred(e)).count()
    }

    pub fn identified_workers(&self) -> Vec<WorkerId> {
        let mut ws: Vec<WorkerId> = self
            .flat()
            .filter_map(|e| match e {
                Event::Identified { workers, .. } => Some(workers.clone()),
                _ => None,
            })
            .flatten()
            .collect();
        ws.sort_unstable();
        ws.dedup();
        ws
    }

    /// Iteration at which a worker was identified (None if never).
    pub fn identification_time(&self, w: WorkerId) -> Option<u64> {
        self.flat().find_map(|e| match e {
            Event::Identified { iter, workers } if workers.contains(&w) => Some(*iter),
            _ => None,
        })
    }

    pub fn audits(&self) -> usize {
        self.count(|e| matches!(e, Event::AuditDecision { audited: true, .. }))
    }

    pub fn detections(&self) -> usize {
        self.count(|e| matches!(e, Event::FaultDetected { .. }))
    }

    pub fn oracle_faulty_updates(&self) -> usize {
        self.count(|e| matches!(e, Event::OracleFaultyUpdate { .. }))
    }

    pub fn crashes(&self) -> usize {
        self.count(|e| matches!(e, Event::WorkerCrashed { .. }))
    }

    /// Straggler abandonments (a worker may appear once per round).
    pub fn stragglers(&self) -> usize {
        self.count(|e| matches!(e, Event::StragglerAbandoned { .. }))
    }

    /// Suspicion-change events, in emission order.
    pub fn suspicion_updates(&self) -> Vec<(u64, WorkerId, f64)> {
        self.flat()
            .filter_map(|e| match e {
                Event::SuspicionUpdated { iter, worker, suspicion } => {
                    Some((*iter, *worker, *suspicion))
                }
                _ => None,
            })
            .collect()
    }

    /// A worker's most recently reported suspicion (None if never).
    pub fn last_suspicion(&self, w: WorkerId) -> Option<f64> {
        self.flat()
            .filter_map(|e| match e {
                Event::SuspicionUpdated { worker, suspicion, .. } if *worker == w => {
                    Some(*suspicion)
                }
                _ => None,
            })
            .last()
    }

    pub fn dead_shards(&self) -> Vec<usize> {
        let mut ss: Vec<usize> = self
            .events
            .iter()
            .filter_map(|e| match e {
                Event::ShardDead { shard, .. } => Some(*shard),
                _ => None,
            })
            .collect();
        ss.sort_unstable();
        ss.dedup();
        ss
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_queries() {
        let mut log = EventLog::default();
        log.push(Event::AuditDecision { iter: 0, q: 0.5, audited: true });
        log.push(Event::FaultDetected { iter: 0, chunk: 3, owners: vec![1, 2] });
        log.push(Event::Identified { iter: 0, workers: vec![2] });
        log.push(Event::Eliminated { iter: 0, worker: 2 });
        log.push(Event::AuditDecision { iter: 1, q: 0.5, audited: false });
        log.push(Event::Identified { iter: 5, workers: vec![0] });
        log.push(Event::WorkerCrashed { iter: 6, worker: 4 });

        assert_eq!(log.crashes(), 1);
        assert_eq!(log.audits(), 1);
        assert_eq!(log.detections(), 1);
        assert_eq!(log.identified_workers(), vec![0, 2]);
        assert_eq!(log.identification_time(2), Some(0));
        assert_eq!(log.identification_time(0), Some(5));
        assert_eq!(log.identification_time(7), None);
    }

    #[test]
    fn suspicion_queries_see_through_shard_wrapping() {
        let mut log = EventLog::default();
        log.push(Event::SuspicionUpdated { iter: 2, worker: 5, suspicion: 0.25 });
        log.push(Event::Shard {
            shard: 1,
            inner: Box::new(Event::SuspicionUpdated { iter: 4, worker: 5, suspicion: 0.75 }),
        });
        log.push(Event::SuspicionUpdated { iter: 6, worker: 2, suspicion: 0.5 });
        assert_eq!(
            log.suspicion_updates(),
            vec![(2, 5, 0.25), (4, 5, 0.75), (6, 2, 0.5)]
        );
        assert_eq!(log.last_suspicion(5), Some(0.75));
        assert_eq!(log.last_suspicion(2), Some(0.5));
        assert_eq!(log.last_suspicion(9), None);
    }

    #[test]
    fn shard_wrapped_events_answer_flat_queries() {
        let mut log = EventLog::default();
        log.push(Event::Shard {
            shard: 1,
            inner: Box::new(Event::Identified { iter: 3, workers: vec![9] }),
        });
        log.push(Event::Shard {
            shard: 0,
            inner: Box::new(Event::WorkerCrashed { iter: 4, worker: 2 }),
        });
        log.push(Event::ShardDead { iter: 5, shard: 2 });
        log.push(Event::RosterEliminated { iter: 3, shard: 1, worker: 9 });

        assert_eq!(log.identified_workers(), vec![9]);
        assert_eq!(log.identification_time(9), Some(3));
        assert_eq!(log.crashes(), 1);
        assert_eq!(log.dead_shards(), vec![2]);
        assert_eq!(log.shard_events(1).len(), 1);
        assert!(log.shard_events(3).is_empty());
    }

    #[test]
    fn flat_peels_exactly_one_shard_level() {
        // Nothing in the protocol produces nested Shard wrapping, but
        // flat()'s contract is "peel one level" — pin that down.
        let nested = Event::Shard {
            shard: 0,
            inner: Box::new(Event::Shard {
                shard: 1,
                inner: Box::new(Event::Eliminated { iter: 2, worker: 7 }),
            }),
        };
        let mut log = EventLog::default();
        log.push(nested);
        let flattened: Vec<&Event> = log.flat().collect();
        assert_eq!(flattened.len(), 1);
        // One peel leaves the inner Shard wrapper intact...
        assert!(matches!(flattened[0], Event::Shard { shard: 1, .. }));
        // ...so queries that match on leaf variants do NOT see through
        // a double wrap:
        assert_eq!(log.count(|e| matches!(e, Event::Eliminated { .. })), 0);
        // shard_events unwraps the outer level only, and keys on the
        // outer shard id.
        assert_eq!(log.shard_events(0).len(), 1);
        assert!(log.shard_events(1).is_empty());
    }

    #[test]
    fn last_suspicion_is_emission_order_not_iter_order() {
        let mut log = EventLog::default();
        // Sharded pipelined runs can emit a later-iter score before an
        // earlier-iter one; last_suspicion is documented as "most
        // recently reported", i.e. log order.
        log.push(Event::SuspicionUpdated { iter: 9, worker: 3, suspicion: 0.9 });
        log.push(Event::SuspicionUpdated { iter: 4, worker: 3, suspicion: 0.1 });
        assert_eq!(log.last_suspicion(3), Some(0.1));
    }

    #[test]
    fn events_round_trip_through_json() {
        use crate::util::json::Json;
        let all = vec![
            Event::AuditDecision { iter: 0, q: 0.337, audited: true },
            Event::FaultDetected { iter: 1, chunk: 3, owners: vec![1, 2] },
            Event::ReactiveRedundancy { iter: 1, chunk: 3, added: vec![0, 4, 5] },
            Event::Identified { iter: 1, workers: vec![2] },
            Event::Eliminated { iter: 1, worker: 2 },
            Event::WorkerCrashed { iter: 2, worker: 4 },
            Event::StragglerAbandoned { iter: 3, worker: 5 },
            Event::SuspicionUpdated { iter: 4, worker: 5, suspicion: 0.625 },
            Event::OracleFaultyUpdate { iter: 5 },
            Event::Shard {
                shard: 1,
                inner: Box::new(Event::Eliminated { iter: 6, worker: 9 }),
            },
            Event::ShardDead { iter: 7, shard: 2 },
            Event::RosterEliminated { iter: 7, shard: 2, worker: 11 },
            Event::NetReconnect { iter: 8, worker: 6 },
        ];
        for e in &all {
            // Through the value representation...
            assert_eq!(&Event::from_json(&e.to_json()).unwrap(), e);
            // ...and through the serialized text (the JSONL line body).
            let text = e.to_json().to_string();
            let parsed = Json::parse(&text).unwrap();
            assert_eq!(&Event::from_json(&parsed).unwrap(), e, "round-trip of {text}");
        }
        assert!(Event::from_json(&Json::parse("{\"type\":\"nope\"}").unwrap()).is_err());
    }

    #[test]
    fn map_workers_remaps_every_worker_field() {
        let mut bump = |w: WorkerId| w + 100;
        let e = Event::Shard {
            shard: 1,
            inner: Box::new(Event::FaultDetected { iter: 0, chunk: 2, owners: vec![0, 3] }),
        };
        assert_eq!(
            e.map_workers(&mut bump),
            Event::Shard {
                shard: 1,
                inner: Box::new(Event::FaultDetected {
                    iter: 0,
                    chunk: 2,
                    owners: vec![100, 103]
                }),
            }
        );
        let e = Event::SuspicionUpdated { iter: 1, worker: 7, suspicion: 0.5 };
        assert_eq!(
            e.map_workers(&mut bump),
            Event::SuspicionUpdated { iter: 1, worker: 107, suspicion: 0.5 }
        );
        let e = Event::NetReconnect { iter: 3, worker: 2 };
        assert_eq!(e.map_workers(&mut bump), Event::NetReconnect { iter: 3, worker: 102 });
        // Events with no worker dimension pass through unchanged.
        let e = Event::AuditDecision { iter: 2, q: 0.1, audited: false };
        assert_eq!(e.map_workers(&mut bump), e);
    }
}
