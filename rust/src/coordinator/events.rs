//! Structured event log: everything notable the master does, kept as
//! data so tests and benches can assert on protocol behaviour instead
//! of scraping log lines.

use super::{ChunkId, WorkerId};

#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// Audit decision for an iteration (q used, and whether it fired).
    AuditDecision { iter: u64, q: f64, audited: bool },
    /// Replication comparison found disagreeing copies on a chunk.
    FaultDetected { iter: u64, chunk: ChunkId, owners: Vec<WorkerId> },
    /// Reactive redundancy imposed: chunk extended to 2f_t+1 owners.
    ReactiveRedundancy { iter: u64, chunk: ChunkId, added: Vec<WorkerId> },
    /// Majority vote identified Byzantine workers.
    Identified { iter: u64, workers: Vec<WorkerId> },
    /// Worker eliminated from subsequent iterations.
    Eliminated { iter: u64, worker: WorkerId },
    /// Worker crash-stopped (sim transport scenarios): retired from
    /// the active set without being *identified* — crashing is not
    /// lying, so it does not consume the Byzantine budget.
    WorkerCrashed { iter: u64, worker: WorkerId },
    /// The proactive quorum/deadline gather stopped waiting for this
    /// worker: its chunks were reassigned like a crashed worker's and
    /// its late delivery is drained, but it rejoins next round. The
    /// raw material for latency-aware audit policies.
    StragglerAbandoned { iter: u64, worker: WorkerId },
    /// A worker's fused suspicion score (latency anomaly blended with
    /// its reliability deficit — see `coordinator::latency`) moved
    /// materially. Emitted once per material change, not per round, so
    /// the log stays bounded; the latest event per worker is its
    /// current score.
    SuspicionUpdated { iter: u64, worker: WorkerId, suspicion: f64 },
    /// A faulty gradient slipped into the update (oracle knowledge —
    /// only the simulator can emit this, never the real master).
    OracleFaultyUpdate { iter: u64 },
    /// Shard-scoped protocol event (sharded runs): the inner event's
    /// worker and chunk ids are already remapped to the global roster,
    /// so the flat queries below see through the wrapper.
    Shard { shard: usize, inner: Box<Event> },
    /// An entire shard lost its last worker: the parameter server
    /// marked it dead and reassigned its chunks to surviving shards.
    ShardDead { iter: u64, shard: usize },
    /// A shard-local elimination was published to the parameter
    /// server's global roster (the liar can never rejoin anywhere).
    RosterEliminated { iter: u64, shard: usize, worker: WorkerId },
}

/// Append-only event log.
#[derive(Clone, Debug, Default)]
pub struct EventLog {
    pub events: Vec<Event>,
}

impl EventLog {
    pub fn push(&mut self, e: Event) {
        self.events.push(e);
    }

    /// Events with one level of [`Event::Shard`] wrapping peeled off,
    /// so per-shard protocol events answer the same queries as
    /// single-master ones.
    pub fn flat(&self) -> impl Iterator<Item = &Event> {
        self.events.iter().map(|e| match e {
            Event::Shard { inner, .. } => inner.as_ref(),
            e => e,
        })
    }

    /// Events of one shard (unwrapped). Single-master events have no
    /// shard dimension and are never returned here.
    pub fn shard_events(&self, shard: usize) -> Vec<&Event> {
        self.events
            .iter()
            .filter_map(|e| match e {
                Event::Shard { shard: s, inner } if *s == shard => Some(inner.as_ref()),
                _ => None,
            })
            .collect()
    }

    pub fn count<F: Fn(&Event) -> bool>(&self, pred: F) -> usize {
        self.flat().filter(|e| pred(e)).count()
    }

    pub fn identified_workers(&self) -> Vec<WorkerId> {
        let mut ws: Vec<WorkerId> = self
            .flat()
            .filter_map(|e| match e {
                Event::Identified { workers, .. } => Some(workers.clone()),
                _ => None,
            })
            .flatten()
            .collect();
        ws.sort_unstable();
        ws.dedup();
        ws
    }

    /// Iteration at which a worker was identified (None if never).
    pub fn identification_time(&self, w: WorkerId) -> Option<u64> {
        self.flat().find_map(|e| match e {
            Event::Identified { iter, workers } if workers.contains(&w) => Some(*iter),
            _ => None,
        })
    }

    pub fn audits(&self) -> usize {
        self.count(|e| matches!(e, Event::AuditDecision { audited: true, .. }))
    }

    pub fn detections(&self) -> usize {
        self.count(|e| matches!(e, Event::FaultDetected { .. }))
    }

    pub fn oracle_faulty_updates(&self) -> usize {
        self.count(|e| matches!(e, Event::OracleFaultyUpdate { .. }))
    }

    pub fn crashes(&self) -> usize {
        self.count(|e| matches!(e, Event::WorkerCrashed { .. }))
    }

    /// Straggler abandonments (a worker may appear once per round).
    pub fn stragglers(&self) -> usize {
        self.count(|e| matches!(e, Event::StragglerAbandoned { .. }))
    }

    /// Suspicion-change events, in emission order.
    pub fn suspicion_updates(&self) -> Vec<(u64, WorkerId, f64)> {
        self.flat()
            .filter_map(|e| match e {
                Event::SuspicionUpdated { iter, worker, suspicion } => {
                    Some((*iter, *worker, *suspicion))
                }
                _ => None,
            })
            .collect()
    }

    /// A worker's most recently reported suspicion (None if never).
    pub fn last_suspicion(&self, w: WorkerId) -> Option<f64> {
        self.flat()
            .filter_map(|e| match e {
                Event::SuspicionUpdated { worker, suspicion, .. } if *worker == w => {
                    Some(*suspicion)
                }
                _ => None,
            })
            .last()
    }

    pub fn dead_shards(&self) -> Vec<usize> {
        let mut ss: Vec<usize> = self
            .events
            .iter()
            .filter_map(|e| match e {
                Event::ShardDead { shard, .. } => Some(*shard),
                _ => None,
            })
            .collect();
        ss.sort_unstable();
        ss.dedup();
        ss
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_queries() {
        let mut log = EventLog::default();
        log.push(Event::AuditDecision { iter: 0, q: 0.5, audited: true });
        log.push(Event::FaultDetected { iter: 0, chunk: 3, owners: vec![1, 2] });
        log.push(Event::Identified { iter: 0, workers: vec![2] });
        log.push(Event::Eliminated { iter: 0, worker: 2 });
        log.push(Event::AuditDecision { iter: 1, q: 0.5, audited: false });
        log.push(Event::Identified { iter: 5, workers: vec![0] });
        log.push(Event::WorkerCrashed { iter: 6, worker: 4 });

        assert_eq!(log.crashes(), 1);
        assert_eq!(log.audits(), 1);
        assert_eq!(log.detections(), 1);
        assert_eq!(log.identified_workers(), vec![0, 2]);
        assert_eq!(log.identification_time(2), Some(0));
        assert_eq!(log.identification_time(0), Some(5));
        assert_eq!(log.identification_time(7), None);
    }

    #[test]
    fn suspicion_queries_see_through_shard_wrapping() {
        let mut log = EventLog::default();
        log.push(Event::SuspicionUpdated { iter: 2, worker: 5, suspicion: 0.25 });
        log.push(Event::Shard {
            shard: 1,
            inner: Box::new(Event::SuspicionUpdated { iter: 4, worker: 5, suspicion: 0.75 }),
        });
        log.push(Event::SuspicionUpdated { iter: 6, worker: 2, suspicion: 0.5 });
        assert_eq!(
            log.suspicion_updates(),
            vec![(2, 5, 0.25), (4, 5, 0.75), (6, 2, 0.5)]
        );
        assert_eq!(log.last_suspicion(5), Some(0.75));
        assert_eq!(log.last_suspicion(2), Some(0.5));
        assert_eq!(log.last_suspicion(9), None);
    }

    #[test]
    fn shard_wrapped_events_answer_flat_queries() {
        let mut log = EventLog::default();
        log.push(Event::Shard {
            shard: 1,
            inner: Box::new(Event::Identified { iter: 3, workers: vec![9] }),
        });
        log.push(Event::Shard {
            shard: 0,
            inner: Box::new(Event::WorkerCrashed { iter: 4, worker: 2 }),
        });
        log.push(Event::ShardDead { iter: 5, shard: 2 });
        log.push(Event::RosterEliminated { iter: 3, shard: 1, worker: 9 });

        assert_eq!(log.identified_workers(), vec![9]);
        assert_eq!(log.identification_time(9), Some(3));
        assert_eq!(log.crashes(), 1);
        assert_eq!(log.dead_shards(), vec![2]);
        assert_eq!(log.shard_events(1).len(), 1);
        assert!(log.shard_events(3).is_empty());
    }
}
