//! Structured event log: everything notable the master does, kept as
//! data so tests and benches can assert on protocol behaviour instead
//! of scraping log lines.

use super::{ChunkId, WorkerId};

#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// Audit decision for an iteration (q used, and whether it fired).
    AuditDecision { iter: u64, q: f64, audited: bool },
    /// Replication comparison found disagreeing copies on a chunk.
    FaultDetected { iter: u64, chunk: ChunkId, owners: Vec<WorkerId> },
    /// Reactive redundancy imposed: chunk extended to 2f_t+1 owners.
    ReactiveRedundancy { iter: u64, chunk: ChunkId, added: Vec<WorkerId> },
    /// Majority vote identified Byzantine workers.
    Identified { iter: u64, workers: Vec<WorkerId> },
    /// Worker eliminated from subsequent iterations.
    Eliminated { iter: u64, worker: WorkerId },
    /// Worker crash-stopped (sim transport scenarios): retired from
    /// the active set without being *identified* — crashing is not
    /// lying, so it does not consume the Byzantine budget.
    WorkerCrashed { iter: u64, worker: WorkerId },
    /// A faulty gradient slipped into the update (oracle knowledge —
    /// only the simulator can emit this, never the real master).
    OracleFaultyUpdate { iter: u64 },
}

/// Append-only event log.
#[derive(Clone, Debug, Default)]
pub struct EventLog {
    pub events: Vec<Event>,
}

impl EventLog {
    pub fn push(&mut self, e: Event) {
        self.events.push(e);
    }

    pub fn count<F: Fn(&Event) -> bool>(&self, pred: F) -> usize {
        self.events.iter().filter(|e| pred(e)).count()
    }

    pub fn identified_workers(&self) -> Vec<WorkerId> {
        let mut ws: Vec<WorkerId> = self
            .events
            .iter()
            .filter_map(|e| match e {
                Event::Identified { workers, .. } => Some(workers.clone()),
                _ => None,
            })
            .flatten()
            .collect();
        ws.sort_unstable();
        ws.dedup();
        ws
    }

    /// Iteration at which a worker was identified (None if never).
    pub fn identification_time(&self, w: WorkerId) -> Option<u64> {
        self.events.iter().find_map(|e| match e {
            Event::Identified { iter, workers } if workers.contains(&w) => Some(*iter),
            _ => None,
        })
    }

    pub fn audits(&self) -> usize {
        self.count(|e| matches!(e, Event::AuditDecision { audited: true, .. }))
    }

    pub fn detections(&self) -> usize {
        self.count(|e| matches!(e, Event::FaultDetected { .. }))
    }

    pub fn oracle_faulty_updates(&self) -> usize {
        self.count(|e| matches!(e, Event::OracleFaultyUpdate { .. }))
    }

    pub fn crashes(&self) -> usize {
        self.count(|e| matches!(e, Event::WorkerCrashed { .. }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_queries() {
        let mut log = EventLog::default();
        log.push(Event::AuditDecision { iter: 0, q: 0.5, audited: true });
        log.push(Event::FaultDetected { iter: 0, chunk: 3, owners: vec![1, 2] });
        log.push(Event::Identified { iter: 0, workers: vec![2] });
        log.push(Event::Eliminated { iter: 0, worker: 2 });
        log.push(Event::AuditDecision { iter: 1, q: 0.5, audited: false });
        log.push(Event::Identified { iter: 5, workers: vec![0] });
        log.push(Event::WorkerCrashed { iter: 6, worker: 4 });

        assert_eq!(log.crashes(), 1);
        assert_eq!(log.audits(), 1);
        assert_eq!(log.detections(), 1);
        assert_eq!(log.identified_workers(), vec![0, 2]);
        assert_eq!(log.identification_time(2), Some(0));
        assert_eq!(log.identification_time(0), Some(5));
        assert_eq!(log.identification_time(7), None);
    }
}
