//! Byzantine identification by majority vote over 2f_t+1 symbol copies
//! (§4.1): with at most f_t liars among the owners, at least f_t+1
//! copies are honest and bit-identical, so the plurality value with
//! count >= f_t+1 is the true gradient; every owner whose copy differs
//! from it provably lied.

use std::collections::HashMap;

use super::codes::{copy_key, SymbolCopy};
use super::{WorkerId, MASTER_SENTINEL};

/// Outcome of a majority vote on one chunk.
#[derive(Clone, Debug)]
pub struct VoteOutcome {
    /// The recovered true gradient and loss.
    pub grad: Vec<f32>,
    pub loss: f32,
    /// Wire bytes of the recovered symbol (compressed runs only).
    pub wire: Option<Vec<u8>>,
    /// Owners whose copy differed from the majority — identified
    /// Byzantine workers.
    pub liars: Vec<WorkerId>,
}

/// Majority vote over copies; `f_t` is the current Byzantine budget.
///
/// Precondition (checked): `copies.len() >= 2 * f_t + 1` with distinct
/// workers. Returns `None` if no value reaches the f_t+1 quorum, which
/// is impossible under the precondition when at most f_t owners lie —
/// hitting it in practice means the caller violated the protocol.
pub fn majority_vote(copies: &[SymbolCopy], f_t: usize) -> Option<VoteOutcome> {
    assert!(
        copies.len() >= 2 * f_t + 1,
        "majority vote needs 2f_t+1 = {} copies, got {}",
        2 * f_t + 1,
        copies.len()
    );
    debug_assert!(
        {
            let mut ws: Vec<WorkerId> = copies.iter().map(|c| c.worker).collect();
            ws.sort_unstable();
            ws.dedup();
            ws.len() == copies.len()
        },
        "duplicate workers in vote"
    );
    // group by exact symbol bits — packed wire bytes when the symbol
    // travelled compressed, dense gradient bits otherwise; hash each
    // copy once (perf: the hash dominates at large d, see
    // EXPERIMENTS.md §Perf)
    let keys: Vec<u64> = copies.iter().map(copy_key).collect();
    let mut groups: HashMap<u64, Vec<usize>> = HashMap::with_capacity(copies.len());
    for (i, &k) in keys.iter().enumerate() {
        groups.entry(k).or_default().push(i);
    }
    let (majority_key, members) = groups
        .into_iter()
        .max_by_key(|(_, members)| members.len())?;
    if members.len() < f_t + 1 {
        return None; // protocol violation: no quorum
    }
    let majority_idx = members[0];
    Some(VoteOutcome {
        grad: copies[majority_idx].grad.clone(),
        loss: copies[majority_idx].loss,
        wire: copies[majority_idx].wire.clone(),
        // the master's own copies (MASTER_SENTINEL) are trusted by
        // definition and can never be named liars — defensive: the
        // protocol should not mix sentinel copies into votes, but a
        // policy bug must not "identify" the master
        liars: copies
            .iter()
            .enumerate()
            .filter(|(i, c)| keys[*i] != majority_key && c.worker != MASTER_SENTINEL)
            .map(|(_, c)| c.worker)
            .collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sym(w: WorkerId, g: Vec<f32>) -> SymbolCopy {
        SymbolCopy { worker: w, grad: g, loss: 1.0, wire: None }
    }

    #[test]
    fn honest_majority_recovers_and_identifies() {
        let truth = vec![1.5f32, -2.0, 0.25];
        let copies = vec![
            sym(0, truth.clone()),
            sym(1, vec![9.0, 9.0, 9.0]), // liar
            sym(2, truth.clone()),
            sym(3, truth.clone()),
            sym(4, vec![-1.0, 0.0, 0.0]), // liar
        ];
        let out = majority_vote(&copies, 2).unwrap();
        assert_eq!(out.grad, truth);
        assert_eq!(out.liars, vec![1, 4]);
    }

    #[test]
    fn all_honest_no_liars() {
        let truth = vec![0.5f32; 4];
        let copies: Vec<_> = (0..5).map(|w| sym(w, truth.clone())).collect();
        let out = majority_vote(&copies, 2).unwrap();
        assert_eq!(out.grad, truth);
        assert!(out.liars.is_empty());
    }

    #[test]
    fn colluding_minority_cannot_win() {
        // f_t = 2 liars send the SAME forged value; 3 honest still win
        let truth = vec![1.0f32, 1.0];
        let forged = vec![5.0f32, 5.0];
        let copies = vec![
            sym(0, forged.clone()),
            sym(1, forged.clone()),
            sym(2, truth.clone()),
            sym(3, truth.clone()),
            sym(4, truth.clone()),
        ];
        let out = majority_vote(&copies, 2).unwrap();
        assert_eq!(out.grad, truth);
        assert_eq!(out.liars, vec![0, 1]);
    }

    #[test]
    #[should_panic(expected = "majority vote needs")]
    fn too_few_copies_panics() {
        let copies = vec![sym(0, vec![1.0]), sym(1, vec![1.0])];
        majority_vote(&copies, 1); // needs 3
    }

    #[test]
    fn quorum_at_exactly_two_f_plus_one() {
        // the minimum copy count: 2f_t+1 with exactly f_t liars means
        // the honest side holds the quorum by exactly one copy
        for f_t in 1..=4usize {
            let truth = vec![0.25f32; 3];
            let copies: Vec<SymbolCopy> = (0..2 * f_t + 1)
                .map(|w| {
                    if w < f_t {
                        sym(w, vec![7.0 + w as f32; 3]) // liars
                    } else {
                        sym(w, truth.clone())
                    }
                })
                .collect();
            let out = majority_vote(&copies, f_t).unwrap();
            assert_eq!(out.grad, truth, "f_t={f_t}");
            assert_eq!(out.liars, (0..f_t).collect::<Vec<_>>(), "f_t={f_t}");
        }
    }

    #[test]
    fn no_quorum_returns_none() {
        // 2f_t+1 copies but every copy distinct: no value reaches the
        // f_t+1 quorum — a protocol violation the caller must surface
        let copies: Vec<SymbolCopy> = (0..5).map(|w| sym(w, vec![w as f32])).collect();
        assert!(majority_vote(&copies, 2).is_none());
    }

    #[test]
    fn master_sentinel_copy_is_never_identified_as_liar() {
        use crate::coordinator::MASTER_SENTINEL;
        let truth = vec![1.0f32, 2.0];
        // a sentinel copy that disagrees with the majority (e.g. a
        // stale self-check copy mixed into a vote) must not be named
        let copies = vec![
            sym(0, truth.clone()),
            sym(1, truth.clone()),
            sym(2, truth.clone()),
            sym(3, vec![9.0, 9.0]),
            sym(MASTER_SENTINEL, vec![8.0, 8.0]),
        ];
        let out = majority_vote(&copies, 2).unwrap();
        assert_eq!(out.grad, truth);
        assert_eq!(out.liars, vec![3], "sentinel must be excluded");
    }

    #[test]
    fn loss_is_part_of_the_vote() {
        // same gradient but lying about the loss is still a lie
        let g = vec![1.0f32];
        let copies = vec![
            SymbolCopy { worker: 0, grad: g.clone(), loss: 1.0, wire: None },
            SymbolCopy { worker: 1, grad: g.clone(), loss: 99.0, wire: None },
            SymbolCopy { worker: 2, grad: g.clone(), loss: 1.0, wire: None },
        ];
        let out = majority_vote(&copies, 1).unwrap();
        assert_eq!(out.liars, vec![1]);
        assert_eq!(out.loss, 1.0);
    }

    #[test]
    fn compressed_copies_vote_on_wire_bytes() {
        // identical dense caches but a tampered wire: the vote must
        // group on the packed representation and catch the liar
        let g = vec![1.0f32, -1.0];
        let wired = |w: WorkerId, wire: Vec<u8>| SymbolCopy {
            worker: w,
            grad: g.clone(),
            loss: 1.0,
            wire: Some(wire),
        };
        let honest = vec![0xAB, 0xCD];
        let copies = vec![
            wired(0, honest.clone()),
            wired(1, vec![0xAB, 0xCE]), // liar: wire differs
            wired(2, honest.clone()),
        ];
        let out = majority_vote(&copies, 1).unwrap();
        assert_eq!(out.liars, vec![1]);
        assert_eq!(out.wire.as_deref(), Some(&honest[..]));
    }
}
