//! Worker compute core: the protocol-visible symbol types and the
//! per-worker state machine that turns (θ, tasks) into gradient
//! symbols.
//!
//! This module is transport-agnostic. The same [`WorkerState`] drives
//! both the one-OS-thread-per-worker pool
//! ([`super::transport::ThreadedTransport`]) and the deterministic
//! virtual-time simulator ([`super::transport::SimTransport`]), which
//! is what makes the two transports bit-identical for the same seed:
//! the gradient, tamper, and compression code paths are literally the
//! same code.
//!
//! Honest workers compute gradient symbols with their engine; Byzantine
//! workers additionally pass them through their attack behaviour. Each
//! symbol carries oracle metadata (`tampered`) that only the metrics
//! layer reads — the master's protocol logic never looks at it.

use std::sync::Arc;

use super::byzantine::ByzantineBehavior;
use super::compress::Compressor;
use super::{ChunkId, WorkerId};
use crate::adversary::AdversaryController;
use crate::data::Batch;
use crate::grad::GradientComputer;
use crate::Result;

/// One computed symbol: the (claimed) mean gradient of a chunk.
#[derive(Clone, Debug)]
pub struct Symbol {
    pub chunk: ChunkId,
    /// Dense gradient. Under a compressor this is the *exact decode*
    /// of `wire` — the value the master aggregates with; the wire
    /// bytes are what detection compares and what the bytes-per-round
    /// accounting counts.
    pub grad: Vec<f32>,
    pub loss: f32,
    /// Oracle flag: was this symbol tampered with? (metrics only)
    pub tampered: bool,
    /// Packed wire bytes (`Some` iff a compressor is configured): the
    /// authoritative transported representation of this symbol.
    pub wire: Option<Vec<u8>>,
}

/// Master -> worker.
pub enum Request {
    Compute {
        iter: u64,
        phase: u32,
        /// Wave id: one per `Transport::submit`, monotone per core.
        /// Pipelined rounds route/drop deliveries by it.
        wave: u64,
        theta: Arc<Vec<f32>>,
        tasks: Vec<(ChunkId, Batch)>,
    },
    Shutdown,
}

/// Worker -> master.
#[derive(Debug)]
pub struct Response {
    pub worker: WorkerId,
    pub iter: u64,
    pub phase: u32,
    /// Echo of the submitting wave id (delivery routing).
    pub wave: u64,
    pub symbols: Vec<Symbol>,
    /// Engine error text, if any (treated as a crash — surfaced loudly).
    pub error: Option<String>,
}

/// A Byzantine worker's line to the coordinated
/// [`AdversaryController`]: the controller decides — from the
/// protocol's public state — whether this worker tampers a given
/// chunk this iteration. `worker` is the *global* id (shard inner
/// transports use local ids, so the handle carries the remap).
#[derive(Clone)]
pub struct AdversaryHandle {
    pub controller: Arc<AdversaryController>,
    pub worker: WorkerId,
}

/// Per-worker compute state, shared by every transport.
pub struct WorkerState {
    pub(crate) id: WorkerId,
    pub(crate) engine: Arc<dyn GradientComputer>,
    pub(crate) byzantine: Option<ByzantineBehavior>,
    /// Coordinated adversary line (replaces the stateless `byzantine`
    /// path when an `--adversary` strategy is configured).
    adversary: Option<AdversaryHandle>,
    /// §2.1/§5: symbols may be compressed gradients; honest compressors
    /// are deterministic so replica comparison still works bit-exactly.
    pub(crate) compressor: Option<Arc<dyn Compressor>>,
    /// Tamper decision is made once per iteration and reused across
    /// phases of the same iteration (§4.2 analysis model).
    tamper_iter: Option<(u64, bool)>,
}

impl WorkerState {
    pub fn new(
        id: WorkerId,
        engine: Arc<dyn GradientComputer>,
        byzantine: Option<ByzantineBehavior>,
        compressor: Option<Arc<dyn Compressor>>,
    ) -> WorkerState {
        WorkerState { id, engine, byzantine, adversary: None, compressor, tamper_iter: None }
    }

    /// Attach a coordinated-adversary line (builder-style; `None` is a
    /// no-op so honest workers can share the construction path).
    pub fn with_adversary(mut self, adversary: Option<AdversaryHandle>) -> WorkerState {
        self.adversary = adversary;
        self
    }

    fn tampering(&mut self, iter: u64) -> bool {
        match self.tamper_iter {
            Some((i, t)) if i == iter => t,
            _ => {
                let t = self
                    .byzantine
                    .as_mut()
                    .map(|b| b.tampers_this_iteration())
                    .unwrap_or(false);
                self.tamper_iter = Some((iter, t));
                t
            }
        }
    }

    /// Compute the symbols for one request. Transport-agnostic: any
    /// latency or failure model is the transport's business.
    pub fn handle(
        &mut self,
        iter: u64,
        theta: &[f32],
        tasks: Vec<(ChunkId, Batch)>,
    ) -> Result<Vec<Symbol>> {
        self.handle_observed(iter, theta, tasks, &|| 0, &mut |_, _, _| {})
    }

    /// [`WorkerState::handle`] with per-chunk compute observation: each
    /// chunk's full loop body (gradient, tamper, compression) is
    /// bracketed by `now_ns` reads and reported through `span` as
    /// `(chunk, start_ns, end_ns)`. The compute path and every RNG
    /// draw are literally the ones `handle` makes — the net worker's
    /// telemetry uses this, and telemetry must never perturb θ.
    pub fn handle_observed(
        &mut self,
        iter: u64,
        theta: &[f32],
        tasks: Vec<(ChunkId, Batch)>,
        now_ns: &dyn Fn() -> u64,
        span: &mut dyn FnMut(ChunkId, u64, u64),
    ) -> Result<Vec<Symbol>> {
        let tamper = self.tampering(iter);
        let mut out = Vec::with_capacity(tasks.len());
        for (chunk, batch) in tasks {
            let t0 = now_ns();
            let g = self
                .engine
                .grad(theta, &batch)
                .map_err(|e| anyhow::anyhow!("worker {} engine error: {e:#}", self.id))?;
            let mut grad = g.grad;
            let mut loss = g.loss;
            let mut tampered = false;
            if let Some(h) = &self.adversary {
                // coordinated path: the controller's round plan decides
                // per (worker, chunk); the lie itself is a pure function
                // of (iteration, chunk), so every colluder pushing this
                // chunk pushes the identical wrong symbol
                let (g0, l0) = (grad.clone(), loss);
                if h.controller.corrupt(h.worker, iter, chunk, &mut grad, &mut loss) {
                    tampered = grad != g0 || loss != l0;
                }
            } else if tamper {
                if let Some(b) = self.byzantine.as_mut() {
                    let (g0, l0) = (grad.clone(), loss);
                    b.corrupt(iter, &mut grad, &mut loss);
                    // oracle flag = *effective* tampering: e.g. a
                    // sign-flip of a bit-zero gradient is still the
                    // zero gradient — numerically a no-op (paper
                    // footnote 2: such a worker "poses no harm")
                    tampered = grad != g0 || loss != l0;
                }
            }
            let mut wire = None;
            if let Some(c) = &self.compressor {
                // pack, then replace the dense gradient with the exact
                // decode of the wire — what the receiver would see
                let d = grad.len();
                let w = c.pack(&grad);
                grad = c.unpack(&w, d);
                wire = Some(w);
            }
            span(chunk, t0, now_ns());
            out.push(Symbol { chunk, grad, loss, tampered, wire });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AttackConfig, AttackKind};
    use crate::data::{Dataset, LinRegDataset};
    use crate::grad::{ModelSpec, NativeEngine};

    fn state(id: WorkerId, byz: bool) -> (WorkerState, LinRegDataset) {
        let ds = LinRegDataset::generate(64, 8, 0.0, 1);
        let engine: Arc<dyn GradientComputer> =
            Arc::new(NativeEngine::new(ModelSpec::LinReg { d: 8, batch: 64 }));
        let behaviour = byz.then(|| {
            ByzantineBehavior::new(
                AttackConfig { kind: AttackKind::SignFlip, p: 1.0, magnitude: 1.0 },
                7,
                id,
            )
        });
        (WorkerState::new(id, engine, behaviour, None), ds)
    }

    #[test]
    fn honest_state_computes_untampered_symbols() {
        let (mut w, ds) = state(0, false);
        let theta = vec![0.1f32; 8];
        let batch = ds.batch(&(0..16).collect::<Vec<_>>());
        let symbols = w.handle(0, &theta, vec![(5, batch)]).unwrap();
        assert_eq!(symbols.len(), 1);
        assert_eq!(symbols[0].chunk, 5);
        assert!(!symbols[0].tampered);
    }

    #[test]
    fn byzantine_state_tampers_every_phase_of_an_iteration() {
        let (mut w, ds) = state(1, true);
        let theta = vec![0.1f32; 8];
        let batch = ds.batch(&(0..16).collect::<Vec<_>>());
        // p = 1.0: tampers in every iteration, consistently across the
        // repeated handle() calls (phases) of that iteration
        for _phase in 0..3 {
            let s = w.handle(7, &theta, vec![(0, batch.clone())]).unwrap();
            assert!(s[0].tampered);
        }
    }
}
