//! Worker pool: one OS thread per worker, synchronous request/response
//! over mpsc channels (the paper's system is synchronous parallelized
//! SGD; tokio is unavailable offline and unnecessary here).
//!
//! Honest workers compute gradient symbols with their engine; Byzantine
//! workers additionally pass them through their attack behaviour. Each
//! symbol carries oracle metadata (`tampered`) that only the metrics
//! layer reads — the master's protocol logic never looks at it.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use super::byzantine::ByzantineBehavior;
use super::compress::Compressor;
use super::{ChunkId, WorkerId};
use crate::data::Batch;
use crate::grad::GradientComputer;
use crate::Result;

/// One computed symbol: the (claimed) mean gradient of a chunk.
#[derive(Clone, Debug)]
pub struct Symbol {
    pub chunk: ChunkId,
    pub grad: Vec<f32>,
    pub loss: f32,
    /// Oracle flag: was this symbol tampered with? (metrics only)
    pub tampered: bool,
}

/// Master -> worker.
pub enum Request {
    Compute {
        iter: u64,
        phase: u32,
        theta: Arc<Vec<f32>>,
        tasks: Vec<(ChunkId, Batch)>,
    },
    Shutdown,
}

/// Worker -> master.
pub struct Response {
    pub worker: WorkerId,
    pub iter: u64,
    pub phase: u32,
    pub symbols: Vec<Symbol>,
    /// Engine error text, if any (treated as a crash — surfaced loudly).
    pub error: Option<String>,
}

struct WorkerState {
    id: WorkerId,
    engine: Arc<dyn GradientComputer>,
    byzantine: Option<ByzantineBehavior>,
    /// §2.1/§5: symbols may be compressed gradients; honest compressors
    /// are deterministic so replica comparison still works bit-exactly.
    compressor: Option<Arc<dyn Compressor>>,
    latency_us: u64,
    /// Tamper decision is made once per iteration and reused across
    /// phases of the same iteration (§4.2 analysis model).
    tamper_iter: Option<(u64, bool)>,
}

impl WorkerState {
    fn tampering(&mut self, iter: u64) -> bool {
        match self.tamper_iter {
            Some((i, t)) if i == iter => t,
            _ => {
                let t = self
                    .byzantine
                    .as_mut()
                    .map(|b| b.tampers_this_iteration())
                    .unwrap_or(false);
                self.tamper_iter = Some((iter, t));
                t
            }
        }
    }

    fn handle(&mut self, iter: u64, theta: &[f32], tasks: Vec<(ChunkId, Batch)>) -> Vec<Symbol> {
        if self.latency_us > 0 {
            std::thread::sleep(std::time::Duration::from_micros(self.latency_us));
        }
        let tamper = self.tampering(iter);
        let mut out = Vec::with_capacity(tasks.len());
        for (chunk, batch) in tasks {
            match self.engine.grad(theta, &batch) {
                Ok(g) => {
                    let mut grad = g.grad;
                    let mut loss = g.loss;
                    let mut tampered = false;
                    if tamper {
                        if let Some(b) = self.byzantine.as_mut() {
                            let (g0, l0) = (grad.clone(), loss);
                            b.corrupt(&mut grad, &mut loss);
                            // oracle flag = *effective* tampering: e.g. a
                            // sign-flip of a bit-zero gradient is still the
                            // zero gradient — numerically a no-op (paper
                            // footnote 2: such a worker "poses no harm")
                            tampered = grad != g0 || loss != l0;
                        }
                    }
                    if let Some(c) = &self.compressor {
                        grad = c.encode(&grad);
                    }
                    out.push(Symbol { chunk, grad, loss, tampered });
                }
                Err(e) => {
                    // surfaced via Response.error by the caller loop
                    panic!("worker {} engine error: {e:#}", self.id);
                }
            }
        }
        out
    }
}

fn byzantine_fn(
    f: &mut impl FnMut(WorkerId) -> Option<ByzantineBehavior>,
) -> impl FnMut(WorkerId) -> Option<ByzantineBehavior> + '_ {
    move |w| f(w)
}

/// Handle to the running pool.
pub struct WorkerPool {
    senders: Vec<Sender<Request>>,
    receiver: Receiver<Response>,
    handles: Vec<JoinHandle<()>>,
    pub n: usize,
}

impl WorkerPool {
    /// Spawn `n` workers. `byzantine(i)` returns the behaviour for
    /// worker i (None = honest). All workers share the engine handle
    /// (engines are Send + Sync; the XLA engine serializes internally).
    pub fn spawn(
        n: usize,
        engine: Arc<dyn GradientComputer>,
        mut byzantine: impl FnMut(WorkerId) -> Option<ByzantineBehavior>,
        latency_us: u64,
    ) -> WorkerPool {
        Self::spawn_with_compressor(n, engine, byzantine_fn(&mut byzantine), None, latency_us)
    }

    /// Spawn with an optional gradient compressor applied to every
    /// outgoing symbol (the §2.1/§5 compressed-gradients generalization).
    pub fn spawn_with_compressor(
        n: usize,
        engine: Arc<dyn GradientComputer>,
        mut byzantine: impl FnMut(WorkerId) -> Option<ByzantineBehavior>,
        compressor: Option<Arc<dyn Compressor>>,
        latency_us: u64,
    ) -> WorkerPool {
        let (resp_tx, resp_rx) = channel::<Response>();
        let mut senders = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for id in 0..n {
            let (req_tx, req_rx) = channel::<Request>();
            senders.push(req_tx);
            let resp_tx = resp_tx.clone();
            let mut state = WorkerState {
                id,
                engine: engine.clone(),
                byzantine: byzantine(id),
                compressor: compressor.clone(),
                latency_us,
                tamper_iter: None,
            };
            handles.push(
                std::thread::Builder::new()
                    .name(format!("r3bft-worker-{id}"))
                    .spawn(move || {
                        while let Ok(req) = req_rx.recv() {
                            match req {
                                Request::Shutdown => break,
                                Request::Compute { iter, phase, theta, tasks } => {
                                    let result = std::panic::catch_unwind(
                                        std::panic::AssertUnwindSafe(|| {
                                            state.handle(iter, &theta, tasks)
                                        }),
                                    );
                                    let resp = match result {
                                        Ok(symbols) => Response {
                                            worker: id,
                                            iter,
                                            phase,
                                            symbols,
                                            error: None,
                                        },
                                        Err(p) => Response {
                                            worker: id,
                                            iter,
                                            phase,
                                            symbols: vec![],
                                            error: Some(
                                                p.downcast_ref::<String>()
                                                    .cloned()
                                                    .unwrap_or_else(|| "worker panicked".into()),
                                            ),
                                        },
                                    };
                                    if resp_tx.send(resp).is_err() {
                                        break; // master gone
                                    }
                                }
                            }
                        }
                    })
                    .expect("spawn worker thread"),
            );
        }
        WorkerPool { senders, receiver: resp_rx, handles, n }
    }

    /// Send a compute request to one worker.
    pub fn send(
        &self,
        w: WorkerId,
        iter: u64,
        phase: u32,
        theta: &Arc<Vec<f32>>,
        tasks: Vec<(ChunkId, Batch)>,
    ) -> Result<()> {
        self.senders[w]
            .send(Request::Compute { iter, phase, theta: theta.clone(), tasks })
            .map_err(|_| anyhow::anyhow!("worker {w} channel closed"))
    }

    /// Collect exactly `expected` responses for (iter, phase).
    pub fn collect(&self, iter: u64, phase: u32, expected: usize) -> Result<Vec<Response>> {
        let mut out = Vec::with_capacity(expected);
        while out.len() < expected {
            let resp = self
                .receiver
                .recv()
                .map_err(|_| anyhow::anyhow!("all workers disconnected"))?;
            if let Some(err) = &resp.error {
                anyhow::bail!("worker {} failed: {err}", resp.worker);
            }
            if resp.iter == iter && resp.phase == phase {
                out.push(resp);
            }
            // responses from other (iter, phase) pairs cannot occur in
            // the synchronous protocol; drop them defensively if they do
        }
        Ok(out)
    }

    pub fn shutdown(self) {
        for s in &self.senders {
            let _ = s.send(Request::Shutdown);
        }
        for h in self.handles {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AttackConfig, AttackKind};
    use crate::data::{Batch, Dataset, LinRegDataset};
    use crate::grad::{ModelSpec, NativeEngine};

    fn pool(n: usize, byz: Vec<WorkerId>) -> (WorkerPool, LinRegDataset) {
        let ds = LinRegDataset::generate(64, 8, 0.0, 1);
        let engine: Arc<dyn GradientComputer> =
            Arc::new(NativeEngine::new(ModelSpec::LinReg { d: 8, batch: 64 }));
        let pool = WorkerPool::spawn(
            n,
            engine,
            |i| {
                byz.contains(&i).then(|| {
                    ByzantineBehavior::new(
                        AttackConfig { kind: AttackKind::SignFlip, p: 1.0, magnitude: 1.0 },
                        7,
                        i,
                    )
                })
            },
            0,
        );
        (pool, ds)
    }

    #[test]
    fn honest_workers_return_identical_symbols() {
        let (pool, ds) = pool(3, vec![]);
        let theta = Arc::new(vec![0.1f32; 8]);
        let batch = ds.batch(&(0..16).collect::<Vec<_>>());
        for w in 0..3 {
            pool.send(w, 0, 0, &theta, vec![(5, batch.clone())]).unwrap();
        }
        let resps = pool.collect(0, 0, 3).unwrap();
        assert_eq!(resps.len(), 3);
        let g0 = &resps[0].symbols[0].grad;
        for r in &resps {
            assert_eq!(r.symbols.len(), 1);
            assert_eq!(r.symbols[0].chunk, 5);
            assert_eq!(&r.symbols[0].grad, g0, "honest symbols must be bit-identical");
            assert!(!r.symbols[0].tampered);
        }
        pool.shutdown();
    }

    #[test]
    fn byzantine_worker_tampers() {
        let (pool, ds) = pool(2, vec![1]);
        let theta = Arc::new(vec![0.1f32; 8]);
        let batch = ds.batch(&(0..16).collect::<Vec<_>>());
        pool.send(0, 0, 0, &theta, vec![(0, batch.clone())]).unwrap();
        pool.send(1, 0, 0, &theta, vec![(0, batch.clone())]).unwrap();
        let resps = pool.collect(0, 0, 2).unwrap();
        let honest = resps.iter().find(|r| r.worker == 0).unwrap();
        let byz = resps.iter().find(|r| r.worker == 1).unwrap();
        assert!(byz.symbols[0].tampered);
        assert_ne!(honest.symbols[0].grad, byz.symbols[0].grad);
        pool.shutdown();
    }

    #[test]
    fn tamper_decision_is_per_iteration() {
        // p = 1.0 means tampering in EVERY iteration, across phases
        let (pool, ds) = pool(1, vec![0]);
        let theta = Arc::new(vec![0.1f32; 8]);
        let batch = ds.batch(&(0..16).collect::<Vec<_>>());
        for phase in 0..3u32 {
            pool.send(0, 7, phase, &theta, vec![(0, batch.clone())]).unwrap();
            let r = pool.collect(7, phase, 1).unwrap();
            assert!(r[0].symbols[0].tampered, "phase {phase}");
        }
        pool.shutdown();
    }

    #[test]
    fn multiple_chunks_per_request() {
        let (pool, ds) = pool(1, vec![]);
        let theta = Arc::new(vec![0.0f32; 8]);
        let b1 = ds.batch(&(0..8).collect::<Vec<_>>());
        let b2 = ds.batch(&(8..16).collect::<Vec<_>>());
        pool.send(0, 0, 0, &theta, vec![(0, b1), (1, b2)]).unwrap();
        let r = pool.collect(0, 0, 1).unwrap();
        assert_eq!(r[0].symbols.len(), 2);
        assert_ne!(r[0].symbols[0].grad, r[0].symbols[1].grad);
        pool.shutdown();
    }
}
