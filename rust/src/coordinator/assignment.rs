//! Data-point -> worker assignment with proactive and reactive
//! replication (§4.1).
//!
//! Each iteration the master samples m = nchunks * chunk_size data
//! points, partitions them into `nchunks` equal chunks (one per active
//! worker), and assigns chunk j to workers j, j+1, ..., j+r-1 (mod
//! nactive) — cyclic replication, so every worker owns exactly r
//! chunks and every chunk has r distinct owners. Reactive redundancy
//! later extends individual chunks to more owners, skipping workers
//! that already own them.

use crate::coordinator::{ChunkId, WorkerId};
use crate::util::rng::Pcg64;

/// One iteration's assignment state.
#[derive(Clone, Debug, Default)]
pub struct Assignment {
    /// chunk -> data-point ids (all chunks equal size).
    pub chunks: Vec<Vec<usize>>,
    /// chunk -> owning workers, in assignment order.
    pub owners: Vec<Vec<WorkerId>>,
    /// Active workers this iteration (indices into the global pool).
    pub active: Vec<WorkerId>,
}

impl Assignment {
    /// Build the proactive assignment.
    ///
    /// * `data_ids` — the m sampled points; length must be a multiple
    ///   of `active.len()`.
    /// * `active` — non-eliminated workers.
    /// * `r` — proactive replication (f_t+1 deterministic, 1 otherwise).
    pub fn new(data_ids: &[usize], active: &[WorkerId], r: usize) -> Assignment {
        let nchunks = active.len();
        assert!(nchunks > 0, "no active workers");
        assert_eq!(
            data_ids.len() % nchunks,
            0,
            "m={} not divisible by nchunks={nchunks}",
            data_ids.len()
        );
        let cs = data_ids.len() / nchunks;
        let chunks: Vec<Vec<usize>> = (0..nchunks)
            .map(|j| data_ids[j * cs..(j + 1) * cs].to_vec())
            .collect();
        Self::from_chunks(chunks, active, r)
    }

    /// Build an assignment over pre-partitioned chunks (the sharded
    /// parameter server samples and partitions the data globally, then
    /// hands each shard its chunk slice). `chunks.len()` may differ
    /// from `active.len()`: chunk j is owned cyclically by
    /// `active[(j + k) % nactive]`, so a survivor shard can absorb a
    /// dead shard's chunks even when it has fewer workers than chunks.
    pub fn from_chunks(chunks: Vec<Vec<usize>>, active: &[WorkerId], r: usize) -> Assignment {
        let nactive = active.len();
        assert!(nactive > 0, "no active workers");
        assert!(!chunks.is_empty(), "no chunks to assign");
        assert!(r >= 1 && r <= nactive, "replication r={r} with {nactive} workers");
        let owners: Vec<Vec<WorkerId>> = (0..chunks.len())
            .map(|j| (0..r.min(nactive)).map(|k| active[(j + k) % nactive]).collect())
            .collect();
        Assignment { chunks, owners, active: active.to_vec() }
    }

    pub fn nchunks(&self) -> usize {
        self.chunks.len()
    }

    /// chunks owned by a given worker (with their index in the chunk's
    /// owner list, which determines send order).
    pub fn chunks_of(&self, w: WorkerId) -> Vec<ChunkId> {
        (0..self.nchunks())
            .filter(|&c| self.owners[c].contains(&w))
            .collect()
    }

    /// Extend chunk `c` by `extra` additional distinct owners chosen
    /// (deterministically from `rng`) among active workers that do not
    /// own it yet. Returns the newly added workers. Panics if the
    /// cluster cannot supply that many — the caller guarantees
    /// 2f_t+1 <= nactive (see DESIGN.md invariant 5).
    pub fn extend(&mut self, c: ChunkId, extra: usize, rng: &mut Pcg64) -> Vec<WorkerId> {
        let mut candidates: Vec<WorkerId> = self
            .active
            .iter()
            .copied()
            .filter(|w| !self.owners[c].contains(w))
            .collect();
        assert!(
            candidates.len() >= extra,
            "cannot extend chunk {c} by {extra}: only {} candidates",
            candidates.len()
        );
        rng.shuffle(&mut candidates);
        let added: Vec<WorkerId> = candidates[..extra].to_vec();
        self.owners[c].extend_from_slice(&added);
        added
    }

    /// Suspicion-weighted variant of [`Assignment::extend`]: extend
    /// chunk `c` by `extra` additional distinct owners, choosing the
    /// candidates with the **lowest** `rank` value first (ties broken
    /// by ascending worker id). The latency-aware audit policy passes
    /// its per-worker suspicion scores here, so replicas of a chunk
    /// owned by a suspect/slow worker land on trusted/fast workers
    /// first — exactness under 2f < n is untouched, because audit
    /// waves still collect every requested copy regardless of who
    /// serves it. Fully deterministic (no RNG draw), so it never
    /// perturbs the shuffle stream used by [`Assignment::extend`].
    pub fn extend_ranked(&mut self, c: ChunkId, extra: usize, rank: &[f64]) -> Vec<WorkerId> {
        let mut candidates: Vec<WorkerId> = self
            .active
            .iter()
            .copied()
            .filter(|w| !self.owners[c].contains(w))
            .collect();
        assert!(
            candidates.len() >= extra,
            "cannot extend chunk {c} by {extra}: only {} candidates",
            candidates.len()
        );
        let score = |w: WorkerId| rank.get(w).copied().unwrap_or(0.0);
        candidates.sort_by(|&a, &b| {
            score(a)
                .partial_cmp(&score(b))
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let added: Vec<WorkerId> = candidates[..extra].to_vec();
        self.owners[c].extend_from_slice(&added);
        added
    }

    /// Remove a worker from this iteration's candidate pool (used when
    /// a worker crash-stops mid-round): it will not be chosen by
    /// subsequent [`Assignment::extend`] calls. Its existing ownership
    /// records stay — received copies remain valid, and chunks it
    /// never answered for are re-extended by the protocol core.
    pub fn retire(&mut self, w: WorkerId) {
        if let Some(pos) = self.active.iter().position(|&a| a == w) {
            self.active.remove(pos);
        }
    }

    /// Sanity invariants (used by property tests).
    pub fn validate(&self) -> Result<(), String> {
        for (c, owners) in self.owners.iter().enumerate() {
            let mut u = owners.clone();
            u.sort_unstable();
            u.dedup();
            if u.len() != owners.len() {
                return Err(format!("chunk {c} has duplicate owners {owners:?}"));
            }
            for w in owners {
                if !self.active.contains(w) {
                    return Err(format!("chunk {c} owned by inactive worker {w}"));
                }
            }
        }
        let cs = self.chunks[0].len();
        if self.chunks.iter().any(|ch| ch.len() != cs) {
            return Err("unequal chunk sizes".into());
        }
        Ok(())
    }
}

/// Sample m distinct data-point ids from a dataset of size n.
pub fn sample_points(rng: &mut Pcg64, n: usize, m: usize) -> Vec<usize> {
    if m <= n {
        rng.sample_indices(n, m)
    } else {
        // tiny datasets in tests: sample with replacement
        (0..m).map(|_| rng.index(n)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cyclic_replication_shape() {
        let active: Vec<usize> = (0..5).collect();
        let data: Vec<usize> = (0..20).collect();
        let a = Assignment::new(&data, &active, 3);
        a.validate().unwrap();
        assert_eq!(a.nchunks(), 5);
        assert_eq!(a.owners[0], vec![0, 1, 2]);
        assert_eq!(a.owners[4], vec![4, 0, 1]);
        // every worker owns exactly r chunks
        for w in 0..5 {
            assert_eq!(a.chunks_of(w).len(), 3, "worker {w}");
        }
    }

    #[test]
    fn replication_one_is_partition() {
        let active: Vec<usize> = vec![2, 5, 7]; // non-contiguous ids
        let data: Vec<usize> = (100..112).collect();
        let a = Assignment::new(&data, &active, 1);
        a.validate().unwrap();
        for (j, owners) in a.owners.iter().enumerate() {
            assert_eq!(owners.len(), 1);
            assert_eq!(owners[0], active[j]);
        }
        // chunks partition the data
        let mut all: Vec<usize> = a.chunks.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (100..112).collect::<Vec<_>>());
    }

    #[test]
    fn extend_adds_distinct_new_owners() {
        let active: Vec<usize> = (0..7).collect();
        let data: Vec<usize> = (0..14).collect();
        let mut a = Assignment::new(&data, &active, 3);
        let mut rng = Pcg64::seeded(1);
        let added = a.extend(2, 2, &mut rng);
        assert_eq!(added.len(), 2);
        a.validate().unwrap();
        assert_eq!(a.owners[2].len(), 5);
    }

    #[test]
    fn extend_ranked_prefers_trusted_workers() {
        let active: Vec<usize> = (0..6).collect();
        let data: Vec<usize> = (0..12).collect();
        let mut a = Assignment::new(&data, &active, 1);
        // chunk 2 is owned by worker 2; suspicion: 4 and 5 are suspect,
        // 0 is mildly suspect, 1 and 3 are clean
        let rank = vec![0.2, 0.0, 0.9, 0.0, 0.8, 0.7];
        let added = a.extend_ranked(2, 3, &rank);
        assert_eq!(added, vec![1, 3, 0], "cleanest candidates first, ties by id");
        a.validate().unwrap();
        assert_eq!(a.owners[2], vec![2, 1, 3, 0]);
        // retired workers are never chosen even if trusted
        a.retire(1);
        let added = a.extend_ranked(0, 2, &rank);
        assert_eq!(added, vec![3, 5], "retired worker 1 skipped, then next-cleanest");
    }

    #[test]
    #[should_panic(expected = "cannot extend")]
    fn extend_ranked_beyond_cluster_panics() {
        let active: Vec<usize> = (0..3).collect();
        let data: Vec<usize> = (0..3).collect();
        let mut a = Assignment::new(&data, &active, 3);
        a.extend_ranked(0, 1, &[0.0; 3]);
    }

    #[test]
    #[should_panic(expected = "cannot extend")]
    fn extend_beyond_cluster_panics() {
        let active: Vec<usize> = (0..3).collect();
        let data: Vec<usize> = (0..3).collect();
        let mut a = Assignment::new(&data, &active, 3);
        let mut rng = Pcg64::seeded(1);
        a.extend(0, 1, &mut rng); // all 3 workers already own chunk 0
    }

    #[test]
    fn retired_workers_are_not_chosen_by_extend() {
        let active: Vec<usize> = (0..6).collect();
        let data: Vec<usize> = (0..12).collect();
        let mut a = Assignment::new(&data, &active, 1);
        a.retire(3);
        a.retire(4);
        let mut rng = Pcg64::seeded(5);
        // chunk 0 is owned by worker 0; extend by the 3 remaining
        // candidates — the retired pair must never appear
        let added = a.extend(0, 3, &mut rng);
        assert_eq!(added.len(), 3);
        assert!(!added.contains(&3) && !added.contains(&4), "added {added:?}");
        a.retire(99); // unknown worker: no-op
        assert_eq!(a.active, vec![0, 1, 2, 5]);
    }

    #[test]
    fn sample_points_distinct_when_possible() {
        let mut rng = Pcg64::seeded(2);
        let s = sample_points(&mut rng, 100, 30);
        let mut u = s.clone();
        u.sort_unstable();
        u.dedup();
        assert_eq!(u.len(), 30);
    }
}
