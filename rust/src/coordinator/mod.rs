//! The paper's contribution: a Byzantine-fault-tolerant parallelized-SGD
//! master built on **reactive redundancy** (Gupta & Vaidya, 2019).
//!
//! ## Layer map
//!
//! The coordinator is four layers, top to bottom:
//!
//! 1. **Policy + SGD glue** — [`master::Master`]: builds the cluster,
//!    asks [`policy`] when to audit, aggregates the per-chunk
//!    gradients with the fixed-shape reproducible tree sum, applies
//!    the SGD update through the gradient engine, and records
//!    [`metrics`] / [`events`].
//! 2. **Shard layer** (when `cluster.shards` > 1) — [`shard`]: a
//!    [`shard::ParameterServer`] owns theta, samples each round's data
//!    globally, and fuses per-shard partial aggregates into one SGD
//!    step; a [`shard::ShardedTransport`] fans the round out to K
//!    [`shard::ShardCore`]s, each wrapping its own protocol core over
//!    only its worker subset (per-shard budgets `2 f_s < n_s`,
//!    shard-local votes and eliminations published to the global
//!    roster, whole-shard crashes rescued by survivors). With K = 1
//!    the master drives a single protocol core directly — at zero
//!    latency both layouts are bit-identical (see [`shard`] docs).
//! 3. **Protocol core** — [`protocol::ProtocolCore`]: one iteration as
//!    explicit phase transitions (proactive → detection → reactive,
//!    [`protocol::Phase`]) over a [`protocol::RoundState`] that owns
//!    the single symbol-ingest path. The core is completion-driven:
//!    each phase submits a wave and reacts to deliveries as they
//!    arrive; the cluster's `GatherPolicy` (all | quorum:k |
//!    deadline) decides when the initial proactive wave may stop
//!    waiting, with chunks owned only by abandoned stragglers
//!    reassigned like crashed workers' chunks. Uses [`assignment`]
//!    for chunk placement, [`codes`] for replica comparison,
//!    [`identify`] for majority voting, and eliminates identified
//!    liars. Delivery timestamps are folded into per-worker
//!    [`latency`] profiles whose fused suspicion scores drive the
//!    `latency-selective` audit policy and the suspicion-ranked audit
//!    re-replication. The round is split into
//!    `begin_round` → `collect_proactive` → `finish_round` so the
//!    sharded layer can put every shard's wave in flight before
//!    waiting on any, and so a pipelined driver (`cluster.pipeline`
//!    ≥ 2) can begin iteration t+1 on a **provisional θ** — the SGD
//!    step off round t's pre-audit aggregate — while t's
//!    detection/reactive waves are still in flight. θ is *applied* in
//!    strict iteration order: if finishing round t catches a liar or
//!    otherwise changes θ away from the speculation, t+1's wave is
//!    retired by wave id (late deliveries are dropped, never
//!    ingested) and reissued on the exact θ, so pipelining never
//!    changes values — fault-free rounds overlap fully and a depth-D
//!    run stays bit-identical to the sequential one.
//! 4. **Transport** — [`transport::Transport`]: a completion-driven
//!    submit/poll channel to the workers. `submit` queues a wave
//!    without waiting; `poll` returns timestamped
//!    [`transport::Delivery`]s (responses and in-band crash-stop
//!    failures) as they arrive — virtual time under
//!    [`transport::SimTransport`] (thousands of simulated workers,
//!    latency/straggler/crash models, zero OS threads), wall-clock
//!    under the real one-OS-thread-per-worker
//!    [`transport::ThreadedTransport`]. Both drive the same
//!    [`worker::WorkerState`] compute core (honest engines are
//!    deterministic, so the transports are bit-identical for the same
//!    seed at zero latency). Shards may mix transport kinds.
//!
//! Outside the trust boundary sits the **red team**
//! ([`crate::adversary`]): when `--adversary <strategy>` is set, the
//! run's Byzantine workers stop flipping stateless per-worker coins
//! ([`byzantine`]) and become puppets of one omniscient controller
//! that observes the protocol's public state through a read-only
//! [`protocol::ProtocolTap`] (round assignments + the event stream)
//! and coordinates every lie. The tap sees no oracle data and cannot
//! mutate anything, so the exactness argument below is unchanged —
//! and adversarially validated by `tests/test_adversary.rs`.
//!
//! ## Per-iteration protocol (unifying §4.1 and §4.2 of the paper)
//!
//! 1. [`assignment`] — the master samples m data points, splits them
//!    into per-worker chunks, and replicates each chunk to
//!    `proactive_r` workers (f_t+1 for the deterministic scheme, 1 for
//!    the randomized/vanilla schemes).
//! 2. [`worker`] — workers compute gradient *symbols* for their
//!    chunks; Byzantine workers ([`byzantine`]) may tamper with theirs.
//! 3. [`policy`] — the master decides whether to audit this iteration
//!    (always / never / Bernoulli(q) / adaptive q*_t / selective /
//!    latency-selective, the last driven by the fused suspicion
//!    scores of [`latency`]). Auditing a chunk that has only one copy
//!    triggers the *detection* phase: f_t additional replicas.
//! 4. [`codes`] + [`identify`] — replicated copies are compared
//!    (f-fault *detection*); on mismatch the master imposes **reactive
//!    redundancy**, topping the chunk up to 2f_t+1 copies, recovering
//!    the true gradient by majority vote and *identifying* the liars,
//!    which are eliminated from all subsequent iterations.
//! 5. The master aggregates the per-chunk gradients, applies the SGD
//!    update through the gradient engine (native or PJRT/XLA), and
//!    updates [`metrics`] (computation-efficiency accounting exactly as
//!    in Definition 2 of the paper).
//!
//! [`analysis`] holds the paper's closed forms (Eqs. 2-5) used by the
//! experiment benches, and [`adaptive`] the adaptive-q* policy (§4.3).

pub mod adaptive;
pub mod analysis;
pub mod assignment;
pub mod byzantine;
pub mod codes;
pub mod compress;
pub mod events;
pub mod identify;
pub mod latency;
pub mod master;
pub mod metrics;
pub mod policy;
pub mod protocol;
pub mod shard;
pub mod transport;
pub mod worker;

/// Worker identifier (index into the cluster's worker vector).
pub type WorkerId = usize;

/// Chunk identifier within one iteration.
pub type ChunkId = usize;

/// Sentinel worker id for symbol copies computed by the master itself
/// (self-check audits, majority-vote winners). The master is trusted
/// by definition: a sentinel copy can never be identified as a liar
/// nor eliminated.
pub const MASTER_SENTINEL: WorkerId = usize::MAX;

pub use events::{Event, EventLog};
pub use latency::LatencyTracker;
pub use master::{Master, TrainOutcome};
pub use policy::FaultCheckPolicy;
pub use shard::{ParameterServer, ShardCore, ShardPlan, ShardedTransport};
pub use transport::{
    Delivery, LatencyModel, SimConfig, SimTransport, StragglerModel, ThreadedTransport,
    Transport,
};
