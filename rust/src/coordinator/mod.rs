//! The paper's contribution: a Byzantine-fault-tolerant parallelized-SGD
//! master built on **reactive redundancy** (Gupta & Vaidya, 2019).
//!
//! Per-iteration protocol (unifying §4.1 and §4.2 of the paper):
//!
//! 1. [`assignment`] — the master samples m data points, splits them
//!    into per-worker chunks, and replicates each chunk to
//!    `proactive_r` workers (f_t+1 for the deterministic scheme, 1 for
//!    the randomized/vanilla schemes).
//! 2. [`worker`] — worker threads compute gradient *symbols* for their
//!    chunks; Byzantine workers ([`byzantine`]) may tamper with theirs.
//! 3. [`policy`] — the master decides whether to audit this iteration
//!    (always / never / Bernoulli(q) / adaptive q*_t / selective).
//!    Auditing a chunk that has only one copy triggers the *detection*
//!    phase: f_t additional replicas.
//! 4. [`codes`] + [`identify`] — replicated copies are compared
//!    (f-fault *detection*); on mismatch the master imposes **reactive
//!    redundancy**, topping the chunk up to 2f_t+1 copies, recovering
//!    the true gradient by majority vote and *identifying* the liars,
//!    which are eliminated from all subsequent iterations.
//! 5. The master aggregates the per-chunk gradients, applies the SGD
//!    update through the gradient engine (native or PJRT/XLA), and
//!    updates [`metrics`] (computation-efficiency accounting exactly as
//!    in Definition 2 of the paper).
//!
//! [`analysis`] holds the paper's closed forms (Eqs. 2-5) used by the
//! experiment benches, and [`adaptive`] the adaptive-q* policy (§4.3).

pub mod adaptive;
pub mod analysis;
pub mod assignment;
pub mod byzantine;
pub mod codes;
pub mod compress;
pub mod events;
pub mod identify;
pub mod master;
pub mod metrics;
pub mod policy;
pub mod worker;

/// Worker identifier (index into the cluster's worker vector).
pub type WorkerId = usize;

/// Chunk identifier within one iteration.
pub type ChunkId = usize;

pub use master::{Master, TrainOutcome};
pub use policy::FaultCheckPolicy;
