//! The paper's closed forms (Eqs. 2-5 and the identification bound),
//! used by the adaptive policy and checked against measurements by the
//! experiment benches (E2-E5).

/// Eq. (2): lower bound on the expected computation efficiency of the
/// randomized scheme with audit probability q and f Byzantine workers:
/// 1 - q * 2f/(2f+1). (Worst case: every audit pays the full 2f
/// reactive redundancy for every gradient.)
pub fn eq2_expected_efficiency(q: f64, f: usize) -> f64 {
    let tf = 2.0 * f as f64;
    1.0 - q * (tf / (tf + 1.0))
}

/// §2.2: choosing q = delta * (2f+1)/(2f) makes the expected
/// efficiency >= 1 - delta.
pub fn q_for_target_inefficiency(delta: f64, f: usize) -> f64 {
    let tf = 2.0 * f as f64;
    (delta * (tf + 1.0) / tf).min(1.0)
}

/// Eq. (3): probability of a faulty parameter update when each of the
/// f Byzantine workers tampers independently with probability p and the
/// master audits with probability q:
/// (1 - (1-p)^f) * (1 - q).
pub fn eq3_prob_faulty_update(p: f64, q: f64, f: usize) -> f64 {
    (1.0 - (1.0 - p).powi(f as i32)) * (1.0 - q)
}

/// §4.2: a Byzantine worker with tamper probability p_i survives
/// unidentified after t iterations with probability <= (1 - q p_i)^t.
pub fn identification_survival_bound(q: f64, p_i: f64, t: u64) -> f64 {
    (1.0 - q * p_i).powf(t as f64)
}

/// §4.3: expected computation efficiency with f_t = f - kappa_t
/// remaining Byzantine workers: comEff_t(q) = (2 f_t (1-q) + 1)/(2 f_t + 1).
pub fn comeff_t(q: f64, f_t: usize) -> f64 {
    let tf = 2.0 * f_t as f64;
    (tf * (1.0 - q) + 1.0) / (tf + 1.0)
}

/// §4.3: probF_t(q) = (1 - (1-p)^{f_t}) (1 - q).
pub fn probf_t(q: f64, p: f64, f_t: usize) -> f64 {
    eq3_prob_faulty_update(p, q, f_t)
}

/// Eq. (4): q*_t = argmin_q (1-λ)(1-comEff_t(q))² + λ probF_t(q)².
///
/// With a := 2f_t/(2f_t+1) (so 1-comEff = a q) and c := 1-(1-p)^{f_t}
/// (so probF = c (1-q)) the objective is a convex quadratic and the
/// minimizer is closed-form:
///     q* = λ c² / ((1-λ) a² + λ c²),   clamped to [0, 1].
/// Degenerate cases: a = 0 (f_t = 0) => q* = 0 unless λ c² > 0 forces 1;
/// both terms zero => q* = 0 (no reason to audit).
pub fn eq4_qstar(lambda: f64, p: f64, f_t: usize) -> f64 {
    let a = 2.0 * f_t as f64 / (2.0 * f_t as f64 + 1.0);
    let c = 1.0 - (1.0 - p).powi(f_t as i32);
    let num = lambda * c * c;
    let den = (1.0 - lambda) * a * a + num;
    if den == 0.0 {
        // objective is identically 0 (f_t = 0 and c = 0, or λ ∈ {0,1}
        // with the matching term vanishing): prefer not auditing
        if lambda >= 1.0 && c > 0.0 {
            return 1.0;
        }
        return 0.0;
    }
    (num / den).clamp(0.0, 1.0)
}

/// Eq. (4) minimized numerically on a grid — the property tests verify
/// the closed form against this.
pub fn eq4_qstar_numeric(lambda: f64, p: f64, f_t: usize, grid: usize) -> f64 {
    let obj = |q: f64| {
        let ce = comeff_t(q, f_t);
        let pf = probf_t(q, p, f_t);
        (1.0 - lambda) * (1.0 - ce) * (1.0 - ce) + lambda * pf * pf
    };
    let mut best_q = 0.0;
    let mut best = f64::INFINITY;
    for i in 0..=grid {
        let q = i as f64 / grid as f64;
        let v = obj(q);
        if v < best {
            best = v;
            best_q = q;
        }
    }
    best_q
}

/// Eq. (5): λ_t = 1 - e^{-ℓ_t} from the observed average loss.
pub fn eq5_lambda(observed_loss: f64) -> f64 {
    1.0 - (-observed_loss.max(0.0)).exp()
}

/// §2/§3 efficiency comparison (experiment E6):
/// vanilla = 1, deterministic = 1/(f+1), DRACO = 1/(2f+1).
pub fn deterministic_efficiency(f: usize) -> f64 {
    1.0 / (f as f64 + 1.0)
}

pub fn draco_efficiency(f: usize) -> f64 {
    1.0 / (2.0 * f as f64 + 1.0)
}

/// §4.1: per-iteration efficiency of the deterministic scheme when a
/// fault IS detected (worst case): 1/(2 f_t + 1).
pub fn deterministic_fault_iteration_efficiency(f_t: usize) -> f64 {
    1.0 / (2.0 * f_t as f64 + 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq2_endpoints() {
        assert!((eq2_expected_efficiency(0.0, 4) - 1.0).abs() < 1e-12);
        // q=1: 1 - 2f/(2f+1) = 1/(2f+1) = DRACO
        assert!((eq2_expected_efficiency(1.0, 4) - draco_efficiency(4)).abs() < 1e-12);
    }

    #[test]
    fn q_for_delta_hits_target() {
        for f in [1usize, 2, 4, 8] {
            for delta in [0.05, 0.1, 0.3] {
                let q = q_for_target_inefficiency(delta, f);
                let eff = eq2_expected_efficiency(q, f);
                assert!(eff >= 1.0 - delta - 1e-12, "f={f} delta={delta}: eff={eff}");
            }
        }
    }

    #[test]
    fn eq3_boundaries() {
        assert_eq!(eq3_prob_faulty_update(0.0, 0.5, 4), 0.0); // honest byz
        assert_eq!(eq3_prob_faulty_update(0.7, 1.0, 4), 0.0); // always audit
        assert!((eq3_prob_faulty_update(1.0, 0.0, 3) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn survival_bound_decays_to_zero() {
        let b100 = identification_survival_bound(0.2, 0.5, 100);
        let b10 = identification_survival_bound(0.2, 0.5, 10);
        assert!(b100 < b10 && b10 < 1.0);
        assert!(identification_survival_bound(0.2, 0.5, 10_000) < 1e-9);
    }

    #[test]
    fn qstar_boundary_conditions_from_paper() {
        // λ -> 1 (loss -> ∞): audit always
        assert!((eq4_qstar(1.0, 0.5, 3) - 1.0).abs() < 1e-12);
        // p = 0: never audit
        assert_eq!(eq4_qstar(0.7, 0.0, 3), 0.0);
        // κ_t = f (f_t = 0): never audit
        assert_eq!(eq4_qstar(0.7, 0.5, 0), 0.0);
    }

    #[test]
    fn qstar_matches_numeric_argmin() {
        for &f_t in &[1usize, 2, 4, 8] {
            for &p in &[0.1, 0.5, 0.9] {
                for &lambda in &[0.0, 0.2, 0.5, 0.8, 0.99] {
                    let closed = eq4_qstar(lambda, p, f_t);
                    let numeric = eq4_qstar_numeric(lambda, p, f_t, 100_000);
                    assert!(
                        (closed - numeric).abs() < 1e-4,
                        "f_t={f_t} p={p} λ={lambda}: closed={closed} numeric={numeric}"
                    );
                }
            }
        }
    }

    #[test]
    fn qstar_monotone_in_lambda() {
        let mut prev = -1.0;
        for i in 0..=20 {
            let l = i as f64 / 20.0;
            let q = eq4_qstar(l, 0.5, 2);
            assert!(q >= prev - 1e-12, "q* not monotone at λ={l}");
            prev = q;
        }
    }

    #[test]
    fn lambda_from_loss() {
        assert_eq!(eq5_lambda(0.0), 0.0);
        assert!((eq5_lambda(1.0) - (1.0 - (-1.0f64).exp())).abs() < 1e-12);
        assert!(eq5_lambda(50.0) > 0.999_999);
        assert_eq!(eq5_lambda(-3.0), 0.0); // clamped
    }

    #[test]
    fn efficiency_hierarchy() {
        // randomized (small q) > deterministic > DRACO, for all f >= 1
        for f in 1..10 {
            let rand = eq2_expected_efficiency(0.1, f);
            assert!(rand > deterministic_efficiency(f));
            assert!(deterministic_efficiency(f) > draco_efficiency(f));
        }
    }
}
