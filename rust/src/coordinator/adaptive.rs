//! Adaptive randomized coding (§4.3): the per-iteration audit
//! probability q*_t that balances computation efficiency against the
//! probability of faulty updates, driven by the observed loss.

use super::analysis;

/// State carried across iterations by the adaptive policy.
#[derive(Clone, Debug)]
pub struct AdaptiveState {
    /// Assumed per-iteration tamper probability p (the paper treats p
    /// as an adversary model parameter the master postulates).
    pub p_assumed: f64,
    /// Floor on q while unidentified Byzantine workers remain and
    /// p > 0. Implementation choice on top of §4.3: Eq. (4) drives
    /// q* -> 0 as the observed loss -> 0, which would let a
    /// low-amplitude attacker survive forever; the §4.2 almost-sure
    /// identification guarantee needs q bounded away from 0. The floor
    /// is not applied when p = 0 or f_t = 0 (the paper's exact
    /// boundary conditions). The `latency-selective` policy attacks
    /// the same low-loss blind spot from the other side: instead of a
    /// uniform floor it keeps auditing the workers whose *timing*
    /// ([`super::latency`]) or reliability history is anomalous, so a
    /// quiet attacker pays for being slow even when the loss signal
    /// says nothing.
    pub q_floor: f64,
    /// λ_t, q*_t of the most recent decision (exposed for logging/E5).
    pub last_lambda: f64,
    pub last_qstar: f64,
}

impl AdaptiveState {
    pub fn new(p_assumed: f64) -> Self {
        AdaptiveState { p_assumed, q_floor: 0.02, last_lambda: 0.0, last_qstar: 0.0 }
    }

    /// Decide q*_t from the observed average loss ℓ_t (robustly
    /// aggregated by the caller, e.g. median of per-chunk losses — the
    /// paper's note recommends a trimmed estimate since up to f workers
    /// lie) and the current number of *unidentified* Byzantine workers
    /// f_t = f - κ_t.
    pub fn decide_q(&mut self, observed_loss: f64, f_t: usize) -> f64 {
        let lambda = analysis::eq5_lambda(observed_loss);
        let mut q = analysis::eq4_qstar(lambda, self.p_assumed, f_t);
        if f_t > 0 && self.p_assumed > 0.0 {
            q = q.max(self.q_floor);
        }
        self.last_lambda = lambda;
        self.last_qstar = q;
        q
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn high_loss_means_audit_almost_surely() {
        let mut s = AdaptiveState::new(0.5);
        let q = s.decide_q(50.0, 3);
        assert!(q > 0.9, "q={q}");
        assert!(s.last_lambda > 0.999);
    }

    #[test]
    fn zero_loss_means_efficiency_first_down_to_the_floor() {
        let mut s = AdaptiveState::new(0.5);
        let q = s.decide_q(0.0, 3);
        assert_eq!(
            q, s.q_floor,
            "λ=0 puts all weight on efficiency, but q stays at the \
             almost-sure-identification floor while attackers remain"
        );
        s.q_floor = 0.0;
        assert_eq!(s.decide_q(0.0, 3), 0.0);
    }

    #[test]
    fn floor_not_applied_at_paper_boundaries() {
        let mut s = AdaptiveState::new(0.0); // p = 0
        assert_eq!(s.decide_q(5.0, 3), 0.0);
        let mut s = AdaptiveState::new(0.5);
        assert_eq!(s.decide_q(5.0, 0), 0.0); // κ_t = f
    }

    #[test]
    fn all_byzantine_identified_stops_audits() {
        let mut s = AdaptiveState::new(0.9);
        let q = s.decide_q(10.0, 0);
        assert_eq!(q, 0.0);
    }

    #[test]
    fn q_decreases_as_loss_decreases() {
        let mut s = AdaptiveState::new(0.5);
        let qs: Vec<f64> = [4.0, 2.0, 1.0, 0.5, 0.1]
            .iter()
            .map(|&l| s.decide_q(l, 2))
            .collect();
        for w in qs.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "q should fall with loss: {qs:?}");
        }
    }
}
