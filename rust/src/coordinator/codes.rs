//! Fault-detection codes over gradient symbols (§4.1 and Fig. 2).
//!
//! A *symbol* is what a worker sends the master for one chunk of data
//! points: for the replication code it is the chunk's mean gradient
//! itself; for the Fig. 2 linear code it is a linear combination of
//! chunk gradients. The code's job is to let the master *detect* up to
//! f faulty symbols cheaply; *identification* then needs reactive
//! redundancy ([`super::identify`]).
//!
//! Symbols from honest workers running the same deterministic engine
//! are bit-identical, so comparison is exact (bitwise); an optional
//! tolerance covers engines with nondeterministic reductions.

use crate::coordinator::WorkerId;

/// One received symbol: the claimed mean gradient for a chunk.
#[derive(Clone, Debug)]
pub struct SymbolCopy {
    pub worker: WorkerId,
    /// Dense gradient (exact decode of `wire` under a compressor).
    pub grad: Vec<f32>,
    pub loss: f32,
    /// Packed wire bytes (`Some` iff a compressor is configured).
    /// When present, hashing and exact comparison use these bytes —
    /// replicas are checked on the representation that actually
    /// travelled, bit-identically.
    pub wire: Option<Vec<u8>>,
}

/// Result of comparing the copies of one chunk's symbol.
#[derive(Clone, Debug, PartialEq)]
pub enum CheckOutcome {
    /// All copies agree; the agreed gradient can be used directly.
    Unanimous,
    /// At least two copies differ — some owner of this chunk lied
    /// (or the single copy could not be cross-checked).
    FaultDetected,
}

/// 64-bit hash over the raw f32 bits — the grouping key for exact
/// majority voting. NaNs with identical payloads collide, which is
/// fine: honest engines never produce NaN, and any NaN copy loses the
/// majority anyway.
///
/// Perf (EXPERIMENTS.md §Perf): processes two f32 words per multiply
/// (FxHash-style u64 mixing) instead of the original byte-at-a-time
/// FNV-1a — ~9x faster at d = 4096 with the same grouping semantics.
pub fn grad_key(grad: &[f32], loss: f32) -> u64 {
    const K: u64 = 0x517c_c1b7_2722_0a95; // FxHash multiplier
    #[inline(always)]
    fn mix(h: u64, w: u64) -> u64 {
        (h.rotate_left(5) ^ w).wrapping_mul(K)
    }
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut chunks = grad.chunks_exact(2);
    for pair in &mut chunks {
        let w = (pair[0].to_bits() as u64) | ((pair[1].to_bits() as u64) << 32);
        h = mix(h, w);
    }
    if let [last] = chunks.remainder() {
        h = mix(h, last.to_bits() as u64);
    }
    h = mix(h, loss.to_bits() as u64 ^ (grad.len() as u64) << 32);
    h
}

/// 64-bit hash over packed wire bytes (same FxHash-style mixing as
/// [`grad_key`], eight bytes per multiply) — the grouping key for
/// majority voting over compressed symbols.
pub fn wire_key(wire: &[u8], loss: f32) -> u64 {
    const K: u64 = 0x517c_c1b7_2722_0a95;
    #[inline(always)]
    fn mix(h: u64, w: u64) -> u64 {
        (h.rotate_left(5) ^ w).wrapping_mul(K)
    }
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut chunks = wire.chunks_exact(8);
    for block in &mut chunks {
        let w = u64::from_le_bytes([
            block[0], block[1], block[2], block[3], block[4], block[5], block[6], block[7],
        ]);
        h = mix(h, w);
    }
    let rem = chunks.remainder();
    if !rem.is_empty() {
        let mut w = 0u64;
        for (i, b) in rem.iter().enumerate() {
            w |= (*b as u64) << (8 * i);
        }
        h = mix(h, w);
    }
    h = mix(h, loss.to_bits() as u64 ^ (wire.len() as u64) << 32);
    h
}

/// Grouping key of one copy: the wire bytes when the symbol travelled
/// packed, else the dense gradient bits.
pub fn copy_key(c: &SymbolCopy) -> u64 {
    match &c.wire {
        Some(w) => wire_key(w, c.loss),
        None => grad_key(&c.grad, c.loss),
    }
}

/// Exact equality of two symbols (bitwise, modulo -0.0 == 0.0 via
/// float comparison when `tol == 0.0`, or within `tol` otherwise).
/// When both copies travelled packed and `tol == 0.0`, the comparison
/// is over the wire bytes themselves — detection compares replicas on
/// the packed representation.
pub fn symbols_equal(a: &SymbolCopy, b: &SymbolCopy, tol: f32) -> bool {
    if tol == 0.0 {
        if let (Some(wa), Some(wb)) = (&a.wire, &b.wire) {
            return wa == wb && a.loss == b.loss;
        }
    }
    if a.grad.len() != b.grad.len() {
        return false;
    }
    if tol == 0.0 {
        a.grad == b.grad && a.loss == b.loss
    } else {
        (a.loss - b.loss).abs() <= tol
            && a
                .grad
                .iter()
                .zip(b.grad.iter())
                .all(|(x, y)| (x - y).abs() <= tol)
    }
}

/// Replication detection code (§4.1): with r >= 2 copies of a chunk,
/// any single disagreement reveals a fault (tolerates detection of up
/// to r-1 faulty copies). With a single copy nothing can be checked.
pub fn check_copies(copies: &[SymbolCopy], tol: f32) -> CheckOutcome {
    if copies.len() < 2 {
        return CheckOutcome::FaultDetected; // cannot verify a lone copy
    }
    let first = &copies[0];
    if copies[1..].iter().all(|c| symbols_equal(first, c, tol)) {
        CheckOutcome::Unanimous
    } else {
        CheckOutcome::FaultDetected
    }
}

// ---------------------------------------------------------------------------
// Fig. 2 linear detection code (n = 3, f = 1)
// ---------------------------------------------------------------------------

/// The worked example from Figure 2 of the paper, kept as an executable
/// artifact (experiment E1): workers hold data-point pairs
/// (z1,z2), (z2,z3), (z3,z1) and send
///   c1 = g1 + 2 g2,   c2 = -g2 + g3,   c3 = -g1 - 2 g3.
/// Then c1 + c2 = -(c2 + c3) = (c1 - c3)/2 = g1 + g2 + g3, giving the
/// master three independent reconstructions of the gradient sum: any
/// single faulty symbol makes them disagree (1-fault detection).
pub struct Fig2Code;

impl Fig2Code {
    /// Symbols from the three true gradients (what honest workers send).
    pub fn encode(g1: &[f32], g2: &[f32], g3: &[f32]) -> [Vec<f32>; 3] {
        let d = g1.len();
        let mut c1 = vec![0.0f32; d];
        let mut c2 = vec![0.0f32; d];
        let mut c3 = vec![0.0f32; d];
        for i in 0..d {
            c1[i] = g1[i] + 2.0 * g2[i];
            c2[i] = -g2[i] + g3[i];
            c3[i] = -g1[i] - 2.0 * g3[i];
        }
        [c1, c2, c3]
    }

    /// The three reconstructions of sum = g1+g2+g3.
    pub fn reconstructions(c1: &[f32], c2: &[f32], c3: &[f32]) -> [Vec<f32>; 3] {
        let d = c1.len();
        let mut r1 = vec![0.0f32; d]; // c1 + c2
        let mut r2 = vec![0.0f32; d]; // -(c2 + c3)
        let mut r3 = vec![0.0f32; d]; // (c1 - c3) / 2
        for i in 0..d {
            r1[i] = c1[i] + c2[i];
            r2[i] = -(c2[i] + c3[i]);
            r3[i] = 0.5 * (c1[i] - c3[i]);
        }
        [r1, r2, r3]
    }

    /// Detection: do the reconstructions agree?
    pub fn detect(c1: &[f32], c2: &[f32], c3: &[f32], tol: f32) -> CheckOutcome {
        let [r1, r2, r3] = Self::reconstructions(c1, c2, c3);
        let eq = |a: &[f32], b: &[f32]| {
            a.iter().zip(b.iter()).all(|(x, y)| (x - y).abs() <= tol)
        };
        if eq(&r1, &r2) && eq(&r2, &r3) {
            CheckOutcome::Unanimous
        } else {
            CheckOutcome::FaultDetected
        }
    }

    /// Reactive phase of Fig. 2: workers re-share the symbols
    /// u1 = (c2, c3), u2 = (c3, c1), u3 = (c1, c2); with one Byzantine
    /// worker, each c_i now has 2 honest copies among the 3 claims
    /// (own send + two relays), so majority voting identifies the liar.
    /// `claims[i][j]` = worker i's claim of symbol c_j (own or relayed).
    /// Returns identified Byzantine workers.
    pub fn identify(claims: &[[Vec<f32>; 3]; 3], tol: f32) -> Vec<WorkerId> {
        // majority value of each symbol
        let mut majority: Vec<Vec<f32>> = Vec::with_capacity(3);
        for j in 0..3 {
            let votes: Vec<&Vec<f32>> = (0..3).map(|i| &claims[i][j]).collect();
            let eq = |a: &[f32], b: &[f32]| {
                a.len() == b.len()
                    && a.iter().zip(b.iter()).all(|(x, y)| (x - y).abs() <= tol)
            };
            // find a value claimed by >= 2 workers
            let mut maj: Option<Vec<f32>> = None;
            for i in 0..3 {
                let count = (0..3).filter(|&k| eq(votes[i], votes[k])).count();
                if count >= 2 {
                    maj = Some(votes[i].clone());
                    break;
                }
            }
            majority.push(maj.expect("with f=1 a 2-of-3 majority always exists"));
        }
        // a worker is Byzantine iff any of its claims deviates from majority
        (0..3)
            .filter(|&i| {
                (0..3).any(|j| {
                    let a = &claims[i][j];
                    let b = &majority[j];
                    a.len() != b.len()
                        || a.iter().zip(b.iter()).any(|(x, y)| (x - y).abs() > tol)
                })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sym(w: WorkerId, g: Vec<f32>) -> SymbolCopy {
        SymbolCopy { worker: w, grad: g, loss: 0.5, wire: None }
    }

    #[test]
    fn unanimous_copies_pass() {
        let copies = vec![sym(0, vec![1.0, 2.0]), sym(1, vec![1.0, 2.0])];
        assert_eq!(check_copies(&copies, 0.0), CheckOutcome::Unanimous);
    }

    #[test]
    fn tampered_copy_detected() {
        let copies = vec![
            sym(0, vec![1.0, 2.0]),
            sym(1, vec![1.0, 2.0]),
            sym(2, vec![1.0, 2.0 + 1e-6]),
        ];
        assert_eq!(check_copies(&copies, 0.0), CheckOutcome::FaultDetected);
    }

    #[test]
    fn lone_copy_cannot_be_verified() {
        assert_eq!(
            check_copies(&[sym(0, vec![1.0])], 0.0),
            CheckOutcome::FaultDetected
        );
    }

    #[test]
    fn tolerance_allows_small_noise() {
        let copies = vec![sym(0, vec![1.0]), sym(1, vec![1.0 + 1e-7])];
        assert_eq!(check_copies(&copies, 1e-6), CheckOutcome::Unanimous);
        assert_eq!(check_copies(&copies, 0.0), CheckOutcome::FaultDetected);
    }

    #[test]
    fn tolerance_near_tie_boundary_is_inclusive() {
        // |x - y| <= tol: a difference of exactly tol is "equal", the
        // next representable value above is a detected fault
        let tol = 0.5f32;
        let base = sym(0, vec![1.0]);
        let at_tol = sym(1, vec![1.0 + tol]);
        assert_eq!(check_copies(&[base.clone(), at_tol], tol), CheckOutcome::Unanimous);
        let above = sym(1, vec![f32::from_bits((1.0f32 + tol).to_bits() + 1)]);
        assert_eq!(check_copies(&[base, above], tol), CheckOutcome::FaultDetected);
    }

    #[test]
    fn tolerance_applies_to_loss_too() {
        let tol = 1e-3f32;
        let a = SymbolCopy { worker: 0, grad: vec![1.0], loss: 1.0, wire: None };
        let near = SymbolCopy { worker: 1, grad: vec![1.0], loss: 1.0 + 0.5 * tol, wire: None };
        let far = SymbolCopy { worker: 2, grad: vec![1.0], loss: 1.0 + 10.0 * tol, wire: None };
        assert!(symbols_equal(&a, &near, tol));
        assert!(!symbols_equal(&a, &far, tol));
        assert_eq!(check_copies(&[a.clone(), near], tol), CheckOutcome::Unanimous);
        assert_eq!(check_copies(&[a, far], tol), CheckOutcome::FaultDetected);
    }

    #[test]
    fn length_mismatch_is_never_equal() {
        // compressed symbols can differ in wire length; that is a fault
        // even under a loose tolerance
        let a = sym(0, vec![1.0, 2.0]);
        let b = sym(1, vec![1.0]);
        assert!(!symbols_equal(&a, &b, 100.0));
        assert_eq!(check_copies(&[a, b], 100.0), CheckOutcome::FaultDetected);
    }

    #[test]
    fn grad_key_distinguishes() {
        let a = grad_key(&[1.0, 2.0], 0.1);
        let b = grad_key(&[1.0, 2.0], 0.1);
        let c = grad_key(&[1.0, 2.000001], 0.1);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_ne!(grad_key(&[0.0], 0.0), grad_key(&[-0.0], 0.0)); // bitwise
    }

    #[test]
    fn wire_key_and_copy_key_group_on_packed_bytes() {
        let w1 = vec![0u8, 1, 2, 3, 4, 5, 6, 7, 8]; // 9 bytes: exercises remainder
        let mut w2 = w1.clone();
        w2[8] ^= 0x40;
        assert_eq!(wire_key(&w1, 0.5), wire_key(&w1, 0.5));
        assert_ne!(wire_key(&w1, 0.5), wire_key(&w2, 0.5));
        assert_ne!(wire_key(&w1, 0.5), wire_key(&w1, 0.75)); // loss is part of the key
        // copy_key: wire bytes dominate the dense cache when present
        let a = SymbolCopy { worker: 0, grad: vec![1.0], loss: 0.5, wire: Some(w1.clone()) };
        let b = SymbolCopy { worker: 1, grad: vec![2.0], loss: 0.5, wire: Some(w1.clone()) };
        assert_eq!(copy_key(&a), copy_key(&b));
        let c = SymbolCopy { worker: 2, grad: vec![1.0], loss: 0.5, wire: Some(w2) };
        assert_ne!(copy_key(&a), copy_key(&c));
        let dense = SymbolCopy { worker: 3, grad: vec![1.0], loss: 0.5, wire: None };
        assert_eq!(copy_key(&dense), grad_key(&[1.0], 0.5));
    }

    #[test]
    fn symbols_equal_compares_wires_bitwise() {
        let mk = |wire: Vec<u8>| SymbolCopy { worker: 0, grad: vec![1.0], loss: 0.5, wire: Some(wire) };
        assert!(symbols_equal(&mk(vec![1, 2, 3]), &mk(vec![1, 2, 3]), 0.0));
        assert!(!symbols_equal(&mk(vec![1, 2, 3]), &mk(vec![1, 2, 4]), 0.0));
        // differing wire lengths are a fault regardless of the dense cache
        assert!(!symbols_equal(&mk(vec![1, 2, 3]), &mk(vec![1, 2]), 0.0));
    }

    // ---------------- Fig. 2 (experiment E1 unit coverage) ----------------

    fn fig2_gradients() -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        (vec![1.0, -2.0], vec![0.5, 3.0], vec![-1.5, 0.25])
    }

    #[test]
    fn fig2_reconstructions_agree_when_honest() {
        let (g1, g2, g3) = fig2_gradients();
        let [c1, c2, c3] = Fig2Code::encode(&g1, &g2, &g3);
        let [r1, r2, r3] = Fig2Code::reconstructions(&c1, &c2, &c3);
        let sum: Vec<f32> = (0..2).map(|i| g1[i] + g2[i] + g3[i]).collect();
        for r in [&r1, &r2, &r3] {
            for i in 0..2 {
                assert!((r[i] - sum[i]).abs() < 1e-5);
            }
        }
        assert_eq!(Fig2Code::detect(&c1, &c2, &c3, 1e-5), CheckOutcome::Unanimous);
    }

    #[test]
    fn fig2_any_single_faulty_symbol_is_detected() {
        let (g1, g2, g3) = fig2_gradients();
        let [c1, c2, c3] = Fig2Code::encode(&g1, &g2, &g3);
        for byz in 0..3 {
            let mut cs = [c1.clone(), c2.clone(), c3.clone()];
            cs[byz][0] += 0.75; // any perturbation
            assert_eq!(
                Fig2Code::detect(&cs[0], &cs[1], &cs[2], 1e-5),
                CheckOutcome::FaultDetected,
                "fault by worker {byz} missed"
            );
        }
    }

    #[test]
    fn fig2_identify_finds_the_liar() {
        let (g1, g2, g3) = fig2_gradients();
        let [c1, c2, c3] = Fig2Code::encode(&g1, &g2, &g3);
        for byz in 0..3 {
            // every worker claims all three symbols (own + relayed);
            // the Byzantine worker lies about its own symbol everywhere
            // it can (its own send and its relayed copies).
            let mut bad = [c1.clone(), c2.clone(), c3.clone()][byz].clone();
            bad[1] -= 2.5;
            let honest = [c1.clone(), c2.clone(), c3.clone()];
            let mut claims: [[Vec<f32>; 3]; 3] = std::array::from_fn(|_| honest.clone());
            // worker `byz` claims its own symbol is `bad` (and may relay
            // garbage for others too — test the worst case where it lies
            // about everything it relays)
            claims[byz] = std::array::from_fn(|j| {
                if j == byz {
                    bad.clone()
                } else {
                    let mut v = honest[j].clone();
                    v[0] += 9.0;
                    v
                }
            });
            let ids = Fig2Code::identify(&claims, 1e-5);
            assert_eq!(ids, vec![byz], "byz={byz}");
        }
    }

    #[test]
    fn fig2_identify_no_liar_when_honest() {
        let (g1, g2, g3) = fig2_gradients();
        let honest = Fig2Code::encode(&g1, &g2, &g3);
        let claims: [[Vec<f32>; 3]; 3] = std::array::from_fn(|_| honest.clone());
        assert!(Fig2Code::identify(&claims, 1e-5).is_empty());
    }
}
