//! Comparators from the paper's related work (§3).
//!
//! * [`draco`] — DRACO (Chen et al., 2018): proactive 2f+1 repetition
//!   with majority decoding; exact fault-tolerance at efficiency
//!   1/(2f+1).
//! * [`filters`] — gradient filters: Krum, coordinate median, trimmed
//!   mean, geometric median of means, norm clipping. Approximate
//!   robustness only (the paper's point: they do not achieve *exact*
//!   fault-tolerance without redundancy), reproduced in E10.

pub mod draco;
pub mod filters;

pub use draco::DracoAggregator;
pub use filters::{
    coordinate_median, geometric_median_of_means, krum, multi_krum, norm_clip_mean,
    trimmed_mean, GradientFilter,
};
