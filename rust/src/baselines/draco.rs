//! DRACO-style aggregation (Chen et al., 2018): every chunk is
//! proactively computed by 2f+1 workers and decoded by majority vote —
//! fault *correction* without any reactive phase.
//!
//! Computation efficiency is therefore exactly 1/(2f+1) every
//! iteration, the number the paper's Eq. (2) discussion compares
//! against (our deterministic scheme: 1/(f+1); randomized: -> 1).

use crate::coordinator::codes::SymbolCopy;
use crate::coordinator::identify::majority_vote;
use crate::coordinator::WorkerId;

pub struct DracoAggregator {
    pub f: usize,
}

/// Outcome of decoding one chunk.
pub struct DracoDecode {
    pub grad: Vec<f32>,
    pub loss: f32,
    /// Workers whose copy lost the vote (provably faulty).
    pub faulty: Vec<WorkerId>,
}

impl DracoAggregator {
    pub fn new(f: usize) -> Self {
        DracoAggregator { f }
    }

    /// Replication factor DRACO requires per chunk.
    pub fn replication(&self) -> usize {
        2 * self.f + 1
    }

    /// Majority-decode one chunk from its 2f+1 copies.
    pub fn decode(&self, copies: &[SymbolCopy]) -> DracoDecode {
        let vote = majority_vote(copies, self.f)
            .expect("2f+1 distinct copies always have an f+1 quorum");
        DracoDecode { grad: vote.grad, loss: vote.loss, faulty: vote.liars }
    }

    /// Per-iteration efficiency (Definition 2): 1/(2f+1) always.
    pub fn efficiency(&self) -> f64 {
        1.0 / (2.0 * self.f as f64 + 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sym(w: WorkerId, g: Vec<f32>) -> SymbolCopy {
        SymbolCopy { worker: w, grad: g, loss: 0.0, wire: None }
    }

    #[test]
    fn decodes_through_f_faults() {
        let d = DracoAggregator::new(2);
        assert_eq!(d.replication(), 5);
        let truth = vec![1.0f32, -1.0];
        let copies = vec![
            sym(0, vec![7.0, 7.0]),
            sym(1, truth.clone()),
            sym(2, vec![-7.0, 0.0]),
            sym(3, truth.clone()),
            sym(4, truth.clone()),
        ];
        let out = d.decode(&copies);
        assert_eq!(out.grad, truth);
        assert_eq!(out.faulty, vec![0, 2]);
    }

    #[test]
    fn efficiency_formula() {
        assert!((DracoAggregator::new(1).efficiency() - 1.0 / 3.0).abs() < 1e-12);
        assert!((DracoAggregator::new(4).efficiency() - 1.0 / 9.0).abs() < 1e-12);
    }
}
