//! Gradient filters (§3 related work): robust aggregation rules that
//! replace the mean at the master. None achieves *exact*
//! fault-tolerance (the paper's argument for reactive redundancy);
//! experiment E10 measures their residual error under each attack.
//!
//! Implemented: Krum / multi-Krum (Blanchard et al., 2017), coordinate
//! median and trimmed mean (Yin et al., 2018), geometric median of
//! means (Chen/Su/Xu, 2017), norm clipping (Gupta & Vaidya, 2019).

use crate::linalg;

/// A filter aggregates n gradient vectors (up to f Byzantine) into one.
pub trait GradientFilter: Send + Sync {
    fn name(&self) -> &'static str;
    fn aggregate(&self, grads: &[Vec<f32>], f: usize) -> Vec<f32>;
}

macro_rules! filter_struct {
    ($ty:ident, $name:literal, $fn:path) => {
        pub struct $ty;
        impl GradientFilter for $ty {
            fn name(&self) -> &'static str {
                $name
            }
            fn aggregate(&self, grads: &[Vec<f32>], f: usize) -> Vec<f32> {
                $fn(grads, f)
            }
        }
    };
}

filter_struct!(KrumFilter, "krum", krum);
filter_struct!(MedianFilter, "median", coordinate_median_f);
filter_struct!(TrimmedMeanFilter, "trimmed_mean", trimmed_mean);
filter_struct!(GeoMedFilter, "geomed", geometric_median_of_means_f);
filter_struct!(NormClipFilter, "norm_clip", norm_clip_mean);

fn coordinate_median_f(grads: &[Vec<f32>], _f: usize) -> Vec<f32> {
    coordinate_median(grads)
}

fn geometric_median_of_means_f(grads: &[Vec<f32>], f: usize) -> Vec<f32> {
    // standard choice: k = 2f+1 groups
    geometric_median_of_means(grads, (2 * f + 1).min(grads.len().max(1)))
}

/// All filters, for experiment sweeps.
pub fn all_filters() -> Vec<Box<dyn GradientFilter>> {
    vec![
        Box::new(KrumFilter),
        Box::new(MedianFilter),
        Box::new(TrimmedMeanFilter),
        Box::new(GeoMedFilter),
        Box::new(NormClipFilter),
    ]
}

/// Krum: select the gradient with the smallest sum of squared distances
/// to its n-f-2 nearest neighbours.
pub fn krum(grads: &[Vec<f32>], f: usize) -> Vec<f32> {
    let n = grads.len();
    assert!(n >= 1);
    let k = n.saturating_sub(f + 2).max(1); // neighbours scored
    let mut best = 0usize;
    let mut best_score = f32::INFINITY;
    for i in 0..n {
        let mut d: Vec<f32> = (0..n)
            .filter(|&j| j != i)
            .map(|j| {
                let dd = linalg::dist2(&grads[i], &grads[j]);
                dd * dd
            })
            .collect();
        d.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let score: f32 = d.iter().take(k).sum();
        if score < best_score {
            best_score = score;
            best = i;
        }
    }
    grads[best].clone()
}

/// Multi-Krum: average the m best-scoring gradients (m = n - f).
pub fn multi_krum(grads: &[Vec<f32>], f: usize) -> Vec<f32> {
    let n = grads.len();
    let k = n.saturating_sub(f + 2).max(1);
    let mut scored: Vec<(f32, usize)> = (0..n)
        .map(|i| {
            let mut d: Vec<f32> = (0..n)
                .filter(|&j| j != i)
                .map(|j| {
                    let dd = linalg::dist2(&grads[i], &grads[j]);
                    dd * dd
                })
                .collect();
            d.sort_by(|a, b| a.partial_cmp(b).unwrap());
            (d.iter().take(k).sum(), i)
        })
        .collect();
    scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let m = n.saturating_sub(f).max(1);
    let chosen: Vec<&[f32]> = scored[..m].iter().map(|&(_, i)| grads[i].as_slice()).collect();
    linalg::mean_of(&chosen)
}

/// Coordinate-wise median.
pub fn coordinate_median(grads: &[Vec<f32>]) -> Vec<f32> {
    let n = grads.len();
    assert!(n >= 1);
    let d = grads[0].len();
    let mut out = vec![0.0f32; d];
    let mut col = vec![0.0f32; n];
    for j in 0..d {
        for (i, g) in grads.iter().enumerate() {
            col[i] = g[j];
        }
        col.sort_by(|a, b| a.partial_cmp(b).unwrap());
        out[j] = if n % 2 == 1 {
            col[n / 2]
        } else {
            0.5 * (col[n / 2 - 1] + col[n / 2])
        };
    }
    out
}

/// Coordinate-wise trimmed mean: drop the f largest and f smallest
/// values per coordinate, average the rest.
pub fn trimmed_mean(grads: &[Vec<f32>], f: usize) -> Vec<f32> {
    let n = grads.len();
    assert!(n > 2 * f, "trimmed mean needs n > 2f (n={n}, f={f})");
    let d = grads[0].len();
    let mut out = vec![0.0f32; d];
    let mut col = vec![0.0f32; n];
    let kept = (n - 2 * f) as f32;
    for j in 0..d {
        for (i, g) in grads.iter().enumerate() {
            col[i] = g[j];
        }
        col.sort_by(|a, b| a.partial_cmp(b).unwrap());
        out[j] = col[f..n - f].iter().sum::<f32>() / kept;
    }
    out
}

/// Geometric median (Weiszfeld iterations) of k group means.
pub fn geometric_median_of_means(grads: &[Vec<f32>], k: usize) -> Vec<f32> {
    let n = grads.len();
    assert!(n >= 1);
    let k = k.clamp(1, n);
    // group means (round-robin groups)
    let d = grads[0].len();
    let mut means = vec![vec![0.0f32; d]; k];
    let mut counts = vec![0usize; k];
    for (i, g) in grads.iter().enumerate() {
        linalg::axpy(1.0, g, &mut means[i % k]);
        counts[i % k] += 1;
    }
    for (m, &c) in means.iter_mut().zip(counts.iter()) {
        linalg::scale(1.0 / c.max(1) as f32, m);
    }
    geometric_median(&means, 64, 1e-7)
}

/// Weiszfeld's algorithm for the geometric median.
pub fn geometric_median(points: &[Vec<f32>], max_iter: usize, eps: f32) -> Vec<f32> {
    let refs: Vec<&[f32]> = points.iter().map(|p| p.as_slice()).collect();
    let mut x = linalg::mean_of(&refs);
    for _ in 0..max_iter {
        let mut num = vec![0.0f32; x.len()];
        let mut den = 0.0f32;
        let mut hit = false;
        for p in points {
            let dist = linalg::dist2(&x, p).max(1e-12);
            if dist < eps {
                hit = true;
                break;
            }
            let w = 1.0 / dist;
            linalg::axpy(w, p, &mut num);
            den += w;
        }
        if hit || den == 0.0 {
            break;
        }
        linalg::scale(1.0 / den, &mut num);
        if linalg::dist2(&num, &x) < eps {
            x = num;
            break;
        }
        x = num;
    }
    x
}

/// Norm clipping: clip every gradient to the median norm, then average.
pub fn norm_clip_mean(grads: &[Vec<f32>], _f: usize) -> Vec<f32> {
    let norms: Vec<f64> = grads.iter().map(|g| linalg::norm2(g) as f64).collect();
    let tau = crate::util::stats::median(&norms) as f32;
    let d = grads[0].len();
    let mut out = vec![0.0f32; d];
    for g in grads {
        let n = linalg::norm2(g);
        let scale = if n > tau && n > 0.0 { tau / n } else { 1.0 };
        linalg::axpy(scale / grads.len() as f32, g, &mut out);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// n gradients near `truth`, f of them wildly corrupted.
    fn setup(n: usize, f: usize, seed: u64) -> (Vec<Vec<f32>>, Vec<f32>) {
        let mut rng = Pcg64::seeded(seed);
        let d = 16;
        let truth: Vec<f32> = rng.gauss_vec(d);
        let mut grads: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                truth
                    .iter()
                    .map(|&v| v + 0.01 * rng.gauss_f32())
                    .collect()
            })
            .collect();
        for g in grads.iter_mut().take(f) {
            for v in g.iter_mut() {
                *v = 100.0 * rng.gauss_f32();
            }
        }
        (grads, truth)
    }

    #[test]
    fn all_filters_resist_outliers() {
        let (grads, truth) = setup(11, 2, 1);
        for filt in all_filters() {
            let agg = filt.aggregate(&grads, 2);
            let err = linalg::dist2(&agg, &truth);
            assert!(
                err < 1.0,
                "{} failed: err = {err} (plain mean err would be ~{})",
                filt.name(),
                {
                    let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
                    linalg::dist2(&linalg::mean_of(&refs), &truth)
                }
            );
        }
    }

    #[test]
    fn plain_mean_is_destroyed_by_the_same_attack() {
        let (grads, truth) = setup(11, 2, 1);
        let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        let err = linalg::dist2(&linalg::mean_of(&refs), &truth);
        assert!(err > 5.0, "attack too weak for the contrast test: {err}");
    }

    #[test]
    fn filters_are_not_exact() {
        // the paper's claim: filters do NOT recover the honest mean
        // exactly even under mild noise (no redundancy => approximate)
        let (grads, _) = setup(9, 2, 3);
        let honest: Vec<&[f32]> = grads[2..].iter().map(|g| g.as_slice()).collect();
        let honest_mean = linalg::mean_of(&honest);
        for filt in all_filters() {
            let agg = filt.aggregate(&grads, 2);
            let err = linalg::dist2(&agg, &honest_mean);
            assert!(
                err > 1e-6,
                "{} was bit-exact, which should be impossible here",
                filt.name()
            );
        }
    }

    #[test]
    fn median_odd_even() {
        let g = vec![vec![1.0f32], vec![3.0], vec![2.0]];
        assert_eq!(coordinate_median(&g), vec![2.0]);
        let g = vec![vec![1.0f32], vec![3.0], vec![2.0], vec![10.0]];
        assert_eq!(coordinate_median(&g), vec![2.5]);
    }

    #[test]
    fn trimmed_mean_drops_extremes() {
        let g = vec![vec![-100.0f32], vec![1.0], vec![2.0], vec![3.0], vec![100.0]];
        let tm = trimmed_mean(&g, 1);
        assert!((tm[0] - 2.0).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "trimmed mean needs")]
    fn trimmed_mean_requires_quorum() {
        trimmed_mean(&[vec![1.0f32], vec![2.0]], 1);
    }

    #[test]
    fn geometric_median_of_cluster() {
        let pts = vec![
            vec![0.0f32, 0.0],
            vec![1.0, 0.0],
            vec![0.0, 1.0],
            vec![1.0, 1.0],
        ];
        let gm = geometric_median(&pts, 128, 1e-9);
        assert!(linalg::dist2(&gm, &[0.5, 0.5]) < 1e-3);
    }

    #[test]
    fn krum_picks_a_cluster_member() {
        let (grads, truth) = setup(9, 2, 5);
        let k = krum(&grads, 2);
        // Krum returns one of the honest inputs
        assert!(grads[2..].iter().any(|g| g == &k));
        assert!(linalg::dist2(&k, &truth) < 0.5);
    }

    #[test]
    fn norm_clip_bounds_influence() {
        let g = vec![vec![1.0f32, 0.0], vec![0.9, 0.1], vec![1000.0, -1000.0]];
        let out = norm_clip_mean(&g, 1);
        assert!(linalg::norm2(&out) < 2.0, "clipped mean too large: {out:?}");
    }
}
