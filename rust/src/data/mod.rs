//! Synthetic workload generators.
//!
//! The paper evaluates nothing empirically, so DESIGN.md fixes three
//! canonical workloads: linear regression with a **planted optimum**
//! (exact fault-tolerance, Def. 1, is checkable as ||w_t - w*|| -> 0),
//! a Gaussian-blob softmax classifier, and a byte-level LM corpus for
//! the end-to-end transformer run.

mod blobs;
mod corpus;
mod linreg;

pub use blobs::BlobsDataset;
pub use corpus::{Corpus, TokenBatch};
pub use linreg::LinRegDataset;

/// A batch handed to a gradient engine. Mirrors the artifact data
/// inputs recorded in `artifacts/manifest.json` (everything except the
/// leading `theta`).
#[derive(Clone, Debug)]
pub enum Batch {
    /// x: `[b, d]` row-major, y: `[b]`
    LinReg { x: Vec<f32>, y: Vec<f32>, b: usize, d: usize },
    /// x: `[b, d]` row-major, labels: `[b]`
    Classif { x: Vec<f32>, labels: Vec<i32>, b: usize, d: usize },
    /// tokens: `[b, t]` row-major
    Tokens { tokens: Vec<i32>, b: usize, t: usize },
}

impl Batch {
    /// Number of data points in the batch.
    pub fn len(&self) -> usize {
        match self {
            Batch::LinReg { b, .. } | Batch::Classif { b, .. } | Batch::Tokens { b, .. } => *b,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Select a sub-batch by data-point indices (replication assigns
    /// *data points*, so workers receive row subsets).
    pub fn select(&self, idx: &[usize]) -> Batch {
        match self {
            Batch::LinReg { x, y, d, .. } => {
                let mut sx = Vec::with_capacity(idx.len() * d);
                let mut sy = Vec::with_capacity(idx.len());
                for &i in idx {
                    sx.extend_from_slice(&x[i * d..(i + 1) * d]);
                    sy.push(y[i]);
                }
                Batch::LinReg { x: sx, y: sy, b: idx.len(), d: *d }
            }
            Batch::Classif { x, labels, d, .. } => {
                let mut sx = Vec::with_capacity(idx.len() * d);
                let mut sl = Vec::with_capacity(idx.len());
                for &i in idx {
                    sx.extend_from_slice(&x[i * d..(i + 1) * d]);
                    sl.push(labels[i]);
                }
                Batch::Classif { x: sx, labels: sl, b: idx.len(), d: *d }
            }
            Batch::Tokens { tokens, t, .. } => {
                let mut st = Vec::with_capacity(idx.len() * t);
                for &i in idx {
                    st.extend_from_slice(&tokens[i * t..(i + 1) * t]);
                }
                Batch::Tokens { tokens: st, b: idx.len(), t: *t }
            }
        }
    }
}

/// A dataset the master can sample batches from.
pub trait Dataset: Send + Sync {
    /// Total number of data points N.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Materialize the batch for the given data-point ids.
    fn batch(&self, ids: &[usize]) -> Batch;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn select_linreg_rows() {
        let b = Batch::LinReg {
            x: vec![1., 2., 3., 4., 5., 6.],
            y: vec![10., 20., 30.],
            b: 3,
            d: 2,
        };
        let s = b.select(&[2, 0]);
        match s {
            Batch::LinReg { x, y, b, d } => {
                assert_eq!((b, d), (2, 2));
                assert_eq!(x, vec![5., 6., 1., 2.]);
                assert_eq!(y, vec![30., 10.]);
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn select_tokens_rows() {
        let b = Batch::Tokens { tokens: vec![1, 2, 3, 4, 5, 6], b: 3, t: 2 };
        match b.select(&[1]) {
            Batch::Tokens { tokens, b, t } => {
                assert_eq!((b, t), (1, 2));
                assert_eq!(tokens, vec![3, 4]);
            }
            _ => panic!("wrong variant"),
        }
    }
}
