//! Linear-regression dataset with a planted optimum.
//!
//! y = X w* + noise, X ~ N(0, 1)^{N x d}. With noise = 0 the average
//! loss is exactly minimized at w*, so Def. 1 ("converges to a minimum
//! point exactly") is machine-checkable: E7 asserts ||w_t - w*|| -> 0.

use super::{Batch, Dataset};
use crate::util::rng::Pcg64;

pub struct LinRegDataset {
    pub d: usize,
    pub w_star: Vec<f32>,
    x: Vec<f32>, // [N, d] row-major
    y: Vec<f32>, // [N]
    n: usize,
}

impl LinRegDataset {
    pub fn generate(n: usize, d: usize, noise_std: f32, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed, 101);
        let w_star: Vec<f32> = (0..d).map(|_| rng.gauss_f32()).collect();
        let mut x = Vec::with_capacity(n * d);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let row: Vec<f32> = (0..d).map(|_| rng.gauss_f32()).collect();
            let mut t = crate::linalg::dot(&row, &w_star);
            if noise_std > 0.0 {
                t += noise_std * rng.gauss_f32();
            }
            x.extend_from_slice(&row);
            y.push(t);
        }
        LinRegDataset { d, w_star, x, y, n }
    }
}

impl Dataset for LinRegDataset {
    fn len(&self) -> usize {
        self.n
    }

    fn batch(&self, ids: &[usize]) -> Batch {
        let mut x = Vec::with_capacity(ids.len() * self.d);
        let mut y = Vec::with_capacity(ids.len());
        for &i in ids {
            x.extend_from_slice(&self.x[i * self.d..(i + 1) * self.d]);
            y.push(self.y[i]);
        }
        Batch::LinReg { x, y, b: ids.len(), d: self.d }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dot;

    #[test]
    fn planted_optimum_zero_noise() {
        let ds = LinRegDataset::generate(100, 8, 0.0, 7);
        // residual at w* is exactly zero for every point
        if let Batch::LinReg { x, y, b, d } = ds.batch(&(0..100).collect::<Vec<_>>()) {
            for i in 0..b {
                let pred = dot(&x[i * d..(i + 1) * d], &ds.w_star);
                assert!((pred - y[i]).abs() < 1e-4, "row {i}: {pred} vs {}", y[i]);
            }
        } else {
            panic!("wrong batch kind");
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let a = LinRegDataset::generate(10, 4, 0.1, 42);
        let b = LinRegDataset::generate(10, 4, 0.1, 42);
        assert_eq!(a.w_star, b.w_star);
        assert_eq!(a.x, b.x);
    }
}
