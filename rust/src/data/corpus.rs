//! Byte-level LM corpus for the end-to-end transformer run.
//!
//! A small synthetic English-like corpus is built from a fixed seed
//! text expanded by a 2nd-order Markov chain over words. It is
//! deterministic, needs no downloads, and has enough structure (word
//! and character statistics) that cross-entropy visibly falls during
//! the few hundred steps of the e2e example.

use super::{Batch, Dataset};
use crate::util::rng::Pcg64;

/// Seed text: public-domain style filler with realistic letter stats.
const SEED_TEXT: &str = "the master assigns data points to workers and each worker computes \
gradients of the loss functions at the current parameter estimate . \
byzantine workers need not follow the instructions correctly and may send \
malicious incorrect symbols to the master . the identity of the faulty \
workers remains fixed throughout the learning algorithm and is unknown a \
priori . the master updates the parameter estimate using the average of \
the gradients for the chosen data points . upon detecting a fault the \
master imposes reactive redundancy where each data point is assigned to \
additional workers . the randomized scheme checks for faults only in \
intermittent iterations chosen at random which reduces the redundancy in \
gradient computations while identifying the byzantine workers almost \
surely . smaller probability of fault checks implies higher efficiency \
but also higher probability of using faulty gradients for the update . \
the adaptive approach varies the probability of fault checks depending \
upon the observed average loss at the current parameter estimate . ";

pub struct Corpus {
    bytes: Vec<u8>,
    pub seq_len: usize,
}

/// A [b, t] batch of token ids (i32, values < 256).
pub type TokenBatch = Batch;

impl Corpus {
    /// Build a corpus of roughly `target_len` bytes with window `seq_len`.
    pub fn synthetic(target_len: usize, seq_len: usize, seed: u64) -> Self {
        let words: Vec<&str> = SEED_TEXT.split_whitespace().collect();
        // 2nd-order word Markov chain from the seed text
        let mut rng = Pcg64::new(seed, 303);
        let mut text = String::with_capacity(target_len + 64);
        let mut i = rng.index(words.len() - 2);
        while text.len() < target_len {
            text.push_str(words[i]);
            text.push(' ');
            // successors of (w_i, w_{i+1}) in the seed text
            let (a, b) = (words[i], words[(i + 1) % words.len()]);
            let nexts: Vec<usize> = (0..words.len().saturating_sub(2))
                .filter(|&j| words[j] == a && words[j + 1] == b)
                .map(|j| j + 1)
                .collect();
            i = if nexts.is_empty() || rng.bernoulli(0.05) {
                rng.index(words.len() - 2)
            } else {
                *nexts[rng.index(nexts.len())..].first().unwrap() % (words.len() - 2)
            };
        }
        Corpus {
            bytes: text.into_bytes(),
            seq_len,
        }
    }

    pub fn num_bytes(&self) -> usize {
        self.bytes.len()
    }
}

impl Dataset for Corpus {
    /// "Data point" = one window start position.
    fn len(&self) -> usize {
        self.bytes.len().saturating_sub(self.seq_len + 1)
    }

    fn batch(&self, ids: &[usize]) -> Batch {
        let t = self.seq_len;
        let mut tokens = Vec::with_capacity(ids.len() * t);
        for &start in ids {
            let w = &self.bytes[start..start + t];
            tokens.extend(w.iter().map(|&b| b as i32));
        }
        Batch::Tokens { tokens, b: ids.len(), t }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_has_requested_size_and_windows() {
        let c = Corpus::synthetic(4096, 65, 11);
        assert!(c.num_bytes() >= 4096);
        assert!(c.len() > 3000);
        match c.batch(&[0, 10]) {
            Batch::Tokens { tokens, b, t } => {
                assert_eq!((b, t), (2, 65));
                assert!(tokens.iter().all(|&x| (0..256).contains(&x)));
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn text_is_ascii_words() {
        let c = Corpus::synthetic(1000, 32, 5);
        assert!(c.bytes.iter().all(|&b| b == b' ' || b == b'.' || b.is_ascii_lowercase()));
    }

    #[test]
    fn deterministic() {
        let a = Corpus::synthetic(500, 16, 1);
        let b = Corpus::synthetic(500, 16, 1);
        assert_eq!(a.bytes, b.bytes);
    }
}
