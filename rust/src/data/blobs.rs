//! Gaussian-blob classification dataset (softmax-classifier workload).
//!
//! `classes` isotropic Gaussians with well-separated means; labels are
//! the generating component. Linearly separable at sep >= ~4, so the
//! MLP converges quickly and attack-induced degradation is visible.

use super::{Batch, Dataset};
use crate::util::rng::Pcg64;

pub struct BlobsDataset {
    pub d: usize,
    pub classes: usize,
    x: Vec<f32>,
    labels: Vec<i32>,
    n: usize,
}

impl BlobsDataset {
    pub fn generate(n: usize, d: usize, classes: usize, sep: f32, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed, 202);
        // class means on random directions scaled by sep
        let means: Vec<Vec<f32>> = (0..classes)
            .map(|_| {
                let v = rng.gauss_vec(d);
                let norm = crate::linalg::norm2(&v).max(1e-6);
                v.iter().map(|x| x / norm * sep).collect()
            })
            .collect();
        let mut x = Vec::with_capacity(n * d);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            let c = rng.index(classes);
            for j in 0..d {
                x.push(means[c][j] + rng.gauss_f32());
            }
            labels.push(c as i32);
        }
        BlobsDataset { d, classes, x, labels, n }
    }
}

impl Dataset for BlobsDataset {
    fn len(&self) -> usize {
        self.n
    }

    fn batch(&self, ids: &[usize]) -> Batch {
        let mut x = Vec::with_capacity(ids.len() * self.d);
        let mut labels = Vec::with_capacity(ids.len());
        for &i in ids {
            x.extend_from_slice(&self.x[i * self.d..(i + 1) * self.d]);
            labels.push(self.labels[i]);
        }
        Batch::Classif { x, labels, b: ids.len(), d: self.d }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_label_range() {
        let ds = BlobsDataset::generate(50, 8, 4, 5.0, 3);
        assert_eq!(ds.len(), 50);
        match ds.batch(&[0, 1, 2]) {
            Batch::Classif { x, labels, b, d } => {
                assert_eq!((b, d), (3, 8));
                assert_eq!(x.len(), 24);
                assert!(labels.iter().all(|&l| (0..4).contains(&l)));
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn classes_are_separated() {
        let ds = BlobsDataset::generate(400, 16, 2, 6.0, 9);
        // nearest-class-mean classifier should beat 95% on separable blobs
        let all: Vec<usize> = (0..400).collect();
        if let Batch::Classif { x, labels, b, d } = ds.batch(&all) {
            // estimate class means from the data itself
            let mut means = vec![vec![0.0f32; d]; 2];
            let mut counts = [0usize; 2];
            for i in 0..b {
                let c = labels[i] as usize;
                counts[c] += 1;
                crate::linalg::axpy(1.0, &x[i * d..(i + 1) * d], &mut means[c]);
            }
            for c in 0..2 {
                crate::linalg::scale(1.0 / counts[c].max(1) as f32, &mut means[c]);
            }
            let mut correct = 0;
            for i in 0..b {
                let row = &x[i * d..(i + 1) * d];
                let d0 = crate::linalg::dist2(row, &means[0]);
                let d1 = crate::linalg::dist2(row, &means[1]);
                let pred = if d0 < d1 { 0 } else { 1 };
                if pred == labels[i] {
                    correct += 1;
                }
            }
            assert!(correct as f64 / b as f64 > 0.95, "acc={}", correct as f64 / b as f64);
        }
    }
}
