//! The omniscient adversary controller: one brain for all Byzantine
//! workers of a run.
//!
//! The controller sits between two read paths and one write path:
//!
//! * **tap (read)** — each protocol core gets a [`CoreTap`] installed
//!   as its [`ProtocolTap`]; the tap remaps shard-local worker ids to
//!   global ones and forwards round assignments and events into the
//!   controller's [`AdversaryView`].
//! * **plan (think)** — on every `on_round_start` the controller asks
//!   its [`Strategy`] for the shard's [`RoundPlan`]. Planning happens
//!   on the master thread *before* the wave is submitted, so by the
//!   time any worker computes a symbol the plan is fixed — worker
//!   threads only read it, which keeps threaded runs deterministic.
//! * **corrupt (write)** — Byzantine workers call
//!   [`AdversaryController::corrupt`] from inside symbol production;
//!   planned (worker, chunk) pairs get the coordinated sign-flip lie,
//!   everything else passes through honest. The simulated transport
//!   additionally asks [`AdversaryController::response_delay_ns`] for
//!   the strategy's faked per-worker stall (latency mimicry).

use std::sync::{Arc, Mutex, MutexGuard};

use super::strategies::{build_strategy, RoundPlan, Strategy};
use crate::config::AdversaryKind;
use crate::coordinator::events::Event;
use crate::coordinator::protocol::ProtocolTap;
use crate::coordinator::{ChunkId, WorkerId, MASTER_SENTINEL};

/// One shard's static shape as the adversary sees it (global ids).
#[derive(Clone, Debug)]
pub struct ShardInfo {
    pub shard: usize,
    /// Global id of the shard's first worker.
    pub lo: WorkerId,
    /// Shard width n_s.
    pub n: usize,
    /// Shard Byzantine budget f_s (the 2f_s+1 floor the equivocator
    /// probes).
    pub f: usize,
}

/// The cluster's static shape: shard ranges and budgets. A
/// single-master run is a one-shard topology.
#[derive(Clone, Debug)]
pub struct Topology {
    pub shards: Vec<ShardInfo>,
    /// Total worker count.
    pub n: usize,
}

impl Topology {
    pub fn single(n: usize, f: usize) -> Topology {
        Topology { shards: vec![ShardInfo { shard: 0, lo: 0, n, f }], n }
    }

    /// The shard owning a global worker id.
    pub fn shard_of(&self, w: WorkerId) -> usize {
        self.shards
            .iter()
            .position(|s| (s.lo..s.lo + s.n).contains(&w))
            .expect("worker id outside the adversary topology")
    }
}

/// The current round of one shard, as the tap reported it.
#[derive(Clone, Debug)]
pub struct ShardRoundView {
    pub iter: u64,
    pub f_t: usize,
    /// `owners[c]` = chunk c's owners, **global** ids. Chunk ids are
    /// shard-round-local — exactly the ids workers see in their task
    /// bundles, so plans key on them directly.
    pub owners: Vec<Vec<WorkerId>>,
}

/// Everything the protocol has made public, folded into one mutable
/// view the strategies plan against. Strictly observational: built
/// from assignments and events only, never from oracle data.
#[derive(Clone, Debug)]
pub struct AdversaryView {
    pub topology: Topology,
    /// The controller's workers (sorted global ids).
    pub colluders: Vec<WorkerId>,
    /// Per global worker: identified-and-eliminated by the master.
    pub eliminated: Vec<bool>,
    /// Per global worker: crash-stopped.
    pub crashed: Vec<bool>,
    /// Per global worker: last suspicion score the master surfaced
    /// (`Event::SuspicionUpdated`); 0.0 until reported.
    pub suspicion: Vec<f64>,
    /// Latest iteration at which a detection named a colluder as a
    /// possible owner of a faulty chunk (the audit-evader's dormancy
    /// clock).
    pub last_detection: Option<u64>,
    /// Audited iterations observed so far (all shards).
    pub audits_seen: usize,
    /// Per shard: the current round, once the first one started.
    pub rounds: Vec<Option<ShardRoundView>>,
}

impl AdversaryView {
    pub fn is_colluder(&self, w: WorkerId) -> bool {
        self.colluders.binary_search(&w).is_ok()
    }

    /// A colluder the master still trusts (not eliminated, not
    /// crashed) — the only kind that can still do damage.
    pub fn colluder_alive(&self, w: WorkerId) -> bool {
        self.is_colluder(w) && !self.eliminated[w] && !self.crashed[w]
    }

    /// Alive colluders inside one shard.
    pub fn alive_colluders_in(&self, shard: usize) -> usize {
        let s = &self.topology.shards[shard];
        (s.lo..s.lo + s.n).filter(|&w| self.colluder_alive(w)).count()
    }
}

/// A fixed per-shard plan: what the colluders do this round.
#[derive(Clone, Debug, Default)]
struct PlannedRound {
    iter: u64,
    /// Tamper exactly these (global worker, local chunk) pairs.
    tampers: Vec<(WorkerId, ChunkId)>,
    /// Fake response stall per worker (sim transport only).
    delays: Vec<(WorkerId, u64)>,
}

struct ControllerState {
    strategy: Box<dyn Strategy>,
    view: AdversaryView,
    /// Current plan per shard (valid for `plans[s].iter` only).
    plans: Vec<PlannedRound>,
}

/// The omniscient adversary: owns all Byzantine workers, watches the
/// protocol's public state through [`CoreTap`]s, and coordinates the
/// colluders' lies per the configured [`Strategy`].
pub struct AdversaryController {
    kind: AdversaryKind,
    /// Sorted global ids of the owned workers (immutable, lock-free).
    colluders: Vec<WorkerId>,
    /// Lie magnitude (the coordinated sign-flip's scale, matching the
    /// stateless `sign_flip` attack's knob).
    magnitude: f32,
    state: Mutex<ControllerState>,
}

impl AdversaryController {
    pub fn new(
        kind: AdversaryKind,
        topology: Topology,
        colluders: &[WorkerId],
        magnitude: f32,
    ) -> AdversaryController {
        let n = topology.n;
        let k = topology.shards.len();
        let mut sorted: Vec<WorkerId> = colluders.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        let view = AdversaryView {
            topology,
            colluders: sorted.clone(),
            eliminated: vec![false; n],
            crashed: vec![false; n],
            suspicion: vec![0.0; n],
            last_detection: None,
            audits_seen: 0,
            rounds: vec![None; k],
        };
        AdversaryController {
            kind,
            colluders: sorted,
            magnitude,
            state: Mutex::new(ControllerState {
                strategy: build_strategy(kind),
                view,
                plans: vec![PlannedRound::default(); k],
            }),
        }
    }

    pub fn kind(&self) -> AdversaryKind {
        self.kind
    }

    /// Is this (global) worker one of the adversary's puppets?
    pub fn is_colluder(&self, w: WorkerId) -> bool {
        self.colluders.binary_search(&w).is_ok()
    }

    fn lock(&self) -> MutexGuard<'_, ControllerState> {
        // a poisoned lock only means some worker thread panicked
        // mid-read; the state itself is never left half-written
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Tap entry: a shard's round assignment is fixed. Re-plans the
    /// shard (on the master thread, before the wave is submitted).
    pub fn round_start(&self, shard: usize, iter: u64, f_t: usize, owners: Vec<Vec<WorkerId>>) {
        let mut st = self.lock();
        let ControllerState { strategy, view, plans } = &mut *st;
        view.rounds[shard] = Some(ShardRoundView { iter, f_t, owners });
        let RoundPlan { tampers, delays } = strategy.plan_round(shard, view);
        plans[shard] = PlannedRound { iter, tampers, delays };
    }

    /// Tap entry: one protocol event (worker ids already global).
    pub fn event(&self, _shard: usize, e: &Event) {
        let mut st = self.lock();
        let view = &mut st.view;
        match e {
            Event::AuditDecision { audited: true, .. } => view.audits_seen += 1,
            Event::FaultDetected { iter, owners, .. } => {
                if owners.iter().any(|&w| view.is_colluder(w)) {
                    view.last_detection =
                        Some(view.last_detection.map_or(*iter, |d| d.max(*iter)));
                }
            }
            Event::Eliminated { worker, .. } => view.eliminated[*worker] = true,
            Event::WorkerCrashed { worker, .. } => view.crashed[*worker] = true,
            Event::SuspicionUpdated { worker, suspicion, .. } => {
                view.suspicion[*worker] = *suspicion;
            }
            _ => {}
        }
    }

    /// Worker entry: should `worker` (global id) tamper `chunk` at
    /// `iter` — and if so, apply the coordinated lie in place. The lie
    /// is a pure function of the true gradient (sign-flip scaled by
    /// the configured magnitude), so colluders sharing a chunk push
    /// bit-identical wrong symbols and repeated phases of one
    /// iteration stay consistent. Returns whether the symbol was
    /// corrupted.
    pub fn corrupt(
        &self,
        worker: WorkerId,
        iter: u64,
        chunk: ChunkId,
        grad: &mut [f32],
        loss: &mut f32,
    ) -> bool {
        let planned = {
            let st = self.lock();
            let plan = &st.plans[st.view.topology.shard_of(worker)];
            plan.iter == iter && plan.tampers.contains(&(worker, chunk))
        };
        if !planned {
            return false;
        }
        let m = self.magnitude;
        for v in grad.iter_mut() {
            *v = -m * *v;
        }
        // lie about the loss too (it feeds the adaptive policy) — same
        // shape as the stateless attacks
        *loss *= 1.0 + 0.5 * m;
        true
    }

    /// Sim-transport entry: extra response stall for `worker` at
    /// `iter` (0 unless the strategy shapes timing).
    pub fn response_delay_ns(&self, worker: WorkerId, iter: u64) -> u64 {
        let st = self.lock();
        let plan = &st.plans[st.view.topology.shard_of(worker)];
        if plan.iter != iter {
            return 0;
        }
        plan.delays
            .iter()
            .find(|(w, _)| *w == worker)
            .map(|(_, d)| *d)
            .unwrap_or(0)
    }
}

/// The [`ProtocolTap`] adapter installed on one protocol core: remaps
/// the core's local worker ids to global ones (shard cores run over
/// local ids `0..n_s`) and forwards into the controller. Single-master
/// runs use `shard = 0, lo = 0` (identity remap).
pub struct CoreTap {
    controller: Arc<AdversaryController>,
    shard: usize,
    lo: WorkerId,
}

impl CoreTap {
    pub fn new(controller: Arc<AdversaryController>, shard: usize, lo: WorkerId) -> CoreTap {
        CoreTap { controller, shard, lo }
    }

    fn global(&self, w: WorkerId) -> WorkerId {
        if w == MASTER_SENTINEL {
            w
        } else {
            w + self.lo
        }
    }

    /// Clone of `e` with worker ids shifted to global (chunk ids stay
    /// round-local; strategies key plans on owner sets, not chunks).
    fn remap(&self, e: &Event) -> Event {
        let g = |w: &WorkerId| self.global(*w);
        match e {
            Event::FaultDetected { iter, chunk, owners } => Event::FaultDetected {
                iter: *iter,
                chunk: *chunk,
                owners: owners.iter().map(g).collect(),
            },
            Event::ReactiveRedundancy { iter, chunk, added } => Event::ReactiveRedundancy {
                iter: *iter,
                chunk: *chunk,
                added: added.iter().map(g).collect(),
            },
            Event::Identified { iter, workers } => {
                Event::Identified { iter: *iter, workers: workers.iter().map(g).collect() }
            }
            Event::Eliminated { iter, worker } => {
                Event::Eliminated { iter: *iter, worker: self.global(*worker) }
            }
            Event::WorkerCrashed { iter, worker } => {
                Event::WorkerCrashed { iter: *iter, worker: self.global(*worker) }
            }
            Event::StragglerAbandoned { iter, worker } => {
                Event::StragglerAbandoned { iter: *iter, worker: self.global(*worker) }
            }
            Event::SuspicionUpdated { iter, worker, suspicion } => Event::SuspicionUpdated {
                iter: *iter,
                worker: self.global(*worker),
                suspicion: *suspicion,
            },
            other => other.clone(),
        }
    }
}

impl ProtocolTap for CoreTap {
    fn on_round_start(&self, iter: u64, f_t: usize, owners: &[Vec<WorkerId>]) {
        let global: Vec<Vec<WorkerId>> = owners
            .iter()
            .map(|os| os.iter().map(|&w| self.global(w)).collect())
            .collect();
        self.controller.round_start(self.shard, iter, f_t, global);
    }

    fn on_event(&self, event: &Event) {
        self.controller.event(self.shard, &self.remap(event));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn controller(kind: AdversaryKind) -> AdversaryController {
        AdversaryController::new(kind, Topology::single(8, 2), &[6, 7], 1.0)
    }

    #[test]
    fn topology_shard_lookup() {
        let t = Topology {
            shards: vec![
                ShardInfo { shard: 0, lo: 0, n: 4, f: 1 },
                ShardInfo { shard: 1, lo: 4, n: 4, f: 1 },
            ],
            n: 8,
        };
        assert_eq!(t.shard_of(0), 0);
        assert_eq!(t.shard_of(3), 0);
        assert_eq!(t.shard_of(4), 1);
        assert_eq!(t.shard_of(7), 1);
    }

    #[test]
    fn unplanned_pairs_pass_through_honest() {
        let c = controller(AdversaryKind::AssignmentAware);
        // no round started yet: nothing may be tampered
        let mut g = vec![1.0f32, -2.0];
        let mut loss = 1.0f32;
        assert!(!c.corrupt(6, 0, 0, &mut g, &mut loss));
        assert_eq!(g, vec![1.0, -2.0]);
        assert_eq!(loss, 1.0);
        assert_eq!(c.response_delay_ns(6, 0), 0);
    }

    #[test]
    fn planned_lie_is_consistent_across_colluders_and_phases() {
        let c = controller(AdversaryKind::AssignmentAware);
        // chunks 6 and 7 are singly owned by the colluders (r = 1)
        let owners: Vec<Vec<WorkerId>> = (0..8).map(|w| vec![w]).collect();
        c.round_start(0, 3, 2, owners);
        let (mut g1, mut g2) = (vec![0.5f32, -1.5], vec![0.5f32, -1.5]);
        let (mut l1, mut l2) = (2.0f32, 2.0f32);
        assert!(c.corrupt(6, 3, 6, &mut g1, &mut l1));
        assert!(c.corrupt(6, 3, 6, &mut g2, &mut l2), "repeat call (later phase)");
        assert_eq!(g1, g2, "the lie is a pure function of (iter, chunk, grad)");
        assert_eq!(g1, vec![-0.5, 1.5]);
        assert!(l1 > 2.0);
        // an honest worker's chunk is never in the plan
        let mut gh = vec![1.0f32];
        let mut lh = 1.0f32;
        assert!(!c.corrupt(0, 3, 0, &mut gh, &mut lh));
        // a stale iteration misses the plan
        let mut gs = vec![1.0f32];
        let mut ls = 1.0f32;
        assert!(!c.corrupt(6, 2, 6, &mut gs, &mut ls));
    }

    #[test]
    fn events_update_the_view() {
        let c = controller(AdversaryKind::AssignmentAware);
        c.event(0, &Event::FaultDetected { iter: 5, chunk: 0, owners: vec![6] });
        c.event(0, &Event::Eliminated { iter: 5, worker: 6 });
        c.event(0, &Event::SuspicionUpdated { iter: 5, worker: 7, suspicion: 0.4 });
        c.event(0, &Event::WorkerCrashed { iter: 6, worker: 2 });
        let st = c.lock();
        assert_eq!(st.view.last_detection, Some(5));
        assert!(st.view.eliminated[6]);
        assert!(st.view.crashed[2]);
        assert_eq!(st.view.suspicion[7], 0.4);
        assert!(!st.view.colluder_alive(6), "eliminated colluder is dead to the plan");
        assert!(st.view.colluder_alive(7));
    }

    #[test]
    fn detections_on_honest_owners_do_not_start_the_dormancy_clock() {
        let c = controller(AdversaryKind::AuditEvader { cooldown: 4 });
        c.event(0, &Event::FaultDetected { iter: 9, chunk: 1, owners: vec![0, 1] });
        assert_eq!(c.lock().view.last_detection, None);
    }

    #[test]
    fn core_tap_remaps_to_global_ids() {
        let c = Arc::new(AdversaryController::new(
            AdversaryKind::AssignmentAware,
            Topology {
                shards: vec![
                    ShardInfo { shard: 0, lo: 0, n: 4, f: 1 },
                    ShardInfo { shard: 1, lo: 4, n: 4, f: 1 },
                ],
                n: 8,
            },
            &[3, 7],
            1.0,
        ));
        let tap = CoreTap::new(c.clone(), 1, 4);
        // shard-local worker 3 is global worker 7 (a colluder)
        tap.on_event(&Event::Eliminated { iter: 2, worker: 3 });
        tap.on_round_start(4, 1, &[vec![0], vec![1], vec![2], vec![3]]);
        let st = c.lock();
        assert!(st.view.eliminated[7]);
        let round = st.view.rounds[1].as_ref().unwrap();
        assert_eq!(round.owners, vec![vec![4], vec![5], vec![6], vec![7]]);
        assert!(st.view.rounds[0].is_none());
    }
}
