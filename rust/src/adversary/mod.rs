//! Coordinated, protocol-aware Byzantine adversaries: the red-team
//! layer that fights back.
//!
//! Every attacker the coordinator faced before this subsystem was a
//! stateless per-worker Bernoulli coin
//! ([`crate::coordinator::byzantine::ByzantineBehavior`]): it never
//! saw the assignment, the audit outcomes, or its own suspicion
//! trajectory. The paper's exactness guarantee (2f < n ⇒ eventual
//! identification and fault-free-identical updates) is claimed against
//! a *worst-case* adversary, and the standard evaluation model for
//! such defenses (Blanchard et al., 2017) is an **omniscient** one:
//! all Byzantine workers are puppets of a single controller that
//! observes everything the protocol makes public and coordinates the
//! lies. Interactive/reactive schemes like this one are exactly where
//! adaptive adversaries get interesting (Jain et al., 2024).
//!
//! ## Pieces
//!
//! * [`AdversaryController`] — owns every Byzantine worker of a run.
//!   It watches the protocol through a read-only
//!   [`crate::coordinator::protocol::ProtocolTap`] (round assignments
//!   the moment they are fixed, plus the event stream: audit
//!   decisions, detections, identifications, eliminations, suspicion
//!   updates) and, at each round start, asks its [`Strategy`] for a
//!   [`RoundPlan`]: which (worker, chunk) pairs to tamper and what
//!   extra response delay each colluder should fake. Workers consult
//!   the controller from inside symbol production
//!   ([`crate::coordinator::worker::AdversaryHandle`]), on both the
//!   threaded and the simulated transport.
//! * [`Strategy`] — the pluggable brain; five ship with the crate
//!   (see [`strategies`]): `assignment-aware`, `sleeper`,
//!   `audit-evader`, `latency-mimic`, and `shard-equivocator`,
//!   selected by `--adversary <strategy>` / `adversary.strategy`.
//! * The **lie** every strategy tells is the coordinated sign-flip
//!   `-m·g` of the true chunk gradient: a pure function of the chunk,
//!   so colluders sharing a chunk push bit-identical wrong symbols
//!   (the replica comparison sees unanimity) and the shape matches
//!   the stateless `sign_flip` baseline for apples-to-apples
//!   robustness numbers (`r3bft experiment e13`, `BENCH_adversary.json`).
//!
//! ## What the adversary can and cannot see
//!
//! The tap mirrors the master's *public* state only: assignments,
//! events, suspicion scores. It never sees oracle data (the `tampered`
//! flags), audit coins before they are spent, or honest workers'
//! gradients — and it cannot mutate anything. The exactness property
//! therefore stays exactly as the paper claims it: randomized audits
//! are unpredictable even to an omniscient observer, so a persistently
//! tampering colluder is identified almost surely, while a colluder
//! that stops tampering to stay hidden stops doing damage (footnote 2
//! of the paper). `tests/test_adversary.rs` asserts both halves for
//! every shipped strategy, single-master and sharded, on both
//! transports.

pub mod controller;
pub mod strategies;

pub use controller::{AdversaryController, AdversaryView, CoreTap, ShardInfo, Topology};
pub use strategies::{build_strategy, RoundPlan, Strategy};
