//! The five shipped adversary strategies.
//!
//! Each strategy is a pure planner: given the read-only
//! [`AdversaryView`] (assignment, suspicion trajectory, detection
//! history, eliminations, topology) it returns the shard's
//! [`RoundPlan`] — which (worker, chunk) pairs to tamper and which
//! fake response stalls to apply. Planning runs once per shard round
//! on the master thread; the plan is immutable while workers read it,
//! so threaded runs stay deterministic.
//!
//! | strategy            | signal exploited                  | what catches it |
//! |---------------------|-----------------------------------|-----------------|
//! | `assignment-aware`  | chunk owner sets                  | randomized audits (it cannot predict the coin) |
//! | `sleeper`           | trust/reliability warm-up         | audits keep firing after the strike begins |
//! | `audit-evader`      | detection events + suspicion decay| dormancy is finite: resumed lies meet fresh audits |
//! | `latency-mimic`     | EWMA anomaly gates                | reliability half of the fused suspicion |
//! | `shard-equivocator` | per-shard 2f_s+1 budgets          | shard-local votes (budgets hold per shard) |

use super::controller::AdversaryView;
use crate::config::AdversaryKind;
use crate::coordinator::latency::{MIN_EXCESS_QUANTA, QUANTUM_NS};
use crate::coordinator::{ChunkId, WorkerId};

/// What one shard's colluders do this round.
#[derive(Clone, Debug, Default)]
pub struct RoundPlan {
    /// Tamper exactly these (global worker, local chunk) pairs; pairs
    /// not listed — including detection/reactive top-ups assigned
    /// mid-round — are answered honestly.
    pub tampers: Vec<(WorkerId, ChunkId)>,
    /// Fake response stall per worker in ns (sim transport only).
    pub delays: Vec<(WorkerId, u64)>,
}

/// A coordinated adversary strategy: plans each shard round from the
/// protocol's public state.
pub trait Strategy: Send {
    fn name(&self) -> &'static str;
    /// Plan `shard`'s round. `view.rounds[shard]` is the fresh
    /// assignment; everything else is the accumulated public state.
    fn plan_round(&mut self, shard: usize, view: &AdversaryView) -> RoundPlan;
}

/// Instantiate the strategy a config names.
pub fn build_strategy(kind: AdversaryKind) -> Box<dyn Strategy> {
    match kind {
        AdversaryKind::AssignmentAware => Box::new(AssignmentAware),
        AdversaryKind::Sleeper { warmup } => Box::new(Sleeper { warmup }),
        AdversaryKind::AuditEvader { cooldown } => Box::new(AuditEvader { cooldown }),
        AdversaryKind::LatencyMimic => Box::new(LatencyMimic),
        AdversaryKind::ShardEquivocator => Box::new(ShardEquivocator),
    }
}

/// Every (worker, chunk) pair where an alive colluder owns a chunk in
/// this shard's round — the "all-in" plan most strategies start from.
fn own_chunks(shard: usize, view: &AdversaryView) -> Vec<(WorkerId, ChunkId)> {
    let Some(round) = view.rounds[shard].as_ref() else {
        return Vec::new();
    };
    let mut out = Vec::new();
    for (c, owners) in round.owners.iter().enumerate() {
        for &w in owners {
            if view.colluder_alive(w) {
                out.push((w, c));
            }
        }
    }
    out
}

/// Tamper a chunk only when the colluders own **every** copy of it,
/// so the proactive replication comparison sees unanimous (wrong)
/// agreement and cannot expose the lie. Under r = 1 policies every
/// colluder-owned chunk qualifies; under the deterministic policy
/// (r = f_t+1 > remaining colluders) no chunk ever does — the
/// strategy goes silent rather than get caught, exactly the
/// cross-check-dodging the paper's replication argument predicts.
/// Randomized *audits* still catch it: the audit coin is spent after
/// symbols are ingested, so even an omniscient planner cannot lie
/// only on unaudited rounds.
pub struct AssignmentAware;

impl Strategy for AssignmentAware {
    fn name(&self) -> &'static str {
        "assignment-aware"
    }

    fn plan_round(&mut self, shard: usize, view: &AdversaryView) -> RoundPlan {
        let Some(round) = view.rounds[shard].as_ref() else {
            return RoundPlan::default();
        };
        let mut tampers = Vec::new();
        for (c, owners) in round.owners.iter().enumerate() {
            if !owners.is_empty() && owners.iter().all(|&w| view.colluder_alive(w)) {
                for &w in owners {
                    tampers.push((w, c));
                }
            }
        }
        RoundPlan { tampers, delays: Vec::new() }
    }
}

/// Honest for `warmup` rounds to build trust — verified chunks push
/// reliability (and the fused suspicion) toward "fully trusted" under
/// `selective` / `latency-selective` — then strike persistently.
/// Costlier to detect than a stateless attacker at equal q budget by
/// construction: nothing can be identified before the strike begins.
pub struct Sleeper {
    pub warmup: u64,
}

impl Strategy for Sleeper {
    fn name(&self) -> &'static str {
        "sleeper"
    }

    fn plan_round(&mut self, shard: usize, view: &AdversaryView) -> RoundPlan {
        match view.rounds[shard].as_ref() {
            Some(round) if round.iter >= self.warmup => {
                RoundPlan { tampers: own_chunks(shard, view), delays: Vec::new() }
            }
            _ => RoundPlan::default(),
        }
    }
}

/// Tamper persistently, but go dormant for `cooldown` rounds after
/// any detection event that names a colluder — timed to ride out the
/// hot phase of the reliability/suspicion response (each suspect's
/// reliability is halved on detection and recovers by +0.1 per
/// verified audit, so a short dormancy sheds the extra per-worker
/// audit pressure before the next strike).
pub struct AuditEvader {
    pub cooldown: u64,
}

impl Strategy for AuditEvader {
    fn name(&self) -> &'static str {
        "audit-evader"
    }

    fn plan_round(&mut self, shard: usize, view: &AdversaryView) -> RoundPlan {
        let Some(round) = view.rounds[shard].as_ref() else {
            return RoundPlan::default();
        };
        if let Some(d) = view.last_detection {
            if round.iter <= d + self.cooldown {
                return RoundPlan::default(); // dormant
            }
        }
        RoundPlan { tampers: own_chunks(shard, view), delays: Vec::new() }
    }
}

/// The maximal response stall that stays under every EWMA anomaly
/// gate of [`crate::coordinator::latency`]: quantized to
/// `MIN_EXCESS_QUANTA` buckets of excess, which fails both the
/// absolute-excess gate (excess < MIN_EXCESS_QUANTA) and the ratio
/// gate (mean <= SLOW_RATIO x the >= 1-quantum median) — so the
/// worker's latency anomaly is pinned to 0 while it steals almost
/// 3 ms of straggling per round.
pub const MIMIC_STALL_NS: u64 = (MIN_EXCESS_QUANTA as u64 + 1) * QUANTUM_NS - 100_000;

/// Lie persistently while shaping response delays to stay invisible
/// to the latency half of the fused suspicion: each colluder stalls
/// [`MIMIC_STALL_NS`] per round (just under the anomaly gates) until
/// the master surfaces *any* suspicion on it, then sheds all delay to
/// look like a recovered straggler. Only the reliability half of the
/// suspicion — fed by actual detections — can build the case.
pub struct LatencyMimic;

impl Strategy for LatencyMimic {
    fn name(&self) -> &'static str {
        "latency-mimic"
    }

    fn plan_round(&mut self, shard: usize, view: &AdversaryView) -> RoundPlan {
        let tampers = own_chunks(shard, view);
        let s = &view.topology.shards[shard];
        let delays = (s.lo..s.lo + s.n)
            .filter(|&w| view.colluder_alive(w) && view.suspicion[w] == 0.0)
            .map(|w| (w, MIMIC_STALL_NS))
            .collect();
        RoundPlan { tampers, delays }
    }
}

/// Concentrate all lying on the *weakest* shard — the one whose alive
/// colluders sit closest to its 2f_s+1 identification floor — while
/// colluders elsewhere stay honest and keep their trust. Once the
/// target shard's colluders are eliminated the pressure moves to the
/// next-weakest shard. With K = 1 this degrades to the all-in attack.
pub struct ShardEquivocator;

impl ShardEquivocator {
    /// The shard to concentrate on: maximal alive-colluder pressure
    /// against its own 2f_s+1 floor (ties to the lowest shard id);
    /// `None` when no shard has an alive colluder left.
    fn target(view: &AdversaryView) -> Option<usize> {
        let mut best: Option<(f64, usize)> = None;
        for s in &view.topology.shards {
            let alive = view.alive_colluders_in(s.shard);
            if alive == 0 {
                continue;
            }
            let pressure = alive as f64 / (2 * s.f + 1) as f64;
            let better = match best {
                None => true,
                Some((bp, _)) => pressure > bp,
            };
            if better {
                best = Some((pressure, s.shard));
            }
        }
        best.map(|(_, s)| s)
    }
}

impl Strategy for ShardEquivocator {
    fn name(&self) -> &'static str {
        "shard-equivocator"
    }

    fn plan_round(&mut self, shard: usize, view: &AdversaryView) -> RoundPlan {
        if Self::target(view) == Some(shard) {
            RoundPlan { tampers: own_chunks(shard, view), delays: Vec::new() }
        } else {
            RoundPlan::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adversary::controller::{ShardInfo, Topology};
    use crate::coordinator::events::Event;
    use crate::adversary::AdversaryController;

    /// Drive a controller's public API to produce a view, then read
    /// the plan back through `corrupt` probes.
    fn planned(c: &AdversaryController, w: WorkerId, iter: u64, chunk: ChunkId) -> bool {
        let mut g = vec![1.0f32, 2.0];
        let mut l = 1.0f32;
        c.corrupt(w, iter, chunk, &mut g, &mut l)
    }

    fn single(kind: AdversaryKind, colluders: &[WorkerId]) -> AdversaryController {
        AdversaryController::new(kind, Topology::single(8, 2), colluders, 1.0)
    }

    fn r1_owners() -> Vec<Vec<WorkerId>> {
        (0..8).map(|w| vec![w]).collect()
    }

    #[test]
    fn assignment_aware_needs_full_ownership() {
        let c = single(AdversaryKind::AssignmentAware, &[6, 7]);
        // r = 2 cyclic: chunk c owned by (c, c+1)
        let owners: Vec<Vec<WorkerId>> = (0..8).map(|c| vec![c, (c + 1) % 8]).collect();
        c.round_start(0, 0, 2, owners);
        // chunk 6 is owned by {6, 7} — all colluders: tamper
        assert!(planned(&c, 6, 0, 6));
        assert!(planned(&c, 7, 0, 6));
        // chunk 5 is owned by {5, 6} — worker 5 is honest: stay silent
        assert!(!planned(&c, 6, 0, 5));
        // chunk 7 is owned by {7, 0} — worker 0 is honest: stay silent
        assert!(!planned(&c, 7, 0, 7));
    }

    #[test]
    fn assignment_aware_goes_silent_under_full_replication() {
        let c = single(AdversaryKind::AssignmentAware, &[6, 7]);
        // r = 3 = f_t+1 (deterministic policy): every chunk has an
        // honest owner, so nothing is ever safe to tamper
        let owners: Vec<Vec<WorkerId>> =
            (0..8).map(|c| vec![c, (c + 1) % 8, (c + 2) % 8]).collect();
        c.round_start(0, 0, 2, owners);
        for chunk in 0..8 {
            for &w in &[6usize, 7] {
                assert!(!planned(&c, w, 0, chunk), "worker {w} chunk {chunk}");
            }
        }
    }

    #[test]
    fn sleeper_waits_out_the_warmup() {
        let c = single(AdversaryKind::Sleeper { warmup: 5 }, &[7]);
        for iter in 0..5u64 {
            c.round_start(0, iter, 2, r1_owners());
            assert!(!planned(&c, 7, iter, 7), "struck during warmup at {iter}");
        }
        c.round_start(0, 5, 2, r1_owners());
        assert!(planned(&c, 7, 5, 7), "no strike after warmup");
    }

    #[test]
    fn audit_evader_goes_dormant_after_a_detection() {
        let c = single(AdversaryKind::AuditEvader { cooldown: 3 }, &[6, 7]);
        c.round_start(0, 0, 2, r1_owners());
        assert!(planned(&c, 7, 0, 7));
        // a detection naming colluder 6 at iter 0 starts the clock
        c.event(0, &Event::FaultDetected { iter: 0, chunk: 6, owners: vec![6] });
        for iter in 1..=3u64 {
            c.round_start(0, iter, 2, r1_owners());
            assert!(!planned(&c, 7, iter, 7), "lied while dormant at {iter}");
        }
        c.round_start(0, 4, 2, r1_owners());
        assert!(planned(&c, 7, 4, 7), "never resumed after cooldown");
    }

    #[test]
    fn latency_mimic_stall_stays_under_the_gates() {
        use crate::coordinator::latency::LatencyTracker;
        // feed the mimic's exact stall into a real tracker next to an
        // on-time cluster: the anomaly must stay pinned at 0
        let mut t = LatencyTracker::new(4);
        let active: Vec<WorkerId> = (0..4).collect();
        for _ in 0..30 {
            for w in 0..3 {
                t.observe_ns(w, 0);
            }
            t.observe_ns(3, MIMIC_STALL_NS);
            t.refresh(&active);
        }
        assert_eq!(t.anomaly(3), 0.0, "mimic stall tripped the anomaly gates");
        // one more quantum would trip them
        let mut t = LatencyTracker::new(4);
        for _ in 0..30 {
            for w in 0..3 {
                t.observe_ns(w, 0);
            }
            t.observe_ns(3, MIMIC_STALL_NS + QUANTUM_NS);
            t.refresh(&active);
        }
        assert!(t.anomaly(3) > 0.0, "one quantum more must be anomalous");
    }

    #[test]
    fn latency_mimic_sheds_delay_once_suspected() {
        let c = single(AdversaryKind::LatencyMimic, &[6, 7]);
        c.round_start(0, 0, 2, r1_owners());
        assert_eq!(c.response_delay_ns(6, 0), MIMIC_STALL_NS);
        assert_eq!(c.response_delay_ns(7, 0), MIMIC_STALL_NS);
        assert_eq!(c.response_delay_ns(0, 0), 0, "honest workers are not stalled");
        assert!(planned(&c, 7, 0, 7), "the mimic still lies");
        // the master surfaces suspicion on 7: it sheds the stall
        c.event(0, &Event::SuspicionUpdated { iter: 0, worker: 7, suspicion: 0.3 });
        c.round_start(0, 1, 2, r1_owners());
        assert_eq!(c.response_delay_ns(7, 1), 0);
        assert_eq!(c.response_delay_ns(6, 1), MIMIC_STALL_NS);
    }

    #[test]
    fn equivocator_concentrates_on_the_weakest_shard() {
        // shard 0: f_s = 2 (floor 5), one colluder -> pressure 1/5;
        // shard 1: f_s = 1 (floor 3), one colluder -> pressure 1/3
        let topo = Topology {
            shards: vec![
                ShardInfo { shard: 0, lo: 0, n: 8, f: 2 },
                ShardInfo { shard: 1, lo: 8, n: 8, f: 1 },
            ],
            n: 16,
        };
        let c = AdversaryController::new(AdversaryKind::ShardEquivocator, topo, &[0, 8], 1.0);
        let owners0: Vec<Vec<WorkerId>> = (0..8).map(|w| vec![w]).collect();
        let owners1: Vec<Vec<WorkerId>> = (8..16).map(|w| vec![w]).collect();
        c.round_start(0, 0, 2, owners0.clone());
        c.round_start(1, 0, 1, owners1.clone());
        assert!(!planned(&c, 0, 0, 0), "colluder outside the target shard must stay honest");
        assert!(planned(&c, 8, 0, 0), "target shard's colluder must strike");
        // the target's colluder is eliminated: pressure moves to shard 0
        c.event(1, &Event::Eliminated { iter: 0, worker: 8 });
        c.round_start(0, 1, 2, owners0);
        c.round_start(1, 1, 1, owners1);
        assert!(planned(&c, 0, 1, 0), "pressure must move to the next shard");
    }
}
