//! Counters + round-time histogram, rendered as Prometheus text
//! exposition format (`--metrics-out`).
//!
//! The registry is deliberately static: a fixed counter list and fixed
//! histogram buckets, so the snapshot is byte-deterministic and every
//! counter is present (at zero) even in a quiet run — scrape configs
//! and dashboards can rely on the full set existing.

use crate::coordinator::Event;

/// Every counter, in exposition order: `(name, help)`.
pub const COUNTERS: [(&str, &str); 14] = [
    ("r3bft_rounds_total", "Protocol rounds finished (per shard core)"),
    ("r3bft_waves_total", "Transport waves submitted (proactive, detection, reactive)"),
    ("r3bft_reissues_total", "Pipelined speculative waves retired and reissued"),
    ("r3bft_deliveries_total", "Worker responses accepted by a gather"),
    ("r3bft_bytes_total", "Honest wire bytes moved"),
    ("r3bft_audits_total", "Audit decisions that fired"),
    ("r3bft_detections_total", "Chunks whose replicated copies disagreed"),
    ("r3bft_reactive_topups_total", "Chunks extended to 2f_t+1 copies by the reactive phase"),
    ("r3bft_eliminated_total", "Workers identified as Byzantine and eliminated"),
    ("r3bft_crashes_total", "Workers that crash-stopped"),
    ("r3bft_stragglers_total", "Workers abandoned by a quorum/deadline gather"),
    ("r3bft_oracle_faulty_updates_total", "Tampered gradients that entered an update (sim oracle)"),
    ("r3bft_shard_deaths_total", "Shards that lost their last worker"),
    ("r3bft_net_reconnects_total", "Worker TCP connections re-established (net transport)"),
];

const ROUNDS: usize = 0;
const WAVES: usize = 1;
const REISSUES: usize = 2;
const DELIVERIES: usize = 3;
const BYTES: usize = 4;
const AUDITS: usize = 5;
const DETECTIONS: usize = 6;
const TOPUPS: usize = 7;
const ELIMINATED: usize = 8;
const CRASHES: usize = 9;
const STRAGGLERS: usize = 10;
const ORACLE_FAULTY: usize = 11;
const SHARD_DEATHS: usize = 12;
const NET_RECONNECTS: usize = 13;

/// Round-time histogram bucket bounds, ns (`+Inf` is implicit).
pub const ROUND_NS_BUCKETS: [u64; 8] = [
    1_000,
    10_000,
    100_000,
    1_000_000,
    10_000_000,
    100_000_000,
    1_000_000_000,
    10_000_000_000,
];

pub struct Registry {
    counts: [u64; COUNTERS.len()],
    /// Per-bucket counts; the last slot is `+Inf`.
    round_ns_buckets: [u64; ROUND_NS_BUCKETS.len() + 1],
    round_ns_sum: u64,
    round_ns_count: u64,
}

impl Default for Registry {
    fn default() -> Self {
        Registry {
            counts: [0; COUNTERS.len()],
            round_ns_buckets: [0; ROUND_NS_BUCKETS.len() + 1],
            round_ns_sum: 0,
            round_ns_count: 0,
        }
    }
}

impl Registry {
    pub fn count_event(&mut self, e: &Event) {
        match e {
            Event::AuditDecision { audited: true, .. } => self.counts[AUDITS] += 1,
            Event::FaultDetected { .. } => self.counts[DETECTIONS] += 1,
            Event::ReactiveRedundancy { .. } => self.counts[TOPUPS] += 1,
            Event::Eliminated { .. } => self.counts[ELIMINATED] += 1,
            Event::WorkerCrashed { .. } => self.counts[CRASHES] += 1,
            Event::StragglerAbandoned { .. } => self.counts[STRAGGLERS] += 1,
            Event::OracleFaultyUpdate { .. } => self.counts[ORACLE_FAULTY] += 1,
            Event::ShardDead { .. } => self.counts[SHARD_DEATHS] += 1,
            Event::NetReconnect { .. } => self.counts[NET_RECONNECTS] += 1,
            _ => {}
        }
    }

    pub fn inc_wave(&mut self) {
        self.counts[WAVES] += 1;
    }

    pub fn inc_reissue(&mut self) {
        self.counts[REISSUES] += 1;
    }

    pub fn inc_delivery(&mut self) {
        self.counts[DELIVERIES] += 1;
    }

    pub fn round_finished(&mut self, round_ns: u64, bytes: u64) {
        self.counts[ROUNDS] += 1;
        self.counts[BYTES] += bytes;
        let i = ROUND_NS_BUCKETS
            .iter()
            .position(|&b| round_ns <= b)
            .unwrap_or(ROUND_NS_BUCKETS.len());
        self.round_ns_buckets[i] += 1;
        self.round_ns_sum = self.round_ns_sum.saturating_add(round_ns);
        self.round_ns_count += 1;
    }

    pub fn get(&self, name: &str) -> u64 {
        COUNTERS
            .iter()
            .position(|(n, _)| *n == name)
            .map(|i| self.counts[i])
            .unwrap_or(0)
    }

    /// Prometheus text exposition format.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (i, (name, help)) in COUNTERS.iter().enumerate() {
            out.push_str(&format!("# HELP {name} {help}\n"));
            out.push_str(&format!("# TYPE {name} counter\n"));
            out.push_str(&format!("{name} {}\n", self.counts[i]));
        }
        let name = "r3bft_round_time_ns";
        out.push_str(&format!(
            "# HELP {name} Exclusive round duration on the transport clock\n"
        ));
        out.push_str(&format!("# TYPE {name} histogram\n"));
        let mut cumulative = 0u64;
        for (i, bound) in ROUND_NS_BUCKETS.iter().enumerate() {
            cumulative += self.round_ns_buckets[i];
            out.push_str(&format!("{name}_bucket{{le=\"{bound}\"}} {cumulative}\n"));
        }
        out.push_str(&format!(
            "{name}_bucket{{le=\"+Inf\"}} {}\n",
            self.round_ns_count
        ));
        out.push_str(&format!("{name}_sum {}\n", self.round_ns_sum));
        out.push_str(&format!("{name}_count {}\n", self.round_ns_count));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_every_counter_even_at_zero() {
        let r = Registry::default();
        let text = r.render();
        for (name, _) in COUNTERS {
            assert!(
                text.contains(&format!("\n{name} 0\n"))
                    || text.starts_with(&format!("{name} 0")),
                "missing counter {name}"
            );
            assert!(text.contains(&format!("# TYPE {name} counter")));
        }
        assert!(text.contains("r3bft_round_time_ns_bucket{le=\"+Inf\"} 0"));
    }

    #[test]
    fn histogram_is_cumulative() {
        let mut r = Registry::default();
        r.round_finished(500, 10); // le=1000
        r.round_finished(5_000, 10); // le=10000
        r.round_finished(u64::MAX, 0); // +Inf
        let text = r.render();
        assert!(text.contains("r3bft_round_time_ns_bucket{le=\"1000\"} 1"));
        assert!(text.contains("r3bft_round_time_ns_bucket{le=\"10000\"} 2"));
        assert!(text.contains("r3bft_round_time_ns_bucket{le=\"+Inf\"} 3"));
        assert!(text.contains("r3bft_round_time_ns_count 3"));
        assert_eq!(r.get("r3bft_rounds_total"), 3);
        assert_eq!(r.get("r3bft_bytes_total"), 20);
    }
}
