//! Counters + round-time histogram, rendered as Prometheus text
//! exposition format (`--metrics-out`).
//!
//! The registry is deliberately static: a fixed counter list and fixed
//! histogram buckets, so the snapshot is byte-deterministic and every
//! counter is present (at zero) even in a quiet run — scrape configs
//! and dashboards can rely on the full set existing.

use std::collections::BTreeMap;

use crate::coordinator::transport::LinkStats;
use crate::coordinator::{Event, WorkerId};

/// Every counter, in exposition order: `(name, help)`.
pub const COUNTERS: [(&str, &str); 14] = [
    ("r3bft_rounds_total", "Protocol rounds finished (per shard core)"),
    ("r3bft_waves_total", "Transport waves submitted (proactive, detection, reactive)"),
    ("r3bft_reissues_total", "Pipelined speculative waves retired and reissued"),
    ("r3bft_deliveries_total", "Worker responses accepted by a gather"),
    ("r3bft_bytes_total", "Honest wire bytes moved"),
    ("r3bft_audits_total", "Audit decisions that fired"),
    ("r3bft_detections_total", "Chunks whose replicated copies disagreed"),
    ("r3bft_reactive_topups_total", "Chunks extended to 2f_t+1 copies by the reactive phase"),
    ("r3bft_eliminated_total", "Workers identified as Byzantine and eliminated"),
    ("r3bft_crashes_total", "Workers that crash-stopped"),
    ("r3bft_stragglers_total", "Workers abandoned by a quorum/deadline gather"),
    ("r3bft_oracle_faulty_updates_total", "Tampered gradients that entered an update (sim oracle)"),
    ("r3bft_shard_deaths_total", "Shards that lost their last worker"),
    ("r3bft_net_reconnects_total", "Worker TCP connections re-established (net transport)"),
];

const ROUNDS: usize = 0;
const WAVES: usize = 1;
const REISSUES: usize = 2;
const DELIVERIES: usize = 3;
const BYTES: usize = 4;
const AUDITS: usize = 5;
const DETECTIONS: usize = 6;
const TOPUPS: usize = 7;
const ELIMINATED: usize = 8;
const CRASHES: usize = 9;
const STRAGGLERS: usize = 10;
const ORACLE_FAULTY: usize = 11;
const SHARD_DEATHS: usize = 12;
const NET_RECONNECTS: usize = 13;

/// Round-time histogram bucket bounds, ns (`+Inf` is implicit).
pub const ROUND_NS_BUCKETS: [u64; 8] = [
    1_000,
    10_000,
    100_000,
    1_000_000,
    10_000_000,
    100_000_000,
    1_000_000_000,
    10_000_000_000,
];

pub struct Registry {
    counts: [u64; COUNTERS.len()],
    /// Per-bucket counts; the last slot is `+Inf`.
    round_ns_buckets: [u64; ROUND_NS_BUCKETS.len() + 1],
    round_ns_sum: u64,
    round_ns_count: u64,
}

impl Default for Registry {
    fn default() -> Self {
        Registry {
            counts: [0; COUNTERS.len()],
            round_ns_buckets: [0; ROUND_NS_BUCKETS.len() + 1],
            round_ns_sum: 0,
            round_ns_count: 0,
        }
    }
}

impl Registry {
    pub fn count_event(&mut self, e: &Event) {
        match e {
            Event::AuditDecision { audited: true, .. } => self.counts[AUDITS] += 1,
            Event::FaultDetected { .. } => self.counts[DETECTIONS] += 1,
            Event::ReactiveRedundancy { .. } => self.counts[TOPUPS] += 1,
            Event::Eliminated { .. } => self.counts[ELIMINATED] += 1,
            Event::WorkerCrashed { .. } => self.counts[CRASHES] += 1,
            Event::StragglerAbandoned { .. } => self.counts[STRAGGLERS] += 1,
            Event::OracleFaultyUpdate { .. } => self.counts[ORACLE_FAULTY] += 1,
            Event::ShardDead { .. } => self.counts[SHARD_DEATHS] += 1,
            Event::NetReconnect { .. } => self.counts[NET_RECONNECTS] += 1,
            _ => {}
        }
    }

    pub fn inc_wave(&mut self) {
        self.counts[WAVES] += 1;
    }

    pub fn inc_reissue(&mut self) {
        self.counts[REISSUES] += 1;
    }

    pub fn inc_delivery(&mut self) {
        self.counts[DELIVERIES] += 1;
    }

    pub fn round_finished(&mut self, round_ns: u64, bytes: u64) {
        self.counts[ROUNDS] += 1;
        self.counts[BYTES] += bytes;
        let i = ROUND_NS_BUCKETS
            .iter()
            .position(|&b| round_ns <= b)
            .unwrap_or(ROUND_NS_BUCKETS.len());
        self.round_ns_buckets[i] += 1;
        self.round_ns_sum = self.round_ns_sum.saturating_add(round_ns);
        self.round_ns_count += 1;
    }

    pub fn get(&self, name: &str) -> u64 {
        COUNTERS
            .iter()
            .position(|(n, _)| *n == name)
            .map(|i| self.counts[i])
            .unwrap_or(0)
    }

    /// Prometheus text exposition format.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (i, (name, help)) in COUNTERS.iter().enumerate() {
            out.push_str(&format!("# HELP {name} {help}\n"));
            out.push_str(&format!("# TYPE {name} counter\n"));
            out.push_str(&format!("{name} {}\n", self.counts[i]));
        }
        let name = "r3bft_round_time_ns";
        out.push_str(&format!(
            "# HELP {name} Exclusive round duration on the transport clock\n"
        ));
        out.push_str(&format!("# TYPE {name} histogram\n"));
        let mut cumulative = 0u64;
        for (i, bound) in ROUND_NS_BUCKETS.iter().enumerate() {
            cumulative += self.round_ns_buckets[i];
            out.push_str(&format!("{name}_bucket{{le=\"{bound}\"}} {cumulative}\n"));
        }
        out.push_str(&format!(
            "{name}_bucket{{le=\"+Inf\"}} {}\n",
            self.round_ns_count
        ));
        out.push_str(&format!("{name}_sum {}\n", self.round_ns_sum));
        out.push_str(&format!("{name}_count {}\n", self.round_ns_count));
        out
    }
}

/// The worker-labeled per-link families of the live scrape
/// (`/metrics` on `--metrics-listen`), one series per link keyed by
/// global worker id. Appended after [`Registry::render`] by
/// `Recorder::prometheus_live`; the deterministic `--metrics-out`
/// snapshot never includes these (their values are wall-clock
/// estimates, not pure functions of the seed). The labeled
/// `r3bft_net_reconnects_total` series reuse the family
/// [`Registry::render`] already declared, so a flapping single link is
/// distinguishable from fleet-wide churn; the remaining families are
/// declared here. Empty input renders to the empty string.
pub fn render_labeled(links: &BTreeMap<WorkerId, LinkStats>) -> String {
    if links.is_empty() {
        return String::new();
    }
    let mut out = String::new();
    // labeled continuation of the aggregate family declared above
    for (w, l) in links {
        out.push_str(&format!("r3bft_net_reconnects_total{{worker=\"{w}\"}} {}\n", l.reconnects));
    }
    // (name, help, type, value extractor) per new per-link family
    type Get = fn(&LinkStats) -> i128;
    let families: [(&str, &str, &str, Get); 8] = [
        (
            "r3bft_net_resends_total",
            "Master-side request resends per link (reconnect replays + chaos resend-on-timeout)",
            "counter",
            |l| l.resends as i128,
        ),
        (
            "r3bft_auth_rejects_total",
            "Frames the worker refused for a bad MAC",
            "counter",
            |l| l.auth_rejects as i128,
        ),
        (
            "r3bft_net_dup_requests_total",
            "Duplicate requests observed worker-side (master resends)",
            "counter",
            |l| l.dup_requests as i128,
        ),
        (
            "r3bft_net_chaos_hits_total",
            "Undecodable frames observed worker-side (chaos corruption)",
            "counter",
            |l| l.chaos_hits as i128,
        ),
        (
            "r3bft_worker_dropped_spans_total",
            "Telemetry spans dropped to keep buffers bounded",
            "counter",
            |l| l.dropped_spans as i128,
        ),
        (
            "r3bft_net_link_rtt_ns",
            "EWMA link round-trip estimate on the master transport clock",
            "gauge",
            |l| l.rtt_ns as i128,
        ),
        (
            "r3bft_net_link_clock_offset_ns",
            "Estimated worker-clock minus master-clock (NTP midpoint, EWMA-refined)",
            "gauge",
            |l| l.offset_ns as i128,
        ),
        (
            "r3bft_worker_span_queue_depth",
            "Worker span-queue high-water mark in the last telemetry batch",
            "gauge",
            |l| l.queue_depth as i128,
        ),
    ];
    for (name, help, kind, get) in families {
        out.push_str(&format!("# HELP {name} {help}\n"));
        out.push_str(&format!("# TYPE {name} {kind}\n"));
        for (w, l) in links {
            out.push_str(&format!("{name}{{worker=\"{w}\"}} {}\n", get(l)));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labeled_families_render_one_series_per_link() {
        let mut links: BTreeMap<WorkerId, LinkStats> = BTreeMap::new();
        assert_eq!(render_labeled(&links), "", "no links, no labeled block");
        links.insert(
            2,
            LinkStats {
                worker: 2,
                rtt_ns: 1500,
                offset_ns: -40,
                reconnects: 3,
                resends: 7,
                auth_rejects: 1,
                requests: 90,
                dup_requests: 5,
                chaos_hits: 2,
                queue_depth: 4,
                dropped_spans: 0,
            },
        );
        links.insert(0, LinkStats { worker: 0, ..Default::default() });
        let text = render_labeled(&links);
        assert!(text.contains("r3bft_net_reconnects_total{worker=\"2\"} 3"));
        assert!(text.contains("r3bft_net_reconnects_total{worker=\"0\"} 0"));
        assert!(text.contains("r3bft_net_resends_total{worker=\"2\"} 7"));
        assert!(text.contains("r3bft_auth_rejects_total{worker=\"2\"} 1"));
        assert!(text.contains("r3bft_net_link_rtt_ns{worker=\"2\"} 1500"));
        assert!(
            text.contains("r3bft_net_link_clock_offset_ns{worker=\"2\"} -40"),
            "gauges carry signed offsets"
        );
        assert!(text.contains("# TYPE r3bft_net_link_rtt_ns gauge"));
        assert!(text.contains("# TYPE r3bft_net_resends_total counter"));
        // worker ids render in sorted order (BTreeMap iteration)
        let w0 = text.find("r3bft_net_resends_total{worker=\"0\"}").unwrap();
        let w2 = text.find("r3bft_net_resends_total{worker=\"2\"}").unwrap();
        assert!(w0 < w2);
    }

    #[test]
    fn renders_every_counter_even_at_zero() {
        let r = Registry::default();
        let text = r.render();
        for (name, _) in COUNTERS {
            assert!(
                text.contains(&format!("\n{name} 0\n"))
                    || text.starts_with(&format!("{name} 0")),
                "missing counter {name}"
            );
            assert!(text.contains(&format!("# TYPE {name} counter")));
        }
        assert!(text.contains("r3bft_round_time_ns_bucket{le=\"+Inf\"} 0"));
    }

    #[test]
    fn histogram_is_cumulative() {
        let mut r = Registry::default();
        r.round_finished(500, 10); // le=1000
        r.round_finished(5_000, 10); // le=10000
        r.round_finished(u64::MAX, 0); // +Inf
        let text = r.render();
        assert!(text.contains("r3bft_round_time_ns_bucket{le=\"1000\"} 1"));
        assert!(text.contains("r3bft_round_time_ns_bucket{le=\"10000\"} 2"));
        assert!(text.contains("r3bft_round_time_ns_bucket{le=\"+Inf\"} 3"));
        assert!(text.contains("r3bft_round_time_ns_count 3"));
        assert_eq!(r.get("r3bft_rounds_total"), 3);
        assert_eq!(r.get("r3bft_bytes_total"), 20);
    }
}
