//! Chrome trace-event JSON exporter (`--trace out.json`).
//!
//! The layout maps protocol structure onto the trace viewer's
//! process/thread grid: one **process (pid) per shard** (pid 0 for a
//! single-master run), thread 0 is the protocol lane (async wave and
//! round spans, anomaly instants), and **one thread per worker**
//! (tid = global worker id + 1) carrying that worker's delivery spans.
//! Open the file in [Perfetto](https://ui.perfetto.dev) or
//! chrome://tracing; overlapping pipelined waves and reissue storms
//! show up as overlapping async spans on the protocol lane.
//!
//! Timestamps are transport-clock ns divided by 1000 (the trace-event
//! `ts` unit is µs). Built on [`crate::util::json::Json`] — object
//! keys are sorted and floats print shortest-round-trip, so the same
//! sim seed renders to byte-identical output.

use crate::coordinator::Event;
use crate::util::json::Json;

use super::{obj, DeliverySpan, RoundSpan, StampedEvent, WaveSpan, WorkerSpan};

/// Worker-*process* rows (remote telemetry spans) get pids far above
/// any shard pid: pid = `WORKER_PID_BASE` + global worker id.
pub const WORKER_PID_BASE: usize = 1000;

fn us(ns: u64) -> Json {
    Json::Num(ns as f64 / 1000.0)
}

fn phase_name(phase: u8) -> &'static str {
    match phase {
        0 => "proactive",
        1 => "detection",
        _ => "reactive",
    }
}

/// Lane name for a remote span kind (`SPAN_COMPUTE`/`DECODE`/`ENCODE`
/// wire codes; the tid doubles as the code).
fn kind_name(kind: u8) -> &'static str {
    match kind {
        0 => "compute",
        1 => "decode",
        _ => "encode",
    }
}

/// Instant-worthy event kinds (detections, identifications, crashes,
/// abandonments — not the per-round audit chatter).
fn instant_name(e: &Event) -> Option<&'static str> {
    match e {
        Event::FaultDetected { .. } => Some("fault_detected"),
        Event::ReactiveRedundancy { .. } => Some("reactive_redundancy"),
        Event::Identified { .. } => Some("identified"),
        Event::Eliminated { .. } => Some("eliminated"),
        Event::WorkerCrashed { .. } => Some("worker_crashed"),
        Event::StragglerAbandoned { .. } => Some("straggler_abandoned"),
        Event::OracleFaultyUpdate { .. } => Some("oracle_faulty_update"),
        Event::ShardDead { .. } => Some("shard_dead"),
        Event::RosterEliminated { .. } => Some("roster_eliminated"),
        Event::NetReconnect { .. } => Some("net_reconnect"),
        _ => None,
    }
}

fn async_pair(
    name: String,
    cat: &str,
    id: String,
    pid: usize,
    begin_ns: u64,
    end_ns: u64,
    args: Json,
) -> [Json; 2] {
    let base = |ph: &str, ts: u64, args: Json| {
        obj(vec![
            ("name", Json::Str(name.clone())),
            ("cat", Json::Str(cat.to_string())),
            ("ph", Json::Str(ph.to_string())),
            ("id", Json::Str(id.clone())),
            ("pid", Json::Num(pid as f64)),
            ("tid", Json::Num(0.0)),
            ("ts", us(ts)),
            ("args", args),
        ])
    };
    [base("b", begin_ns, args), base("e", end_ns, Json::Null)]
}

/// Render all recorded spans and events as one Chrome trace document.
/// `worker_spans` is empty for in-process transports, so their output
/// is byte-identical to the pre-telemetry export.
pub(crate) fn render(
    waves: &[WaveSpan],
    deliveries: &[DeliverySpan],
    rounds: &[RoundSpan],
    events: &[StampedEvent],
    worker_spans: &[WorkerSpan],
) -> String {
    let mut te: Vec<Json> = Vec::new();

    // Metadata: name every shard process and worker thread that
    // appears anywhere in the data, in sorted order.
    let mut shards: Vec<usize> = waves
        .iter()
        .map(|w| w.shard)
        .chain(rounds.iter().map(|r| r.shard))
        .chain(deliveries.iter().map(|d| d.shard))
        .collect();
    shards.sort_unstable();
    shards.dedup();
    let mut worker_threads: Vec<(usize, usize)> =
        deliveries.iter().map(|d| (d.shard, d.worker)).collect();
    worker_threads.sort_unstable();
    worker_threads.dedup();
    for &s in &shards {
        te.push(obj(vec![
            ("name", Json::Str("process_name".to_string())),
            ("ph", Json::Str("M".to_string())),
            ("pid", Json::Num(s as f64)),
            ("tid", Json::Num(0.0)),
            ("args", obj(vec![("name", Json::Str(format!("shard {s}")))])),
        ]));
        te.push(obj(vec![
            ("name", Json::Str("thread_name".to_string())),
            ("ph", Json::Str("M".to_string())),
            ("pid", Json::Num(s as f64)),
            ("tid", Json::Num(0.0)),
            ("args", obj(vec![("name", Json::Str("protocol".to_string()))])),
        ]));
    }
    for &(s, w) in &worker_threads {
        te.push(obj(vec![
            ("name", Json::Str("thread_name".to_string())),
            ("ph", Json::Str("M".to_string())),
            ("pid", Json::Num(s as f64)),
            ("tid", Json::Num((w + 1) as f64)),
            ("args", obj(vec![("name", Json::Str(format!("worker {w}")))])),
        ]));
    }
    // Worker-process rows (remote telemetry): one process per remote
    // worker, one lane per span kind that actually occurred.
    let mut remote_workers: Vec<usize> = worker_spans.iter().map(|s| s.worker).collect();
    remote_workers.sort_unstable();
    remote_workers.dedup();
    let mut remote_lanes: Vec<(usize, u8)> =
        worker_spans.iter().map(|s| (s.worker, s.kind)).collect();
    remote_lanes.sort_unstable();
    remote_lanes.dedup();
    for &w in &remote_workers {
        te.push(obj(vec![
            ("name", Json::Str("process_name".to_string())),
            ("ph", Json::Str("M".to_string())),
            ("pid", Json::Num((WORKER_PID_BASE + w) as f64)),
            ("tid", Json::Num(0.0)),
            ("args", obj(vec![("name", Json::Str(format!("worker {w} (remote)")))])),
        ]));
    }
    for &(w, k) in &remote_lanes {
        te.push(obj(vec![
            ("name", Json::Str("thread_name".to_string())),
            ("ph", Json::Str("M".to_string())),
            ("pid", Json::Num((WORKER_PID_BASE + w) as f64)),
            ("tid", Json::Num(k as f64)),
            ("args", obj(vec![("name", Json::Str(kind_name(k).to_string()))])),
        ]));
    }

    for r in rounds {
        te.extend(async_pair(
            format!("round {}", r.iter),
            "round",
            format!("r{}.{}", r.shard, r.iter),
            r.shard,
            r.start_ns,
            r.end_ns,
            obj(vec![
                ("iter", Json::Num(r.iter as f64)),
                ("round_ns", Json::Num(r.round_ns as f64)),
                ("bytes", Json::Num(r.bytes as f64)),
            ]),
        ));
    }

    for w in waves {
        te.extend(async_pair(
            format!("{} wave i{}", phase_name(w.phase), w.iter),
            "wave",
            format!("w{}.{}", w.shard, w.wave),
            w.shard,
            w.start_ns,
            w.end_ns.max(w.start_ns),
            obj(vec![
                ("iter", Json::Num(w.iter as f64)),
                ("wave", Json::Num(w.wave as f64)),
                ("phase", Json::Str(phase_name(w.phase).to_string())),
                ("workers", Json::Num(w.workers as f64)),
                ("responses", Json::Num(w.responses as f64)),
                ("reissued", Json::Bool(w.reissued)),
            ]),
        ));
    }

    for d in deliveries {
        te.push(obj(vec![
            ("name", Json::Str(format!("delivery w{}", d.wave))),
            ("cat", Json::Str("delivery".to_string())),
            ("ph", Json::Str("X".to_string())),
            ("pid", Json::Num(d.shard as f64)),
            ("tid", Json::Num((d.worker + 1) as f64)),
            ("ts", us(d.submit_ns)),
            ("dur", us(d.at_ns.saturating_sub(d.submit_ns))),
            (
                "args",
                obj(vec![
                    ("iter", Json::Num(d.iter as f64)),
                    ("wave", Json::Num(d.wave as f64)),
                    ("worker", Json::Num(d.worker as f64)),
                ]),
            ),
        ]));
    }

    // Remote worker spans, twice each where it helps: every span on
    // its worker-process row, and compute spans additionally as
    // clock-aligned nested slices on the master-side delivery lane —
    // the delivery X slice covers submit→arrival, and the remapped
    // compute slice sits inside it, splitting the delivery into
    // worker-compute vs. network time.
    for ws in worker_spans {
        let dur = ws.end_ns.saturating_sub(ws.start_ns);
        let args = obj(vec![
            ("chunk", Json::Num(ws.chunk as f64)),
            ("iter", Json::Num(ws.iter as f64)),
            ("wave", Json::Num(ws.wave as f64)),
            ("worker", Json::Num(ws.worker as f64)),
        ]);
        te.push(obj(vec![
            ("name", Json::Str(format!("{} i{}", kind_name(ws.kind), ws.iter))),
            ("cat", Json::Str("worker".to_string())),
            ("ph", Json::Str("X".to_string())),
            ("pid", Json::Num((WORKER_PID_BASE + ws.worker) as f64)),
            ("tid", Json::Num(ws.kind as f64)),
            ("ts", us(ws.start_ns)),
            ("dur", us(dur)),
            ("args", args.clone()),
        ]));
        if ws.kind == 0 {
            te.push(obj(vec![
                ("name", Json::Str(format!("compute w{}", ws.wave))),
                ("cat", Json::Str("worker_compute".to_string())),
                ("ph", Json::Str("X".to_string())),
                ("pid", Json::Num(ws.shard as f64)),
                ("tid", Json::Num((ws.worker + 1) as f64)),
                ("ts", us(ws.start_ns)),
                ("dur", us(dur)),
                ("args", args),
            ]));
        }
    }

    for s in events {
        let (pid, inner) = match &s.event {
            Event::Shard { shard, inner } => (*shard, inner.as_ref()),
            e => (0, e),
        };
        if let Some(name) = instant_name(inner) {
            te.push(obj(vec![
                ("name", Json::Str(name.to_string())),
                ("cat", Json::Str("event".to_string())),
                ("ph", Json::Str("i".to_string())),
                ("s", Json::Str("p".to_string())),
                ("pid", Json::Num(pid as f64)),
                ("tid", Json::Num(0.0)),
                ("ts", us(s.at_ns)),
                ("args", inner.to_json()),
            ]));
        }
    }

    obj(vec![
        ("displayTimeUnit", Json::Str("ms".to_string())),
        ("traceEvents", Json::Arr(te)),
    ])
    .to_string()
}
