//! Live scrape endpoint (`--metrics-listen HOST:PORT`).
//!
//! A hand-rolled HTTP/1.1 server over `std::net::TcpListener` — no
//! framework, no dependency — serving four routes while a run is in
//! flight:
//!
//! - `/metrics`  — Prometheus text exposition: the deterministic
//!   fixed-family registry plus the worker-labeled per-link families
//!   ([`Recorder::prometheus_live`]). The `--metrics-out` file
//!   snapshot is unaffected (it stays a pure function of the seed).
//! - `/healthz`  — liveness: `200 ok` as soon as the socket is bound.
//! - `/readyz`   — readiness: `503` until the first protocol round
//!   finishes, `200 ready` after.
//! - `/status`   — a JSON snapshot of the protocol state: round
//!   progress, roster counts, eliminated/crashed workers, per-worker
//!   suspicion scores, and per-shard health. Schema in
//!   `docs/TRACING.md`.
//!
//! The server thread is a daemon: it holds only `Arc`s and dies with
//! the process. Each connection is answered on its own short-lived
//! thread with `Connection: close`, a `Content-Length`, and a read
//! timeout, so a stalled scraper can never wedge the accept loop or
//! the training run (the run never waits on this module — scrapes
//! read the same mutexes the recorder's event path already uses).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::coordinator::events::{Event, EventLog};
use crate::coordinator::metrics::IterationRecord;
use crate::coordinator::WorkerId;
use crate::util::json::Json;
use crate::Result;

use super::{obj, Recorder};

/// Per-shard health row of the `/status` snapshot.
#[derive(Clone, Debug)]
pub struct ShardHealth {
    pub shard: usize,
    /// Active workers when the shard's latest round started.
    pub workers_active: usize,
    /// Latest round duration on the shard transport's clock.
    pub round_ns: u64,
    pub net_reconnects: u64,
    pub dead: bool,
}

/// Everything `/status` reports, refreshed once per finished round.
#[derive(Clone, Debug, Default)]
pub struct Status {
    /// Total workers the run started with.
    pub n: usize,
    /// Configured iteration count.
    pub steps: u64,
    /// Latest finished iteration (meaningful once `ready`).
    pub round: u64,
    pub rounds_finished: u64,
    /// True once the first round finished (`/readyz` gate).
    pub ready: bool,
    /// True once the run returned (the snapshot is final).
    pub done: bool,
    pub eliminated: Vec<WorkerId>,
    pub crashed: Vec<WorkerId>,
    /// Per-worker suspicion scores above zero, ascending by id (the
    /// snapshot the latest round's audit decision used).
    pub suspicion: Vec<(WorkerId, f64)>,
    /// Per-shard breakdown (empty for single-master runs).
    pub shards: Vec<ShardHealth>,
}

impl Status {
    fn to_json(&self) -> Json {
        let ids = |ws: &[WorkerId]| Json::Arr(ws.iter().map(|&w| Json::Num(w as f64)).collect());
        let suspicion = self
            .suspicion
            .iter()
            .map(|&(w, s)| {
                obj(vec![("worker", Json::Num(w as f64)), ("score", Json::Num(s))])
            })
            .collect();
        let shards = self
            .shards
            .iter()
            .map(|s| {
                obj(vec![
                    ("shard", Json::Num(s.shard as f64)),
                    ("workers_active", Json::Num(s.workers_active as f64)),
                    ("round_ns", Json::Num(s.round_ns as f64)),
                    ("net_reconnects", Json::Num(s.net_reconnects as f64)),
                    ("dead", Json::Bool(s.dead)),
                ])
            })
            .collect();
        obj(vec![
            ("n", Json::Num(self.n as f64)),
            ("steps", Json::Num(self.steps as f64)),
            ("round", Json::Num(self.round as f64)),
            ("rounds_finished", Json::Num(self.rounds_finished as f64)),
            ("ready", Json::Bool(self.ready)),
            ("done", Json::Bool(self.done)),
            ("active_workers", Json::Num(self.active() as f64)),
            ("eliminated", ids(&self.eliminated)),
            ("crashed", ids(&self.crashed)),
            ("suspicion", Json::Arr(suspicion)),
            ("shards", Json::Arr(shards)),
        ])
    }

    fn active(&self) -> usize {
        self.n
            .saturating_sub(self.eliminated.len())
            .saturating_sub(self.crashed.len())
    }
}

/// Shared scoreboard behind `/status` and `/readyz`: the master posts
/// one update per finished round ([`StatusBoard::on_round`]), the
/// server threads read snapshots. One mutex, touched once per round
/// and once per scrape — never on the protocol hot path.
pub struct StatusBoard {
    inner: Mutex<Status>,
}

impl StatusBoard {
    #[allow(clippy::new_ret_no_self)]
    pub fn new(n: usize, steps: u64) -> Arc<StatusBoard> {
        Arc::new(StatusBoard {
            inner: Mutex::new(Status { n, steps, ..Status::default() }),
        })
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Status> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Post one finished round: progress/suspicion/shard health from
    /// the metrics record, roster changes rescanned from the event log
    /// (global ids; Eliminated/RosterEliminated/WorkerCrashed).
    pub fn on_round(&self, rec: &IterationRecord, events: &EventLog) {
        let mut eliminated: Vec<WorkerId> = Vec::new();
        let mut crashed: Vec<WorkerId> = Vec::new();
        for e in events.flat() {
            match e {
                Event::Eliminated { worker, .. } => eliminated.push(*worker),
                Event::WorkerCrashed { worker, .. } => crashed.push(*worker),
                _ => {}
            }
        }
        eliminated.sort_unstable();
        eliminated.dedup();
        crashed.sort_unstable();
        crashed.dedup();
        let dead = events.dead_shards();
        let mut s = self.lock();
        s.round = rec.iter;
        s.rounds_finished += 1;
        s.ready = true;
        s.eliminated = eliminated;
        s.crashed = crashed;
        s.suspicion = rec.suspicion.clone();
        s.shards = rec
            .shard_stats
            .iter()
            .map(|st| ShardHealth {
                shard: st.shard,
                workers_active: st.workers_active,
                round_ns: st.round_ns,
                net_reconnects: st.net_reconnects,
                dead: dead.contains(&st.shard),
            })
            .collect();
    }

    /// The run returned; the snapshot is final.
    pub fn mark_done(&self) {
        let mut s = self.lock();
        s.done = true;
        s.ready = true;
    }

    pub fn snapshot(&self) -> Status {
        self.lock().clone()
    }
}

/// Bind `addr` and serve scrapes on a daemon thread; returns the bound
/// address (port 0 picks a free one, as `--listen` does for workers).
pub fn spawn(addr: &str, rec: Arc<Recorder>, board: Arc<StatusBoard>) -> Result<SocketAddr> {
    let listener =
        TcpListener::bind(addr).map_err(|e| anyhow::anyhow!("bind {addr}: {e}"))?;
    let bound = listener.local_addr()?;
    std::thread::Builder::new()
        .name("r3bft-metrics-http".into())
        .spawn(move || {
            for stream in listener.incoming() {
                let Ok(stream) = stream else { continue };
                let rec = rec.clone();
                let board = board.clone();
                // one short-lived thread per connection: a stalled
                // scraper blocks its own thread, never the accept loop
                let _ = std::thread::Builder::new()
                    .name("r3bft-metrics-conn".into())
                    .spawn(move || handle(stream, &rec, &board));
            }
        })?;
    Ok(bound)
}

/// Max bytes of request head we will buffer before answering.
const MAX_REQUEST: usize = 8 * 1024;

fn handle(mut stream: TcpStream, rec: &Recorder, board: &StatusBoard) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(2)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(2)));
    let Some((method, path)) = read_request_line(&mut stream) else {
        return;
    };
    if method != "GET" {
        respond(&mut stream, "405 Method Not Allowed", "text/plain", "GET only\n");
        return;
    }
    // strip any query string; scrapers sometimes append cache-busters
    let path = path.split('?').next().unwrap_or("");
    match path {
        "/metrics" => respond(
            &mut stream,
            "200 OK",
            "text/plain; version=0.0.4",
            &rec.prometheus_live(),
        ),
        "/healthz" => respond(&mut stream, "200 OK", "text/plain", "ok\n"),
        "/readyz" => {
            if board.lock().ready {
                respond(&mut stream, "200 OK", "text/plain", "ready\n");
            } else {
                respond(
                    &mut stream,
                    "503 Service Unavailable",
                    "text/plain",
                    "no round finished yet\n",
                );
            }
        }
        "/status" => {
            let body = board.lock().to_json().to_string();
            respond(&mut stream, "200 OK", "application/json", &body);
        }
        _ => respond(
            &mut stream,
            "404 Not Found",
            "text/plain",
            "routes: /metrics /healthz /readyz /status\n",
        ),
    }
}

/// Read up to the end of the request head and parse the request line
/// into (method, path). Anything malformed or oversized yields `None`
/// (the connection is just dropped).
fn read_request_line(stream: &mut TcpStream) -> Option<(String, String)> {
    let mut buf = Vec::new();
    let mut chunk = [0u8; 512];
    loop {
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                if buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.len() >= MAX_REQUEST {
                    break;
                }
            }
            Err(_) => return None,
        }
    }
    let head = String::from_utf8_lossy(&buf);
    let line = head.lines().next()?;
    let mut parts = line.split_whitespace();
    let method = parts.next()?.to_string();
    let path = parts.next()?.to_string();
    Some((method, path))
}

fn respond(stream: &mut TcpStream, status: &str, ctype: &str, body: &str) {
    let _ = write!(
        stream,
        "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    let _ = stream.flush();
}

#[cfg(test)]
mod tests {
    use super::*;

    fn get(addr: SocketAddr, path: &str) -> (String, String) {
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut text = String::new();
        s.read_to_string(&mut text).unwrap();
        let (head, body) = text.split_once("\r\n\r\n").expect("header/body split");
        (head.to_string(), body.to_string())
    }

    #[test]
    fn endpoint_serves_all_routes() {
        let rec = Recorder::new();
        let board = StatusBoard::new(8, 50);
        let addr = spawn("127.0.0.1:0", rec.clone(), board.clone()).unwrap();

        let (head, body) = get(addr, "/healthz");
        assert!(head.starts_with("HTTP/1.1 200"), "healthz: {head}");
        assert_eq!(body, "ok\n");
        assert!(head.contains("Content-Length: 3"));
        assert!(head.contains("Connection: close"));

        // not ready until a round finishes
        let (head, _) = get(addr, "/readyz");
        assert!(head.starts_with("HTTP/1.1 503"), "readyz before a round: {head}");

        // /metrics serves the full deterministic family set mid-run
        let (head, body) = get(addr, "/metrics");
        assert!(head.starts_with("HTTP/1.1 200"));
        assert!(head.contains("text/plain; version=0.0.4"));
        assert!(body.contains("r3bft_rounds_total 0"));
        assert!(body.contains("# TYPE r3bft_round_time_ns histogram"));

        // a finished round flips readiness and fills /status
        let mut events = EventLog::default();
        events.push(Event::Eliminated { iter: 3, worker: 2 });
        let rec_row = IterationRecord {
            iter: 3,
            suspicion: vec![(2, 0.75)],
            ..IterationRecord::default()
        };
        board.on_round(&rec_row, &events);
        let (head, _) = get(addr, "/readyz");
        assert!(head.starts_with("HTTP/1.1 200"), "readyz after a round: {head}");
        let (head, body) = get(addr, "/status");
        assert!(head.starts_with("HTTP/1.1 200"));
        assert!(head.contains("application/json"));
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.req("round").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.req("n").unwrap().as_f64(), Some(8.0));
        assert_eq!(j.req("ready").unwrap().as_bool(), Some(true));
        assert_eq!(j.req("done").unwrap().as_bool(), Some(false));
        assert_eq!(j.req("active_workers").unwrap().as_f64(), Some(7.0));
        assert_eq!(j.req_arr("eliminated").unwrap().len(), 1);
        assert_eq!(j.req_arr("suspicion").unwrap().len(), 1);

        board.mark_done();
        let (_, body) = get(addr, "/status");
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.req("done").unwrap().as_bool(), Some(true));

        let (head, _) = get(addr, "/nope");
        assert!(head.starts_with("HTTP/1.1 404"));
    }

    #[test]
    fn non_get_methods_are_refused() {
        let rec = Recorder::new();
        let board = StatusBoard::new(1, 1);
        let addr = spawn("127.0.0.1:0", rec, board).unwrap();
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "POST /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut text = String::new();
        s.read_to_string(&mut text).unwrap();
        assert!(text.starts_with("HTTP/1.1 405"), "{text}");
    }
}
