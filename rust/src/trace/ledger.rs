//! Forensic evidence ledger: one chain per detected fault, from the
//! policy coin that triggered the audit to the eliminations the vote
//! produced.
//!
//! The paper's exactness claim (Definition 1) is only as good as the
//! audit trail: a worker is eliminated iff a 2f_t+1 majority vote over
//! bit-exact symbol copies named it a liar. The ledger materializes
//! that trail as data — the audited chunk, the disagreeing
//! packed-symbol hashes ([`crate::coordinator::codes::copy_key`]),
//! the reactive top-up, the vote tally — so a red-team harness or an
//! operator can check it per elimination instead of trusting the
//! counter.
//!
//! Chains are keyed by `(shard, iter, chunk)` with shard-local chunk
//! indexes (the parameter server's global chunk remap happens above
//! the core that owns the evidence).

use crate::coordinator::{ChunkId, Event, WorkerId, MASTER_SENTINEL};
use crate::util::json::Json;

use super::obj;

/// The disagreeing copies behind a detection: each owner's
/// packed-symbol hash (wire bytes when the symbol travelled packed,
/// dense f32 bits otherwise). The master's self-check copy appears as
/// [`MASTER_SENTINEL`].
#[derive(Clone, Debug, PartialEq)]
pub struct DetectionEvidence {
    pub hashes: Vec<(WorkerId, u64)>,
}

/// The resolved vote: tally of copies per distinct hash, the winning
/// hash, and the workers whose copies disagreed with it.
#[derive(Clone, Debug, PartialEq)]
pub struct VoteEvidence {
    /// `(hash, copies)` sorted by hash.
    pub tally: Vec<(u64, usize)>,
    pub winner: u64,
    pub liars: Vec<WorkerId>,
}

/// One fault's full evidence chain: audit coin → detection → reactive
/// top-up → vote → eliminations.
#[derive(Clone, Debug, PartialEq)]
pub struct EvidenceChain {
    pub shard: usize,
    pub iter: u64,
    /// Shard-local chunk index.
    pub chunk: ChunkId,
    /// The policy coin of the audit decision that exposed the fault.
    pub q: f64,
    pub audited: bool,
    pub detection: Option<DetectionEvidence>,
    /// Workers the reactive phase added to reach 2f_t+1 copies (empty
    /// under `--self-check`, where the master recomputes instead).
    pub topup: Vec<WorkerId>,
    pub vote: Option<VoteEvidence>,
    /// Workers eliminated on this chain's vote.
    pub eliminated: Vec<WorkerId>,
}

impl EvidenceChain {
    /// A chain is complete when all three replication-path stages are
    /// present: detection hashes, a reactive top-up, and a vote. (The
    /// self-check path legitimately has no top-up; callers asserting
    /// completeness should know which path the run used.)
    pub fn complete(&self) -> bool {
        self.detection.is_some() && !self.topup.is_empty() && self.vote.is_some()
    }

    pub fn to_json(&self) -> Json {
        fn worker_json(w: WorkerId) -> Json {
            if w == MASTER_SENTINEL {
                Json::Str("master".to_string())
            } else {
                Json::Num(w as f64)
            }
        }
        fn workers_json(ws: &[WorkerId]) -> Json {
            Json::Arr(ws.iter().map(|&w| worker_json(w)).collect())
        }
        let detection = match &self.detection {
            Some(d) => Json::Arr(
                d.hashes
                    .iter()
                    .map(|(w, h)| {
                        obj(vec![
                            ("worker", worker_json(*w)),
                            ("hash", Json::Str(format!("{h:016x}"))),
                        ])
                    })
                    .collect(),
            ),
            None => Json::Null,
        };
        let vote = match &self.vote {
            Some(v) => obj(vec![
                (
                    "tally",
                    Json::Arr(
                        v.tally
                            .iter()
                            .map(|(h, n)| {
                                obj(vec![
                                    ("hash", Json::Str(format!("{h:016x}"))),
                                    ("copies", Json::Num(*n as f64)),
                                ])
                            })
                            .collect(),
                    ),
                ),
                ("winner", Json::Str(format!("{:016x}", v.winner))),
                ("liars", workers_json(&v.liars)),
            ]),
            None => Json::Null,
        };
        obj(vec![
            ("shard", Json::Num(self.shard as f64)),
            ("iter", Json::Num(self.iter as f64)),
            ("chunk", Json::Num(self.chunk as f64)),
            ("q", Json::Num(self.q)),
            ("audited", Json::Bool(self.audited)),
            ("detection", detection),
            ("topup", workers_json(&self.topup)),
            ("vote", vote),
            ("eliminated", workers_json(&self.eliminated)),
            ("complete", Json::Bool(self.complete())),
        ])
    }
}

/// Assembles chains from the interleaved event/evidence stream. All
/// worker ids arriving here are already global.
#[derive(Default)]
pub struct Ledger {
    /// Last audit decision seen per shard: `(shard, iter, q, audited)`.
    last_audit: Vec<(usize, u64, f64, bool)>,
    pub chains: Vec<EvidenceChain>,
}

impl Ledger {
    fn chain_mut(&mut self, shard: usize, iter: u64, chunk: ChunkId) -> &mut EvidenceChain {
        if let Some(i) = self
            .chains
            .iter()
            .rposition(|c| c.shard == shard && c.iter == iter && c.chunk == chunk)
        {
            return &mut self.chains[i];
        }
        let (q, audited) = self
            .last_audit
            .iter()
            .find(|(s, i, _, _)| *s == shard && *i == iter)
            .map(|(_, _, q, a)| (*q, *a))
            .unwrap_or((0.0, false));
        self.chains.push(EvidenceChain {
            shard,
            iter,
            chunk,
            q,
            audited,
            detection: None,
            topup: Vec::new(),
            vote: None,
            eliminated: Vec::new(),
        });
        self.chains.last_mut().expect("just pushed")
    }

    /// Feed a protocol event (already unwrapped and id-remapped).
    pub fn observe(&mut self, shard: usize, e: &Event) {
        match e {
            Event::AuditDecision { iter, q, audited } => {
                match self.last_audit.iter_mut().find(|(s, _, _, _)| *s == shard) {
                    Some(slot) => *slot = (shard, *iter, *q, *audited),
                    None => self.last_audit.push((shard, *iter, *q, *audited)),
                }
            }
            Event::ReactiveRedundancy { iter, chunk, added } => {
                let chain = self.chain_mut(shard, *iter, *chunk);
                chain.topup.extend_from_slice(added);
            }
            Event::Eliminated { iter, worker } => {
                // Attach to the chain whose vote named this worker.
                if let Some(c) = self.chains.iter_mut().rev().find(|c| {
                    c.shard == shard
                        && c.iter == *iter
                        && c.vote.as_ref().is_some_and(|v| v.liars.contains(worker))
                }) {
                    c.eliminated.push(*worker);
                }
            }
            _ => {}
        }
    }

    pub fn on_detection(
        &mut self,
        shard: usize,
        iter: u64,
        chunk: ChunkId,
        hashes: Vec<(WorkerId, u64)>,
    ) {
        self.chain_mut(shard, iter, chunk).detection = Some(DetectionEvidence { hashes });
    }

    pub fn on_vote(
        &mut self,
        shard: usize,
        iter: u64,
        chunk: ChunkId,
        tally: Vec<(u64, usize)>,
        winner: u64,
        liars: Vec<WorkerId>,
    ) {
        self.chain_mut(shard, iter, chunk).vote = Some(VoteEvidence { tally, winner, liars });
    }

    /// Chains whose vote named `worker` (global id) a liar.
    pub fn evidence_for(&self, worker: WorkerId) -> Vec<EvidenceChain> {
        self.chains
            .iter()
            .filter(|c| c.vote.as_ref().is_some_and(|v| v.liars.contains(&worker)))
            .cloned()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_assembles_in_protocol_order() {
        let mut l = Ledger::default();
        l.observe(0, &Event::AuditDecision { iter: 3, q: 0.4, audited: true });
        l.on_detection(0, 3, 2, vec![(1, 0xaa), (5, 0xbb)]);
        l.observe(0, &Event::ReactiveRedundancy { iter: 3, chunk: 2, added: vec![0, 4, 6] });
        l.on_vote(0, 3, 2, vec![(0xaa, 4), (0xbb, 1)], 0xaa, vec![5]);
        l.observe(0, &Event::Eliminated { iter: 3, worker: 5 });

        assert_eq!(l.chains.len(), 1);
        let c = &l.chains[0];
        assert!(c.complete());
        assert_eq!(c.q, 0.4);
        assert!(c.audited);
        assert_eq!(c.eliminated, vec![5]);
        assert_eq!(l.evidence_for(5).len(), 1);
        assert!(l.evidence_for(1).is_empty());
    }

    #[test]
    fn chains_are_keyed_per_shard_and_chunk() {
        let mut l = Ledger::default();
        l.on_detection(0, 1, 0, vec![(0, 1), (1, 2)]);
        l.on_detection(1, 1, 0, vec![(8, 3), (9, 4)]);
        assert_eq!(l.chains.len(), 2);
        assert!(!l.chains[0].complete());
    }

    #[test]
    fn incomplete_without_topup() {
        let mut l = Ledger::default();
        l.on_detection(0, 0, 1, vec![(2, 7), (3, 8)]);
        l.on_vote(0, 0, 1, vec![(7, 3), (8, 1)], 7, vec![3]);
        assert!(!l.chains[0].complete());
        let j = l.chains[0].to_json().to_string();
        assert!(j.contains("\"complete\":false"));
    }
}
