//! Structured tracing, flight recorder, and metrics exposition.
//!
//! Everything the protocol does is observable from three choke points:
//! the single [`Event`] emit path in `ProtocolCore`, the wave
//! submit/collect boundaries around the transport, and the round
//! finish. A [`Recorder`] taps all three through per-core
//! [`TraceHandle`]s and turns the stream into four artifacts:
//!
//! - **Spans** on the transport clock (virtual ns under `--transport
//!   sim`, wall-clock under `threaded`): one [`RoundSpan`] per
//!   protocol round, one [`WaveSpan`] per submitted wave (proactive,
//!   detection, reactive — including reissued pipelined waves), one
//!   [`DeliverySpan`] per accepted worker response. Exported as Chrome
//!   trace-event JSON by [`Recorder::chrome_trace`] (see
//!   [`chrome`]).
//! - **Stamped events**: every [`Event`] with a transport-clock
//!   timestamp and a global sequence number, exported as JSONL by
//!   [`Recorder::events_jsonl`] or streamed live through
//!   [`Recorder::set_events_sink`].
//! - **Evidence ledger** ([`ledger`]): per identification, the full
//!   chain the paper's exactness argument rests on — the audited
//!   chunk, the disagreeing packed-symbol hashes, the reactive top-up,
//!   and the 2f_t+1 vote tally, keyed back to the policy coin that
//!   triggered the audit.
//! - **Metrics registry** ([`metrics`]): counters and a round-time
//!   histogram, snapshotted as Prometheus text format by
//!   [`Recorder::prometheus`].
//!
//! A bounded ring of recent activity backs the **flight recorder**: on
//! an anomaly (elimination, shard death, oracle faulty update,
//! dead-wave reissue) the ring and the relevant evidence chains are
//! frozen into a [`ForensicBundle`].
//!
//! Zero-cost when disabled: each core holds an `Option<TraceHandle>`
//! checked once per event/wave/round — never in the per-symbol hot
//! loop — and no `Recorder` is ever constructed unless an export flag
//! asked for one. Under the sim transport the entire output is a pure
//! function of the seed: same seed ⇒ byte-identical trace, JSONL, and
//! metrics files.

pub mod chrome;
pub mod http;
pub mod ledger;
pub mod metrics;

use std::collections::{BTreeMap, VecDeque};
use std::io::Write;
use std::sync::{Arc, Mutex};

use crate::coordinator::codes::{copy_key, SymbolCopy};
use crate::coordinator::transport::{LinkStats, RemoteSpan};
use crate::coordinator::{ChunkId, Event, WorkerId, MASTER_SENTINEL};
use crate::util::json::Json;

use ledger::{EvidenceChain, Ledger};
use metrics::Registry;

/// Flight-recorder ring capacity (recent spans/events kept for dumps).
pub const RING_CAP: usize = 256;
/// Hard cap on retained forensic bundles (an elimination storm must
/// not grow memory without bound; the first `MAX_BUNDLES` anomalies
/// are the interesting ones anyway).
pub const MAX_BUNDLES: usize = 64;

/// An [`Event`] with its transport-clock timestamp and a global
/// sequence number (the JSONL line order).
#[derive(Clone, Debug)]
pub struct StampedEvent {
    pub seq: u64,
    /// Transport-clock ns of the shard that emitted the event (for
    /// master-level events: the emitting shard's watermark).
    pub at_ns: u64,
    /// Shard-wrapped for sharded cores, exactly like the `EventLog`.
    pub event: Event,
}

/// One transport wave: submit → gather, on the transport clock.
#[derive(Clone, Debug)]
pub struct WaveSpan {
    pub shard: usize,
    pub iter: u64,
    pub wave: u64,
    /// Phase wire code: 0 proactive, 1 detection, 2 reactive.
    pub phase: u8,
    pub start_ns: u64,
    pub end_ns: u64,
    /// Workers the wave was submitted to.
    pub workers: usize,
    /// Responses accepted by the gather.
    pub responses: usize,
    /// True when the wave was retired unconsumed by a pipelined
    /// reissue (speculation on a provisional θ that missed).
    pub reissued: bool,
    /// False while the wave is still in flight.
    pub closed: bool,
}

/// One accepted worker response within a wave.
#[derive(Clone, Debug)]
pub struct DeliverySpan {
    pub shard: usize,
    pub iter: u64,
    pub wave: u64,
    /// Global worker id.
    pub worker: WorkerId,
    pub submit_ns: u64,
    pub at_ns: u64,
}

/// One worker-*process* span shipped over a telemetry-enabled net
/// link: a remote compute/decode/encode interval, already remapped
/// onto the master transport clock by the link's offset estimate and
/// onto global worker ids by the handle. Rendered as dedicated
/// worker-process rows in the Chrome export (see [`chrome`]).
#[derive(Clone, Debug)]
pub struct WorkerSpan {
    pub shard: usize,
    /// Global worker id.
    pub worker: WorkerId,
    /// `SPAN_COMPUTE` / `SPAN_DECODE` / `SPAN_ENCODE` (see
    /// `coordinator::transport::net::frame`).
    pub kind: u8,
    pub iter: u64,
    pub wave: u64,
    pub chunk: u64,
    /// Master-transport-clock ns.
    pub start_ns: u64,
    pub end_ns: u64,
}

/// One finished protocol round (per shard core).
#[derive(Clone, Debug)]
pub struct RoundSpan {
    pub shard: usize,
    pub iter: u64,
    pub start_ns: u64,
    pub end_ns: u64,
    /// Exclusive duration as reported in metrics (`round_time`).
    pub round_ns: u64,
    /// Honest wire bytes moved this round.
    pub bytes: u64,
}

/// One line of the flight-recorder ring: a terse, human-readable
/// record of recent activity.
#[derive(Clone, Debug)]
pub struct RingEntry {
    pub at_ns: u64,
    pub shard: usize,
    pub what: String,
}

/// Everything frozen when an anomaly fired: the reason, the recent
/// ring, and the evidence chains relevant to the anomaly.
#[derive(Clone, Debug)]
pub struct ForensicBundle {
    pub reason: String,
    pub shard: usize,
    pub iter: u64,
    pub at_ns: u64,
    pub ring: Vec<RingEntry>,
    pub evidence: Vec<EvidenceChain>,
}

impl ForensicBundle {
    pub fn to_json(&self) -> Json {
        let ring = self
            .ring
            .iter()
            .map(|r| {
                obj(vec![
                    ("at_ns", Json::Num(r.at_ns as f64)),
                    ("shard", Json::Num(r.shard as f64)),
                    ("what", Json::Str(r.what.clone())),
                ])
            })
            .collect();
        obj(vec![
            ("reason", Json::Str(self.reason.clone())),
            ("shard", Json::Num(self.shard as f64)),
            ("iter", Json::Num(self.iter as f64)),
            ("at_ns", Json::Num(self.at_ns as f64)),
            ("ring", Json::Arr(ring)),
            (
                "evidence",
                Json::Arr(self.evidence.iter().map(|c| c.to_json()).collect()),
            ),
        ])
    }
}

pub(crate) fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[derive(Default)]
struct Inner {
    seq: u64,
    events: Vec<StampedEvent>,
    waves: Vec<WaveSpan>,
    deliveries: Vec<DeliverySpan>,
    rounds: Vec<RoundSpan>,
    /// Remote worker-process spans (net telemetry; empty otherwise).
    worker_spans: Vec<WorkerSpan>,
    /// Latest per-link health snapshot, by global worker id — the
    /// worker-labeled families of the live scrape.
    links: BTreeMap<WorkerId, LinkStats>,
    ring: VecDeque<RingEntry>,
    bundles: Vec<ForensicBundle>,
    ledger: Ledger,
    registry: Registry,
    /// Per-shard high-water mark of observed transport-clock ns, used
    /// to stamp master-level events that carry no clock of their own.
    watermark: Vec<(usize, u64)>,
    sink: Option<Box<dyn Write + Send>>,
}

impl Inner {
    fn note_ns(&mut self, shard: usize, at_ns: u64) {
        match self.watermark.iter_mut().find(|(s, _)| *s == shard) {
            Some((_, w)) => *w = (*w).max(at_ns),
            None => self.watermark.push((shard, at_ns)),
        }
    }

    fn watermark(&self, shard: Option<usize>) -> u64 {
        match shard {
            Some(s) => self
                .watermark
                .iter()
                .find(|(w, _)| *w == s)
                .map(|(_, ns)| *ns)
                .unwrap_or(0),
            None => self.watermark.iter().map(|(_, ns)| *ns).max().unwrap_or(0),
        }
    }

    fn ring_push(&mut self, at_ns: u64, shard: usize, what: String) {
        if self.ring.len() == RING_CAP {
            self.ring.pop_front();
        }
        self.ring.push_back(RingEntry { at_ns, shard, what });
    }

    /// Record one (already id-remapped, optionally shard-wrapped)
    /// event: stamp, stream, feed the ledger and counters, and dump a
    /// forensic bundle when the event is an anomaly.
    fn record_event(&mut self, shard: usize, wrapped: Event, at_ns: u64) {
        self.note_ns(shard, at_ns);
        let inner: &Event = match &wrapped {
            Event::Shard { inner, .. } => inner,
            e => e,
        };
        self.registry.count_event(inner);
        self.ledger.observe(shard, inner);
        self.ring_push(at_ns, shard, format!("{inner:?}"));

        let anomaly = match inner {
            Event::Eliminated { iter, worker } => Some((
                *iter,
                format!("worker {worker} eliminated"),
                self.ledger.evidence_for(*worker),
            )),
            Event::ShardDead { iter, shard } => {
                Some((*iter, format!("shard {shard} dead"), Vec::new()))
            }
            Event::OracleFaultyUpdate { iter } => {
                Some((*iter, "oracle faulty update".to_string(), Vec::new()))
            }
            // a net session-break is forensic material too: what was
            // in flight when the link flapped is exactly what a
            // post-mortem of a suspected-Byzantine link needs
            Event::NetReconnect { iter, worker } => Some((
                *iter,
                format!("net session-break (worker {worker} reconnected)"),
                Vec::new(),
            )),
            _ => None,
        };
        if let Some((iter, reason, evidence)) = anomaly {
            self.dump_bundle(reason, shard, iter, at_ns, evidence);
        }

        let seq = self.seq;
        self.seq += 1;
        let stamped = StampedEvent { seq, at_ns, event: wrapped };
        if let Some(sink) = &mut self.sink {
            let _ = writeln!(sink, "{}", jsonl_line(&stamped));
        }
        self.events.push(stamped);
    }

    fn dump_bundle(
        &mut self,
        reason: String,
        shard: usize,
        iter: u64,
        at_ns: u64,
        evidence: Vec<EvidenceChain>,
    ) {
        if self.bundles.len() >= MAX_BUNDLES {
            return;
        }
        self.bundles.push(ForensicBundle {
            reason,
            shard,
            iter,
            at_ns,
            ring: self.ring.iter().cloned().collect(),
            evidence,
        });
    }
}

fn jsonl_line(s: &StampedEvent) -> String {
    obj(vec![
        ("seq", Json::Num(s.seq as f64)),
        ("at_ns", Json::Num(s.at_ns as f64)),
        ("event", s.event.to_json()),
    ])
    .to_string()
}

/// The recorder: one per run, shared by every core through cheap
/// [`TraceHandle`]s. All state sits behind one mutex — contention is
/// bounded by event rate (per wave / per round, never per symbol).
pub struct Recorder {
    inner: Mutex<Inner>,
}

impl Recorder {
    #[allow(clippy::new_ret_no_self)]
    pub fn new() -> Arc<Recorder> {
        Arc::new(Recorder { inner: Mutex::new(Inner::default()) })
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Handle for a single-master core (no shard wrapping, global ids
    /// already).
    pub fn handle(self: Arc<Self>) -> TraceHandle {
        TraceHandle { rec: self, shard: None, lo: 0 }
    }

    /// Handle for shard `shard` whose local worker 0 is global `lo`:
    /// the handle remaps ids and shard-wraps events exactly like the
    /// `EventLog` the master keeps.
    pub fn shard_handle(self: Arc<Self>, shard: usize, lo: WorkerId) -> TraceHandle {
        TraceHandle { rec: self, shard: Some(shard), lo }
    }

    /// Stream every subsequent event as one JSONL line to `sink`
    /// (events are always buffered in memory as well).
    pub fn set_events_sink(&self, sink: Box<dyn Write + Send>) {
        self.lock().sink = Some(sink);
    }

    /// Flush and drop the streaming sink (call after the run).
    pub fn close_events_sink(&self) {
        let mut inner = self.lock();
        if let Some(mut sink) = inner.sink.take() {
            let _ = sink.flush();
        }
    }

    /// Record a master-level event (already global ids, not
    /// shard-wrapped): `ShardDead`, `RosterEliminated`,
    /// `OracleFaultyUpdate`. Stamped with the named shard's clock
    /// watermark (or the global maximum when `shard` is `None`) —
    /// there is no cross-shard clock, so the watermark is the latest
    /// instant the recorder can causally order the event after.
    pub fn on_master_event(&self, shard: Option<usize>, e: &Event) {
        let mut inner = self.lock();
        let at_ns = inner.watermark(shard);
        inner.record_event(shard.unwrap_or(0), e.clone(), at_ns);
    }

    // -- exporters ---------------------------------------------------------

    /// Chrome trace-event JSON (open in Perfetto or chrome://tracing).
    pub fn chrome_trace(&self) -> String {
        let inner = self.lock();
        chrome::render(
            &inner.waves,
            &inner.deliveries,
            &inner.rounds,
            &inner.events,
            &inner.worker_spans,
        )
    }

    /// The stamped event stream as JSON Lines.
    pub fn events_jsonl(&self) -> String {
        let inner = self.lock();
        let mut out = String::new();
        for s in &inner.events {
            out.push_str(&jsonl_line(s));
            out.push('\n');
        }
        out
    }

    /// Prometheus text-format snapshot of the metrics registry — the
    /// deterministic fixed-family set `--metrics-out` writes.
    pub fn prometheus(&self) -> String {
        self.lock().registry.render()
    }

    /// The live-scrape variant (`/metrics` on `--metrics-listen`): the
    /// deterministic fixed-family set of [`Recorder::prometheus`] plus
    /// the worker-labeled per-link families (RTT/offset gauges,
    /// resend/reconnect/auth-reject/dup/chaos counters) — present only
    /// once a telemetry-enabled net transport has reported links.
    pub fn prometheus_live(&self) -> String {
        let inner = self.lock();
        let mut out = inner.registry.render();
        out.push_str(&metrics::render_labeled(&inner.links));
        out
    }

    /// All forensic bundles as one JSON document.
    pub fn flight_json(&self) -> String {
        let inner = self.lock();
        obj(vec![
            ("bundles", Json::Arr(inner.bundles.iter().map(|b| b.to_json()).collect())),
            (
                "evidence",
                Json::Arr(inner.ledger.chains.iter().map(|c| c.to_json()).collect()),
            ),
        ])
        .to_string()
    }

    // -- queries (tests, red-team harness) ---------------------------------

    pub fn bundles(&self) -> Vec<ForensicBundle> {
        self.lock().bundles.clone()
    }

    /// Evidence chains whose vote named `worker` (global id) a liar.
    pub fn evidence_for(&self, worker: WorkerId) -> Vec<EvidenceChain> {
        self.lock().ledger.evidence_for(worker)
    }

    pub fn evidence_chains(&self) -> Vec<EvidenceChain> {
        self.lock().ledger.chains.clone()
    }

    pub fn wave_spans(&self) -> Vec<WaveSpan> {
        self.lock().waves.clone()
    }

    pub fn round_spans(&self) -> Vec<RoundSpan> {
        self.lock().rounds.clone()
    }

    pub fn stamped_events(&self) -> Vec<StampedEvent> {
        self.lock().events.clone()
    }

    pub fn worker_spans(&self) -> Vec<WorkerSpan> {
        self.lock().worker_spans.clone()
    }

    /// Latest per-link health snapshots, keyed by global worker id.
    pub fn links(&self) -> BTreeMap<WorkerId, LinkStats> {
        self.lock().links.clone()
    }

    /// Current value of a registry counter (see [`metrics::COUNTERS`]).
    pub fn counter(&self, name: &str) -> u64 {
        self.lock().registry.get(name)
    }
}

/// Per-core handle: carries the shard identity and the local→global
/// worker-id offset so the core can report local ids and the recorder
/// stores global ones. Cloneable and cheap; every method takes `&self`
/// and locks the recorder once.
#[derive(Clone)]
pub struct TraceHandle {
    rec: Arc<Recorder>,
    /// `None` for the single-master core (ids already global, events
    /// stored unwrapped).
    shard: Option<usize>,
    lo: WorkerId,
}

impl TraceHandle {
    fn global(&self, w: WorkerId) -> WorkerId {
        if w == MASTER_SENTINEL {
            w
        } else {
            w + self.lo
        }
    }

    fn shard_idx(&self) -> usize {
        self.shard.unwrap_or(0)
    }

    /// An [`Event`] passed through the core's emit path, stamped with
    /// the core's transport clock.
    pub fn on_event(&self, at_ns: u64, e: &Event) {
        let remapped = e.map_workers(&mut |w| self.global(w));
        let wrapped = match self.shard {
            Some(shard) => Event::Shard { shard, inner: Box::new(remapped) },
            None => remapped,
        };
        self.rec.lock().record_event(self.shard_idx(), wrapped, at_ns);
    }

    /// A wave was submitted to `workers` workers.
    pub fn wave_begin(&self, iter: u64, wave: u64, phase: u8, start_ns: u64, workers: usize) {
        let shard = self.shard_idx();
        let mut inner = self.rec.lock();
        inner.note_ns(shard, start_ns);
        inner.registry.inc_wave();
        inner.ring_push(
            start_ns,
            shard,
            format!("wave {wave} begin (iter {iter}, phase {phase}, {workers} workers)"),
        );
        inner.waves.push(WaveSpan {
            shard,
            iter,
            wave,
            phase,
            start_ns,
            end_ns: start_ns,
            workers,
            responses: 0,
            reissued: false,
            closed: false,
        });
    }

    /// The gather for `wave` stopped waiting with `responses` accepted.
    pub fn wave_end(&self, wave: u64, end_ns: u64, responses: usize) {
        let shard = self.shard_idx();
        let mut inner = self.rec.lock();
        inner.note_ns(shard, end_ns);
        if let Some(w) = inner
            .waves
            .iter_mut()
            .rev()
            .find(|w| w.shard == shard && w.wave == wave && !w.closed)
        {
            w.end_ns = end_ns;
            w.responses = responses;
            w.closed = true;
        }
        inner.ring_push(end_ns, shard, format!("wave {wave} end ({responses} responses)"));
    }

    /// A pipelined speculative wave was retired unconsumed (the audit
    /// changed θ) — an anomaly worth a forensic bundle: reissue storms
    /// are how mispredicted speculation shows up.
    pub fn wave_reissued(&self, iter: u64, wave: u64, at_ns: u64) {
        let shard = self.shard_idx();
        let mut inner = self.rec.lock();
        inner.note_ns(shard, at_ns);
        inner.registry.inc_reissue();
        if let Some(w) = inner
            .waves
            .iter_mut()
            .rev()
            .find(|w| w.shard == shard && w.wave == wave && !w.closed)
        {
            w.end_ns = at_ns;
            w.reissued = true;
            w.closed = true;
        }
        inner.ring_push(at_ns, shard, format!("wave {wave} reissued (iter {iter})"));
        inner.dump_bundle(
            format!("dead-wave reissue (wave {wave})"),
            shard,
            iter,
            at_ns,
            Vec::new(),
        );
    }

    /// One response accepted by the gather (`worker` is core-local).
    pub fn delivery(&self, iter: u64, wave: u64, worker: WorkerId, submit_ns: u64, at_ns: u64) {
        let shard = self.shard_idx();
        let worker = self.global(worker);
        let mut inner = self.rec.lock();
        inner.note_ns(shard, at_ns);
        inner.registry.inc_delivery();
        inner.deliveries.push(DeliverySpan { shard, iter, wave, worker, submit_ns, at_ns });
    }

    /// Detection found disagreeing copies on `chunk`: record each
    /// copy's packed-symbol hash against its (global) owner.
    pub fn detection_evidence(&self, at_ns: u64, iter: u64, chunk: ChunkId, copies: &[SymbolCopy]) {
        let shard = self.shard_idx();
        let hashes: Vec<(WorkerId, u64)> =
            copies.iter().map(|c| (self.global(c.worker), copy_key(c))).collect();
        let mut inner = self.rec.lock();
        inner.note_ns(shard, at_ns);
        inner.ring_push(at_ns, shard, format!("detection evidence chunk {chunk} (iter {iter})"));
        inner.ledger.on_detection(shard, iter, chunk, hashes);
    }

    /// The vote on `chunk` resolved: record the tally over
    /// packed-symbol hashes, the winning hash, and the liars.
    pub fn vote_evidence(
        &self,
        at_ns: u64,
        iter: u64,
        chunk: ChunkId,
        copies: &[SymbolCopy],
        winner: &SymbolCopy,
        liars: &[WorkerId],
    ) {
        let shard = self.shard_idx();
        let mut tally: Vec<(u64, usize)> = Vec::new();
        for c in copies {
            let k = copy_key(c);
            match tally.iter_mut().find(|(h, _)| *h == k) {
                Some((_, n)) => *n += 1,
                None => tally.push((k, 1)),
            }
        }
        tally.sort_unstable();
        let winner_key = copy_key(winner);
        let liars: Vec<WorkerId> = liars.iter().map(|&w| self.global(w)).collect();
        let mut inner = self.rec.lock();
        inner.note_ns(shard, at_ns);
        inner.ring_push(
            at_ns,
            shard,
            format!("vote chunk {chunk} (iter {iter}, {} liars)", liars.len()),
        );
        inner.ledger.on_vote(shard, iter, chunk, tally, winner_key, liars);
    }

    /// Worker-side telemetry spans drained from a net transport,
    /// already on the master transport clock; ids are core-local and
    /// remapped to global here.
    pub fn remote_spans(&self, spans: Vec<RemoteSpan>) {
        let shard = self.shard_idx();
        let mut inner = self.rec.lock();
        for s in spans {
            inner.worker_spans.push(WorkerSpan {
                shard,
                worker: self.global(s.worker),
                kind: s.kind,
                iter: s.iter,
                wave: s.wave,
                chunk: s.chunk,
                start_ns: s.start_ns,
                end_ns: s.end_ns,
            });
        }
    }

    /// Per-link health snapshot from a net transport (ids core-local;
    /// latest snapshot wins — the counters are cumulative).
    pub fn link_stats(&self, stats: Vec<LinkStats>) {
        let mut inner = self.rec.lock();
        for s in stats {
            let worker = self.global(s.worker);
            inner.links.insert(worker, s);
        }
    }

    /// The round finished; `round_ns` and `bytes` as reported to the
    /// metrics row.
    pub fn round_finished(&self, iter: u64, start_ns: u64, end_ns: u64, round_ns: u64, bytes: u64) {
        let shard = self.shard_idx();
        let mut inner = self.rec.lock();
        inner.note_ns(shard, end_ns);
        inner.registry.round_finished(round_ns, bytes);
        inner.ring_push(end_ns, shard, format!("round {iter} finished ({round_ns} ns)"));
        inner.rounds.push(RoundSpan { shard, iter, start_ns, end_ns, round_ns, bytes });
    }
}
