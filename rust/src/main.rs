//! r3bft launcher.
//!
//! ```text
//! r3bft train       [--config file.toml] [--model linreg|mlp|transformer]
//!                   [--engine native|xla] [--policy ...] [--q 0.2] [--n 8]
//!                   [--f 2] [--shards 1] [--transport threaded|sim]
//!                   [--gather all|quorum:K|quorum:0.F|deadline:US]
//!                   [--pipeline D] [--compress dense|sign|topk:K]
//!                   [--attack sign_flip]
//!                   [--adversary assignment-aware|sleeper[:W]|audit-evader[:C]
//!                   |latency-mimic|shard-equivocator]
//!                   [--p 1.0] [--steps 200] [--seed 42] [--csv out.csv]
//!                   [--trace out.json] [--events out.jsonl]
//!                   [--metrics-out metrics.prom] [--flight flight.json]
//!                   [--metrics-listen HOST:PORT]
//!                   [--chaos SPEC] [--auth-key KEY] [--latency-us US]
//! r3bft worker      --listen HOST:PORT [--chaos SPEC] [--auth-key KEY]
//! r3bft experiment  <e1..e14|all> [--full]
//! r3bft inspect     [--artifacts artifacts]
//! r3bft help
//! ```

use std::sync::Arc;

use r3bft::config::{
    AdversaryKind, AttackConfig, AttackKind, ClusterConfig, ExperimentConfig, GatherPolicy,
    PolicyKind, TrainConfig, TransportKind,
};
use r3bft::coordinator::master::{Master, MasterOptions};
use r3bft::data::{BlobsDataset, Corpus, Dataset, LinRegDataset};
use r3bft::grad::{models, GradientComputer, ModelSpec, NativeEngine, XlaEngine};
use r3bft::runtime::Runtime;
use r3bft::util::args::Args;
use r3bft::util::logger;
use r3bft::Result;

fn main() {
    logger::init();
    let args = Args::from_env();
    let code = match args.subcommand.as_deref() {
        Some("train") => run_train(&args),
        Some("worker") => run_worker(&args),
        Some("experiment") => run_experiment(&args),
        Some("inspect") => run_inspect(&args),
        Some("help") | None => {
            print_help();
            Ok(())
        }
        Some(other) => {
            eprintln!("unknown subcommand '{other}'\n");
            print_help();
            std::process::exit(2);
        }
    };
    if let Err(e) = code {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "r3bft — Randomized Reactive Redundancy for Byzantine fault-tolerant parallelized SGD

USAGE:
  r3bft train [opts]          run a training experiment
  r3bft worker --listen ADDR  host one worker over TCP (the master connects
                              with --transport net --peers ...; ADDR is
                              HOST:PORT, port 0 picks a free one — the bound
                              address is printed as 'listening on HOST:PORT');
                              accepts --chaos and --auth-key like train
  r3bft experiment <id>       reproduce a paper experiment (e1..e14, all); --full for long runs
  r3bft inspect               list + compile the AOT artifacts
  r3bft help

TRAIN OPTIONS (defaults in parens):
  --config FILE      TOML config (overridden by explicit flags below)
  --model M          linreg | mlp | transformer (linreg)
  --engine E         native | xla (native; transformer requires xla)
  --policy P         none | deterministic | randomized | adaptive | selective
                     | latency-selective (randomized); latency-selective
                     audits per worker from the fused suspicion score
                     (delivery-latency anomaly + reliability history)
  --q Q              audit probability for randomized/selective/
                     latency-selective (0.2)
  --p-assumed P      assumed tamper prob for adaptive (0.5)
  --n N              workers (8)        --f F   Byzantine bound (2)
  --shards K         partition workers into K shards, each with its own
                     protocol core behind one parameter server (1);
                     per-shard budgets must satisfy 2*f_s < n_s
  --transport T      threaded | sim | net (threaded); sim runs workers in
                     deterministic virtual time (no OS threads, n can
                     be in the thousands); net connects to `r3bft worker`
                     processes over TCP (see docs/NETWORK.md)
  --peers LIST       net transport only: comma-separated worker addresses
                     in worker-id order (host:port, one per worker)
  --latency-us US    artificial per-request compute delay applied
                     worker-side (0); paces a loopback net run so
                     mid-run scrapes and straggler policies have
                     something to observe
  --chaos SPEC       net transport only: deterministic fault injection on
                     every TCP link — comma-separated fields from
                     drop:P, delay:DUR, dup:P, reorder:P, corrupt:P,
                     kill:P, partition:FOR@EVERY (durations take us/ms/s
                     suffixes; 'off' disables). Seeded from --seed: same
                     seed, same storm. Pass the same spec to each
                     `r3bft worker` to also perturb the response path
  --auth-key KEY     net transport only: shared passphrase; every frame
                     (both directions) carries a keyed MAC and unauthentic
                     peers are refused at the handshake. Workers must be
                     started with the same key. Falls back to the
                     R3BFT_AUTH_KEY environment variable
  --gather G         all | quorum:K | quorum:0.F | deadline:US (all);
                     when the proactive gather may stop waiting —
                     quorum:K proceeds after K responses (quorum:0.8 =
                     80% of n, scaled per shard), deadline:US after US
                     microseconds; stragglers' chunks are reassigned
                     like crashed workers', detection/reactive phases
                     still wait for every requested copy
  --pipeline D       round pipeline depth (1); with D >= 2 the next
                     round's proactive wave is launched on a
                     provisional θ while this round's audits are in
                     flight, and reissued only when the audit changed θ
  --compress C       dense | sign | topk:K (off): workers send
                     byte-packed wire symbols — sign packs 1 bit/coord
                     plus a 4-byte scale, topk:K packs K (index, value)
                     pairs; detection compares the packed bytes
  --attack A         sign_flip|noise|constant|zero|small_bias|collude (sign_flip)
  --adversary S      coordinated adversary strategy replacing the stateless
                     attack for the Byzantine workers: assignment-aware |
                     sleeper[:WARMUP] | audit-evader[:COOLDOWN] |
                     latency-mimic | shard-equivocator (off); one omniscient
                     controller watches the protocol's public state (see
                     docs/ATTACKS.md and experiment e13)
  --p P              per-iteration tamper probability (1.0)
  --magnitude M      attack magnitude (1.0; also scales the coordinated lie)
  --steps S          iterations (200)   --lr LR step size (0.1)
  --seed S           RNG seed (42)      --self-check  master recomputes audits
  --artifacts DIR    artifacts dir for --engine xla (artifacts)
  --csv FILE         write per-iteration metrics CSV

OBSERVABILITY (see docs/TRACING.md; any flag enables the recorder):
  --trace FILE       write a Chrome trace-event JSON timeline (open in
                     Perfetto / chrome://tracing): waves, rounds,
                     per-worker deliveries, anomaly instants
  --events FILE      stream the timestamped event log as JSON Lines
                     during the run
  --metrics-out FILE write a Prometheus text-format metrics snapshot
                     (counters + round-time histogram) after the run
  --flight FILE      write the flight-recorder forensic bundles and the
                     full evidence ledger as JSON after the run
  --metrics-listen A serve live observability over HTTP at A (HOST:PORT;
                     port 0 picks a free one — the bound address is
                     printed as 'metrics listening on ADDR'): /metrics
                     (Prometheus text, per-worker-labeled link families
                     under --transport net), /healthz, /readyz (503
                     until the first round finishes), /status (JSON
                     round/roster/suspicion/shard snapshot). Scrapeable
                     mid-run; --metrics-out is unaffected"
    );
}

fn cfg_from_args(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = if let Some(path) = args.get("config") {
        ExperimentConfig::from_file(path)?
    } else {
        ExperimentConfig {
            name: "cli".into(),
            cluster: ClusterConfig::new(8, 2, 42),
            policy: PolicyKind::Bernoulli { q: 0.2 },
            attack: AttackConfig::default(),
            adversary: None,
            train: TrainConfig::default(),
        }
    };
    if let Some(n) = args.get("n") {
        cfg.cluster.n = n.parse()?;
    }
    if let Some(f) = args.get("f") {
        cfg.cluster.f = f.parse()?;
    }
    if args.get("n").is_some() || args.get("f").is_some() {
        cfg.cluster.byzantine_ids = (0..cfg.cluster.f.min(cfg.cluster.n)).collect();
    }
    cfg.cluster.seed = args.u64("seed", cfg.cluster.seed);
    if let Some(t) = args.get("transport") {
        cfg.cluster.transport = TransportKind::parse(t)?;
    }
    if let Some(g) = args.get("gather") {
        cfg.cluster.gather = GatherPolicy::parse(g, cfg.cluster.n)?;
    } else if args.get("n").is_some() {
        // a fractional cluster.gather from the config file was resolved
        // against the file's n; re-resolve it against the overridden n
        if let Some(path) = args.get("config") {
            let text = std::fs::read_to_string(path)?;
            let doc = r3bft::config::toml::TomlDoc::parse(&text)
                .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
            cfg.cluster.gather =
                GatherPolicy::parse(&doc.str_or("cluster.gather", "all"), cfg.cluster.n)?;
        }
    }
    if let Some(peers) = args.get("peers") {
        cfg.cluster.peers = peers
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
    }
    if let Some(spec) = args.get("chaos") {
        cfg.cluster.chaos = Some(spec.to_string());
    }
    cfg.cluster.latency_us = args.u64("latency-us", cfg.cluster.latency_us);
    if let Some(key) = args.get("auth-key").map(String::from).or_else(auth_key_from_env) {
        cfg.cluster.auth_key = Some(key);
    }
    cfg.cluster.shards = args.usize("shards", cfg.cluster.shards);
    cfg.cluster.pipeline = args.usize("pipeline", cfg.cluster.pipeline);
    if let Some(kind) = args.get("policy") {
        cfg.policy = PolicyKind::parse(
            kind,
            args.f64("q", 0.2),
            args.f64("p-assumed", r3bft::config::DEFAULT_P_ASSUMED),
        )?;
    }
    if let Some(kind) = args.get("attack") {
        cfg.attack.kind = AttackKind::parse(kind)?;
    }
    if let Some(s) = args.get("adversary") {
        cfg.adversary = Some(AdversaryKind::parse(s)?);
    }
    cfg.attack.p = args.f64("p", cfg.attack.p);
    cfg.attack.magnitude = args.f64("magnitude", cfg.attack.magnitude as f64) as f32;
    if let Some(m) = args.get("model") {
        cfg.train.model = m.to_string();
    }
    if let Some(e) = args.get("engine") {
        cfg.train.engine = e.to_string();
    }
    cfg.train.steps = args.usize("steps", cfg.train.steps);
    cfg.train.lr = args.f64("lr", cfg.train.lr as f64) as f32;
    cfg.cluster.validate()?;
    Ok(cfg)
}

fn run_train(args: &Args) -> Result<()> {
    let cfg = cfg_from_args(args)?;
    let seed = cfg.cluster.seed;
    let self_check = args.flag("self-check");
    let artifacts = args.get_or("artifacts", "artifacts");

    // model + engine + dataset + init
    let (spec, dataset, w_star): (ModelSpec, Arc<dyn Dataset>, Option<Vec<f32>>) =
        match cfg.train.model.as_str() {
            "linreg" => {
                let ds =
                    LinRegDataset::generate(cfg.train.dataset_size, 64, cfg.train.noise_std, seed);
                let w = ds.w_star.clone();
                (ModelSpec::LinReg { d: 64, batch: 256 }, Arc::new(ds), Some(w))
            }
            "mlp" => (
                ModelSpec::Mlp { in_dim: 32, hidden: 64, classes: 4, batch: 128 },
                Arc::new(BlobsDataset::generate(cfg.train.dataset_size, 32, 4, 4.0, seed)),
                None,
            ),
            "transformer" => (
                ModelSpec::Transformer { param_dim: 136_512, batch: 8, seq_len: 65 },
                Arc::new(Corpus::synthetic(64 * 1024, 65, seed)),
                None,
            ),
            other => anyhow::bail!("unknown model '{other}'"),
        };

    let engine: Arc<dyn GradientComputer> = match cfg.train.engine.as_str() {
        "native" => {
            anyhow::ensure!(
                !matches!(spec, ModelSpec::Transformer { .. }),
                "the transformer requires --engine xla"
            );
            Arc::new(NativeEngine::new(spec.clone()))
        }
        "xla" => {
            let rt = Arc::new(Runtime::cpu(artifacts)?);
            Arc::new(XlaEngine::new(rt, spec.clone())?)
        }
        other => anyhow::bail!("unknown engine '{other}'"),
    };

    let theta0 = match &spec {
        ModelSpec::Transformer { .. } => models::init_transformer_tiny(seed),
        s => s.init_theta(seed),
    };
    let chunk = spec.batch();
    let compressor = match args.get("compress") {
        Some(spec) => Some(r3bft::coordinator::compress::parse(spec)?),
        None => None,
    };
    // any observability flag builds a recorder; none costs nothing
    let trace_path = args.get("trace").map(String::from);
    let events_path = args.get("events").map(String::from);
    let metrics_path = args.get("metrics-out").map(String::from);
    let flight_path = args.get("flight").map(String::from);
    let metrics_listen = args.get("metrics-listen").map(String::from);
    let recorder = (trace_path.is_some()
        || events_path.is_some()
        || metrics_path.is_some()
        || flight_path.is_some()
        || metrics_listen.is_some())
    .then(r3bft::trace::Recorder::new);
    if let (Some(rec), Some(path)) = (&recorder, &events_path) {
        let file = std::fs::File::create(path)?;
        rec.set_events_sink(Box::new(std::io::BufWriter::new(file)));
    }
    // live scrape endpoint: bind before the run starts so harnesses
    // can poll /healthz while workers connect
    let status = match (&recorder, &metrics_listen) {
        (Some(rec), Some(addr)) => {
            let board =
                r3bft::trace::http::StatusBoard::new(cfg.cluster.n, cfg.train.steps as u64);
            let bound = r3bft::trace::http::spawn(addr, rec.clone(), board.clone())?;
            println!("metrics listening on {bound}");
            Some(board)
        }
        _ => None,
    };
    let opts = MasterOptions {
        self_check,
        w_star,
        compressor,
        recorder: recorder.clone(),
        net_model: Some(spec.clone()),
        status: status.clone(),
        ..Default::default()
    };

    log::info!(
        "train: model={} engine={} n={} f={} shards={} transport={} gather={} policy={:?} attack={} steps={}",
        cfg.train.model,
        cfg.train.engine,
        cfg.cluster.n,
        cfg.cluster.f,
        cfg.cluster.shards,
        cfg.cluster.transport.name(),
        cfg.cluster.gather.describe(),
        cfg.policy,
        match cfg.adversary {
            Some(kind) => format!("adversary:{}", kind.describe()),
            None => format!("{:?}", cfg.attack.kind),
        },
        cfg.train.steps
    );
    let csv_path = args.get("csv").map(String::from);
    let steps = cfg.train.steps;
    let master = Master::new(cfg, opts, engine, dataset, theta0, chunk)?;
    let out = master.run()?;
    if let Some(board) = &status {
        board.mark_done();
    }

    println!("== run summary ==");
    println!("iterations           : {steps}");
    println!("final loss           : {:.6}", out.metrics.final_loss());
    println!("avg efficiency       : {:.4}", out.metrics.average_efficiency());
    println!("audit rate           : {:.4}", out.metrics.audit_rate());
    println!("faulty updates       : {:.4}", out.metrics.faulty_update_rate());
    println!("faults detected      : {}", out.events.detections());
    println!("mean round time      : {:.1} us", out.metrics.mean_round_ns() / 1e3);
    println!("stragglers abandoned : {}", out.events.stragglers());
    if let Some((w, s)) = out.metrics.top_suspect() {
        println!("top suspicion        : worker {w} ({s:.3})");
    }
    println!("eliminated workers   : {:?}", out.eliminated);
    if let Some(d) = out.metrics.iterations.last().and_then(|r| r.dist_to_opt) {
        println!("dist to optimum      : {d:.3e}");
    }
    if let Some(path) = csv_path {
        std::fs::write(&path, out.metrics.to_csv())?;
        println!("metrics csv          : {path}");
    }
    if let Some(rec) = &recorder {
        rec.close_events_sink();
        if let Some(path) = &events_path {
            println!("events jsonl         : {path}");
        }
        if let Some(path) = &trace_path {
            std::fs::write(path, rec.chrome_trace())?;
            println!("chrome trace         : {path}");
        }
        if let Some(path) = &metrics_path {
            std::fs::write(path, rec.prometheus())?;
            println!("prometheus metrics   : {path}");
        }
        if let Some(path) = &flight_path {
            std::fs::write(path, rec.flight_json())?;
            println!("flight recorder      : {path}");
        }
    }
    Ok(())
}

/// The `--auth-key` fallback: both `train` and `worker` read
/// `R3BFT_AUTH_KEY` when the flag is absent, so harnesses can arm
/// authentication fleet-wide without editing every command line.
fn auth_key_from_env() -> Option<String> {
    std::env::var("R3BFT_AUTH_KEY").ok().filter(|k| !k.is_empty())
}

/// `r3bft worker --listen ADDR`: bind, announce the bound address on
/// stdout (port 0 picks a free port — harnesses parse this line), and
/// serve master sessions until a shutdown frame arrives. `--chaos`
/// perturbs the response path; `--auth-key` (or `R3BFT_AUTH_KEY`)
/// refuses unauthenticated masters.
fn run_worker(args: &Args) -> Result<()> {
    let addr = args
        .get("listen")
        .ok_or_else(|| anyhow::anyhow!("worker needs --listen HOST:PORT"))?;
    let chaos = match args.get("chaos") {
        Some(spec) => Some(r3bft::coordinator::transport::ChaosSpec::parse(spec)?),
        None => None,
    };
    let auth = args
        .get("auth-key")
        .map(String::from)
        .or_else(auth_key_from_env)
        .map(|k| r3bft::coordinator::transport::AuthKey::from_passphrase(&k));
    let listener = std::net::TcpListener::bind(addr)
        .map_err(|e| anyhow::anyhow!("bind {addr}: {e}"))?;
    let bound = listener.local_addr()?;
    println!("listening on {bound}");
    let opts = r3bft::coordinator::transport::net::server::ServeOptions { auth, chaos };
    r3bft::coordinator::transport::net::server::serve_with(listener, opts)
}

fn run_experiment(args: &Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("all");
    r3bft::experiments::run(id, !args.flag("full"))
}

fn run_inspect(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let rt = Runtime::cpu(dir)?;
    println!("{:<28} {:>6} {:>10} {:>8}  inputs", "artifact", "kind", "param_dim", "compile");
    let specs: Vec<_> = rt.manifest.artifacts.clone();
    for a in specs {
        let t0 = std::time::Instant::now();
        rt.preload(&a.name)?;
        let dt = t0.elapsed().as_secs_f64() * 1e3;
        let inputs: Vec<String> = a
            .inputs
            .iter()
            .map(|s| format!("{}{:?}", s.name, s.shape))
            .collect();
        println!(
            "{:<28} {:>6} {:>10} {:>7.1}ms  {}",
            a.name,
            a.kind,
            a.param_dim,
            dt,
            inputs.join(", ")
        );
    }
    let s = rt.stats();
    println!(
        "\ncompiled {} artifacts in {:.1} ms total",
        s.compilations,
        s.total_compile_ns as f64 / 1e6
    );
    Ok(())
}
