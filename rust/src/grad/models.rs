//! Model zoo descriptors shared by the native and XLA engines.
//!
//! A `ModelSpec` ties together: the model family, its shape
//! hyper-parameters, the flat parameter dimension, and (for the XLA
//! engine) the artifact names to execute. The parameter initialization
//! is defined here so both engines and all experiments start from the
//! same point for a given seed.

use crate::util::rng::Pcg64;

#[derive(Clone, Debug, PartialEq)]
pub enum ModelSpec {
    /// Half-MSE linear regression, theta = w in R^d.
    LinReg { d: usize, batch: usize },
    /// 2-layer relu MLP + softmax cross-entropy.
    Mlp { in_dim: usize, hidden: usize, classes: usize, batch: usize },
    /// Byte-level decoder-only transformer (XLA engine only).
    Transformer { param_dim: usize, batch: usize, seq_len: usize },
}

impl ModelSpec {
    pub fn param_dim(&self) -> usize {
        match self {
            ModelSpec::LinReg { d, .. } => *d,
            ModelSpec::Mlp { in_dim, hidden, classes, .. } => {
                in_dim * hidden + hidden + hidden * classes + classes
            }
            ModelSpec::Transformer { param_dim, .. } => *param_dim,
        }
    }

    pub fn batch(&self) -> usize {
        match self {
            ModelSpec::LinReg { batch, .. }
            | ModelSpec::Mlp { batch, .. }
            | ModelSpec::Transformer { batch, .. } => *batch,
        }
    }

    /// Artifact names for the XLA engine (grad, loss, update).
    pub fn artifact_names(&self) -> (String, String, String) {
        match self {
            ModelSpec::LinReg { d, batch } => (
                format!("linreg_grad_d{d}_b{batch}"),
                format!("linreg_loss_d{d}_b{batch}"),
                format!("sgd_linreg_d{d}"),
            ),
            ModelSpec::Mlp { in_dim, hidden, classes, batch } => (
                format!("mlp_grad_i{in_dim}_h{hidden}_c{classes}_b{batch}"),
                format!("mlp_loss_i{in_dim}_h{hidden}_c{classes}_b{batch}"),
                "sgd_mlp".to_string(),
            ),
            ModelSpec::Transformer { .. } => (
                "tfm_grad_tiny".to_string(),
                "tfm_loss_tiny".to_string(),
                "sgd_tfm_tiny".to_string(),
            ),
        }
    }

    /// Deterministic init matching python/compile/models/common.py:
    /// matrices ~ N(0, 1/sqrt(fan_in)), vectors zero. For LinReg the
    /// whole theta is a small random start.
    pub fn init_theta(&self, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::new(seed, 404);
        match self {
            ModelSpec::LinReg { d, .. } => (0..*d).map(|_| 0.1 * rng.gauss_f32()).collect(),
            ModelSpec::Mlp { in_dim, hidden, classes, .. } => {
                let mut theta = Vec::with_capacity(self.param_dim());
                let s1 = 1.0 / (*in_dim as f32).sqrt();
                theta.extend((0..in_dim * hidden).map(|_| s1 * rng.gauss_f32()));
                theta.extend(std::iter::repeat(0.0f32).take(*hidden));
                let s2 = 1.0 / (*hidden as f32).sqrt();
                theta.extend((0..hidden * classes).map(|_| s2 * rng.gauss_f32()));
                theta.extend(std::iter::repeat(0.0f32).take(*classes));
                theta
            }
            ModelSpec::Transformer { param_dim, .. } => {
                // scaled-down global init; layernorm scales need ~1.0 but
                // a uniform small init still trains at tiny scale. The
                // e2e example instead initializes via init_transformer().
                (0..*param_dim).map(|_| 0.02 * rng.gauss_f32()).collect()
            }
        }
    }
}

/// Structured init for the tiny transformer artifact (matches the
/// Packer layout in python/compile/models/transformer.py for the
/// tfm_*_tiny config: vocab=256, seq_len=65, d=64, heads=4, layers=2,
/// mlp_mult=4). LayerNorm scales init to 1, matrices to N(0, 1/sqrt(in)).
pub fn init_transformer_tiny(seed: u64) -> Vec<f32> {
    let (vocab, seq, d, layers, mult) = (256usize, 65usize, 64usize, 2usize, 4usize);
    let mut rng = Pcg64::new(seed, 505);
    let mut theta: Vec<f32> = Vec::new();
    let mat = |rows: usize, cols: usize, theta: &mut Vec<f32>, rng: &mut Pcg64| {
        let s = 1.0 / (rows as f32).sqrt();
        theta.extend((0..rows * cols).map(|_| s * rng.gauss_f32()));
    };
    mat(vocab, d, &mut theta, &mut rng); // embed (std 1/16)
    theta.extend((0..seq * d).map(|_| 0.01 * rng.gauss_f32())); // pos
    for _ in 0..layers {
        theta.extend(std::iter::repeat(1.0f32).take(d)); // ln1_s
        theta.extend(std::iter::repeat(0.0f32).take(d)); // ln1_b
        for _ in 0..4 {
            mat(d, d, &mut theta, &mut rng); // wq wk wv wo
        }
        theta.extend(std::iter::repeat(1.0f32).take(d)); // ln2_s
        theta.extend(std::iter::repeat(0.0f32).take(d)); // ln2_b
        mat(d, mult * d, &mut theta, &mut rng); // w_up
        theta.extend(std::iter::repeat(0.0f32).take(mult * d)); // b_up
        mat(mult * d, d, &mut theta, &mut rng); // w_down
        theta.extend(std::iter::repeat(0.0f32).take(d)); // b_down
    }
    theta.extend(std::iter::repeat(1.0f32).take(d)); // lnf_s
    theta.extend(std::iter::repeat(0.0f32).take(d)); // lnf_b
    mat(d, vocab, &mut theta, &mut rng); // unembed
    theta
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_dims() {
        assert_eq!(ModelSpec::LinReg { d: 64, batch: 256 }.param_dim(), 64);
        let mlp = ModelSpec::Mlp { in_dim: 32, hidden: 64, classes: 4, batch: 128 };
        assert_eq!(mlp.param_dim(), 32 * 64 + 64 + 64 * 4 + 4); // 2372, matches aot.py
    }

    #[test]
    fn artifact_names_match_aot() {
        let (g, l, u) = ModelSpec::LinReg { d: 64, batch: 256 }.artifact_names();
        assert_eq!(g, "linreg_grad_d64_b256");
        assert_eq!(l, "linreg_loss_d64_b256");
        assert_eq!(u, "sgd_linreg_d64");
    }

    #[test]
    fn transformer_tiny_init_dim() {
        // Packer layout total for the tiny config (must equal aot.py's P)
        let theta = init_transformer_tiny(0);
        assert_eq!(theta.len(), 136_512);
        // layernorm scales present: embed block then pos block then ln1_s of ones
        let off = 256 * 64 + 65 * 64;
        assert!(theta[off..off + 64].iter().all(|&v| v == 1.0));
    }

    #[test]
    fn init_is_deterministic() {
        let a = ModelSpec::LinReg { d: 16, batch: 8 }.init_theta(9);
        let b = ModelSpec::LinReg { d: 16, batch: 8 }.init_theta(9);
        assert_eq!(a, b);
    }
}
