//! XLA-backed gradient engine: executes the AOT artifacts via PJRT.
//!
//! The production path of the three-layer stack — the same HLO a TPU
//! deployment would run, compiled once per artifact and reused for
//! every (worker, iteration) execution.

use std::sync::Arc;

use anyhow::bail;

use super::{GradOutput, GradientComputer, ModelSpec};
use crate::data::Batch;
use crate::runtime::{HostTensor, Runtime};
use crate::Result;

pub struct XlaEngine {
    runtime: Arc<Runtime>,
    pub spec: ModelSpec,
    grad_name: String,
    loss_name: String,
    update_name: String,
    /// Use the fused SGD-update artifact only below this parameter
    /// count. Perf (EXPERIMENTS.md §Perf): on CPU PJRT each execution
    /// pays literal-copy overhead on both sides; for large P the host
    /// axpy (~µs) beats the artifact round trip (~ms) by ~500x. On a
    /// real accelerator with donated device buffers the fused artifact
    /// wins instead — flip via `set_fused_update_max_dim(usize::MAX)`.
    fused_update_max_dim: usize,
}

impl XlaEngine {
    /// Build over a shared runtime; compiles the three artifacts eagerly
    /// so the first training iteration pays no compile latency.
    pub fn new(runtime: Arc<Runtime>, spec: ModelSpec) -> Result<XlaEngine> {
        let (grad_name, loss_name, update_name) = spec.artifact_names();
        let a = runtime.preload(&grad_name)?;
        if a.param_dim != spec.param_dim() {
            bail!(
                "artifact '{grad_name}' param_dim {} != model param_dim {} — \
                 stale artifacts? re-run `make artifacts`",
                a.param_dim,
                spec.param_dim()
            );
        }
        runtime.preload(&loss_name)?;
        runtime.preload(&update_name)?;
        Ok(XlaEngine {
            runtime,
            spec,
            grad_name,
            loss_name,
            update_name,
            fused_update_max_dim: 16_384,
        })
    }

    /// Override the fused-update crossover (see field docs).
    pub fn set_fused_update_max_dim(&mut self, max_dim: usize) {
        self.fused_update_max_dim = max_dim;
    }

    fn batch_tensors(&self, batch: &Batch) -> Result<Vec<HostTensor>> {
        Ok(match batch {
            Batch::LinReg { x, y, .. } => vec![
                HostTensor::F32(x.clone()),
                HostTensor::F32(y.clone()),
            ],
            Batch::Classif { x, labels, .. } => vec![
                HostTensor::F32(x.clone()),
                HostTensor::I32(labels.clone()),
            ],
            Batch::Tokens { tokens, .. } => vec![HostTensor::I32(tokens.clone())],
        })
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }
}

impl GradientComputer for XlaEngine {
    fn param_dim(&self) -> usize {
        self.spec.param_dim()
    }

    fn grad(&self, theta: &[f32], batch: &Batch) -> Result<GradOutput> {
        if batch.len() != self.spec.batch() {
            bail!(
                "XLA engine '{}' is AOT-compiled for batch {}, got {} — \
                 assignment must pad sub-batches to the artifact batch size",
                self.grad_name,
                self.spec.batch(),
                batch.len()
            );
        }
        let mut inputs = vec![HostTensor::F32(theta.to_vec())];
        inputs.extend(self.batch_tensors(batch)?);
        let mut out = self.runtime.run(&self.grad_name, &inputs)?;
        if out.len() != 2 {
            bail!("grad artifact returned {} outputs, expected 2", out.len());
        }
        let loss = out.pop().unwrap().into_f32()?[0];
        let grad = out.pop().unwrap().into_f32()?;
        Ok(GradOutput { grad, loss })
    }

    fn loss(&self, theta: &[f32], batch: &Batch) -> Result<f32> {
        let mut inputs = vec![HostTensor::F32(theta.to_vec())];
        inputs.extend(self.batch_tensors(batch)?);
        let out = self.runtime.run(&self.loss_name, &inputs)?;
        Ok(out[0].as_f32()?[0])
    }

    fn sgd_step(&self, theta: &mut Vec<f32>, grad: &[f32], lr: f32) -> Result<()> {
        if theta.len() > self.fused_update_max_dim {
            // host axpy fast path (see field docs for the rationale)
            crate::linalg::axpy(-lr, grad, theta);
            return Ok(());
        }
        let inputs = vec![
            HostTensor::F32(std::mem::take(theta)),
            HostTensor::F32(grad.to_vec()),
            HostTensor::F32(vec![lr]),
        ];
        let mut out = self.runtime.run(&self.update_name, &inputs)?;
        *theta = out.pop().unwrap().into_f32()?;
        Ok(())
    }
}
