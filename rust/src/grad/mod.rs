//! Gradient engines: how a worker (or the master, in self-check mode)
//! turns (theta, batch) into (gradient, loss).
//!
//! Two interchangeable implementations:
//! * [`native::NativeEngine`] — pure Rust math on `linalg`; used for
//!   the simulation-scale experiments (thousands of SGD iterations)
//!   and for tests that must run without `artifacts/`.
//! * [`xla_engine::XlaEngine`] — executes the AOT artifacts on the
//!   PJRT CPU client; the production path, and the only engine that
//!   supports the transformer model.
//!
//! Both satisfy the uniform artifact ABI: flat `theta` in, flat
//! gradient + scalar loss out (see python/compile/models/common.py).

pub mod models;
pub mod native;
pub mod xla_engine;

use crate::data::Batch;
use crate::Result;

pub use models::ModelSpec;
pub use native::NativeEngine;
pub use xla_engine::XlaEngine;

/// A computed gradient plus the loss observed at the same point.
#[derive(Clone, Debug)]
pub struct GradOutput {
    pub grad: Vec<f32>,
    pub loss: f32,
}

/// Engine interface shared by workers and the master's self-check path.
pub trait GradientComputer: Send + Sync {
    /// Flat parameter dimension P.
    fn param_dim(&self) -> usize;

    /// Gradient of the mean loss over `batch` at `theta`, plus the loss.
    fn grad(&self, theta: &[f32], batch: &Batch) -> Result<GradOutput>;

    /// Loss only (used by the adaptive policy's observed-loss probe).
    fn loss(&self, theta: &[f32], batch: &Batch) -> Result<f32> {
        Ok(self.grad(theta, batch)?.loss)
    }

    /// Apply an SGD step; default is a host-side axpy, the XLA engine
    /// overrides it with the fused update artifact.
    fn sgd_step(&self, theta: &mut Vec<f32>, grad: &[f32], lr: f32) -> Result<()> {
        crate::linalg::axpy(-lr, grad, theta);
        Ok(())
    }
}
