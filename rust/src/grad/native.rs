//! Pure-Rust gradient engine.
//!
//! Implements exactly the same math as the L1/L2 Python stack (see
//! kernels/ref.py) for the linreg and MLP models, so experiments that
//! need tens of thousands of SGD iterations can run at native speed and
//! tests can run without `artifacts/`. Cross-checked against the XLA
//! engine in rust/tests/test_engines_agree.rs.

use anyhow::bail;

use super::{GradOutput, GradientComputer, ModelSpec};
use crate::data::Batch;
use crate::linalg;
use crate::Result;

pub struct NativeEngine {
    pub spec: ModelSpec,
}

impl NativeEngine {
    pub fn new(spec: ModelSpec) -> Self {
        NativeEngine { spec }
    }

    fn linreg(&self, theta: &[f32], x: &[f32], y: &[f32], b: usize, d: usize) -> GradOutput {
        // r = Xw - y ; grad = X^T r / B ; loss = 0.5 mean r^2
        let mut grad = vec![0.0f32; d];
        let mut loss = 0.0f32;
        for i in 0..b {
            let row = &x[i * d..(i + 1) * d];
            let r = linalg::dot(row, theta) - y[i];
            linalg::axpy(r, row, &mut grad);
            loss += r * r;
        }
        let inv_b = 1.0 / b as f32;
        linalg::scale(inv_b, &mut grad);
        GradOutput { grad, loss: 0.5 * loss * inv_b }
    }

    fn mlp(
        &self,
        theta: &[f32],
        x: &[f32],
        labels: &[i32],
        b: usize,
        in_dim: usize,
        hidden: usize,
        classes: usize,
    ) -> GradOutput {
        // unpack theta in Packer order: w1 [I,H], b1 [H], w2 [H,C], b2 [C]
        let (o1, o2, o3) = (
            in_dim * hidden,
            in_dim * hidden + hidden,
            in_dim * hidden + hidden + hidden * classes,
        );
        let w1 = &theta[..o1];
        let b1 = &theta[o1..o2];
        let w2 = &theta[o2..o3];
        let b2 = &theta[o3..];

        let mut grad = vec![0.0f32; theta.len()];
        let (gw1, rest) = grad.split_at_mut(o1);
        let (gb1, rest) = rest.split_at_mut(hidden);
        let (gw2, gb2) = rest.split_at_mut(hidden * classes);

        let mut loss = 0.0f32;
        let inv_b = 1.0 / b as f32;
        let mut z1 = vec![0.0f32; hidden];
        let mut logits = vec![0.0f32; classes];
        let mut dlog = vec![0.0f32; classes];
        let mut dh = vec![0.0f32; hidden];
        for i in 0..b {
            let row = &x[i * in_dim..(i + 1) * in_dim];
            // z1 = x @ w1 + b1 (w1 row-major [I, H])
            z1.copy_from_slice(b1);
            for (j, &xv) in row.iter().enumerate() {
                if xv != 0.0 {
                    linalg::axpy(xv, &w1[j * hidden..(j + 1) * hidden], &mut z1);
                }
            }
            // h = relu(z1); logits = h @ w2 + b2
            logits.copy_from_slice(b2);
            for (j, &zv) in z1.iter().enumerate() {
                if zv > 0.0 {
                    linalg::axpy(zv, &w2[j * classes..(j + 1) * classes], &mut logits);
                }
            }
            // softmax xent
            let maxl = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0.0f32;
            for &l in logits.iter() {
                z += (l - maxl).exp();
            }
            let logz = maxl + z.ln();
            let label = labels[i] as usize;
            loss += logz - logits[label];
            // dlogits = (softmax - onehot)/B
            for (c, dl) in dlog.iter_mut().enumerate() {
                let p = (logits[c] - logz).exp();
                *dl = (p - if c == label { 1.0 } else { 0.0 }) * inv_b;
            }
            // dw2 += h^T dlog ; db2 += dlog ; dh = dlog @ w2^T
            dh.iter_mut().for_each(|v| *v = 0.0);
            for (j, &zv) in z1.iter().enumerate() {
                let h = zv.max(0.0);
                if h != 0.0 {
                    linalg::axpy(h, &dlog, &mut gw2[j * classes..(j + 1) * classes]);
                }
                if zv > 0.0 {
                    dh[j] = linalg::dot(&dlog, &w2[j * classes..(j + 1) * classes]);
                }
            }
            linalg::axpy(1.0, &dlog, gb2);
            // dz1 = dh * relu'(z1) (already folded into dh above)
            // dw1 += x^T dz1 ; db1 += dz1
            for (j, &xv) in row.iter().enumerate() {
                if xv != 0.0 {
                    linalg::axpy(xv, &dh, &mut gw1[j * hidden..(j + 1) * hidden]);
                }
            }
            linalg::axpy(1.0, &dh, gb1);
        }
        GradOutput { grad, loss: loss * inv_b }
    }
}

impl GradientComputer for NativeEngine {
    fn param_dim(&self) -> usize {
        self.spec.param_dim()
    }

    fn grad(&self, theta: &[f32], batch: &Batch) -> Result<GradOutput> {
        match (&self.spec, batch) {
            (ModelSpec::LinReg { d, .. }, Batch::LinReg { x, y, b, d: bd }) => {
                if bd != d {
                    bail!("linreg dim mismatch: model d={d}, batch d={bd}");
                }
                Ok(self.linreg(theta, x, y, *b, *d))
            }
            (
                ModelSpec::Mlp { in_dim, hidden, classes, .. },
                Batch::Classif { x, labels, b, d },
            ) => {
                if d != in_dim {
                    bail!("mlp dim mismatch: model in_dim={in_dim}, batch d={d}");
                }
                Ok(self.mlp(theta, x, labels, *b, *in_dim, *hidden, *classes))
            }
            (ModelSpec::Transformer { .. }, _) => {
                bail!("the native engine does not implement the transformer; use --engine xla")
            }
            _ => bail!("batch kind does not match model {:?}", self.spec),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Batch, Dataset, LinRegDataset};

    #[test]
    fn linreg_grad_is_zero_at_optimum() {
        let ds = LinRegDataset::generate(128, 16, 0.0, 3);
        let eng = NativeEngine::new(ModelSpec::LinReg { d: 16, batch: 128 });
        let batch = ds.batch(&(0..128).collect::<Vec<_>>());
        let out = eng.grad(&ds.w_star, &batch).unwrap();
        assert!(linalg::norm2(&out.grad) < 1e-4, "grad at w* = {}", linalg::norm2(&out.grad));
        assert!(out.loss < 1e-8);
    }

    #[test]
    fn linreg_matches_finite_differences() {
        let ds = LinRegDataset::generate(32, 6, 0.1, 5);
        let eng = NativeEngine::new(ModelSpec::LinReg { d: 6, batch: 32 });
        let batch = ds.batch(&(0..32).collect::<Vec<_>>());
        let theta: Vec<f32> = (0..6).map(|i| 0.3 * i as f32 - 0.7).collect();
        let out = eng.grad(&theta, &batch).unwrap();
        let eps = 1e-3f32;
        for j in 0..6 {
            let mut tp = theta.clone();
            tp[j] += eps;
            let lp = eng.grad(&tp, &batch).unwrap().loss;
            let mut tm = theta.clone();
            tm[j] -= eps;
            let lm = eng.grad(&tm, &batch).unwrap().loss;
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - out.grad[j]).abs() < 2e-2 * (1.0 + fd.abs()),
                "coord {j}: fd={fd} analytic={}",
                out.grad[j]
            );
        }
    }

    #[test]
    fn mlp_matches_finite_differences() {
        use crate::data::BlobsDataset;
        let ds = BlobsDataset::generate(64, 8, 3, 3.0, 7);
        let spec = ModelSpec::Mlp { in_dim: 8, hidden: 12, classes: 3, batch: 64 };
        let eng = NativeEngine::new(spec.clone());
        let batch = ds.batch(&(0..64).collect::<Vec<_>>());
        let theta = spec.init_theta(11);
        let out = eng.grad(&theta, &batch).unwrap();
        assert!(out.loss > 0.0);
        let eps = 1e-2f32;
        // spot-check 20 coordinates spread over the parameter vector
        let p = theta.len();
        for t in 0..20 {
            let j = t * p / 20;
            let mut tp = theta.clone();
            tp[j] += eps;
            let lp = eng.grad(&tp, &batch).unwrap().loss;
            let mut tm = theta.clone();
            tm[j] -= eps;
            let lm = eng.grad(&tm, &batch).unwrap().loss;
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - out.grad[j]).abs() < 5e-2 * (1.0 + fd.abs()),
                "coord {j}: fd={fd} analytic={}",
                out.grad[j]
            );
        }
    }

    #[test]
    fn sgd_converges_to_planted_optimum() {
        let ds = LinRegDataset::generate(256, 8, 0.0, 13);
        let eng = NativeEngine::new(ModelSpec::LinReg { d: 8, batch: 256 });
        let batch = ds.batch(&(0..256).collect::<Vec<_>>());
        let mut theta = vec![0.0f32; 8];
        for _ in 0..300 {
            let out = eng.grad(&theta, &batch).unwrap();
            eng.sgd_step(&mut theta, &out.grad, 0.5).unwrap();
        }
        assert!(
            linalg::dist2(&theta, &ds.w_star) < 1e-3,
            "dist = {}",
            linalg::dist2(&theta, &ds.w_star)
        );
    }
}
