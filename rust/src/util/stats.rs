//! Streaming and batch statistics used by metrics and the bench harness.

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 =
            self.m2 + other.m2 + d * d * self.n as f64 * other.n as f64 / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Percentile over a copy of the data (nearest-rank on a sorted copy).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[rank.min(v.len() - 1)]
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_matches_batch() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut s = OnlineStats::new();
        for &x in &xs {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn merge_matches_single_stream() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut all = OnlineStats::new();
        for &x in &xs {
            all.push(x);
        }
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.variance() - all.variance()).abs() < 1e-9);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 50.0) - 50.0).abs() <= 1.0);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
    }
}
