//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `binary [subcommand] --key value --flag [positional...]`.
//! Typed getters parse on access and report friendly errors.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (tests) — first token may be a
    /// bare subcommand.
    pub fn parse_from<I: IntoIterator<Item = String>>(tokens: I) -> Self {
        let mut out = Args::default();
        let mut it = tokens.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = it.next();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(key.to_string(), v);
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// Parse from the process environment, skipping argv[0].
    pub fn from_env() -> Self {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a float, got '{v}'")))
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        // NOTE: a bare `--flag value` is read as an option (value-taking);
        // trailing flags or `--flag` before another `--opt` are flags.
        let a = p("train data.bin --n 16 --f 2 --q 0.25 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.usize("n", 0), 16);
        assert_eq!(a.usize("f", 0), 2);
        assert!((a.f64("q", 0.0) - 0.25).abs() < 1e-12);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["data.bin"]);
    }

    #[test]
    fn equals_syntax() {
        let a = p("--scheme=randomized --q=0.1");
        assert_eq!(a.get("scheme"), Some("randomized"));
        assert!((a.f64("q", 0.0) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn trailing_flag() {
        let a = p("bench --fast");
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
        assert!(a.flag("fast"));
    }

    #[test]
    fn defaults() {
        let a = p("run");
        assert_eq!(a.usize("missing", 7), 7);
        assert_eq!(a.get_or("scheme", "deterministic"), "deterministic");
    }

    #[test]
    fn negative_number_values() {
        let a = p("x --shift -3.5");
        assert!((a.f64("shift", 0.0) + 3.5).abs() < 1e-12);
    }
}
