//! PCG-XSL-RR 128/64 pseudo-random generator plus the sampling helpers
//! the coordinator needs (Bernoulli audits, assignment shuffles,
//! Gaussian data/attack noise).
//!
//! Deterministic and seedable: every experiment in EXPERIMENTS.md
//! records its seed, and the property-test harness replays failures by
//! seed alone.

/// PCG-XSL-RR 128/64 (the `pcg64` reference variant).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seed with an arbitrary 64-bit value; `stream` selects an
    /// independent sequence (used to give each worker its own RNG).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Convenience single-stream constructor.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Derive an independent child generator (splittable-RNG style).
    pub fn split(&mut self, tag: u64) -> Pcg64 {
        let s = self.next_u64() ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        Pcg64::new(s, tag.wrapping_add(0x5851_f42d_4c95_7f2d))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// usize convenience for indexing.
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Bernoulli(p) draw.
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (one value per call; simple and
    /// branch-free enough for data generation off the hot path).
    pub fn gauss(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    pub fn gauss_f32(&mut self) -> f32 {
        self.gauss() as f32
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample {k} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.index(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Vector of standard-normal f32s.
    pub fn gauss_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.gauss_f32()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_diverge() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn streams_diverge() {
        let mut a = Pcg64::new(7, 0);
        let mut b = Pcg64::new(7, 1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::seeded(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Pcg64::seeded(4);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn bernoulli_mean() {
        let mut r = Pcg64::seeded(5);
        let hits = (0..100_000).filter(|_| r.bernoulli(0.3)).count();
        assert!((hits as f64 / 100_000.0 - 0.3).abs() < 0.01);
    }

    #[test]
    fn gauss_moments() {
        let mut r = Pcg64::seeded(6);
        let xs: Vec<f64> = (0..100_000).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seeded(7);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg64::seeded(8);
        for _ in 0..100 {
            let k = r.index(20);
            let s = r.sample_indices(20, k);
            let mut u = s.clone();
            u.sort_unstable();
            u.dedup();
            assert_eq!(u.len(), s.len());
            assert!(s.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn split_independent() {
        let mut root = Pcg64::seeded(9);
        let mut a = root.split(0);
        let mut b = root.split(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
