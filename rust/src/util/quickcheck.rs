//! Mini property-based testing harness (proptest is unavailable
//! offline).
//!
//! A property runs `cases` times with independent PCG streams; on
//! failure the harness reports the exact seed so the case replays
//! deterministically:
//!
//! ```ignore
//! forall("exact recovery", 200, |g| {
//!     let n = g.usize_in(3, 32);
//!     ...
//!     prop_assert!(cond, "context {n}");
//! });
//! ```

use super::rng::Pcg64;

/// Generator handed to each property case: a seeded RNG plus ranged
/// sampling helpers.
pub struct Gen {
    pub rng: Pcg64,
    pub case_seed: u64,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.index(hi - lo + 1)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.f64() * (hi - lo)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.f64_in(lo as f64, hi as f64) as f32
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bernoulli(0.5)
    }

    /// Gradient-like vector with entries in roughly [-3, 3].
    pub fn vec_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.rng.gauss_f32()).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.index(xs.len())]
    }

    /// k distinct indices below n.
    pub fn distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        self.rng.sample_indices(n, k)
    }
}

/// Outcome of a single case, used with [`forall`].
pub type PropResult = Result<(), String>;

/// Run `prop` for `cases` independent random cases. Panics (failing the
/// enclosing `#[test]`) with the replay seed on the first failure.
pub fn forall<F: FnMut(&mut Gen) -> PropResult>(name: &str, cases: u64, mut prop: F) {
    // Honor an explicit replay request: R3BFT_PROP_SEED=name:seed
    let replay: Option<u64> = std::env::var("R3BFT_PROP_SEED")
        .ok()
        .and_then(|v| v.split_once(':').and_then(|(n, s)| {
            (n == name).then(|| s.parse().ok()).flatten()
        }));
    let base = 0x5eed_0000u64;
    let seeds: Vec<u64> = match replay {
        Some(s) => vec![s],
        None => (0..cases).map(|i| base.wrapping_add(i)).collect(),
    };
    for seed in seeds {
        let mut g = Gen {
            rng: Pcg64::seeded(seed),
            case_seed: seed,
        };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed (replay with R3BFT_PROP_SEED={name}:{seed}): {msg}"
            );
        }
    }
}

/// Assertion macro for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

/// Approximate-equality assertion for floats inside properties.
#[macro_export]
macro_rules! prop_assert_close {
    ($a:expr, $b:expr, $tol:expr) => {{
        let (a, b) = ($a as f64, $b as f64);
        if (a - b).abs() > $tol {
            return Err(format!(
                "{} = {a} vs {} = {b} (|diff| = {} > {})",
                stringify!($a),
                stringify!($b),
                (a - b).abs(),
                $tol
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("sum commutes", 100, |g| {
            let a = g.f64_in(-10.0, 10.0);
            let b = g.f64_in(-10.0, 10.0);
            prop_assert!((a + b - (b + a)).abs() < 1e-12, "a={a} b={b}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn forall_reports_failure_with_seed() {
        forall("always fails", 5, |g| {
            let x = g.usize_in(0, 10);
            prop_assert!(x > 100, "x={x} is not > 100");
            Ok(())
        });
    }

    #[test]
    fn gen_ranges_hold() {
        forall("gen ranges", 200, |g| {
            let n = g.usize_in(1, 50);
            prop_assert!((1..=50).contains(&n), "n={n}");
            let x = g.f64_in(-2.0, 3.0);
            prop_assert!((-2.0..3.0).contains(&x), "x={x}");
            let v = g.vec_f32(n);
            prop_assert!(v.len() == n, "len mismatch");
            let d = g.distinct(20, 5);
            let mut u = d.clone();
            u.sort_unstable();
            u.dedup();
            prop_assert!(u.len() == 5, "distinct produced dups: {d:?}");
            Ok(())
        });
    }
}
