//! Tiny `log`-facade backend with env-style level filtering
//! (`R3BFT_LOG=debug`). Initialized once by the CLI and examples.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

use once_cell::sync::Lazy;

static START: Lazy<Instant> = Lazy::new(Instant::now);
static INSTALLED: AtomicBool = AtomicBool::new(false);

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &log::Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &log::Record) {
        if self.enabled(record.metadata()) {
            let t = START.elapsed();
            eprintln!(
                "[{:>9.3}s {:>5} {}] {}",
                t.as_secs_f64(),
                record.level(),
                record.target().split("::").last().unwrap_or(""),
                record.args()
            );
        }
    }

    fn flush(&self) {}
}

static LOGGER: StderrLogger = StderrLogger;

/// Install the logger (idempotent). Level comes from `R3BFT_LOG`
/// (error|warn|info|debug|trace), default `info`.
pub fn init() {
    if INSTALLED.swap(true, Ordering::SeqCst) {
        return;
    }
    let level = match std::env::var("R3BFT_LOG").as_deref() {
        Ok("error") => log::LevelFilter::Error,
        Ok("warn") => log::LevelFilter::Warn,
        Ok("debug") => log::LevelFilter::Debug,
        Ok("trace") => log::LevelFilter::Trace,
        Ok("off") => log::LevelFilter::Off,
        _ => log::LevelFilter::Info,
    };
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger test line");
    }
}
