//! Tiny `log`-facade backend with env-style per-target filtering.
//!
//! `R3BFT_LOG` takes a comma-separated directive list, `env_logger`
//! style: a bare level sets the default, and `target=level` overrides
//! it for every module whose `::`-separated path contains (or starts
//! with) `target` — e.g. `R3BFT_LOG=protocol=debug,transport=warn`
//! turns protocol internals up and transport chatter down while the
//! rest of the crate stays at the default `info`. The most specific
//! matching directive wins: the one whose match sits deepest in the
//! module path (so `protocol=trace` beats `coordinator=warn` for
//! `r3bft::coordinator::protocol`). Initialized once by the CLI and
//! examples.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

use once_cell::sync::Lazy;

static START: Lazy<Instant> = Lazy::new(Instant::now);
static INSTALLED: AtomicBool = AtomicBool::new(false);

/// One parsed `target=level` override.
struct Directive {
    target: String,
    level: log::LevelFilter,
}

fn parse_level(s: &str) -> Option<log::LevelFilter> {
    match s.trim() {
        "off" => Some(log::LevelFilter::Off),
        "error" => Some(log::LevelFilter::Error),
        "warn" => Some(log::LevelFilter::Warn),
        "info" => Some(log::LevelFilter::Info),
        "debug" => Some(log::LevelFilter::Debug),
        "trace" => Some(log::LevelFilter::Trace),
        _ => None,
    }
}

/// Parse a spec like `protocol=debug,transport=warn,info` into the
/// default level and the per-target directives. Unparseable pieces are
/// ignored (a logger must never fail the process).
fn parse_spec(spec: &str) -> (log::LevelFilter, Vec<Directive>) {
    let mut default = log::LevelFilter::Info;
    let mut directives = Vec::new();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        match part.split_once('=') {
            None => {
                if let Some(level) = parse_level(part) {
                    default = level;
                }
            }
            Some((target, level)) => {
                if let Some(level) = parse_level(level) {
                    directives
                        .push(Directive { target: target.trim().to_string(), level });
                }
            }
        }
    }
    (default, directives)
}

/// A directive matches a record target (a module path like
/// `r3bft::coordinator::protocol`) when the target starts with it or
/// any `::` component equals it; the returned depth is the index of
/// the deepest target component the directive reaches (`None` = no
/// match). Deeper matches are more specific.
fn match_depth(directive: &str, target: &str) -> Option<usize> {
    if target == directive
        || (target.starts_with(directive) && target[directive.len()..].starts_with("::"))
    {
        return Some(directive.split("::").count() - 1);
    }
    target
        .split("::")
        .enumerate()
        .filter(|(_, c)| *c == directive)
        .map(|(i, _)| i)
        .last()
}

/// Effective level for `target`: the deepest-matching directive (ties
/// go to the longer directive name), or the default.
fn level_for(default: log::LevelFilter, directives: &[Directive], target: &str) -> log::LevelFilter {
    directives
        .iter()
        .filter_map(|d| match_depth(&d.target, target).map(|depth| (depth, d)))
        .max_by_key(|(depth, d)| (*depth, d.target.len()))
        .map(|(_, d)| d.level)
        .unwrap_or(default)
}

struct StderrLogger {
    default: log::LevelFilter,
    directives: Vec<Directive>,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &log::Metadata) -> bool {
        metadata.level() <= level_for(self.default, &self.directives, metadata.target())
    }

    fn log(&self, record: &log::Record) {
        if self.enabled(record.metadata()) {
            let t = START.elapsed();
            eprintln!(
                "[{:>9.3}s {:>5} {}] {}",
                t.as_secs_f64(),
                record.level(),
                record.target().split("::").last().unwrap_or(""),
                record.args()
            );
        }
    }

    fn flush(&self) {}
}

static LOGGER: Lazy<StderrLogger> = Lazy::new(|| {
    let spec = std::env::var("R3BFT_LOG").unwrap_or_default();
    let (default, directives) = parse_spec(&spec);
    StderrLogger { default, directives }
});

/// Install the logger (idempotent). Filtering comes from `R3BFT_LOG`
/// (default `info`) — see the module docs for the directive syntax.
pub fn init() {
    if INSTALLED.swap(true, Ordering::SeqCst) {
        return;
    }
    let logger: &'static StderrLogger = &LOGGER;
    // the facade's fast path gates on the max over every directive, so
    // an upgraded target actually gets through to per-target filtering
    let max = logger
        .directives
        .iter()
        .map(|d| d.level)
        .fold(logger.default, log::LevelFilter::max);
    let _ = log::set_logger(logger);
    log::set_max_level(max);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger test line");
    }

    #[test]
    fn spec_parses_default_and_directives() {
        let (default, dirs) = parse_spec("protocol=debug, transport=warn ,warn");
        assert_eq!(default, log::LevelFilter::Warn);
        assert_eq!(dirs.len(), 2);
        assert_eq!(dirs[0].target, "protocol");
        assert_eq!(dirs[0].level, log::LevelFilter::Debug);
        assert_eq!(dirs[1].target, "transport");
        assert_eq!(dirs[1].level, log::LevelFilter::Warn);
    }

    #[test]
    fn garbage_is_ignored() {
        let (default, dirs) = parse_spec("nonsense=verybad,,=,");
        assert_eq!(default, log::LevelFilter::Info);
        assert!(dirs.is_empty());
    }

    #[test]
    fn target_matching_is_per_component_and_prefix() {
        let t = "r3bft::coordinator::protocol";
        assert_eq!(match_depth("protocol", t), Some(2));
        assert_eq!(match_depth("coordinator", t), Some(1));
        assert_eq!(match_depth("r3bft::coordinator", t), Some(1));
        assert_eq!(match_depth("r3bft::coordinator::protocol", t), Some(2));
        assert_eq!(match_depth("proto", t), None);
        assert_eq!(match_depth("transport", t), None);
    }

    #[test]
    fn deepest_match_wins() {
        let (default, dirs) = parse_spec("coordinator=warn,protocol=trace");
        let target = "r3bft::coordinator::protocol";
        assert_eq!(level_for(default, &dirs, target), log::LevelFilter::Trace);
        assert_eq!(
            level_for(default, &dirs, "r3bft::coordinator::master"),
            log::LevelFilter::Warn
        );
        assert_eq!(level_for(default, &dirs, "r3bft::runtime"), log::LevelFilter::Info);
    }
}
