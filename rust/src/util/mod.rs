//! Substrate utilities.
//!
//! The offline image vendors only the `xla` crate's dependency closure,
//! so the usual ecosystem crates (rand, serde, clap, criterion,
//! proptest) are unavailable; this module provides the small, tested
//! replacements the rest of the crate builds on.

pub mod args;
pub mod bench;
pub mod json;
pub mod logger;
pub mod quickcheck;
pub mod rng;
pub mod stats;

pub use rng::Pcg64;
