//! Minimal JSON parser (serde is unavailable offline).
//!
//! Supports the full JSON grammar needed by `artifacts/manifest.json`
//! and the experiment result files: objects, arrays, strings with
//! escapes, numbers, booleans, null. Also includes a writer used by
//! the bench harness to emit machine-readable results.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Required-field helpers returning descriptive errors.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key)
            .ok_or_else(|| JsonError(format!("missing field '{key}'")))
    }

    pub fn req_str(&self, key: &str) -> Result<&str, JsonError> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| JsonError(format!("field '{key}' is not a string")))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize, JsonError> {
        self.req(key)?
            .as_f64()
            .map(|n| n as usize)
            .ok_or_else(|| JsonError(format!("field '{key}' is not a number")))
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Json], JsonError> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| JsonError(format!("field '{key}' is not an array")))
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError(pub String);

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error: {}", self.0)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError(format!("{msg} at byte {}", self.i))
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // copy a run of plain bytes
                    let start = self.i;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

/// Serialize (used for machine-readable bench output).
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-12e2").unwrap(), Json::Num(-1200.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(j.get("c"), Some(&Json::Null));
        let arr = j.req_arr("a").unwrap();
        assert_eq!(arr[0], Json::Num(1.0));
        assert_eq!(arr[2].req_str("b").unwrap(), "x\ny");
    }

    #[test]
    fn escapes() {
        let j = Json::parse(r#""a\"b\\cA""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\"b\\cA");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn roundtrip_display() {
        let src = r#"{"arr":[1,2.5,"s"],"b":true,"n":null}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn manifest_shape() {
        let src = r#"{"version":1,"artifacts":[{"name":"x","param_dim":64,
            "inputs":[{"dtype":"f32","shape":[256,64]}]}]}"#;
        let j = Json::parse(src).unwrap();
        let arts = j.req_arr("artifacts").unwrap();
        assert_eq!(arts[0].req_str("name").unwrap(), "x");
        assert_eq!(arts[0].req_usize("param_dim").unwrap(), 64);
    }
}
